//! Quickstart: simulate one workload on the AVX baseline and on VIMA, and
//! (if `make artifacts` has been run) verify the VIMA instruction stream
//! *functionally* through the PJRT runtime.
//!
//! Run: `cargo run --release --example quickstart`

use vima_sim::util::error::Result;
use vima_sim::config::SystemConfig;
use vima_sim::isa::TraceEvent;
use vima_sim::runtime::functional::FunctionalVima;
use vima_sim::runtime::{default_artifacts_dir, Engine};
use vima_sim::sim::simulate;
use vima_sim::trace::{layout, Backend, KernelId, TraceParams};

fn main() -> Result<()> {
    let cfg = SystemConfig::default();
    let footprint = 12u64 << 20; // 12 MB total (three 4 MB arrays)

    // --- timing: VecSum on both backends --------------------------------
    let avx = simulate(&cfg, TraceParams::new(KernelId::VecSum, Backend::Avx, footprint))?;
    let vima = simulate(&cfg, TraceParams::new(KernelId::VecSum, Backend::Vima, footprint))?;
    println!("VecSum, {} MB total footprint:", footprint >> 20);
    println!("  AVX  baseline: {:>12} cycles  {:>10.6} J", avx.cycles, avx.energy.total_j);
    println!("  VIMA         : {:>12} cycles  {:>10.6} J", vima.cycles, vima.energy.total_j);
    println!(
        "  speedup {:.2}x, energy {:.1}% of baseline",
        vima.speedup_vs(&avx),
        vima.energy_ratio_vs(&avx) * 100.0
    );

    // --- functional: replay the first VIMA instructions through PJRT ----
    match Engine::new(default_artifacts_dir()) {
        Ok(engine) => {
            let mut fx = FunctionalVima::new(engine);
            // Seed functional memory for the first 4 vector triples.
            let elems = 2048usize;
            for v in 0..4u64 {
                let base = v * 8192;
                let a: Vec<f32> = (0..elems).map(|i| (v as f32) + i as f32 * 0.001).collect();
                let b: Vec<f32> = (0..elems).map(|i| 1.0 + i as f32 * 0.002).collect();
                fx.write_vector(layout::A + base, a);
                fx.write_vector(layout::B + base, b);
            }
            let trace = TraceParams::new(KernelId::VecSum, Backend::Vima, 4 * 3 * 8192);
            for ev in trace.stream()? {
                if let TraceEvent::Vima(instr) = ev {
                    fx.execute(&instr)?;
                }
            }
            // Check c = a + b elementwise for every produced vector.
            let mut checked = 0;
            for v in 0..4u64 {
                let base = v * 8192;
                let a = fx.read_vector(layout::A + base).unwrap().to_vec();
                let b = fx.read_vector(layout::B + base).unwrap().to_vec();
                let c = fx.read_vector(layout::C + base).expect("result vector");
                for i in 0..elems {
                    assert!((c[i] - (a[i] + b[i])).abs() < 1e-5, "mismatch at {v}/{i}");
                    checked += 1;
                }
            }
            println!(
                "\nfunctional check: {} VIMA instructions executed via PJRT, {checked} elements verified",
                fx.executed
            );
        }
        Err(e) => {
            println!("\n(skipping functional check: {e}; run `make artifacts` first)");
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
