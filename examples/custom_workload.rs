//! Custom workload end to end: define an Intrinsics-VIMA program with the
//! streaming DSL, register it, and run it through the same sweep engine the
//! paper figures use — VIMA vs the honest AVX lowering of the *same*
//! program, with result-cache dedup.
//!
//! Run: `cargo run --release --example custom_workload`

use vima_sim::prelude::*;
use vima_sim::util::error::Result;

fn main() -> Result<()> {
    // --- 1. write the program (y += a*x, then a dot-product check) -------
    let mut p = VimaProgram::new();
    let vb = p.vector_bytes() as u64;
    let vectors = 128u64;
    let alpha = p.alloc(vb);
    let x = p.alloc(vectors * vb);
    let y = p.alloc(vectors * vb);
    p.vim2k_sets(alpha);
    p.vloop(vectors, |l| {
        l.vim2k_fmadds(alpha, x.walk(vb), y.walk(vb), y.walk(vb));
    });
    p.vim2k_dots(x, y);
    println!(
        "program: {} vector instructions, {} trace events, {} MB footprint",
        p.instructions(),
        p.events(),
        p.footprint() >> 20
    );

    // --- 2. register it: now it is a first-class workload ----------------
    p.register("axpy-dot")?; // addressable by name from here on

    // --- 3. run it through the deduplicating sweep engine ----------------
    let cfg = SystemConfig::default();
    let runner = SweepRunner::new(0);
    let w = SizedWorkload::custom("axpy-dot")?;
    let mut plan = SweepPlan::new();
    let avx = plan.push(RunCell::new(w, Backend::Avx));
    let vima = plan.push(RunCell::new(w, Backend::Vima));
    // The same cell again: served from the result cache, never re-simulated.
    let dup = plan.push(RunCell::new(w, Backend::Vima));
    let res = runner.run(&cfg, &plan)?;

    let (a, v) = (&res[avx], &res[vima]);
    println!("AVX lowering : {:>12} cycles  {:>10.6} J", a.cycles, a.energy.total_j);
    println!("VIMA         : {:>12} cycles  {:>10.6} J", v.cycles, v.energy.total_j);
    println!(
        "speedup {:.2}x, energy {:.1}% of baseline",
        v.speedup_vs(a),
        v.energy_ratio_vs(a) * 100.0
    );
    assert_eq!(res[dup].cycles, res[vima].cycles);
    let stats = runner.stats();
    println!(
        "sweep accounting: {} cells -> {} simulations, {} cache hit(s)",
        stats.cells, stats.unique_runs, stats.cache_hits
    );

    // --- 4. the two shipped example programs, via the Experiment ---------
    let exp = Experiment::new(cfg, vima_sim::coordinator::workloads::SizeScale::Quick);
    println!("\n{}", exp.custom_programs()?.to_markdown());
    Ok(())
}
