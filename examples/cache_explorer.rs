//! Cache explorer: the Fig. 5 design-space exploration interactively —
//! sweep the VIMA cache size and the vector size, show hit rates and
//! speedups for the reuse-heavy kernels.
//!
//! Run: `cargo run --release --example cache_explorer [-- --paper]`

use vima_sim::config::SystemConfig;
use vima_sim::sim::simulate;
use vima_sim::trace::{Backend, KernelId, TraceParams};
use vima_sim::util::cli::Args;

fn main() {
    let args = Args::parse();
    let footprint: u64 = if args.flag("paper") { 64 << 20 } else { 4 << 20 };
    let base_cfg = SystemConfig::default();

    println!("== VIMA cache size sweep (Stencil, {} MB) ==", footprint >> 20);
    println!(
        "{:<9} {:>7} {:>14} {:>10} {:>10} {:>9}",
        "cache", "lines", "vima cycles", "hits", "misses", "speedup"
    );
    let avx =
        simulate(&base_cfg, TraceParams::new(KernelId::Stencil, Backend::Avx, footprint)).unwrap();
    for kb in [8usize, 16, 32, 64, 128, 256] {
        let mut cfg = base_cfg.clone();
        cfg.vima.cache_bytes = kb << 10;
        let r =
            simulate(&cfg, TraceParams::new(KernelId::Stencil, Backend::Vima, footprint)).unwrap();
        println!(
            "{:<9} {:>7} {:>14} {:>10} {:>10} {:>8.2}x",
            format!("{kb}KB"),
            kb * 1024 / cfg.vima.vector_bytes,
            r.cycles,
            r.report.get("vima.vcache_hits").unwrap_or(0.0),
            r.report.get("vima.vcache_misses").unwrap_or(0.0),
            avx.cycles as f64 / r.cycles as f64,
        );
    }

    println!("\n== Vector size ablation (VecSum, {} MB; Sec. III-C) ==", footprint >> 20);
    println!("{:<9} {:>14} {:>10} {:>22}", "vector", "vima cycles", "speedup", "vs 8KB configuration");
    let avx =
        simulate(&base_cfg, TraceParams::new(KernelId::VecSum, Backend::Avx, footprint)).unwrap();
    let mut best = None;
    let mut rows = Vec::new();
    for vb in [256u32, 512, 1024, 2048, 4096, 8192] {
        let mut cfg = base_cfg.clone();
        cfg.vima.vector_bytes = vb as usize;
        let p = TraceParams::new(KernelId::VecSum, Backend::Vima, footprint).with_vector_bytes(vb);
        let r = simulate(&cfg, p).unwrap();
        if vb == 8192 {
            best = Some(r.cycles);
        }
        rows.push((vb, r.cycles, avx.cycles as f64 / r.cycles as f64));
    }
    let best = best.unwrap();
    for (vb, cycles, speedup) in rows {
        println!(
            "{:<9} {:>14} {:>9.2}x {:>21.1}%",
            format!("{vb}B"),
            cycles,
            speedup,
            (cycles as f64 / best as f64 - 1.0) * 100.0
        );
    }
    println!("\n(the paper reports 256 B vectors ~74% worse than 8 KB on average)");
}
