//! Streaming suite: the paper's intro motivation — MemSet / MemCopy / VecSum
//! across dataset sizes on all three systems (AVX baseline, HIVE, VIMA),
//! i.e. a superset of Fig. 2's kernels with per-size detail.
//!
//! Run: `cargo run --release --example streaming_suite [-- --paper]`

use vima_sim::config::SystemConfig;
use vima_sim::sim::simulate;
use vima_sim::trace::{Backend, KernelId, TraceParams};
use vima_sim::util::cli::Args;

fn main() {
    let args = Args::parse();
    let sizes: &[u64] = if args.flag("paper") {
        &[4 << 20, 16 << 20, 64 << 20]
    } else {
        &[1 << 20, 4 << 20]
    };
    let cfg = SystemConfig::default();

    println!(
        "{:<10} {:>6} {:>14} {:>14} {:>14} {:>12} {:>12}",
        "kernel", "MB", "avx cycles", "hive cycles", "vima cycles", "hive spdup", "vima spdup"
    );
    for kernel in [KernelId::MemSet, KernelId::MemCopy, KernelId::VecSum] {
        for &bytes in sizes {
            let avx = simulate(&cfg, TraceParams::new(kernel, Backend::Avx, bytes)).unwrap();
            let hive = simulate(&cfg, TraceParams::new(kernel, Backend::Hive, bytes)).unwrap();
            let vima = simulate(&cfg, TraceParams::new(kernel, Backend::Vima, bytes)).unwrap();
            println!(
                "{:<10} {:>6} {:>14} {:>14} {:>14} {:>11.2}x {:>11.2}x",
                kernel.to_string(),
                bytes >> 20,
                avx.cycles,
                hive.cycles,
                vima.cycles,
                hive.speedup_vs(&avx),
                vima.speedup_vs(&avx),
            );
        }
    }

    println!("\nEnergy breakdown for VecSum at {} MB:", sizes[sizes.len() - 1] >> 20);
    let bytes = sizes[sizes.len() - 1];
    for (name, backend) in [("AVX", Backend::Avx), ("VIMA", Backend::Vima)] {
        let r = simulate(&cfg, TraceParams::new(KernelId::VecSum, backend, bytes)).unwrap();
        let e = &r.energy;
        println!(
            "  {name:<5} total={:.6} J  core={:.6}  caches={:.6}  dram={:.6}  vima={:.6}",
            e.total_j,
            e.core_j,
            e.cache_dynamic_j + e.cache_static_j,
            e.dram_dynamic_j + e.dram_static_j,
            e.vima_j
        );
    }
}
