//! End-to-end driver: MLP inference through ALL THREE LAYERS.
//!
//! 1. **Functional** (L1 Pallas -> L2 JAX -> AOT HLO -> Rust PJRT): loads the
//!    `mlp_logits_f32` / `mlp_inference_i32` artifacts, runs a real batch of
//!    inputs, and verifies the numerics against a pure-Rust oracle.
//! 2. **Temporal** (L3 cycle model): simulates the paper's MLP workload
//!    (Sec. IV-A: 16384 instances, F in {64, 256, 1024}) on the AVX baseline
//!    and on VIMA, reporting the Fig. 3 speedup/energy cells.
//!
//! This is the composition proof: the same system definition produces
//! validated values (through PJRT) and validated time/energy (through the
//! simulator), with Python nowhere at run time.
//!
//! Run: `make artifacts && cargo run --release --example mlp_e2e`

use vima_sim::util::error::Result;
use vima_sim::config::SystemConfig;
use vima_sim::runtime::{default_artifacts_dir, literal_f32, Engine};
use vima_sim::sim::simulate;
use vima_sim::trace::{Backend, KernelId, TraceParams};
use vima_sim::util::Rng;

const B: usize = 32; // batch
const F: usize = 256; // features
const H: usize = 256; // hidden
const C: usize = 16; // classes

fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    // sum of uniforms ~ gaussian-ish; deterministic
    (0..n)
        .map(|_| (rng.f32(-1.0, 1.0) + rng.f32(-1.0, 1.0) + rng.f32(-1.0, 1.0)) * scale)
        .collect()
}

/// Pure-Rust oracle for relu(W1 x + b1) -> W2 h + b2.
fn mlp_logits_oracle(x: &[f32], w1: &[f32], b1: &[f32], w2: &[f32], b2: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; B * C];
    for i in 0..B {
        let xi = &x[i * F..(i + 1) * F];
        let mut h = vec![0f32; H];
        for r in 0..H {
            let mut acc = b1[r];
            for c in 0..F {
                acc += w1[r * F + c] * xi[c];
            }
            h[r] = acc.max(0.0);
        }
        for r in 0..C {
            let mut acc = b2[r];
            for c in 0..H {
                acc += w2[r * H + c] * h[c];
            }
            out[i * C + r] = acc;
        }
    }
    out
}

fn main() -> Result<()> {
    println!("=== VIMA end-to-end: MLP inference ===\n");

    // ---------- functional half: PJRT artifacts ----------
    let mut engine = Engine::new(default_artifacts_dir())?;
    let mut rng = Rng::new(0x1157);
    let x = randn(&mut rng, B * F, 1.0);
    let w1 = randn(&mut rng, H * F, 0.08);
    let b1 = randn(&mut rng, H, 0.05);
    let w2 = randn(&mut rng, C * H, 0.08);
    let b2 = randn(&mut rng, C, 0.05);

    let logits = engine.execute_f32("mlp_logits_f32", &[&x, &w1, &b1, &w2, &b2])?;
    let oracle = mlp_logits_oracle(&x, &w1, &b1, &w2, &b2);
    let max_err =
        logits.iter().zip(&oracle).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    println!(
        "[functional] mlp_logits_f32 via PJRT: {} logits, max |err| vs oracle = {max_err:.2e}",
        logits.len()
    );
    vima_sim::ensure!(max_err < 1e-3, "numeric mismatch vs oracle");

    // predicted classes through the int artifact
    let preds_lit = engine.execute(
        "mlp_inference_i32",
        &[
            literal_f32(&x, &[B, F])?,
            literal_f32(&w1, &[H, F])?,
            literal_f32(&b1, &[H])?,
            literal_f32(&w2, &[C, H])?,
            literal_f32(&b2, &[C])?,
        ],
    )?;
    let preds = preds_lit.to_vec::<i32>().map_err(|e| vima_sim::util::error::Error::msg(format!("{e:?}")))?;
    let oracle_preds: Vec<i32> = (0..B)
        .map(|i| {
            (0..C)
                .max_by(|&a, &b| oracle[i * C + a].partial_cmp(&oracle[i * C + b]).unwrap())
                .unwrap() as i32
        })
        .collect();
    let agree = preds.iter().zip(&oracle_preds).filter(|(a, b)| a == b).count();
    println!("[functional] mlp_inference_i32: {agree}/{B} class predictions match the oracle");
    vima_sim::ensure!(agree == B, "classification mismatch");

    // ---------- temporal half: cycle-level simulation ----------
    println!("\n[temporal] paper MLP workload (16384 instances), AVX vs VIMA:");
    println!(
        "{:<10} {:>14} {:>14} {:>9} {:>13}",
        "features", "avx cycles", "vima cycles", "speedup", "energy ratio"
    );
    let cfg = SystemConfig::default();
    for (mb, label) in [(4u64, "64"), (16, "256"), (64, "1024")] {
        let avx = simulate(&cfg, TraceParams::new(KernelId::Mlp, Backend::Avx, mb << 20))?;
        let vima = simulate(&cfg, TraceParams::new(KernelId::Mlp, Backend::Vima, mb << 20))?;
        println!(
            "{label:<10} {:>14} {:>14} {:>8.2}x {:>12.1}%",
            avx.cycles,
            vima.cycles,
            vima.speedup_vs(&avx),
            vima.energy_ratio_vs(&avx) * 100.0
        );
    }
    println!("\nmlp_e2e OK: three layers composed (Pallas kernels -> HLO -> PJRT) + timing model.");
    Ok(())
}
