//! The VIMA logic layer (Sec. III-D): instruction sequencer, vector cache,
//! fill buffer, and the 256-lane vector functional units.
//!
//! Timing protocol per instruction (all converted to CPU cycles):
//!
//! 1. The instruction arrives from the processor (`inst_lat` cycles).
//! 2. The sequencer checks the VIMA cache for each unique source vector.
//!    Misses split into 128 x 64 B sub-requests issued across vaults/banks
//!    through the device's [`MemPort`] — a raw
//!    [`Mem3D`](crate::mem3d::Mem3D) or a routing
//!    [`FabricPort`](crate::fabric::FabricPort); *both* operands of a
//!    two-source instruction fetch in parallel (Sec. IV-B1). A hit costs
//!    one tag-check cycle.
//! 3. Operand vectors stream from the cache to the FUs over the 2 cache
//!    ports in `beats` pipelined transfers; the FU array adds its remaining
//!    pipeline depth (Table I: int alu/mul/div 8-12-28, fp 13-13-28 for a
//!    full 8 KB vector).
//! 4. The result lands in the fill buffer; its write into the VIMA cache is
//!    hidden inside the stop-and-go gap (Sec. III-D), so only dirty
//!    *evictions* cost DRAM writes.
//! 5. A status signal returns to the processor (`inst_lat` cycles).

pub mod vcache;

pub use vcache::VCache;

use crate::config::VimaConfig;
use crate::isa::{VDtype, VimaFuKind, VimaInstr};
use crate::mem3d::MemPort;
use crate::stats::StatsReport;
use crate::util::error::Result;

#[derive(Debug, Default, Clone)]
pub struct VimaStats {
    pub instructions: u64,
    pub vector_fetches: u64,
    pub fetch_cycles_sum: u64,
    pub compute_cycles_sum: u64,
    pub busy_until: u64,
    pub writeback_vectors: u64,
}

/// The VIMA device on the 3D-stack logic layer.
pub struct VimaDevice {
    pub cfg: VimaConfig,
    cpu_ghz: f64,
    inst_lat: u64,
    pub vcache: VCache,
    /// Next-free per FU pipeline: [int_alu, int_mul, int_div, fp_alu, fp_mul, fp_div].
    fu_free: [u64; 6],
    pub stats: VimaStats,
}

impl VimaDevice {
    pub fn new(cfg: &VimaConfig, inst_lat: u64, cpu_ghz: f64) -> Self {
        Self {
            vcache: VCache::new(cfg.cache_lines(), cfg.vector_bytes),
            fu_free: [0; 6],
            cpu_ghz,
            inst_lat,
            stats: VimaStats::default(),
            cfg: cfg.clone(),
        }
    }

    fn fu_index(dtype: VDtype, kind: VimaFuKind) -> usize {
        let base = if dtype.is_float() { 3 } else { 0 };
        base + match kind {
            VimaFuKind::Alu => 0,
            VimaFuKind::Mul => 1,
            VimaFuKind::Div => 2,
        }
    }

    /// Table-I pipelined latency for a full vector of this class, VIMA cycles.
    fn fu_total_lat(&self, dtype: VDtype, kind: VimaFuKind) -> u64 {
        match (dtype.is_float(), kind) {
            (false, VimaFuKind::Alu) => self.cfg.int_alu_lat,
            (false, VimaFuKind::Mul) => self.cfg.int_mul_lat,
            (false, VimaFuKind::Div) => self.cfg.int_div_lat,
            (true, VimaFuKind::Alu) => self.cfg.fp_alu_lat,
            (true, VimaFuKind::Mul) => self.cfg.fp_mul_lat,
            (true, VimaFuKind::Div) => self.cfg.fp_div_lat,
        }
    }

    /// Fetch one vector (or partial vector of `bytes`) into the VIMA cache.
    /// Returns the cycle its data is available to the FUs.
    fn fetch_vector(&mut self, base: u64, bytes: u32, at: u64, mem: &mut impl MemPort) -> u64 {
        self.stats.vector_fetches += 1;
        if self.vcache.lookup(base) {
            // Tag check only; data streams during the compute beats.
            return at + self.cfg.to_cpu_cycles(self.cfg.cache_tag_lat, self.cpu_ghz);
        }
        // Miss: split into 64 B sub-requests over vaults and banks.
        let subs = (bytes as u64).div_ceil(64);
        let mut ready = at;
        for i in 0..subs {
            let c = mem.vima_access(base + i * 64, false, at);
            ready = ready.max(c.done);
        }
        if let Some((victim, vbytes)) = self.vcache.insert_sized(base, false, bytes) {
            self.writeback_vector(victim, vbytes, ready, mem);
        }
        self.stats.fetch_cycles_sum += ready - at;
        ready
    }

    /// Posted write-back of a dirty vector (sub-requests across vaults).
    fn writeback_vector(&mut self, base: u64, bytes: u32, at: u64, mem: &mut impl MemPort) {
        self.stats.writeback_vectors += 1;
        let subs = (bytes as u64).div_ceil(64);
        for i in 0..subs {
            mem.vima_access(base + i * 64, true, at);
        }
    }

    /// Execute one VIMA instruction dispatched by the processor at
    /// `dispatch`. Returns the cycle the completion signal reaches the CPU.
    ///
    /// An instruction whose vector exceeds the configured device vector is
    /// a typed error — it used to be a `debug_assert!` that release builds
    /// silently waved through, yielding nonsense timing.
    pub fn execute(
        &mut self,
        instr: &VimaInstr,
        dispatch: u64,
        mem: &mut impl MemPort,
    ) -> Result<u64> {
        crate::ensure!(
            instr.vector_bytes as usize <= self.cfg.vector_bytes,
            "VIMA instruction vector ({} B) exceeds the configured device vector ({} B)",
            instr.vector_bytes,
            self.cfg.vector_bytes
        );
        self.stats.instructions += 1;
        let arrive = dispatch + self.inst_lat;

        // 1. Operand fetch: unique sources fetch in parallel.
        let mut operands_ready = arrive;
        let srcs = instr.unique_src_addrs();
        for &s in &srcs {
            let r = self.fetch_vector(s, instr.vector_bytes, arrive, mem);
            operands_ready = operands_ready.max(r);
        }

        // 2. FU schedule: tag + ported transfer beats + remaining pipe depth.
        let kind = instr.op.fu_kind();
        let elems = instr.vector_bytes as u64 / instr.dtype.bytes() as u64;
        let beats = elems.div_ceil(self.cfg.lanes as u64).max(1);
        let port_rounds = (instr.op.num_srcs().max(1) as u64).div_ceil(self.cfg.cache_ports as u64);
        let transfer = beats * port_rounds;
        // Table I's pipelined FU latency covers transfer + drain of the
        // instruction's own beats; the remaining depth is the total minus
        // the *actual* beat count. The old hardcoded `- 8` assumed a full
        // 8 KB f32 vector (8 beats), undercounting the pipeline depth of
        // small-vector (ablation) instructions and 64-bit dtypes.
        let depth = self.fu_total_lat(instr.dtype, kind).saturating_sub(beats);
        let duration_vima = self.cfg.cache_tag_lat + transfer + depth + self.cfg.cache_beat_lat;
        let duration = self.cfg.to_cpu_cycles(duration_vima, self.cpu_ghz);

        let fu = Self::fu_index(instr.dtype, kind);
        let start = operands_ready.max(self.fu_free[fu]);
        let done = start + duration;
        self.fu_free[fu] = done;
        self.stats.compute_cycles_sum += duration;
        self.stats.busy_until = self.stats.busy_until.max(done);

        // 3. Result to fill buffer -> VIMA cache (hidden in the dispatch gap).
        if instr.op.writes_vector() {
            if let Some(dst) = instr.dst() {
                if let Some((victim, vbytes)) = self.vcache.insert_sized(dst, true, instr.vector_bytes)
                {
                    self.writeback_vector(victim, vbytes, done, mem);
                }
            }
        }

        // 4. Status signal back to the processor.
        Ok(done + self.inst_lat)
    }

    /// Functional-phase twin of [`execute`](Self::execute): replays the
    /// exact vector-cache lookup/insert order (so tags, LRU stamps, dirty
    /// bits and the hit/miss/eviction counters stay bit-identical to
    /// detailed execution) and counts every 64 B DRAM sub-request through
    /// `mem`, but touches no FU pipeline, accrues no fetch/compute cycle
    /// sums and leaves `busy_until` alone — those are durations, measured
    /// only inside detailed sample windows (DESIGN.md §11).
    pub fn execute_functional(
        &mut self,
        instr: &VimaInstr,
        mut mem: impl FnMut(u64, bool),
    ) -> Result<()> {
        crate::ensure!(
            instr.vector_bytes as usize <= self.cfg.vector_bytes,
            "VIMA instruction vector ({} B) exceeds the configured device vector ({} B)",
            instr.vector_bytes,
            self.cfg.vector_bytes
        );
        self.stats.instructions += 1;
        for &s in &instr.unique_src_addrs() {
            self.fetch_vector_functional(s, instr.vector_bytes, &mut mem);
        }
        if instr.op.writes_vector() {
            if let Some(dst) = instr.dst() {
                if let Some((victim, vbytes)) =
                    self.vcache.insert_sized(dst, true, instr.vector_bytes)
                {
                    self.writeback_vector_functional(victim, vbytes, &mut mem);
                }
            }
        }
        Ok(())
    }

    /// Functional [`fetch_vector`](Self::fetch_vector): same cache calls,
    /// no latency accounting.
    fn fetch_vector_functional(
        &mut self,
        base: u64,
        bytes: u32,
        mem: &mut impl FnMut(u64, bool),
    ) {
        self.stats.vector_fetches += 1;
        if self.vcache.lookup(base) {
            return;
        }
        let subs = (bytes as u64).div_ceil(64);
        for i in 0..subs {
            mem(base + i * 64, false);
        }
        if let Some((victim, vbytes)) = self.vcache.insert_sized(base, false, bytes) {
            self.writeback_vector_functional(victim, vbytes, mem);
        }
    }

    /// Functional [`writeback_vector`](Self::writeback_vector).
    fn writeback_vector_functional(
        &mut self,
        base: u64,
        bytes: u32,
        mem: &mut impl FnMut(u64, bool),
    ) {
        self.stats.writeback_vectors += 1;
        let subs = (bytes as u64).div_ceil(64);
        for i in 0..subs {
            mem(base + i * 64, true);
        }
    }

    /// Functional [`flush_vector`](Self::flush_vector) (dispatcher
    /// coherence during fast-forward phases).
    pub fn flush_vector_functional(&mut self, base: u64, mut mem: impl FnMut(u64, bool)) -> bool {
        if let Some(bytes) = self.vcache.clean(base) {
            self.writeback_vector_functional(base, bytes, &mut mem);
            true
        } else {
            false
        }
    }

    /// Functional [`invalidate`](Self::invalidate) (host wrote the vector
    /// during a fast-forward phase).
    pub fn invalidate_functional(&mut self, base: u64, mut mem: impl FnMut(u64, bool)) {
        if let Some(bytes) = self.vcache.invalidate(base) {
            self.writeback_vector_functional(base, bytes, &mut mem);
        }
    }

    /// Fabric coherence (DESIGN.md §10): if this device holds `base`
    /// dirty, post its write-back and downgrade the copy to clean —
    /// called by the dispatcher before a *sibling* cube's device gathers
    /// the vector, so cross-cube reads never observe data that only
    /// exists in another logic layer's cache. Returns whether a
    /// write-back was issued.
    pub fn flush_vector(&mut self, base: u64, at: u64, mem: &mut impl MemPort) -> bool {
        if let Some(bytes) = self.vcache.clean(base) {
            self.writeback_vector(base, bytes, at, mem);
            true
        } else {
            false
        }
    }

    /// Host-coherence invalidation of one vector (processor wrote to it).
    /// Writes back the resident line's actual touched size — partial
    /// vectors and small-vector (ablation) instructions on a large-vector
    /// device must not bill a full `cfg.vector_bytes` of DRAM traffic.
    pub fn invalidate(&mut self, base: u64, at: u64, mem: &mut impl MemPort) {
        if let Some(bytes) = self.vcache.invalidate(base) {
            self.writeback_vector(base, bytes, at, mem);
        }
    }

    /// End-of-run drain: write back every dirty resident vector.
    /// Returns when memory settles.
    pub fn drain(&mut self, at: u64, mem: &mut impl MemPort) -> u64 {
        for (base, bytes) in self.vcache.dirty_lines() {
            self.writeback_vector(base, bytes, at, mem);
            let _ = self.vcache.invalidate(base);
        }
        mem.drained_at().max(at)
    }

    pub fn dump_stats(&self, report: &mut StatsReport) {
        let s = &self.stats;
        report.add("vima.instructions", s.instructions as f64);
        report.add("vima.vector_fetches", s.vector_fetches as f64);
        report.add("vima.vcache_hits", self.vcache.hits as f64);
        report.add("vima.vcache_misses", self.vcache.misses as f64);
        report.add("vima.vcache_dirty_evictions", self.vcache.dirty_evictions as f64);
        report.add("vima.writeback_vectors", s.writeback_vectors as f64);
        report.add("vima.fetch_cycles_sum", s.fetch_cycles_sum as f64);
        report.add("vima.compute_cycles_sum", s.compute_cycles_sum as f64);
        report.add("vima.busy_until", s.busy_until as f64);
    }

    pub fn reset(&mut self) {
        self.vcache.reset();
        self.fu_free = [0; 6];
        self.stats = VimaStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mem3DConfig, VimaConfig};
    use crate::isa::VimaOp;
    use crate::mem3d::Mem3D;

    fn setup() -> (VimaDevice, Mem3D) {
        let vcfg = VimaConfig::default();
        let mcfg = Mem3DConfig::default();
        (VimaDevice::new(&vcfg, 1, 2.0), Mem3D::new(&mcfg, 2.0).unwrap())
    }

    fn add_instr(a: u64, b: u64, dst: u64) -> VimaInstr {
        VimaInstr::new(VimaOp::Add, VDtype::F32, &[a, b], Some(dst), 8192)
    }

    #[test]
    fn cold_instruction_pays_fetch_plus_compute() {
        let (mut v, mut mem) = setup();
        let done = v.execute(&add_instr(0x0000, 0x4000, 0x8000), 0, &mut mem).unwrap();
        // fetch (~60-150 cycles for 128 parallel subreqs) + compute (~28).
        assert!(done > 50 && done < 400, "cold add latency {done}");
        assert_eq!(v.vcache.misses, 2);
        assert_eq!(mem.stats.vima_reads, 256);
    }

    #[test]
    fn cache_hit_skips_dram() {
        let (mut v, mut mem) = setup();
        let t1 = v.execute(&add_instr(0x0000, 0x4000, 0x8000), 0, &mut mem).unwrap();
        let reads = mem.stats.vima_reads;
        // Same operands again: both hit, no new DRAM reads.
        let t2 = v.execute(&add_instr(0x0000, 0x4000, 0xA000), t1, &mut mem).unwrap();
        assert_eq!(mem.stats.vima_reads, reads);
        assert!(t2 - t1 < 60, "hit latency {}", t2 - t1);
    }

    #[test]
    fn result_reuse_hits_fill_buffer_line() {
        let (mut v, mut mem) = setup();
        // c = a + b; d = c + a -> c must hit (it was filled by instr 1).
        let t1 = v.execute(&add_instr(0x0000, 0x2000, 0x4000), 0, &mut mem).unwrap();
        let reads = mem.stats.vima_reads;
        v.execute(&add_instr(0x4000, 0x0000, 0x6000), t1, &mut mem).unwrap();
        assert_eq!(mem.stats.vima_reads, reads, "result vector should be cache-resident");
    }

    #[test]
    fn streaming_evicts_dirty_results() {
        let (mut v, mut mem) = setup();
        let mut t = 0;
        // 20 distinct adds: 40 source vectors + 20 results >> 8 lines.
        for i in 0..20u64 {
            let base = i * 0x6000;
            t = v.execute(&add_instr(base, base + 0x2000, base + 0x4000), t, &mut mem).unwrap();
        }
        assert!(v.vcache.dirty_evictions > 0, "results must evict as dirty");
        assert!(mem.stats.vima_writes > 0);
    }

    #[test]
    fn dot_writes_no_vector() {
        let (mut v, mut mem) = setup();
        let i = VimaInstr::new(VimaOp::Dot, VDtype::F32, &[0x0, 0x2000], None, 8192);
        v.execute(&i, 0, &mut mem).unwrap();
        assert_eq!(v.vcache.dirty_lines().len(), 0);
    }

    #[test]
    fn bcast_needs_no_fetch() {
        let (mut v, mut mem) = setup();
        let i = VimaInstr::new(VimaOp::Bcast, VDtype::I32, &[], Some(0x2000), 8192);
        let done = v.execute(&i, 0, &mut mem).unwrap();
        assert_eq!(mem.stats.vima_reads, 0);
        assert!(done < 50, "memset instr is compute-only: {done}");
        assert_eq!(v.vcache.dirty_lines(), vec![(0x2000, 8192)]);
    }

    #[test]
    fn int_alu_faster_than_fp_div() {
        let (mut v1, mut m1) = setup();
        let (mut v2, mut m2) = setup();
        let add = VimaInstr::new(VimaOp::Add, VDtype::I32, &[0x0, 0x2000], Some(0x4000), 8192);
        let div = VimaInstr::new(VimaOp::Div, VDtype::F32, &[0x0, 0x2000], Some(0x4000), 8192);
        let t_add = v1.execute(&add, 0, &mut m1).unwrap();
        let t_div = v2.execute(&div, 0, &mut m2).unwrap();
        assert!(t_div > t_add, "div {t_div} vs add {t_add}");
    }

    #[test]
    fn smaller_vectors_lose_parallelism_per_byte() {
        let mut cfg = VimaConfig::default();
        cfg.vector_bytes = 256;
        let mut v = VimaDevice::new(&cfg, 1, 2.0);
        let mut mem = Mem3D::new(&Mem3DConfig::default(), 2.0).unwrap();
        // 32 x 256 B instructions move the same 8 KB as one big one...
        let mut t = 0;
        for i in 0..32u64 {
            let instr =
                VimaInstr::new(VimaOp::Add, VDtype::F32, &[i * 256, 0x20000 + i * 256], Some(0x40000 + i * 256), 256);
            t = v.execute(&instr, t, &mut mem).unwrap();
        }
        // ...but serially: much slower than the ~150-cycle 8 KB instruction.
        assert!(t > 400, "256 B vectors must underuse the memory: {t}");
    }

    #[test]
    fn drain_writes_back_dirty() {
        let (mut v, mut mem) = setup();
        let t = v.execute(&add_instr(0x0, 0x2000, 0x4000), 0, &mut mem).unwrap();
        let w_before = mem.stats.vima_writes;
        v.drain(t, &mut mem);
        assert!(mem.stats.vima_writes > w_before);
        assert_eq!(v.vcache.dirty_lines().len(), 0);
    }

    #[test]
    fn host_invalidate_forces_writeback() {
        let (mut v, mut mem) = setup();
        let t = v.execute(&add_instr(0x0, 0x2000, 0x4000), 0, &mut mem).unwrap();
        let w = mem.stats.vima_writes;
        v.invalidate(0x4000, t, &mut mem);
        assert!(mem.stats.vima_writes > w);
    }

    #[test]
    fn oversized_vector_is_a_typed_error() {
        // Used to be a debug_assert! — release builds simulated the
        // impossible instruction with a straight face.
        let (mut v, mut mem) = setup();
        let i = VimaInstr::new(VimaOp::Add, VDtype::F32, &[0x0, 0x4000], Some(0x8000), 16384);
        let e = v.execute(&i, 0, &mut mem).unwrap_err().to_string();
        assert!(e.contains("16384") && e.contains("8192"), "{e}");
        assert_eq!(v.stats.instructions, 0, "rejected instructions must not count");
    }

    #[test]
    fn fu_depth_uses_actual_beat_count() {
        // Table I's pipelined FU latency is fill + drain for the
        // instruction's own transfer beats, so for a fully-pipelined 2-src
        // op the duration is tag + total_lat + beat *regardless* of vector
        // length: a 256 B add streams fewer beats but still drains the
        // same pipeline. The old code subtracted a hardcoded 8 beats,
        // undercounting small-vector (ablation) and 64-bit-dtype depth.
        let duration_of = |instr: &VimaInstr| {
            let (mut v, mut mem) = setup();
            v.execute(instr, 0, &mut mem).unwrap();
            v.stats.compute_cycles_sum
        };
        let small = VimaInstr::new(VimaOp::Add, VDtype::F32, &[0x0, 0x2000], Some(0x4000), 256);
        let big = add_instr(0x0, 0x2000, 0x4000);
        let d_small = duration_of(&small);
        let d_big = duration_of(&big);
        assert_eq!(d_small, d_big, "pipelined add duration must not depend on beat count");

        // f64 streams half the beats per 8 KB; the depth term absorbs it.
        let f64_big =
            VimaInstr::new(VimaOp::Add, VDtype::F64, &[0x0, 0x2000], Some(0x4000), 8192);
        assert_eq!(duration_of(&f64_big), d_big, "f64 (4 beats) must match f32 (8 beats)");

        // A 3-src FMA is port-bound (2 cache ports): each extra beat adds
        // one port round net of the shrinking depth — 7 extra beats between
        // 256 B (1 beat) and 8 KB (8 beats) is exactly 7 VIMA cycles
        // (14 CPU cycles at the 2:1 clock ratio). Consistent scaling, not
        // the old constant-depth discount.
        let fma_small =
            VimaInstr::new(VimaOp::Fma, VDtype::F32, &[0x0, 0x2000, 0x4000], Some(0x6000), 256);
        let fma_big =
            VimaInstr::new(VimaOp::Fma, VDtype::F32, &[0x0, 0x2000, 0x4000], Some(0x6000), 8192);
        assert_eq!(duration_of(&fma_big) - duration_of(&fma_small), 14);
    }

    #[test]
    fn functional_execute_mirrors_cache_state_without_timing() {
        // Drive the same instruction stream through a detailed device and
        // a functional one: vector-cache state and event counters must be
        // bit-identical, while the functional device accrues zero timing.
        let (mut v_det, mut mem_det) = setup();
        let mut v_fun = VimaDevice::new(&VimaConfig::default(), 1, 2.0);
        let mut mem_fun = Mem3D::new(&Mem3DConfig::default(), 2.0).unwrap();
        let mut t = 0;
        for i in 0..20u64 {
            let base = i * 0x6000;
            let instr = add_instr(base, base + 0x2000, base + 0x4000);
            t = v_det.execute(&instr, t, &mut mem_det).unwrap();
            v_fun
                .execute_functional(&instr, |a, w| mem_fun.vima_access_functional(a, w))
                .unwrap();
        }
        assert_eq!(v_fun.vcache.dirty_lines(), v_det.vcache.dirty_lines());
        assert_eq!(
            (v_fun.vcache.hits, v_fun.vcache.misses, v_fun.vcache.dirty_evictions),
            (v_det.vcache.hits, v_det.vcache.misses, v_det.vcache.dirty_evictions)
        );
        assert_eq!(v_fun.stats.instructions, v_det.stats.instructions);
        assert_eq!(v_fun.stats.vector_fetches, v_det.stats.vector_fetches);
        assert_eq!(v_fun.stats.writeback_vectors, v_det.stats.writeback_vectors);
        assert_eq!(mem_fun.stats.vima_reads, mem_det.stats.vima_reads);
        assert_eq!(mem_fun.stats.vima_writes, mem_det.stats.vima_writes);
        assert_eq!(
            (v_fun.stats.busy_until, v_fun.stats.compute_cycles_sum, v_fun.stats.fetch_cycles_sum),
            (0, 0, 0),
            "functional path must accrue no timing"
        );
    }

    #[test]
    fn invalidate_writes_back_resident_size_not_config_size() {
        // Regression (vector-size ablation): a 256 B instruction on a
        // default 8 KB-vector device leaves a dirty line whose touched size
        // is 256 B. Host invalidation owes 4 x 64 B sub-request
        // write-backs — the old code billed cfg.vector_bytes (128 of them).
        let (mut v, mut mem) = setup();
        let instr = VimaInstr::new(VimaOp::Add, VDtype::F32, &[0x0, 0x2000], Some(0x4000), 256);
        let t = v.execute(&instr, 0, &mut mem).unwrap();
        let w = mem.stats.vima_writes;
        v.invalidate(0x4000, t, &mut mem);
        assert_eq!(mem.stats.vima_writes - w, 4, "256 B = 4 x 64 B write-backs");
    }
}
