//! The VIMA cache (Sec. III-D): 64 KB, fully associative, 8 lines of one
//! 8 KB vector each, LRU replacement, write-allocate from the fill buffer.
//!
//! This small cache is the paper's key physical addition over prior NDP work:
//! it turns the register bank of HIVE-class designs into an address-tagged
//! store, enabling short-term reuse of vector operands without lock/unlock
//! transactions.

/// Fully-associative vector cache. Lines are whole VIMA vectors; partial
/// vectors (e.g. MatMul rows shorter than 8 KB) occupy a full line but
/// remember their touched size for write-back accounting.
pub struct VCache {
    /// (base address, dirty, lru stamp, touched bytes); tag == u64::MAX = invalid.
    lines: Vec<(u64, bool, u64, u32)>,
    vector_bytes: u64,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub dirty_evictions: u64,
}

const INVALID: u64 = u64::MAX;

impl VCache {
    pub fn new(num_lines: usize, vector_bytes: usize) -> Self {
        assert!(num_lines >= 1, "VIMA cache needs at least one line");
        Self {
            lines: vec![(INVALID, false, 0, 0); num_lines],
            vector_bytes: vector_bytes as u64,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            dirty_evictions: 0,
        }
    }

    #[inline]
    fn tag(&self, addr: u64) -> u64 {
        addr / self.vector_bytes * self.vector_bytes
    }

    /// Probe for the vector containing `addr`; refresh LRU on hit.
    pub fn lookup(&mut self, addr: u64) -> bool {
        let tag = self.tag(addr);
        self.tick += 1;
        for l in &mut self.lines {
            if l.0 == tag {
                l.2 = self.tick;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Install the vector at `addr` (LRU eviction). Returns the base address
    /// and touched size of an evicted dirty vector that must be written back.
    pub fn insert(&mut self, addr: u64, dirty: bool) -> Option<(u64, u32)> {
        self.insert_sized(addr, dirty, self.vector_bytes as u32)
    }

    /// As [`insert`](Self::insert) with an explicit touched-bytes size
    /// (partial vectors, e.g. matrix rows shorter than one full vector).
    pub fn insert_sized(&mut self, addr: u64, dirty: bool, bytes: u32) -> Option<(u64, u32)> {
        let tag = self.tag(addr);
        self.tick += 1;
        // Already present (e.g. fill-buffer write to a resident line)?
        for l in &mut self.lines {
            if l.0 == tag {
                l.1 |= dirty;
                l.2 = self.tick;
                l.3 = l.3.max(bytes);
                return None;
            }
        }
        let mut victim = 0;
        let mut best = u64::MAX;
        for (i, l) in self.lines.iter().enumerate() {
            if l.0 == INVALID {
                victim = i;
                break;
            }
            if l.2 < best {
                best = l.2;
                victim = i;
            }
        }
        let evicted = self.lines[victim];
        let result = if evicted.0 != INVALID {
            self.evictions += 1;
            if evicted.1 {
                self.dirty_evictions += 1;
                Some((evicted.0, evicted.3))
            } else {
                None
            }
        } else {
            None
        };
        self.lines[victim] = (tag, dirty, self.tick, bytes);
        result
    }

    /// Mark the vector at `addr` dirty (fill-buffer write of a result).
    pub fn mark_dirty(&mut self, addr: u64) {
        let tag = self.tag(addr);
        for l in &mut self.lines {
            if l.0 == tag {
                l.1 = true;
                return;
            }
        }
    }

    /// Downgrade the vector at `addr` to clean, keeping it resident.
    /// Returns the touched size if it was present **and dirty** — the
    /// bytes the caller owes DRAM. Used by the fabric dispatcher: when a
    /// sibling cube's device reads a vector this device produced, the
    /// dirty copy must reach DRAM first, but the local copy stays usable.
    pub fn clean(&mut self, addr: u64) -> Option<u32> {
        let tag = self.tag(addr);
        for l in &mut self.lines {
            if l.0 == tag && l.1 {
                l.1 = false;
                return Some(l.3);
            }
        }
        None
    }

    /// Host-coherence hook (Sec. III-D): on a processor write to a cached
    /// vector, VIMA writes the line back and invalidates it. Returns the
    /// touched size of the dropped line if it was present **and dirty** —
    /// exactly the bytes the caller owes DRAM — and `None` otherwise.
    pub fn invalidate(&mut self, addr: u64) -> Option<u32> {
        let tag = self.tag(addr);
        for l in &mut self.lines {
            if l.0 == tag {
                let (was_dirty, bytes) = (l.1, l.3);
                *l = (INVALID, false, 0, 0);
                return was_dirty.then_some(bytes);
            }
        }
        None
    }

    /// Fold the complete line state into `h` (sampled-mode state-parity
    /// digests; see `Machine::state_digest`).
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        self.tick.hash(h);
        self.lines.hash(h);
    }

    /// All dirty vector (base address, touched bytes) pairs (end-of-run drain).
    pub fn dirty_lines(&self) -> Vec<(u64, u32)> {
        self.lines.iter().filter(|l| l.0 != INVALID && l.1).map(|l| (l.0, l.3)).collect()
    }

    pub fn occupancy(&self) -> usize {
        self.lines.iter().filter(|l| l.0 != INVALID).count()
    }

    pub fn num_lines(&self) -> usize {
        self.lines.len()
    }

    pub fn reset(&mut self) {
        for l in &mut self.lines {
            *l = (INVALID, false, 0, 0);
        }
        self.tick = 0;
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
        self.dirty_evictions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_lines_of_8kb() {
        let c = VCache::new(8, 8192);
        assert_eq!(c.num_lines(), 8);
        assert_eq!(c.occupancy(), 0);
    }

    #[test]
    fn hit_after_insert() {
        let mut c = VCache::new(8, 8192);
        assert!(!c.lookup(0x10000));
        c.insert(0x10000, false);
        assert!(c.lookup(0x10000));
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn sub_vector_addresses_alias_to_line() {
        let mut c = VCache::new(8, 8192);
        c.insert(0x4000, false); // vector [0x4000, 0x6000)
        assert!(c.lookup(0x4000 + 4096));
        assert!(!c.lookup(0x6000));
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = VCache::new(2, 8192);
        c.insert(0x0000, false);
        c.insert(0x2000, false);
        c.lookup(0x0000); // refresh
        c.insert(0x4000, false); // evicts 0x2000
        assert!(c.lookup(0x0000));
        assert!(!c.lookup(0x2000));
    }

    #[test]
    fn dirty_eviction_returns_base() {
        let mut c = VCache::new(1, 8192);
        c.insert(0x2000, true);
        assert_eq!(c.insert(0x6000, false), Some((0x2000, 8192)));
        assert_eq!(c.dirty_evictions, 1);
    }

    #[test]
    fn reinsert_resident_line_updates_dirty_without_eviction() {
        let mut c = VCache::new(2, 8192);
        c.insert(0x2000, false);
        assert_eq!(c.insert(0x2000, true), None);
        assert_eq!(c.evictions, 0);
        assert_eq!(c.dirty_lines(), vec![(0x2000, 8192)]);
    }

    #[test]
    fn invalidate_reports_dirty_bytes() {
        let mut c = VCache::new(4, 8192);
        c.insert(0x2000, true);
        assert_eq!(c.invalidate(0x2000), Some(8192));
        assert_eq!(c.invalidate(0x2000), None);
        assert_eq!(c.occupancy(), 0);
        // Clean lines drop silently — nothing to write back.
        c.insert(0x4000, false);
        assert_eq!(c.invalidate(0x4000), None);
        assert_eq!(c.occupancy(), 0);
    }

    #[test]
    fn invalidate_reports_touched_size_of_partial_line() {
        // A partial vector (e.g. a 724-float MatMul row) occupies a full
        // line but only its touched bytes are owed on write-back.
        let mut c = VCache::new(4, 8192);
        c.insert_sized(0x2000, true, 724 * 4);
        assert_eq!(c.invalidate(0x2000), Some(724 * 4));
    }

    #[test]
    fn clean_downgrades_but_keeps_resident() {
        let mut c = VCache::new(4, 8192);
        c.insert(0x2000, true);
        assert_eq!(c.clean(0x2000), Some(8192), "dirty line owes its bytes");
        assert_eq!(c.clean(0x2000), None, "already clean");
        assert!(c.lookup(0x2000), "line must stay resident");
        assert!(c.dirty_lines().is_empty());
        // Absent lines are a no-op.
        assert_eq!(c.clean(0x8000), None);
    }

    #[test]
    fn mark_dirty_after_fill() {
        let mut c = VCache::new(4, 8192);
        c.insert(0x8000, false);
        c.mark_dirty(0x8000);
        assert_eq!(c.dirty_lines(), vec![(0x8000, 8192)]);
    }

    #[test]
    fn configurable_vector_size() {
        // 256 B vectors (the Sec. III-C ablation): 64 KB cache = 256 lines.
        let mut c = VCache::new(256, 256);
        c.insert(0x100, false);
        assert!(c.lookup(0x1FF));
        assert!(!c.lookup(0x200));
    }
}
