//! Experiment coordinator: regenerates every table and figure of the paper.
//!
//! Each `fig*` function returns a [`FigTable`] whose rows mirror the paper's
//! plot series; the CLI prints them as markdown and optionally CSV. The
//! acceptance criterion is *shape* (who wins, crossover points, rough
//! factors), not absolute cycle counts — see EXPERIMENTS.md.

pub mod workloads;

use crate::config::SystemConfig;
use crate::sim::{simulate, simulate_threads, SimResult};
use crate::trace::{Backend, KernelId, TraceParams};
use workloads::{SizeScale, Workload, WorkloadSet};

/// One experiment cell: a workload run on a backend with some threads.
#[derive(Debug, Clone, Copy)]
pub struct RunSpec {
    pub workload: Workload,
    pub backend: Backend,
    pub threads: usize,
}

impl RunSpec {
    pub fn run(&self, cfg: &SystemConfig) -> SimResult {
        simulate_threads(cfg, self.workload.params(self.backend), self.threads)
    }
}

/// A figure/table reproduction: labelled rows of named columns.
#[derive(Debug, Clone)]
pub struct FigTable {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl FigTable {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, label: impl Into<String>, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((label.into(), values));
    }

    pub fn get(&self, label: &str, column: &str) -> Option<f64> {
        let ci = self.columns.iter().position(|c| c == column)?;
        self.rows.iter().find(|(l, _)| l == label).map(|(_, v)| v[ci])
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n| workload |", self.title);
        for c in &self.columns {
            s += &format!(" {c} |");
        }
        s += "\n|---|";
        for _ in &self.columns {
            s += "---|";
        }
        s += "\n";
        for (label, vals) in &self.rows {
            s += &format!("| {label} |");
            for v in vals {
                s += &format!(" {v:.3} |");
            }
            s += "\n";
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("workload");
        for c in &self.columns {
            s += &format!(",{c}");
        }
        s += "\n";
        for (label, vals) in &self.rows {
            s += label;
            for v in vals {
                s += &format!(",{v}");
            }
            s += "\n";
        }
        s
    }
}

/// The experiment driver.
pub struct Experiment {
    pub cfg: SystemConfig,
    pub scale: SizeScale,
    /// Print progress lines while running.
    pub verbose: bool,
}

impl Experiment {
    pub fn new(cfg: SystemConfig, scale: SizeScale) -> Self {
        Self { cfg, scale, verbose: false }
    }

    fn log(&self, msg: &str) {
        if self.verbose {
            eprintln!("[vima-sim] {msg}");
        }
    }

    fn baseline(&self, w: &Workload) -> SimResult {
        self.log(&format!("  baseline AVX {}", w.label()));
        simulate(&self.cfg, w.params(Backend::Avx))
    }

    /// **Fig. 2** — HIVE vs VIMA speedup over single-thread AVX for
    /// MemSet / VecSum / Stencil.
    pub fn fig2(&self) -> FigTable {
        let mut t = FigTable::new(
            "Fig. 2: HIVE and VIMA speedup vs AVX single-thread",
            &["hive", "vima"],
        );
        for w in WorkloadSet::fig2(self.scale) {
            let base = self.baseline(&w);
            self.log(&format!("  HIVE {}", w.label()));
            let hive = simulate(&self.cfg, w.params(Backend::Hive));
            self.log(&format!("  VIMA {}", w.label()));
            let vima = simulate(&self.cfg, w.params(Backend::Vima));
            t.push(w.label(), vec![hive.speedup_vs(&base), vima.speedup_vs(&base)]);
        }
        t
    }

    /// **Fig. 3** — VIMA speedup over single-thread AVX, all 7 kernels x 3 sizes.
    pub fn fig3(&self) -> FigTable {
        let mut t = FigTable::new(
            "Fig. 3: VIMA speedup vs AVX single-thread",
            &["speedup", "avx_cycles", "vima_cycles", "energy_ratio"],
        );
        for w in WorkloadSet::all(self.scale) {
            let base = self.baseline(&w);
            self.log(&format!("  VIMA {}", w.label()));
            let vima = simulate(&self.cfg, w.params(Backend::Vima));
            t.push(
                w.label(),
                vec![
                    vima.speedup_vs(&base),
                    base.cycles as f64,
                    vima.cycles as f64,
                    vima.energy_ratio_vs(&base),
                ],
            );
        }
        t
    }

    /// **Fig. 4** — multithreaded AVX (1..32 cores) vs single VIMA device on
    /// the largest Stencil / VecSum / MatMul; speedup and energy, both
    /// normalized to single-thread AVX.
    pub fn fig4(&self) -> FigTable {
        let threads = [1usize, 2, 4, 8, 16, 32];
        let mut cols: Vec<String> = vec!["vima_speedup".into(), "vima_energy".into()];
        for th in threads {
            cols.push(format!("avx{th}_speedup"));
            cols.push(format!("avx{th}_energy"));
        }
        let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut t = FigTable::new(
            "Fig. 4: VIMA vs multithreaded AVX (largest datasets), both normalized to AVX-1T",
            &cols_ref,
        );
        for w in WorkloadSet::multithread(self.scale) {
            let base = self.baseline(&w);
            self.log(&format!("  VIMA {}", w.label()));
            let vima = simulate(&self.cfg, w.params(Backend::Vima));
            let mut row = vec![vima.speedup_vs(&base), vima.energy_ratio_vs(&base)];
            for th in threads {
                self.log(&format!("  AVX x{th} {}", w.label()));
                let r = simulate_threads(&self.cfg, w.params(Backend::Avx), th);
                row.push(r.speedup_vs(&base));
                row.push(r.energy_ratio_vs(&base));
            }
            t.push(w.label(), row);
        }
        t
    }

    /// **Fig. 5** — VIMA cache-size sweep (16..256 KB) on the largest
    /// Stencil / VecSum / MatMul, speedup vs single-thread AVX.
    pub fn fig5(&self) -> FigTable {
        let sizes_kb = [16usize, 32, 64, 128, 256];
        let cols: Vec<String> = sizes_kb.iter().map(|k| format!("{k}KB")).collect();
        let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut t =
            FigTable::new("Fig. 5: VIMA speedup vs AVX for different VIMA cache sizes", &cols_ref);
        for w in WorkloadSet::multithread(self.scale) {
            let base = self.baseline(&w);
            let mut row = Vec::new();
            for kb in sizes_kb {
                let mut cfg = self.cfg.clone();
                cfg.vima.cache_bytes = kb << 10;
                self.log(&format!("  VIMA {}KB {}", kb, w.label()));
                let vima = simulate(&cfg, w.params(Backend::Vima));
                row.push(vima.speedup_vs(&base));
            }
            t.push(w.label(), row);
        }
        t
    }

    /// **Sec. III-C ablation** — vector size: 256 B performs ~74% worse than
    /// 8 KB on streaming kernels.
    pub fn ablation_vector_size(&self) -> FigTable {
        let sizes: [u32; 6] = [256, 512, 1024, 2048, 4096, 8192];
        let cols: Vec<String> = sizes.iter().map(|b| format!("{b}B")).collect();
        let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut t = FigTable::new(
            "Ablation: VIMA vector size (speedup vs AVX single-thread)",
            &cols_ref,
        );
        for kernel in [KernelId::MemSet, KernelId::VecSum] {
            let w = *WorkloadSet::sizes(kernel, self.scale).last().unwrap();
            let base = self.baseline(&w);
            let mut row = Vec::new();
            for vb in sizes {
                let mut cfg = self.cfg.clone();
                cfg.vima.vector_bytes = vb as usize;
                // cache stays 64 KB; lines = 64 KB / vb
                let p = TraceParams::new(kernel, Backend::Vima, w.footprint).with_vector_bytes(vb);
                self.log(&format!("  VIMA vb={vb} {}", w.label()));
                let r = simulate(&cfg, p);
                row.push(r.speedup_vs(&base));
            }
            t.push(w.label(), row);
        }
        t
    }

    /// **Sec. III-C ablation** — precise-exception dispatch cost, split in
    /// two as the paper does:
    ///
    /// * `gap_pct` — the execution-gap *bubble* between committing one VIMA
    ///   instruction and dispatching the next (paper: "varying between 2%
    ///   and 4%"): default dispatch gap vs zero gap, stop-and-go retained.
    /// * `pipelined_pct` — the full cost of one-at-a-time dispatch vs a
    ///   HIVE-like fire-and-forget pipeline (non-precise exceptions); this
    ///   is the upper bound the paper trades for precise exceptions.
    pub fn ablation_stop_and_go(&self) -> FigTable {
        let mut t = FigTable::new(
            "Ablation: stop-and-go dispatch (gap bubble %, full pipelining %)",
            &["default_cycles", "gap_pct", "pipelined_pct"],
        );
        for w in WorkloadSet::multithread(self.scale) {
            let with = simulate(&self.cfg, w.params(Backend::Vima));
            let mut no_gap = self.cfg.clone();
            no_gap.vima.dispatch_gap_cycles = 0;
            let gapless = simulate(&no_gap, w.params(Backend::Vima));
            let mut pipe = self.cfg.clone();
            pipe.vima.stop_and_go = false;
            pipe.vima.dispatch_gap_cycles = 0;
            let pipelined = simulate(&pipe, w.params(Backend::Vima));
            let gap_pct = (with.cycles as f64 / gapless.cycles as f64 - 1.0) * 100.0;
            let pipelined_pct = (with.cycles as f64 / pipelined.cycles as f64 - 1.0) * 100.0;
            t.push(w.label(), vec![with.cycles as f64, gap_pct, pipelined_pct]);
        }
        t
    }

    /// **Extension ablation** — baseline strength: Table-I (no prefetcher)
    /// vs a Sandy-Bridge-class LLC stride streamer. Shows which paper claims
    /// depend on the prefetcher-less baseline.
    pub fn ablation_prefetcher(&self) -> FigTable {
        let mut t = FigTable::new(
            "Ablation: baseline prefetcher (VIMA speedup vs AVX, without / with LLC streamer)",
            &["no_prefetch", "with_prefetch"],
        );
        let mut pf_cfg = self.cfg.clone();
        pf_cfg.prefetch.enabled = true;
        let mut base_cfg = self.cfg.clone();
        base_cfg.prefetch.enabled = false;
        for kernel in [KernelId::VecSum, KernelId::MemCopy, KernelId::Knn, KernelId::Mlp] {
            let w = *WorkloadSet::sizes(kernel, self.scale).last().unwrap();
            let mut row = Vec::new();
            for cfg in [&base_cfg, &pf_cfg] {
                let avx = simulate(cfg, w.params(Backend::Avx));
                let vima = simulate(cfg, w.params(Backend::Vima));
                row.push(vima.speedup_vs(&avx));
            }
            t.push(w.label(), row);
        }
        t
    }

    /// **Headline numbers** — max speedup and max energy saving across Fig. 3.
    pub fn headline(&self) -> FigTable {
        let fig3 = self.fig3();
        let mut best_speedup: f64 = 0.0;
        let mut best_energy: f64 = 1.0;
        for (_, vals) in &fig3.rows {
            best_speedup = best_speedup.max(vals[0]);
            best_energy = best_energy.min(vals[3]);
        }
        let mut t = FigTable::new(
            "Headline: paper claims up to 26x speedup and 93% energy saving",
            &["value"],
        );
        t.push("max_speedup", vec![best_speedup]);
        t.push("max_energy_saving_pct", vec![(1.0 - best_energy) * 100.0]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figtable_markdown_and_csv() {
        let mut t = FigTable::new("Test", &["a", "b"]);
        t.push("row1", vec![1.0, 2.0]);
        let md = t.to_markdown();
        assert!(md.contains("| row1 | 1.000 | 2.000 |"));
        let csv = t.to_csv();
        assert!(csv.contains("row1,1,2"));
        assert_eq!(t.get("row1", "b"), Some(2.0));
        assert_eq!(t.get("row1", "c"), None);
    }

    #[test]
    fn fig2_quick_shape() {
        let e = Experiment::new(SystemConfig::default(), SizeScale::Quick);
        let t = e.fig2();
        assert_eq!(t.rows.len(), 9); // 3 kernels x 3 sizes
        // VIMA must beat the baseline on streaming kernels.
        for (label, vals) in &t.rows {
            if label.starts_with("MemSet") || label.starts_with("VecSum") {
                assert!(vals[1] > 1.0, "{label}: vima speedup {}", vals[1]);
            }
        }
    }

    #[test]
    fn ablation_stop_and_go_has_positive_overhead() {
        let e = Experiment::new(SystemConfig::default(), SizeScale::Quick);
        let t = e.ablation_stop_and_go();
        for (label, vals) in &t.rows {
            assert!(vals[2] >= 0.0, "{label}: negative overhead {}", vals[2]);
        }
    }
}
