//! Experiment coordinator: regenerates every table and figure of the paper.
//!
//! Each `fig*` function *declares* its grid as a [`SweepPlan`] of
//! [`RunCell`]s and assembles a [`FigTable`] from the results; plans are
//! submitted to the [`Experiment`]'s [`SimService`] — the same long-lived
//! scheduler (worker pool, pooled machines, bounded result cache) that
//! serves ad-hoc [`Job`](crate::service::Job)s and the `vima-sim serve`
//! JSONL mode — so the AVX baselines every figure normalizes against
//! simulate exactly once per [`Experiment`], no matter how many figures ask
//! for them (`vima-sim sweep` prints the dedup accounting). The acceptance
//! criterion is *shape* (who wins, crossover points, rough factors), not
//! absolute cycle counts — see EXPERIMENTS.md.

pub mod workloads;

use crate::config::SystemConfig;
use crate::service::{ServiceConfig, SimService};
use crate::sim::{simulate_threads, SimResult};
use crate::sweep::{RunCell, SweepPlan, SweepStats};
use crate::trace::{Backend, KernelId};
use crate::util::error::Result;
use workloads::{SizeScale, SizedWorkload, WorkloadSet};

/// One experiment cell: a workload run on a backend with some threads.
/// Standalone convenience (one-off runs); the figure drivers use
/// [`RunCell`]s so results dedup and parallelize.
#[derive(Debug, Clone, Copy)]
pub struct RunSpec {
    pub workload: SizedWorkload,
    pub backend: Backend,
    pub threads: usize,
}

impl RunSpec {
    pub fn run(&self, cfg: &SystemConfig) -> Result<SimResult> {
        simulate_threads(cfg, self.workload.params(self.backend), self.threads)
    }
}

/// A figure/table reproduction: labelled rows of named columns.
#[derive(Debug, Clone)]
pub struct FigTable {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl FigTable {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, label: impl Into<String>, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((label.into(), values));
    }

    pub fn get(&self, label: &str, column: &str) -> Option<f64> {
        let ci = self.columns.iter().position(|c| c == column)?;
        self.rows.iter().find(|(l, _)| l == label).map(|(_, v)| v[ci])
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n| workload |", self.title);
        for c in &self.columns {
            s += &format!(" {c} |");
        }
        s += "\n|---|";
        for _ in &self.columns {
            s += "---|";
        }
        s += "\n";
        for (label, vals) in &self.rows {
            s += &format!("| {label} |");
            for v in vals {
                s += &format!(" {v:.3} |");
            }
            s += "\n";
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("workload");
        for c in &self.columns {
            s += &format!(",{c}");
        }
        s += "\n";
        for (label, vals) in &self.rows {
            s += label;
            for v in vals {
                s += &format!(",{v}");
            }
            s += "\n";
        }
        s
    }
}

/// The experiment driver. Holds a [`SimService`] (worker pool + bounded
/// result cache), so figures requested from the same `Experiment` share
/// baseline simulations — and ad-hoc jobs submitted through
/// [`service`](Self::service) run on the very same scheduler as the paper
/// suite.
pub struct Experiment {
    pub cfg: SystemConfig,
    pub scale: SizeScale,
    /// Print progress lines while running.
    pub verbose: bool,
    service: SimService,
}

impl Experiment {
    /// Worker count defaults to `available_parallelism()`.
    pub fn new(cfg: SystemConfig, scale: SizeScale) -> Self {
        Self::with_jobs(cfg, scale, 0)
    }

    /// Explicit worker count (`jobs = 0` means `available_parallelism()`,
    /// `jobs = 1` is fully serial).
    pub fn with_jobs(cfg: SystemConfig, scale: SizeScale, jobs: usize) -> Self {
        let service =
            SimService::new(ServiceConfig { base: cfg.clone(), jobs, ..ServiceConfig::default() });
        Self { cfg, scale, verbose: false, service }
    }

    /// The scheduler the figures run on; submit ad-hoc
    /// [`Job`](crate::service::Job)s here to share its cache and workers.
    pub fn service(&self) -> &SimService {
        &self.service
    }

    /// Dedup accounting across every figure this experiment has produced.
    pub fn sweep_stats(&self) -> SweepStats {
        self.service.stats()
    }

    /// Worker-pool width.
    pub fn jobs(&self) -> usize {
        self.service.jobs()
    }

    fn run_plan(&self, plan: &SweepPlan) -> Result<Vec<SimResult>> {
        self.service.run_plan(&self.cfg, plan, self.verbose)
    }

    /// **Fig. 2** — HIVE vs VIMA speedup over single-thread AVX for
    /// MemSet / VecSum / Stencil.
    pub fn fig2(&self) -> Result<FigTable> {
        let mut plan = SweepPlan::new();
        let rows: Vec<_> = WorkloadSet::fig2(self.scale)
            .into_iter()
            .map(|w| {
                (
                    w.label(),
                    plan.push(RunCell::new(w, Backend::Avx)),
                    plan.push(RunCell::new(w, Backend::Hive)),
                    plan.push(RunCell::new(w, Backend::Vima)),
                )
            })
            .collect();
        let res = self.run_plan(&plan)?;
        let mut t = FigTable::new(
            "Fig. 2: HIVE and VIMA speedup vs AVX single-thread",
            &["hive", "vima"],
        );
        for (label, base, hive, vima) in rows {
            t.push(
                label,
                vec![res[hive].speedup_vs(&res[base]), res[vima].speedup_vs(&res[base])],
            );
        }
        Ok(t)
    }

    /// **Fig. 3** — VIMA speedup over single-thread AVX, all 7 kernels x 3 sizes.
    pub fn fig3(&self) -> Result<FigTable> {
        let mut plan = SweepPlan::new();
        let rows: Vec<_> = WorkloadSet::all(self.scale)
            .into_iter()
            .map(|w| {
                (
                    w.label(),
                    plan.push(RunCell::new(w, Backend::Avx)),
                    plan.push(RunCell::new(w, Backend::Vima)),
                )
            })
            .collect();
        let res = self.run_plan(&plan)?;
        let mut t = FigTable::new(
            "Fig. 3: VIMA speedup vs AVX single-thread",
            &["speedup", "avx_cycles", "vima_cycles", "energy_ratio"],
        );
        for (label, base, vima) in rows {
            let (base, vima) = (&res[base], &res[vima]);
            t.push(
                label,
                vec![
                    vima.speedup_vs(base),
                    base.cycles as f64,
                    vima.cycles as f64,
                    vima.energy_ratio_vs(base),
                ],
            );
        }
        Ok(t)
    }

    /// **Fig. 4** — multithreaded AVX (1..32 cores) vs single VIMA device on
    /// the largest Stencil / VecSum / MatMul; speedup and energy, both
    /// normalized to single-thread AVX. (The AVX-1T column *is* the
    /// baseline cell — the cache runs it once.)
    pub fn fig4(&self) -> Result<FigTable> {
        let threads = [1usize, 2, 4, 8, 16, 32];
        let mut cols: Vec<String> = vec!["vima_speedup".into(), "vima_energy".into()];
        for th in threads {
            cols.push(format!("avx{th}_speedup"));
            cols.push(format!("avx{th}_energy"));
        }
        let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();

        let mut plan = SweepPlan::new();
        let rows: Vec<_> = WorkloadSet::multithread(self.scale)
            .into_iter()
            .map(|w| {
                let base = plan.push(RunCell::new(w, Backend::Avx));
                let vima = plan.push(RunCell::new(w, Backend::Vima));
                let avx: Vec<usize> = threads
                    .iter()
                    .map(|&th| plan.push(RunCell::new(w, Backend::Avx).with_threads(th)))
                    .collect();
                (w.label(), base, vima, avx)
            })
            .collect();
        let res = self.run_plan(&plan)?;

        let mut t = FigTable::new(
            "Fig. 4: VIMA vs multithreaded AVX (largest datasets), both normalized to AVX-1T",
            &cols_ref,
        );
        for (label, base, vima, avx) in rows {
            let base = &res[base];
            let mut row = vec![res[vima].speedup_vs(base), res[vima].energy_ratio_vs(base)];
            for i in avx {
                row.push(res[i].speedup_vs(base));
                row.push(res[i].energy_ratio_vs(base));
            }
            t.push(label, row);
        }
        Ok(t)
    }

    /// **Fig. 5** — VIMA cache-size sweep (16..256 KB) on the largest
    /// Stencil / VecSum / MatMul, speedup vs single-thread AVX.
    pub fn fig5(&self) -> Result<FigTable> {
        let sizes_kb = [16usize, 32, 64, 128, 256];
        let cols: Vec<String> = sizes_kb.iter().map(|k| format!("{k}KB")).collect();
        let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();

        let mut plan = SweepPlan::new();
        let rows: Vec<_> = WorkloadSet::multithread(self.scale)
            .into_iter()
            .map(|w| {
                let base = plan.push(RunCell::new(w, Backend::Avx));
                let sweep: Vec<usize> = sizes_kb
                    .iter()
                    .map(|&kb| {
                        let mut cfg = self.cfg.clone();
                        cfg.vima.cache_bytes = kb << 10;
                        plan.push(RunCell::new(w, Backend::Vima).with_cfg(cfg))
                    })
                    .collect();
                (w.label(), base, sweep)
            })
            .collect();
        let res = self.run_plan(&plan)?;

        let mut t =
            FigTable::new("Fig. 5: VIMA speedup vs AVX for different VIMA cache sizes", &cols_ref);
        for (label, base, sweep) in rows {
            let row = sweep.iter().map(|&i| res[i].speedup_vs(&res[base])).collect();
            t.push(label, row);
        }
        Ok(t)
    }

    /// **Sec. III-C ablation** — vector size: 256 B performs ~74% worse than
    /// 8 KB on streaming kernels.
    pub fn ablation_vector_size(&self) -> Result<FigTable> {
        let sizes: [u32; 6] = [256, 512, 1024, 2048, 4096, 8192];
        let cols: Vec<String> = sizes.iter().map(|b| format!("{b}B")).collect();
        let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();

        let mut plan = SweepPlan::new();
        let rows: Vec<_> = [KernelId::MemSet, KernelId::VecSum]
            .into_iter()
            .map(|kernel| {
                let w = *WorkloadSet::sizes(kernel, self.scale).last().unwrap();
                let base = plan.push(RunCell::new(w, Backend::Avx));
                let sweep: Vec<usize> = sizes
                    .iter()
                    .map(|&vb| {
                        let mut cfg = self.cfg.clone();
                        cfg.vima.vector_bytes = vb as usize;
                        // cache stays 64 KB; lines = 64 KB / vb
                        plan.push(
                            RunCell::new(w, Backend::Vima).with_cfg(cfg).with_vector_bytes(vb),
                        )
                    })
                    .collect();
                (w.label(), base, sweep)
            })
            .collect();
        let res = self.run_plan(&plan)?;

        let mut t = FigTable::new(
            "Ablation: VIMA vector size (speedup vs AVX single-thread)",
            &cols_ref,
        );
        for (label, base, sweep) in rows {
            let row = sweep.iter().map(|&i| res[i].speedup_vs(&res[base])).collect();
            t.push(label, row);
        }
        Ok(t)
    }

    /// **Sec. III-C ablation** — precise-exception dispatch cost, split in
    /// two as the paper does:
    ///
    /// * `gap_pct` — the execution-gap *bubble* between committing one VIMA
    ///   instruction and dispatching the next (paper: "varying between 2%
    ///   and 4%"): default dispatch gap vs zero gap, stop-and-go retained.
    /// * `pipelined_pct` — the full cost of one-at-a-time dispatch vs a
    ///   HIVE-like fire-and-forget pipeline (non-precise exceptions); this
    ///   is the upper bound the paper trades for precise exceptions.
    pub fn ablation_stop_and_go(&self) -> Result<FigTable> {
        let mut no_gap = self.cfg.clone();
        no_gap.vima.dispatch_gap_cycles = 0;
        let mut pipe = self.cfg.clone();
        pipe.vima.stop_and_go = false;
        pipe.vima.dispatch_gap_cycles = 0;

        let mut plan = SweepPlan::new();
        let rows: Vec<_> = WorkloadSet::multithread(self.scale)
            .into_iter()
            .map(|w| {
                (
                    w.label(),
                    plan.push(RunCell::new(w, Backend::Vima)),
                    plan.push(RunCell::new(w, Backend::Vima).with_cfg(no_gap.clone())),
                    plan.push(RunCell::new(w, Backend::Vima).with_cfg(pipe.clone())),
                )
            })
            .collect();
        let res = self.run_plan(&plan)?;

        let mut t = FigTable::new(
            "Ablation: stop-and-go dispatch (gap bubble %, full pipelining %)",
            &["default_cycles", "gap_pct", "pipelined_pct"],
        );
        for (label, with, gapless, pipelined) in rows {
            let with = &res[with];
            let gap_pct = (with.cycles as f64 / res[gapless].cycles as f64 - 1.0) * 100.0;
            let pipelined_pct = (with.cycles as f64 / res[pipelined].cycles as f64 - 1.0) * 100.0;
            t.push(label, vec![with.cycles as f64, gap_pct, pipelined_pct]);
        }
        Ok(t)
    }

    /// **Extension ablation** — baseline strength: Table-I (no prefetcher)
    /// vs a Sandy-Bridge-class LLC stride streamer. Shows which paper claims
    /// depend on the prefetcher-less baseline.
    pub fn ablation_prefetcher(&self) -> Result<FigTable> {
        let mut pf_cfg = self.cfg.clone();
        pf_cfg.prefetch.enabled = true;
        let mut base_cfg = self.cfg.clone();
        base_cfg.prefetch.enabled = false;

        let mut plan = SweepPlan::new();
        let rows: Vec<_> = [KernelId::VecSum, KernelId::MemCopy, KernelId::Knn, KernelId::Mlp]
            .into_iter()
            .map(|kernel| {
                let w = *WorkloadSet::sizes(kernel, self.scale).last().unwrap();
                let cells: Vec<(usize, usize)> = [&base_cfg, &pf_cfg]
                    .into_iter()
                    .map(|cfg| {
                        (
                            plan.push(RunCell::new(w, Backend::Avx).with_cfg(cfg.clone())),
                            plan.push(RunCell::new(w, Backend::Vima).with_cfg(cfg.clone())),
                        )
                    })
                    .collect();
                (w.label(), cells)
            })
            .collect();
        let res = self.run_plan(&plan)?;

        let mut t = FigTable::new(
            "Ablation: baseline prefetcher (VIMA speedup vs AVX, without / with LLC streamer)",
            &["no_prefetch", "with_prefetch"],
        );
        for (label, cells) in rows {
            let row = cells.iter().map(|&(avx, vima)| res[vima].speedup_vs(&res[avx])).collect();
            t.push(label, row);
        }
        Ok(t)
    }

    /// **Cube-scaling figure** — the fabric extension (DESIGN.md §10): the
    /// streaming kernels at their largest size, 8 host threads driving a
    /// 1/2/4/8-cube [`MemFabric`](crate::fabric::MemFabric), each point
    /// normalized to the same kernel's 1-cube run. With one cube all eight
    /// threads serialize on a single VIMA device and one cube's vaults;
    /// sharding gives each cube its own device, vector cache, and DRAM
    /// bandwidth, so streaming throughput scales with the cube count
    /// (minus the cross-cube gather hops the fabric charges honestly).
    pub fn scaling_cubes(&self) -> Result<FigTable> {
        let cube_counts = [1usize, 2, 4, 8];
        let threads = 8;
        let cols: Vec<String> = cube_counts.iter().map(|c| format!("{c}cube")).collect();
        let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();

        let mut plan = SweepPlan::new();
        let rows: Vec<_> = [KernelId::MemSet, KernelId::MemCopy, KernelId::VecSum]
            .into_iter()
            .map(|kernel| {
                let w = *WorkloadSet::sizes(kernel, self.scale).last().unwrap();
                let cells: Vec<usize> = cube_counts
                    .iter()
                    .map(|&n| {
                        let mut cfg = self.cfg.clone();
                        cfg.mem.num_cubes = n;
                        plan.push(
                            RunCell::new(w, Backend::Vima).with_cfg(cfg).with_threads(threads),
                        )
                    })
                    .collect();
                (w.label(), cells)
            })
            .collect();
        let res = self.run_plan(&plan)?;

        let mut t = FigTable::new(
            "Cube scaling: streaming-kernel throughput vs fabric size \
             (speedup over the 1-cube fabric, 8 threads)",
            &cols_ref,
        );
        for (label, cells) in rows {
            let base = &res[cells[0]];
            let row = cells.iter().map(|&i| res[i].speedup_vs(base)).collect();
            t.push(label, row);
        }
        Ok(t)
    }

    /// **Headline numbers** — max speedup and max energy saving across
    /// Fig. 3 (all cells cached if `fig3` already ran).
    pub fn headline(&self) -> Result<FigTable> {
        let fig3 = self.fig3()?;
        let mut best_speedup: f64 = 0.0;
        let mut best_energy: f64 = 1.0;
        for (_, vals) in &fig3.rows {
            best_speedup = best_speedup.max(vals[0]);
            best_energy = best_energy.min(vals[3]);
        }
        let mut t = FigTable::new(
            "Headline: paper claims up to 26x speedup and 93% energy saving",
            &["value"],
        );
        t.push("max_speedup", vec![best_speedup]);
        t.push("max_energy_saving_pct", vec![(1.0 - best_energy) * 100.0]);
        Ok(t)
    }

    /// **Custom workloads** — every registered program workload (built-in
    /// Intrinsics-VIMA programs *and* runtime-loaded `.vpr` files; anything
    /// beyond the paper's seven kernels), each program's VIMA stream vs the
    /// AVX lowering of the *same* program. Runs through the shared result
    /// cache like every paper figure, so repeated cells dedup — a loaded
    /// program is a distinct `CellKey` like any built-in.
    pub fn custom_programs(&self) -> Result<FigTable> {
        let names: Vec<String> =
            crate::workload::program_ids().into_iter().map(crate::workload::name).collect();
        let refs: Vec<&str> = names.iter().map(String::as_str).collect();
        self.custom_workloads(&refs)
    }

    /// Same as [`custom_programs`](Self::custom_programs) for an arbitrary
    /// list of registered workload names.
    pub fn custom_workloads(&self, names: &[&str]) -> Result<FigTable> {
        let mut plan = SweepPlan::new();
        let mut rows = Vec::new();
        for name in names {
            let w = SizedWorkload::custom(name)?;
            rows.push((
                w.label(),
                plan.push(RunCell::new(w, Backend::Avx)),
                plan.push(RunCell::new(w, Backend::Vima)),
            ));
        }
        let res = self.run_plan(&plan)?;
        let mut t = FigTable::new(
            "Custom workloads: registered Intrinsics-VIMA programs, VIMA vs AVX lowering",
            &["speedup", "avx_cycles", "vima_cycles", "energy_ratio"],
        );
        for (label, avx, vima) in rows {
            let (avx, vima) = (&res[avx], &res[vima]);
            t.push(
                label,
                vec![
                    vima.speedup_vs(avx),
                    avx.cycles as f64,
                    vima.cycles as f64,
                    vima.energy_ratio_vs(avx),
                ],
            );
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figtable_markdown_and_csv() {
        let mut t = FigTable::new("Test", &["a", "b"]);
        t.push("row1", vec![1.0, 2.0]);
        let md = t.to_markdown();
        assert!(md.contains("| row1 | 1.000 | 2.000 |"));
        let csv = t.to_csv();
        assert!(csv.contains("row1,1,2"));
        assert_eq!(t.get("row1", "b"), Some(2.0));
        assert_eq!(t.get("row1", "c"), None);
    }

    #[test]
    fn fig2_quick_shape() {
        let e = Experiment::new(SystemConfig::default(), SizeScale::Quick);
        let t = e.fig2().unwrap();
        assert_eq!(t.rows.len(), 9); // 3 kernels x 3 sizes
        // VIMA must beat the baseline on streaming kernels.
        for (label, vals) in &t.rows {
            if label.starts_with("MemSet") || label.starts_with("VecSum") {
                assert!(vals[1] > 1.0, "{label}: vima speedup {}", vals[1]);
            }
        }
    }

    #[test]
    fn ablation_stop_and_go_has_positive_overhead() {
        let e = Experiment::new(SystemConfig::default(), SizeScale::Quick);
        let t = e.ablation_stop_and_go().unwrap();
        for (label, vals) in &t.rows {
            assert!(vals[2] >= 0.0, "{label}: negative overhead {}", vals[2]);
        }
    }

    #[test]
    fn custom_figure_runs_registered_programs() {
        let e = Experiment::with_jobs(SystemConfig::default(), SizeScale::Quick, 2);
        // The figure enumerates the registry at call time, so tests that
        // register extra programs (the `.vpr` loader suite runs in this
        // process) may add rows — the built-ins must always be present.
        let t = e.custom_programs().unwrap();
        assert!(t.rows.len() >= 2, "expected at least saxpy + softmax, got {:?}", t.rows);
        for name in ["saxpy", "softmax"] {
            assert!(
                t.rows.iter().any(|(label, _)| label.starts_with(name)),
                "missing row for {name}: {:?}",
                t.rows
            );
        }
        for (label, vals) in &t.rows {
            assert!(vals[1] > 0.0 && vals[2] > 0.0, "{label}: zero cycles");
        }
        // Re-running cells already in the figure is pure cache hits.
        let runs = e.sweep_stats().unique_runs;
        e.custom_workloads(&["saxpy", "softmax"]).unwrap();
        assert_eq!(e.sweep_stats().unique_runs, runs);
    }

    #[test]
    fn repeated_figures_are_free() {
        let e = Experiment::with_jobs(SystemConfig::default(), SizeScale::Quick, 2);
        let a = e.fig2().unwrap();
        let runs_after_first = e.sweep_stats().unique_runs;
        let b = e.fig2().unwrap();
        assert_eq!(e.sweep_stats().unique_runs, runs_after_first, "second fig2 must be all hits");
        assert_eq!(a.rows, b.rows);
    }
}
