//! The paper's workload matrix (Sec. IV-A): seven kernels, three dataset
//! sizes each, plus the labels the figures use. Since the open-workload
//! redesign a matrix cell is a *registry id* + footprint, so the same
//! machinery sizes custom workloads (see [`SizedWorkload::custom`]).

use crate::trace::{Backend, KernelId, TraceParams};
use crate::util::error::Result;
use crate::workload::{self, WorkloadId};

/// One (workload, size) cell of the evaluation matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SizedWorkload {
    /// Registry identity (paper kernel or registered custom workload).
    pub workload: WorkloadId,
    /// Total footprint in bytes.
    pub footprint: u64,
    /// Paper's axis label for this size (e.g. "64MB" or "512" features).
    pub size_label: &'static str,
}

impl SizedWorkload {
    /// A registered custom workload at its own default footprint.
    pub fn custom(name: &str) -> Result<Self> {
        let id = workload::resolve(name)?;
        let footprint = workload::get(id)?.default_footprint();
        Ok(Self { workload: id, footprint, size_label: "default" })
    }

    pub fn params(&self, backend: Backend) -> TraceParams {
        TraceParams::new(self.workload, backend, self.footprint)
    }

    pub fn label(&self) -> String {
        format!("{}-{}", workload::name(self.workload), self.size_label)
    }
}

/// Scale knob: `Paper` runs the full Sec. IV sizes; `Quick` divides
/// footprints by 16 for smoke tests and CI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeScale {
    Paper,
    Quick,
}

impl SizeScale {
    fn apply(&self, bytes: u64) -> u64 {
        match self {
            SizeScale::Paper => bytes,
            SizeScale::Quick => (bytes / 16).max(1 << 20),
        }
    }
}

/// The full evaluation matrix.
pub struct WorkloadSet;

impl WorkloadSet {
    const MB: u64 = 1 << 20;

    /// Standard three sizes for the streaming/ML kernels (4/16/64 MB).
    pub fn sizes(kernel: KernelId, scale: SizeScale) -> Vec<SizedWorkload> {
        let mk = |footprint: u64, size_label: &'static str| SizedWorkload {
            workload: kernel.into(),
            footprint: scale.apply(footprint),
            size_label,
        };
        match kernel {
            KernelId::MatMul => vec![
                mk(6 * Self::MB, "6MB"),
                mk(12 * Self::MB, "12MB"),
                mk(24 * Self::MB, "24MB"),
            ],
            KernelId::Knn => vec![
                mk(4 * Self::MB, "32"),
                mk(16 * Self::MB, "128"),
                mk(64 * Self::MB, "512"),
            ],
            KernelId::Mlp => vec![
                mk(4 * Self::MB, "64"),
                mk(16 * Self::MB, "256"),
                mk(64 * Self::MB, "1024"),
            ],
            _ => vec![
                mk(4 * Self::MB, "4MB"),
                mk(16 * Self::MB, "16MB"),
                mk(64 * Self::MB, "64MB"),
            ],
        }
    }

    /// All seven kernels (Fig. 3 matrix).
    pub fn all(scale: SizeScale) -> Vec<SizedWorkload> {
        [
            KernelId::MemSet,
            KernelId::MemCopy,
            KernelId::VecSum,
            KernelId::Stencil,
            KernelId::MatMul,
            KernelId::Knn,
            KernelId::Mlp,
        ]
        .into_iter()
        .flat_map(|k| Self::sizes(k, scale))
        .collect()
    }

    /// Fig. 2's kernels (the HIVE comparison).
    pub fn fig2(scale: SizeScale) -> Vec<SizedWorkload> {
        [KernelId::MemSet, KernelId::VecSum, KernelId::Stencil]
            .into_iter()
            .flat_map(|k| Self::sizes(k, scale))
            .collect()
    }

    /// Fig. 4 / Fig. 5 use the largest size of these three kernels.
    pub fn multithread(scale: SizeScale) -> Vec<SizedWorkload> {
        [KernelId::Stencil, KernelId::VecSum, KernelId::MatMul]
            .into_iter()
            .map(|k| *Self::sizes(k, scale).last().unwrap())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_matrix_is_21_cells() {
        assert_eq!(WorkloadSet::all(SizeScale::Paper).len(), 21);
    }

    #[test]
    fn paper_sizes_match_section_4() {
        let knn = WorkloadSet::sizes(KernelId::Knn, SizeScale::Paper);
        assert_eq!(knn[2].footprint, 64 << 20);
        assert_eq!(knn[2].size_label, "512");
        let mm = WorkloadSet::sizes(KernelId::MatMul, SizeScale::Paper);
        assert_eq!(mm[0].footprint, 6 << 20);
    }

    #[test]
    fn quick_scale_shrinks() {
        let p = WorkloadSet::sizes(KernelId::VecSum, SizeScale::Paper);
        let q = WorkloadSet::sizes(KernelId::VecSum, SizeScale::Quick);
        assert!(q[2].footprint < p[2].footprint);
        assert!(q[0].footprint >= 1 << 20);
    }

    #[test]
    fn multithread_set_uses_largest() {
        let m = WorkloadSet::multithread(SizeScale::Paper);
        assert_eq!(m.len(), 3);
        assert!(m.iter().all(|w| w.size_label == "64MB" || w.size_label == "24MB"));
    }
}
