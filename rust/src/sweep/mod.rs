//! Declarative sweep grids — the experiment path's run-plan vocabulary.
//!
//! The paper's figures are grids of `(kernel × backend × threads × size ×
//! config)` simulation cells, and many cells recur across figures (every
//! figure normalizes to the same single-thread AVX baselines). The
//! coordinator *declares* a [`SweepPlan`] of [`RunCell`]s; execution —
//! worker pool, machine pooling, result caching, dedup — lives in the
//! [`service`](crate::service) layer, the crate's single scheduler.
//! [`SweepRunner`] survives as a thin façade over an owned
//! [`SimService`]:
//!
//! * **deduplicates** — cells are keyed by their full identity
//!   ([`CellKey`]: the cell's `Eq + Hash` [`TraceParams`] — workload,
//!   backend, footprint, threads, vector size — plus the complete
//!   [`SystemConfig`]) in the service's result cache, so a cell shared by
//!   fig3/fig4/fig5 simulates exactly once while cached. Unlike the old
//!   engine, concurrent submissions racing on an uncached cell now *join*
//!   the in-flight run instead of simulating twice;
//! * **parallelizes** — unique cells execute on the service's long-lived
//!   worker pool (default `available_parallelism()`, `--jobs N`
//!   override). Each simulation is single-threaded and deterministic, so
//!   scheduling order cannot change any result: serial (`jobs = 1`) and
//!   parallel runs produce bit-identical tables;
//! * **reuses machines** — workers pool [`Machine`](crate::sim::Machine)s
//!   per `(config, threads)` shape and reset them between cells (see
//!   [`MachineCache`]).
//!
//! The result cache is **bounded** (default
//! [`DEFAULT_CACHE_CAPACITY`](crate::service::DEFAULT_CACHE_CAPACITY),
//! far above the 111-cell paper suite) with LRU-ish eviction;
//! [`SweepStats`] reports hits, misses, and evictions.
//!
//! Results come back in plan order, so callers assemble figure tables by
//! the indices [`SweepPlan::push`] returned.

use crate::config::SystemConfig;
use crate::coordinator::workloads::SizedWorkload;
use crate::service::{ServiceConfig, SimService, DEFAULT_CACHE_CAPACITY};
use crate::sim::SimResult;
use crate::trace::{Backend, TraceParams};
use crate::util::error::Result;
use crate::workload::{self, WorkloadId};

/// Per-worker machine reuse (kept under its historical sweep-engine name;
/// the implementation is the service's machine pool).
pub use crate::service::MachinePool as MachineCache;

/// One cell of the run grid: a workload on a backend with a thread count
/// and an optional configuration override.
#[derive(Debug, Clone)]
pub struct RunCell {
    /// Registry identity — any registered workload, paper kernel or custom.
    pub workload: WorkloadId,
    /// Total data footprint in bytes.
    pub footprint: u64,
    pub backend: Backend,
    /// Data-parallel host cores driving the run.
    pub threads: usize,
    /// VIMA/HIVE vector size in bytes (8192 default; the ablation sweeps it).
    pub vector_bytes: u32,
    /// Full-config override; `None` inherits the sweep's base config.
    pub cfg_override: Option<SystemConfig>,
}

impl RunCell {
    pub fn new(w: SizedWorkload, backend: Backend) -> Self {
        Self {
            workload: w.workload,
            footprint: w.footprint,
            backend,
            threads: 1,
            vector_bytes: 8192,
            cfg_override: None,
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_vector_bytes(mut self, vb: u32) -> Self {
        self.vector_bytes = vb;
        self
    }

    pub fn with_cfg(mut self, cfg: SystemConfig) -> Self {
        self.cfg_override = Some(cfg);
        self
    }

    /// Trace-generator parameters for this cell (per-thread slicing happens
    /// inside [`run_on`](crate::sim::run_on)).
    pub fn params(&self) -> TraceParams {
        TraceParams::new(self.workload, self.backend, self.footprint)
            .with_vector_bytes(self.vector_bytes)
            .with_threads(0, self.threads)
    }

    pub(crate) fn effective_cfg<'a>(&'a self, base: &'a SystemConfig) -> &'a SystemConfig {
        self.cfg_override.as_ref().unwrap_or(base)
    }

    /// Cache identity under a base config. An override equal to the base
    /// hashes identically to no override — identity is by value, not by
    /// provenance.
    pub fn key(&self, base: &SystemConfig) -> CellKey {
        CellKey::new(self.params(), self.effective_cfg(base).clone())
    }

    /// Progress label for verbose runs.
    pub fn label(&self) -> String {
        let mut s = format!(
            "{}/{} {:.1}MB x{}",
            workload::name(self.workload),
            self.backend,
            self.footprint as f64 / (1 << 20) as f64,
            self.threads
        );
        if self.vector_bytes != 8192 {
            s += &format!(" vb={}", self.vector_bytes);
        }
        if self.cfg_override.is_some() {
            s += " [cfg]";
        }
        s
    }
}

/// Full identity of a simulation cell — the result-cache key: the cell's
/// [`TraceParams`] (workload identity, backend, footprint, threads, vector
/// size — all-integer and `Hash`) plus the effective [`SystemConfig`]. The
/// simulator is deterministic, so equal keys imply bit-identical
/// [`SimResult`]s and the second occurrence never runs.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CellKey {
    params: TraceParams,
    cfg: SystemConfig,
}

impl CellKey {
    /// Identity is by value: any `(params, effective config)` pair keys
    /// the same cache slot no matter which entry point built it.
    pub fn new(params: TraceParams, cfg: SystemConfig) -> Self {
        Self { params, cfg }
    }
}

/// An ordered list of cells; [`push`](Self::push) returns the index used to
/// look up that cell's result in the runner's output.
#[derive(Debug, Clone, Default)]
pub struct SweepPlan {
    cells: Vec<RunCell>,
}

impl SweepPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a cell; returns its result index.
    pub fn push(&mut self, cell: RunCell) -> usize {
        self.cells.push(cell);
        self.cells.len() - 1
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    pub fn cells(&self) -> &[RunCell] {
        &self.cells
    }
}

/// Scheduler accounting across everything a service (or runner) has
/// executed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Cells requested across all submissions (before dedup).
    pub cells: u64,
    /// Cells that actually simulated (`Machine::run` invocations).
    pub unique_runs: u64,
    /// Cells served without a new simulation: result-cache hits plus
    /// submissions that joined an in-flight run of the same key.
    pub cache_hits: u64,
    /// Cache lookups that scheduled a new simulation. Every miss runs
    /// exactly once, so this tracks `unique_runs`; it is kept explicit as
    /// the cache-contract counterpart of `cache_hits`/`evictions`.
    pub cache_misses: u64,
    /// Results evicted by the bounded cache (an evicted cell re-simulates
    /// if requested again).
    pub evictions: u64,
}

/// Executes [`SweepPlan`]s — a façade over an owned [`SimService`] (the
/// historical sweep-engine entry point; new code can talk to the service
/// directly).
///
/// Dedup is exact across sequential `run` calls *and* — new with the
/// service — across concurrent ones: racing `run`s on an uncached cell
/// join one in-flight simulation instead of both simulating.
pub struct SweepRunner {
    service: SimService,
}

impl SweepRunner {
    /// `jobs = 0` means `available_parallelism()`.
    pub fn new(jobs: usize) -> Self {
        Self::with_cache_capacity(jobs, DEFAULT_CACHE_CAPACITY)
    }

    /// Runner with an explicit result-cache bound (entries; LRU-ish
    /// eviction past it, counted in [`SweepStats::evictions`]).
    pub fn with_cache_capacity(jobs: usize, cache_capacity: usize) -> Self {
        Self {
            service: SimService::new(ServiceConfig {
                jobs,
                cache_capacity,
                ..ServiceConfig::default()
            }),
        }
    }

    /// The scheduler this runner submits to.
    pub fn service(&self) -> &SimService {
        &self.service
    }

    pub fn jobs(&self) -> usize {
        self.service.jobs()
    }

    pub fn stats(&self) -> SweepStats {
        self.service.stats()
    }

    /// Number of distinct cells currently cached.
    pub fn cached_cells(&self) -> usize {
        self.service.cached_cells()
    }

    /// Execute a plan; results are returned in plan order. Every cell is
    /// validated against the workload registry up front, so a bad cell
    /// fails fast (typed error) before any simulation starts.
    pub fn run(&self, base: &SystemConfig, plan: &SweepPlan) -> Result<Vec<SimResult>> {
        self.run_verbose(base, plan, false)
    }

    /// Execute a plan, optionally logging one line per simulated cell.
    pub fn run_verbose(
        &self,
        base: &SystemConfig,
        plan: &SweepPlan,
        verbose: bool,
    ) -> Result<Vec<SimResult>> {
        self.service.run_plan(base, plan, verbose)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::workloads::{SizeScale, WorkloadSet};
    use crate::trace::KernelId;

    fn small_workload() -> SizedWorkload {
        // Quick-scale MemSet, smallest size (1 MB floor).
        WorkloadSet::sizes(KernelId::MemSet, SizeScale::Quick)[0]
    }

    #[test]
    fn identical_cells_simulate_once() {
        let cfg = SystemConfig::default();
        let runner = SweepRunner::new(2);
        let mut plan = SweepPlan::new();
        let a = plan.push(RunCell::new(small_workload(), Backend::Avx));
        let b = plan.push(RunCell::new(small_workload(), Backend::Avx));
        let res = runner.run(&cfg, &plan).unwrap();
        assert_eq!(res[a].cycles, res[b].cycles);
        let stats = runner.stats();
        assert_eq!(stats.cells, 2);
        assert_eq!(stats.unique_runs, 1);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.cache_misses, 1);
        assert_eq!(stats.evictions, 0);
    }

    #[test]
    fn cache_persists_across_plans() {
        let cfg = SystemConfig::default();
        let runner = SweepRunner::new(1);
        let mut plan = SweepPlan::new();
        plan.push(RunCell::new(small_workload(), Backend::Vima));
        runner.run(&cfg, &plan).unwrap();
        runner.run(&cfg, &plan).unwrap();
        let stats = runner.stats();
        assert_eq!(stats.unique_runs, 1);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(runner.cached_cells(), 1);
    }

    #[test]
    fn bounded_cache_evicts_and_recounts() {
        let cfg = SystemConfig::default();
        let runner = SweepRunner::with_cache_capacity(1, 2);
        let sizes = WorkloadSet::sizes(KernelId::MemSet, SizeScale::Quick);
        let mut plan = SweepPlan::new();
        // Three distinct footprints through a 2-entry cache.
        for mb in [1u64, 2, 3] {
            let mut w = sizes[0];
            w.footprint = mb << 20;
            plan.push(RunCell::new(w, Backend::Avx));
        }
        runner.run(&cfg, &plan).unwrap();
        assert_eq!(runner.cached_cells(), 2);
        let stats = runner.stats();
        assert_eq!(stats.unique_runs, 3);
        assert_eq!(stats.evictions, 1);
        // Re-running the full plan re-simulates evicted cells only.
        runner.run(&cfg, &plan).unwrap();
        assert!(runner.stats().unique_runs > 3);
        assert!(runner.stats().cache_hits >= 1);
    }

    #[test]
    fn config_override_changes_identity_by_value() {
        let base = SystemConfig::default();
        let w = small_workload();
        // Override equal to the base config: same key as no override.
        assert_eq!(
            RunCell::new(w, Backend::Vima).with_cfg(base.clone()).key(&base),
            RunCell::new(w, Backend::Vima).key(&base),
        );
        // A real difference separates the keys.
        let mut small_cache = base.clone();
        small_cache.vima.cache_bytes = 16 << 10;
        assert_ne!(
            RunCell::new(w, Backend::Vima).with_cfg(small_cache).key(&base),
            RunCell::new(w, Backend::Vima).key(&base),
        );
        // So do threads and vector size.
        assert_ne!(
            RunCell::new(w, Backend::Avx).with_threads(2).key(&base),
            RunCell::new(w, Backend::Avx).key(&base),
        );
        assert_ne!(
            RunCell::new(w, Backend::Vima).with_vector_bytes(256).key(&base),
            RunCell::new(w, Backend::Vima).key(&base),
        );
    }

    #[test]
    fn machine_cache_reuses_on_matching_shape() {
        let cfg = SystemConfig::default();
        let mut mc = MachineCache::default();
        mc.get(&cfg, 1).unwrap();
        mc.get(&cfg, 1).unwrap();
        assert_eq!((mc.builds, mc.reuses), (1, 1));
        mc.get(&cfg, 2).unwrap(); // different thread count: build
        let mut other = cfg.clone();
        other.vima.cache_bytes = 16 << 10;
        mc.get(&other, 2).unwrap(); // different config: build
        assert_eq!((mc.builds, mc.reuses), (3, 1));
    }

    #[test]
    fn results_match_direct_simulation() {
        let cfg = SystemConfig::default();
        let runner = SweepRunner::new(2);
        let mut plan = SweepPlan::new();
        let w = small_workload();
        let i = plan.push(RunCell::new(w, Backend::Vima));
        let res = runner.run(&cfg, &plan).unwrap();
        let direct =
            crate::sim::simulate(&cfg, RunCell::new(w, Backend::Vima).params()).unwrap();
        assert_eq!(res[i].cycles, direct.cycles);
        assert_eq!(res[i].report, direct.report);
    }
}
