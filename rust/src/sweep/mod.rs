//! Declarative sweep engine — the experiment path's run grid.
//!
//! The paper's figures are grids of `(kernel × backend × threads × size ×
//! config)` simulation cells, and many cells recur across figures (every
//! figure normalizes to the same single-thread AVX baselines). Instead of
//! hand-rolled serial loops per figure, the coordinator now *declares* a
//! [`SweepPlan`] of [`RunCell`]s and hands it to a [`SweepRunner`], which:
//!
//! * **deduplicates** — cells are keyed by their full identity
//!   ([`CellKey`]: the cell's `Eq + Hash` [`TraceParams`] — workload,
//!   backend, footprint, threads, vector size — plus the complete
//!   [`SystemConfig`]) in a persistent result cache, so a cell
//!   shared by fig3/fig4/fig5 simulates exactly once per runner (across
//!   *sequential* `run` calls — two `run`s racing on the same runner may
//!   both simulate a cell neither has cached yet; results are unaffected,
//!   the work is just duplicated);
//! * **parallelizes** — unique cells execute on a `std::thread::scope`
//!   worker pool (default `available_parallelism()`, `--jobs N` override;
//!   no extra dependencies). Each simulation is single-threaded and
//!   deterministic, so scheduling order cannot change any result: serial
//!   (`jobs = 1`) and parallel runs produce bit-identical tables;
//! * **reuses machines** — each worker keeps its [`Machine`] alive across
//!   cells with the same `(config, threads)` shape and calls
//!   [`Machine::reset`] instead of reallocating the cache hierarchy
//!   (see [`MachineCache`]).
//!
//! Results come back in plan order, so callers assemble figure tables by
//! the indices [`SweepPlan::push`] returned.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::SystemConfig;
use crate::coordinator::workloads::SizedWorkload;
use crate::sim::{run_on, Machine, SimResult};
use crate::trace::{Backend, TraceParams};
use crate::util::error::Result;
use crate::workload::{self, WorkloadId};

/// One cell of the run grid: a workload on a backend with a thread count
/// and an optional configuration override.
#[derive(Debug, Clone)]
pub struct RunCell {
    /// Registry identity — any registered workload, paper kernel or custom.
    pub workload: WorkloadId,
    /// Total data footprint in bytes.
    pub footprint: u64,
    pub backend: Backend,
    /// Data-parallel host cores driving the run.
    pub threads: usize,
    /// VIMA/HIVE vector size in bytes (8192 default; the ablation sweeps it).
    pub vector_bytes: u32,
    /// Full-config override; `None` inherits the sweep's base config.
    pub cfg_override: Option<SystemConfig>,
}

impl RunCell {
    pub fn new(w: SizedWorkload, backend: Backend) -> Self {
        Self {
            workload: w.workload,
            footprint: w.footprint,
            backend,
            threads: 1,
            vector_bytes: 8192,
            cfg_override: None,
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_vector_bytes(mut self, vb: u32) -> Self {
        self.vector_bytes = vb;
        self
    }

    pub fn with_cfg(mut self, cfg: SystemConfig) -> Self {
        self.cfg_override = Some(cfg);
        self
    }

    /// Trace-generator parameters for this cell (per-thread slicing happens
    /// inside [`run_on`]).
    pub fn params(&self) -> TraceParams {
        TraceParams::new(self.workload, self.backend, self.footprint)
            .with_vector_bytes(self.vector_bytes)
            .with_threads(0, self.threads)
    }

    fn effective_cfg<'a>(&'a self, base: &'a SystemConfig) -> &'a SystemConfig {
        self.cfg_override.as_ref().unwrap_or(base)
    }

    /// Cache identity under a base config. An override equal to the base
    /// hashes identically to no override — identity is by value, not by
    /// provenance.
    pub fn key(&self, base: &SystemConfig) -> CellKey {
        CellKey { params: self.params(), cfg: self.effective_cfg(base).clone() }
    }

    /// Progress label for verbose runs.
    pub fn label(&self) -> String {
        let mut s = format!(
            "{}/{} {:.1}MB x{}",
            workload::name(self.workload),
            self.backend,
            self.footprint as f64 / (1 << 20) as f64,
            self.threads
        );
        if self.vector_bytes != 8192 {
            s += &format!(" vb={}", self.vector_bytes);
        }
        if self.cfg_override.is_some() {
            s += " [cfg]";
        }
        s
    }
}

/// Full identity of a simulation cell — the result-cache key: the cell's
/// [`TraceParams`] (workload identity, backend, footprint, threads, vector
/// size — all-integer and `Hash`) plus the effective [`SystemConfig`]. The
/// simulator is deterministic, so equal keys imply bit-identical
/// [`SimResult`]s and the second occurrence never runs.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CellKey {
    params: TraceParams,
    cfg: SystemConfig,
}

/// An ordered list of cells; [`push`](Self::push) returns the index used to
/// look up that cell's result in the runner's output.
#[derive(Debug, Clone, Default)]
pub struct SweepPlan {
    cells: Vec<RunCell>,
}

impl SweepPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a cell; returns its result index.
    pub fn push(&mut self, cell: RunCell) -> usize {
        self.cells.push(cell);
        self.cells.len() - 1
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    pub fn cells(&self) -> &[RunCell] {
        &self.cells
    }
}

/// Per-worker machine reuse: consecutive cells sharing a `(config,
/// threads)` shape re-run on a [`Machine::reset`] machine instead of a
/// fresh allocation.
#[derive(Default)]
pub struct MachineCache {
    machine: Option<Machine>,
    pub reuses: u64,
    pub builds: u64,
}

impl MachineCache {
    pub fn get(&mut self, cfg: &SystemConfig, threads: usize) -> &mut Machine {
        let reusable =
            self.machine.as_ref().is_some_and(|m| m.threads() == threads && m.cfg == *cfg);
        if reusable {
            self.reuses += 1;
            let m = self.machine.as_mut().unwrap();
            m.reset();
            m
        } else {
            self.builds += 1;
            self.machine = Some(Machine::new(cfg, threads));
            self.machine.as_mut().unwrap()
        }
    }
}

/// Dedup accounting across every plan a runner has executed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Cells requested across all plans (before dedup).
    pub cells: u64,
    /// Cells that actually simulated (`Machine::run` invocations).
    pub unique_runs: u64,
    /// Cells answered from the result cache (or deduped within a plan).
    pub cache_hits: u64,
}

/// Executes [`SweepPlan`]s against a persistent, thread-safe result cache.
///
/// Dedup is exact across sequential `run` calls. The runner is `Sync`, but
/// concurrent `run` calls do not coordinate in-flight work: cells neither
/// call has cached yet may simulate in both (results identical — the
/// simulator is deterministic — only wall-clock and the stats counters
/// notice). The coordinator only issues sequential runs.
pub struct SweepRunner {
    jobs: usize,
    cache: Mutex<HashMap<CellKey, SimResult>>,
    stats: Mutex<SweepStats>,
}

impl SweepRunner {
    /// `jobs = 0` means `available_parallelism()`.
    pub fn new(jobs: usize) -> Self {
        Self {
            jobs: resolve_jobs(jobs),
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(SweepStats::default()),
        }
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    pub fn stats(&self) -> SweepStats {
        *self.stats.lock().unwrap()
    }

    /// Number of distinct cells currently cached.
    pub fn cached_cells(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute a plan; results are returned in plan order. Every cell is
    /// validated against the workload registry up front, so a bad cell
    /// fails fast (typed error) before any simulation starts.
    pub fn run(&self, base: &SystemConfig, plan: &SweepPlan) -> Result<Vec<SimResult>> {
        self.run_verbose(base, plan, false)
    }

    /// Execute a plan, optionally logging one line per simulated cell.
    pub fn run_verbose(
        &self,
        base: &SystemConfig,
        plan: &SweepPlan,
        verbose: bool,
    ) -> Result<Vec<SimResult>> {
        for cell in plan.cells() {
            cell.params()
                .check()
                .map_err(|e| e.context(format!("sweep cell {}", cell.label())))?;
        }
        let keys: Vec<CellKey> = plan.cells().iter().map(|c| c.key(base)).collect();

        // First occurrence of each not-yet-cached key gets simulated; later
        // occurrences (and cached keys) are hits.
        let todo: Vec<usize> = {
            let cache = self.cache.lock().unwrap();
            let mut claimed: HashSet<&CellKey> = HashSet::new();
            let mut todo = Vec::new();
            for (i, k) in keys.iter().enumerate() {
                if !cache.contains_key(k) && claimed.insert(k) {
                    todo.push(i);
                }
            }
            let mut stats = self.stats.lock().unwrap();
            stats.cells += keys.len() as u64;
            stats.unique_runs += todo.len() as u64;
            stats.cache_hits += (keys.len() - todo.len()) as u64;
            todo
        };

        if !todo.is_empty() {
            let workers = self.jobs.min(todo.len()).max(1);
            let next = AtomicUsize::new(0);
            let done: Mutex<Vec<(usize, Result<SimResult>)>> =
                Mutex::new(Vec::with_capacity(todo.len()));
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| {
                        let mut machines = MachineCache::default();
                        loop {
                            let j = next.fetch_add(1, Ordering::Relaxed);
                            let Some(&i) = todo.get(j) else { break };
                            let cell = &plan.cells()[i];
                            let cfg = cell.effective_cfg(base);
                            if verbose {
                                eprintln!("[vima-sim] run {}", cell.label());
                            }
                            let machine = machines.get(cfg, cell.threads);
                            // Pre-validation catches registry/parameter
                            // errors; a custom workload's chunker can still
                            // fail here, so errors propagate, never panic.
                            let result = run_on(machine, cell.params());
                            done.lock().unwrap().push((i, result));
                        }
                    });
                }
            });
            let mut cache = self.cache.lock().unwrap();
            let mut first_err = None;
            for (i, result) in done.into_inner().unwrap() {
                match result {
                    Ok(r) => {
                        cache.insert(keys[i].clone(), r);
                    }
                    Err(e) if first_err.is_none() => {
                        first_err =
                            Some(e.context(format!("sweep cell {}", plan.cells()[i].label())));
                    }
                    Err(_) => {}
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
        }

        let cache = self.cache.lock().unwrap();
        Ok(keys.iter().map(|k| cache[k].clone()).collect())
    }
}

fn resolve_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::workloads::{SizeScale, WorkloadSet};
    use crate::trace::KernelId;

    fn small_workload() -> SizedWorkload {
        // Quick-scale MemSet, smallest size (1 MB floor).
        WorkloadSet::sizes(KernelId::MemSet, SizeScale::Quick)[0]
    }

    #[test]
    fn identical_cells_simulate_once() {
        let cfg = SystemConfig::default();
        let runner = SweepRunner::new(2);
        let mut plan = SweepPlan::new();
        let a = plan.push(RunCell::new(small_workload(), Backend::Avx));
        let b = plan.push(RunCell::new(small_workload(), Backend::Avx));
        let res = runner.run(&cfg, &plan).unwrap();
        assert_eq!(res[a].cycles, res[b].cycles);
        let stats = runner.stats();
        assert_eq!(stats.cells, 2);
        assert_eq!(stats.unique_runs, 1);
        assert_eq!(stats.cache_hits, 1);
    }

    #[test]
    fn cache_persists_across_plans() {
        let cfg = SystemConfig::default();
        let runner = SweepRunner::new(1);
        let mut plan = SweepPlan::new();
        plan.push(RunCell::new(small_workload(), Backend::Vima));
        runner.run(&cfg, &plan).unwrap();
        runner.run(&cfg, &plan).unwrap();
        let stats = runner.stats();
        assert_eq!(stats.unique_runs, 1);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(runner.cached_cells(), 1);
    }

    #[test]
    fn config_override_changes_identity_by_value() {
        let base = SystemConfig::default();
        let w = small_workload();
        // Override equal to the base config: same key as no override.
        assert_eq!(
            RunCell::new(w, Backend::Vima).with_cfg(base.clone()).key(&base),
            RunCell::new(w, Backend::Vima).key(&base),
        );
        // A real difference separates the keys.
        let mut small_cache = base.clone();
        small_cache.vima.cache_bytes = 16 << 10;
        assert_ne!(
            RunCell::new(w, Backend::Vima).with_cfg(small_cache).key(&base),
            RunCell::new(w, Backend::Vima).key(&base),
        );
        // So do threads and vector size.
        assert_ne!(
            RunCell::new(w, Backend::Avx).with_threads(2).key(&base),
            RunCell::new(w, Backend::Avx).key(&base),
        );
        assert_ne!(
            RunCell::new(w, Backend::Vima).with_vector_bytes(256).key(&base),
            RunCell::new(w, Backend::Vima).key(&base),
        );
    }

    #[test]
    fn machine_cache_reuses_on_matching_shape() {
        let cfg = SystemConfig::default();
        let mut mc = MachineCache::default();
        mc.get(&cfg, 1);
        mc.get(&cfg, 1);
        assert_eq!((mc.builds, mc.reuses), (1, 1));
        mc.get(&cfg, 2); // different thread count: rebuild
        let mut other = cfg.clone();
        other.vima.cache_bytes = 16 << 10;
        mc.get(&other, 2); // different config: rebuild
        assert_eq!((mc.builds, mc.reuses), (3, 1));
    }

    #[test]
    fn results_match_direct_simulation() {
        let cfg = SystemConfig::default();
        let runner = SweepRunner::new(2);
        let mut plan = SweepPlan::new();
        let w = small_workload();
        let i = plan.push(RunCell::new(w, Backend::Vima));
        let res = runner.run(&cfg, &plan).unwrap();
        let direct =
            crate::sim::simulate(&cfg, RunCell::new(w, Backend::Vima).params()).unwrap();
        assert_eq!(res[i].cycles, direct.cycles);
        assert_eq!(res[i].report, direct.report);
    }
}
