//! Simulator throughput benchmark — the §Perf trajectory instrument.
//!
//! Measures **simulated events per second** of the chunked execution
//! engine ([`Machine::run`]) against the event-at-a-time reference path
//! ([`Machine::run_reference`]) over a representative workload matrix, and
//! serializes the result as the `BENCH_*.json` record the repo's perf
//! trajectory is built from (`vima-sim bench --json BENCH_PR3.json`; CI
//! uploads it as an artifact on every push).
//!
//! JSON is emitted by hand: the offline build is dependency-free by
//! design, and the schema is flat (see [`ThroughputReport::to_json`]).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::config::SystemConfig;
use crate::coordinator::workloads::{SizeScale, WorkloadSet};
use crate::net::{run_sharded, NetServer, ShardOptions};
use crate::service::{Job, ServiceConfig, SimService};
use crate::sim::{run_on, Machine};
use crate::sweep::{RunCell, SweepPlan};
use crate::trace::{Backend, KernelId, TraceParams, TraceStream};
use crate::util::error::{Context, Result};
use crate::workload::{self, WorkloadId};
use crate::{bail, ensure};

/// One benchmark cell: a workload/backend pair timed on both engines.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub workload: String,
    pub backend: String,
    /// Dynamic trace events simulated per run.
    pub events: u64,
    /// Simulated events per wall-clock second, reference engine.
    pub reference_eps: f64,
    /// Simulated events per wall-clock second, chunked engine.
    pub chunked_eps: f64,
    /// `chunked_eps / reference_eps`.
    pub speedup: f64,
}

/// One accuracy/speed frontier cell: the same workload run full-detail and
/// sampled (DESIGN.md §11), comparing wall time and reported results.
#[derive(Debug, Clone)]
pub struct SampledRow {
    pub workload: String,
    pub backend: String,
    /// Dynamic trace events in the full run.
    pub events: u64,
    /// Detailed-window events the sampled run actually simulated in detail.
    pub detailed_events: u64,
    pub full_wall_s: f64,
    pub sampled_wall_s: f64,
    /// `full_wall_s / sampled_wall_s`.
    pub speedup: f64,
    /// `|sampled.cycles - full.cycles| / full.cycles * 100`.
    pub cycle_error_pct: f64,
    /// `|sampled.energy - full.energy| / full.energy * 100`.
    pub energy_error_pct: f64,
}

/// One connection-scaling point of the serving saturation bench
/// (`bench --net`): N concurrent loopback-TCP clients pipelining
/// warm-cache requests at one `vima-sim net` server.
#[derive(Debug, Clone)]
pub struct NetConnRow {
    pub connections: usize,
    /// Total requests answered across every connection.
    pub requests: u64,
    pub wall_s: f64,
    /// `requests / wall_s` — protocol + scheduling throughput, since the
    /// result cache is pre-warmed.
    pub jobs_per_sec: f64,
}

/// One worker-scaling point of the serving saturation bench: the
/// quick-scale Fig. 2 plan sharded across N `net worker` processes.
#[derive(Debug, Clone)]
pub struct NetWorkerRow {
    pub workers: usize,
    /// Plan cells (before dedup).
    pub cells: usize,
    /// Unique cells actually dispatched.
    pub unique: usize,
    pub wall_s: f64,
    /// `unique / wall_s` — end-to-end sharded sweep throughput.
    pub cells_per_sec: f64,
}

/// One predicted-vs-simulated cross-check cell (`bench --predict`,
/// DESIGN.md §15): a registered program's static-cost-model cycle
/// prediction against the cycle count the detailed simulator reports for
/// the same program, machine, and VIMA lowering.
#[derive(Debug, Clone)]
pub struct PredictRow {
    pub workload: String,
    /// Cycles the static cost model predicts (no simulation).
    pub predicted_cycles: u64,
    /// Cycles the detailed simulator reports.
    pub simulated_cycles: u64,
    /// Signed relative error: `(predicted - simulated) / simulated * 100`.
    pub error_pct: f64,
}

/// The `bench --net` section: serving-layer saturation along both axes
/// (connections into one server, worker processes under one coordinator).
#[derive(Debug, Clone)]
pub struct NetReport {
    pub conn_rows: Vec<NetConnRow>,
    pub worker_rows: Vec<NetWorkerRow>,
}

impl NetReport {
    /// Best jobs/sec across the connection-scaling rows.
    pub fn peak_jobs_per_sec(&self) -> f64 {
        self.conn_rows.iter().map(|r| r.jobs_per_sec).fold(0.0, f64::max)
    }

    /// Connection count of the peak jobs/sec row.
    pub fn peak_connections(&self) -> usize {
        self.conn_rows
            .iter()
            .max_by(|a, b| a.jobs_per_sec.total_cmp(&b.jobs_per_sec))
            .map(|r| r.connections)
            .unwrap_or(0)
    }
}

/// The full benchmark record; serializes to `BENCH_*.json`.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    /// True when run on the 1/16 quick sizes (CI smoke mode).
    pub quick: bool,
    pub iters: u32,
    pub rows: Vec<ThroughputRow>,
    /// Sampled-mode accuracy/speed frontier (`bench --sampled`); empty
    /// when the frontier was not requested.
    pub sampled: Vec<SampledRow>,
    /// Serving saturation section (`bench --net`); absent when the net
    /// section was not requested.
    pub net: Option<NetReport>,
    /// Predicted-vs-simulated cross-check (`bench --predict`); empty when
    /// the cross-check was not requested.
    pub predict: Vec<PredictRow>,
}

impl ThroughputReport {
    /// Geometric mean of the per-row chunked-vs-reference speedups.
    pub fn geomean_speedup(&self) -> f64 {
        if self.rows.is_empty() {
            return 1.0;
        }
        let log_sum: f64 = self.rows.iter().map(|r| r.speedup.ln()).sum();
        (log_sum / self.rows.len() as f64).exp()
    }

    pub fn min_speedup(&self) -> f64 {
        self.rows.iter().map(|r| r.speedup).fold(f64::INFINITY, f64::min)
    }

    /// Best chunked events/sec across rows (the headline throughput).
    pub fn peak_chunked_eps(&self) -> f64 {
        self.rows.iter().map(|r| r.chunked_eps).fold(0.0, f64::max)
    }

    /// Geometric mean of the sampled-vs-full wall-clock speedups.
    pub fn geomean_sampled_speedup(&self) -> f64 {
        if self.sampled.is_empty() {
            return 1.0;
        }
        let log_sum: f64 = self.sampled.iter().map(|r| r.speedup.ln()).sum();
        (log_sum / self.sampled.len() as f64).exp()
    }

    /// Worst cycle error across the sampled frontier, in percent.
    pub fn max_cycle_error_pct(&self) -> f64 {
        self.sampled.iter().map(|r| r.cycle_error_pct).fold(0.0, f64::max)
    }

    /// Worst energy error across the sampled frontier, in percent.
    pub fn max_energy_error_pct(&self) -> f64 {
        self.sampled.iter().map(|r| r.energy_error_pct).fold(0.0, f64::max)
    }

    /// Worst absolute prediction error across the `--predict` rows, in
    /// percent.
    pub fn max_predict_error_pct(&self) -> f64 {
        self.predict.iter().map(|r| r.error_pct.abs()).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s += "  \"benchmark\": \"vima-sim simulator throughput (events/sec)\",\n";
        s += &format!("  \"quick\": {},\n  \"iters\": {},\n", self.quick, self.iters);
        s += "  \"rows\": [\n";
        for (i, r) in self.rows.iter().enumerate() {
            s += &format!(
                "    {{\"workload\": \"{}\", \"backend\": \"{}\", \"events\": {}, \
                 \"reference_events_per_sec\": {:.0}, \"chunked_events_per_sec\": {:.0}, \
                 \"speedup\": {:.3}}}{}\n",
                r.workload,
                r.backend,
                r.events,
                r.reference_eps,
                r.chunked_eps,
                r.speedup,
                if i + 1 < self.rows.len() { "," } else { "" }
            );
        }
        s += "  ],\n";
        if !self.sampled.is_empty() {
            s += "  \"sampled\": [\n";
            for (i, r) in self.sampled.iter().enumerate() {
                s += &format!(
                    "    {{\"workload\": \"{}\", \"backend\": \"{}\", \"events\": {}, \
                     \"detailed_events\": {}, \"full_wall_s\": {:.4}, \
                     \"sampled_wall_s\": {:.4}, \"speedup\": {:.2}, \
                     \"cycle_error_pct\": {:.3}, \"energy_error_pct\": {:.3}}}{}\n",
                    r.workload,
                    r.backend,
                    r.events,
                    r.detailed_events,
                    r.full_wall_s,
                    r.sampled_wall_s,
                    r.speedup,
                    r.cycle_error_pct,
                    r.energy_error_pct,
                    if i + 1 < self.sampled.len() { "," } else { "" }
                );
            }
            s += "  ],\n";
            s += &format!(
                "  \"sampled_summary\": {{\"geomean_speedup\": {:.2}, \
                 \"max_cycle_error_pct\": {:.3}, \"max_energy_error_pct\": {:.3}}},\n",
                self.geomean_sampled_speedup(),
                self.max_cycle_error_pct(),
                self.max_energy_error_pct()
            );
        }
        if !self.predict.is_empty() {
            s += "  \"predict\": [\n";
            for (i, r) in self.predict.iter().enumerate() {
                s += &format!(
                    "    {{\"workload\": \"{}\", \"backend\": \"vima\", \
                     \"predicted_cycles\": {}, \"simulated_cycles\": {}, \
                     \"error_pct\": {:.2}}}{}\n",
                    r.workload,
                    r.predicted_cycles,
                    r.simulated_cycles,
                    r.error_pct,
                    if i + 1 < self.predict.len() { "," } else { "" }
                );
            }
            s += "  ],\n";
            s += &format!(
                "  \"predict_summary\": {{\"max_abs_error_pct\": {:.2}}},\n",
                self.max_predict_error_pct()
            );
        }
        if let Some(net) = &self.net {
            s += "  \"net\": {\n    \"connections\": [\n";
            for (i, r) in net.conn_rows.iter().enumerate() {
                s += &format!(
                    "      {{\"connections\": {}, \"requests\": {}, \"wall_s\": {:.4}, \
                     \"jobs_per_sec\": {:.0}}}{}\n",
                    r.connections,
                    r.requests,
                    r.wall_s,
                    r.jobs_per_sec,
                    if i + 1 < net.conn_rows.len() { "," } else { "" }
                );
            }
            s += "    ],\n    \"workers\": [\n";
            for (i, r) in net.worker_rows.iter().enumerate() {
                s += &format!(
                    "      {{\"workers\": {}, \"cells\": {}, \"unique_cells\": {}, \
                     \"wall_s\": {:.4}, \"cells_per_sec\": {:.2}}}{}\n",
                    r.workers,
                    r.cells,
                    r.unique,
                    r.wall_s,
                    r.cells_per_sec,
                    if i + 1 < net.worker_rows.len() { "," } else { "" }
                );
            }
            s += &format!(
                "    ],\n    \"peak_jobs_per_sec\": {:.0}\n  }},\n",
                net.peak_jobs_per_sec()
            );
        }
        s += &format!(
            "  \"summary\": {{\"geomean_speedup\": {:.3}, \"min_speedup\": {:.3}, \
             \"peak_chunked_events_per_sec\": {:.0}}}\n",
            self.geomean_speedup(),
            self.min_speedup(),
            self.peak_chunked_eps()
        );
        s += "}\n";
        s
    }
}

/// Workload matrix: the three trace shapes that stress different hot paths
/// (µop-dense AVX streaming, VIMA instruction dispatch + coherence walks,
/// HIVE transactions), plus a multithreaded cell for the interleaver, plus
/// one loaded-`.vpr` program cell (`saxpy` round-tripped through the text
/// format) so the parser + `ProgramChunker` path is tracked in the
/// `BENCH_*.json` trajectory. The program cell's footprint is fixed by its
/// structure, so `quick` does not scale it.
fn matrix(quick: bool) -> Result<Vec<(WorkloadId, String, Backend, u64, usize)>> {
    let mb = if quick { 1u64 } else { 8 };
    let kernel_cells = [
        (KernelId::VecSum, Backend::Avx, mb << 20, 1),
        (KernelId::MemCopy, Backend::Avx, mb << 20, 1),
        (KernelId::VecSum, Backend::Vima, mb << 20, 1),
        (KernelId::VecSum, Backend::Hive, mb << 20, 1),
        (KernelId::VecSum, Backend::Avx, mb << 20, 4),
    ];
    let mut cells: Vec<(WorkloadId, String, Backend, u64, usize)> = kernel_cells
        .into_iter()
        .map(|(k, b, fp, t)| (k.into(), k.to_string(), b, fp, t))
        .collect();
    let vpr = crate::program::bench_workload()?;
    let fp = workload::get(vpr)?.default_footprint();
    cells.push((vpr, workload::name(vpr), Backend::Vima, fp, 1));
    Ok(cells)
}

fn streams(p: TraceParams, threads: usize) -> Result<Vec<TraceStream>> {
    (0..threads).map(|t| p.with_threads(t, threads).stream()).collect()
}

/// Median-of-`iters` wall time of `f` (one warm-up run first). Even
/// iteration counts average the two middle samples — `times[len / 2]`
/// alone would report the *slower* middle, turning one scheduler hiccup
/// under `--iters 2` into a fake regression in the trajectory record.
fn time_runs(iters: u32, mut f: impl FnMut() -> Result<u64>) -> Result<f64> {
    std::hint::black_box(f()?);
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f()?);
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let mid = times.len() / 2;
    Ok(if times.len() % 2 == 1 { times[mid] } else { (times[mid - 1] + times[mid]) / 2.0 })
}

/// Run the throughput matrix; `verbose` prints one line per cell.
pub fn throughput(
    cfg: &SystemConfig,
    quick: bool,
    iters: u32,
    verbose: bool,
) -> Result<ThroughputReport> {
    let mut rows = Vec::new();
    for (id, name, backend, footprint, threads) in matrix(quick)? {
        let p = TraceParams::new(id, backend, footprint);
        let events = streams(p, threads)?
            .into_iter()
            .map(|s| s.count() as u64)
            .sum::<u64>();
        let mut m = Machine::new(cfg, threads)?;
        let t_ref = time_runs(iters, || {
            m.reset();
            Ok(m.run_reference(streams(p, threads)?)?.cycles)
        })?;
        let t_chunk = time_runs(iters, || {
            m.reset();
            Ok(m.run(streams(p, threads)?)?.cycles)
        })?;
        let row = ThroughputRow {
            workload: name,
            backend: backend.to_string(),
            events,
            reference_eps: events as f64 / t_ref,
            chunked_eps: events as f64 / t_chunk,
            speedup: t_ref / t_chunk,
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench {}/{} x{}: {:.2}M ev/s chunked vs {:.2}M ev/s reference \
                 ({:.2}x)",
                row.workload,
                row.backend,
                threads,
                row.chunked_eps / 1e6,
                row.reference_eps / 1e6,
                row.speedup
            );
        }
        rows.push(row);
    }
    Ok(ThroughputReport { quick, iters, rows, sampled: Vec::new(), net: None, predict: Vec::new() })
}

/// Streaming-kernel cells for the sampled accuracy/speed frontier:
/// µop-dense AVX traces at paper-scale footprints — the shapes where
/// fast-forward has the most events to skip.
fn sampled_matrix(quick: bool) -> Vec<(KernelId, Backend, u64)> {
    let mb = if quick { 2u64 } else { 24 };
    vec![
        (KernelId::MemSet, Backend::Avx, mb << 20),
        (KernelId::MemCopy, Backend::Avx, mb << 20),
        (KernelId::VecSum, Backend::Avx, mb << 20),
        (KernelId::Stencil, Backend::Avx, mb << 20),
    ]
}

/// Measure the sampled-mode accuracy/speed frontier (`bench --sampled`):
/// each streaming kernel timed full-detail vs sampled at the workload's
/// default window/period, comparing the reported cycles and energy. Goes
/// through the production [`run_on`] path so every number matches what a
/// sampled sweep cell would report.
pub fn sampled_frontier(
    cfg: &SystemConfig,
    quick: bool,
    iters: u32,
    verbose: bool,
) -> Result<Vec<SampledRow>> {
    let mut cfg_sampled = cfg.clone();
    cfg_sampled.sample.enabled = true;
    let err_pct =
        |got: f64, want: f64| if want == 0.0 { 0.0 } else { (got - want).abs() / want * 100.0 };
    let mut rows = Vec::new();
    for (kernel, backend, footprint) in sampled_matrix(quick) {
        let p = TraceParams::new(kernel, backend, footprint);
        let events = p.stream()?.count() as u64;
        let mut m_full = Machine::new(cfg, 1)?;
        let mut m_sampled = Machine::new(&cfg_sampled, 1)?;
        let full = run_on(&mut m_full, p)?;
        m_sampled.reset();
        let sampled = run_on(&mut m_sampled, p)?;
        let detailed_events =
            sampled.report.get("sample.detailed_events").unwrap_or(events as f64) as u64;
        let full_wall_s = time_runs(iters, || {
            m_full.reset();
            Ok(run_on(&mut m_full, p)?.cycles)
        })?;
        let sampled_wall_s = time_runs(iters, || {
            m_sampled.reset();
            Ok(run_on(&mut m_sampled, p)?.cycles)
        })?;
        let row = SampledRow {
            workload: kernel.to_string(),
            backend: backend.to_string(),
            events,
            detailed_events,
            full_wall_s,
            sampled_wall_s,
            speedup: full_wall_s / sampled_wall_s,
            cycle_error_pct: err_pct(sampled.cycles as f64, full.cycles as f64),
            energy_error_pct: err_pct(sampled.energy.total_j, full.energy.total_j),
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench --sampled {}/{}: {:.2}x wall speedup, \
                 cycle err {:.2}%, energy err {:.2}%",
                row.workload, row.backend, row.speedup, row.cycle_error_pct, row.energy_error_pct
            );
        }
        rows.push(row);
    }
    Ok(rows)
}

/// Measure the predicted-vs-simulated cross-check (`bench --predict`,
/// DESIGN.md §15): every registered program workload — the built-ins plus
/// anything registered via `--load` (e.g. the golden `.vpr` files) — has
/// its static-cost-model cycle prediction compared against a detailed
/// single-thread VIMA simulation of the same program on the same machine
/// configuration. Paper kernels have no statement tree and are skipped.
/// The row reports *signed* relative error so systematic over- and
/// under-prediction stay distinguishable in the `BENCH_*.json` trajectory.
pub fn predict_frontier(cfg: &SystemConfig, verbose: bool) -> Result<Vec<PredictRow>> {
    let mut rows = Vec::new();
    for id in workload::all_ids() {
        let w = workload::get(id)?;
        let Some(cost) = w.predict(cfg) else { continue };
        let predicted = cost.vima.predicted_cycles.unwrap_or(0);
        let p = TraceParams::new(id, Backend::Vima, w.default_footprint());
        let mut m = Machine::new(cfg, 1)?;
        let simulated = run_on(&mut m, p)?.cycles;
        let error_pct = if simulated == 0 {
            0.0
        } else {
            (predicted as f64 - simulated as f64) / simulated as f64 * 100.0
        };
        let row = PredictRow {
            workload: w.name().to_string(),
            predicted_cycles: predicted,
            simulated_cycles: simulated,
            error_pct,
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench --predict {}: {} predicted vs {} simulated cycles \
                 ({:+.2}%)",
                row.workload, row.predicted_cycles, row.simulated_cycles, row.error_pct
            );
        }
        rows.push(row);
    }
    rows.sort_by(|a, b| a.workload.cmp(&b.workload));
    Ok(rows)
}

/// Distinct warm-cache cells the connection-scaling clients rotate over.
const NET_DISTINCT_CELLS: usize = 8;

/// The request line for the `i`-th connection-scaling job: one of
/// [`NET_DISTINCT_CELLS`] small memset/AVX cells, all pre-warmed into the
/// service cache so the row measures protocol + scheduling throughput.
fn net_request(i: u64) -> String {
    format!(
        "{{\"id\": {i}, \"workload\": \"memset\", \"backend\": \"avx\", \"footprint\": {}}}",
        net_footprint(i as usize % NET_DISTINCT_CELLS)
    )
}

fn net_footprint(k: usize) -> u64 {
    ((k + 1) as u64) * (256 << 10)
}

/// One client of the connection-scaling bench: pipeline `total` requests
/// with a bounded in-flight depth (write-then-read interleave, so neither
/// the session window nor the TCP buffers can deadlock the pair) and
/// verify every response is a `done` line.
fn net_client(addr: &str, total: u64) -> Result<u64> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect bench client to {addr}"))?;
    let mut reader = BufReader::new(stream.try_clone().context("clone bench client stream")?);
    let depth = 16u64.min(total.max(1));
    let (mut sent, mut received) = (0u64, 0u64);
    let mut line = String::new();
    while received < total {
        while sent < total && sent - received < depth {
            writeln!(stream, "{}", net_request(sent))?;
            sent += 1;
        }
        stream.flush()?;
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection after {received}/{total} responses");
        }
        ensure!(
            line.contains("\"status\": \"done\""),
            "bench client expected a done line, got: {}",
            line.trim()
        );
        received += 1;
    }
    Ok(received)
}

/// Measure the serving saturation section (`bench --net`, DESIGN.md §14).
///
/// Two axes:
/// * **Connections** — one in-process [`NetServer`] on an ephemeral
///   loopback port, N concurrent pipelining clients over a pre-warmed
///   result cache: jobs/sec vs connection count.
/// * **Workers** — the Fig. 2 plan sharded via [`run_sharded`] across N
///   spawned `net worker` processes (one scheduler job each, so scaling
///   comes from processes, not intra-worker threads): cells/sec vs worker
///   count. Always quick-scale — the axis measures orchestration, not
///   simulator throughput (the `rows` section already tracks that).
pub fn net_saturation(cfg: &SystemConfig, quick: bool, verbose: bool) -> Result<NetReport> {
    let conn_counts: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let per_conn: u64 = if quick { 200 } else { 1000 };

    let svc = SimService::new(ServiceConfig { base: cfg.clone(), ..ServiceConfig::default() });
    // Pre-warm every distinct cell so the timed rounds are pure cache
    // hits: the row should saturate the serving layer, not the simulator.
    let memset = workload::resolve("memset")?;
    for k in 0..NET_DISTINCT_CELLS {
        svc.submit(Job::new(TraceParams::new(memset, Backend::Avx, net_footprint(k)))).wait()?;
    }

    let mut conn_rows = Vec::new();
    for &connections in conn_counts {
        let server = NetServer::bind_tcp("127.0.0.1:0")?;
        let addr = server.local_addr();
        let ctl = server.ctl();
        let (wall_s, requests) = std::thread::scope(|scope| -> Result<(f64, u64)> {
            let serving = scope.spawn(|| server.serve(&svc));
            let t0 = Instant::now();
            let clients: Vec<_> = (0..connections)
                .map(|_| {
                    let addr = addr.clone();
                    scope.spawn(move || net_client(&addr, per_conn))
                })
                .collect();
            let mut requests = 0u64;
            for client in clients {
                requests += client
                    .join()
                    .unwrap_or_else(|_| Err(crate::util::error::Error::msg(
                        "bench client panicked",
                    )))?;
            }
            let wall_s = t0.elapsed().as_secs_f64();
            ctl.request_drain();
            serving.join().expect("bench server thread")?;
            Ok((wall_s, requests))
        })?;
        let row = NetConnRow {
            connections,
            requests,
            wall_s,
            jobs_per_sec: requests as f64 / wall_s.max(1e-9),
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench --net: {} connection(s): {} request(s) in {:.3}s \
                 ({:.0} jobs/s)",
                row.connections, row.requests, row.wall_s, row.jobs_per_sec
            );
        }
        conn_rows.push(row);
    }

    let mut plan = SweepPlan::new();
    for w in WorkloadSet::fig2(SizeScale::Quick) {
        for b in [Backend::Avx, Backend::Hive, Backend::Vima] {
            plan.push(RunCell::new(w, b));
        }
    }
    let mut worker_rows = Vec::new();
    for &workers in if quick { &[1usize, 2][..] } else { &[1usize, 2, 4][..] } {
        let opts = ShardOptions { workers, worker_jobs: 1, ..ShardOptions::default() };
        let t0 = Instant::now();
        let (_, stats) = run_sharded(cfg, &plan, &opts)?;
        let wall_s = t0.elapsed().as_secs_f64();
        let row = NetWorkerRow {
            workers,
            cells: stats.cells,
            unique: stats.unique_cells,
            wall_s,
            cells_per_sec: stats.unique_cells as f64 / wall_s.max(1e-9),
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench --net: {} worker(s): {} unique cell(s) in {:.3}s \
                 ({:.2} cells/s)",
                row.workers, row.unique, row.wall_s, row.cells_per_sec
            );
        }
        worker_rows.push(row);
    }
    Ok(NetReport { conn_rows, worker_rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_is_parseable_enough() {
        let report = ThroughputReport {
            quick: true,
            iters: 1,
            rows: vec![ThroughputRow {
                workload: "VecSum".into(),
                backend: "AVX".into(),
                events: 1000,
                reference_eps: 1e6,
                chunked_eps: 2e6,
                speedup: 2.0,
            }],
            sampled: Vec::new(),
            net: None,
            predict: Vec::new(),
        };
        let j = report.to_json();
        assert!(j.contains("\"speedup\": 2.000"), "{j}");
        assert!(j.contains("\"geomean_speedup\": 2.000"), "{j}");
        assert!(!j.contains("\"sampled\""), "{j}");
        assert!(j.ends_with("}\n"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn sampled_section_appears_and_balances() {
        let report = ThroughputReport {
            quick: true,
            iters: 1,
            rows: Vec::new(),
            sampled: vec![SampledRow {
                workload: "VecSum".into(),
                backend: "AVX".into(),
                events: 1000,
                detailed_events: 50,
                full_wall_s: 2.0,
                sampled_wall_s: 0.1,
                speedup: 20.0,
                cycle_error_pct: 1.5,
                energy_error_pct: 0.5,
            }],
            net: None,
            predict: Vec::new(),
        };
        let j = report.to_json();
        assert!(j.contains("\"sampled_summary\""), "{j}");
        assert!(j.contains("\"max_cycle_error_pct\": 1.500"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!((report.geomean_sampled_speedup() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn net_section_appears_and_balances() {
        let report = ThroughputReport {
            quick: true,
            iters: 1,
            rows: Vec::new(),
            sampled: Vec::new(),
            net: Some(NetReport {
                conn_rows: vec![
                    NetConnRow {
                        connections: 1,
                        requests: 200,
                        wall_s: 0.5,
                        jobs_per_sec: 400.0,
                    },
                    NetConnRow {
                        connections: 4,
                        requests: 800,
                        wall_s: 0.5,
                        jobs_per_sec: 1600.0,
                    },
                ],
                worker_rows: vec![NetWorkerRow {
                    workers: 2,
                    cells: 27,
                    unique: 27,
                    wall_s: 1.5,
                    cells_per_sec: 18.0,
                }],
            }),
            predict: Vec::new(),
        };
        let j = report.to_json();
        assert!(j.contains("\"net\": {"), "{j}");
        assert!(j.contains("\"peak_jobs_per_sec\": 1600"), "{j}");
        assert!(j.contains("\"unique_cells\": 27"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert_eq!(report.net.as_ref().unwrap().peak_connections(), 4);
    }

    #[test]
    fn predict_section_appears_and_balances() {
        let report = ThroughputReport {
            quick: true,
            iters: 1,
            rows: Vec::new(),
            sampled: Vec::new(),
            net: None,
            predict: vec![
                PredictRow {
                    workload: "saxpy".into(),
                    predicted_cycles: 9500,
                    simulated_cycles: 10000,
                    error_pct: -5.0,
                },
                PredictRow {
                    workload: "softmax".into(),
                    predicted_cycles: 11000,
                    simulated_cycles: 10000,
                    error_pct: 10.0,
                },
            ],
        };
        let j = report.to_json();
        assert!(j.contains("\"predict\": ["), "{j}");
        assert!(j.contains("\"error_pct\": -5.00"), "{j}");
        assert!(j.contains("\"max_abs_error_pct\": 10.00"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!((report.max_predict_error_pct() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_and_min() {
        let row = |s: f64| ThroughputRow {
            workload: "w".into(),
            backend: "b".into(),
            events: 1,
            reference_eps: 1.0,
            chunked_eps: s,
            speedup: s,
        };
        let r = ThroughputReport {
            quick: true,
            iters: 1,
            rows: vec![row(2.0), row(8.0)],
            sampled: Vec::new(),
            net: None,
            predict: Vec::new(),
        };
        assert!((r.geomean_speedup() - 4.0).abs() < 1e-9);
        assert_eq!(r.min_speedup(), 2.0);
        assert_eq!(r.peak_chunked_eps(), 8.0);
    }
}
