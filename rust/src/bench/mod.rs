//! Simulator throughput benchmark — the §Perf trajectory instrument.
//!
//! Measures **simulated events per second** of the chunked execution
//! engine ([`Machine::run`]) against the event-at-a-time reference path
//! ([`Machine::run_reference`]) over a representative workload matrix, and
//! serializes the result as the `BENCH_*.json` record the repo's perf
//! trajectory is built from (`vima-sim bench --json BENCH_PR3.json`; CI
//! uploads it as an artifact on every push).
//!
//! JSON is emitted by hand: the offline build is dependency-free by
//! design, and the schema is flat (see [`ThroughputReport::to_json`]).

use std::time::Instant;

use crate::config::SystemConfig;
use crate::sim::Machine;
use crate::trace::{Backend, KernelId, TraceParams, TraceStream};
use crate::util::error::Result;

/// One benchmark cell: a workload/backend pair timed on both engines.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub workload: String,
    pub backend: String,
    /// Dynamic trace events simulated per run.
    pub events: u64,
    /// Simulated events per wall-clock second, reference engine.
    pub reference_eps: f64,
    /// Simulated events per wall-clock second, chunked engine.
    pub chunked_eps: f64,
    /// `chunked_eps / reference_eps`.
    pub speedup: f64,
}

/// The full benchmark record; serializes to `BENCH_*.json`.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    /// True when run on the 1/16 quick sizes (CI smoke mode).
    pub quick: bool,
    pub iters: u32,
    pub rows: Vec<ThroughputRow>,
}

impl ThroughputReport {
    /// Geometric mean of the per-row chunked-vs-reference speedups.
    pub fn geomean_speedup(&self) -> f64 {
        if self.rows.is_empty() {
            return 1.0;
        }
        let log_sum: f64 = self.rows.iter().map(|r| r.speedup.ln()).sum();
        (log_sum / self.rows.len() as f64).exp()
    }

    pub fn min_speedup(&self) -> f64 {
        self.rows.iter().map(|r| r.speedup).fold(f64::INFINITY, f64::min)
    }

    /// Best chunked events/sec across rows (the headline throughput).
    pub fn peak_chunked_eps(&self) -> f64 {
        self.rows.iter().map(|r| r.chunked_eps).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s += "  \"benchmark\": \"vima-sim simulator throughput (events/sec)\",\n";
        s += &format!("  \"quick\": {},\n  \"iters\": {},\n", self.quick, self.iters);
        s += "  \"rows\": [\n";
        for (i, r) in self.rows.iter().enumerate() {
            s += &format!(
                "    {{\"workload\": \"{}\", \"backend\": \"{}\", \"events\": {}, \
                 \"reference_events_per_sec\": {:.0}, \"chunked_events_per_sec\": {:.0}, \
                 \"speedup\": {:.3}}}{}\n",
                r.workload,
                r.backend,
                r.events,
                r.reference_eps,
                r.chunked_eps,
                r.speedup,
                if i + 1 < self.rows.len() { "," } else { "" }
            );
        }
        s += "  ],\n";
        s += &format!(
            "  \"summary\": {{\"geomean_speedup\": {:.3}, \"min_speedup\": {:.3}, \
             \"peak_chunked_events_per_sec\": {:.0}}}\n",
            self.geomean_speedup(),
            self.min_speedup(),
            self.peak_chunked_eps()
        );
        s += "}\n";
        s
    }
}

/// Workload matrix: the three trace shapes that stress different hot paths
/// (µop-dense AVX streaming, VIMA instruction dispatch + coherence walks,
/// HIVE transactions), plus a multithreaded cell for the interleaver.
fn matrix(quick: bool) -> Vec<(KernelId, Backend, u64, usize)> {
    let mb = if quick { 1u64 } else { 8 };
    vec![
        (KernelId::VecSum, Backend::Avx, mb << 20, 1),
        (KernelId::MemCopy, Backend::Avx, mb << 20, 1),
        (KernelId::VecSum, Backend::Vima, mb << 20, 1),
        (KernelId::VecSum, Backend::Hive, mb << 20, 1),
        (KernelId::VecSum, Backend::Avx, mb << 20, 4),
    ]
}

fn streams(p: TraceParams, threads: usize) -> Result<Vec<TraceStream>> {
    (0..threads).map(|t| p.with_threads(t, threads).stream()).collect()
}

/// Median-of-`iters` wall time of `f` (one warm-up run first). Even
/// iteration counts average the two middle samples — `times[len / 2]`
/// alone would report the *slower* middle, turning one scheduler hiccup
/// under `--iters 2` into a fake regression in the trajectory record.
fn time_runs(iters: u32, mut f: impl FnMut() -> Result<u64>) -> Result<f64> {
    std::hint::black_box(f()?);
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f()?);
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let mid = times.len() / 2;
    Ok(if times.len() % 2 == 1 { times[mid] } else { (times[mid - 1] + times[mid]) / 2.0 })
}

/// Run the throughput matrix; `verbose` prints one line per cell.
pub fn throughput(
    cfg: &SystemConfig,
    quick: bool,
    iters: u32,
    verbose: bool,
) -> Result<ThroughputReport> {
    let mut rows = Vec::new();
    for (kernel, backend, footprint, threads) in matrix(quick) {
        let p = TraceParams::new(kernel, backend, footprint);
        let events = streams(p, threads)?
            .into_iter()
            .map(|s| s.count() as u64)
            .sum::<u64>();
        let mut m = Machine::new(cfg, threads)?;
        let t_ref = time_runs(iters, || {
            m.reset();
            Ok(m.run_reference(streams(p, threads)?)?.cycles)
        })?;
        let t_chunk = time_runs(iters, || {
            m.reset();
            Ok(m.run(streams(p, threads)?)?.cycles)
        })?;
        let row = ThroughputRow {
            workload: kernel.to_string(),
            backend: backend.to_string(),
            events,
            reference_eps: events as f64 / t_ref,
            chunked_eps: events as f64 / t_chunk,
            speedup: t_ref / t_chunk,
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench {}/{} x{}: {:.2}M ev/s chunked vs {:.2}M ev/s reference \
                 ({:.2}x)",
                row.workload,
                row.backend,
                threads,
                row.chunked_eps / 1e6,
                row.reference_eps / 1e6,
                row.speedup
            );
        }
        rows.push(row);
    }
    Ok(ThroughputReport { quick, iters, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_is_parseable_enough() {
        let report = ThroughputReport {
            quick: true,
            iters: 1,
            rows: vec![ThroughputRow {
                workload: "VecSum".into(),
                backend: "AVX".into(),
                events: 1000,
                reference_eps: 1e6,
                chunked_eps: 2e6,
                speedup: 2.0,
            }],
        };
        let j = report.to_json();
        assert!(j.contains("\"speedup\": 2.000"), "{j}");
        assert!(j.contains("\"geomean_speedup\": 2.000"), "{j}");
        assert!(j.ends_with("}\n"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn geomean_and_min() {
        let row = |s: f64| ThroughputRow {
            workload: "w".into(),
            backend: "b".into(),
            events: 1,
            reference_eps: 1.0,
            chunked_eps: s,
            speedup: s,
        };
        let r = ThroughputReport { quick: true, iters: 1, rows: vec![row(2.0), row(8.0)] };
        assert!((r.geomean_speedup() - 4.0).abs() < 1e-9);
        assert_eq!(r.min_speedup(), 2.0);
        assert_eq!(r.peak_chunked_eps(), 8.0);
    }
}
