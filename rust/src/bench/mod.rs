//! Simulator throughput benchmark — the §Perf trajectory instrument.
//!
//! Measures **simulated events per second** of the chunked execution
//! engine ([`Machine::run`]) against the event-at-a-time reference path
//! ([`Machine::run_reference`]) over a representative workload matrix, and
//! serializes the result as the `BENCH_*.json` record the repo's perf
//! trajectory is built from (`vima-sim bench --json BENCH_PR3.json`; CI
//! uploads it as an artifact on every push).
//!
//! JSON is emitted by hand: the offline build is dependency-free by
//! design, and the schema is flat (see [`ThroughputReport::to_json`]).

use std::time::Instant;

use crate::config::SystemConfig;
use crate::sim::{run_on, Machine};
use crate::trace::{Backend, KernelId, TraceParams, TraceStream};
use crate::util::error::Result;
use crate::workload::{self, WorkloadId};

/// One benchmark cell: a workload/backend pair timed on both engines.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub workload: String,
    pub backend: String,
    /// Dynamic trace events simulated per run.
    pub events: u64,
    /// Simulated events per wall-clock second, reference engine.
    pub reference_eps: f64,
    /// Simulated events per wall-clock second, chunked engine.
    pub chunked_eps: f64,
    /// `chunked_eps / reference_eps`.
    pub speedup: f64,
}

/// One accuracy/speed frontier cell: the same workload run full-detail and
/// sampled (DESIGN.md §11), comparing wall time and reported results.
#[derive(Debug, Clone)]
pub struct SampledRow {
    pub workload: String,
    pub backend: String,
    /// Dynamic trace events in the full run.
    pub events: u64,
    /// Detailed-window events the sampled run actually simulated in detail.
    pub detailed_events: u64,
    pub full_wall_s: f64,
    pub sampled_wall_s: f64,
    /// `full_wall_s / sampled_wall_s`.
    pub speedup: f64,
    /// `|sampled.cycles - full.cycles| / full.cycles * 100`.
    pub cycle_error_pct: f64,
    /// `|sampled.energy - full.energy| / full.energy * 100`.
    pub energy_error_pct: f64,
}

/// The full benchmark record; serializes to `BENCH_*.json`.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    /// True when run on the 1/16 quick sizes (CI smoke mode).
    pub quick: bool,
    pub iters: u32,
    pub rows: Vec<ThroughputRow>,
    /// Sampled-mode accuracy/speed frontier (`bench --sampled`); empty
    /// when the frontier was not requested.
    pub sampled: Vec<SampledRow>,
}

impl ThroughputReport {
    /// Geometric mean of the per-row chunked-vs-reference speedups.
    pub fn geomean_speedup(&self) -> f64 {
        if self.rows.is_empty() {
            return 1.0;
        }
        let log_sum: f64 = self.rows.iter().map(|r| r.speedup.ln()).sum();
        (log_sum / self.rows.len() as f64).exp()
    }

    pub fn min_speedup(&self) -> f64 {
        self.rows.iter().map(|r| r.speedup).fold(f64::INFINITY, f64::min)
    }

    /// Best chunked events/sec across rows (the headline throughput).
    pub fn peak_chunked_eps(&self) -> f64 {
        self.rows.iter().map(|r| r.chunked_eps).fold(0.0, f64::max)
    }

    /// Geometric mean of the sampled-vs-full wall-clock speedups.
    pub fn geomean_sampled_speedup(&self) -> f64 {
        if self.sampled.is_empty() {
            return 1.0;
        }
        let log_sum: f64 = self.sampled.iter().map(|r| r.speedup.ln()).sum();
        (log_sum / self.sampled.len() as f64).exp()
    }

    /// Worst cycle error across the sampled frontier, in percent.
    pub fn max_cycle_error_pct(&self) -> f64 {
        self.sampled.iter().map(|r| r.cycle_error_pct).fold(0.0, f64::max)
    }

    /// Worst energy error across the sampled frontier, in percent.
    pub fn max_energy_error_pct(&self) -> f64 {
        self.sampled.iter().map(|r| r.energy_error_pct).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s += "  \"benchmark\": \"vima-sim simulator throughput (events/sec)\",\n";
        s += &format!("  \"quick\": {},\n  \"iters\": {},\n", self.quick, self.iters);
        s += "  \"rows\": [\n";
        for (i, r) in self.rows.iter().enumerate() {
            s += &format!(
                "    {{\"workload\": \"{}\", \"backend\": \"{}\", \"events\": {}, \
                 \"reference_events_per_sec\": {:.0}, \"chunked_events_per_sec\": {:.0}, \
                 \"speedup\": {:.3}}}{}\n",
                r.workload,
                r.backend,
                r.events,
                r.reference_eps,
                r.chunked_eps,
                r.speedup,
                if i + 1 < self.rows.len() { "," } else { "" }
            );
        }
        s += "  ],\n";
        if !self.sampled.is_empty() {
            s += "  \"sampled\": [\n";
            for (i, r) in self.sampled.iter().enumerate() {
                s += &format!(
                    "    {{\"workload\": \"{}\", \"backend\": \"{}\", \"events\": {}, \
                     \"detailed_events\": {}, \"full_wall_s\": {:.4}, \
                     \"sampled_wall_s\": {:.4}, \"speedup\": {:.2}, \
                     \"cycle_error_pct\": {:.3}, \"energy_error_pct\": {:.3}}}{}\n",
                    r.workload,
                    r.backend,
                    r.events,
                    r.detailed_events,
                    r.full_wall_s,
                    r.sampled_wall_s,
                    r.speedup,
                    r.cycle_error_pct,
                    r.energy_error_pct,
                    if i + 1 < self.sampled.len() { "," } else { "" }
                );
            }
            s += "  ],\n";
            s += &format!(
                "  \"sampled_summary\": {{\"geomean_speedup\": {:.2}, \
                 \"max_cycle_error_pct\": {:.3}, \"max_energy_error_pct\": {:.3}}},\n",
                self.geomean_sampled_speedup(),
                self.max_cycle_error_pct(),
                self.max_energy_error_pct()
            );
        }
        s += &format!(
            "  \"summary\": {{\"geomean_speedup\": {:.3}, \"min_speedup\": {:.3}, \
             \"peak_chunked_events_per_sec\": {:.0}}}\n",
            self.geomean_speedup(),
            self.min_speedup(),
            self.peak_chunked_eps()
        );
        s += "}\n";
        s
    }
}

/// Workload matrix: the three trace shapes that stress different hot paths
/// (µop-dense AVX streaming, VIMA instruction dispatch + coherence walks,
/// HIVE transactions), plus a multithreaded cell for the interleaver, plus
/// one loaded-`.vpr` program cell (`saxpy` round-tripped through the text
/// format) so the parser + `ProgramChunker` path is tracked in the
/// `BENCH_*.json` trajectory. The program cell's footprint is fixed by its
/// structure, so `quick` does not scale it.
fn matrix(quick: bool) -> Result<Vec<(WorkloadId, String, Backend, u64, usize)>> {
    let mb = if quick { 1u64 } else { 8 };
    let kernel_cells = [
        (KernelId::VecSum, Backend::Avx, mb << 20, 1),
        (KernelId::MemCopy, Backend::Avx, mb << 20, 1),
        (KernelId::VecSum, Backend::Vima, mb << 20, 1),
        (KernelId::VecSum, Backend::Hive, mb << 20, 1),
        (KernelId::VecSum, Backend::Avx, mb << 20, 4),
    ];
    let mut cells: Vec<(WorkloadId, String, Backend, u64, usize)> = kernel_cells
        .into_iter()
        .map(|(k, b, fp, t)| (k.into(), k.to_string(), b, fp, t))
        .collect();
    let vpr = crate::program::bench_workload()?;
    let fp = workload::get(vpr)?.default_footprint();
    cells.push((vpr, workload::name(vpr), Backend::Vima, fp, 1));
    Ok(cells)
}

fn streams(p: TraceParams, threads: usize) -> Result<Vec<TraceStream>> {
    (0..threads).map(|t| p.with_threads(t, threads).stream()).collect()
}

/// Median-of-`iters` wall time of `f` (one warm-up run first). Even
/// iteration counts average the two middle samples — `times[len / 2]`
/// alone would report the *slower* middle, turning one scheduler hiccup
/// under `--iters 2` into a fake regression in the trajectory record.
fn time_runs(iters: u32, mut f: impl FnMut() -> Result<u64>) -> Result<f64> {
    std::hint::black_box(f()?);
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f()?);
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let mid = times.len() / 2;
    Ok(if times.len() % 2 == 1 { times[mid] } else { (times[mid - 1] + times[mid]) / 2.0 })
}

/// Run the throughput matrix; `verbose` prints one line per cell.
pub fn throughput(
    cfg: &SystemConfig,
    quick: bool,
    iters: u32,
    verbose: bool,
) -> Result<ThroughputReport> {
    let mut rows = Vec::new();
    for (id, name, backend, footprint, threads) in matrix(quick)? {
        let p = TraceParams::new(id, backend, footprint);
        let events = streams(p, threads)?
            .into_iter()
            .map(|s| s.count() as u64)
            .sum::<u64>();
        let mut m = Machine::new(cfg, threads)?;
        let t_ref = time_runs(iters, || {
            m.reset();
            Ok(m.run_reference(streams(p, threads)?)?.cycles)
        })?;
        let t_chunk = time_runs(iters, || {
            m.reset();
            Ok(m.run(streams(p, threads)?)?.cycles)
        })?;
        let row = ThroughputRow {
            workload: name,
            backend: backend.to_string(),
            events,
            reference_eps: events as f64 / t_ref,
            chunked_eps: events as f64 / t_chunk,
            speedup: t_ref / t_chunk,
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench {}/{} x{}: {:.2}M ev/s chunked vs {:.2}M ev/s reference \
                 ({:.2}x)",
                row.workload,
                row.backend,
                threads,
                row.chunked_eps / 1e6,
                row.reference_eps / 1e6,
                row.speedup
            );
        }
        rows.push(row);
    }
    Ok(ThroughputReport { quick, iters, rows, sampled: Vec::new() })
}

/// Streaming-kernel cells for the sampled accuracy/speed frontier:
/// µop-dense AVX traces at paper-scale footprints — the shapes where
/// fast-forward has the most events to skip.
fn sampled_matrix(quick: bool) -> Vec<(KernelId, Backend, u64)> {
    let mb = if quick { 2u64 } else { 24 };
    vec![
        (KernelId::MemSet, Backend::Avx, mb << 20),
        (KernelId::MemCopy, Backend::Avx, mb << 20),
        (KernelId::VecSum, Backend::Avx, mb << 20),
        (KernelId::Stencil, Backend::Avx, mb << 20),
    ]
}

/// Measure the sampled-mode accuracy/speed frontier (`bench --sampled`):
/// each streaming kernel timed full-detail vs sampled at the workload's
/// default window/period, comparing the reported cycles and energy. Goes
/// through the production [`run_on`] path so every number matches what a
/// sampled sweep cell would report.
pub fn sampled_frontier(
    cfg: &SystemConfig,
    quick: bool,
    iters: u32,
    verbose: bool,
) -> Result<Vec<SampledRow>> {
    let mut cfg_sampled = cfg.clone();
    cfg_sampled.sample.enabled = true;
    let err_pct =
        |got: f64, want: f64| if want == 0.0 { 0.0 } else { (got - want).abs() / want * 100.0 };
    let mut rows = Vec::new();
    for (kernel, backend, footprint) in sampled_matrix(quick) {
        let p = TraceParams::new(kernel, backend, footprint);
        let events = p.stream()?.count() as u64;
        let mut m_full = Machine::new(cfg, 1)?;
        let mut m_sampled = Machine::new(&cfg_sampled, 1)?;
        let full = run_on(&mut m_full, p)?;
        m_sampled.reset();
        let sampled = run_on(&mut m_sampled, p)?;
        let detailed_events =
            sampled.report.get("sample.detailed_events").unwrap_or(events as f64) as u64;
        let full_wall_s = time_runs(iters, || {
            m_full.reset();
            Ok(run_on(&mut m_full, p)?.cycles)
        })?;
        let sampled_wall_s = time_runs(iters, || {
            m_sampled.reset();
            Ok(run_on(&mut m_sampled, p)?.cycles)
        })?;
        let row = SampledRow {
            workload: kernel.to_string(),
            backend: backend.to_string(),
            events,
            detailed_events,
            full_wall_s,
            sampled_wall_s,
            speedup: full_wall_s / sampled_wall_s,
            cycle_error_pct: err_pct(sampled.cycles as f64, full.cycles as f64),
            energy_error_pct: err_pct(sampled.energy.total_j, full.energy.total_j),
        };
        if verbose {
            eprintln!(
                "[vima-sim] bench --sampled {}/{}: {:.2}x wall speedup, \
                 cycle err {:.2}%, energy err {:.2}%",
                row.workload, row.backend, row.speedup, row.cycle_error_pct, row.energy_error_pct
            );
        }
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_is_parseable_enough() {
        let report = ThroughputReport {
            quick: true,
            iters: 1,
            rows: vec![ThroughputRow {
                workload: "VecSum".into(),
                backend: "AVX".into(),
                events: 1000,
                reference_eps: 1e6,
                chunked_eps: 2e6,
                speedup: 2.0,
            }],
            sampled: Vec::new(),
        };
        let j = report.to_json();
        assert!(j.contains("\"speedup\": 2.000"), "{j}");
        assert!(j.contains("\"geomean_speedup\": 2.000"), "{j}");
        assert!(!j.contains("\"sampled\""), "{j}");
        assert!(j.ends_with("}\n"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn sampled_section_appears_and_balances() {
        let report = ThroughputReport {
            quick: true,
            iters: 1,
            rows: Vec::new(),
            sampled: vec![SampledRow {
                workload: "VecSum".into(),
                backend: "AVX".into(),
                events: 1000,
                detailed_events: 50,
                full_wall_s: 2.0,
                sampled_wall_s: 0.1,
                speedup: 20.0,
                cycle_error_pct: 1.5,
                energy_error_pct: 0.5,
            }],
        };
        let j = report.to_json();
        assert!(j.contains("\"sampled_summary\""), "{j}");
        assert!(j.contains("\"max_cycle_error_pct\": 1.500"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!((report.geomean_sampled_speedup() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_and_min() {
        let row = |s: f64| ThroughputRow {
            workload: "w".into(),
            backend: "b".into(),
            events: 1,
            reference_eps: 1.0,
            chunked_eps: s,
            speedup: s,
        };
        let r = ThroughputReport {
            quick: true,
            iters: 1,
            rows: vec![row(2.0), row(8.0)],
            sampled: Vec::new(),
        };
        assert!((r.geomean_speedup() - 4.0).abs() < 1e-9);
        assert_eq!(r.min_speedup(), 2.0);
        assert_eq!(r.peak_chunked_eps(), 8.0);
    }
}
