//! Per-PC stride prefetcher for the host baseline.
//!
//! Sandy-Bridge-class cores ship L2/LLC streaming prefetchers; the paper's
//! introduction explicitly frames VIMA against baselines with prefetching
//! (and its limits: "aggressive policies ... massive data movements and
//! cache pollution"). This is the standard reference design: a small
//! PC-indexed table learns per-instruction strides; once a stride repeats,
//! `degree` lines ahead are pulled into the LLC. Prefetch DRAM traffic is
//! issued through the posted queue, so it occupies banks/links like any
//! demand access.

use crate::config::PrefetchConfig;

#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    pc: u64,
    last_addr: u64,
    stride: i64,
    confidence: u64,
    lru: u64,
}

pub struct StridePrefetcher {
    entries: Vec<Entry>,
    degree: u64,
    min_confidence: u64,
    tick: u64,
    pub issued: u64,
    pub detections: u64,
}

impl StridePrefetcher {
    pub fn new(cfg: &PrefetchConfig) -> Self {
        Self {
            entries: vec![Entry::default(); cfg.table_entries.max(1)],
            degree: cfg.degree,
            min_confidence: cfg.min_confidence,
            tick: 0,
            issued: 0,
            detections: 0,
        }
    }

    /// Observe one demand access; returns line addresses to prefetch.
    pub fn observe(&mut self, pc: u64, addr: u64, out: &mut Vec<u64>) {
        self.tick += 1;
        // find or allocate the PC's entry
        let mut idx = None;
        let mut victim = 0;
        let mut victim_lru = u64::MAX;
        for (i, e) in self.entries.iter().enumerate() {
            if e.pc == pc {
                idx = Some(i);
                break;
            }
            if e.lru < victim_lru {
                victim_lru = e.lru;
                victim = i;
            }
        }
        let i = match idx {
            Some(i) => i,
            None => {
                self.entries[victim] =
                    Entry { pc, last_addr: addr, stride: 0, confidence: 0, lru: self.tick };
                return;
            }
        };
        let e = &mut self.entries[i];
        e.lru = self.tick;
        let stride = addr as i64 - e.last_addr as i64;
        e.last_addr = addr;
        if stride == 0 {
            return;
        }
        if stride == e.stride {
            e.confidence += 1;
        } else {
            e.stride = stride;
            e.confidence = 1;
        }
        if e.confidence >= self.min_confidence {
            self.detections += 1;
            let (stride, degree) = (e.stride, self.degree);
            for k in 1..=degree {
                let target = addr as i64 + stride * k as i64;
                if target >= 0 {
                    out.push((target as u64) & !63);
                    self.issued += 1;
                }
            }
        }
    }

    pub fn reset(&mut self) {
        self.entries.fill(Entry::default());
        self.tick = 0;
        self.issued = 0;
        self.detections = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefetchConfig;

    fn pf() -> StridePrefetcher {
        StridePrefetcher::new(&PrefetchConfig::default())
    }

    #[test]
    fn learns_unit_stride_stream() {
        let mut p = pf();
        let mut out = Vec::new();
        for i in 0..8u64 {
            out.clear();
            p.observe(0x400, i * 64, &mut out);
        }
        // after confidence builds, each access prefetches `degree` lines ahead
        assert_eq!(out.len(), 4);
        assert_eq!(out[0], 8 * 64);
        assert_eq!(out[3], 11 * 64);
    }

    #[test]
    fn learns_large_stride_column_walk() {
        let mut p = pf();
        let mut out = Vec::new();
        for i in 0..6u64 {
            out.clear();
            p.observe(0x908, i * 8192, &mut out); // MatMul B-column stride
        }
        assert!(!out.is_empty());
        assert_eq!(out[0], 6 * 8192);
    }

    #[test]
    fn random_pattern_stays_quiet() {
        let mut p = pf();
        let mut out = Vec::new();
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..100 {
            p.observe(0x500, rng.next_u64() & 0xFFFF_FFC0, &mut out);
        }
        assert!(
            (out.len() as f64) < 40.0,
            "random stream should rarely trigger: {}",
            out.len()
        );
    }

    #[test]
    fn distinct_pcs_tracked_separately() {
        let mut p = pf();
        let mut out = Vec::new();
        for i in 0..8u64 {
            p.observe(0xA00, i * 64, &mut out);
            p.observe(0xB00, 0x100000 + i * 128, &mut out);
        }
        // both streams detected
        assert!(p.detections >= 8, "{}", p.detections);
    }

    #[test]
    fn repeated_same_address_is_ignored() {
        let mut p = pf();
        let mut out = Vec::new();
        for _ in 0..20 {
            p.observe(0xC00, 0x4000, &mut out);
        }
        assert!(out.is_empty());
    }
}
