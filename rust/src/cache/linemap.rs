//! Open-addressed `line address -> cycle` map for the in-flight prefetch
//! window.
//!
//! `std::collections::HashMap` pays SipHash plus DoS-resistant table
//! machinery per probe; the prefetch window only ever keys on 64 B-aligned
//! line addresses and sits on the per-access hot path, so a linear-probe
//! table with a multiplicative hash does the same job in a fraction of the
//! cost. Deletions leave tombstones; the table rebuilds (dropping them)
//! when the occupied fraction crosses 3/4, doubling only when the *live*
//! load demands it. Keys are 64 B-aligned, so the two unaligned sentinel
//! values can never collide with a real key.

/// Slot never used.
const EMPTY: u64 = u64::MAX;
/// Slot deleted (probe chains continue through it).
const TOMB: u64 = u64::MAX - 1;

/// Linear-probe hash map from 64 B-aligned line addresses to cycle stamps.
pub struct LineMap {
    keys: Vec<u64>,
    vals: Vec<u64>,
    /// Live entries.
    live: usize,
    /// Live entries + tombstones (slots that are not `EMPTY`).
    used: usize,
}

impl LineMap {
    pub fn new() -> Self {
        Self::with_pow2_capacity(64)
    }

    fn with_pow2_capacity(cap: usize) -> Self {
        debug_assert!(cap.is_power_of_two());
        Self { keys: vec![EMPTY; cap], vals: vec![0; cap], live: 0, used: 0 }
    }

    #[inline]
    fn mask(&self) -> usize {
        self.keys.len() - 1
    }

    /// Fibonacci (multiplicative) hash: one multiply, top bits, mask.
    #[inline]
    fn slot_of(key: u64, mask: usize) -> usize {
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize & mask
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn clear(&mut self) {
        self.keys.fill(EMPTY);
        self.live = 0;
        self.used = 0;
    }

    /// Index of the slot holding `key`, if present.
    #[inline]
    fn find(&self, key: u64) -> Option<usize> {
        debug_assert!(key < TOMB, "unaligned sentinel key");
        let mask = self.mask();
        let mut i = Self::slot_of(key, mask);
        loop {
            match self.keys[i] {
                k if k == key => return Some(i),
                EMPTY => return None,
                // Tombstones and other keys: probe on.
                _ => i = (i + 1) & mask,
            }
        }
    }

    pub fn contains(&self, key: u64) -> bool {
        self.find(key).is_some()
    }

    /// Insert or overwrite.
    pub fn insert(&mut self, key: u64, val: u64) {
        // Keep at least a quarter of the slots EMPTY so probe chains stay
        // short and terminate.
        if (self.used + 1) * 4 >= self.keys.len() * 3 {
            self.rebuild();
        }
        let mask = self.mask();
        let mut i = Self::slot_of(key, mask);
        let mut first_tomb = None;
        loop {
            match self.keys[i] {
                k if k == key => {
                    self.vals[i] = val;
                    return;
                }
                EMPTY => {
                    // Prefer reusing a tombstone seen on the way (keeps
                    // `used` flat under insert/remove churn).
                    let slot = match first_tomb {
                        Some(t) => t,
                        None => {
                            self.used += 1;
                            i
                        }
                    };
                    self.keys[slot] = key;
                    self.vals[slot] = val;
                    self.live += 1;
                    return;
                }
                TOMB => {
                    if first_tomb.is_none() {
                        first_tomb = Some(i);
                    }
                    i = (i + 1) & mask;
                }
                _ => i = (i + 1) & mask,
            }
        }
    }

    /// Remove `key`, returning its value if it was present.
    pub fn remove(&mut self, key: u64) -> Option<u64> {
        let i = self.find(key)?;
        self.keys[i] = TOMB;
        self.live -= 1;
        Some(self.vals[i])
    }

    /// Re-insert the live entries into a table sized for them (dropping
    /// tombstones); doubles only when the live load itself is high.
    fn rebuild(&mut self) {
        let new_cap =
            if self.live * 2 >= self.keys.len() { self.keys.len() * 2 } else { self.keys.len() };
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY; new_cap]);
        let old_vals = std::mem::replace(&mut self.vals, vec![0; new_cap]);
        self.live = 0;
        self.used = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k != EMPTY && k != TOMB {
                self.insert(k, v);
            }
        }
    }
}

impl Default for LineMap {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_find_remove() {
        let mut m = LineMap::new();
        assert!(m.is_empty());
        m.insert(0x1000, 42);
        m.insert(0x2000, 43);
        assert_eq!(m.len(), 2);
        assert!(m.contains(0x1000));
        assert!(!m.contains(0x3000));
        assert_eq!(m.remove(0x1000), Some(42));
        assert_eq!(m.remove(0x1000), None);
        assert_eq!(m.len(), 1);
        assert_eq!(m.remove(0x2000), Some(43));
        assert!(m.is_empty());
    }

    #[test]
    fn overwrite_keeps_one_entry() {
        let mut m = LineMap::new();
        m.insert(0x40, 1);
        m.insert(0x40, 2);
        assert_eq!(m.len(), 1);
        assert_eq!(m.remove(0x40), Some(2));
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut m = LineMap::new();
        for i in 0..10_000u64 {
            m.insert(i * 64, i);
        }
        assert_eq!(m.len(), 10_000);
        for i in (0..10_000u64).rev() {
            assert_eq!(m.remove(i * 64), Some(i), "lost key {i}");
        }
        assert!(m.is_empty());
    }

    #[test]
    fn churn_does_not_fill_table_with_tombstones() {
        // Insert/remove cycles at bounded live size: the rebuild must keep
        // probing terminating (this loops forever if tombstones leak).
        let mut m = LineMap::new();
        for round in 0..2_000u64 {
            let k = (round % 97) * 64;
            m.insert(k, round);
            if round % 3 != 0 {
                m.remove(k);
            }
        }
        assert!(m.len() <= 97);
    }

    #[test]
    fn clear_resets() {
        let mut m = LineMap::new();
        for i in 0..100u64 {
            m.insert(i * 64, i);
        }
        m.clear();
        assert!(m.is_empty());
        assert!(!m.contains(0));
        m.insert(0, 7);
        assert_eq!(m.remove(0), Some(7));
    }

    #[test]
    fn colliding_keys_chain() {
        // Keys an exact table-capacity multiple apart often hash to nearby
        // slots; verify chains survive middle deletions.
        let mut m = LineMap::new();
        let keys: Vec<u64> = (0..32).map(|i| i * 64 * 64).collect();
        for (i, &k) in keys.iter().enumerate() {
            m.insert(k, i as u64);
        }
        m.remove(keys[10]);
        for (i, &k) in keys.iter().enumerate() {
            if i != 10 {
                assert!(m.contains(k), "key {i} lost after middle deletion");
            }
        }
    }
}
