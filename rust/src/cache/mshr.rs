//! MSHR window: bounds miss-level parallelism per cache level.
//!
//! Modeled as a fixed-capacity multiset of completion timestamps. Acquiring a
//! slot at time `t` when all slots are busy pushes the start time to the
//! earliest completion — exactly the stall a blocked miss queue produces.

/// Outstanding-miss tracker.
pub struct MshrWindow {
    /// Completion times of in-flight misses (unordered; capacity = MSHRs).
    slots: Vec<u64>,
    capacity: usize,
}

impl MshrWindow {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "need at least one MSHR");
        Self { slots: Vec::with_capacity(capacity), capacity }
    }

    /// Try to start a miss at `t`. Returns `(actual_start, stall_cycles)`.
    ///
    /// The caller must later call [`release`](Self::release) with the miss's
    /// completion time.
    pub fn acquire(&mut self, t: u64) -> (u64, u64) {
        // Fast path: a free slot exists without any pruning.
        if self.slots.len() < self.capacity {
            return (t, 0);
        }
        // Single pass: find the earliest completion while pruning slots that
        // already completed by `t` (avoids the two O(n) scans of
        // retain + min_by_key on the hot path).
        let mut i = 0;
        let mut min_idx = usize::MAX;
        let mut min_val = u64::MAX;
        while i < self.slots.len() {
            let c = self.slots[i];
            if c <= t {
                self.slots.swap_remove(i);
                if min_idx == self.slots.len() {
                    // swap_remove moved the recorded-min (last) element here
                    min_idx = i;
                }
                continue; // re-inspect the swapped-in element at `i`
            }
            if c < min_val {
                min_val = c;
                min_idx = i;
            }
            i += 1;
        }
        if self.slots.len() < self.capacity {
            return (t, 0);
        }
        // Full of still-outstanding misses: wait for the earliest.
        self.slots.swap_remove(min_idx);
        (min_val, min_val - t)
    }

    /// Record the completion time of the miss started by the last `acquire`.
    pub fn release(&mut self, completion: u64) {
        debug_assert!(self.slots.len() < self.capacity);
        self.slots.push(completion);
    }

    pub fn in_flight(&self) -> usize {
        self.slots.len()
    }

    pub fn reset(&mut self) {
        self.slots.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_until_full() {
        let mut m = MshrWindow::new(2);
        let (s1, st1) = m.acquire(10);
        m.release(100);
        let (s2, st2) = m.acquire(10);
        m.release(200);
        assert_eq!((s1, st1), (10, 0));
        assert_eq!((s2, st2), (10, 0));
    }

    #[test]
    fn full_window_stalls_until_earliest() {
        let mut m = MshrWindow::new(2);
        m.acquire(0);
        m.release(100);
        m.acquire(0);
        m.release(50);
        let (start, stall) = m.acquire(10);
        assert_eq!(start, 50); // waits for the miss completing at 50
        assert_eq!(stall, 40);
    }

    #[test]
    fn completed_slots_are_freed() {
        let mut m = MshrWindow::new(1);
        m.acquire(0);
        m.release(5);
        // At t=10 the previous miss is done; no stall.
        let (start, stall) = m.acquire(10);
        assert_eq!((start, stall), (10, 0));
    }

    #[test]
    fn in_flight_tracking() {
        let mut m = MshrWindow::new(4);
        m.acquire(0);
        m.release(100);
        assert_eq!(m.in_flight(), 1);
        m.reset();
        assert_eq!(m.in_flight(), 0);
    }
}
