//! Host cache hierarchy (Table I rows 2-4): per-core L1D/L1I and L2, shared
//! LLC, all 64 B lines with LRU replacement, write-back + write-allocate,
//! MSHR-limited miss parallelism.
//!
//! Like [`crate::mem3d`], the model is latency-forwarding: each level tracks
//! its outstanding-miss window (MSHRs) as a ring of completion timestamps, so
//! miss-level parallelism is bounded exactly without per-cycle ticking.

mod array;
mod linemap;
mod mshr;
mod prefetch;

pub use array::CacheArray;
pub use linemap::LineMap;
pub use mshr::MshrWindow;
pub use prefetch::StridePrefetcher;

use std::collections::VecDeque;

use crate::config::{CacheConfig, SystemConfig};
use crate::fabric::MemFabric;
use crate::stats::StatsReport;
use crate::util::error::Result;

/// 1 MB-region occupancy filter size (16 K regions = 16 GB before aliasing;
/// aliasing is harmless — it only forces the slow path).
const REGION_WORDS: usize = 256;

#[derive(Debug, Default, Clone)]
pub struct LevelStats {
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
    pub accesses: u64,
    /// Cycles spent waiting for a free MSHR.
    pub mshr_stall_cycles: u64,
}

/// One cache level: array + MSHR window + stats.
pub struct CacheLevel {
    pub cfg: CacheConfig,
    array: CacheArray,
    mshrs: MshrWindow,
    pub stats: LevelStats,
}

impl CacheLevel {
    pub fn new(cfg: &CacheConfig) -> Self {
        Self {
            array: CacheArray::new(cfg.sets(), cfg.ways, cfg.line_bytes),
            mshrs: MshrWindow::new(cfg.mshrs),
            cfg: cfg.clone(),
            stats: LevelStats::default(),
        }
    }

    pub fn reset(&mut self) {
        self.array.reset();
        self.mshrs.reset();
        self.stats = LevelStats::default();
    }

    /// Fold the tag/LRU/dirty state into `h` (sampled-mode state-parity
    /// digests; see `Machine::state_digest`).
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        self.array.digest_into(h);
    }
}

/// The full host-side memory system for `n` cores: per-core L1D + L2,
/// shared LLC, backed by the 3D-stacked memory.
///
/// (L1I is omitted from timing: the paper's kernels are tiny loops that
/// always hit; its static/dynamic energy is accounted in [`crate::energy`].)
pub struct MemorySystem {
    pub l1: Vec<CacheLevel>,
    pub l2: Vec<CacheLevel>,
    pub llc: CacheLevel,
    /// The DRAM substrate: one or more 3D-stacked cubes behind the
    /// address-interleaved [`MemFabric`] front door (one cube ≡ the
    /// paper's single `Mem3D`, bit for bit).
    pub mem: MemFabric,
    /// Posted DRAM traffic (store write-allocate fetches, dirty write-backs,
    /// prefetches) ordered by arrival time. Demand loads merge this queue
    /// before they touch the DRAM resource clocks, so the latency-forwarding
    /// model sees requests in approximately arrival order even though stores
    /// issue at data-dependent (much later) pipeline times than younger loads.
    ///
    /// Kept as a deque sorted ascending by `(time, addr, is_write)`. Posts
    /// arrive nearly in order (bounded multi-core skew, write-backs a DRAM
    /// round-trip ahead), so the binary-search insert lands at or near the
    /// tail, and peek/pop-front are O(1) — cheaper than a `BinaryHeap`'s
    /// sift on both ends while draining the identical ascending sequence.
    pending: VecDeque<(u64, u64, bool)>,
    /// Per-core stride prefetchers (into the LLC; see [`StridePrefetcher`]).
    prefetchers: Vec<StridePrefetcher>,
    pf_enabled: bool,
    pf_buf: Vec<u64>,
    /// Coarse occupancy filter: bit per 1 MB address region that has ever
    /// been touched by a host access since the last reset. `flush_range`
    /// (the per-VIMA-instruction coherence walk) skips regions the host
    /// never cached — the dominant cost of VIMA-heavy simulations otherwise.
    region_filter: Vec<u64>,
    /// In-flight prefetches: line -> cycle the data reaches the LLC.
    /// A demand access that meets an in-flight prefetch waits for the
    /// remainder (prefetch *timeliness*: a k-ahead stream only hides
    /// k x demand-interval cycles of DRAM latency, not all of it).
    pf_inflight: LineMap,
    /// DRAM fill latency estimate for prefetch timeliness.
    pf_fill_latency: u64,
    pub pf_late_hits: u64,
    /// Functional fast-forward phase (DESIGN.md §11): posted DRAM traffic
    /// bypasses the arrival-ordered queue and lands directly on the
    /// clock-free counters. Toggled by [`begin_functional`](Self::begin_functional).
    functional: bool,
}

/// Result of a host memory access.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccessResult {
    pub done: u64,
    /// Which level served it: 1, 2, 3 (LLC) or 4 (DRAM).
    pub level: u8,
}

impl MemorySystem {
    pub fn new(cfg: &SystemConfig, cores: usize) -> Result<Self> {
        let mem = MemFabric::new(&cfg.mem, cfg.core.freq_ghz)?;
        // RCD+CAS + burst + link, rounded: one uncontended DRAM round trip
        let pf_fill_latency = mem.uncontended_read_latency();
        Ok(Self {
            l1: (0..cores).map(|_| CacheLevel::new(&cfg.l1d)).collect(),
            l2: (0..cores).map(|_| CacheLevel::new(&cfg.l2)).collect(),
            llc: CacheLevel::new(&cfg.llc),
            mem,
            pending: VecDeque::new(),
            region_filter: vec![0; REGION_WORDS],
            prefetchers: (0..cores).map(|_| StridePrefetcher::new(&cfg.prefetch)).collect(),
            pf_enabled: cfg.prefetch.enabled,
            pf_buf: Vec::with_capacity(8),
            pf_inflight: LineMap::new(),
            pf_fill_latency,
            pf_late_hits: 0,
            functional: false,
        })
    }

    pub fn reset(&mut self) {
        for l in &mut self.l1 {
            l.reset();
        }
        for l in &mut self.l2 {
            l.reset();
        }
        self.llc.reset();
        self.mem.reset();
        self.pending.clear();
        for p in &mut self.prefetchers {
            p.reset();
        }
        self.pf_buf.clear();
        self.pf_inflight.clear();
        self.pf_late_hits = 0;
        self.region_filter.fill(0);
        self.functional = false;
    }

    #[inline]
    fn region_bit(addr: u64) -> (usize, u64) {
        let region = ((addr >> 20) as usize) & (REGION_WORDS * 64 - 1);
        (region / 64, 1u64 << (region % 64))
    }

    #[inline]
    fn mark_region(&mut self, addr: u64) {
        let (w, b) = Self::region_bit(addr);
        self.region_filter[w] |= b;
    }

    #[inline]
    fn region_touched(&self, addr: u64) -> bool {
        let (w, b) = Self::region_bit(addr);
        self.region_filter[w] & b != 0
    }

    /// Feed the stride detector; pull detected lines into the LLC via
    /// posted DRAM reads (bandwidth-accounted, MSHR-free like a real
    /// prefetch engine with its own request queue).
    fn maybe_prefetch(&mut self, core: usize, pc: u64, addr: u64, now: u64) {
        if !self.pf_enabled {
            return;
        }
        let mut buf = std::mem::take(&mut self.pf_buf);
        buf.clear();
        self.prefetchers[core].observe(pc, addr, &mut buf);
        for &line in &buf {
            if !self.llc.array.lookup(line, false) && !self.pf_inflight.contains(line) {
                self.post(line, false, now);
                self.pf_inflight.insert(line, now + self.pf_fill_latency);
                if self.pf_inflight.len() > (1 << 15) {
                    // runaway protection (wild stride patterns)
                    self.pf_inflight.clear();
                }
            }
        }
        self.pf_buf = buf;
    }

    /// If `addr` is covered by an in-flight prefetch, complete it: install
    /// into the LLC and return the cycle its data is available there.
    fn take_inflight_prefetch(&mut self, addr: u64, now: u64) -> Option<u64> {
        if self.pf_inflight.is_empty() {
            return None; // fast path: prefetcher off or idle (no hashing)
        }
        let line = addr & !63;
        let ready = self.pf_inflight.remove(line)?;
        if let Some(victim) = self.llc.array.insert(line, false) {
            self.llc.stats.writebacks += 1;
            self.post(victim, true, ready);
        }
        if ready > now {
            self.pf_late_hits += 1;
        }
        Some(ready)
    }

    /// Queue posted DRAM traffic (applied in arrival order). The fast path
    /// is a tail push; out-of-order posts binary-search their slot, which
    /// preserves the exact ascending drain order the heap produced.
    fn post(&mut self, addr: u64, is_write: bool, at: u64) {
        if self.functional {
            // Fast-forward phase: the timestamp is a frozen clock, so
            // ordering is meaningless — count the traffic immediately.
            self.mem.host_access_functional(addr, is_write);
            return;
        }
        let item = (at, addr, is_write);
        match self.pending.back() {
            Some(last) if *last > item => {
                let idx = self.pending.partition_point(|e| *e <= item);
                self.pending.insert(idx, item);
            }
            _ => self.pending.push_back(item),
        }
    }

    /// Apply every posted request with arrival time <= `upto`.
    fn apply_pending(&mut self, upto: u64) {
        while let Some(&(t, addr, w)) = self.pending.front() {
            if t > upto {
                break;
            }
            self.pending.pop_front();
            self.mem.host_access(addr, w, t);
        }
    }

    /// Flush all posted traffic into the DRAM model (end of run).
    pub fn drain_pending(&mut self) {
        self.apply_pending(u64::MAX);
    }

    /// Enter a functional fast-forward phase (DESIGN.md §11): drain the
    /// posted-traffic queue (its entries carry detailed-window timestamps)
    /// and reroute subsequent posts straight to the DRAM counters.
    pub fn begin_functional(&mut self) {
        self.drain_pending();
        self.functional = true;
    }

    /// Leave the functional phase; posts queue and merge by arrival time
    /// again.
    pub fn end_functional(&mut self) {
        self.functional = false;
    }

    /// Functional-phase twin of [`access_pc`](Self::access_pc): replays
    /// the *exact* tag/LRU/dirty bookkeeping of a detailed access — the
    /// same lookup and insert call order at every level, the same
    /// prefetcher observations and in-flight prefetch bookkeeping — and
    /// counts DRAM traffic, but acquires no MSHRs, advances no resource
    /// clocks and returns no completion time. `now` is the frozen
    /// fast-forward clock, used only to stamp in-flight prefetch entries.
    pub fn access_functional(&mut self, core: usize, pc: u64, addr: u64, is_write: bool, now: u64) {
        debug_assert!(self.functional, "call begin_functional() first");
        self.mark_region(addr);
        let level = if is_write {
            self.store_functional(core, addr, now)
        } else {
            self.load_functional(core, addr, now)
        };
        if level > 1 {
            self.maybe_prefetch(core, pc, addr, now);
        }
    }

    /// [`load_access`](Self::load_access) minus every timing term; array
    /// operations mirror the detailed path one for one.
    fn load_functional(&mut self, core: usize, addr: u64, now: u64) -> u8 {
        let l1 = &mut self.l1[core];
        l1.stats.accesses += 1;
        if l1.array.lookup(addr, false) {
            l1.stats.hits += 1;
            return 1;
        }
        l1.stats.misses += 1;

        let l2 = &mut self.l2[core];
        l2.stats.accesses += 1;
        let level = if l2.array.lookup(addr, false) {
            l2.stats.hits += 1;
            2
        } else {
            l2.stats.misses += 1;
            self.llc.stats.accesses += 1;
            let lvl = if self.llc.array.lookup(addr, false) {
                self.llc.stats.hits += 1;
                3
            } else if self.take_inflight_prefetch(addr, now).is_some() {
                self.llc.stats.hits += 1;
                3
            } else {
                self.llc.stats.misses += 1;
                self.mem.host_access_functional(addr, false);
                if let Some(victim) = self.llc.array.insert(addr, false) {
                    self.llc.stats.writebacks += 1;
                    self.post(victim, true, now);
                }
                4
            };
            self.fill_l2(core, addr, now);
            lvl
        };
        self.fill_l1(core, addr, false, now);
        level
    }

    /// [`store_access`](Self::store_access) minus every timing term.
    fn store_functional(&mut self, core: usize, addr: u64, now: u64) -> u8 {
        let l1 = &mut self.l1[core];
        l1.stats.accesses += 1;
        if l1.array.lookup(addr, true) {
            l1.stats.hits += 1;
            return 1;
        }
        l1.stats.misses += 1;

        let l2 = &mut self.l2[core];
        l2.stats.accesses += 1;
        let level = if l2.array.lookup(addr, false) {
            l2.stats.hits += 1;
            2
        } else {
            l2.stats.misses += 1;
            self.llc.stats.accesses += 1;
            if self.llc.array.lookup(addr, false) {
                self.llc.stats.hits += 1;
                3
            } else if self.take_inflight_prefetch(addr, now).is_some() {
                self.llc.stats.hits += 1;
                3
            } else {
                self.llc.stats.misses += 1;
                // write-allocate fetch, counted immediately
                self.post(addr, false, now);
                if let Some(victim) = self.llc.array.insert(addr, false) {
                    self.llc.stats.writebacks += 1;
                    self.post(victim, true, now);
                }
                4
            }
        };
        self.fill_l2(core, addr, now);
        self.fill_l1(core, addr, true, now);
        level
    }

    /// Functional [`flush_range`](Self::flush_range): identical
    /// region-filter fast path and invalidation walk (state parity), dirty
    /// write-backs counted without advancing DRAM clocks. Returns the
    /// number of dirty lines written back.
    pub fn flush_range_functional(&mut self, base: u64, bytes: usize) -> u64 {
        let first = base >> 20;
        let last = (base + bytes as u64 - 1) >> 20;
        if (first..=last).all(|r| !self.region_touched(r << 20)) {
            return 0;
        }
        let mut dirty_lines = 0;
        for off in (0..bytes as u64).step_by(64) {
            let addr = base + off;
            let mut was_dirty = false;
            for l1 in &mut self.l1 {
                was_dirty |= l1.array.invalidate(addr);
            }
            for l2 in &mut self.l2 {
                was_dirty |= l2.array.invalidate(addr);
            }
            was_dirty |= self.llc.array.invalidate(addr);
            if was_dirty {
                dirty_lines += 1;
                self.mem.host_access_functional(addr, true);
            }
        }
        dirty_lines
    }

    /// Fold the complete order-driven hierarchy state (every level's
    /// tag/LRU/dirty arrays plus the region occupancy filter) into `h`
    /// (sampled-mode state-parity digests; see `Machine::state_digest`).
    /// Timing state — MSHR windows, the posted queue, in-flight prefetch
    /// ready times — is deliberately excluded.
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        for l in &self.l1 {
            l.digest_into(h);
        }
        for l in &self.l2 {
            l.digest_into(h);
        }
        self.llc.digest_into(h);
        self.region_filter.hash(h);
    }

    /// One 64 B-line access from `core` at cycle `now`.
    ///
    /// **Loads** are demand requests: they walk the MSHR-limited latency
    /// chain down to DRAM and return when data arrives.
    ///
    /// **Stores** are write-allocate but *posted*: the tag arrays update
    /// immediately (hit/miss, dirtying, evictions) and any DRAM traffic they
    /// generate (allocate-fetch, write-backs) is queued and merged into the
    /// DRAM resource clocks in arrival order; the returned completion is the
    /// store-buffer drain estimate used for MOB occupancy.
    pub fn access(&mut self, core: usize, addr: u64, is_write: bool, now: u64) -> AccessResult {
        self.access_pc(core, 0, addr, is_write, now)
    }

    /// As [`access`](Self::access), with the accessing instruction's PC
    /// (drives the per-PC stride prefetcher).
    pub fn access_pc(
        &mut self,
        core: usize,
        pc: u64,
        addr: u64,
        is_write: bool,
        now: u64,
    ) -> AccessResult {
        self.mark_region(addr);
        if is_write {
            let r = self.store_access(core, addr, now);
            if r.level > 1 {
                self.maybe_prefetch(core, pc, addr, now);
            }
            r
        } else {
            self.apply_pending(now);
            let r = self.load_access(core, addr, now);
            if r.level > 1 {
                self.maybe_prefetch(core, pc, addr, now);
            }
            r
        }
    }

    fn load_access(&mut self, core: usize, addr: u64, now: u64) -> AccessResult {
        // --- L1 ---
        let l1 = &mut self.l1[core];
        l1.stats.accesses += 1;
        let t_l1 = now + l1.cfg.latency;
        if l1.array.lookup(addr, false) {
            l1.stats.hits += 1;
            return AccessResult { done: t_l1, level: 1 };
        }
        l1.stats.misses += 1;
        let (start, stall) = l1.mshrs.acquire(t_l1);
        l1.stats.mshr_stall_cycles += stall;

        // --- L2 ---
        let l2 = &mut self.l2[core];
        l2.stats.accesses += 1;
        let t_l2 = start + l2.cfg.latency;
        let done = if l2.array.lookup(addr, false) {
            l2.stats.hits += 1;
            AccessResult { done: t_l2, level: 2 }
        } else {
            l2.stats.misses += 1;
            let (start2, stall2) = l2.mshrs.acquire(t_l2);
            l2.stats.mshr_stall_cycles += stall2;

            // --- LLC (shared) ---
            self.llc.stats.accesses += 1;
            let t_llc = start2 + self.llc.cfg.latency;
            let r = if self.llc.array.lookup(addr, false) {
                self.llc.stats.hits += 1;
                AccessResult { done: t_llc, level: 3 }
            } else if let Some(ready) = self.take_inflight_prefetch(addr, t_llc) {
                // prefetch in flight: wait for its fill (partial hiding)
                self.llc.stats.hits += 1;
                AccessResult { done: t_llc.max(ready), level: 3 }
            } else {
                self.llc.stats.misses += 1;
                let (start3, stall3) = self.llc.mshrs.acquire(t_llc);
                self.llc.stats.mshr_stall_cycles += stall3;
                let mc = self.mem.host_access(addr, false, start3);
                if let Some(victim) = self.llc.array.insert(addr, false) {
                    self.llc.stats.writebacks += 1;
                    self.post(victim, true, mc.done);
                }
                self.llc.mshrs.release(mc.done);
                AccessResult { done: mc.done, level: 4 }
            };
            self.fill_l2(core, addr, r.done);
            self.l2[core].mshrs.release(r.done);
            r
        };

        self.fill_l1(core, addr, false, done.done);
        self.l1[core].mshrs.release(done.done);
        done
    }

    /// Posted store: tag bookkeeping now, DRAM traffic queued.
    fn store_access(&mut self, core: usize, addr: u64, now: u64) -> AccessResult {
        let l1 = &mut self.l1[core];
        l1.stats.accesses += 1;
        if l1.array.lookup(addr, true) {
            l1.stats.hits += 1;
            return AccessResult { done: now + l1.cfg.latency, level: 1 };
        }
        l1.stats.misses += 1;

        let l2 = &mut self.l2[core];
        l2.stats.accesses += 1;
        let (level, drain) = if l2.array.lookup(addr, false) {
            l2.stats.hits += 1;
            (2u8, l2.cfg.latency + self.l1[core].cfg.latency)
        } else {
            l2.stats.misses += 1;
            self.llc.stats.accesses += 1;
            if self.llc.array.lookup(addr, false) {
                self.llc.stats.hits += 1;
                (3, self.llc.cfg.latency + 12)
            } else if self.take_inflight_prefetch(addr, now).is_some() {
                self.llc.stats.hits += 1;
                (3, self.llc.cfg.latency + 12)
            } else {
                self.llc.stats.misses += 1;
                // write-allocate fetch from DRAM, posted
                self.post(addr, false, now);
                if let Some(victim) = self.llc.array.insert(addr, false) {
                    self.llc.stats.writebacks += 1;
                    self.post(victim, true, now);
                }
                // store-buffer drain estimate for a DRAM-filling store
                (4, 70)
            }
        };
        self.fill_l2(core, addr, now);
        self.fill_l1(core, addr, true, now);
        AccessResult { done: now + drain, level }
    }

    /// Install into L2, pushing dirty victims down (write-backs posted).
    fn fill_l2(&mut self, core: usize, addr: u64, at: u64) {
        let l2 = &mut self.l2[core];
        if let Some(victim) = l2.array.insert(addr, false) {
            l2.stats.writebacks += 1;
            self.llc.stats.accesses += 1;
            if self.llc.array.lookup(victim, true) {
                self.llc.stats.hits += 1;
            } else {
                self.llc.stats.misses += 1;
                if let Some(v2) = self.llc.array.insert(victim, true) {
                    self.llc.stats.writebacks += 1;
                    self.post(v2, true, at);
                }
            }
        }
    }

    /// Install into L1, pushing dirty victims down (write-backs posted).
    fn fill_l1(&mut self, core: usize, addr: u64, dirty: bool, at: u64) {
        let l1 = &mut self.l1[core];
        if let Some(victim) = l1.array.insert(addr, dirty) {
            l1.stats.writebacks += 1;
            let l2 = &mut self.l2[core];
            l2.stats.accesses += 1;
            if l2.array.lookup(victim, true) {
                l2.stats.hits += 1;
            } else {
                l2.stats.misses += 1;
                if let Some(v2) = l2.array.insert(victim, true) {
                    l2.stats.writebacks += 1;
                    self.llc.stats.accesses += 1;
                    if self.llc.array.lookup(v2, true) {
                        self.llc.stats.hits += 1;
                    } else {
                        self.llc.stats.misses += 1;
                        if let Some(v3) = self.llc.array.insert(v2, true) {
                            self.llc.stats.writebacks += 1;
                            self.post(v3, true, at);
                        }
                    }
                }
            }
        }
    }

    /// VIMA-aware coherence (Sec. III-C): before a VIMA instruction executes,
    /// dirty lines of every operand vector are written back and all copies
    /// invalidated. Returns the cycle the flush settles and the number of
    /// dirty lines written back.
    pub fn flush_range(&mut self, base: u64, bytes: usize, now: u64) -> (u64, u64) {
        // Fast path: the host never cached anything in the touched regions
        // (true for most VIMA operand arrays) — nothing to write back.
        let first = base >> 20;
        let last = (base + bytes as u64 - 1) >> 20;
        if (first..=last).all(|r| !self.region_touched(r << 20)) {
            return (now, 0);
        }
        self.apply_pending(now);
        let mut settle = now;
        let mut dirty_lines = 0;
        let line = 64u64;
        for off in (0..bytes as u64).step_by(64) {
            let addr = base + off;
            let mut was_dirty = false;
            for l1 in &mut self.l1 {
                was_dirty |= l1.array.invalidate(addr);
            }
            for l2 in &mut self.l2 {
                was_dirty |= l2.array.invalidate(addr);
            }
            was_dirty |= self.llc.array.invalidate(addr);
            if was_dirty {
                dirty_lines += 1;
                let c = self.mem.host_access(addr, true, now);
                settle = settle.max(c.done);
            }
        }
        let _ = line;
        (settle, dirty_lines)
    }

    pub fn dump_stats(&self, report: &mut StatsReport) {
        for (name, levels) in [("l1d", &self.l1), ("l2", &self.l2)] {
            let mut agg = LevelStats::default();
            for l in levels.iter() {
                agg.hits += l.stats.hits;
                agg.misses += l.stats.misses;
                agg.writebacks += l.stats.writebacks;
                agg.accesses += l.stats.accesses;
                agg.mshr_stall_cycles += l.stats.mshr_stall_cycles;
            }
            Self::dump_level(report, name, &agg);
        }
        Self::dump_level(report, "llc", &self.llc.stats);
        let issued: u64 = self.prefetchers.iter().map(|p| p.issued).sum();
        let detections: u64 = self.prefetchers.iter().map(|p| p.detections).sum();
        report.add("prefetch.issued", issued as f64);
        report.add("prefetch.detections", detections as f64);
        report.add("prefetch.late_hits", self.pf_late_hits as f64);
        self.mem.dump_stats(report);
    }

    fn dump_level(report: &mut StatsReport, name: &str, s: &LevelStats) {
        report.add(format!("{name}.accesses"), s.accesses as f64);
        report.add(format!("{name}.hits"), s.hits as f64);
        report.add(format!("{name}.misses"), s.misses as f64);
        report.add(format!("{name}.writebacks"), s.writebacks as f64);
        report.add(format!("{name}.mshr_stall_cycles"), s.mshr_stall_cycles as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn sys() -> MemorySystem {
        MemorySystem::new(&SystemConfig::default(), 1).unwrap()
    }

    #[test]
    fn l1_hit_after_fill() {
        let mut m = sys();
        let a = m.access(0, 0x1000, false, 0);
        assert_eq!(a.level, 4); // cold: DRAM
        let b = m.access(0, 0x1000, false, a.done);
        assert_eq!(b.level, 1);
        assert_eq!(b.done, a.done + 2);
    }

    #[test]
    fn level_latencies_order() {
        let mut m = sys();
        let dram = m.access(0, 0x2000, false, 0).done;
        let l1 = m.access(0, 0x2000, false, dram).done - dram;
        assert!(dram > 22, "dram path {dram}");
        assert_eq!(l1, 2);
    }

    #[test]
    fn llc_serves_second_core() {
        let mut m = MemorySystem::new(&SystemConfig::default(), 2).unwrap();
        let a = m.access(0, 0x4000, false, 0);
        let b = m.access(1, 0x4000, false, a.done);
        assert_eq!(b.level, 3, "expected LLC hit from the other core");
    }

    #[test]
    fn streaming_evicts_and_writes_back() {
        let mut m = sys();
        let mut now = 0;
        // Write-stream 4 MB: far beyond L1+L2, forcing dirty evictions.
        for i in 0..(4 << 20) / 64u64 {
            now = m.access(0, i * 64, true, now).done;
        }
        assert!(m.l1[0].stats.writebacks > 0);
        assert!(m.l2[0].stats.writebacks > 0);
    }

    #[test]
    fn mshr_limits_increase_latency_under_burst() {
        let mut m = sys();
        // Issue a burst of independent misses at the same cycle.
        let mut dones: Vec<u64> = (0..64).map(|i| m.access(0, i * 4096, false, 0).done).collect();
        dones.sort_unstable();
        // With 10 L1 MSHRs the tail must be significantly delayed vs head.
        assert!(dones[63] > dones[0] + 50, "no MSHR throttling: {:?}", &dones[60..]);
        assert!(m.l1[0].stats.mshr_stall_cycles > 0);
    }

    #[test]
    fn flush_range_writes_back_dirty() {
        let mut m = sys();
        let mut now = 0;
        for i in 0..128u64 {
            now = m.access(0, 0x10000 + i * 64, true, now).done;
        }
        let (settle, dirty) = m.flush_range(0x10000, 8192, now);
        assert_eq!(dirty, 128);
        assert!(settle > now);
        // After the flush, the lines are gone from every level.
        let r = m.access(0, 0x10000, false, settle);
        assert_eq!(r.level, 4);
    }

    #[test]
    fn flush_clean_range_is_free() {
        let mut m = sys();
        let (settle, dirty) = m.flush_range(0x80000, 8192, 100);
        assert_eq!((settle, dirty), (100, 0));
    }

    #[test]
    fn functional_stream_matches_detailed_hit_miss_and_traffic() {
        // The functional path must replay the detailed path's exact tag
        // walk: hit/miss/writeback counters and total DRAM traffic are
        // order-derived, so equality here pins the call-order contract.
        let mut det = sys();
        let mut fun = sys();
        fun.begin_functional();
        let mut now = 0;
        for i in 0..8192u64 {
            let addr = ((i * 97) % 4096) * 64 + ((i % 7) << 20);
            let w = i % 3 == 0;
            let pc = 0x400 + (i % 4) * 8;
            now = det.access_pc(0, pc, addr, w, now).done;
            fun.access_functional(0, pc, addr, w, 0);
        }
        det.drain_pending();
        for (a, b) in [
            (&det.l1[0].stats, &fun.l1[0].stats),
            (&det.l2[0].stats, &fun.l2[0].stats),
            (&det.llc.stats, &fun.llc.stats),
        ] {
            assert_eq!(
                (a.accesses, a.hits, a.misses, a.writebacks),
                (b.accesses, b.hits, b.misses, b.writebacks)
            );
            assert_eq!(b.mshr_stall_cycles, 0, "functional path must not touch MSHRs");
        }
        let (dt, ft) = (det.mem.stats_total(), fun.mem.stats_total());
        assert_eq!((dt.host_reads, dt.host_writes), (ft.host_reads, ft.host_writes));
        assert_eq!(ft.host_queue_cycles, 0, "functional path must not advance DRAM clocks");
    }

    #[test]
    fn working_set_in_llc_stops_dram_traffic() {
        let mut m = sys();
        let lines = (4 << 20) / 64u64; // 4 MB: fits 16 MB LLC
        let mut now = 0;
        for i in 0..lines {
            now = m.access(0, i * 64, false, now).done;
        }
        let cold_dram = m.mem.stats_total().host_reads;
        for i in 0..lines {
            now = m.access(0, i * 64, false, now).done;
        }
        // Second pass: no new DRAM reads (all <= LLC).
        assert_eq!(m.mem.stats_total().host_reads, cold_dram);
    }
}
