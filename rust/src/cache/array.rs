//! Set-associative tag array with true-LRU replacement and dirty bits.

/// Tag storage for one cache. Data values are never stored — the simulator
/// is timing-only on this path (functional values flow through
/// `crate::runtime` instead, when built with the `pjrt` feature).
pub struct CacheArray {
    sets: usize,
    ways: usize,
    line_shift: u32,
    /// Interleaved (tag, stamp<<1 | dirty) per way — one cache-friendly
    /// array instead of three parallel ones (the tag walk is the hottest
    /// loop in the whole simulator).
    lines: Vec<(u64, u64)>,
    tick: u64,
}

pub const INVALID: u64 = u64::MAX;

impl CacheArray {
    pub fn new(sets: usize, ways: usize, line_bytes: usize) -> Self {
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!(line_bytes.is_power_of_two());
        Self {
            sets,
            ways,
            line_shift: line_bytes.trailing_zeros(),
            lines: vec![(INVALID, 0); sets * ways],
            tick: 0,
        }
    }

    #[inline]
    fn index(&self, addr: u64) -> (usize, u64) {
        let line = addr >> self.line_shift;
        ((line as usize) & (self.sets - 1), line)
    }

    /// Probe for `addr`; on hit, refresh LRU and (for writes) set dirty.
    #[inline]
    pub fn lookup(&mut self, addr: u64, is_write: bool) -> bool {
        let (set, line) = self.index(addr);
        let base = set * self.ways;
        for w in 0..self.ways {
            let l = &mut self.lines[base + w];
            if l.0 == line {
                self.tick += 1;
                l.1 = (self.tick << 1) | (l.1 & 1) | (is_write as u64);
                return true;
            }
        }
        false
    }

    /// Install `addr` (evicting LRU if needed). Returns the address of an
    /// evicted **dirty** line, if any, which the caller must write back.
    pub fn insert(&mut self, addr: u64, dirty: bool) -> Option<u64> {
        let (set, line) = self.index(addr);
        let base = set * self.ways;
        // Prefer an invalid way; otherwise evict the smallest stamp (LRU).
        let mut victim = 0;
        let mut best = u64::MAX;
        for w in 0..self.ways {
            let l = self.lines[base + w];
            if l.0 == INVALID {
                victim = w;
                break;
            }
            if l.1 >> 1 < best {
                best = l.1 >> 1;
                victim = w;
            }
        }
        let idx = base + victim;
        let old = self.lines[idx];
        let evicted = if old.0 != INVALID && old.1 & 1 == 1 {
            Some(old.0 << self.line_shift)
        } else {
            None
        };
        self.tick += 1;
        self.lines[idx] = (line, (self.tick << 1) | dirty as u64);
        evicted
    }

    /// Drop `addr` if present; returns whether the dropped line was dirty.
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let (set, line) = self.index(addr);
        let base = set * self.ways;
        for w in 0..self.ways {
            let l = &mut self.lines[base + w];
            if l.0 == line {
                let was_dirty = l.1 & 1 == 1;
                *l = (INVALID, 0);
                return was_dirty;
            }
        }
        false
    }

    /// Fold the complete tag/LRU/dirty state into `h` (sampled-mode
    /// state-parity digests; see `Machine::state_digest`).
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        self.tick.hash(h);
        self.lines.hash(h);
    }

    /// Number of valid lines currently resident (test/inspection helper).
    pub fn occupancy(&self) -> usize {
        self.lines.iter().filter(|l| l.0 != INVALID).count()
    }

    pub fn reset(&mut self) {
        self.lines.fill((INVALID, 0));
        self.tick = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit() {
        let mut c = CacheArray::new(4, 2, 64);
        assert!(!c.lookup(0x100, false));
        assert_eq!(c.insert(0x100, false), None);
        assert!(c.lookup(0x100, false));
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = CacheArray::new(1, 2, 64); // one set, 2 ways
        c.insert(0x000, false);
        c.insert(0x040, false);
        c.lookup(0x000, false); // refresh line 0 -> line 0x040 becomes LRU
        c.insert(0x080, false); // evicts 0x040
        assert!(c.lookup(0x000, false));
        assert!(!c.lookup(0x040, false));
        assert!(c.lookup(0x080, false));
    }

    #[test]
    fn dirty_eviction_returns_victim_address() {
        let mut c = CacheArray::new(1, 1, 64);
        c.insert(0x1000, true);
        let victim = c.insert(0x2000, false);
        assert_eq!(victim, Some(0x1000));
    }

    #[test]
    fn clean_eviction_returns_none() {
        let mut c = CacheArray::new(1, 1, 64);
        c.insert(0x1000, false);
        assert_eq!(c.insert(0x2000, false), None);
    }

    #[test]
    fn write_hit_dirties_line() {
        let mut c = CacheArray::new(2, 1, 64);
        c.insert(0x40, false);
        assert!(c.lookup(0x40, true)); // write hit
        assert_eq!(c.insert(0x40 + 128, false), Some(0x40)); // same set, evict dirty
    }

    #[test]
    fn invalidate_reports_dirtiness() {
        let mut c = CacheArray::new(2, 2, 64);
        c.insert(0x80, true);
        assert!(c.invalidate(0x80));
        assert!(!c.lookup(0x80, false));
        assert!(!c.invalidate(0x80)); // already gone
    }

    #[test]
    fn occupancy_counts() {
        let mut c = CacheArray::new(4, 2, 64);
        assert_eq!(c.occupancy(), 0);
        c.insert(0x0, false);
        c.insert(0x40, false);
        assert_eq!(c.occupancy(), 2);
        c.reset();
        assert_eq!(c.occupancy(), 0);
    }

    #[test]
    fn addresses_in_same_line_alias() {
        let mut c = CacheArray::new(4, 2, 64);
        c.insert(0x100, false);
        assert!(c.lookup(0x13F, false)); // same 64 B line
        assert!(!c.lookup(0x140, false));
    }
}
