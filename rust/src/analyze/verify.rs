//! `vima-verify`: symbolic cross-backend equivalence proofs.
//!
//! The paper's programmability claim rests on one invariant: a program's
//! VIMA lowering computes the same values as the scalar/AVX code it
//! replaces. This module *proves* that invariant per statement from the
//! [`symbolic`] summaries, instead of assuming it from shared source. The
//! two lowerings are **dataflow-equivalent** iff, for every statement:
//!
//! 1. **coverage** — both backends touch the same bytes of every operand.
//!    AVX truncates the vector to whole 64 B chunks, so a `vector_bytes`
//!    that is not a multiple of 64 silently drops the tail on one backend
//!    only (`backend-divergence`, reachable from the DSL; the `.vpr`
//!    parser already pins `vector_bytes` to a power of two ≥ 64);
//! 2. **no chunk clobber** — the AVX lowering reads and writes 64 B
//!    blocks in place, ascending. When a destination is shifted *forward*
//!    of a source by `d` bytes with `0 < d < covered`, block `c`'s store
//!    lands on source bytes block `c+1` has not read yet; VIMA fetches
//!    whole source vectors before writing, so the backends compute
//!    different values (`backend-divergence`). A *backward* shift
//!    (`d < 0`) is proven safe: stores trail the read cursor on both
//!    backends. Exact aliasing (`d = 0`) reads-then-writes each block and
//!    matches VIMA's semantics. This is the precise, direction-aware
//!    refinement of the conservative `partial-overlap` hazard lint;
//! 3. **same reduction tree for non-associative dtypes** — VIMA folds
//!    `Dot`/`RedSum` as a lane-parallel binary tree, AVX as a sequential
//!    fold in chunk order. For float dtypes the two rounding orders give
//!    bit-different scalars (`reduction-order-sensitive`, a warning: the
//!    divergence is bounded by rounding, not a wrong dataflow).
//!
//! The affine clobber test walks the same candidate iterations as the
//! analyzer's overlap pass (endpoints plus the zero-crossings of the
//! linear difference), so the proof is exact over the whole `vloop`
//! iteration space, not just iteration 0. Rules and worked examples:
//! DESIGN.md §15.

use crate::analyze::{lint, Diagnostic, Severity, SourceInfo};
use crate::analyze::symbolic::{
    self, AccessPattern, BackendSummary, IntraOrder, ReductionShape,
};
use crate::intrinsics::VimaProgram;
use crate::trace::Backend;

/// The verifier's result for one program: the per-backend symbolic
/// summaries it compared, and every divergence it found as a standard
/// [`Diagnostic`] (merged into [`crate::analyze::analyze`]'s report).
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub vima: BackendSummary,
    pub avx: BackendSummary,
    pub diags: Vec<Diagnostic>,
}

impl VerifyReport {
    /// Proven dataflow-equivalent: no error-severity divergence.
    /// (`reduction-order-sensitive` warnings — rounding-order drift on
    /// float reductions — do not break equivalence.)
    pub fn equivalent(&self) -> bool {
        self.diags.iter().all(|d| d.severity != Severity::Error)
    }

    /// Count of statements whose lowerings were compared.
    pub fn statements_checked(&self) -> usize {
        self.vima.instrs.len()
    }
}

/// Name an access pattern's base the way the analyzer does
/// (`name[+off][:stride]`, or a raw hex address outside any allocation).
fn label(p: &VimaProgram, src: &SourceInfo, a: &AccessPattern) -> String {
    for (i, al) in p.allocs.iter().enumerate() {
        if a.base >= al.base && a.base < al.base + al.size {
            let mut s = src
                .alloc_names
                .get(i)
                .cloned()
                .unwrap_or_else(|| format!("v{i}"));
            let off = a.base - al.base;
            if off > 0 {
                s.push_str(&format!("+{off}"));
            }
            if a.stride > 0 {
                s.push_str(&format!(":{}", a.stride));
            }
            return s;
        }
    }
    format!("0x{:x}", a.base)
}

/// Does the chunked lowering clobber `read` bytes before reading them,
/// for some iteration of the enclosing loop? Returns the offending
/// forward shift `d` (bytes) if so.
///
/// Per iteration the shift is `d(i) = write.at(i) - read.at(i)`, linear in
/// `i`. Block `c`'s store hits an unread source byte iff `0 < d < len`
/// and the shift is not confined to the block being processed
/// (`d >= chunk || len > chunk`). The linear difference is monotone, so
/// testing the endpoints plus the iterations nearest the `d = 0` and
/// `d = len` crossings covers the whole iteration space.
fn chunk_clobber(read: &AccessPattern, write: &AccessPattern, chunk: u64) -> Option<i128> {
    let len = read.len.min(write.len) as i128;
    let d0 = write.base as i128 - read.base as i128;
    let slope = write.stride as i128 - read.stride as i128;
    let n = read.count.min(write.count) as i128;
    let diverges = |d: i128| d > 0 && d < len && (d >= chunk as i128 || len > chunk as i128);
    let mut candidates = vec![0, n - 1];
    if slope != 0 {
        for target in [0i128, len] {
            let cross = (target - d0).div_euclid(slope);
            candidates.extend([cross - 1, cross, cross + 1]);
        }
    }
    for i in candidates {
        if i >= 0 && i < n {
            let d = d0 + i * slope;
            if diverges(d) {
                return Some(d);
            }
        }
    }
    None
}

/// Prove (or refute) dataflow equivalence of the VIMA and AVX lowerings.
/// Machine-independent: the verdict depends only on the program, so it
/// participates in the `program::load_str` load gate alongside the other
/// machine-independent error lints.
pub fn verify(p: &VimaProgram, src: &SourceInfo) -> VerifyReport {
    let vima = symbolic::summarize(p, src, Backend::Vima);
    let avx = symbolic::summarize(p, src, Backend::Avx);
    let mut diags = Vec::new();
    debug_assert_eq!(vima.instrs.len(), avx.instrs.len());

    for (iv, ia) in vima.instrs.iter().zip(&avx.instrs) {
        // Rule 1: per-operand byte coverage.
        if iv.covered != ia.covered {
            diags.push(Diagnostic {
                id: lint::BACKEND_DIVERGENCE,
                severity: Severity::Error,
                span: iv.span,
                message: format!(
                    "VIMA and AVX lowerings are not dataflow-equivalent: AVX covers {} B \
                     of each {} B operand (vector_bytes is not a multiple of the 64 B \
                     chunk), so the vector tail is computed on one backend only",
                    ia.covered, iv.covered
                ),
            });
        }

        // Rule 2: chunk clobber under the AVX in-place block order.
        if let (IntraOrder::Chunked { chunk }, Some(w)) = (ia.order, &ia.write) {
            let mut fired = false;
            for r in &ia.reads {
                if fired || !r.hull_overlaps(w) {
                    continue;
                }
                if let Some(d) = chunk_clobber(r, w, chunk) {
                    fired = true;
                    diags.push(Diagnostic {
                        id: lint::BACKEND_DIVERGENCE,
                        severity: Severity::Error,
                        span: ia.span,
                        message: format!(
                            "VIMA and AVX lowerings are not dataflow-equivalent: destination \
                             `{}` leads source `{}` by {} B, so the AVX {} B in-place blocks \
                             overwrite source bytes before reading them, while VIMA fetches \
                             whole source vectors first",
                            label(p, src, w),
                            label(p, src, r),
                            d,
                            chunk
                        ),
                    });
                }
            }
        }

        // Rule 3: reduction-tree shape on non-associative dtypes.
        if iv.dtype.is_float()
            && matches!(iv.reduction, ReductionShape::LaneTree)
            && matches!(ia.reduction, ReductionShape::SequentialChunks { .. })
        {
            diags.push(Diagnostic {
                id: lint::REDUCTION_ORDER_SENSITIVE,
                severity: Severity::Warning,
                span: iv.span,
                message: format!(
                    "float {:?} reduction folds as a lane-parallel tree on VIMA but \
                     sequentially per 64 B chunk on AVX: non-associative rounding makes \
                     the backends differ in the result's low bits",
                    iv.op
                ),
            });
        }
    }

    VerifyReport { vima, avx, diags }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saxpy_is_equivalent() {
        let p = crate::workload::programs::saxpy(8);
        let r = verify(&p, &SourceInfo::default());
        assert!(r.equivalent(), "{:?}", r.diags);
        assert!(r.diags.is_empty());
        assert!(r.statements_checked() >= 2);
    }

    #[test]
    fn softmax_is_equivalent_with_reduction_warning() {
        let p = crate::workload::programs::softmax(8);
        let r = verify(&p, &SourceInfo::default());
        assert!(r.equivalent(), "{:?}", r.diags);
        let ids: Vec<_> = r.diags.iter().map(|d| d.id).collect();
        assert_eq!(ids, vec![lint::REDUCTION_ORDER_SENSITIVE]);
    }

    #[test]
    fn forward_shift_diverges_backward_does_not() {
        // dst = src + vb/2: AVX clobbers the unread source tail.
        let fwd = verify_shift(4096);
        assert!(!fwd.equivalent());
        assert!(fwd.diags.iter().any(|d| d.id == lint::BACKEND_DIVERGENCE));
        // Backward shift: write a, read a+4096 — proven safe.
        let bwd = verify_shift(-4096);
        assert!(bwd.equivalent(), "{:?}", bwd.diags);
    }

    /// Program with `add (base+s0) (base+s0) -> (base+s1)` where the
    /// shift `s1 - s0` is `shift`; both halves initialized first.
    fn verify_shift(shift: i64) -> VerifyReport {
        let mut p = VimaProgram::new();
        let a = p.alloc(32768);
        let (src, dst) = if shift >= 0 {
            (a.walk(0), crate::intrinsics::VecPtr(a.0 + shift as u64).walk(0))
        } else {
            (crate::intrinsics::VecPtr(a.0 + (-shift) as u64).walk(0), a.walk(0))
        };
        p.vim2k_sets(a);
        p.vim2k_sets(crate::intrinsics::VecPtr(a.0 + 8192));
        p.vim2k_adds(src, src, dst);
        verify(&p, &SourceInfo::default())
    }

    #[test]
    fn exact_alias_accumulator_is_equivalent() {
        // matmul-style: fmadd a b c -> c (d = 0) must stay equivalent.
        let mut p = VimaProgram::new();
        let a = p.alloc(8192);
        let b = p.alloc(8192);
        let c = p.alloc(8192);
        p.vim2k_sets(a);
        p.vim2k_sets(b);
        p.vim2k_sets(c);
        p.vim2k_fmadds(a, b, c, c);
        let r = verify(&p, &SourceInfo::default());
        assert!(r.equivalent(), "{:?}", r.diags);
    }

    #[test]
    fn odd_vector_bytes_diverges_in_coverage() {
        let mut p = VimaProgram::new().with_vector_bytes(96);
        let a = p.alloc(96);
        p.vim2k_sets(a);
        let r = verify(&p, &SourceInfo::default());
        assert!(!r.equivalent());
        assert!(r
            .diags
            .iter()
            .any(|d| d.id == lint::BACKEND_DIVERGENCE && d.message.contains("covers 64 B")));
    }

    #[test]
    fn loop_strided_clobber_is_caught_mid_loop() {
        // Shift grows with i: d(i) = -8192 + i*4096. Both endpoints are
        // safe — d(0) = -8192 (backward), d(4) = 8192 = len (disjoint) —
        // and only i = 3 gives 0 < d < len, so endpoint testing alone
        // would miss it; the d = 0 crossing candidates must be walked.
        let mut p = VimaProgram::new();
        let a = p.alloc(1 << 20);
        p.vim2k_sets(a.walk(8192));
        let src = crate::intrinsics::VecPtr(a.0 + 8192).walk(4096);
        let dst = a.walk(8192);
        p.vloop(5, |b| b.vim2k_adds(src, src, dst));
        let r = verify(&p, &SourceInfo::default());
        assert!(!r.equivalent(), "expected a mid-loop clobber");
    }
}
