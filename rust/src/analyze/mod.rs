//! `vima-check`: a multi-pass static analyzer for VIMA programs.
//!
//! The paper sells VIMA on an *easy programming interface* with *precise
//! exceptions* — but before this module, a malformed or pathological
//! program was only caught when the simulator tripped over it at run time,
//! and performance hazards were never caught at all. The analyzer walks a
//! [`VimaProgram`] statement tree (and therefore every parsed `.vpr` file)
//! *before execution* and reports typed [`Diagnostic`]s with stable lint
//! IDs, severities, and line/column spans. Four pass families
//! (DESIGN.md §13):
//!
//! 1. **interval dataflow per allocation** — read-before-initialize, dead
//!    stores, and write-after-write shadowing, computed across `vloop`
//!    iteration spaces with strided-interval arithmetic on
//!    `NAME[+OFF][:STRIDE]` operands;
//! 2. **alias/overlap** — partial src/dst overlap within one instruction
//!    (which the chunked AVX lowering would miscompute) and loop-carried
//!    overlap or exact aliasing across iterations;
//! 3. **backend portability** — vector sizes the configured VIMA unit
//!    cannot execute (the run-time "oversized vector" error, moved to load
//!    time);
//! 4. **performance, keyed to the simulated machine** — vcache thrash,
//!    redundant re-loads of unmodified regions, hoistable loop-invariant
//!    statements, and operand walks that ping-pong across `MemFabric`
//!    cubes.
//!
//! Entry points: [`analyze`] for a program plus its [`SourceInfo`] (spans
//! and allocation names from the `.vpr` parser; empty for DSL-built
//! programs), [`analyze_parsed`] for a [`ParsedVpr`]. The loaders in
//! [`crate::program`] reject error-bearing files on load, and the
//! `vima-sim check` subcommand runs the analyzer against the session's
//! machine configuration.
//!
//! PR 10 grows the lint pass into **`vima-verify`** (DESIGN.md §15):
//!
//! 5. **symbolic cross-backend equivalence** — [`symbolic`] summarizes
//!    each backend lowering as affine access/compute polytopes and
//!    [`verify`] proves the VIMA and AVX lowerings dataflow-equivalent
//!    per statement; divergences surface as `backend-divergence` (error)
//!    and `reduction-order-sensitive` (warning) through the same
//!    [`analyze`] entry point, so the `.vpr` load gate rejects genuinely
//!    divergent programs;
//! 6. **static cost prediction** — [`cost`] prices the same summaries
//!    with the configured vcache/DRAM geometry and the fabric's
//!    `cube_index` hash, surfaced as `vima-sim check --predict` and
//!    cross-checked against the detailed simulator by `bench --predict`.

mod passes;

pub mod cost;
pub mod symbolic;
pub mod verify;

pub use verify::VerifyReport;

use crate::config::SystemConfig;
use crate::intrinsics::VimaProgram;
use crate::program::ParsedVpr;

/// A 1-based line/column source position; `line == 0` means unknown
/// (DSL-built programs carry no source text).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Span {
    pub line: u32,
    pub col: u32,
}

impl Span {
    pub const UNKNOWN: Span = Span { line: 0, col: 0 };

    pub fn new(line: u32, col: u32) -> Self {
        Span { line, col }
    }

    pub fn known(self) -> bool {
        self.line > 0
    }
}

/// Source positions for a statement list, mirroring the [`Stmt`] tree
/// shape: one node per statement, loops carry their body's nodes.
///
/// [`Stmt`]: crate::intrinsics
#[derive(Debug, Clone)]
pub enum SpanNode {
    Leaf(Span),
    Loop(Span, Vec<SpanNode>),
}

impl SpanNode {
    pub fn span(&self) -> Span {
        match self {
            SpanNode::Leaf(s) => *s,
            SpanNode::Loop(s, _) => *s,
        }
    }
}

/// Everything the analyzer knows about a program's source text. Default
/// (empty) for DSL-built programs: spans render as file-level diagnostics
/// and allocations are named `v0`, `v1`, ... (the emitter's convention).
#[derive(Debug, Clone, Default)]
pub struct SourceInfo {
    /// One node per top-level statement (empty = no source positions).
    pub spans: Vec<SpanNode>,
    /// One name per allocation (empty = `v{index}` defaults).
    pub alloc_names: Vec<String>,
    /// Position of the `vector_bytes` header directive, if any.
    pub vb_span: Span,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warning,
    Info,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Info => "info",
        }
    }
}

/// Stable lint identifiers (the `check` output contract: tests and CI pin
/// diagnostics by these IDs).
pub mod lint {
    pub const UNINIT_READ: &str = "uninit-read";
    pub const MAYBE_UNINIT_READ: &str = "maybe-uninit-read";
    pub const DEAD_STORE: &str = "dead-store";
    pub const LOOP_SHADOWED_STORE: &str = "loop-shadowed-store";
    pub const PARTIAL_OVERLAP: &str = "partial-overlap";
    pub const LOOP_CARRIED_OVERLAP: &str = "loop-carried-overlap";
    pub const LOOP_CARRIED_ALIAS: &str = "loop-carried-alias";
    pub const EMPTY_LOOP: &str = "empty-loop";
    pub const VECTOR_SIZE_UNSUPPORTED: &str = "vector-size-unsupported";
    pub const UNREAD_REDUCTION: &str = "unread-reduction";
    pub const VCACHE_THRASH: &str = "vcache-thrash";
    pub const REDUNDANT_RELOAD: &str = "redundant-reload";
    pub const HOISTABLE_INVARIANT: &str = "hoistable-invariant";
    pub const CUBE_PING_PONG: &str = "cube-ping-pong";
    pub const BACKEND_DIVERGENCE: &str = "backend-divergence";
    pub const REDUCTION_ORDER_SENSITIVE: &str = "reduction-order-sensitive";

    /// Every lint the analyzer can emit, for docs and coverage tests.
    pub const ALL: [&str; 16] = [
        UNINIT_READ,
        MAYBE_UNINIT_READ,
        DEAD_STORE,
        LOOP_SHADOWED_STORE,
        PARTIAL_OVERLAP,
        LOOP_CARRIED_OVERLAP,
        LOOP_CARRIED_ALIAS,
        EMPTY_LOOP,
        VECTOR_SIZE_UNSUPPORTED,
        UNREAD_REDUCTION,
        VCACHE_THRASH,
        REDUNDANT_RELOAD,
        HOISTABLE_INVARIANT,
        CUBE_PING_PONG,
        BACKEND_DIVERGENCE,
        REDUCTION_ORDER_SENSITIVE,
    ];
}

/// One analyzer finding: a stable lint ID, a severity, a source span (may
/// be unknown for DSL programs), and a rendered message.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub id: &'static str,
    pub severity: Severity,
    pub span: Span,
    pub message: String,
}

impl Diagnostic {
    /// `file:line:col: severity[id]: message` (the line/col segment is
    /// omitted when the span is unknown).
    pub fn render(&self, file: &str) -> String {
        if self.span.known() {
            format!(
                "{file}:{}:{}: {}[{}]: {}",
                self.span.line,
                self.span.col,
                self.severity.label(),
                self.id,
                self.message
            )
        } else {
            format!("{file}: {}[{}]: {}", self.severity.label(), self.id, self.message)
        }
    }

    /// One flat JSON object (hand-rolled; see [`crate::service::jsonl`]).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"id\": \"{}\", \"severity\": \"{}\", \"line\": {}, \"col\": {}, \
             \"message\": \"{}\"}}",
            self.id,
            self.severity.label(),
            self.span.line,
            self.span.col,
            crate::service::jsonl::escape(&self.message)
        )
    }
}

/// The analyzer's result for one program: diagnostics sorted by source
/// position (file-level first), stable within a statement.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diags: Vec<Diagnostic>,
}

impl Report {
    pub fn count(&self, sev: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity == sev).count()
    }

    pub fn error_count(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warning_count(&self) -> usize {
        self.count(Severity::Warning)
    }

    pub fn info_count(&self) -> usize {
        self.count(Severity::Info)
    }

    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// First error-severity diagnostic, if any (the load-gate message).
    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.diags.iter().find(|d| d.severity == Severity::Error)
    }

    /// Render every diagnostic, one line each, with a trailing newline
    /// (empty string when clean) — the `.expect` fixture format.
    pub fn render(&self, file: &str) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&d.render(file));
            out.push('\n');
        }
        out
    }

    /// Compact `"1E 2W 3I"` counts label (or `"clean"`) for the
    /// `vima-sim workloads` listing.
    pub fn counts_label(&self) -> String {
        if self.is_clean() {
            return "clean".to_string();
        }
        let mut parts = Vec::new();
        for (n, tag) in [
            (self.error_count(), "E"),
            (self.warning_count(), "W"),
            (self.info_count(), "I"),
        ] {
            if n > 0 {
                parts.push(format!("{n}{tag}"));
            }
        }
        parts.join(" ")
    }

    /// The per-file JSON fragment for `check --json`.
    pub fn to_json(&self, file: &str) -> String {
        let diags: Vec<String> = self.diags.iter().map(Diagnostic::to_json).collect();
        format!(
            "{{\"file\": \"{}\", \"errors\": {}, \"warnings\": {}, \"infos\": {}, \
             \"diagnostics\": [{}]}}",
            crate::service::jsonl::escape(file),
            self.error_count(),
            self.warning_count(),
            self.info_count(),
            diags.join(", ")
        )
    }
}

/// Analyze a program against a machine configuration. `src` supplies
/// source spans and allocation names where available ([`SourceInfo`]
/// default for DSL-built programs). Runs the lint passes *and* the
/// cross-backend equivalence verifier; the combined report is sorted by
/// (span, lint id) so output is deterministic across passes.
pub fn analyze(program: &VimaProgram, src: &SourceInfo, cfg: &SystemConfig) -> Report {
    let mut r = passes::run(program, src, cfg);
    r.diags.extend(verify::verify(program, src).diags);
    r.diags.sort_by_key(|d| (d.span.line, d.span.col, d.id));
    r
}

/// Analyze a parsed `.vpr` file (spans and names travel with it).
pub fn analyze_parsed(parsed: &ParsedVpr, cfg: &SystemConfig) -> Report {
    analyze(&parsed.program, &parsed.source, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_includes_span_when_known() {
        let d = Diagnostic {
            id: lint::UNINIT_READ,
            severity: Severity::Error,
            span: Span::new(7, 3),
            message: "m".to_string(),
        };
        assert_eq!(d.render("f.vpr"), "f.vpr:7:3: error[uninit-read]: m");
        let d2 = Diagnostic { span: Span::UNKNOWN, ..d };
        assert_eq!(d2.render("f.vpr"), "f.vpr: error[uninit-read]: m");
    }

    #[test]
    fn counts_label_summarizes() {
        let mut r = Report::default();
        assert_eq!(r.counts_label(), "clean");
        r.diags.push(Diagnostic {
            id: lint::DEAD_STORE,
            severity: Severity::Warning,
            span: Span::UNKNOWN,
            message: String::new(),
        });
        assert_eq!(r.counts_label(), "1W");
        assert_eq!(r.warning_count(), 1);
        assert!(r.first_error().is_none());
    }

    #[test]
    fn dsl_saxpy_is_clean() {
        let p = crate::workload::programs::saxpy(16);
        let r = analyze(&p, &SourceInfo::default(), &SystemConfig::default());
        assert!(r.is_clean(), "{}", r.render("saxpy"));
    }

    #[test]
    fn dsl_softmax_is_error_free_with_reduction_warning() {
        let p = crate::workload::programs::softmax(16);
        let r = analyze(&p, &SourceInfo::default(), &SystemConfig::default());
        assert_eq!(r.error_count(), 0, "{}", r.render("softmax"));
        // The float dot reduction folds in different orders per backend.
        assert!(
            r.diags.iter().any(|d| d.id == lint::REDUCTION_ORDER_SENSITIVE),
            "{}",
            r.render("softmax")
        );
    }
}
