//! The analyzer passes: strided-interval dataflow, alias/overlap,
//! portability, and machine-keyed performance lints.
//!
//! Every operand is abstracted as a [`Pat`]: `count` instances of
//! `extent` bytes starting at `base`, `stride` bytes apart (`count` = the
//! innermost loop's iteration count, 1 at top level). The walk follows
//! the statement tree in execution order, maintaining the set of byte
//! intervals proven written ([`IntervalSet`]), instance-precise records
//! of sparse (gapped) writes, and the set of stores not yet observed by
//! any read. An ownership pre-pass classifies each allocation by its
//! first textual touch — written first means VIMA-owned (reads must be
//! proven initialized), read first means host-initialized input (reads
//! are trusted, matching `host_store`-style preloading that the program
//! text cannot see).

use super::{lint, Diagnostic, Report, Severity, SourceInfo, Span, SpanNode};
use crate::config::SystemConfig;
use crate::fabric::cube_index;
use crate::intrinsics::{Operand, Stmt, VimaProgram};
use crate::isa::VimaOp;

/// A strided access pattern: `count` instances of `extent` bytes,
/// `stride` apart, starting at `base`.
#[derive(Debug, Clone, Copy)]
struct Pat {
    base: u64,
    stride: u64,
    count: u64,
    extent: u64,
}

impl Pat {
    fn of(o: &Operand, iters: u64, extent: u64) -> Pat {
        Pat { base: o.base, stride: o.stride, count: iters.max(1), extent }
    }

    /// Convex hull `[lo, hi)` over every instance.
    fn hull(&self) -> (u64, u64) {
        (self.base, self.base + (self.count - 1) * self.stride + self.extent)
    }

    /// Iteration 0's instance `[lo, hi)`.
    fn first(&self) -> (u64, u64) {
        (self.base, self.base + self.extent)
    }

    /// Dense patterns tile their hull with no gaps between instances.
    fn dense(&self) -> bool {
        self.count == 1 || self.stride <= self.extent
    }

    /// Whether one single instance contains `[lo, hi)` (instance-precise
    /// membership for sparse writes).
    fn instance_covers(&self, lo: u64, hi: u64) -> bool {
        if lo >= hi || lo < self.base || hi - lo > self.extent {
            return false;
        }
        let k = if self.stride == 0 {
            0
        } else {
            ((lo - self.base) / self.stride).min(self.count - 1)
        };
        let start = self.base + k * self.stride;
        start <= lo && hi <= start + self.extent
    }
}

/// Sorted, disjoint half-open byte intervals with merge-on-touch insert.
#[derive(Debug, Clone, Default)]
struct IntervalSet {
    v: Vec<(u64, u64)>,
}

impl IntervalSet {
    fn insert(&mut self, mut lo: u64, mut hi: u64) {
        if lo >= hi {
            return;
        }
        let mut out: Vec<(u64, u64)> = Vec::with_capacity(self.v.len() + 1);
        let mut placed = false;
        for &(a, b) in &self.v {
            if b < lo || hi < a {
                if a > hi && !placed {
                    out.push((lo, hi));
                    placed = true;
                }
                out.push((a, b));
            } else {
                lo = lo.min(a);
                hi = hi.max(b);
            }
        }
        if !placed {
            out.push((lo, hi));
        }
        self.v = out;
    }

    /// After merge-on-touch, containment in a single interval is exact.
    fn covers(&self, lo: u64, hi: u64) -> bool {
        lo >= hi || self.v.iter().any(|&(a, b)| a <= lo && hi <= b)
    }

    fn total(&self) -> u64 {
        self.v.iter().map(|&(a, b)| b - a).sum()
    }
}

/// First-textual-touch classification of an allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Owner {
    Untouched,
    /// First touched by a VIMA write: reads must be proven initialized.
    Owned,
    /// First touched by a read (or `host_load`): a host-initialized input
    /// whose contents the program text cannot see — reads are trusted.
    External,
}

/// One write site recorded by the pre-pass (for the optimistic
/// any-write-anywhere union behind `maybe-uninit-read`).
struct WriteRec {
    stmt: usize,
    pat: Pat,
}

/// A completed dense store no read has observed yet.
struct Pending {
    alloc: usize,
    lo: u64,
    hi: u64,
    span: Span,
}

/// Per-statement write patterns for the block being walked (nested loops
/// contribute their dense write hulls as stride-0 pseudo-patterns).
struct Entry {
    writes: Vec<Pat>,
}

fn span_at(spans: &[SpanNode], pos: usize) -> (Span, &[SpanNode]) {
    match spans.get(pos) {
        Some(SpanNode::Leaf(s)) => (*s, &[]),
        Some(SpanNode::Loop(s, kids)) => (*s, kids),
        None => (Span::UNKNOWN, &[]),
    }
}

/// Hulls of every *dense* write in `stmts`, recursively.
fn dense_write_hulls(stmts: &[Stmt], iters: u64, vb: u64, out: &mut Vec<(u64, u64)>) {
    for s in stmts {
        match s {
            Stmt::Instr { dst: Some(d), .. } => {
                let w = Pat::of(d, iters, vb);
                if w.dense() {
                    out.push(w.hull());
                }
            }
            Stmt::Instr { .. } | Stmt::HostLoad { .. } => {}
            Stmt::Loop { start, end, body } => {
                if *end > *start {
                    dense_write_hulls(body, *end - *start, vb, out);
                }
            }
        }
    }
}

/// Hulls of every write in `stmts` (dense or not), recursively.
fn write_hulls(stmts: &[Stmt], iters: u64, vb: u64, out: &mut Vec<(u64, u64)>) {
    for s in stmts {
        match s {
            Stmt::Instr { dst: Some(d), .. } => out.push(Pat::of(d, iters, vb).hull()),
            Stmt::Instr { .. } | Stmt::HostLoad { .. } => {}
            Stmt::Loop { start, end, body } => {
                if *end > *start {
                    write_hulls(body, *end - *start, vb, out);
                }
            }
        }
    }
}

/// Hulls of every read in `stmts`, recursively. `host` controls whether
/// `host_load` counts as a read (it does for liveness, not for the
/// VIMA-cache re-load lint: host loads bypass the vcache).
fn read_hulls(stmts: &[Stmt], iters: u64, vb: u64, host: bool, out: &mut Vec<(u64, u64)>) {
    for s in stmts {
        match s {
            Stmt::Instr { srcs, .. } => {
                for o in srcs {
                    out.push(Pat::of(o, iters, vb).hull());
                }
            }
            Stmt::HostLoad { addr, bytes } => {
                if host {
                    out.push(Pat::of(addr, iters, u64::from(*bytes)).hull());
                }
            }
            Stmt::Loop { start, end, body } => {
                if *end > *start {
                    read_hulls(body, *end - *start, vb, host, out);
                }
            }
        }
    }
}

fn has_host_load(stmts: &[Stmt]) -> bool {
    stmts.iter().any(|s| match s {
        Stmt::HostLoad { .. } => true,
        Stmt::Loop { start, end, body } => *end > *start && has_host_load(body),
        Stmt::Instr { .. } => false,
    })
}

fn overlaps(lo: u64, hi: u64, ranges: &[(u64, u64)]) -> bool {
    ranges.iter().any(|&(a, b)| a < hi && lo < b)
}

/// Can write pattern `w` (at body position `wpos`) prove read `r` (at
/// `rpos`) initialized on every iteration >= 1? `rl1..rh1` is the read's
/// rest-hull (instances 1..count). Same-body patterns share `count`.
fn strided_cover(w: &Pat, wpos: usize, r: &Pat, rpos: usize, rl1: u64, rh1: u64) -> bool {
    if w.stride == 0 {
        // A constant interval rewritten every iteration: iteration i >= 1
        // saw iteration i-1's instance regardless of body position.
        return w.base <= rl1 && rh1 <= w.base + w.extent;
    }
    if w.stride != r.stride {
        return false;
    }
    let s = r.stride as i128;
    let d = r.base as i128 - w.base as i128;
    let q = d.div_euclid(s);
    let rem = d.rem_euclid(s) as u64;
    // Congruent offsets: read instance i lies inside write instance i + q.
    // q == -1 completed last iteration; q == 0 needs the write textually
    // earlier in the body.
    if rem + r.extent <= w.extent && (q == -1 || (q == 0 && wpos < rpos)) {
        return true;
    }
    // Dense prefix: an earlier dense walker's instances 0..=i tile
    // [w.base, w.base + i*s + extent), which contains read instance i.
    w.stride <= w.extent
        && wpos < rpos
        && w.base <= r.base
        && r.base + r.extent <= w.base + w.extent
}

struct Analyzer<'a> {
    p: &'a VimaProgram,
    cfg: &'a SystemConfig,
    names: Vec<String>,
    owner: Vec<Owner>,
    all_writes: Vec<WriteRec>,
    /// Byte intervals proven written by completed dense stores.
    init: IntervalSet,
    /// Completed sparse (gapped) write patterns, instance-precise.
    sparse: Vec<Pat>,
    pending: Vec<Pending>,
    diags: Vec<Diagnostic>,
    vb: u64,
    counter: usize,
}

pub(super) fn run(p: &VimaProgram, src: &SourceInfo, cfg: &SystemConfig) -> Report {
    let mut names: Vec<String> = (0..p.allocs.len()).map(|i| format!("v{i}")).collect();
    for (i, n) in src.alloc_names.iter().enumerate() {
        if i < names.len() {
            names[i] = n.clone();
        }
    }
    let mut a = Analyzer {
        p,
        cfg,
        names,
        owner: vec![Owner::Untouched; p.allocs.len()],
        all_writes: Vec::new(),
        init: IntervalSet::default(),
        sparse: Vec::new(),
        pending: Vec::new(),
        diags: Vec::new(),
        vb: u64::from(p.vector_bytes),
        counter: 0,
    };
    if p.vector_bytes as usize > cfg.vima.vector_bytes {
        a.diag(
            lint::VECTOR_SIZE_UNSUPPORTED,
            Severity::Error,
            src.vb_span,
            format!(
                "program uses {} B vectors but the configured VIMA unit supports {} B \
                 (raise [vima] vector_bytes or rebuild the program)",
                p.vector_bytes, cfg.vima.vector_bytes
            ),
        );
    }
    let mut c = 0usize;
    a.prepass(&p.stmts, 1, &mut c);
    a.block(&p.stmts, &src.spans, 1, Span::UNKNOWN);
    a.diags.sort_by_key(|d| (d.span.line, d.span.col, d.id));
    Report { diags: a.diags }
}

impl Analyzer<'_> {
    fn diag(&mut self, id: &'static str, severity: Severity, span: Span, message: String) {
        self.diags.push(Diagnostic { id, severity, span, message });
    }

    fn alloc_of(&self, addr: u64) -> Option<usize> {
        self.p.allocs.iter().position(|al| al.base <= addr && addr < al.base + al.size)
    }

    /// `NAME[+OFF][:STRIDE]`, the `.vpr` operand syntax.
    fn label(&self, o: &Operand) -> String {
        match self.alloc_of(o.base) {
            Some(i) => {
                let mut s = self.names[i].clone();
                let off = o.base - self.p.allocs[i].base;
                if off > 0 {
                    s.push_str(&format!("+{off}"));
                }
                if o.stride > 0 {
                    s.push_str(&format!(":{}", o.stride));
                }
                s
            }
            None => format!("0x{:x}", o.base),
        }
    }

    fn touch(&mut self, addr: u64, write: bool) {
        if let Some(i) = self.alloc_of(addr) {
            if self.owner[i] == Owner::Untouched {
                self.owner[i] = if write { Owner::Owned } else { Owner::External };
            }
        }
    }

    /// Ownership + write-site collection, in textual (= first-execution)
    /// order. Zero-iteration loops are skipped exactly as in the main
    /// walk so statement ids stay aligned.
    fn prepass(&mut self, stmts: &[Stmt], iters: u64, counter: &mut usize) {
        for s in stmts {
            let id = *counter;
            *counter += 1;
            match s {
                Stmt::Instr { srcs, dst, .. } => {
                    for o in srcs {
                        self.touch(o.base, false);
                    }
                    if let Some(d) = dst {
                        self.touch(d.base, true);
                        let pat = Pat::of(d, iters, self.vb);
                        self.all_writes.push(WriteRec { stmt: id, pat });
                    }
                }
                Stmt::HostLoad { addr, .. } => self.touch(addr.base, false),
                Stmt::Loop { start, end, body } => {
                    if *end > *start {
                        self.prepass(body, *end - *start, counter);
                    }
                }
            }
        }
    }

    fn covered_completed(&self, lo: u64, hi: u64) -> bool {
        self.init.covers(lo, hi) || self.sparse.iter().any(|p| p.instance_covers(lo, hi))
    }

    /// Fold a completed write pattern into the proven-written state.
    fn complete(&mut self, w: Pat) {
        if w.dense() {
            let (lo, hi) = w.hull();
            self.init.insert(lo, hi);
        } else {
            self.sparse.push(w);
        }
    }

    fn mark_live(&mut self, lo: u64, hi: u64) {
        self.pending.retain(|p| !(p.lo < hi && lo < p.hi));
    }

    /// Record a store: report pending stores it fully shadows, then (if
    /// dense) become the new pending store for its hull.
    fn store(&mut self, w: Pat, span: Span) {
        if !w.dense() {
            return;
        }
        let (lo, hi) = w.hull();
        let mut i = 0;
        while i < self.pending.len() {
            if lo <= self.pending[i].lo && self.pending[i].hi <= hi {
                let dead = self.pending.remove(i);
                let base = self.p.allocs[dead.alloc].base;
                let tail = if span.known() {
                    format!("is overwritten by line {} before any read", span.line)
                } else {
                    "is overwritten before any read".to_string()
                };
                let msg = format!(
                    "store to `{}` bytes {}..{} {}",
                    self.names[dead.alloc],
                    dead.lo - base,
                    dead.hi - base,
                    tail
                );
                self.diag(lint::DEAD_STORE, Severity::Warning, dead.span, msg);
            } else {
                i += 1;
            }
        }
        if let Some(alloc) = self.alloc_of(w.base) {
            self.pending.push(Pending { alloc, lo, hi, span });
        }
    }

    /// Per-statement write patterns for one block, used for in-body
    /// coverage and alias suppression.
    fn scan_entries(&self, stmts: &[Stmt], iters: u64) -> Vec<Entry> {
        stmts
            .iter()
            .map(|s| {
                let writes = match s {
                    Stmt::Instr { dst: Some(d), .. } => vec![Pat::of(d, iters, self.vb)],
                    Stmt::Instr { .. } | Stmt::HostLoad { .. } => Vec::new(),
                    Stmt::Loop { start, end, body } => {
                        if *end > *start {
                            let mut hulls = Vec::new();
                            dense_write_hulls(body, *end - *start, self.vb, &mut hulls);
                            hulls
                                .into_iter()
                                .map(|(lo, hi)| Pat {
                                    base: lo,
                                    stride: 0,
                                    count: iters,
                                    extent: hi - lo,
                                })
                                .collect()
                        } else {
                            Vec::new()
                        }
                    }
                };
                Entry { writes }
            })
            .collect()
    }

    /// Walk one statement list executing `iters` times (1 = top level).
    fn block(&mut self, stmts: &[Stmt], spans: &[SpanNode], iters: u64, loop_span: Span) {
        let entries = self.scan_entries(stmts, iters);
        let mut body_reads = Vec::new();
        read_hulls(stmts, iters, self.vb, true, &mut body_reads);
        let body_has_host = has_host_load(stmts);
        if iters >= 2 {
            self.vcache_thrash(stmts, loop_span);
            self.redundant_reload(stmts, iters, loop_span);
        }
        for (pos, s) in stmts.iter().enumerate() {
            let id = self.counter;
            self.counter += 1;
            let (span, child_spans) = span_at(spans, pos);
            match s {
                Stmt::Instr { op, srcs, dst, .. } => {
                    for o in srcs {
                        let r = Pat::of(o, iters, self.vb);
                        self.check_read(&r, pos, &entries, id, span);
                        let (lo, hi) = r.hull();
                        self.mark_live(lo, hi);
                    }
                    if let Some(d) = dst {
                        self.alias(srcs, d, iters, pos, &entries, span);
                        let w = Pat::of(d, iters, self.vb);
                        self.store(w, span);
                        if iters >= 2 && w.stride < w.extent {
                            let (lo, hi) = w.hull();
                            if !overlaps(lo, hi, &body_reads) {
                                let msg = format!(
                                    "store to `{}` overwrites the same bytes every iteration \
                                     (stride {} < vector size {}) and the result is never read \
                                     in this loop",
                                    self.label(d),
                                    w.stride,
                                    self.vb
                                );
                                self.diag(lint::LOOP_SHADOWED_STORE, Severity::Warning, span, msg);
                            }
                        }
                    }
                    if iters >= 2 {
                        self.hoistable(srcs, dst.as_ref(), &entries, body_has_host, span);
                        if matches!(op, VimaOp::Dot | VimaOp::RedSum) && !body_has_host {
                            self.diag(
                                lint::UNREAD_REDUCTION,
                                Severity::Info,
                                span,
                                "reduction result is never read back in this loop (no \
                                 host_load): each iteration overwrites the VIMA status register"
                                    .to_string(),
                            );
                        }
                        self.cube_ping_pong(srcs, dst.as_ref(), iters, span);
                    }
                    if iters == 1 {
                        if let Some(d) = dst {
                            self.complete(Pat::of(d, 1, self.vb));
                        }
                    }
                }
                Stmt::HostLoad { addr, bytes } => {
                    let r = Pat::of(addr, iters, u64::from(*bytes));
                    let (lo, hi) = r.hull();
                    self.mark_live(lo, hi);
                }
                Stmt::Loop { start, end, body } => {
                    let n = end.saturating_sub(*start);
                    if n == 0 {
                        self.diag(
                            lint::EMPTY_LOOP,
                            Severity::Warning,
                            span,
                            "vloop executes zero iterations".to_string(),
                        );
                        continue;
                    }
                    if body.is_empty() {
                        self.diag(
                            lint::EMPTY_LOOP,
                            Severity::Warning,
                            span,
                            "vloop body is empty".to_string(),
                        );
                    }
                    self.block(body, child_spans, n, span);
                    // The loop has fully executed: fold its writes into
                    // the proven-written state, and let its reads keep
                    // earlier stores live.
                    for e in self.scan_entries(body, n) {
                        for w in e.writes {
                            self.complete(w);
                        }
                    }
                    let mut reads = Vec::new();
                    read_hulls(body, n, self.vb, true, &mut reads);
                    for (lo, hi) in reads {
                        self.mark_live(lo, hi);
                    }
                }
            }
        }
    }

    /// The read-before-initialize check for one source pattern.
    fn check_read(&mut self, r: &Pat, pos: usize, entries: &[Entry], id: usize, span: Span) {
        let Some(alloc) = self.alloc_of(r.base) else {
            return;
        };
        if self.owner[alloc] != Owner::Owned {
            return;
        }
        let (rl0, rh0) = r.first();
        let covered0 = self.covered_completed(rl0, rh0)
            || entries[..pos]
                .iter()
                .any(|e| e.writes.iter().any(|w| w.base <= rl0 && rh0 <= w.base + w.extent));
        let covered_rest = if r.count <= 1 {
            true
        } else if r.stride == 0 {
            // Iterations >= 1 re-read iteration i-1's bytes: any stride-0
            // body write over the interval (its own accumulator included)
            // proves them.
            covered0
                || entries.iter().any(|e| {
                    e.writes
                        .iter()
                        .any(|w| w.stride == 0 && w.base <= rl0 && rh0 <= w.base + w.extent)
                })
        } else {
            let (_, rh) = r.hull();
            let rl1 = r.base + r.stride;
            self.covered_completed(rl1, rh)
                || entries.iter().enumerate().any(|(wpos, e)| {
                    e.writes.iter().any(|w| strided_cover(w, wpos, r, pos, rl1, rh))
                })
        };
        if covered0 && covered_rest {
            return;
        }
        let (lo, hi) = if covered0 && r.count > 1 && r.stride > 0 {
            (r.base + r.stride, r.hull().1)
        } else {
            r.hull()
        };
        // Optimistic union of every write site in the program except this
        // statement's own in-place destination: if even that cannot reach
        // the read, the bytes are definitely never written.
        let mut others = IntervalSet::default();
        for rec in &self.all_writes {
            if rec.stmt == id && rec.pat.base == r.base && rec.pat.stride == r.stride {
                continue;
            }
            let (a, b) = rec.pat.hull();
            others.insert(a, b);
        }
        let base = self.p.allocs[alloc].base;
        let name = self.names[alloc].clone();
        if others.covers(lo, hi) {
            self.diag(
                lint::MAYBE_UNINIT_READ,
                Severity::Warning,
                span,
                format!(
                    "read of `{name}` bytes {}..{} cannot be proven initialized before this \
                     statement",
                    lo - base,
                    hi - base
                ),
            );
        } else {
            self.diag(
                lint::UNINIT_READ,
                Severity::Error,
                span,
                format!(
                    "read of `{name}` bytes {}..{} before any write reaches them",
                    lo - base,
                    hi - base
                ),
            );
        }
    }

    /// Src/dst overlap within one instruction and across iterations.
    fn alias(
        &mut self,
        srcs: &[Operand],
        d: &Operand,
        iters: u64,
        pos: usize,
        entries: &[Entry],
        span: Span,
    ) {
        let dp = Pat::of(d, iters, self.vb);
        let (dl, dh) = dp.hull();
        let ext = self.vb as i128;
        let n = iters as i128;
        let mut partial_done = false;
        for o in srcs {
            let sp = Pat::of(o, iters, self.vb);
            let (sl, sh) = sp.hull();
            if !(sl < dh && dl < sh) {
                continue;
            }
            let ss = sp.stride as i128;
            let ds = dp.stride as i128;
            let diff0 = sp.base as i128 - dp.base as i128;
            // Same-iteration partial overlap. Exact aliasing (diff 0) is
            // fine — in-place updates are whole-vector — but a partial
            // shift is miscomputed by the chunked AVX lowering.
            let mut fire_partial = |a: &mut Self, dv: i128| {
                if dv != 0 && dv.abs() < ext && !partial_done {
                    partial_done = true;
                    let msg = format!(
                        "source `{}` partially overlaps destination `{}`: the chunked AVX \
                         lowering reads and writes 64 B blocks in place, so overlapped source \
                         bytes are clobbered mid-instruction",
                        a.label(o),
                        a.label(d)
                    );
                    a.diag(lint::PARTIAL_OVERLAP, Severity::Error, span, msg);
                }
            };
            if ss == ds {
                fire_partial(self, diff0);
            } else {
                // diff(i) = diff0 + i*(ss - ds) is monotone: check the
                // endpoints and the iterations nearest the zero crossing.
                let slope = ss - ds;
                let cross = -diff0 / slope;
                for i in [0, n - 1, cross - 1, cross, cross + 1] {
                    if i >= 0 && i < n {
                        fire_partial(self, diff0 + i * slope);
                    }
                }
            }
            if iters < 2 || ss != ds {
                continue;
            }
            // Loop-carried: src instance i vs dst instance i - k.
            if ss == 0 {
                if diff0 == 0 {
                    let (l0, h0) = sp.first();
                    let rewritten = entries[..pos]
                        .iter()
                        .any(|e| e.writes.iter().any(|w| w.base <= l0 && h0 <= w.base + w.extent));
                    if !rewritten {
                        let msg = format!(
                            "`{}` reads exactly what `{}` wrote 1 iteration(s) earlier: \
                             loop-carried dependence (not safe to slice across threads)",
                            self.label(o),
                            self.label(d)
                        );
                        self.diag(lint::LOOP_CARRIED_ALIAS, Severity::Info, span, msg);
                    }
                }
                continue;
            }
            let k1 = (-diff0).div_euclid(ss);
            let mut cand = [k1, k1 + 1];
            cand.sort_unstable_by_key(|k| (k.abs(), *k));
            for k in cand {
                if k == 0 || k.abs() > n - 1 {
                    continue;
                }
                let dv = diff0 + k * ss;
                if dv == 0 {
                    let msg = if k > 0 {
                        format!(
                            "`{}` reads exactly what `{}` wrote {} iteration(s) earlier: \
                             loop-carried dependence (not safe to slice across threads)",
                            self.label(o),
                            self.label(d),
                            k
                        )
                    } else {
                        format!(
                            "`{}` reads bytes that `{}` overwrites {} iteration(s) later: \
                             loop-carried anti-dependence (not safe to slice across threads)",
                            self.label(o),
                            self.label(d),
                            -k
                        )
                    };
                    self.diag(lint::LOOP_CARRIED_ALIAS, Severity::Info, span, msg);
                    break;
                } else if dv.abs() < ext {
                    let (lag, when) = if k > 0 { (k, "earlier") } else { (-k, "later") };
                    let msg = format!(
                        "source `{}` overlaps bytes that `{}` writes {} iteration(s) {}: \
                         loop-carried hazard",
                        self.label(o),
                        self.label(d),
                        lag,
                        when
                    );
                    self.diag(lint::LOOP_CARRIED_OVERLAP, Severity::Warning, span, msg);
                    break;
                }
            }
        }
    }

    /// All-stride-0 statement whose inputs nothing in the body writes.
    fn hoistable(
        &mut self,
        srcs: &[Operand],
        dst: Option<&Operand>,
        entries: &[Entry],
        body_has_host: bool,
        span: Span,
    ) {
        if body_has_host || (srcs.is_empty() && dst.is_none()) {
            return;
        }
        if srcs.iter().any(|o| o.stride != 0) || dst.is_some_and(|d| d.stride != 0) {
            return;
        }
        for o in srcs {
            let (lo, hi) = (o.base, o.base + self.vb);
            let written = entries.iter().any(|e| {
                e.writes.iter().any(|w| {
                    let (a, b) = w.hull();
                    a < hi && lo < b
                })
            });
            if written {
                return;
            }
        }
        self.diag(
            lint::HOISTABLE_INVARIANT,
            Severity::Info,
            span,
            "every operand has stride 0, so this statement computes the same value every \
             iteration: hoist it out of the vloop"
                .to_string(),
        );
    }

    /// Distinct resident operands vs the VIMA cache's line count.
    fn vcache_thrash(&mut self, stmts: &[Stmt], span: Span) {
        let mut keys: Vec<(u64, u64)> = Vec::new();
        let mut pinned = false;
        for s in stmts {
            if let Stmt::Instr { srcs, dst, .. } = s {
                for o in srcs.iter().chain(dst.as_ref()) {
                    let k = (o.base, o.stride);
                    if !keys.contains(&k) {
                        keys.push(k);
                        if o.stride == 0 {
                            pinned = true;
                        }
                    }
                }
            }
        }
        let per = self.vb.div_ceil(self.cfg.vima.vector_bytes as u64);
        let lines = keys.len() as u64 * per;
        let cap = self.cfg.vima.cache_lines() as u64;
        if pinned && lines > cap {
            self.diag(
                lint::VCACHE_THRASH,
                Severity::Warning,
                span,
                format!(
                    "loop body touches {lines} vector-cache lines per iteration but the VIMA \
                     cache holds {cap}: resident operands will thrash"
                ),
            );
        }
    }

    /// Loop-invariant bytes re-read every iteration, vs cache capacity.
    fn redundant_reload(&mut self, stmts: &[Stmt], iters: u64, span: Span) {
        let mut writes = Vec::new();
        write_hulls(stmts, iters, self.vb, &mut writes);
        let mut cands: Vec<(u64, u64)> = Vec::new();
        for s in stmts {
            match s {
                Stmt::Instr { srcs, .. } => {
                    for o in srcs {
                        if o.stride == 0 {
                            cands.push((o.base, o.base + self.vb));
                        }
                    }
                }
                Stmt::HostLoad { .. } => {}
                Stmt::Loop { start, end, body } => {
                    // Everything a nested loop reads is re-read on every
                    // iteration of *this* loop.
                    if *end > *start {
                        read_hulls(body, *end - *start, self.vb, false, &mut cands);
                    }
                }
            }
        }
        let mut inv = IntervalSet::default();
        for (lo, hi) in cands {
            if !overlaps(lo, hi, &writes) {
                inv.insert(lo, hi);
            }
        }
        let total = inv.total();
        let cap = self.cfg.vima.cache_bytes as u64;
        if total > cap {
            self.diag(
                lint::REDUNDANT_RELOAD,
                Severity::Info,
                span,
                format!(
                    "loop re-reads {total} B of loop-invariant data per iteration, more than \
                     the {cap} B VIMA cache: hoist or tile to avoid re-loading from DRAM"
                ),
            );
        }
    }

    /// Sampled iterations whose source cube differs from the destination
    /// cube (uses the fabric's real address→cube hash).
    fn cube_ping_pong(&mut self, srcs: &[Operand], dst: Option<&Operand>, iters: u64, span: Span) {
        let cubes = self.cfg.mem.num_cubes;
        let Some(d) = dst else {
            return;
        };
        if cubes < 2 || srcs.is_empty() {
            return;
        }
        let shard = self.cfg.mem.cube_shard_bytes;
        let samples = iters.min(64);
        let mut crossing = 0u64;
        for i in 0..samples {
            let dc = cube_index(d.base + i * d.stride, cubes, shard);
            if srcs.iter().any(|o| cube_index(o.base + i * o.stride, cubes, shard) != dc) {
                crossing += 1;
            }
        }
        if 2 * crossing > samples {
            self.diag(
                lint::CUBE_PING_PONG,
                Severity::Warning,
                span,
                format!(
                    "{crossing} of {samples} sampled iterations gather a source vector from a \
                     different cube than the destination ({cubes}-cube fabric): operands \
                     ping-pong across cube links"
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_set_merges_on_touch() {
        let mut s = IntervalSet::default();
        s.insert(0, 10);
        s.insert(20, 30);
        assert_eq!(s.v, vec![(0, 10), (20, 30)]);
        s.insert(10, 20);
        assert_eq!(s.v, vec![(0, 30)]);
        assert!(s.covers(5, 25));
        assert!(!s.covers(5, 31));
        assert_eq!(s.total(), 30);
    }

    #[test]
    fn sparse_pattern_instance_coverage() {
        // 4 instances of 8 bytes, 32 apart: [100,108) [132,140) ...
        let p = Pat { base: 100, stride: 32, count: 4, extent: 8 };
        assert!(!p.dense());
        assert!(p.instance_covers(132, 140));
        assert!(p.instance_covers(134, 136));
        assert!(!p.instance_covers(140, 148));
        assert!(!p.instance_covers(96, 104));
        assert_eq!(p.hull(), (100, 204));
    }

    #[test]
    fn dense_walk_is_dense() {
        let p = Pat { base: 0, stride: 8192, count: 16, extent: 8192 };
        assert!(p.dense());
        assert_eq!(p.hull(), (0, 16 * 8192));
    }
}
