//! Symbolic evaluation of [`VimaProgram`] backend lowerings.
//!
//! The verifier ([`super::verify`]) and the static cost model
//! ([`super::cost`]) both need to reason about *what a lowering does*
//! without materializing its event stream. This module walks the statement
//! tree once per backend and summarizes every lowered instruction as a set
//! of **affine access patterns** (`base + i*stride` polytopes over the
//! enclosing loop's iteration space, each instance touching a contiguous
//! byte run), plus the two ordering facts that distinguish the backends:
//!
//! * **intra-instruction order** — VIMA fetches every source vector into
//!   the vector cache before the FU writes the destination
//!   ([`IntraOrder::ReadAllThenWrite`]); the honest AVX lowering walks the
//!   vector in 64 B blocks, loading and storing each block before moving
//!   to the next ([`IntraOrder::Chunked`]);
//! * **reduction shape** — VIMA folds `Dot`/`RedSum` in a lane-parallel
//!   binary tree ([`ReductionShape::LaneTree`]), AVX folds sequentially in
//!   chunk order ([`ReductionShape::SequentialChunks`]).
//!
//! The summaries mirror [`crate::intrinsics`]'s `ProgramChunker::emit` /
//! `emit_avx` shapes statement-for-statement (the two lowerings share one
//! `Stmt` tree, so summaries pair 1:1 by statement index), which is what
//! lets [`super::verify`] *prove* dataflow equivalence instead of assuming
//! it. Formal rules: DESIGN.md §15.

use crate::analyze::{Span, SourceInfo, SpanNode};
use crate::intrinsics::{Operand, Stmt, VimaProgram};
use crate::isa::{VDtype, VimaOp};
use crate::trace::Backend;

/// AVX chunk granularity (one ZMM register), in bytes.
pub const AVX_CHUNK: u64 = 64;

/// An affine access polytope: `count` instances at `base + i*stride`
/// (`i` in `0..count`), each touching `len` contiguous bytes, the whole
/// pattern repeated `repeats` times by enclosing outer loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessPattern {
    pub base: u64,
    pub stride: u64,
    pub count: u64,
    pub len: u64,
    pub repeats: u64,
}

impl AccessPattern {
    fn of(o: Operand, iters: u64, len: u64, repeats: u64) -> Self {
        AccessPattern { base: o.base, stride: o.stride, count: iters.max(1), len, repeats }
    }

    /// Address of instance `i`.
    pub fn at(&self, i: u64) -> u64 {
        self.base + i * self.stride
    }

    /// Convex hull `[lo, hi)` over every instance.
    pub fn hull(&self) -> (u64, u64) {
        (self.base, self.at(self.count - 1) + self.len)
    }

    /// Total bytes touched, counting revisits (traffic, not footprint).
    pub fn bytes(&self) -> u64 {
        self.count * self.len * self.repeats
    }

    /// Does any instance of `self` overlap any instance of `other`?
    /// (Convex-hull test — sound for the divergence proof, which refines
    /// it with the exact affine difference before firing.)
    pub fn hull_overlaps(&self, other: &AccessPattern) -> bool {
        let (al, ah) = self.hull();
        let (bl, bh) = other.hull();
        al < bh && bl < ah
    }
}

/// How a backend orders reads and writes *within* one lowered instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntraOrder {
    /// Every source byte is observed before any destination byte is
    /// written (VIMA: sources are fetched whole into the vcache, the FU
    /// computes, the result vector is inserted afterwards).
    ReadAllThenWrite,
    /// The lowering advances through the vector in `chunk`-byte blocks,
    /// reading then writing each block before touching the next (the
    /// honest AVX 64 B load/compute/store loop).
    Chunked { chunk: u64 },
}

/// The combine tree a backend lowers a reduction (`Dot`/`RedSum`) to.
/// Distinct shapes give bit-different results for non-associative
/// (floating-point) element types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReductionShape {
    /// Not a reduction.
    None,
    /// Lane-parallel binary tree over the whole vector (VIMA FU array).
    LaneTree,
    /// Sequential left fold in ascending chunk order (AVX).
    SequentialChunks { chunk: u64 },
}

/// Summary of one lowered vector instruction on one backend: its op-DAG
/// node (op/dtype plus reduction shape) and its access polytopes.
#[derive(Debug, Clone)]
pub struct InstrSummary {
    /// Flattened statement index (identical across backends — both
    /// lowerings walk one `Stmt` tree).
    pub stmt: usize,
    pub span: Span,
    pub op: VimaOp,
    pub dtype: VDtype,
    /// One read polytope per source operand (in operand order, duplicates
    /// preserved — the verifier needs the full arity).
    pub reads: Vec<AccessPattern>,
    /// Destination polytope, when the op writes a vector.
    pub write: Option<AccessPattern>,
    /// Bytes of the logical `vector_bytes`-sized vector this lowering
    /// actually covers per operand instance (AVX truncates to whole
    /// chunks; VIMA always covers the full vector).
    pub covered: u64,
    pub order: IntraOrder,
    pub reduction: ReductionShape,
    /// Lowered trace events per operand instance (used by the cost model).
    pub events_per_instance: u64,
}

/// One backend's symbolic summary of a whole program: the instruction
/// op-DAG nodes plus its def→use edges (reads that can observe an earlier
/// write, by hull intersection).
#[derive(Debug, Clone)]
pub struct BackendSummary {
    pub backend: Backend,
    pub vector_bytes: u64,
    pub instrs: Vec<InstrSummary>,
    /// `(producer, consumer)` pairs of indices into `instrs`: consumer has
    /// a read polytope hull-overlapping producer's write polytope.
    pub dag_edges: Vec<(usize, usize)>,
    /// Total lowered trace events (host-load and loop-control µops
    /// included).
    pub total_events: u64,
}

/// Walk the statement tree and produce `backend`'s symbolic summary.
/// Program workloads lower to `Avx` or `Vima` only; `Hive` (a paper-kernel
/// backend with no program lowering) summarizes like `Vima`.
pub fn summarize(p: &VimaProgram, src: &SourceInfo, backend: Backend) -> BackendSummary {
    let vb = p.vector_bytes as u64;
    let mut s = BackendSummary {
        backend,
        vector_bytes: vb,
        instrs: Vec::new(),
        dag_edges: Vec::new(),
        total_events: 0,
    };
    let mut stmt_counter = 0usize;
    walk(p, &p.stmts, src.spans.as_slice(), 1, 1, backend, &mut stmt_counter, &mut s);
    for c in 0..s.instrs.len() {
        for pr in 0..c {
            let Some(w) = s.instrs[pr].write else { continue };
            if s.instrs[c].reads.iter().any(|r| r.hull_overlaps(&w)) {
                s.dag_edges.push((pr, c));
            }
        }
    }
    s
}

/// Per-instance lowered event count for one `Instr` statement.
fn instr_events(p: &VimaProgram, backend: Backend, op: VimaOp, srcs: usize, has_dst: bool) -> u64 {
    match backend {
        Backend::Vima | Backend::Hive => {
            // One VimaInstr, plus the scalar bump+branch pair when the
            // host loop is modeled.
            if p.loop_overhead {
                3
            } else {
                1
            }
        }
        Backend::Avx => {
            let chunks = (p.vector_bytes as u64 / AVX_CHUNK).max(1);
            let compute = if matches!(op, VimaOp::Mov | VimaOp::Bcast) { 0 } else { 1 };
            let store = if has_dst { 1 } else { 0 };
            // loads + compute + store + loop_ctl (bump + branch) per chunk.
            chunks * (srcs.min(3) as u64 + compute + store + 2)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn walk(
    p: &VimaProgram,
    stmts: &[Stmt],
    spans: &[SpanNode],
    iters: u64,
    repeats: u64,
    backend: Backend,
    stmt_counter: &mut usize,
    out: &mut BackendSummary,
) {
    let vb = p.vector_bytes as u64;
    for (i, stmt) in stmts.iter().enumerate() {
        let node = spans.get(i);
        let span = node.map(SpanNode::span).unwrap_or(Span::UNKNOWN);
        let stmt_id = *stmt_counter;
        *stmt_counter += 1;
        match stmt {
            Stmt::Instr { op, dtype, srcs, dst } => {
                let avx = backend == Backend::Avx;
                let covered = if avx { (vb / AVX_CHUNK).max(1) * AVX_CHUNK } else { vb };
                let order = if avx {
                    IntraOrder::Chunked { chunk: AVX_CHUNK }
                } else {
                    IntraOrder::ReadAllThenWrite
                };
                let reduction = match op {
                    VimaOp::Dot | VimaOp::RedSum => {
                        if avx {
                            ReductionShape::SequentialChunks { chunk: AVX_CHUNK }
                        } else {
                            ReductionShape::LaneTree
                        }
                    }
                    _ => ReductionShape::None,
                };
                let events = instr_events(p, backend, *op, srcs.len(), dst.is_some());
                out.total_events += events * iters.max(1) * repeats;
                out.instrs.push(InstrSummary {
                    stmt: stmt_id,
                    span,
                    op: *op,
                    dtype: *dtype,
                    reads: srcs
                        .iter()
                        .map(|o| AccessPattern::of(*o, iters, covered, repeats))
                        .collect(),
                    write: dst.map(|o| AccessPattern::of(o, iters, covered, repeats)),
                    covered,
                    order,
                    reduction,
                    events_per_instance: events,
                });
            }
            Stmt::HostLoad { .. } => {
                out.total_events += iters.max(1) * repeats;
            }
            Stmt::Loop { start, end, body } => {
                let n = end.saturating_sub(*start);
                let inner = match node {
                    Some(SpanNode::Loop(_, b)) => b.as_slice(),
                    _ => &[],
                };
                // Operand strides resolve against the innermost loop, so
                // an outer loop multiplies repeats instead of widening the
                // polytope.
                walk(p, body, inner, n, repeats * iters.max(1), backend, stmt_counter, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn saxpyish() -> VimaProgram {
        crate::workload::programs::saxpy(8)
    }

    #[test]
    fn backends_summarize_the_same_statements() {
        let p = saxpyish();
        let v = summarize(&p, &SourceInfo::default(), Backend::Vima);
        let a = summarize(&p, &SourceInfo::default(), Backend::Avx);
        assert_eq!(v.instrs.len(), a.instrs.len());
        for (iv, ia) in v.instrs.iter().zip(&a.instrs) {
            assert_eq!(iv.stmt, ia.stmt);
            assert_eq!(iv.op, ia.op);
            assert_eq!(iv.reads.len(), ia.reads.len());
        }
        assert_eq!(v.total_events, p.events());
    }

    #[test]
    fn avx_order_is_chunked_and_vima_reads_first() {
        let p = saxpyish();
        let v = summarize(&p, &SourceInfo::default(), Backend::Vima);
        let a = summarize(&p, &SourceInfo::default(), Backend::Avx);
        assert!(v.instrs.iter().all(|i| i.order == IntraOrder::ReadAllThenWrite));
        assert!(a.instrs.iter().all(|i| i.order == IntraOrder::Chunked { chunk: 64 }));
    }

    #[test]
    fn reduction_shapes_differ_by_backend() {
        let p = crate::workload::programs::softmax(4);
        let v = summarize(&p, &SourceInfo::default(), Backend::Vima);
        let a = summarize(&p, &SourceInfo::default(), Backend::Avx);
        let vd = v.instrs.iter().find(|i| i.op == VimaOp::Dot).unwrap();
        let ad = a.instrs.iter().find(|i| i.op == VimaOp::Dot).unwrap();
        assert_eq!(vd.reduction, ReductionShape::LaneTree);
        assert_eq!(ad.reduction, ReductionShape::SequentialChunks { chunk: 64 });
    }

    #[test]
    fn dag_edges_capture_def_use() {
        // set -> a; add a a -> b : edge (0, 1).
        let mut p = VimaProgram::new();
        let a = p.alloc(8192);
        let b = p.alloc(8192);
        p.vim2k_sets(a);
        p.vim2k_adds(a, a, b);
        let s = summarize(&p, &SourceInfo::default(), Backend::Vima);
        assert_eq!(s.dag_edges, vec![(0, 1)]);
    }

    #[test]
    fn avx_coverage_truncates_to_chunks() {
        let mut p = VimaProgram::new().with_vector_bytes(96);
        let a = p.alloc(96);
        p.vim2k_sets(a);
        let s = summarize(&p, &SourceInfo::default(), Backend::Avx);
        assert_eq!(s.instrs[0].covered, 64);
        let sv = summarize(&p, &SourceInfo::default(), Backend::Vima);
        assert_eq!(sv.instrs[0].covered, 96);
    }
}
