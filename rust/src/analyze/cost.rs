//! Static analytic cost model for VIMA programs.
//!
//! `vima-sim check --predict` answers "what will this program cost on this
//! machine?" *without running the detailed simulator*: it replays the
//! statement tree once per loop iteration against **real machine state
//! replicas** — the same [`VCache`] type the device uses (so hit/miss/
//! eviction streams are exact, not estimated), the same
//! [`cube_index`](crate::fabric::cube_index) hash the fabric uses (so the
//! per-cube instruction distribution is exact) — and prices each event
//! with closed-form latency terms derived from the configured geometry:
//!
//! * **FU time** — the device's own duration formula (tag + ported
//!   transfer beats + residual pipeline depth + beat drain), reproduced
//!   exactly from [`VimaConfig`];
//! * **DRAM time** — a vector miss splits into 64 B sub-requests striped
//!   across the cube's vaults by the address hash; the model charges the
//!   closed-row access latency once plus the per-vault data-bus
//!   serialization `ceil(lines / vaults) * burst`, and tracks a per-cube
//!   bus clock so posted write-backs push later fetches the way the
//!   per-vault `next_free` clocks do in [`crate::mem3d`];
//! * **host time** — dispatch latency, the `stop_and_go` serialization
//!   gap, the scalar loop-overhead µop pair, and an analytic LLC-miss
//!   path (L1+L2+LLC lookup plus one uncontended link+DRAM round trip)
//!   for `host_load` synchronization points.
//!
//! What the model deliberately does **not** track — per-bank conflict
//! queueing inside a vault, host-cache flush settling on dispatch, and
//! host-core pipeline overlap — is exactly where predictions legitimately
//! diverge from the simulator; the `bench --predict` cross-check harness
//! measures that divergence per kernel and records it in BENCH_PR10.json.
//! Formulas and the measured error bound: DESIGN.md §15.

use crate::analyze::symbolic::{self, AccessPattern};
use crate::analyze::SourceInfo;
use crate::config::SystemConfig;
use crate::fabric::cube_index;
use crate::intrinsics::{Stmt, VimaProgram};
use crate::isa::{VDtype, VimaFuKind, VimaOp};
use crate::trace::Backend;
use crate::vima::VCache;

/// Predicted cost of one backend lowering.
#[derive(Debug, Clone, Default)]
pub struct BackendCost {
    /// Logical vector statements executed (loop-expanded).
    pub instructions: u64,
    /// Lowered trace events (host µops included).
    pub events: u64,
    /// Architectural bytes read / written by vector operands.
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// VIMA vector-cache behaviour (exact LRU replay; zero for AVX).
    pub vcache_hits: u64,
    pub vcache_misses: u64,
    pub writeback_vectors: u64,
    /// Predicted DRAM traffic under the VIMA lowering (zero for AVX: its
    /// traffic depends on the host cache hierarchy the model does not
    /// replay).
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    /// Host-side synchronization loads (`host_load`).
    pub host_loads: u64,
    /// Vector instructions homed per cube by the fabric's address hash.
    pub cube_instructions: Vec<u64>,
    /// Source operands fetched across cubes (owner != home).
    pub cross_cube_fetches: u64,
    /// Predicted end-to-end cycles (VIMA lowering only).
    pub predicted_cycles: Option<u64>,
}

/// The full `--predict` result for one program.
#[derive(Debug, Clone)]
pub struct CostReport {
    pub vector_bytes: u32,
    pub vima: BackendCost,
    pub avx: BackendCost,
}

impl CostReport {
    /// Hand-rolled JSON object (house style: see [`crate::service::jsonl`]).
    pub fn to_json(&self) -> String {
        fn backend(b: &BackendCost) -> String {
            let cubes: Vec<String> =
                b.cube_instructions.iter().map(u64::to_string).collect();
            let mut s = format!(
                "{{\"instructions\": {}, \"events\": {}, \"bytes_read\": {}, \
                 \"bytes_written\": {}",
                b.instructions, b.events, b.bytes_read, b.bytes_written
            );
            if b.predicted_cycles.is_some() {
                s.push_str(&format!(
                    ", \"vcache_hits\": {}, \"vcache_misses\": {}, \
                     \"writeback_vectors\": {}, \"dram_read_bytes\": {}, \
                     \"dram_write_bytes\": {}, \"host_loads\": {}, \
                     \"cube_instructions\": [{}], \"cross_cube_fetches\": {}",
                    b.vcache_hits,
                    b.vcache_misses,
                    b.writeback_vectors,
                    b.dram_read_bytes,
                    b.dram_write_bytes,
                    b.host_loads,
                    cubes.join(", "),
                    b.cross_cube_fetches
                ));
            }
            if let Some(c) = b.predicted_cycles {
                s.push_str(&format!(", \"predicted_cycles\": {c}"));
            }
            s.push('}');
            s
        }
        format!(
            "{{\"vector_bytes\": {}, \"vima\": {}, \"avx\": {}}}",
            self.vector_bytes,
            backend(&self.vima),
            backend(&self.avx)
        )
    }

    /// Multi-line human rendering for `check --predict` text mode.
    pub fn render(&self, file: &str) -> String {
        let v = &self.vima;
        let a = &self.avx;
        let mut out = format!(
            "{file}: predict: vima {} instr / {} events, avx {} events\n\
             {file}: predict: vcache {} hit / {} miss / {} writeback vectors\n\
             {file}: predict: dram {} B read, {} B written, {} host load(s)\n",
            v.instructions,
            v.events,
            a.events,
            v.vcache_hits,
            v.vcache_misses,
            v.writeback_vectors,
            v.dram_read_bytes,
            v.dram_write_bytes,
            v.host_loads,
        );
        if v.cube_instructions.len() > 1 {
            let cubes: Vec<String> =
                v.cube_instructions.iter().map(u64::to_string).collect();
            out.push_str(&format!(
                "{file}: predict: cube homes [{}], {} cross-cube fetch(es)\n",
                cubes.join(", "),
                v.cross_cube_fetches
            ));
        }
        if let Some(c) = v.predicted_cycles {
            out.push_str(&format!("{file}: predict: {c} cycles (vima backend)\n"));
        }
        out
    }
}

/// Analytic latency terms, all in CPU cycles, derived once from the
/// configured geometry.
struct Lat {
    inst: u64,
    tag: u64,
    /// Vault command issue slot.
    cmd: u64,
    /// Closed-row activate + column read.
    access: u64,
    /// One 64 B line over a vault's internal data bus.
    burst: u64,
    /// Posted-write completion (activate + write column).
    write: u64,
    /// Host LLC-miss round trip for a `host_load` (cache lookups + link +
    /// DRAM + link).
    host_load: u64,
    /// Host-side scalar loop µops (pointer bump + fused compare-branch).
    loop_ctl: u64,
    vaults: u64,
    dispatch_gap: u64,
    stop_and_go: bool,
}

impl Lat {
    fn of(cfg: &SystemConfig) -> Lat {
        let ghz = cfg.core.freq_ghz;
        let m = &cfg.mem;
        let link = m.link_cycles_per_line(ghz).ceil() as u64;
        Lat {
            inst: m.inst_lat_cycles,
            tag: cfg.vima.to_cpu_cycles(cfg.vima.cache_tag_lat, ghz),
            cmd: m.dram_to_cpu(1, ghz).max(1),
            access: m.dram_to_cpu(m.access_dram_cycles(), ghz),
            burst: m.dram_to_cpu(64 / 8, ghz),
            write: m.dram_to_cpu(m.t_cwd + m.t_rcd, ghz),
            host_load: cfg.l1d.latency
                + cfg.l2.latency
                + cfg.llc.latency
                + m.dram_to_cpu(1, ghz).max(1)
                + m.dram_to_cpu(m.access_dram_cycles(), ghz)
                + m.dram_to_cpu(64 / 8, ghz)
                + 2 * link.max(1),
            loop_ctl: 2,
            vaults: m.vaults as u64,
            dispatch_gap: cfg.vima.dispatch_gap_cycles,
            stop_and_go: cfg.vima.stop_and_go,
        }
    }

    /// Per-vault serialization of `lines` 64 B bursts striped across the
    /// vaults (the hash spreads consecutive lines round-robin).
    fn stripe(&self, lines: u64) -> u64 {
        (lines * self.burst).div_ceil(self.vaults)
    }
}

/// One cube's device replica: the real vector cache plus FU and data-bus
/// ready clocks.
struct CubeState {
    vcache: VCache,
    fu_free: [u64; 6],
    bus_free: u64,
}

struct Model<'a> {
    cfg: &'a SystemConfig,
    lat: Lat,
    cubes: Vec<CubeState>,
    t: u64,
    cost: BackendCost,
}

impl Model<'_> {
    fn fu_index(dtype: VDtype, kind: VimaFuKind) -> usize {
        let base = if dtype.is_float() { 3 } else { 0 };
        base + match kind {
            VimaFuKind::Alu => 0,
            VimaFuKind::Mul => 1,
            VimaFuKind::Div => 2,
        }
    }

    fn fu_total_lat(&self, dtype: VDtype, kind: VimaFuKind) -> u64 {
        let v = &self.cfg.vima;
        match (dtype.is_float(), kind) {
            (false, VimaFuKind::Alu) => v.int_alu_lat,
            (false, VimaFuKind::Mul) => v.int_mul_lat,
            (false, VimaFuKind::Div) => v.int_div_lat,
            (true, VimaFuKind::Alu) => v.fp_alu_lat,
            (true, VimaFuKind::Mul) => v.fp_mul_lat,
            (true, VimaFuKind::Div) => v.fp_div_lat,
        }
    }

    fn home_of(&self, srcs: &[u64], dst: Option<u64>) -> usize {
        let anchor = dst.or_else(|| srcs.first().copied()).unwrap_or(0);
        cube_index(anchor, self.cubes.len(), self.cfg.mem.cube_shard_bytes)
    }

    /// Posted write-back of `bytes` at `at`: occupies the cube's data bus.
    fn writeback(&mut self, cube: usize, bytes: u32, at: u64) {
        let lines = u64::from(bytes).div_ceil(64);
        self.cost.writeback_vectors += 1;
        self.cost.dram_write_bytes += lines * 64;
        let serial = self.lat.stripe(lines);
        let c = &mut self.cubes[cube];
        c.bus_free = c.bus_free.max(at) + serial;
    }

    /// Mirror of `VimaDevice::fetch_vector` with the analytic DRAM terms.
    fn fetch(&mut self, cube: usize, base: u64, bytes: u32, at: u64) -> u64 {
        if self.cubes[cube].vcache.lookup(base) {
            self.cost.vcache_hits += 1;
            return at + self.lat.tag;
        }
        self.cost.vcache_misses += 1;
        let lines = u64::from(bytes).div_ceil(64);
        self.cost.dram_read_bytes += lines * 64;
        let serial = self.lat.stripe(lines);
        let start = self.cubes[cube].bus_free.max(at);
        let ready = start + self.lat.cmd + self.lat.access + serial;
        self.cubes[cube].bus_free = start + serial;
        if let Some((_victim, vbytes)) =
            self.cubes[cube].vcache.insert_sized(base, false, bytes)
        {
            self.writeback(cube, vbytes, ready);
        }
        ready
    }

    /// Mirror of `VimaDevice::execute` (plus the fabric's coherence walk
    /// when more than one cube is configured). Returns the completion
    /// signal time at the CPU.
    fn execute(
        &mut self,
        op: VimaOp,
        dtype: VDtype,
        srcs: &[u64],
        dst: Option<u64>,
        vb: u32,
        dispatch: u64,
    ) -> u64 {
        let home = self.home_of(srcs, dst);
        self.cost.cube_instructions[home] += 1;
        let arrive = dispatch + self.lat.inst;

        let mut unique: Vec<u64> = srcs.to_vec();
        unique.sort_unstable();
        unique.dedup();

        // Cross-cube gathers: the owner flushes its dirty copy first.
        if self.cubes.len() > 1 {
            for &s in &unique {
                let owner = cube_index(s, self.cubes.len(), self.cfg.mem.cube_shard_bytes);
                if owner != home {
                    self.cost.cross_cube_fetches += 1;
                    if let Some(bytes) = self.cubes[owner].vcache.clean(s) {
                        self.writeback(owner, bytes, arrive);
                    }
                }
            }
        }

        let mut operands_ready = arrive;
        for &s in &unique {
            let r = self.fetch(home, s, vb, arrive);
            operands_ready = operands_ready.max(r);
        }

        let kind = op.fu_kind();
        let elems = u64::from(vb) / dtype.bytes() as u64;
        let beats = elems.div_ceil(self.cfg.vima.lanes as u64).max(1);
        let port_rounds =
            (op.num_srcs().max(1) as u64).div_ceil(self.cfg.vima.cache_ports as u64);
        let transfer = beats * port_rounds;
        let depth = self.fu_total_lat(dtype, kind).saturating_sub(beats);
        let duration_vima =
            self.cfg.vima.cache_tag_lat + transfer + depth + self.cfg.vima.cache_beat_lat;
        let duration = self.cfg.vima.to_cpu_cycles(duration_vima, self.cfg.core.freq_ghz);

        let fu = Self::fu_index(dtype, kind);
        let start = operands_ready.max(self.cubes[home].fu_free[fu]);
        let done = start + duration;
        self.cubes[home].fu_free[fu] = done;

        if op.writes_vector() {
            if let Some(d) = dst {
                if self.cubes.len() > 1 {
                    for i in 0..self.cubes.len() {
                        if i != home {
                            self.cubes[i].vcache.invalidate(d);
                        }
                    }
                }
                if let Some((_victim, vbytes)) =
                    self.cubes[home].vcache.insert_sized(d, true, vb)
                {
                    self.writeback(home, vbytes, done);
                }
            }
        }
        done + self.lat.inst
    }

    /// Replay one statement list; `iter` is the innermost loop induction
    /// value (operand strides resolve against it).
    fn block(&mut self, p: &VimaProgram, stmts: &[Stmt], iter: u64) {
        for stmt in stmts {
            match stmt {
                Stmt::Instr { op, dtype, srcs, dst } => {
                    self.cost.instructions += 1;
                    let rs: Vec<u64> = srcs.iter().map(|o| o.at(iter)).collect();
                    let rd = dst.map(|o| o.at(iter));
                    let ret = self.execute(*op, *dtype, &rs, rd, p.vector_bytes, self.t);
                    if self.lat.stop_and_go {
                        // The host serializes to `done + dispatch_gap`
                        // (`ret` is `done + inst_lat`).
                        let done = ret.saturating_sub(self.lat.inst);
                        self.t = ret.max(done + self.lat.dispatch_gap);
                    }
                    if p.loop_overhead {
                        self.t += self.lat.loop_ctl;
                    }
                }
                Stmt::HostLoad { addr, bytes } => {
                    let _ = addr.at(iter);
                    self.cost.host_loads += 1;
                    self.t += self.lat.host_load + u64::from(*bytes) / 8;
                }
                Stmt::Loop { start, end, body } => {
                    for i in *start..*end {
                        self.block(p, body, i);
                    }
                }
            }
        }
    }

    /// End-of-run drain: post every dirty vector and wait out the bus.
    fn drain(&mut self) -> u64 {
        let mut end = self.t;
        for c in 0..self.cubes.len() {
            for (base, bytes) in self.cubes[c].vcache.dirty_lines() {
                self.cubes[c].vcache.clean(base);
                self.writeback(c, bytes, end);
            }
            end = end.max(self.cubes[c].bus_free + self.lat.write);
            for f in self.cubes[c].fu_free {
                end = end.max(f);
            }
        }
        end
    }
}

/// Predict the cost of `p` on the machine described by `cfg`.
///
/// Counts (instructions, events, architectural bytes) are exact by
/// construction; the VIMA vcache stream is exact (same replacement code);
/// predicted cycles are analytic and model a single host thread — the
/// cross-check in `bench --predict` quantifies the residual error.
pub fn predict(p: &VimaProgram, cfg: &SystemConfig) -> CostReport {
    let src = SourceInfo::default();
    let vsum = symbolic::summarize(p, &src, Backend::Vima);
    let asum = symbolic::summarize(p, &src, Backend::Avx);
    let arch = |patterns: &[AccessPattern]| patterns.iter().map(AccessPattern::bytes).sum::<u64>();

    let num_cubes = cfg.mem.num_cubes.max(1);
    let lat = Lat::of(cfg);
    let mut model = Model {
        cfg,
        lat,
        cubes: (0..num_cubes)
            .map(|_| CubeState {
                vcache: VCache::new(cfg.vima.cache_lines(), cfg.vima.vector_bytes),
                fu_free: [0; 6],
                bus_free: 0,
            })
            .collect(),
        t: 0,
        cost: BackendCost {
            cube_instructions: vec![0; num_cubes],
            ..BackendCost::default()
        },
    };
    model.block(p, &p.stmts, 0);
    let end = model.drain();

    let mut vima = model.cost;
    vima.events = vsum.total_events;
    vima.bytes_read = vsum.instrs.iter().map(|i| arch(&i.reads)).sum();
    vima.bytes_written =
        vsum.instrs.iter().filter_map(|i| i.write.as_ref()).map(AccessPattern::bytes).sum();
    vima.predicted_cycles = Some(end);

    let avx = BackendCost {
        instructions: vima.instructions,
        events: asum.total_events,
        bytes_read: asum.instrs.iter().map(|i| arch(&i.reads)).sum(),
        bytes_written: asum
            .instrs
            .iter()
            .filter_map(|i| i.write.as_ref())
            .map(AccessPattern::bytes)
            .sum(),
        cube_instructions: Vec::new(),
        ..BackendCost::default()
    };

    CostReport { vector_bytes: p.vector_bytes, vima, avx }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saxpy_counts_are_exact() {
        let p = crate::workload::programs::saxpy(16);
        let cfg = SystemConfig::default();
        let r = predict(&p, &cfg);
        // 1 set + 16 fmadds.
        assert_eq!(r.vima.instructions, 17);
        assert_eq!(r.vima.events, p.events());
        assert!(r.vima.predicted_cycles.unwrap() > 0);
        // Streaming x+y misses, alpha hits after its first touch.
        assert!(r.vima.vcache_misses > r.vima.vcache_hits);
        assert!(r.avx.events > r.vima.events);
        assert!(r.avx.predicted_cycles.is_none());
    }

    #[test]
    fn dram_traffic_scales_with_footprint() {
        let cfg = SystemConfig::default();
        let small = predict(&crate::workload::programs::saxpy(8), &cfg);
        let big = predict(&crate::workload::programs::saxpy(64), &cfg);
        assert!(big.vima.dram_read_bytes > small.vima.dram_read_bytes);
        assert!(big.vima.predicted_cycles > small.vima.predicted_cycles);
    }

    #[test]
    fn cube_histogram_spreads_homes() {
        let mut cfg = SystemConfig::default();
        cfg.mem.num_cubes = 4;
        let r = predict(&crate::workload::programs::saxpy(64), &cfg);
        assert_eq!(r.vima.cube_instructions.len(), 4);
        assert_eq!(
            r.vima.cube_instructions.iter().sum::<u64>(),
            r.vima.instructions
        );
        assert!(
            r.vima.cube_instructions.iter().filter(|&&c| c > 0).count() > 1,
            "hash should spread homes: {:?}",
            r.vima.cube_instructions
        );
    }

    #[test]
    fn json_is_balanced() {
        let r = predict(&crate::workload::programs::softmax(4), &SystemConfig::default());
        let j = r.to_json();
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(j.contains("\"predicted_cycles\""));
        assert!(j.contains("\"host_loads\""));
    }
}
