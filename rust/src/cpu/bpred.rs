//! Two-level GAs branch predictor + set-associative BTB (Table I row 1:
//! "Branch predictor: Two-level GAs. 4096 entry BTB").
//!
//! GAs: a global history register indexes per-address pattern history tables
//! of 2-bit saturating counters (history XOR-folded with the PC — gshare-style
//! address mixing, the standard GAs realization).

use crate::config::CoreConfig;

pub struct BranchPredictor {
    history: u64,
    history_bits: usize,
    /// 2-bit saturating counters.
    pht: Vec<u8>,
    /// BTB tags (direct-mapped within `ways` per set).
    btb_tags: Vec<u64>,
    btb_sets: usize,
    btb_ways: usize,
    btb_tick: u64,
    btb_stamp: Vec<u64>,
    pub lookups: u64,
    pub mispredicts: u64,
    pub btb_misses: u64,
}

impl BranchPredictor {
    pub fn new(cfg: &CoreConfig) -> Self {
        let pht_size = 1usize << cfg.bpred_history_bits;
        let sets = cfg.btb_entries / cfg.btb_ways;
        assert!(sets.is_power_of_two());
        Self {
            history: 0,
            history_bits: cfg.bpred_history_bits,
            pht: vec![2; pht_size], // weakly taken
            btb_tags: vec![u64::MAX; cfg.btb_entries],
            btb_sets: sets,
            btb_ways: cfg.btb_ways,
            btb_tick: 0,
            btb_stamp: vec![0; cfg.btb_entries],
            lookups: 0,
            mispredicts: 0,
            btb_misses: 0,
        }
    }

    #[inline]
    fn pht_index(&self, pc: u64) -> usize {
        let mask = (1usize << self.history_bits) - 1;
        ((self.history as usize) ^ (pc >> 2) as usize) & mask
    }

    /// Predict + update for one dynamic branch. Returns `true` if the
    /// prediction (direction AND target availability) was correct.
    pub fn predict_and_update(&mut self, pc: u64, taken: bool) -> bool {
        self.lookups += 1;
        let idx = self.pht_index(pc);
        let predicted_taken = self.pht[idx] >= 2;

        // Direction update (2-bit saturating).
        if taken {
            self.pht[idx] = (self.pht[idx] + 1).min(3);
        } else {
            self.pht[idx] = self.pht[idx].saturating_sub(1);
        }
        self.history = ((self.history << 1) | taken as u64) & ((1 << self.history_bits) - 1);

        // Target lookup: a taken branch with no BTB entry is a misfetch even
        // if the direction was right.
        let btb_ok = if taken { self.btb_touch(pc) } else { true };

        let correct = predicted_taken == taken && btb_ok;
        if !correct {
            self.mispredicts += 1;
        }
        correct
    }

    /// Probe/refresh the BTB entry for `pc`, allocating on miss.
    /// Returns whether it was present.
    fn btb_touch(&mut self, pc: u64) -> bool {
        let set = ((pc >> 2) as usize) & (self.btb_sets - 1);
        let base = set * self.btb_ways;
        self.btb_tick += 1;
        for w in 0..self.btb_ways {
            if self.btb_tags[base + w] == pc {
                self.btb_stamp[base + w] = self.btb_tick;
                return true;
            }
        }
        self.btb_misses += 1;
        // Allocate LRU way.
        let mut victim = 0;
        let mut best = u64::MAX;
        for w in 0..self.btb_ways {
            if self.btb_stamp[base + w] < best {
                best = self.btb_stamp[base + w];
                victim = w;
            }
        }
        self.btb_tags[base + victim] = pc;
        self.btb_stamp[base + victim] = self.btb_tick;
        false
    }

    /// Fold the complete predictor state (history, PHT counters, BTB tags
    /// and stamps) into `h` (sampled-mode state-parity digests; see
    /// `Machine::state_digest`).
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        self.history.hash(h);
        self.pht.hash(h);
        self.btb_tick.hash(h);
        self.btb_tags.hash(h);
        self.btb_stamp.hash(h);
    }

    pub fn reset(&mut self) {
        self.history = 0;
        self.pht.fill(2);
        self.btb_tags.fill(u64::MAX);
        self.btb_stamp.fill(0);
        self.btb_tick = 0;
        self.lookups = 0;
        self.mispredicts = 0;
        self.btb_misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bp() -> BranchPredictor {
        BranchPredictor::new(&CoreConfig::default())
    }

    #[test]
    fn learns_always_taken_loop() {
        let mut p = bp();
        let pc = 0x400;
        // warm up
        for _ in 0..8 {
            p.predict_and_update(pc, true);
        }
        let before = p.mispredicts;
        for _ in 0..100 {
            p.predict_and_update(pc, true);
        }
        assert_eq!(p.mispredicts, before, "steady taken loop must be perfect");
    }

    #[test]
    fn loop_exit_mispredicts_once_per_iteration_set() {
        let mut p = bp();
        let pc = 0x400;
        let mut misses = 0;
        // 10 runs of (15 taken + 1 not-taken) — classic loop pattern.
        for _ in 0..10 {
            for _ in 0..15 {
                if !p.predict_and_update(pc, true) {
                    misses += 1;
                }
            }
            if !p.predict_and_update(pc, false) {
                misses += 1;
            }
        }
        // With 12 bits of history the 16-iteration pattern is learnable;
        // allow warm-up noise only.
        assert!(misses < 40, "too many mispredicts: {misses}");
    }

    #[test]
    fn btb_miss_counts_first_encounter() {
        let mut p = bp();
        p.predict_and_update(0x1000, true);
        let first = p.btb_misses;
        assert!(first >= 1);
        // warm the direction counters so only BTB matters
        for _ in 0..4 {
            p.predict_and_update(0x1000, true);
        }
        let before = p.btb_misses;
        p.predict_and_update(0x1000, true);
        assert_eq!(p.btb_misses, before);
    }

    #[test]
    fn not_taken_branches_skip_btb() {
        let mut p = bp();
        for i in 0..100u64 {
            p.predict_and_update(0x2000 + i * 4, false);
        }
        assert_eq!(p.btb_misses, 0);
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut p = bp();
        p.predict_and_update(0x400, true);
        p.reset();
        assert_eq!(p.lookups, 0);
        assert_eq!(p.mispredicts, 0);
    }
}
