//! Out-of-order core timing model (Table I row 1).
//!
//! A latency-forwarding OoO model: micro-ops are processed in program order,
//! and for each one the model computes *when* it fetches, dispatches, issues,
//! completes and retires, given
//!
//! * front-end bandwidth (issue-width per cycle, one branch per fetch cycle,
//!   misprediction restarts),
//! * ROB occupancy (dispatch waits for the retire of the op `rob_entries`
//!   earlier),
//! * register dependencies (renaming: a table of per-register ready times),
//! * functional-unit counts/latencies (div is unpipelined),
//! * the memory-order buffer (64 read / 36 write windows) and the cache
//!   hierarchy (via [`MemorySystem`]).
//!
//! This captures the first-order behaviour that drives the paper's results —
//! a core that can overlap a limited number of cache misses (MSHR/MOB bound)
//! and issues at most 6 µops/cycle — without per-cycle pipeline simulation.

pub mod bpred;
pub mod tlb;

use crate::cache::MemorySystem;
use crate::config::CoreConfig;
use crate::isa::{FuType, Uop, NO_REG};
use crate::stats::StatsReport;
use bpred::BranchPredictor;
use tlb::Tlb;

#[derive(Debug, Default, Clone)]
pub struct CoreStats {
    pub uops: u64,
    pub loads: u64,
    pub stores: u64,
    pub branches: u64,
    pub mispredicts: u64,
    pub fu_stall_cycles: u64,
    pub mob_stall_cycles: u64,
}

/// Ring of the last N timestamps (ROB / MOB / retire-width windows).
struct Ring {
    buf: Vec<u64>,
    head: usize,
}

impl Ring {
    fn new(n: usize) -> Self {
        Self { buf: vec![0; n.max(1)], head: 0 }
    }

    /// Timestamp stored N slots ago (the constraint), then overwrite with `t`.
    ///
    /// The wrap is a compare, not `% len`: ring sizes come straight from
    /// the config (ROB 168, MOB 64/36, issue width 6) and are generally
    /// *not* powers of two, so a mask cannot replace the modulo without
    /// changing the window the ring models — and the integer division
    /// behind `%` by a runtime value costs ~20+ cycles on a path that runs
    /// two to three times per simulated µop. The branch predicts perfectly
    /// (taken once per `len` calls).
    #[inline]
    fn rotate(&mut self, t: u64) -> u64 {
        let old = self.buf[self.head];
        self.buf[self.head] = t;
        self.head += 1;
        if self.head == self.buf.len() {
            self.head = 0;
        }
        old
    }

    fn reset(&mut self) {
        self.buf.fill(0);
        self.head = 0;
    }
}

/// Per-cycle issue-slot scheduler for one functional-unit class.
///
/// A scalar `next_free` clock would serialize issue in *processing* order —
/// a younger op whose operands are ready early would queue behind an older
/// op that reserved the unit at a later cycle (no backfill), turning the
/// model into in-order issue. Real OOO schedulers pick any ready op, so we
/// track per-cycle slot occupancy (stamp-versioned ring) and let each op
/// claim the first cycle >= its ready time with a free slot.
struct FuSchedule {
    /// (cycle stamp, issues that cycle); indexed by `cycle & MASK`.
    slots: Vec<(u64, u8)>,
    units: u8,
}

const FU_RING: usize = 4096;

impl FuSchedule {
    fn new(units: usize) -> Self {
        Self { slots: vec![(u64::MAX, 0); FU_RING], units: units as u8 }
    }

    #[inline]
    fn load(&mut self, cycle: u64) -> &mut (u64, u8) {
        let slot = &mut self.slots[(cycle as usize) & (FU_RING - 1)];
        if slot.0 != cycle {
            *slot = (cycle, 0);
        }
        slot
    }

    /// Claim one issue slot at the first free cycle >= `ready` (pipelined op).
    #[inline]
    fn issue(&mut self, ready: u64) -> u64 {
        let units = self.units;
        let mut c = ready;
        loop {
            let slot = self.load(c);
            if slot.1 < units {
                slot.1 += 1;
                return c;
            }
            c += 1;
        }
    }

    /// Claim `span` consecutive cycles on one unit (unpipelined op, e.g. div).
    fn issue_span(&mut self, ready: u64, span: u64) -> u64 {
        let units = self.units;
        let mut c = ready;
        'outer: loop {
            for k in 0..span {
                if self.load(c + k).1 >= units {
                    c = c + k + 1;
                    continue 'outer;
                }
            }
            for k in 0..span {
                self.load(c + k).1 += 1;
            }
            return c;
        }
    }

    fn reset(&mut self) {
        self.slots.fill((u64::MAX, 0));
    }
}

/// One out-of-order core.
pub struct Core {
    pub id: usize,
    cfg: CoreConfig,
    // Front end.
    fetch_cycle: u64,
    fetched_this_cycle: usize,
    branches_this_cycle: usize,
    restart_at: u64,
    // Rename: per-architectural-register ready time.
    reg_ready: [u64; 256],
    // ROB slot availability + in-order retire tracking.
    rob: Ring,
    retire_width: Ring,
    last_retire: u64,
    // Functional units: per-cycle issue-slot schedulers.
    fu_int_alu: FuSchedule,
    fu_int_mul: FuSchedule,
    fu_int_div: FuSchedule,
    fu_fp_alu: FuSchedule,
    fu_fp_mul: FuSchedule,
    fu_fp_div: FuSchedule,
    fu_load: FuSchedule,
    fu_store: FuSchedule,
    // Memory-order buffer windows.
    mob_read: Ring,
    mob_write: Ring,
    pub bpred: BranchPredictor,
    pub dtlb: Tlb,
    pub stats: CoreStats,
}

impl Core {
    pub fn new(id: usize, cfg: &CoreConfig) -> Self {
        Self {
            id,
            fetch_cycle: 0,
            fetched_this_cycle: 0,
            branches_this_cycle: 0,
            restart_at: 0,
            reg_ready: [0; 256],
            rob: Ring::new(cfg.rob_entries),
            retire_width: Ring::new(cfg.issue_width),
            last_retire: 0,
            fu_int_alu: FuSchedule::new(cfg.int_alu.0),
            fu_int_mul: FuSchedule::new(cfg.int_mul.0),
            fu_int_div: FuSchedule::new(cfg.int_div.0),
            fu_fp_alu: FuSchedule::new(cfg.fp_alu.0),
            fu_fp_mul: FuSchedule::new(cfg.fp_mul.0),
            fu_fp_div: FuSchedule::new(cfg.fp_div.0),
            fu_load: FuSchedule::new(cfg.load_units),
            fu_store: FuSchedule::new(cfg.store_units),
            mob_read: Ring::new(cfg.mob_read),
            mob_write: Ring::new(cfg.mob_write),
            bpred: BranchPredictor::new(cfg),
            dtlb: Tlb::huge_page_default(),
            stats: CoreStats::default(),
            cfg: cfg.clone(),
        }
    }

    /// Local time: retirement of the most recent µop.
    pub fn now(&self) -> u64 {
        self.last_retire
    }

    /// Front-end slot for the next µop (issue-width per cycle, one branch
    /// per fetch cycle, restart after mispredictions).
    fn fetch_slot(&mut self, is_branch: bool) -> u64 {
        if self.fetch_cycle < self.restart_at {
            self.fetch_cycle = self.restart_at;
            self.fetched_this_cycle = 0;
            self.branches_this_cycle = 0;
        }
        loop {
            let width_ok = self.fetched_this_cycle < self.cfg.issue_width;
            let branch_ok = !is_branch || self.branches_this_cycle < self.cfg.branch_per_fetch;
            if width_ok && branch_ok {
                break;
            }
            self.fetch_cycle += 1;
            self.fetched_this_cycle = 0;
            self.branches_this_cycle = 0;
        }
        self.fetched_this_cycle += 1;
        if is_branch {
            self.branches_this_cycle += 1;
        }
        self.fetch_cycle
    }

    /// Process one µop; returns its retire time. The core's local clock
    /// advances to that time.
    pub fn run_uop(&mut self, u: &Uop, mem: &mut MemorySystem) -> u64 {
        self.stats.uops += 1;
        let fetch = self.fetch_slot(u.fu == FuType::Branch);
        // ROB slot: wait for retire of the op `rob_entries` back.
        let rob_free = self.rob.buf[self.rob.head];
        let dispatch = fetch.max(rob_free);

        // Register dependencies.
        let mut deps = dispatch;
        for &s in &u.srcs {
            if s != NO_REG {
                deps = deps.max(self.reg_ready[s as usize]);
            }
        }

        let complete = match u.fu {
            FuType::Load => {
                self.stats.loads += 1;
                let slot_free = self.mob_read.buf[self.mob_read.head];
                let ready = deps.max(slot_free);
                self.stats.mob_stall_cycles += slot_free.saturating_sub(deps);
                let start = self.fu_load.issue(ready);
                let walk = self.dtlb.access(u.addr);
                let done = mem
                    .access_pc(self.id, u.pc, u.addr, false, start + self.cfg.load_lat + walk)
                    .done;
                self.mob_read.rotate(done);
                done
            }
            FuType::Store => {
                self.stats.stores += 1;
                let slot_free = self.mob_write.buf[self.mob_write.head];
                let ready = deps.max(slot_free);
                self.stats.mob_stall_cycles += slot_free.saturating_sub(deps);
                let start = self.fu_store.issue(ready);
                let walk = self.dtlb.access(u.addr);
                // The store retires once accepted by the store buffer; the
                // write itself is posted to the hierarchy.
                let done = mem
                    .access_pc(self.id, u.pc, u.addr, true, start + self.cfg.store_lat + walk)
                    .done;
                self.mob_write.rotate(done);
                start + self.cfg.store_lat
            }
            FuType::Branch => {
                self.stats.branches += 1;
                let start = self.fu_int_alu.issue(deps);
                let resolve = start + 1;
                if !self.bpred.predict_and_update(u.pc, u.taken) {
                    self.stats.mispredicts += 1;
                    self.restart_at = resolve + self.cfg.mispredict_penalty;
                }
                resolve
            }
            FuType::Nop => deps + 1,
            _ => {
                let (units, lat, pipelined): (&mut FuSchedule, u64, bool) = match u.fu {
                    FuType::IntAlu => (&mut self.fu_int_alu, self.cfg.int_alu.1, true),
                    FuType::IntMul => (&mut self.fu_int_mul, self.cfg.int_mul.1, true),
                    FuType::IntDiv => (&mut self.fu_int_div, self.cfg.int_div.1, false),
                    FuType::FpAlu => (&mut self.fu_fp_alu, self.cfg.fp_alu.1, true),
                    FuType::FpMul => (&mut self.fu_fp_mul, self.cfg.fp_mul.1, true),
                    FuType::FpDiv => (&mut self.fu_fp_div, self.cfg.fp_div.1, false),
                    _ => unreachable!(),
                };
                // Unpipelined units (div) hold their unit for the full latency.
                let start =
                    if pipelined { units.issue(deps) } else { units.issue_span(deps, lat) };
                self.stats.fu_stall_cycles += start.saturating_sub(deps);
                start + lat
            }
        };

        if u.dst != NO_REG {
            self.reg_ready[u.dst as usize] = complete;
        }

        // In-order retire, bounded by retire width per cycle.
        let width_slot = self.retire_width.buf[self.retire_width.head];
        let retire = complete.max(self.last_retire).max(width_slot + 1);
        self.rob.rotate(retire);
        self.retire_width.rotate(retire);
        self.last_retire = retire;
        retire
    }

    /// Functional-phase twin of [`run_uop`](Self::run_uop): updates every
    /// order-driven structure — µop/load/store/branch/mispredict counts,
    /// the DTLB, the branch predictor tables, and the cache hierarchy via
    /// [`MemorySystem::access_functional`] — while leaving all pipeline
    /// clocks (fetch, ROB, rename, FU schedules, MOB, retire) untouched.
    /// `now` is the frozen fast-forward clock, forwarded only to the
    /// prefetch bookkeeping.
    pub fn run_uop_functional(&mut self, u: &Uop, mem: &mut MemorySystem, now: u64) {
        self.stats.uops += 1;
        match u.fu {
            FuType::Load => {
                self.stats.loads += 1;
                let _ = self.dtlb.access(u.addr);
                mem.access_functional(self.id, u.pc, u.addr, false, now);
            }
            FuType::Store => {
                self.stats.stores += 1;
                let _ = self.dtlb.access(u.addr);
                mem.access_functional(self.id, u.pc, u.addr, true, now);
            }
            FuType::Branch => {
                self.stats.branches += 1;
                if !self.bpred.predict_and_update(u.pc, u.taken) {
                    self.stats.mispredicts += 1;
                    // No restart: the fetch bubble is a timing effect.
                }
            }
            _ => {}
        }
    }

    /// Drain: cycle when everything currently in flight has retired
    /// (used by the stop-and-go VIMA dispatch protocol).
    pub fn drain(&self) -> u64 {
        self.last_retire
    }

    /// Serialize the front end: nothing fetches before `t` (used to model
    /// the wait for a VIMA completion signal plus the dispatch gap).
    pub fn serialize_until(&mut self, t: u64) {
        self.restart_at = self.restart_at.max(t);
        if self.last_retire < t {
            self.last_retire = t;
        }
    }

    pub fn dump_stats(&self, report: &mut StatsReport) {
        let s = &self.stats;
        report.add("core.uops", s.uops as f64);
        report.add("core.loads", s.loads as f64);
        report.add("core.stores", s.stores as f64);
        report.add("core.branches", s.branches as f64);
        report.add("core.mispredicts", s.mispredicts as f64);
        report.add("core.fu_stall_cycles", s.fu_stall_cycles as f64);
        report.add("core.mob_stall_cycles", s.mob_stall_cycles as f64);
        report.add("core.bpred_lookups", self.bpred.lookups as f64);
        report.add("core.btb_misses", self.bpred.btb_misses as f64);
        report.add("core.dtlb_hits", self.dtlb.hits as f64);
        report.add("core.dtlb_misses", self.dtlb.misses as f64);
    }

    pub fn reset(&mut self) {
        self.fetch_cycle = 0;
        self.fetched_this_cycle = 0;
        self.branches_this_cycle = 0;
        self.restart_at = 0;
        self.reg_ready = [0; 256];
        self.rob.reset();
        self.retire_width.reset();
        self.last_retire = 0;
        for f in [
            &mut self.fu_int_alu,
            &mut self.fu_int_mul,
            &mut self.fu_int_div,
            &mut self.fu_fp_alu,
            &mut self.fu_fp_mul,
            &mut self.fu_fp_div,
            &mut self.fu_load,
            &mut self.fu_store,
        ] {
            f.reset();
        }
        self.mob_read.reset();
        self.mob_write.reset();
        self.bpred.reset();
        self.dtlb.reset();
        self.stats = CoreStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::isa::Uop;

    fn setup() -> (Core, MemorySystem) {
        let cfg = SystemConfig::default();
        (Core::new(0, &cfg.core), MemorySystem::new(&cfg, 1).unwrap())
    }

    #[test]
    fn independent_alu_ops_reach_issue_width_ipc() {
        let (mut core, mut mem) = setup();
        let n = 6000;
        let mut last = 0;
        for i in 0..n {
            // No dependencies, 3 int ALUs -> throughput-bound at 3/cycle.
            let u = Uop::alu(0x400 + (i % 16) * 4, FuType::IntAlu, [NO_REG; 3], NO_REG);
            last = core.run_uop(&u, &mut mem);
        }
        let ipc = n as f64 / last as f64;
        assert!(ipc > 2.5 && ipc <= 3.2, "int ALU ipc = {ipc}");
    }

    #[test]
    fn dependency_chain_serializes() {
        let (mut core, mut mem) = setup();
        let n = 1000;
        let mut last = 0;
        for i in 0..n {
            // r1 = r1 + r1 : 1-cycle chain
            let u = Uop::alu(0x400 + (i % 8) * 4, FuType::IntAlu, [1, NO_REG, NO_REG], 1);
            last = core.run_uop(&u, &mut mem);
        }
        assert!(last >= n as u64, "chain must be >= 1 cycle per op: {last}");
    }

    #[test]
    fn fp_div_is_unpipelined() {
        let (mut core, mut mem) = setup();
        let n = 100u64;
        let mut last = 0;
        for i in 0..n {
            let u = Uop::alu(0x400 + (i % 8) * 4, FuType::FpDiv, [NO_REG; 3], NO_REG);
            last = core.run_uop(&u, &mut mem);
        }
        // 1 div unit x 10-cycle recovery
        assert!(last >= n * 10, "divs must serialize: {last}");
    }

    #[test]
    fn cached_loads_overlap() {
        let (mut core, mut mem) = setup();
        // Warm one line, then hammer it: 2 load units, L1 2 cycles.
        let warm = core.run_uop(&Uop::load(0x400, 0x1000, 64, 1), &mut mem);
        core.serialize_until(warm);
        let n = 1000u64;
        let mut last = 0;
        for i in 0..n {
            last = core.run_uop(&Uop::load(0x404 + (i % 8) * 4, 0x1000, 64, NO_REG), &mut mem);
        }
        let per_op = (last - warm) as f64 / n as f64;
        assert!(per_op < 1.2, "L1-hit loads should sustain ~2/cycle: {per_op}");
    }

    #[test]
    fn mispredict_inserts_bubble() {
        let (mut core, mut mem) = setup();
        // Pseudo-random outcomes are unlearnable: every predictor scheme
        // must mispredict often and pay restart bubbles.
        let mut rng = crate::util::Rng::new(1234);
        let mut last = 0;
        for _ in 0..200u64 {
            let u = Uop::branch(0x500, rng.bool());
            last = core.run_uop(&u, &mut mem);
        }
        assert!(core.stats.mispredicts > 20, "{}", core.stats.mispredicts);
        assert!(last > 400, "mispredict penalties must show up: {last}");
    }

    #[test]
    fn rob_limits_runahead_past_long_miss() {
        let cfg = SystemConfig::default();
        let mut core = Core::new(0, &cfg.core);
        let mut mem = MemorySystem::new(&cfg, 1).unwrap();
        // A cold DRAM miss followed by >ROB independent ALU ops: the ALU ops
        // beyond the ROB window must wait for the load to retire.
        let load_done = {
            let u = Uop::load(0x400, 0x10_0000, 64, 1);
            core.run_uop(&u, &mut mem)
        };
        let mut last = 0;
        for i in 0..200u64 {
            let u = Uop::alu(0x404 + (i % 4) * 4, FuType::IntAlu, [NO_REG; 3], NO_REG);
            last = core.run_uop(&u, &mut mem);
        }
        // 200 ops at 3/cycle ~ 67 cycles ≪ load_done; the in-order retire
        // pins them behind the load.
        assert!(last >= load_done, "retire is in-order: {last} vs {load_done}");
    }

    #[test]
    fn serialize_until_blocks_fetch() {
        let (mut core, mut mem) = setup();
        core.serialize_until(5000);
        let t = core.run_uop(&Uop::alu(0x400, FuType::IntAlu, [NO_REG; 3], NO_REG), &mut mem);
        assert!(t >= 5000);
    }

    #[test]
    fn stats_accumulate() {
        let (mut core, mut mem) = setup();
        core.run_uop(&Uop::load(0x400, 0, 64, 1), &mut mem);
        core.run_uop(&Uop::store(0x404, 64, 64, [1, NO_REG, NO_REG]), &mut mem);
        core.run_uop(&Uop::branch(0x408, true), &mut mem);
        assert_eq!(core.stats.loads, 1);
        assert_eq!(core.stats.stores, 1);
        assert_eq!(core.stats.branches, 1);
        assert_eq!(core.stats.uops, 3);
    }
}
