//! Data TLB (Sec. III-C: VIMA addresses "are translated by the TLB and go
//! through permission checks like any memory operation. We assume hardware
//! support for huge TLB pages").
//!
//! A 64-entry fully-associative DTLB over 2 MB huge pages: at the paper's
//! footprints (<= 64 MB = 32 pages) everything fits, which is exactly the
//! paper's argument for assuming translation is never the bottleneck. The
//! model keeps the books (and charges a page-walk penalty when a workload
//! ever exceeds the reach) so the assumption is *checked*, not silent.

/// Fully-associative TLB with pseudo-LRU (stamp) replacement.
pub struct Tlb {
    /// (virtual page number, lru stamp); u64::MAX = invalid.
    entries: Vec<(u64, u64)>,
    page_shift: u32,
    tick: u64,
    /// Most-recently-hit slot. Streaming kernels translate the same huge
    /// page for thousands of consecutive accesses, so one compare replaces
    /// the full associative scan on the hot path (timing-identical: same
    /// hit, same stamp update).
    mru: usize,
    pub hits: u64,
    pub misses: u64,
    /// CPU cycles per page walk (charged on a miss).
    pub walk_penalty: u64,
}

impl Tlb {
    /// Default per Sec. III-C: 64 entries of 2 MB huge pages, ~30-cycle walk.
    pub fn huge_page_default() -> Self {
        Self::new(64, 21, 30)
    }

    pub fn new(entries: usize, page_shift: u32, walk_penalty: u64) -> Self {
        assert!(entries >= 1);
        Self {
            entries: vec![(u64::MAX, 0); entries],
            page_shift,
            tick: 0,
            mru: 0,
            hits: 0,
            misses: 0,
            walk_penalty,
        }
    }

    /// Translate one access; returns the added latency (0 on a hit).
    pub fn access(&mut self, addr: u64) -> u64 {
        let vpn = addr >> self.page_shift;
        self.tick += 1;
        if self.entries[self.mru].0 == vpn {
            self.entries[self.mru].1 = self.tick;
            self.hits += 1;
            return 0;
        }
        for (i, e) in self.entries.iter_mut().enumerate() {
            if e.0 == vpn {
                e.1 = self.tick;
                self.mru = i;
                self.hits += 1;
                return 0;
            }
        }
        self.misses += 1;
        // install over LRU
        let mut victim = 0;
        let mut best = u64::MAX;
        for (i, e) in self.entries.iter().enumerate() {
            if e.0 == u64::MAX {
                victim = i;
                break;
            }
            if e.1 < best {
                best = e.1;
                victim = i;
            }
        }
        self.entries[victim] = (vpn, self.tick);
        self.mru = victim;
        self.walk_penalty
    }

    /// Fold the complete translation state into `h` (sampled-mode
    /// state-parity digests; see `Machine::state_digest`).
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        self.tick.hash(h);
        self.mru.hash(h);
        self.entries.hash(h);
    }

    /// TLB reach in bytes (entries x page size).
    pub fn reach(&self) -> u64 {
        self.entries.len() as u64 * (1 << self.page_shift)
    }

    pub fn reset(&mut self) {
        self.entries.fill((u64::MAX, 0));
        self.tick = 0;
        self.mru = 0;
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn huge_pages_cover_paper_footprints() {
        let t = Tlb::huge_page_default();
        assert_eq!(t.reach(), 64 * 2 * 1024 * 1024); // 128 MB >= 64 MB
    }

    #[test]
    fn first_touch_misses_then_hits() {
        let mut t = Tlb::huge_page_default();
        assert_eq!(t.access(0x1_0000_0000), 30);
        assert_eq!(t.access(0x1_0000_0040), 0); // same 2 MB page
        assert_eq!(t.access(0x1_0020_0000), 30); // next page
        assert_eq!((t.hits, t.misses), (1, 2));
    }

    #[test]
    fn working_set_within_reach_stabilizes() {
        let mut t = Tlb::huge_page_default();
        // 32 pages (64 MB), touched twice: second pass all hits.
        for pass in 0..2 {
            for p in 0..32u64 {
                let lat = t.access(p << 21);
                if pass == 1 {
                    assert_eq!(lat, 0, "page {p} missed on second pass");
                }
            }
        }
    }

    #[test]
    fn thrashes_beyond_reach() {
        let mut t = Tlb::new(4, 21, 30);
        for _ in 0..3 {
            for p in 0..8u64 {
                t.access(p << 21);
            }
        }
        assert!(t.misses > 8, "LRU must thrash: {}", t.misses);
    }

    #[test]
    fn reset_clears() {
        let mut t = Tlb::huge_page_default();
        t.access(0);
        t.reset();
        assert_eq!((t.hits, t.misses), (0, 0));
        assert_eq!(t.access(0), 30);
    }
}
