//! Energy model — every coefficient from Table I.
//!
//! * Cores: 6 W per active core while it runs.
//! * Caches: dynamic energy per line access (L1 194 pJ, L2 340 pJ,
//!   LLC 3.01 nJ) + static power (30 mW / 130 mW / 7 W) over the run.
//! * 3D memory: 10.8 pJ/bit on the host path, 4.8 pJ/bit on the VIMA path,
//!   4 W static.
//! * VIMA logic: 3.2 W while the device is busy (the paper assumes the
//!   cache/FUs can be gated-vdd when idle), + its cache's dynamic/static.

use crate::config::SystemConfig;
use crate::stats::StatsReport;

/// Joules per component group.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub core_j: f64,
    pub cache_dynamic_j: f64,
    pub cache_static_j: f64,
    pub dram_dynamic_j: f64,
    pub dram_static_j: f64,
    pub vima_j: f64,
    pub total_j: f64,
}

impl EnergyBreakdown {
    pub fn dump_into(&self, report: &mut StatsReport) {
        report.set("energy.core_j", self.core_j);
        report.set("energy.cache_dynamic_j", self.cache_dynamic_j);
        report.set("energy.cache_static_j", self.cache_static_j);
        report.set("energy.dram_dynamic_j", self.dram_dynamic_j);
        report.set("energy.dram_static_j", self.dram_static_j);
        report.set("energy.vima_j", self.vima_j);
        report.set("energy.total_j", self.total_j);
    }
}

pub struct EnergyModel {
    cfg: SystemConfig,
}

impl EnergyModel {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self { cfg: cfg.clone() }
    }

    /// Compute the run's energy from the final counter report.
    pub fn compute(&self, report: &StatsReport, cycles: u64, active_cores: usize) -> EnergyBreakdown {
        let g = |k: &str| report.get(k).unwrap_or(0.0);
        let seconds = cycles as f64 / (self.cfg.core.freq_ghz * 1e9);

        // --- cores (active only; idle cores are power-gated / parked) ---
        let core_j = self.cfg.core.power_w * seconds * active_cores as f64;

        // --- caches: dynamic per access + writeback, static over time ---
        let pj = 1e-12;
        let cache_dynamic_j = (g("l1d.accesses") + g("l1d.writebacks"))
            * self.cfg.l1d.dyn_pj_per_access
            * pj
            + (g("l2.accesses") + g("l2.writebacks")) * self.cfg.l2.dyn_pj_per_access * pj
            + (g("llc.accesses") + g("llc.writebacks")) * self.cfg.llc.dyn_pj_per_access * pj;
        // L1I mirrors L1D static cost (timing untracked; kernels always hit).
        let per_core_static_mw =
            2.0 * self.cfg.l1d.static_mw + self.cfg.l2.static_mw;
        let cache_static_j = (per_core_static_mw * 1e-3 * active_cores as f64
            + self.cfg.llc.static_mw * 1e-3)
            * seconds;

        // --- 3D memory (static power per cube: a chained fabric keeps
        // every cube refreshed/linked for the whole run) ---
        let dram_dynamic_j = g("mem.host_bits") * self.cfg.mem.x86_pj_per_bit * pj
            + g("mem.vima_bits") * self.cfg.mem.vima_pj_per_bit * pj;
        let dram_static_j =
            self.cfg.mem.static_w * seconds * self.cfg.mem.num_cubes.max(1) as f64;

        // --- VIMA logic layer (gated when unused) ---
        let vima_used = g("vima.instructions") > 0.0 || g("hive.computes") > 0.0;
        let vima_j = if vima_used {
            // Multi-cube fabrics report the per-device busy-time sum
            // (`vima.busy_cycles_sum`): each cube's logic layer burns power
            // for its own busy window. Single-cube reports carry only the
            // classic `busy_until` gauge — same value, same energy.
            let gated = g("vima.busy_until").max(g("hive.writeback_cycles")).min(cycles as f64);
            let busy = match report.get("vima.busy_cycles_sum") {
                Some(sum) => sum
                    .min(cycles as f64 * self.cfg.mem.num_cubes.max(1) as f64)
                    .max(gated),
                None => gated,
            };
            let busy_s = busy / (self.cfg.core.freq_ghz * 1e9);
            self.cfg.vima.power_w * busy_s
                + (g("vima.vcache_hits") + g("vima.vcache_misses"))
                    * self.cfg.vima.cache_dyn_pj_per_access
                    * pj
                + self.cfg.vima.cache_static_mw * 1e-3 * busy_s
        } else {
            0.0
        };

        let total_j =
            core_j + cache_dynamic_j + cache_static_j + dram_dynamic_j + dram_static_j + vima_j;
        EnergyBreakdown {
            core_j,
            cache_dynamic_j,
            cache_static_j,
            dram_dynamic_j,
            dram_static_j,
            vima_j,
            total_j,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(pairs: &[(&str, f64)]) -> StatsReport {
        let mut r = StatsReport::new();
        for (k, v) in pairs {
            r.set(*k, *v);
        }
        r
    }

    #[test]
    fn core_energy_scales_with_cores_and_time() {
        let m = EnergyModel::new(&SystemConfig::default());
        let r = report_with(&[]);
        let e1 = m.compute(&r, 2_000_000_000, 1); // 1 s at 2 GHz
        let e4 = m.compute(&r, 2_000_000_000, 4);
        assert!((e1.core_j - 6.0).abs() < 1e-9);
        assert!((e4.core_j - 24.0).abs() < 1e-9);
    }

    #[test]
    fn dram_energy_per_bit_paths_differ() {
        let m = EnergyModel::new(&SystemConfig::default());
        let bits = 1e12;
        let host = m.compute(&report_with(&[("mem.host_bits", bits)]), 1000, 1);
        let vima = m.compute(&report_with(&[("mem.vima_bits", bits)]), 1000, 1);
        assert!((host.dram_dynamic_j - 10.8).abs() < 1e-6);
        assert!((vima.dram_dynamic_j - 4.8).abs() < 1e-6);
    }

    #[test]
    fn vima_power_gated_when_unused() {
        let m = EnergyModel::new(&SystemConfig::default());
        let e = m.compute(&report_with(&[("l1d.accesses", 100.0)]), 1000, 1);
        assert_eq!(e.vima_j, 0.0);
    }

    #[test]
    fn llc_access_energy_dominates_l1() {
        let m = EnergyModel::new(&SystemConfig::default());
        let l1 = m.compute(&report_with(&[("l1d.accesses", 1e6)]), 1000, 1);
        let llc = m.compute(&report_with(&[("llc.accesses", 1e6)]), 1000, 1);
        assert!(llc.cache_dynamic_j > 10.0 * l1.cache_dynamic_j);
    }

    #[test]
    fn total_is_sum_of_parts() {
        let m = EnergyModel::new(&SystemConfig::default());
        let e = m.compute(
            &report_with(&[
                ("l1d.accesses", 1e6),
                ("mem.host_bits", 1e9),
                ("vima.instructions", 10.0),
                ("vima.busy_until", 500.0),
            ]),
            1000,
            2,
        );
        let sum = e.core_j
            + e.cache_dynamic_j
            + e.cache_static_j
            + e.dram_dynamic_j
            + e.dram_static_j
            + e.vima_j;
        assert!((e.total_j - sum).abs() < 1e-12);
    }
}
