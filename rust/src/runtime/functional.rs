//! Functional VIMA: executes the *same* [`VimaInstr`] stream the timing
//! model consumes, but computes real values through the PJRT artifacts —
//! the per-instruction HLO modules lowered from the Layer-1 Pallas kernels.
//!
//! This is how the end-to-end examples prove the three layers compose: one
//! trace drives both the cycle model (time/energy) and this functional
//! executor (numerics), and the numerics are asserted against a pure-Rust
//! oracle.

use std::collections::HashMap;

use crate::ensure;
use crate::util::error::{Error, Result};

use super::Engine;
use crate::isa::{VDtype, VimaInstr, VimaOp};

/// Sparse vector memory: base address -> f32 vector contents.
pub struct FunctionalVima {
    engine: Engine,
    memory: HashMap<u64, Vec<f32>>,
    /// Value used for `Bcast` instructions (the trace carries no immediates;
    /// the driver sets it before executing a broadcast).
    pub bcast_value: f32,
    pub executed: u64,
}

impl FunctionalVima {
    pub fn new(engine: Engine) -> Self {
        Self { engine, memory: HashMap::new(), bcast_value: 0.0, executed: 0 }
    }

    /// Pre-load a vector into functional memory.
    pub fn write_vector(&mut self, base: u64, data: Vec<f32>) {
        self.memory.insert(base, data);
    }

    pub fn read_vector(&self, base: u64) -> Option<&[f32]> {
        self.memory.get(&base).map(|v| v.as_slice())
    }

    fn fetch(&self, base: u64, elems: usize) -> Result<Vec<f32>> {
        let v = self
            .memory
            .get(&base)
            .ok_or_else(|| Error::msg(format!("functional memory miss at {base:#x}")))?;
        ensure!(v.len() == elems, "vector at {base:#x} has {} elems, want {elems}", v.len());
        Ok(v.clone())
    }

    /// Execute one f32 VIMA instruction through the PJRT artifacts.
    pub fn execute(&mut self, instr: &VimaInstr) -> Result<()> {
        ensure!(instr.dtype == VDtype::F32, "functional path supports f32 traces");
        let elems = instr.vector_bytes as usize / 4;
        ensure!(elems == 2048, "per-instruction artifacts are 8 KB vectors");
        self.executed += 1;

        let artifact = match instr.op {
            VimaOp::Add => "vadd_f32",
            VimaOp::Sub => "vsub_f32",
            VimaOp::Mul => "vmul_f32",
            VimaOp::Div => "vdiv_f32",
            VimaOp::Min => "vmin_f32",
            VimaOp::Max => "vmax_f32",
            VimaOp::Fma => "vfma_f32",
            VimaOp::Mov => "vmov_f32",
            VimaOp::Bcast => "vbcast_f32",
            VimaOp::Dot => "vdot_f32",
            VimaOp::RedSum => "vredsum_f32",
            op => crate::bail!("no f32 artifact for {op:?}"),
        };

        let mut inputs: Vec<Vec<f32>> = Vec::new();
        if instr.op == VimaOp::Bcast {
            inputs.push(vec![self.bcast_value]);
        } else {
            for a in instr.src_addrs() {
                inputs.push(self.fetch(a, elems)?);
            }
        }
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = self.engine.execute_f32(artifact, &refs)?;

        if let Some(dst) = instr.dst() {
            self.memory.insert(dst, out);
        } else {
            // reductions: stash the scalar at a well-known slot
            self.memory.insert(u64::MAX, out);
        }
        Ok(())
    }

    /// Last reduction result (Dot/RedSum with no destination).
    pub fn last_scalar(&self) -> Option<f32> {
        self.memory.get(&u64::MAX).and_then(|v| v.first().copied())
    }

    pub fn into_engine(self) -> Engine {
        self.engine
    }
}
