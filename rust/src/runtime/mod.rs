//! PJRT functional runtime — loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from Rust.
//!
//! This is the *functional* half of the simulator: the cycle model (L3)
//! answers "how long / how much energy", this module answers "what values",
//! by running the very HLO the Layer-2 JAX graphs (and their Layer-1 Pallas
//! kernels) lower to. Python is never on this path: artifacts are HLO
//! **text** files compiled by the PJRT CPU client at load time
//! (see /opt/xla-example/README.md for why text, not serialized protos).
//!
//! The artifact registry is `artifacts/manifest.tsv`:
//! `name<TAB>inputs<TAB>outputs`, each side `dtype:dim,dim,...` joined by
//! `;` (scalar shapes use an empty dim list: `float32:`).

pub mod functional;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::ensure;
use crate::util::error::{Context, Error, Result};

/// One artifact's signature from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(s: &str) -> Result<Self> {
        let (dtype, dims) = s.split_once(':').with_context(|| format!("bad spec {s:?}"))?;
        let shape = if dims.is_empty() {
            vec![]
        } else {
            dims.split(',')
                .map(|d| d.parse::<usize>().with_context(|| format!("bad dim {d:?}")))
                .collect::<Result<_>>()?
        };
        Ok(Self { shape, dtype: dtype.to_string() })
    }
}

fn parse_manifest(text: &str) -> Result<HashMap<String, ArtifactMeta>> {
    let mut out = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut cols = line.split('\t');
        let name = cols.next().context("missing name")?;
        let ins = cols.next().with_context(|| format!("line {}: missing inputs", lineno + 1))?;
        let outs = cols.next().with_context(|| format!("line {}: missing outputs", lineno + 1))?;
        let parse_side = |side: &str| -> Result<Vec<TensorSpec>> {
            if side == "-" {
                return Ok(vec![]);
            }
            side.split(';').map(TensorSpec::parse).collect()
        };
        out.insert(
            name.to_string(),
            ArtifactMeta { inputs: parse_side(ins)?, outputs: parse_side(outs)? },
        );
    }
    Ok(out)
}

/// PJRT engine: artifact registry + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Open the artifacts directory (default `artifacts/` at the repo root).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("missing {manifest_path:?}; run `make artifacts`"))?;
        let manifest = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| Error::msg(format!("PJRT: {e:?}")))?;
        Ok(Self { client, dir, manifest, compiled: HashMap::new() })
    }

    /// Artifact names available.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.manifest.keys().map(|s| s.as_str())
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }

    fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        ensure!(self.manifest.contains_key(name), "unknown artifact {name}");
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| Error::msg(format!("parse {path:?}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).map_err(|e| Error::msg(format!("compile {name}: {e:?}")))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with the given input literals; returns the
    /// single output literal (all our entry points return one array,
    /// lowered as a 1-tuple).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        self.compile(name)?;
        let meta = &self.manifest[name];
        ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: expected {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        let exe = &self.compiled[name];
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::msg(format!("execute {name}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::msg(format!("fetch {name}: {e:?}")))?;
        result.to_tuple1().map_err(|e| Error::msg(format!("untuple {name}: {e:?}")))
    }

    /// Execute with f32 slices in/out (shape checked against the manifest).
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let meta =
            self.meta(name).with_context(|| format!("unknown artifact {name}"))?.clone();
        let mut lits = Vec::with_capacity(inputs.len());
        for (spec, data) in meta.inputs.iter().zip(inputs) {
            ensure!(
                spec.dtype == "float32",
                "{name}: input is {}, use execute() for non-f32",
                spec.dtype
            );
            ensure!(
                spec.elements() == data.len(),
                "{name}: expected {} elements, got {}",
                spec.elements(),
                data.len()
            );
            lits.push(literal_f32(data, &spec.shape)?);
        }
        let out = self.execute(name, &lits)?;
        out.to_vec::<f32>().map_err(|e| Error::msg(format!("to_vec {name}: {e:?}")))
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() <= 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| Error::msg(format!("reshape: {e:?}")))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() <= 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| Error::msg(format!("reshape: {e:?}")))
}

/// Default artifacts directory: `$VIMA_ARTIFACTS` or `artifacts/`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("VIMA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = "vadd_f32\tfloat32:2048;float32:2048\tfloat32:2048\n\
                    mlp\tfloat32:32,256;float32:256\tint32:32\n\
                    scalar\tfloat32:\tfloat32:\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m["vadd_f32"].inputs.len(), 2);
        assert_eq!(m["vadd_f32"].inputs[0].elements(), 2048);
        assert_eq!(m["mlp"].inputs[0].shape, vec![32, 256]);
        assert_eq!(m["mlp"].outputs[0].dtype, "int32");
        assert_eq!(m["scalar"].inputs[0].elements(), 1); // empty shape = scalar
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("name-only-line\n").is_err());
        assert!(parse_manifest("n\tfloat32-2048\tfloat32:1\n").is_err());
    }

    #[test]
    fn manifest_skips_comments() {
        let m = parse_manifest("# header\n\nvadd\tfloat32:4\tfloat32:4\n").unwrap();
        assert_eq!(m.len(), 1);
    }
}
