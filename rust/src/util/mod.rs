//! In-tree replacements for crates unavailable in the offline build
//! environment: a deterministic RNG (property tests), a micro-benchmark
//! harness (`cargo bench` targets), a tiny CLI argument helper, and the
//! `anyhow`-shaped error plumbing in [`error`].

pub mod bench;
pub mod cli;
pub mod error;

/// SplitMix64 — tiny, deterministic, high-quality 64-bit generator.
/// Used by the property-based tests and workload randomization.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self(seed.wrapping_add(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + u * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Property-test driver: runs `f` on `cases` seeded RNGs; failures report
/// the seed for exact reproduction.
pub fn proptest(cases: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f32_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f32(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn proptest_runs_all_cases() {
        let mut n = 0;
        proptest(25, |_| n += 1);
        assert_eq!(n, 25);
    }
}
