//! Minimal benchmark harness for the `cargo bench` targets (criterion is
//! unavailable offline). Reports min/mean/max wall time per iteration and
//! a derived throughput column.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Time `f` over `iters` iterations after one warm-up run.
pub fn bench<T>(name: &str, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    std::hint::black_box(f()); // warm-up
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    let min_s = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_s = times.iter().cloned().fold(0.0, f64::max);
    let r = BenchResult { name: name.to_string(), iters, mean_s, min_s, max_s };
    println!(
        "bench {:<44} iters={:<3} mean={:>10.4} ms  min={:>10.4} ms  max={:>10.4} ms",
        r.name,
        r.iters,
        r.mean_s * 1e3,
        r.min_s * 1e3,
        r.max_s * 1e3
    );
    r
}

/// Print a named scalar metric in a stable, grep-friendly format.
pub fn metric(name: &str, value: f64, unit: &str) {
    println!("metric {name:<48} {value:>14.4} {unit}");
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
