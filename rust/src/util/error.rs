//! Minimal error plumbing — the offline build has no `anyhow` crate, and
//! the default (no-`pjrt`) build must be dependency-free.
//!
//! Provides the slice of the `anyhow` API the crate actually uses: a
//! string-backed [`Error`], a [`Result`] alias, the [`bail!`]/[`ensure!`]
//! macros, and a [`Context`] extension trait for `Result`/`Option`. Error
//! messages render identically (`"context: cause"` chains), so swapping a
//! module between this and `anyhow` is a one-line import change.
//!
//! [`bail!`]: crate::bail
//! [`ensure!`]: crate::ensure

use std::fmt;

/// A string-backed error with pre-flattened context chain.
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }

    /// Prepend a context layer (`"ctx: self"`), like `anyhow::Context`.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error(format!("{ctx}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// `fn main() -> Result<()>` prints the `Debug` form on error; show the
// message, not a struct dump.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-shaped extension for attaching context to errors.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke with code {}", 7);
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "broke with code 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(12).unwrap_err().to_string(), "x too big: 12");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing field {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing field x");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
