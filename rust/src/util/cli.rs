//! Tiny CLI argument helper (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

pub const FLAG_SET: &str = "<set>";

impl Args {
    /// Parse from an explicit argument list (excluding argv[0]).
    pub fn parse_from(args: impl IntoIterator<Item = String>) -> Self {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    flags.insert(stripped.to_string(), FLAG_SET.to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self { positional, flags }
    }

    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str()).filter(|s| *s != FLAG_SET)
    }

    /// Comma-separated list flag: `--figs fig2,fig3` -> `["fig2", "fig3"]`.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name).map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).map(|v| v.parse().expect("numeric flag")).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| v.parse().expect("numeric flag")).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = args(&["fig3", "--quick", "--out", "results", "--mb=16"]);
        assert_eq!(a.positional, vec!["fig3"]);
        assert!(a.flag("quick"));
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.get_u64("mb", 4), 16);
        assert_eq!(a.get_u64("threads", 1), 1);
    }

    #[test]
    fn list_flags() {
        let a = args(&["sweep", "--figs", "fig2, fig5,", "--jobs=4"]);
        let figs = a.get_list("figs").unwrap();
        assert_eq!(figs, vec!["fig2", "fig5"]);
        assert_eq!(a.get_usize("jobs", 0), 4);
        assert_eq!(a.get_list("missing"), None);
    }

    #[test]
    fn boolean_flag_before_positional() {
        // "--quick fig3": "fig3" is consumed as quick's value by design;
        // callers pass flags after the subcommand.
        let a = args(&["run", "vecsum", "--stats"]);
        assert_eq!(a.positional, vec!["run", "vecsum"]);
        assert!(a.flag("stats"));
        assert_eq!(a.get("stats"), None); // bare flag has no value
    }
}
