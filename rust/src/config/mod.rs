//! System configuration — Table I of the paper as typed defaults.
//!
//! Every number in the `Default` impls is taken verbatim from *Table I:
//! Baseline and VIMA system configuration*. Anything the table does not pin
//! down (MSHR depths, mispredict penalty, interconnect details) is an
//! explicit field with a documented, conservative default so experiments can
//! sweep it.
//!
//! Configs serialize to/from a TOML subset (`[section]` + `key = value`
//! lines, parsed in-tree — the offline build has no serde/toml crates), so
//! every experiment is reproducible from a checked-in file:
//!
//! ```toml
//! [vima]
//! cache_bytes = 131072    # 16-line VIMA cache
//! [llc]
//! size_bytes = 8388608
//! ```

use crate::util::error::{Context, Result};
use crate::{bail, ensure};

/// Value conversion for the TOML subset.
pub trait TomlValue: Sized {
    fn parse_toml(s: &str) -> Result<Self>;
    fn emit_toml(&self) -> String;
}

/// Stable per-field hashing for sweep-cache keys.
///
/// `f64` fields hash by bit pattern with `±0.0` normalized so `Hash` stays
/// consistent with the derived `PartialEq`. NaN would still break the
/// reflexive `Eq` claim below, so non-finite floats are rejected twice: at
/// the TOML parse boundary and by [`SystemConfig::validate`] (via
/// `all_finite`, for programmatically built configs).
pub trait FieldHash {
    fn field_hash<H: std::hash::Hasher>(&self, state: &mut H);

    /// Finiteness of float fields (non-float fields are trivially finite).
    fn field_finite(&self) -> bool {
        true
    }
}

impl FieldHash for f64 {
    fn field_hash<H: std::hash::Hasher>(&self, state: &mut H) {
        let normalized = if *self == 0.0 { 0.0f64 } else { *self };
        state.write_u64(normalized.to_bits());
    }

    fn field_finite(&self) -> bool {
        self.is_finite()
    }
}

impl FieldHash for u64 {
    fn field_hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u64(*self);
    }
}

impl FieldHash for usize {
    fn field_hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u64(*self as u64);
    }
}

impl FieldHash for bool {
    fn field_hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u8(*self as u8);
    }
}

impl FieldHash for (usize, u64) {
    fn field_hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u64(self.0 as u64);
        state.write_u64(self.1);
    }
}

impl TomlValue for f64 {
    fn parse_toml(s: &str) -> Result<Self> {
        let v: f64 = s.parse().with_context(|| format!("bad float {s:?}"))?;
        // `"nan".parse::<f64>()` succeeds; NaN would break the Eq/Hash
        // contract the sweep cache keys rely on.
        ensure!(v.is_finite(), "non-finite float {s:?}");
        Ok(v)
    }
    fn emit_toml(&self) -> String {
        if self.fract() == 0.0 {
            format!("{self:.1}")
        } else {
            format!("{self}")
        }
    }
}

impl TomlValue for u64 {
    fn parse_toml(s: &str) -> Result<Self> {
        s.parse().with_context(|| format!("bad integer {s:?}"))
    }
    fn emit_toml(&self) -> String {
        format!("{self}")
    }
}

impl TomlValue for usize {
    fn parse_toml(s: &str) -> Result<Self> {
        s.parse().with_context(|| format!("bad integer {s:?}"))
    }
    fn emit_toml(&self) -> String {
        format!("{self}")
    }
}

impl TomlValue for bool {
    fn parse_toml(s: &str) -> Result<Self> {
        match s {
            "true" => Ok(true),
            "false" => Ok(false),
            _ => bail!("bad bool {s:?}"),
        }
    }
    fn emit_toml(&self) -> String {
        format!("{self}")
    }
}

/// `(count, latency)` FU descriptors serialize as `[count, latency]`.
impl TomlValue for (usize, u64) {
    fn parse_toml(s: &str) -> Result<Self> {
        let inner = s.trim().strip_prefix('[').and_then(|x| x.strip_suffix(']'));
        let inner = inner.with_context(|| format!("expected [count, latency], got {s:?}"))?;
        let mut parts = inner.split(',').map(str::trim);
        let a = parts.next().context("missing count")?.parse()?;
        let b = parts.next().context("missing latency")?.parse()?;
        Ok((a, b))
    }
    fn emit_toml(&self) -> String {
        format!("[{}, {}]", self.0, self.1)
    }
}

/// Defines a config struct with Table-I defaults plus TOML-subset get/set.
macro_rules! cfg_struct {
    ($(#[$meta:meta])* $name:ident { $($field:ident : $ty:ty = $default:expr),* $(,)? }) => {
        $(#[$meta])*
        #[derive(Debug, Clone, PartialEq)]
        pub struct $name {
            $(pub $field: $ty,)*
        }

        impl Default for $name {
            fn default() -> Self {
                Self { $($field: $default,)* }
            }
        }

        impl $name {
            /// Set one field from its TOML representation.
            pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
                match key {
                    $(stringify!($field) => {
                        self.$field = <$ty as TomlValue>::parse_toml(value)
                            .with_context(|| format!("field {}", key))?;
                    })*
                    _ => bail!("unknown key {key:?} in {}", stringify!($name)),
                }
                Ok(())
            }

            fn write_toml(&self, out: &mut String) {
                $(
                    out.push_str(stringify!($field));
                    out.push_str(" = ");
                    out.push_str(&TomlValue::emit_toml(&self.$field));
                    out.push('\n');
                )*
            }

            /// True when every float field is finite — NaN would break the
            /// `Eq`/`Hash` contract the sweep cache relies on.
            pub fn all_finite(&self) -> bool {
                $(FieldHash::field_finite(&self.$field) &&)* true
            }
        }

        // Sweep-cache identity: configs key the result cache, so every
        // section hashes all of its fields (consistent with the derived
        // `PartialEq`; see `FieldHash` for the f64 treatment).
        impl std::hash::Hash for $name {
            fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
                $(FieldHash::field_hash(&self.$field, state);)*
            }
        }

        impl Eq for $name {}
    };
}

cfg_struct!(
    /// Out-of-order x86 core (Sandy-Bridge-like, Table I row 1):
    /// 32 cores @ 2 GHz, 6 W/core, 6-wide issue, 18-entry fetch and
    /// 28-entry decode buffers, 168-entry ROB, MOB 64-read/36-write,
    /// 2 load + 1 store units (1-1 cy), int alu/mul/div = 3/1/1 units at
    /// 1-3-32 cy, fp alu/mul/div = 1/1/1 units at 3-5-10 cy, 1 branch per
    /// fetch, two-level GAs predictor + 4096-entry BTB.
    /// `mispredict_penalty` and `bpred_history_bits` are not in the table
    /// (typical Sandy-Bridge front-end values).
    CoreConfig {
        freq_ghz: f64 = 2.0,
        num_cores: usize = 32,
        power_w: f64 = 6.0,
        issue_width: usize = 6,
        fetch_buffer: usize = 18,
        decode_buffer: usize = 28,
        rob_entries: usize = 168,
        mob_read: usize = 64,
        mob_write: usize = 36,
        load_units: usize = 2,
        load_lat: u64 = 1,
        store_units: usize = 1,
        store_lat: u64 = 1,
        int_alu: (usize, u64) = (3, 1),
        int_mul: (usize, u64) = (1, 3),
        int_div: (usize, u64) = (1, 32),
        fp_alu: (usize, u64) = (1, 3),
        fp_mul: (usize, u64) = (1, 5),
        fp_div: (usize, u64) = (1, 10),
        branch_per_fetch: usize = 1,
        mispredict_penalty: u64 = 14,
        bpred_history_bits: usize = 12,
        btb_entries: usize = 4096,
        btb_ways: usize = 4,
    }
);

cfg_struct!(
    /// One cache level (Table I rows 2-4). Defaults are the L1 row; use the
    /// `l2()` / `llc()` constructors for the other levels. `mshrs` is not in
    /// the table (SiNUCA-like defaults).
    CacheConfig {
        size_bytes: usize = 64 << 10,
        ways: usize = 8,
        latency: u64 = 2,
        line_bytes: usize = 64,
        mshrs: usize = 10,
        dyn_pj_per_access: f64 = 194.0,
        static_mw: f64 = 30.0,
    }
);

impl CacheConfig {
    pub fn l1() -> Self {
        Self::default()
    }

    /// L2: 256 KB, 8-way, 10 cy, 340 pJ/access, 130 mW.
    pub fn l2() -> Self {
        Self {
            size_bytes: 256 << 10,
            latency: 10,
            mshrs: 20,
            dyn_pj_per_access: 340.0,
            static_mw: 130.0,
            ..Self::default()
        }
    }

    /// LLC: 16 MB, 16-way, 22 cy, 3.01 nJ/access, 7 W. The MSHR count is
    /// not in Table I; a 32-core shared LLC is sliced per core (4 misses
    /// per slice).
    pub fn llc() -> Self {
        Self {
            size_bytes: 16 << 20,
            ways: 16,
            latency: 22,
            mshrs: 128,
            dyn_pj_per_access: 3010.0,
            static_mw: 7000.0,
            ..Self::default()
        }
    }

    pub fn sets(&self) -> usize {
        self.size_bytes / (self.line_bytes * self.ways)
    }
}

cfg_struct!(
    /// 3D-stacked memory (Table I row 5): 32 vaults x 8 banks, 256 B row
    /// buffer, 4 GB, DRAM @ 1666 MHz, 4 links @ 8 GHz with 8 B bursts at a
    /// 2.5:1 core-to-bus ratio, closed-row, CAS-RP-RCD-RAS-CWD =
    /// 9-9-9-24-7, instruction latency 1 CPU cycle, 10.8 / 4.8 pJ/bit on
    /// the x86 / VIMA paths, 4 W static.
    ///
    /// The last three fields configure the sharded multi-cube **fabric**
    /// (DESIGN.md §10; not in Table I — the paper evaluates one cube, and
    /// `num_cubes = 1` reproduces it bit-for-bit): `num_cubes` chained
    /// HMC-style cubes behind one address-interleaved front door,
    /// `cube_hop_cycles` CPU cycles per inter-cube SerDes hop (~6 ns at
    /// 2 GHz, a typical chained-HMC link traversal), and
    /// `cube_shard_bytes` — the interleaving granularity, sized to the
    /// largest VIMA vector so one vector never straddles cubes.
    Mem3DConfig {
        vaults: usize = 32,
        banks_per_vault: usize = 8,
        row_buffer_bytes: usize = 256,
        capacity_bytes: u64 = 4 << 30,
        dram_freq_mhz: f64 = 1666.0,
        links: usize = 4,
        link_freq_ghz: f64 = 8.0,
        burst_bytes: usize = 8,
        core_to_bus_ratio: f64 = 2.5,
        t_cas: u64 = 9,
        t_rp: u64 = 9,
        t_rcd: u64 = 9,
        t_ras: u64 = 24,
        t_cwd: u64 = 7,
        open_row: bool = false,
        inst_lat_cycles: u64 = 1,
        x86_pj_per_bit: f64 = 10.8,
        vima_pj_per_bit: f64 = 4.8,
        static_w: f64 = 4.0,
        num_cubes: usize = 1,
        cube_hop_cycles: u64 = 12,
        cube_shard_bytes: usize = 8192,
    }
);

impl Mem3DConfig {
    /// Sub-request granularity (= cache line size everywhere in the system).
    pub fn line_bytes(&self) -> usize {
        64
    }

    /// DRAM cycles per CPU cycle (CPU 2 GHz, DRAM 1.666 GHz -> ~0.83).
    pub fn dram_cycles_per_cpu_cycle(&self, cpu_ghz: f64) -> f64 {
        self.dram_freq_mhz / 1000.0 / cpu_ghz
    }

    /// Convert DRAM cycles to CPU cycles (rounded up).
    pub fn dram_to_cpu(&self, dram_cycles: u64, cpu_ghz: f64) -> u64 {
        (dram_cycles as f64 / self.dram_cycles_per_cpu_cycle(cpu_ghz)).ceil() as u64
    }

    /// Closed-row access latency seen by one 64 B sub-request, DRAM cycles:
    /// activate (RCD) + column read (CAS).
    pub fn access_dram_cycles(&self) -> u64 {
        self.t_rcd + self.t_cas
    }

    /// Bank busy time per closed-row access, DRAM cycles: the bank cannot
    /// accept the next activate until RAS + RP elapse.
    pub fn bank_busy_dram_cycles(&self) -> u64 {
        self.t_ras + self.t_rp
    }

    /// CPU cycles for one 64 B line crossing the serial links (all links
    /// aggregated; each transfer is packetized in `burst_bytes` flits).
    pub fn link_cycles_per_line(&self, cpu_ghz: f64) -> f64 {
        let bytes_per_ns = self.links as f64 * self.burst_bytes as f64 * self.link_freq_ghz;
        let ns = 64.0 / bytes_per_ns;
        ns * cpu_ghz
    }
}

cfg_struct!(
    /// VIMA logic layer (Table I row 6): 1 GHz, 3.2 W, 256 int + 256 fp
    /// lanes, pipelined 8 KB latencies int alu/mul/div = 8-12-28 and fp =
    /// 13-13-28 VIMA cycles, 64 KB fully-associative cache (8 lines) at
    /// 2 cy (1 tag + 1 per transfer) with 2 ports, 194 pJ/access + 134 mW.
    /// `stop_and_go` / `dispatch_gap_cycles` model the Sec. III-C precise-
    /// exception dispatch protocol (sweepable for the ablation).
    VimaConfig {
        freq_ghz: f64 = 1.0,
        power_w: f64 = 3.2,
        lanes: usize = 256,
        vector_bytes: usize = 8192,
        int_alu_lat: u64 = 8,
        int_mul_lat: u64 = 12,
        int_div_lat: u64 = 28,
        fp_alu_lat: u64 = 13,
        fp_mul_lat: u64 = 13,
        fp_div_lat: u64 = 28,
        cache_bytes: usize = 64 << 10,
        cache_tag_lat: u64 = 1,
        cache_beat_lat: u64 = 1,
        cache_ports: usize = 2,
        cache_dyn_pj_per_access: f64 = 194.0,
        cache_static_mw: f64 = 134.0,
        stop_and_go: bool = true,
        // Calibrated so the execution-gap bubble costs 2-4% on the
        // compute-chained kernels, the band Sec. III-C reports.
        dispatch_gap_cycles: u64 = 2,
    }
);

impl VimaConfig {
    /// Number of vector lines the VIMA cache holds (8 by default).
    pub fn cache_lines(&self) -> usize {
        (self.cache_bytes / self.vector_bytes).max(1)
    }

    /// 64 B sub-requests per vector fetch (128 for 8 KB vectors).
    pub fn subrequests_per_vector(&self) -> usize {
        self.vector_bytes / 64
    }

    /// Pipelined beats to stream one vector through the lanes
    /// (8 for 2048 x 32-bit elements over 256 lanes).
    pub fn beats_per_vector(&self, elem_bytes: usize) -> u64 {
        let elems = self.vector_bytes / elem_bytes;
        (elems as f64 / self.lanes as f64).ceil() as u64
    }

    /// VIMA cycles to CPU cycles.
    pub fn to_cpu_cycles(&self, vima_cycles: u64, cpu_ghz: f64) -> u64 {
        (vima_cycles as f64 * cpu_ghz / self.freq_ghz).ceil() as u64
    }
}

cfg_struct!(
    /// HIVE comparator (Alves et al., DATE 2016): 8-register bank of 8 KB
    /// vectors sharing VIMA's lane array, wrapped in lock/unlock
    /// transactions with sequential write-back on unlock (Sec. III-E).
    HiveConfig {
        registers: usize = 8,
        vector_bytes: usize = 8192,
        lanes: usize = 256,
        freq_ghz: f64 = 1.0,
        power_w: f64 = 3.2,
        lock_cycles: u64 = 60,
        unlock_cycles: u64 = 60,
        sequential_writeback: bool = true,
    }
);

cfg_struct!(
    /// Host hardware prefetcher (not in Table I, but the baseline is a
    /// Sandy-Bridge-like core, which ships L2/LLC streamers; the paper's
    /// intro explicitly positions VIMA against prefetching baselines).
    /// A per-PC stride detector issues `degree` prefetches into the LLC
    /// once a stride repeats `min_confidence` times. Prefetch DRAM traffic
    /// is accounted like any other access.
    ///
    /// **Disabled by default**: Table I lists no prefetcher, and the paper's
    /// kNN/MLP LLC-fit crossover (Fig. 3) only exists against a
    /// prefetcher-less baseline. Enable for the "stronger baseline"
    /// ablation (`vima-sim ablation`): streaming speedups drop from ~13x to
    /// ~7x (VecSum) while the crossover flattens.
    PrefetchConfig {
        enabled: bool = false,
        table_entries: usize = 16,
        degree: u64 = 4,
        min_confidence: u64 = 2,
    }
);

cfg_struct!(
    /// Sampled execution (DESIGN.md §11; not in Table I — a simulator
    /// methodology knob, SMARTS-style). When `enabled`, the engine
    /// alternates **functional fast-forward** phases (caches, DTLB, branch
    /// predictors, vcache, and fabric counters updated at near-zero cost,
    /// no latency accounting) with **detailed windows** whose measured
    /// cycles are extrapolated to the full run. `window_events` /
    /// `period_events` are in trace events; `0` defers to the workload's
    /// [`sample_defaults`](crate::workload::Workload::sample_defaults).
    /// `window_events >= period_events` degenerates to a plain detailed
    /// run (bit-identical to `sample.enabled = false`).
    SampleConfig {
        enabled: bool = false,
        window_events: u64 = 0,
        period_events: u64 = 0,
    }
);

/// Full-system configuration (baseline CPU + 3D memory + VIMA + HIVE).
///
/// Implements `Hash`/`Eq` (every section does) so a full config can key the
/// sweep engine's result cache: two cells agree on identity only if every
/// Table-I parameter agrees.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SystemConfig {
    pub core: CoreConfig,
    pub l1d: CacheConfig,
    pub l1i: CacheConfig,
    pub l2: CacheConfig,
    pub llc: CacheConfig,
    pub mem: Mem3DConfig,
    pub vima: VimaConfig,
    pub hive: HiveConfig,
    pub prefetch: PrefetchConfig,
    pub sample: SampleConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            core: CoreConfig::default(),
            l1d: CacheConfig::l1(),
            l1i: CacheConfig::l1(),
            l2: CacheConfig::l2(),
            llc: CacheConfig::llc(),
            mem: Mem3DConfig::default(),
            vima: VimaConfig::default(),
            hive: HiveConfig::default(),
            prefetch: PrefetchConfig::default(),
            sample: SampleConfig::default(),
        }
    }
}

impl SystemConfig {
    /// Parse the TOML subset; missing keys keep their Table I values.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let mut cfg = Self::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let (key, value) = (key.trim(), value.trim());
            match section.as_str() {
                "core" => cfg.core.set(key, value)?,
                "l1d" => cfg.l1d.set(key, value)?,
                "l1i" => cfg.l1i.set(key, value)?,
                "l2" => cfg.l2.set(key, value)?,
                "llc" => cfg.llc.set(key, value)?,
                "mem" => cfg.mem.set(key, value)?,
                "vima" => cfg.vima.set(key, value)?,
                "hive" => cfg.hive.set(key, value)?,
                "prefetch" => cfg.prefetch.set(key, value)?,
                "sample" => cfg.sample.set(key, value)?,
                other => bail!("unknown section [{other}]"),
            }
        }
        Ok(cfg)
    }

    /// Load a TOML override file.
    pub fn from_toml_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_toml_str(&text)
    }

    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        for (name, write) in [
            ("core", &self.core as &dyn Section),
            ("l1d", &self.l1d),
            ("l1i", &self.l1i),
            ("l2", &self.l2),
            ("llc", &self.llc),
            ("mem", &self.mem),
            ("vima", &self.vima),
            ("hive", &self.hive),
            ("prefetch", &self.prefetch),
            ("sample", &self.sample),
        ] {
            s.push_str(&format!("[{name}]\n"));
            write.emit(&mut s);
            s.push('\n');
        }
        s
    }

    /// Sanity-check cross-field invariants; call after any mutation.
    pub fn validate(&self) -> Result<()> {
        let finite = self.core.all_finite()
            && self.l1d.all_finite()
            && self.l1i.all_finite()
            && self.l2.all_finite()
            && self.llc.all_finite()
            && self.mem.all_finite()
            && self.vima.all_finite()
            && self.hive.all_finite()
            && self.prefetch.all_finite()
            && self.sample.all_finite();
        ensure!(finite, "non-finite float field (breaks sweep-cache hashing)");
        ensure!(self.core.issue_width > 0, "issue width must be positive");
        for (name, c) in
            [("l1d", &self.l1d), ("l1i", &self.l1i), ("l2", &self.l2), ("llc", &self.llc)]
        {
            ensure!(
                c.size_bytes % (c.line_bytes * c.ways) == 0,
                "{name}: size {} not divisible by line*ways",
                c.size_bytes
            );
            ensure!(c.sets().is_power_of_two(), "{name}: sets must be a power of two");
        }
        ensure!(self.mem.vaults.is_power_of_two(), "vault count must be 2^n");
        ensure!(self.mem.banks_per_vault.is_power_of_two(), "bank count must be 2^n");
        ensure!(
            self.mem.num_cubes >= 1 && self.mem.num_cubes.is_power_of_two(),
            "mem3d.num_cubes ({}) must be a power of two",
            self.mem.num_cubes
        );
        ensure!(
            self.mem.cube_shard_bytes >= 64
                && self.mem.cube_shard_bytes.is_power_of_two(),
            "mem3d.cube_shard_bytes ({}) must be a power-of-two multiple of 64",
            self.mem.cube_shard_bytes
        );
        ensure!(
            self.vima.vector_bytes <= self.mem.cube_shard_bytes,
            "VIMA vector ({} B) must fit one fabric shard ({} B) so vectors never straddle cubes",
            self.vima.vector_bytes,
            self.mem.cube_shard_bytes
        );
        ensure!(
            self.mem.row_buffer_bytes % 64 == 0
                && (self.mem.row_buffer_bytes / 64).is_power_of_two(),
            "row buffer ({} B) must hold a power-of-two count of 64 B lines",
            self.mem.row_buffer_bytes
        );
        ensure!(
            self.vima.vector_bytes % self.mem.line_bytes() == 0,
            "VIMA vector must be a multiple of the 64 B sub-request granularity"
        );
        ensure!(
            self.vima.cache_bytes % self.vima.vector_bytes == 0,
            "VIMA cache must hold an integral number of vector lines"
        );
        Ok(())
    }
}

trait Section {
    fn emit(&self, out: &mut String);
}

macro_rules! impl_section {
    ($($t:ty),*) => {
        $(impl Section for $t {
            fn emit(&self, out: &mut String) {
                self.write_toml(out);
            }
        })*
    };
}

impl_section!(
    CoreConfig,
    CacheConfig,
    Mem3DConfig,
    VimaConfig,
    HiveConfig,
    PrefetchConfig,
    SampleConfig
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let c = SystemConfig::default();
        assert_eq!(c.core.num_cores, 32);
        assert_eq!(c.core.rob_entries, 168);
        assert_eq!(c.core.int_div, (1, 32));
        assert_eq!(c.l1d.size_bytes, 64 << 10);
        assert_eq!(c.l2.size_bytes, 256 << 10);
        assert_eq!(c.llc.size_bytes, 16 << 20);
        assert_eq!(c.llc.ways, 16);
        assert_eq!(c.mem.vaults, 32);
        assert_eq!(c.mem.banks_per_vault, 8);
        assert_eq!(c.vima.cache_lines(), 8);
        assert_eq!(c.vima.subrequests_per_vector(), 128);
        assert_eq!(c.vima.beats_per_vector(4), 8);
        assert_eq!(c.vima.beats_per_vector(8), 4);
        c.validate().unwrap();
    }

    #[test]
    fn cache_sets() {
        assert_eq!(CacheConfig::l1().sets(), 128);
        assert_eq!(CacheConfig::llc().sets(), 16384);
    }

    #[test]
    fn toml_roundtrip() {
        let c = SystemConfig::default();
        let text = c.to_toml();
        let back = SystemConfig::from_toml_str(&text).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn toml_partial_override() {
        let c = SystemConfig::from_toml_str("[vima]\ncache_bytes = 131072\n").unwrap();
        assert_eq!(c.vima.cache_bytes, 128 << 10);
        assert_eq!(c.vima.cache_lines(), 16);
        // everything else still Table I
        assert_eq!(c.core.rob_entries, 168);
    }

    #[test]
    fn toml_tuple_and_bool_fields() {
        let c = SystemConfig::from_toml_str(
            "[core]\nint_alu = [4, 2]\n[vima]\nstop_and_go = false\n",
        )
        .unwrap();
        assert_eq!(c.core.int_alu, (4, 2));
        assert!(!c.vima.stop_and_go);
    }

    #[test]
    fn toml_comments_and_blanks() {
        let c = SystemConfig::from_toml_str("# comment\n\n[llc]\nsize_bytes = 8388608 # 8MB\n")
            .unwrap();
        assert_eq!(c.llc.size_bytes, 8 << 20);
    }

    #[test]
    fn toml_rejects_unknown_key() {
        assert!(SystemConfig::from_toml_str("[core]\nwarp_size = 32\n").is_err());
        assert!(SystemConfig::from_toml_str("[gpu]\nx = 1\n").is_err());
    }

    #[test]
    fn dram_cycle_conversion() {
        let m = Mem3DConfig::default();
        // 1666 MHz DRAM vs 2 GHz CPU: 9 DRAM cycles ~ 11 CPU cycles
        assert_eq!(m.dram_to_cpu(9, 2.0), 11);
        assert_eq!(m.access_dram_cycles(), 18);
        assert_eq!(m.bank_busy_dram_cycles(), 33);
    }

    #[test]
    fn link_bandwidth() {
        let m = Mem3DConfig::default();
        // 4 links x 8 B x 8 GHz = 256 GB/s => 64 B in 0.25 ns = 0.5 CPU cycles
        let cyc = m.link_cycles_per_line(2.0);
        assert!((cyc - 0.5).abs() < 1e-9, "{cyc}");
    }

    #[test]
    fn validate_rejects_bad_vector() {
        let mut c = SystemConfig::default();
        c.vima.vector_bytes = 100;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_fabric_geometry() {
        let mut c = SystemConfig::default();
        c.mem.num_cubes = 3;
        let e = c.validate().unwrap_err().to_string();
        assert!(e.contains("num_cubes") && e.contains('3'), "{e}");

        let mut c = SystemConfig::default();
        c.mem.cube_shard_bytes = 4096; // < the 8 KB vector: it would straddle
        assert!(c.validate().is_err());

        let mut c = SystemConfig::default();
        c.mem.num_cubes = 8;
        c.mem.cube_shard_bytes = 16384;
        c.validate().unwrap();
    }

    #[test]
    fn sample_section_round_trips_and_separates_identity() {
        let c = SystemConfig::default();
        assert!(!c.sample.enabled, "sampling must be opt-in");
        let s = SystemConfig::from_toml_str(
            "[sample]\nenabled = true\nwindow_events = 1024\nperiod_events = 65536\n",
        )
        .unwrap();
        assert!(s.sample.enabled);
        assert_eq!(s.sample.window_events, 1024);
        assert_eq!(s.sample.period_events, 65536);
        s.validate().unwrap();
        // A sampled config is a distinct cache identity from the full-detail
        // one — the service result cache must never conflate them.
        assert_ne!(c, s);
        use std::collections::HashSet;
        let set: HashSet<SystemConfig> = [c, s].into_iter().collect();
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn non_finite_floats_rejected() {
        // TOML boundary: "nan"/"inf" parse as f64 but must be refused.
        assert!(SystemConfig::from_toml_str("[core]\nfreq_ghz = nan\n").is_err());
        assert!(SystemConfig::from_toml_str("[core]\nfreq_ghz = inf\n").is_err());
        // Programmatic configs are caught by validate().
        let mut c = SystemConfig::default();
        c.vima.power_w = f64::NAN;
        assert!(c.validate().is_err());
        assert!(!c.vima.all_finite());
    }
}
