//! Sharded multi-cube memory fabric (DESIGN.md §10).
//!
//! The paper evaluates one 3D-stacked cube (Table I), but its HMC substrate
//! is explicitly chainable. [`MemFabric`] generalizes the single
//! [`Mem3D`] into `num_cubes` cubes behind one address-interleaved front
//! door:
//!
//! * **Sharding** — addresses interleave across cubes at
//!   `cube_shard_bytes` granularity (default 8 KB, the largest VIMA
//!   vector) through the same XOR-folded hash the per-cube vault mapping
//!   uses, so consecutive vectors spread over cubes while any one
//!   vector-aligned VIMA vector lives wholly inside a single cube.
//! * **Host path** — every cube keeps its own SerDes links (they live in
//!   [`Mem3D`]), and the chain topology charges `cube_hop_cycles` per hop
//!   from the host-attached cube 0: a read to cube *k* pays `k` hops each
//!   way on top of that cube's own link/DRAM timing.
//! * **Logic-layer path** — each cube carries its own VIMA device
//!   ([`VimaDispatcher`] holds one [`VimaDevice`] per cube); an
//!   instruction executes on the cube owning its destination (*home*),
//!   and any operand sub-request that hashes to another cube is a
//!   **cross-cube gather**: it is served by the owning cube's
//!   vaults and pays `|cube − home| · cube_hop_cycles` per direction,
//!   accounted in [`FabricStats`].
//!
//! With `num_cubes = 1` every routing decision degenerates to cube 0 with
//! zero hop cost, so the fabric is bit-identical to the classic
//! single-`Mem3D` system (pinned by `tests/fabric.rs`).

use crate::config::{Mem3DConfig, VimaConfig};
use crate::isa::VimaInstr;
use crate::mem3d::{Mem3D, MemCompletion, MemPort, MemStats};
use crate::stats::StatsReport;
use crate::util::error::Result;
use crate::vima::VimaDevice;

/// Fabric-level accounting (all zero while `num_cubes = 1`).
#[derive(Debug, Default, Clone)]
pub struct FabricStats {
    /// 64 B logic-layer sub-requests served by a cube other than the
    /// requesting device's home cube (cross-cube operand gathers).
    pub cross_cube_lines: u64,
    /// Host lines served by chained (non-root) cubes.
    pub chained_host_lines: u64,
    /// Total extra cycles charged for inter-cube hops (request + response
    /// legs).
    pub hop_cycles: u64,
}

/// The pure address→cube mapping, shared with the static analyzer so
/// `check` predicts exactly the cube the fabric would pick: the
/// XOR-folded hash of the shard-granular block index. `num_cubes` must be
/// a power of two (enforced at [`MemFabric::new`]); `num_cubes == 1`
/// always maps to cube 0.
#[inline]
pub fn cube_index(addr: u64, num_cubes: usize, cube_shard_bytes: usize) -> usize {
    if num_cubes <= 1 {
        return 0;
    }
    let blk = addr >> cube_shard_bytes.trailing_zeros();
    let mix = blk ^ (blk >> 5) ^ (blk >> 10) ^ (blk >> 15) ^ (blk >> 20) ^ (blk >> 25);
    (mix as usize) & (num_cubes - 1)
}

/// `num_cubes` stacked-memory cubes behind one address-interleaved front
/// door. See the module docs for the sharding/hop model.
#[derive(Debug)]
pub struct MemFabric {
    cubes: Vec<Mem3D>,
    /// `num_cubes - 1` (power of two enforced at construction).
    cube_mask: usize,
    /// log2 of the interleaving granularity.
    shard_shift: u32,
    /// CPU cycles per inter-cube hop on the chain.
    hop_lat: u64,
    pub stats: FabricStats,
}

impl MemFabric {
    pub fn new(cfg: &Mem3DConfig, cpu_ghz: f64) -> Result<Self> {
        crate::ensure!(
            cfg.num_cubes >= 1 && cfg.num_cubes.is_power_of_two(),
            "mem3d.num_cubes ({}) must be a power of two (the cube index is mask-mapped)",
            cfg.num_cubes
        );
        crate::ensure!(
            cfg.cube_shard_bytes >= 64 && cfg.cube_shard_bytes.is_power_of_two(),
            "mem3d.cube_shard_bytes ({}) must be a power-of-two multiple of 64",
            cfg.cube_shard_bytes
        );
        let cubes = (0..cfg.num_cubes)
            .map(|_| Mem3D::new(cfg, cpu_ghz))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            cubes,
            cube_mask: cfg.num_cubes - 1,
            shard_shift: cfg.cube_shard_bytes.trailing_zeros(),
            hop_lat: cfg.cube_hop_cycles,
            stats: FabricStats::default(),
        })
    }

    pub fn num_cubes(&self) -> usize {
        self.cubes.len()
    }

    pub fn cube(&self, i: usize) -> &Mem3D {
        &self.cubes[i]
    }

    pub fn cube_mut(&mut self, i: usize) -> &mut Mem3D {
        &mut self.cubes[i]
    }

    /// Shared single-cube configuration.
    pub fn config(&self) -> &Mem3DConfig {
        self.cubes[0].config()
    }

    /// Which cube owns `addr`: the XOR-folded hash of the shard-granular
    /// block index — the same decorrelation trick as the per-cube
    /// vault/bank mapping ([`Mem3D::map`]), one level up. Any
    /// `cube_shard_bytes`-aligned block (hence any vector-aligned VIMA
    /// vector of at most that size) maps to exactly one cube.
    #[inline]
    pub fn cube_of(&self, addr: u64) -> usize {
        cube_index(addr, self.cube_mask + 1, 1usize << self.shard_shift)
    }

    /// Host-side access for one 64 B line. The owning cube's own SerDes
    /// links and DRAM timing apply; chained cubes additionally pay
    /// `cube_index` hops from the host-attached cube 0 on the request leg,
    /// and reads pay them again on the response leg (writes are posted).
    pub fn host_access(&mut self, addr: u64, is_write: bool, now: u64) -> MemCompletion {
        let cube = self.cube_of(addr);
        if cube == 0 {
            return self.cubes[0].host_access(addr, is_write, now);
        }
        let hop = self.hop_lat * cube as u64;
        self.stats.chained_host_lines += 1;
        self.stats.hop_cycles += if is_write { hop } else { 2 * hop };
        let c = self.cubes[cube].host_access(addr, is_write, now + hop);
        MemCompletion { done: if is_write { c.done } else { c.done + hop }, ..c }
    }

    /// Logic-layer access issued by the device on `home`'s logic layer.
    /// Local lines go straight to `home`'s vaults; remote lines are served
    /// by the owning cube and pay `|cube - home|` hops per direction
    /// (cross-cube operand gather / write scatter).
    pub fn vima_access_from(
        &mut self,
        home: usize,
        addr: u64,
        is_write: bool,
        now: u64,
    ) -> MemCompletion {
        let cube = self.cube_of(addr);
        if cube == home {
            return self.cubes[cube].vima_access(addr, is_write, now);
        }
        let hop = self.hop_lat * cube.abs_diff(home) as u64;
        self.stats.cross_cube_lines += 1;
        self.stats.hop_cycles += if is_write { hop } else { 2 * hop };
        let c = self.cubes[cube].vima_access(addr, is_write, now + hop);
        MemCompletion { done: if is_write { c.done } else { c.done + hop }, ..c }
    }

    /// Functional-phase twin of [`host_access`](Self::host_access): routes
    /// to the owning cube and counts the chained-line traffic, but charges
    /// no hop cycles and advances no cube resource clock — hop latency is
    /// a duration, and durations are measured only inside detailed sample
    /// windows (DESIGN.md §11).
    #[inline]
    pub fn host_access_functional(&mut self, addr: u64, is_write: bool) {
        let cube = self.cube_of(addr);
        if cube != 0 {
            self.stats.chained_host_lines += 1;
        }
        self.cubes[cube].host_access_functional(addr, is_write);
    }

    /// Functional-phase twin of [`vima_access_from`](Self::vima_access_from):
    /// counts cross-cube gather lines without touching hop cycles or the
    /// owning cube's vault clocks.
    #[inline]
    pub fn vima_access_functional_from(&mut self, home: usize, addr: u64, is_write: bool) {
        let cube = self.cube_of(addr);
        if cube != home {
            self.stats.cross_cube_lines += 1;
        }
        self.cubes[cube].vima_access_functional(addr, is_write);
    }

    /// Uncontended host read latency of the nearest cube (prefetch
    /// fill-time estimate, as before).
    pub fn uncontended_read_latency(&self) -> u64 {
        self.cubes[0].uncontended_read_latency()
    }

    /// Earliest cycle at which every cube is fully idle.
    pub fn drained_at(&self) -> u64 {
        self.cubes.iter().map(|c| c.drained_at()).max().unwrap_or(0)
    }

    /// Aggregated per-cube DRAM counters (the `mem.*` totals).
    pub fn stats_total(&self) -> MemStats {
        let mut total = MemStats::default();
        for c in &self.cubes {
            total.accumulate(&c.stats);
        }
        total
    }

    /// Emit the classic `mem.*` keys (summed over cubes — identical to the
    /// single-cube report when `num_cubes = 1`), plus `fabric.*` keys for
    /// multi-cube runs only, so single-cube reports stay bit-identical to
    /// the pre-fabric simulator.
    pub fn dump_stats(&self, report: &mut StatsReport) {
        self.stats_total().dump_into(report);
        if self.cubes.len() > 1 {
            report.add("fabric.cubes", self.cubes.len() as f64);
            report.add("fabric.cross_cube_lines", self.stats.cross_cube_lines as f64);
            report.add("fabric.chained_host_lines", self.stats.chained_host_lines as f64);
            report.add("fabric.hop_cycles", self.stats.hop_cycles as f64);
        }
    }

    pub fn reset(&mut self) {
        for c in &mut self.cubes {
            c.reset();
        }
        self.stats = FabricStats::default();
    }
}

/// A [`MemPort`] view of the fabric from one cube's logic layer: every
/// 64 B sub-request routes to the cube owning its address, charging hops
/// relative to `home`. This is how a per-cube [`VimaDevice`] (or the HIVE
/// comparator, pinned to cube 0) reads and writes through the fabric
/// without knowing the topology.
pub struct FabricPort<'a> {
    fabric: &'a mut MemFabric,
    home: usize,
}

impl<'a> FabricPort<'a> {
    pub fn new(fabric: &'a mut MemFabric, home: usize) -> Self {
        debug_assert!(home < fabric.num_cubes());
        Self { fabric, home }
    }
}

impl MemPort for FabricPort<'_> {
    fn vima_access(&mut self, addr: u64, is_write: bool, now: u64) -> MemCompletion {
        self.fabric.vima_access_from(self.home, addr, is_write, now)
    }

    fn drained_at(&self) -> u64 {
        self.fabric.drained_at()
    }
}

/// One VIMA logic layer per cube, plus the routing that picks which device
/// executes each instruction: the cube owning the destination vector (or
/// the first source for reductions) is the instruction's *home* — results
/// always land in the home cube's vector cache and DRAM, while remote
/// operands stream in as accounted cross-cube gathers.
pub struct VimaDispatcher {
    devices: Vec<VimaDevice>,
    /// Instructions whose home was a chained (non-zero) cube.
    pub remote_home_instrs: u64,
}

impl VimaDispatcher {
    pub fn new(cfg: &VimaConfig, inst_lat: u64, cpu_ghz: f64, num_cubes: usize) -> Self {
        let n = num_cubes.max(1);
        Self {
            devices: (0..n).map(|_| VimaDevice::new(cfg, inst_lat, cpu_ghz)).collect(),
            remote_home_instrs: 0,
        }
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn device(&self, i: usize) -> &VimaDevice {
        &self.devices[i]
    }

    pub fn device_mut(&mut self, i: usize) -> &mut VimaDevice {
        &mut self.devices[i]
    }

    /// The cube whose logic layer executes `instr`.
    pub fn home_cube(&self, instr: &VimaInstr, fabric: &MemFabric) -> usize {
        let anchor = instr.dst().or_else(|| instr.src_addrs().next()).unwrap_or(0);
        fabric.cube_of(anchor)
    }

    /// Execute one instruction on its home cube's device, streaming
    /// operands through a [`FabricPort`]. Identical to a single
    /// [`VimaDevice`] over a single [`Mem3D`] when the fabric has one cube.
    ///
    /// Cross-device coherence (two directions, both cheap because a
    /// vector is dirty only in the device that produced it as a
    /// destination — its *owning* cube, since results always execute
    /// where their destination lives):
    ///
    /// * **gather of a dirty vector** — before a device reads a source
    ///   owned by another home, the owner posts the write-back and keeps
    ///   a clean copy ([`VimaDevice::flush_vector`]), so remote reads
    ///   never observe data that exists only in a sibling cache;
    /// * **rewrite of a shared vector** — writing a destination drops any
    ///   stale *clean* copies sibling devices gathered earlier, so a later
    ///   read there re-fetches (and is charged the cross-cube gather)
    ///   instead of hitting stale data.
    pub fn execute(
        &mut self,
        instr: &VimaInstr,
        dispatch: u64,
        fabric: &mut MemFabric,
    ) -> Result<u64> {
        let home = self.home_cube(instr, fabric);
        if home != 0 {
            self.remote_home_instrs += 1;
        }
        if self.devices.len() > 1 {
            for s in instr.unique_src_addrs() {
                let owner = fabric.cube_of(s);
                if owner != home {
                    let mut port = FabricPort::new(&mut *fabric, owner);
                    self.devices[owner].flush_vector(s, dispatch, &mut port);
                }
            }
            if instr.op.writes_vector() {
                if let Some(dst) = instr.dst() {
                    for (i, dev) in self.devices.iter_mut().enumerate() {
                        if i != home {
                            // Siblings can only hold dst clean (dirty
                            // copies live in the owner == home).
                            let dirty = dev.vcache.invalidate(dst);
                            debug_assert!(
                                dirty.is_none(),
                                "dirty vectors live only in their owner's device"
                            );
                            let _ = dirty;
                        }
                    }
                }
            }
        }
        let mut port = FabricPort::new(&mut *fabric, home);
        self.devices[home].execute(instr, dispatch, &mut port)
    }

    /// Functional-phase twin of [`execute`](Self::execute): same home
    /// routing, same coherence walk (owner flushes, sibling invalidations)
    /// and the same per-device vector-cache call order — so tags, LRU
    /// stamps and dirty bits stay bit-identical to detailed execution —
    /// but all DRAM traffic flows through the clock-free functional
    /// accessors and no FU or hop timing accrues.
    pub fn execute_functional(
        &mut self,
        instr: &VimaInstr,
        fabric: &mut MemFabric,
    ) -> Result<()> {
        let home = self.home_cube(instr, fabric);
        if home != 0 {
            self.remote_home_instrs += 1;
        }
        if self.devices.len() > 1 {
            for s in instr.unique_src_addrs() {
                let owner = fabric.cube_of(s);
                if owner != home {
                    self.devices[owner].flush_vector_functional(s, |a, w| {
                        fabric.vima_access_functional_from(owner, a, w)
                    });
                }
            }
            if instr.op.writes_vector() {
                if let Some(dst) = instr.dst() {
                    for (i, dev) in self.devices.iter_mut().enumerate() {
                        if i != home {
                            let dirty = dev.vcache.invalidate(dst);
                            debug_assert!(
                                dirty.is_none(),
                                "dirty vectors live only in their owner's device"
                            );
                            let _ = dirty;
                        }
                    }
                }
            }
        }
        self.devices[home]
            .execute_functional(instr, |a, w| fabric.vima_access_functional_from(home, a, w))
    }

    /// Fold every device's vector-cache state into `h` (sampled-mode
    /// state-parity digests; see `Machine::state_digest`).
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        for d in &self.devices {
            d.vcache.digest_into(h);
        }
    }

    /// End-of-run drain: write back every device's dirty vectors to its
    /// own cube and wait for the whole fabric to settle.
    pub fn drain(&mut self, at: u64, fabric: &mut MemFabric) -> u64 {
        let mut end = at;
        for (home, dev) in self.devices.iter_mut().enumerate() {
            let mut port = FabricPort::new(&mut *fabric, home);
            end = end.max(dev.drain(at, &mut port));
        }
        end
    }

    /// Aggregate device counters under the classic `vima.*` keys by
    /// merging each device's own [`VimaDevice::dump_stats`] report —
    /// counters sum, `*.busy_until` combines by max
    /// ([`StatsReport::merge`]'s gauge rule) — so any counter a device
    /// grows in the future aggregates without touching this code.
    /// Multi-cube runs additionally report the per-device busy-time sum
    /// (drives the per-cube energy model) and the dispatcher's routing
    /// counters; single-cube reports carry exactly the pre-fabric key set.
    pub fn dump_stats(&self, report: &mut StatsReport) {
        let mut agg = StatsReport::new();
        for d in &self.devices {
            let mut one = StatsReport::new();
            d.dump_stats(&mut one);
            agg.merge(&one);
        }
        for (k, v) in agg.iter() {
            report.add(k, v);
        }
        if self.devices.len() > 1 {
            let busy_sum: u64 = self.devices.iter().map(|d| d.stats.busy_until).sum();
            report.add("vima.devices", self.devices.len() as f64);
            report.add("vima.busy_cycles_sum", busy_sum as f64);
            report.add("vima.remote_home_instrs", self.remote_home_instrs as f64);
        }
    }

    pub fn reset(&mut self) {
        for d in &mut self.devices {
            d.reset();
        }
        self.remote_home_instrs = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{VDtype, VimaOp};

    fn cfg_with(cubes: usize) -> Mem3DConfig {
        let mut cfg = Mem3DConfig::default();
        cfg.num_cubes = cubes;
        cfg
    }

    #[test]
    fn single_cube_routes_everything_to_cube_zero_for_free() {
        let mut fab = MemFabric::new(&cfg_with(1), 2.0).unwrap();
        let mut raw = Mem3D::new(&Mem3DConfig::default(), 2.0).unwrap();
        for i in 0..200u64 {
            let addr = i * 4096 + (i % 7) * 64;
            let w = i % 3 == 0;
            assert_eq!(fab.cube_of(addr), 0);
            let a = fab.host_access(addr, w, i);
            let b = raw.host_access(addr, w, i);
            assert_eq!(a, b, "host access diverged at line {i}");
            let a = fab.vima_access_from(0, addr, !w, i);
            let b = raw.vima_access(addr, !w, i);
            assert_eq!(a, b, "vima access diverged at line {i}");
        }
        assert_eq!(fab.stats.cross_cube_lines, 0);
        assert_eq!(fab.stats.hop_cycles, 0);
        assert_eq!(fab.drained_at(), raw.drained_at());
        let t = fab.stats_total();
        assert_eq!(
            (t.host_reads, t.host_writes, t.vima_reads, t.vima_writes),
            (
                raw.stats.host_reads,
                raw.stats.host_writes,
                raw.stats.vima_reads,
                raw.stats.vima_writes
            )
        );
    }

    #[test]
    fn sharding_covers_all_cubes_and_keeps_vectors_whole() {
        let fab = MemFabric::new(&cfg_with(8), 2.0).unwrap();
        let mut seen = std::collections::HashSet::new();
        for v in 0..4096u64 {
            let base = v * 8192;
            let cube = fab.cube_of(base);
            seen.insert(cube);
            // Every 64 B line of an 8 KB-aligned vector lives in one cube.
            for line in 0..128u64 {
                assert_eq!(fab.cube_of(base + line * 64), cube, "vector {v} straddles cubes");
            }
        }
        assert_eq!(seen.len(), 8, "shard hash must reach every cube");
    }

    #[test]
    fn chained_host_reads_pay_hops_per_direction() {
        let mut cfg = cfg_with(4);
        cfg.cube_hop_cycles = 100; // exaggerate for visibility
        let mut fab = MemFabric::new(&cfg, 2.0).unwrap();
        // Find a vector block owned by a chained cube.
        let addr = (0..1024u64)
            .map(|v| v * 8192)
            .find(|&a| fab.cube_of(a) > 0)
            .expect("some block must live off cube 0");
        let cube = fab.cube_of(addr);
        let mut near = Mem3D::new(&cfg, 2.0).unwrap();
        let far = fab.host_access(addr, false, 0).done;
        let base = near.host_access(addr, false, 0).done;
        assert_eq!(far, base + 2 * 100 * cube as u64, "read pays {cube} hops each way");
        assert_eq!(fab.stats.chained_host_lines, 1);
        assert_eq!(fab.stats.hop_cycles, 2 * 100 * cube as u64);
    }

    #[test]
    fn cross_cube_gather_is_slower_than_local() {
        let mut cfg = cfg_with(4);
        cfg.cube_hop_cycles = 50;
        let mut fab = MemFabric::new(&cfg, 2.0).unwrap();
        let addr = (0..1024u64)
            .map(|v| v * 8192)
            .find(|&a| fab.cube_of(a) > 0)
            .expect("some block must live off cube 0");
        let owner = fab.cube_of(addr);
        let local = fab.vima_access_from(owner, addr, false, 0).done;
        fab.reset();
        let remote = fab.vima_access_from(0, addr, false, 0).done;
        assert_eq!(remote, local + 2 * 50 * owner as u64);
        assert_eq!(fab.stats.cross_cube_lines, 1);
    }

    #[test]
    fn dispatcher_single_device_matches_raw_device() {
        // One cube: the dispatcher must be indistinguishable from driving
        // a lone VimaDevice over a lone Mem3D — the bit-identical contract
        // every paper figure relies on.
        let vcfg = VimaConfig::default();
        let mut disp = VimaDispatcher::new(&vcfg, 1, 2.0, 1);
        let mut fab = MemFabric::new(&cfg_with(1), 2.0).unwrap();
        let mut dev = VimaDevice::new(&vcfg, 1, 2.0);
        let mut raw = Mem3D::new(&Mem3DConfig::default(), 2.0).unwrap();
        let mut t_a = 0;
        let mut t_b = 0;
        for i in 0..24u64 {
            let base = i * 0x6000;
            let instr = VimaInstr::new(
                VimaOp::Add,
                VDtype::F32,
                &[base, base + 0x2000],
                Some(base + 0x4000),
                8192,
            );
            t_a = disp.execute(&instr, t_a, &mut fab).unwrap();
            t_b = dev.execute(&instr, t_b, &mut raw).unwrap();
            assert_eq!(t_a, t_b, "instruction {i} diverged");
        }
        let da = disp.drain(t_a, &mut fab);
        let db = dev.drain(t_b, &mut raw);
        assert_eq!(da, db);
        let mut ra = StatsReport::new();
        disp.dump_stats(&mut ra);
        let mut rb = StatsReport::new();
        dev.dump_stats(&mut rb);
        assert_eq!(ra, rb, "single-device dispatcher stats must match raw device");
    }

    #[test]
    fn dispatcher_routes_homes_across_cubes() {
        let vcfg = VimaConfig::default();
        let mut disp = VimaDispatcher::new(&vcfg, 1, 2.0, 4);
        let mut fab = MemFabric::new(&cfg_with(4), 2.0).unwrap();
        let mut t = 0;
        for i in 0..64u64 {
            let base = i * 0x6000;
            let instr = VimaInstr::new(
                VimaOp::Add,
                VDtype::F32,
                &[base, base + 0x2000],
                Some(base + 0x4000),
                8192,
            );
            t = disp.execute(&instr, t, &mut fab).unwrap();
        }
        assert!(disp.remote_home_instrs > 0, "homes must spread off cube 0");
        let used: usize =
            (0..4).filter(|&i| disp.device(i).stats.instructions > 0).count();
        assert!(used >= 2, "at least two cubes' devices must execute");
        assert!(fab.stats.cross_cube_lines > 0, "streaming operands must gather cross-cube");
    }

    #[test]
    fn cross_home_read_of_dirty_vector_forces_owner_writeback() {
        // Producer/consumer across homes: instr 1 leaves its result dirty
        // in the owning cube's vcache (no DRAM write yet); a consumer
        // homed elsewhere must see the owner post the write-back before
        // gathering — data can't be read from DRAM it never reached.
        let vcfg = VimaConfig::default();
        let mut disp = VimaDispatcher::new(&vcfg, 1, 2.0, 4);
        let mut fab = MemFabric::new(&cfg_with(4), 2.0).unwrap();
        let block = |cube: usize, skip: u64| {
            (0..4096u64)
                .map(|i| i * 8192)
                .find(|&a| fab.cube_of(a) == cube && a != skip)
                .expect("shard hash reaches every cube")
        };
        let v = block(2, u64::MAX);
        let d = block(0, u64::MAX);
        let w = block(0, d);

        let produce = VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(v), 8192);
        let t = disp.execute(&produce, 0, &mut fab).unwrap();
        assert_eq!(fab.cube(2).stats.vima_writes, 0, "result sits in the vcache, not DRAM");

        // Consumer homed on cube 0 (dst d) reads v, owned by cube 2.
        let consume = VimaInstr::new(VimaOp::Add, VDtype::F32, &[v, w], Some(d), 8192);
        disp.execute(&consume, t, &mut fab).unwrap();
        assert_eq!(
            fab.cube(2).stats.vima_writes,
            128,
            "dirty producer must flush to its own cube before the gather"
        );
        assert!(disp.device(2).vcache.dirty_lines().is_empty(), "copy downgraded to clean");
    }

    #[test]
    fn rewriting_a_vector_invalidates_stale_sibling_copies() {
        // Ping-pong pattern: a device gathers a remote vector (cached
        // clean), the owner rewrites it, and the first device reads it
        // again — the stale clean copy must be dropped so the re-read is
        // charged a full cross-cube re-gather, not a one-cycle tag hit.
        let vcfg = VimaConfig::default();
        let mut disp = VimaDispatcher::new(&vcfg, 1, 2.0, 4);
        let mut fab = MemFabric::new(&cfg_with(4), 2.0).unwrap();
        let block = |cube: usize, skip: u64| {
            (0..4096u64)
                .map(|i| i * 8192)
                .find(|&a| fab.cube_of(a) == cube && a != skip)
                .expect("shard hash reaches every cube")
        };
        let a = block(2, u64::MAX);
        let b = block(0, u64::MAX);
        let b2 = block(0, b);

        // 1. Consumer homed on cube 0 gathers `a` (owned by cube 2).
        let gather = VimaInstr::new(VimaOp::Add, VDtype::F32, &[a, b2], Some(b), 8192);
        let t = disp.execute(&gather, 0, &mut fab).unwrap();
        let reads = fab.cube(2).stats.vima_reads;
        assert_eq!(reads, 128, "first gather reads the owner's vaults");

        // 2. The owner rewrites `a` (homed on cube 2).
        let rewrite = VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(a), 8192);
        let t = disp.execute(&rewrite, t, &mut fab).unwrap();

        // 3. Re-consume on cube 0: `b2` is still cached there, but `a`
        //    must re-fetch from cube 2 (after the owner's flush).
        disp.execute(&gather, t, &mut fab).unwrap();
        assert_eq!(
            fab.cube(2).stats.vima_reads,
            reads + 128,
            "stale sibling copy must be dropped and re-gathered"
        );
    }

    #[test]
    fn fabric_rejects_bad_cube_counts() {
        let e = MemFabric::new(&cfg_with(3), 2.0).unwrap_err().to_string();
        assert!(e.contains("num_cubes") && e.contains('3'), "{e}");
        let mut cfg = cfg_with(2);
        cfg.cube_shard_bytes = 100;
        let e = MemFabric::new(&cfg, 2.0).unwrap_err().to_string();
        assert!(e.contains("cube_shard_bytes"), "{e}");
    }
}
