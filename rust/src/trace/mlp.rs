//! MLP inference kernel traces (Sec. IV-A): 16384 test instances with F
//! features (F in {64, 256, 1024} = 4/16/64 MB instance data), H hidden
//! neurons.
//!
//! Both backends run neuron-major (for each neuron, stream the instance
//! matrix), which re-reads the instance data H times — the access pattern
//! that makes LLC fit the deciding factor, matching Fig. 3's kNN/MLP
//! discussion.
//!
//! * **AVX**: per (neuron, instance): AVX-512 dot product over F features.
//! * **VIMA**: feature-major instance matrix; per (neuron, chunk-of-2048
//!   instances, feature): broadcast the weight, FMA the instance column
//!   into a resident accumulator; ReLU at the end; host reads activations.

use super::{emit, layout, TraceChunker, TraceParams};
use crate::isa::{FuType, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};

pub const INSTANCES: u64 = 16384;
pub const NEURONS: u64 = 32;
/// Neurons actually simulated (uniform work; harness extrapolates).
pub const SIM_NEURONS: u64 = 4;

pub fn features_for(footprint: u64) -> u64 {
    (footprint / (INSTANCES * 4)).max(4)
}

pub fn scale_factor() -> f64 {
    NEURONS as f64 / SIM_NEURONS as f64
}

// ------------------------------------------------------------------- AVX ----

pub struct MlpAvx {
    f: u64,
    neuron: u64,
    end_neuron: u64,
    inst: u64,
}

impl MlpAvx {
    pub fn new(p: &TraceParams) -> Self {
        let f = features_for(p.footprint);
        let (lo, hi) = p.slice(SIM_NEURONS);
        Self { f, neuron: lo, end_neuron: hi, inst: 0 }
    }
}

impl TraceChunker for MlpAvx {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.neuron >= self.end_neuron {
            return false;
        }
        // One chunk = dot(weights[neuron], x[inst]) + relu + store. Four
        // rotating accumulators break the FMA chain (unrolled reduction).
        let x = layout::A + self.inst * self.f * 4;
        let w = layout::B + self.neuron * self.f * 4; // L1/L2-resident
        // zero-idiom accumulator clears (rename-stage, dependency-breaking)
        for a in 0..(self.f / 16).min(4) {
            buf.push(Uop::alu(0xAF0 + a * 4, FuType::Nop, [NO_REG; 3], (12 + a) as u8).into());
        }
        for c in 0..self.f / 16 {
            let rx = (c % 4) as u8;
            let rw = (4 + c % 4) as u8;
            let acc = (12 + c % 4) as u8;
            buf.push(Uop::load(0xB00, x + c * 64, 64, rx).into());
            buf.push(Uop::load(0xB08, w + c * 64, 64, rw).into());
            buf.push(Uop::alu(0xB10, FuType::FpMul, [rx, rw, acc], acc).into()); // fma
        }
        // combine accumulators (log-tree), shuffle-based horizontal reduce,
        // relu (max), store activation
        let acc = 15u8;
        let accs = (self.f / 16).min(4);
        if accs >= 2 {
            buf.push(Uop::alu(0xB20, FuType::FpAlu, [12, 13, NO_REG], 12).into());
        }
        if accs >= 4 {
            buf.push(Uop::alu(0xB24, FuType::FpAlu, [14, 15, NO_REG], 14).into());
            buf.push(Uop::alu(0xB28, FuType::FpAlu, [12, 14, NO_REG], 12).into());
        }
        buf.push(Uop::alu(0xB30, FuType::IntAlu, [12, NO_REG, NO_REG], 13).into()); // shuffle
        buf.push(Uop::alu(0xB34, FuType::FpAlu, [12, 13, NO_REG], 12).into());
        buf.push(Uop::alu(0xB38, FuType::IntAlu, [12, NO_REG, NO_REG], 13).into()); // shuffle
        buf.push(Uop::alu(0xB3C, FuType::FpAlu, [12, 13, NO_REG], acc).into());
        buf.push(Uop::alu(0xB40, FuType::FpAlu, [acc, NO_REG, NO_REG], acc).into()); // relu
        let out = layout::C + (self.neuron * INSTANCES + self.inst) * 4;
        buf.push(Uop::store(0xB48, out, 4, [acc, NO_REG, NO_REG]).into());

        self.inst += 1;
        if self.inst >= INSTANCES {
            self.inst = 0;
            self.neuron += 1;
        }
        emit::loop_ctl(buf, 0xB50, 16, self.neuron < self.end_neuron);
        true
    }
}

// ------------------------------------------------------------------ VIMA ----

/// Feature-major VIMA MLP. Instance column for (feature f, chunk c) lives at
/// `A + (f * chunks + c) * 8192`.
pub struct MlpVima {
    f: u64,
    chunks: u64,
    neuron: u64,
    end_neuron: u64,
    chunk: u64,
    feat: u64,
    vb: u32,
    scratch: u64,
}

impl MlpVima {
    pub fn new(p: &TraceParams) -> Self {
        let f = features_for(p.footprint);
        let vb = p.vector_bytes;
        let chunks = INSTANCES / (vb / 4) as u64;
        let (lo, hi) = p.slice(SIM_NEURONS);
        Self {
            f,
            chunks: chunks.max(1),
            neuron: lo,
            end_neuron: hi,
            chunk: 0,
            feat: 0,
            vb,
            scratch: layout::SCRATCH + p.thread as u64 * (1 << 20),
        }
    }
}

impl TraceChunker for MlpVima {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.neuron >= self.end_neuron {
            return false;
        }
        let vb = self.vb;
        let acc = self.scratch;
        let wb = self.scratch + vb as u64;

        if self.feat == 0 {
            buf.push(VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(acc), vb).into());
        }
        // scalar weight load + broadcast + FMA with the instance column
        let w_addr = layout::B + (self.neuron * self.f + self.feat) * 4;
        let col = layout::A + (self.feat * self.chunks + self.chunk) * 8192;
        buf.push(Uop::load(0xB80, w_addr, 4, 0).into());
        buf.push(VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(wb), vb).into());
        buf.push(VimaInstr::new(VimaOp::Fma, VDtype::F32, &[wb, col, acc], Some(acc), vb).into());
        // Loop-exit branch accounting must mirror the AVX generator: the
        // branch falls through exactly once, on the stream's last
        // (neuron, chunk, feature) iteration.
        let last = self.feat + 1 >= self.f
            && self.chunk + 1 >= self.chunks
            && self.neuron + 1 >= self.end_neuron;
        emit::loop_ctl(buf, 0xBA0, 16, !last);

        self.feat += 1;
        if self.feat >= self.f {
            self.feat = 0;
            // ReLU on the accumulated activations, then write result vector
            let out = layout::C + (self.neuron * self.chunks + self.chunk) * 8192;
            buf.push(VimaInstr::new(VimaOp::Max, VDtype::F32, &[acc, wb], Some(out), vb).into());
            self.chunk += 1;
            if self.chunk >= self.chunks {
                self.chunk = 0;
                self.neuron += 1;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId};

    #[test]
    fn features_match_paper_footprints() {
        assert_eq!(features_for(4 << 20), 64);
        assert_eq!(features_for(16 << 20), 256);
        assert_eq!(features_for(64 << 20), 1024);
    }

    #[test]
    fn avx_instance_loads_dominate() {
        let p = TraceParams::new(KernelId::Mlp, Backend::Avx, 4 << 20);
        let loads = p
            .stream().unwrap()
            .filter(|e| {
                matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Load && u.addr < layout::B)
            })
            .count() as u64;
        assert_eq!(loads, SIM_NEURONS * INSTANCES * (64 / 16));
    }

    #[test]
    fn vima_fma_count() {
        let p = TraceParams::new(KernelId::Mlp, Backend::Vima, 4 << 20);
        let fmas = p
            .stream().unwrap()
            .filter(|e| matches!(e, TraceEvent::Vima(v) if v.op == VimaOp::Fma))
            .count() as u64;
        // chunks = 16384/2048 = 8, F = 64
        assert_eq!(fmas, SIM_NEURONS * 8 * 64);
    }

    #[test]
    fn vima_loop_branch_exits_exactly_once() {
        // Branch accounting parity with the AVX generator: one not-taken
        // loop-exit branch per stream (it used to emit taken=true forever).
        let p = TraceParams::new(KernelId::Mlp, Backend::Vima, 4 << 20);
        let branches: Vec<bool> = p
            .stream()
            .unwrap()
            .filter_map(|e| match e {
                TraceEvent::Uop(u) if u.fu == FuType::Branch => Some(u.taken),
                _ => None,
            })
            .collect();
        assert_eq!(branches.iter().filter(|&&t| !t).count(), 1);
        assert!(!branches.last().unwrap());
    }

    #[test]
    fn vima_emits_relu_per_chunk() {
        let p = TraceParams::new(KernelId::Mlp, Backend::Vima, 4 << 20);
        let relus = p
            .stream().unwrap()
            .filter(|e| matches!(e, TraceEvent::Vima(v) if v.op == VimaOp::Max))
            .count() as u64;
        assert_eq!(relus, SIM_NEURONS * 8);
    }
}
