//! kNN kernel traces (Sec. IV-A): classify test instances against 32768
//! training instances of F features; F in {32, 128, 512} gives the paper's
//! 4/16/64 MB training-set footprints.
//!
//! * **AVX**: row-major training set; per (test, train-row) an AVX-512
//!   inner loop computes the squared-L2 distance (2 loads, sub, mul,
//!   accumulate per 16 floats), then a scalar top-k insertion.
//! * **VIMA**: feature-major (column) layout — the standard NDP
//!   formulation: 2048 training rows are processed per 8 KB vector; for each
//!   feature, broadcast the test value, subtract the column vector, and
//!   FMA into a resident accumulator vector (reuse in the VIMA cache).
//!   The accumulated distance vector is then scanned on the host.
//!
//! Tests simulated are capped (work per test is uniform) — see
//! DESIGN.md §Sampling; harnesses extrapolate.

use super::{emit, layout, TraceChunker, TraceParams};
use crate::isa::{FuType, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};

pub const TRAIN_ROWS: u64 = 32768;
pub const PAPER_TESTS: u64 = 256;
/// Tests actually simulated (uniform work per test; results extrapolate).
pub const SIM_TESTS: u64 = 16;

/// Features from footprint: footprint = TRAIN_ROWS * F * 4.
pub fn features_for(footprint: u64) -> u64 {
    (footprint / (TRAIN_ROWS * 4)).max(4)
}

pub fn scale_factor() -> f64 {
    PAPER_TESTS as f64 / SIM_TESTS as f64
}

// ------------------------------------------------------------------- AVX ----

pub struct KnnAvx {
    f: u64,
    test: u64,
    end_test: u64,
    row: u64,
    row_stride: u64,
}

impl KnnAvx {
    pub fn new(p: &TraceParams) -> Self {
        let f = features_for(p.footprint);
        let (lo, hi) = p.slice(SIM_TESTS);
        Self { f, test: lo, end_test: hi, row: 0, row_stride: f * 4 }
    }
}

impl TraceChunker for KnnAvx {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.test >= self.end_test {
            return false;
        }
        // One chunk = distance(test, row) + top-k insertion. Four rotating
        // accumulators break the FMA dependency chain, as an unrolled -O3
        // reduction does.
        let train = layout::A + self.row * self.row_stride;
        let test = layout::B + self.test * self.row_stride;
        // zero-idiom accumulator clears (rename-stage, dependency-breaking)
        for a in 0..(self.f / 16).min(4) {
            buf.push(Uop::alu(0x9F0 + a * 4, FuType::Nop, [NO_REG; 3], (12 + a) as u8).into());
        }
        for c in 0..self.f / 16 {
            let rt = (c % 4) as u8;
            let rr = (4 + c % 4) as u8;
            let rd = (8 + c % 4) as u8;
            let acc = (12 + c % 4) as u8;
            buf.push(Uop::load(0xA00, train + c * 64, 64, rr).into());
            buf.push(Uop::load(0xA08, test + c * 64, 64, rt).into()); // L1-resident
            buf.push(Uop::alu(0xA10, FuType::FpAlu, [rr, rt, NO_REG], rd).into()); // sub
            buf.push(Uop::alu(0xA18, FuType::FpMul, [rd, rd, acc], acc).into()); // fma
        }
        // Combine however many accumulators the row used (log-tree), then a
        // shuffle-based horizontal reduce (shuffles go to the integer/shuffle
        // port, adds to the FP port), then heap-style top-k: one compare
        // against the current k-th distance, branch rarely taken.
        let acc = 15u8;
        let accs = (self.f / 16).min(4);
        if accs >= 2 {
            buf.push(Uop::alu(0xA20, FuType::FpAlu, [12, 13, NO_REG], 12).into());
        }
        if accs >= 4 {
            buf.push(Uop::alu(0xA24, FuType::FpAlu, [14, 15, NO_REG], 14).into());
            buf.push(Uop::alu(0xA28, FuType::FpAlu, [12, 14, NO_REG], 12).into());
        }
        buf.push(Uop::alu(0xA30, FuType::IntAlu, [12, NO_REG, NO_REG], 13).into()); // shuffle
        buf.push(Uop::alu(0xA34, FuType::FpAlu, [12, 13, NO_REG], 12).into());
        buf.push(Uop::alu(0xA38, FuType::IntAlu, [12, NO_REG, NO_REG], 13).into()); // shuffle
        buf.push(Uop::alu(0xA3C, FuType::FpAlu, [12, 13, NO_REG], acc).into());
        buf.push(Uop::alu(0xA40, FuType::IntAlu, [acc, 14, NO_REG], NO_REG).into()); // cmp kth
        buf.push(Uop::branch(0xA60, self.row % 23 == 0).into()); // rare heap insert

        self.row += 1;
        if self.row >= TRAIN_ROWS {
            self.row = 0;
            self.test += 1;
        }
        emit::loop_ctl(buf, 0xA70, 16, self.test < self.end_test);
        true
    }
}

// ------------------------------------------------------------------ VIMA ----

/// Feature-major VIMA kNN. Column vector for (feature f, chunk c) lives at
/// `A + (f * chunks + c) * 8192`.
pub struct KnnVima {
    f: u64,
    chunks: u64,
    test: u64,
    end_test: u64,
    chunk: u64,
    feat: u64,
    vb: u32,
    scan: bool,
    scan_line: u64,
    scratch: u64,
}

impl KnnVima {
    pub fn new(p: &TraceParams) -> Self {
        let f = features_for(p.footprint);
        let vb = p.vector_bytes;
        let rows_per_vec = (vb / 4) as u64;
        let chunks = TRAIN_ROWS / rows_per_vec;
        let (lo, hi) = p.slice(SIM_TESTS);
        Self {
            f,
            chunks,
            test: lo,
            end_test: hi,
            chunk: 0,
            feat: 0,
            vb,
            scan: false,
            scan_line: 0,
            scratch: layout::SCRATCH + p.thread as u64 * (1 << 20),
        }
    }
}

impl TraceChunker for KnnVima {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.test >= self.end_test {
            return false;
        }
        let vb = self.vb;
        let acc = self.scratch;
        let tb = self.scratch + vb as u64;
        let d = self.scratch + 2 * vb as u64;

        if self.scan {
            // Host scans the finished 8 KB distance vector: 64 B loads +
            // scalar compare/branch per line (top-k maintenance).
            let addr = acc + self.scan_line * 64;
            buf.push(Uop::load(0xA80, addr, 64, 1).into());
            buf.push(Uop::alu(0xA88, FuType::IntAlu, [1, NO_REG, NO_REG], 2).into());
            buf.push(Uop::branch(0xA90, self.scan_line % 9 != 0).into());
            self.scan_line += 1;
            if self.scan_line >= (vb / 64) as u64 {
                self.scan_line = 0;
                self.scan = false;
                self.chunk += 1;
                if self.chunk >= self.chunks {
                    self.chunk = 0;
                    self.test += 1;
                }
            }
            return true;
        }

        if self.feat == 0 {
            // zero the accumulator vector
            buf.push(VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(acc), vb).into());
        }
        // scalar load of test[t][f], broadcast, subtract column, FMA into acc
        let test_addr = layout::B + self.test * self.f * 4 + self.feat * 4;
        let col = layout::A + (self.feat * self.chunks + self.chunk) * 8192;
        buf.push(Uop::load(0xAA0, test_addr, 4, 0).into());
        buf.push(VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(tb), vb).into());
        buf.push(VimaInstr::new(VimaOp::Sub, VDtype::F32, &[col, tb], Some(d), vb).into());
        buf.push(VimaInstr::new(VimaOp::Fma, VDtype::F32, &[d, d, acc], Some(acc), vb).into());
        // Loop-exit branch accounting must mirror the AVX generator: the
        // feature loop's branch falls through exactly once, at the last
        // feature of the last chunk of the last test instance.
        let last = self.feat + 1 >= self.f
            && self.chunk + 1 >= self.chunks
            && self.test + 1 >= self.end_test;
        emit::loop_ctl(buf, 0xAC0, 16, !last);

        self.feat += 1;
        if self.feat >= self.f {
            self.feat = 0;
            self.scan = true; // distances done: host reads them back
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId};

    #[test]
    fn features_match_paper_footprints() {
        assert_eq!(features_for(4 << 20), 32);
        assert_eq!(features_for(16 << 20), 128);
        assert_eq!(features_for(64 << 20), 512);
    }

    #[test]
    fn avx_streams_whole_training_set_per_test() {
        let p = TraceParams::new(KernelId::Knn, Backend::Avx, 1 << 20);
        let f = features_for(1 << 20); // 8 features
        let loads = p
            .stream().unwrap()
            .filter(|e| {
                matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Load && u.addr < layout::B)
            })
            .count() as u64;
        // f/16 rounds to 0 chunks for f=8 -> min 0; use bigger footprint
        let _ = (f, loads);
        let p = TraceParams::new(KernelId::Knn, Backend::Avx, 4 << 20);
        let loads = p
            .stream().unwrap()
            .filter(|e| {
                matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Load && u.addr < layout::B)
            })
            .count() as u64;
        assert_eq!(loads, SIM_TESTS * TRAIN_ROWS * (32 / 16));
    }

    #[test]
    fn vima_acc_is_reused_per_feature() {
        let p = TraceParams::new(KernelId::Knn, Backend::Vima, 4 << 20);
        let mut acc_writes = 0u64;
        let mut fmas = 0u64;
        for e in p.stream().unwrap() {
            if let TraceEvent::Vima(v) = e {
                match v.op {
                    VimaOp::Fma => fmas += 1,
                    VimaOp::Bcast if v.dst() == Some(layout::SCRATCH) => acc_writes += 1,
                    _ => {}
                }
            }
        }
        // acc zeroed once per (test, chunk); FMA once per feature
        assert_eq!(acc_writes, SIM_TESTS * 16);
        assert_eq!(fmas, SIM_TESTS * 16 * 32);
    }

    #[test]
    fn vima_feature_loop_branch_exits_exactly_once() {
        // Branch accounting parity with the AVX generator: the feature
        // loop's branch (pc 0xAC4; the 0xA90 scan branches are
        // data-dependent) falls through exactly once, at the end of the
        // stream's last feature loop (it used to emit taken=true forever).
        let p = TraceParams::new(KernelId::Knn, Backend::Vima, 4 << 20);
        let exits = p
            .stream()
            .unwrap()
            .filter(|e| {
                matches!(e, TraceEvent::Uop(u)
                    if u.fu == FuType::Branch && u.pc == 0xAC4 && !u.taken)
            })
            .count();
        assert_eq!(exits, 1);
    }

    #[test]
    fn vima_host_scans_distances() {
        let p = TraceParams::new(KernelId::Knn, Backend::Vima, 4 << 20);
        let scans = p
            .stream().unwrap()
            .filter(|e| {
                matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Load && u.addr >= layout::SCRATCH && u.addr < layout::SCRATCH + 8192)
            })
            .count() as u64;
        assert_eq!(scans, SIM_TESTS * 16 * 128); // 128 lines per 8 KB vector
    }
}
