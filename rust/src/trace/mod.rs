//! Synthetic trace generation — the Pin replacement (Sec. IV-A).
//!
//! The paper traced real binaries with Pin + Intrinsics-VIMA; the simulator
//! consumes *dynamic* instruction streams, and the seven kernels are tiny,
//! fully-specified loops, so we regenerate equivalent streams directly:
//!
//! * **AVX backend** — the µop stream an x86-64 + AVX-512 compiler emits for
//!   the kernel (64 B vector loads/stores, FMAs, pointer bumps, loop
//!   branches, the same unrolling a `-O3` build uses).
//! * **VIMA backend** — the same kernel compiled against Intrinsics-VIMA:
//!   one 8 KB vector instruction where AVX needs 128 iterations, plus the
//!   scalar loop-control µops that remain on the host.
//! * **HIVE backend** — the kernel written as HIVE transactions
//!   (lock / explicit register loads / compute / unlock).
//!
//! Streams are generated lazily in chunks (one outer-loop iteration per
//! refill) so multi-gigabyte-footprint workloads never materialize a trace.

pub mod knn;
pub mod matmul;
pub mod mlp;
pub mod stencil;
pub mod streaming;

use crate::isa::TraceEvent;
use crate::util::error::Result;
use crate::workload::{self, WorkloadId};

/// Which ISA the kernel was "compiled" for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    Avx,
    Vima,
    Hive,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Avx => write!(f, "AVX"),
            Backend::Vima => write!(f, "VIMA"),
            Backend::Hive => write!(f, "HIVE"),
        }
    }
}

impl std::str::FromStr for Backend {
    type Err = crate::util::error::Error;

    /// Case-insensitive backend name, as the CLI and the `serve` JSONL
    /// protocol spell it; unknown names enumerate the valid choices.
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "avx" => Ok(Backend::Avx),
            "vima" => Ok(Backend::Vima),
            "hive" => Ok(Backend::Hive),
            _ => Err(crate::util::error::Error::msg(format!(
                "unknown backend {s:?}; valid backends: avx, vima, hive"
            ))),
        }
    }
}

/// The paper's seven kernels (Sec. IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelId {
    MemSet,
    MemCopy,
    VecSum,
    Stencil,
    MatMul,
    Knn,
    Mlp,
}

impl std::fmt::Display for KernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            KernelId::MemSet => "MemSet",
            KernelId::MemCopy => "MemCopy",
            KernelId::VecSum => "VecSum",
            KernelId::Stencil => "Stencil",
            KernelId::MatMul => "MatMul",
            KernelId::Knn => "kNN",
            KernelId::Mlp => "MLP",
        };
        write!(f, "{s}")
    }
}

/// Array base addresses used by every generator (1 GB apart, vector-aligned).
pub mod layout {
    pub const A: u64 = 0x1_0000_0000;
    pub const B: u64 = 0x2_0000_0000;
    pub const C: u64 = 0x3_0000_0000;
    /// Scratch temporaries (stencil partials, kNN accumulators...).
    pub const SCRATCH: u64 = 0x0_4000_0000;
}

/// A chunk-refilled trace producer. One `refill` = one outer-loop iteration;
/// returning `false` means the stream ended (nothing was appended).
///
/// `Send` is a supertrait so [`TraceStream`]s can cross into the sweep
/// engine's worker threads; every generator is plain owned data, so the
/// bound is free.
pub trait TraceChunker: Send {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool;
}

/// Events one [`TraceStream::fill`] aggregates per chunk: enough to
/// amortize the virtual refill call and the consumer's dispatch loop over
/// thousands of events, written and read strictly sequentially.
pub const CHUNK_TARGET: usize = 4096;

/// Event stream over a [`TraceChunker`].
///
/// The simulator's chunked hot path ([`crate::sim::Machine::run_chunk`])
/// consumes the refill buffer **in place** via [`fill`](Self::fill) /
/// [`chunk`](Self::chunk) / [`consume`](Self::consume) — no per-event
/// `Option` round trip, no copy into a second buffer, one virtual call per
/// ~[`CHUNK_TARGET`] events. The [`Iterator`] impl remains for tests and
/// offline tooling (collect, transpile) and pays one copy per event.
pub struct TraceStream {
    chunker: Box<dyn TraceChunker>,
    buf: Vec<TraceEvent>,
    pos: usize,
}

impl TraceStream {
    pub fn new(chunker: Box<dyn TraceChunker>) -> Self {
        Self { chunker, buf: Vec::with_capacity(CHUNK_TARGET), pos: 0 }
    }

    /// Ensure the buffer holds unconsumed events, aggregating as many
    /// chunker refills (one outer-loop iteration each) as fit the chunk
    /// target. Returns `false` once the stream is exhausted. The buffer is
    /// reused across fills, so the refill loop allocates nothing in steady
    /// state.
    pub fn fill(&mut self) -> bool {
        if self.pos < self.buf.len() {
            return true;
        }
        self.buf.clear();
        self.pos = 0;
        while self.buf.len() < CHUNK_TARGET && self.chunker.refill(&mut self.buf) {}
        !self.buf.is_empty()
    }

    /// Unconsumed slice of the current chunk (empty before the first
    /// [`fill`](Self::fill) and after exhaustion).
    pub fn chunk(&self) -> &[TraceEvent] {
        &self.buf[self.pos..]
    }

    /// Mark the first `n` events of [`chunk`](Self::chunk) consumed.
    pub fn consume(&mut self, n: usize) {
        debug_assert!(n <= self.buf.len() - self.pos);
        self.pos += n;
    }
}

impl Iterator for TraceStream {
    type Item = TraceEvent;

    fn next(&mut self) -> Option<TraceEvent> {
        if !self.fill() {
            return None;
        }
        let e = self.buf[self.pos];
        self.pos += 1;
        Some(e)
    }
}

/// Workload parameters handed to the generators. All-integer and
/// `Eq + Hash`: a `TraceParams` *is* the workload identity, so the sweep
/// engine keys its result cache on it directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceParams {
    /// Registry identity ([`KernelId`] converts for the paper kernels).
    pub workload: WorkloadId,
    pub backend: Backend,
    /// Total data footprint in bytes (the paper's "dataset" axis).
    pub footprint: u64,
    /// VIMA/HIVE vector size (8192 default; swept by the ablation).
    pub vector_bytes: u32,
    /// This thread's index and the total thread count (data-parallel slice).
    pub thread: usize,
    pub threads: usize,
}

impl TraceParams {
    pub fn new(workload: impl Into<WorkloadId>, backend: Backend, footprint: u64) -> Self {
        Self {
            workload: workload.into(),
            backend,
            footprint,
            vector_bytes: 8192,
            thread: 0,
            threads: 1,
        }
    }

    pub fn with_threads(mut self, thread: usize, threads: usize) -> Self {
        assert!(thread < threads);
        self.thread = thread;
        self.threads = threads;
        self
    }

    pub fn with_vector_bytes(mut self, vb: u32) -> Self {
        self.vector_bytes = vb;
        self
    }

    /// Slice `[0, n)` into `threads` contiguous ranges; returns this
    /// thread's `[lo, hi)`.
    pub fn slice(&self, n: u64) -> (u64, u64) {
        let per = n.div_ceil(self.threads as u64);
        let lo = (self.thread as u64 * per).min(n);
        let hi = (lo + per).min(n);
        (lo, hi)
    }

    /// Resolve the workload and validate these parameters without building
    /// a trace — the cheap pre-flight the sweep engine runs on every cell
    /// before dispatching to its worker pool.
    pub fn check(&self) -> Result<()> {
        let w = workload::get(self.workload)?;
        if !w.backends().contains(&self.backend) {
            let supported: Vec<String> = w.backends().iter().map(|b| b.to_string()).collect();
            crate::bail!(
                "no {} trace generator for {} (supported backends: {})",
                self.backend,
                w.name(),
                supported.join(", ")
            );
        }
        w.validate(self)
    }

    /// Build the event stream for these parameters through the workload
    /// registry. Unknown workloads, unsupported backends, and invalid
    /// parameters are typed errors (the old enum dispatch panicked).
    pub fn stream(&self) -> Result<TraceStream> {
        self.check()?;
        let w = workload::get(self.workload)?;
        Ok(TraceStream::new(w.chunker(self)?))
    }
}

/// Emission helpers shared by the generators.
pub(crate) mod emit {
    use crate::isa::{FuType, Reg, TraceEvent, Uop, NO_REG};

    /// AVX-512 vector width in bytes.
    pub const ZMM: u64 = 64;

    /// Scalar loop control: pointer bump + compare&branch (macro-fused).
    /// `taken` should be false on the final iteration.
    pub fn loop_ctl(buf: &mut Vec<TraceEvent>, pc: u64, ptr_reg: Reg, taken: bool) {
        buf.push(Uop::alu(pc, FuType::IntAlu, [ptr_reg, NO_REG, NO_REG], ptr_reg).into());
        buf.push(Uop::branch(pc + 4, taken).into());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::TraceEvent;

    fn count(params: TraceParams) -> (u64, u64, u64) {
        let (mut uops, mut vima, mut hive) = (0, 0, 0);
        for e in params.stream().unwrap() {
            match e {
                TraceEvent::Uop(_) => uops += 1,
                TraceEvent::Vima(_) => vima += 1,
                TraceEvent::Hive(_) => hive += 1,
            }
        }
        (uops, vima, hive)
    }

    #[test]
    fn every_generator_produces_events() {
        for kernel in [
            KernelId::MemSet,
            KernelId::MemCopy,
            KernelId::VecSum,
            KernelId::Stencil,
            KernelId::MatMul,
            KernelId::Knn,
            KernelId::Mlp,
        ] {
            for backend in [Backend::Avx, Backend::Vima] {
                let p = TraceParams::new(kernel, backend, 256 << 10);
                let (u, v, h) = count(p);
                assert!(u + v + h > 0, "{kernel}/{backend} empty");
                if backend == Backend::Vima {
                    assert!(v > 0, "{kernel}/VIMA produced no VIMA instructions");
                } else {
                    assert_eq!(v, 0, "{kernel}/AVX must not produce VIMA instrs");
                }
            }
        }
    }

    #[test]
    fn hive_generators_for_fig2_kernels() {
        for kernel in [KernelId::MemSet, KernelId::MemCopy, KernelId::VecSum, KernelId::Stencil] {
            let p = TraceParams::new(kernel, Backend::Hive, 256 << 10);
            let (_, v, h) = count(p);
            assert!(h > 0, "{kernel}/HIVE produced no HIVE ops");
            assert_eq!(v, 0);
        }
    }

    #[test]
    fn unsupported_backends_are_typed_errors() {
        // The HIVE gaps (MatMul/kNN/MLP) used to panic; now they are
        // results the CLI can surface.
        for kernel in [KernelId::MatMul, KernelId::Knn, KernelId::Mlp] {
            let p = TraceParams::new(kernel, Backend::Hive, 6 << 20);
            let e = p.stream().unwrap_err().to_string();
            assert!(e.contains("HIVE"), "{e}");
            assert!(e.contains(&kernel.to_string()), "{e}");
        }
    }

    #[test]
    fn chunk_api_yields_same_events_as_iterator() {
        let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 256 << 10);
        let via_iter: Vec<TraceEvent> = p.stream().unwrap().collect();
        let mut via_chunks = Vec::new();
        let mut s = p.stream().unwrap();
        while s.fill() {
            // Ragged consumption exercises partial-chunk bookkeeping.
            let n = (s.chunk().len() / 2).max(1);
            via_chunks.extend_from_slice(&s.chunk()[..n]);
            s.consume(n);
        }
        assert_eq!(via_iter.len(), via_chunks.len());
        assert!(via_iter == via_chunks, "chunked and iterated events must agree");
    }

    #[test]
    fn fill_aggregates_many_refills_per_chunk() {
        let p = TraceParams::new(KernelId::MemSet, Backend::Avx, 1 << 20);
        let mut s = p.stream().unwrap();
        assert!(s.fill());
        assert!(s.chunk().len() >= CHUNK_TARGET, "chunk too small: {}", s.chunk().len());
    }

    #[test]
    fn params_are_hashable_identity() {
        use std::collections::HashSet;
        let a = TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20);
        let b = TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20);
        let c = b.with_vector_bytes(256);
        let set: HashSet<TraceParams> = [a, b, c].into_iter().collect();
        assert_eq!(set.len(), 2, "equal params must collapse, distinct must not");
    }

    #[test]
    fn vima_moves_same_data_with_fewer_instructions() {
        let avx = count(TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20));
        let vima = count(TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20));
        // One 8 KB VIMA instr covers 128 AVX iterations.
        assert!(avx.0 > 50 * vima.1, "avx {avx:?} vs vima {vima:?}");
    }

    #[test]
    fn thread_slices_partition_the_stream() {
        let total = count(TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20));
        let mut sum = 0;
        for t in 0..4 {
            let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20).with_threads(t, 4);
            sum += count(p).0;
        }
        // Slices cover the same work within loop-overhead rounding.
        let diff = (sum as i64 - total.0 as i64).abs();
        assert!(diff < total.0 as i64 / 20, "sum {sum} vs total {}", total.0);
    }

    #[test]
    fn vector_size_scales_instruction_count() {
        let big = count(TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20));
        let small = count(
            TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20).with_vector_bytes(256),
        );
        assert!(small.1 >= 30 * big.1, "256 B vectors need ~32x instrs: {small:?} vs {big:?}");
    }

    #[test]
    fn footprint_scales_stream_length() {
        let small = count(TraceParams::new(KernelId::MemCopy, Backend::Avx, 1 << 20)).0;
        let large = count(TraceParams::new(KernelId::MemCopy, Backend::Avx, 4 << 20)).0;
        let ratio = large as f64 / small as f64;
        assert!((3.5..4.5).contains(&ratio), "ratio {ratio}");
    }
}
