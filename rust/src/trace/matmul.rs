//! MatMul kernel traces: C = A x B over n x n f32 matrices.
//!
//! Footprint convention (paper Sec. IV-A): the *three* matrices together are
//! 6/12/24 MB, i.e. n = 724 / 1024 / 1448. Rows are padded to an 8 KB stride
//! so each row starts vector-aligned; only the first `n * 4` bytes of a row
//! are ever touched, keeping the true traffic at the paper's footprint.
//!
//! Per Sec. IV-B1 the paper deliberately uses the *same straightforward
//! algorithm* on both systems:
//!
//! * **AVX**: textbook ijk — the inner product walks a B *column*, a strided
//!   access the cache hierarchy serves terribly (one line fetched per 4 B
//!   used). This is exactly why the paper reports large MatMul gains and
//!   notes a tiled AVX version would recover ~4x.
//! * **VIMA**: the vectorized form of the same loop nest, ikj — `C[i][*] +=
//!   A[i][k] * B[k][*]` with the C row staying resident in the VIMA cache
//!   across the whole k loop (the data-reuse showcase).

use super::{emit, layout, TraceChunker, TraceParams};
use crate::isa::{FuType, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};

/// Padded row stride: one VIMA vector per row.
pub const ROW_STRIDE: u64 = 8192;

/// Matrix dimension from the footprint (3 matrices of n^2 f32 each).
pub fn dim_for(footprint: u64) -> u64 {
    let per_matrix = footprint / 3;
    let n = ((per_matrix / 4) as f64).sqrt() as u64;
    n.max(16)
}

/// Fraction of i-rows actually simulated (work per row is uniform, so the
/// harness extrapolates total cycles; see DESIGN.md §Sampling).
#[derive(Debug, Clone, Copy)]
pub struct MatMulSampling {
    pub rows_simulated: u64,
    pub rows_total: u64,
}

pub fn sampling_for(p: &TraceParams) -> MatMulSampling {
    let n = dim_for(p.footprint);
    let (lo, hi) = p.slice(n);
    let rows_total = hi - lo;
    // Cap simulated rows: B-reuse steady state is reached within a few
    // rows. The cap is divided across threads (each thread's slice is
    // uniform work, so a few rows per thread suffice).
    let cap = (48 / p.threads as u64).max(6);
    let rows_simulated = rows_total.min(cap);
    MatMulSampling { rows_simulated, rows_total }
}

// ------------------------------------------------------------------- AVX ----

/// Naive ijk matmul: scalar inner product with strided B-column loads.
pub struct MatMulAvx {
    n: u64,
    i: u64,
    end_i: u64,
    j: u64,
    k: u64,
}

impl MatMulAvx {
    pub fn new(p: &TraceParams) -> Self {
        let n = dim_for(p.footprint);
        let (lo, _) = p.slice(n);
        let s = sampling_for(p);
        Self { n, i: lo, end_i: lo + s.rows_simulated, j: 0, k: 0 }
    }
}

impl TraceChunker for MatMulAvx {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.i >= self.end_i {
            return false;
        }
        // One chunk = 8 k-iterations of the inner product, unrolled with two
        // alternating accumulators (halves the FMA dependency chain — the
        // form -O3 emits for a reassociable reduction).
        let a_row = layout::A + self.i * ROW_STRIDE;
        for u in 0..8u64 {
            if self.k >= self.n {
                break;
            }
            let acc = (12 + u % 2) as u8; // alternating accumulators
            let ra = (u % 4) as u8;
            let rb = (4 + u % 4) as u8;
            buf.push(Uop::load(0x900 + u * 16, a_row + self.k * 4, 4, ra).into());
            // strided column walk: one fresh cache line per element
            buf.push(
                Uop::load(0x908 + u * 16, layout::B + self.k * ROW_STRIDE + self.j * 4, 4, rb)
                    .into(),
            );
            buf.push(Uop::alu(0x910 + u * 16, FuType::FpMul, [ra, rb, acc], acc).into());
            self.k += 1;
        }
        if self.k >= self.n {
            // combine accumulators, store C[i][j], advance j (then i)
            buf.push(Uop::alu(0x97C, FuType::FpAlu, [12, 13, NO_REG], 12).into());
            buf.push(
                Uop::store(
                    0x980,
                    layout::C + self.i * ROW_STRIDE + self.j * 4,
                    4,
                    [12, NO_REG, NO_REG],
                )
                .into(),
            );
            self.k = 0;
            self.j += 1;
            if self.j >= self.n {
                self.j = 0;
                self.i += 1;
            }
        }
        emit::loop_ctl(buf, 0x990, 16, !(self.i >= self.end_i));
        true
    }
}

// ------------------------------------------------------------------ VIMA ----

/// Vectorized ikj: per (i, k), broadcast A\[i\]\[k\] and FMA it with row
/// B\[k\]\[*\] into the resident C\[i\]\[*\] accumulator.
pub struct MatMulVima {
    n: u64,
    i: u64,
    end_i: u64,
    k: u64,
    row_bytes: u32,
    scratch: u64,
}

impl MatMulVima {
    pub fn new(p: &TraceParams) -> Self {
        let n = dim_for(p.footprint);
        let (lo, _) = p.slice(n);
        let s = sampling_for(p);
        let row_bytes = (n * 4).min(p.vector_bytes as u64) as u32;
        Self {
            n,
            i: lo,
            end_i: lo + s.rows_simulated,
            k: 0,
            row_bytes,
            scratch: layout::SCRATCH + p.thread as u64 * (1 << 20),
        }
    }
}

impl TraceChunker for MatMulVima {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.i >= self.end_i {
            return false;
        }
        let vb = self.row_bytes;
        let wb = self.scratch; // broadcast scratch vector (per-thread)
        let b_row = layout::B + self.k * ROW_STRIDE;
        let c_row = layout::C + self.i * ROW_STRIDE;
        // scalar load of A[i][k] feeding the broadcast
        buf.push(Uop::load(0x9C0, layout::A + self.i * ROW_STRIDE + self.k * 4, 4, 0).into());
        buf.push(VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(wb), vb).into());
        buf.push(VimaInstr::new(VimaOp::Fma, VDtype::F32, &[wb, b_row, c_row], Some(c_row), vb).into());
        self.k += 1;
        if self.k >= self.n {
            self.k = 0;
            self.i += 1;
        }
        emit::loop_ctl(buf, 0x9E0, 16, self.i < self.end_i);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId};

    #[test]
    fn dim_matches_paper_sizes() {
        assert_eq!(dim_for(6 << 20), 724);
        assert_eq!(dim_for(12 << 20), 1024);
        assert_eq!(dim_for(24 << 20), 1448);
    }

    #[test]
    fn avx_b_loads_are_strided() {
        let p = TraceParams::new(KernelId::MatMul, Backend::Avx, 3 << 20);
        let mut b_addrs = vec![];
        for e in p.stream().unwrap().take(4000) {
            if let TraceEvent::Uop(u) = e {
                if u.fu == FuType::Load && u.addr >= layout::B && u.addr < layout::C {
                    b_addrs.push(u.addr);
                }
            }
        }
        // consecutive B loads are ROW_STRIDE apart (column walk)
        assert!(b_addrs.len() > 2);
        assert_eq!(b_addrs[1] - b_addrs[0], ROW_STRIDE);
    }

    #[test]
    fn vima_c_row_is_reused_across_k() {
        let p = TraceParams::new(KernelId::MatMul, Backend::Vima, 3 << 20);
        let mut c_dsts = std::collections::HashMap::new();
        for e in p.stream().unwrap().take(20000) {
            if let TraceEvent::Vima(v) = e {
                if let Some(d) = v.dst() {
                    if d >= layout::C {
                        *c_dsts.entry(d).or_insert(0u32) += 1;
                    }
                }
            }
        }
        let max = c_dsts.values().max().copied().unwrap();
        assert!(max > 100, "C row must accumulate across the k loop: {max}");
    }

    #[test]
    fn vima_partial_vector_rows() {
        let p = TraceParams::new(KernelId::MatMul, Backend::Vima, 6 << 20);
        for e in p.stream().unwrap().take(100) {
            if let TraceEvent::Vima(v) = e {
                assert_eq!(v.vector_bytes, 724 * 4);
            }
        }
    }

    #[test]
    fn sampling_caps_simulated_rows() {
        let p = TraceParams::new(KernelId::MatMul, Backend::Avx, 24 << 20);
        let s = sampling_for(&p);
        assert_eq!(s.rows_total, 1448);
        assert_eq!(s.rows_simulated, 48);
    }
}
