//! Stencil kernel traces: 5-point convolution over an (H, W) f32 matrix.
//!
//! Footprint convention: input + output matrices together = `footprint`.
//! W is fixed at 2048 floats so one matrix row is exactly one 8 KB VIMA
//! vector — the layout Intrinsics-VIMA code uses (Sec. IV-B1: "data fetches
//! with a single element stride are expected and can be served by the
//! cache"). Rows are reused by three consecutive output rows:
//! VIMA serves that reuse from its vector cache, HIVE cannot (registers are
//! flushed at every unlock), AVX relies on L1/L2.

use super::{emit, layout, TraceChunker, TraceParams};
use crate::isa::{FuType, HiveOp, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};

/// Row width in f32 elements (2048 * 4 B = one 8 KB vector per row).
pub const W: u64 = 2048;
const ROW_BYTES: u64 = W * 4;

fn rows_for(p: &TraceParams) -> u64 {
    // input + output matrices = footprint
    (p.footprint / 2 / ROW_BYTES).max(3)
}

// ------------------------------------------------------------------- AVX ----

/// AVX-512 stencil row pass: per 16-float chunk, 5 loads (up, down, left,
/// right, center), 3 adds, 2 mul/fma, 1 store.
pub struct StencilAvx {
    row: u64,
    end_row: u64,
    col: u64,
}

impl StencilAvx {
    pub fn new(p: &TraceParams) -> Self {
        let h = rows_for(p);
        // interior rows [1, h-1)
        let (lo, hi) = p.slice(h.saturating_sub(2));
        Self { row: 1 + lo, end_row: 1 + hi, col: 0 }
    }
}

impl TraceChunker for StencilAvx {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.row >= self.end_row {
            return false;
        }
        let base = layout::A + self.row * ROW_BYTES + self.col * 4;
        // 16 floats per iteration.
        let (up, down) = (base - ROW_BYTES, base + ROW_BYTES);
        buf.push(Uop::load(0x800, up, 64, 0).into());
        buf.push(Uop::load(0x808, down, 64, 1).into());
        buf.push(Uop::load(0x810, base.saturating_sub(4), 64, 2).into()); // left (unaligned)
        buf.push(Uop::load(0x818, base + 4, 64, 3).into()); // right (unaligned)
        buf.push(Uop::load(0x820, base, 64, 4).into()); // center
        buf.push(Uop::alu(0x828, FuType::FpAlu, [0, 1, NO_REG], 5).into()); // up+down
        buf.push(Uop::alu(0x830, FuType::FpAlu, [2, 3, NO_REG], 6).into()); // left+right
        buf.push(Uop::alu(0x838, FuType::FpAlu, [5, 6, NO_REG], 7).into());
        buf.push(Uop::alu(0x840, FuType::FpMul, [7, 8, NO_REG], 9).into()); // * cn
        buf.push(Uop::alu(0x848, FuType::FpMul, [4, 10, 9], 11).into()); // fma center*cc + t
        let out = layout::B + self.row * ROW_BYTES + self.col * 4;
        buf.push(Uop::store(0x850, out, 64, [11, NO_REG, NO_REG]).into());

        self.col += 16;
        let mut row_done = false;
        if self.col >= W {
            self.col = 0;
            self.row += 1;
            row_done = true;
        }
        emit::loop_ctl(buf, 0x860, 16, !(row_done && self.row >= self.end_row));
        true
    }
}

// ------------------------------------------------------------------ VIMA ----

/// Intrinsics-VIMA stencil: one row = one vector. Per output row:
/// `t1 = up + down` (both usually cache hits thanks to row reuse),
/// `t2 = left + right` (aliases the center row: hits),
/// `t3 = t1 + t2`, `out = fma(center, cc_vec, cn*t3)`.
pub struct StencilVima {
    row: u64,
    end_row: u64,
    vb: u32,
    emitted_coeff: bool,
    scratch: u64,
}

impl StencilVima {
    pub fn new(p: &TraceParams) -> Self {
        let h = rows_for(p);
        let (lo, hi) = p.slice(h.saturating_sub(2));
        Self {
            row: 1 + lo,
            end_row: 1 + hi,
            vb: ROW_BYTES as u32,
            emitted_coeff: false,
            // disjoint per-thread temporaries
            scratch: layout::SCRATCH + p.thread as u64 * (1 << 20),
        }
    }
}

impl TraceChunker for StencilVima {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.row >= self.end_row {
            return false;
        }
        let vb = self.vb;
        let t1 = self.scratch;
        let t2 = self.scratch + vb as u64;
        let t3 = self.scratch + 2 * vb as u64;
        let coeff = self.scratch + 3 * vb as u64;
        if !self.emitted_coeff {
            // Broadcast the neighbour coefficient once; stays cache-resident.
            buf.push(VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(coeff), vb).into());
            self.emitted_coeff = true;
        }
        let up = layout::A + (self.row - 1) * ROW_BYTES;
        let cur = layout::A + self.row * ROW_BYTES;
        let down = layout::A + (self.row + 1) * ROW_BYTES;
        let out = layout::B + self.row * ROW_BYTES;
        buf.push(VimaInstr::new(VimaOp::Add, VDtype::F32, &[up, down], Some(t1), vb).into());
        // left+right alias the center row's aligned vector (stride-1 shifts).
        buf.push(VimaInstr::new(VimaOp::Add, VDtype::F32, &[cur, cur], Some(t2), vb).into());
        buf.push(VimaInstr::new(VimaOp::Add, VDtype::F32, &[t1, t2], Some(t3), vb).into());
        buf.push(VimaInstr::new(VimaOp::Mul, VDtype::F32, &[t3, coeff], Some(t3), vb).into());
        buf.push(VimaInstr::new(VimaOp::Fma, VDtype::F32, &[cur, coeff, t3], Some(out), vb).into());
        self.row += 1;
        emit::loop_ctl(buf, 0x8A0, 16, self.row < self.end_row);
        true
    }
}

// ------------------------------------------------------------------ HIVE ----

/// HIVE stencil: one transaction per output row; the lock/unlock protocol
/// flushes the register bank so row reuse is impossible — each input row is
/// re-fetched three times (the Fig. 2 contrast with VIMA).
pub struct StencilHive {
    row: u64,
    end_row: u64,
}

impl StencilHive {
    pub fn new(p: &TraceParams) -> Self {
        let h = rows_for(p);
        let (lo, hi) = p.slice(h.saturating_sub(2));
        Self { row: 1 + lo, end_row: 1 + hi }
    }
}

impl TraceChunker for StencilHive {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.row >= self.end_row {
            return false;
        }
        let up = layout::A + (self.row - 1) * ROW_BYTES;
        let cur = layout::A + self.row * ROW_BYTES;
        let down = layout::A + (self.row + 1) * ROW_BYTES;
        let out = layout::B + self.row * ROW_BYTES;
        buf.push(HiveOp::Lock.into());
        buf.push(HiveOp::LoadReg { reg: 0, addr: up }.into());
        buf.push(HiveOp::LoadReg { reg: 1, addr: cur }.into());
        buf.push(HiveOp::LoadReg { reg: 2, addr: down }.into());
        // coefficient broadcast into r3 every transaction (bank was flushed)
        buf.push(HiveOp::Compute { op: VimaOp::Bcast, dtype: VDtype::F32, r1: 3, r2: 3, rd: 3 }.into());
        buf.push(HiveOp::Compute { op: VimaOp::Add, dtype: VDtype::F32, r1: 0, r2: 2, rd: 4 }.into());
        buf.push(HiveOp::Compute { op: VimaOp::Add, dtype: VDtype::F32, r1: 1, r2: 1, rd: 5 }.into());
        buf.push(HiveOp::Compute { op: VimaOp::Add, dtype: VDtype::F32, r1: 4, r2: 5, rd: 6 }.into());
        buf.push(HiveOp::Compute { op: VimaOp::Mul, dtype: VDtype::F32, r1: 6, r2: 3, rd: 7 }.into());
        buf.push(HiveOp::StoreReg { reg: 7, addr: out }.into());
        buf.push(HiveOp::Unlock.into());
        self.row += 1;
        emit::loop_ctl(buf, 0x8E0, 16, self.row < self.end_row);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId};

    #[test]
    fn vima_rows_are_vector_aligned() {
        let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 512 << 10);
        for e in p.stream().unwrap() {
            if let TraceEvent::Vima(v) = e {
                for a in v.src_addrs() {
                    assert_eq!(a % 8192, 0, "unaligned vector src {a:#x}");
                }
            }
        }
    }

    #[test]
    fn vima_reuses_rows_across_iterations() {
        let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 512 << 10);
        let mut row_fetches = std::collections::HashMap::new();
        for e in p.stream().unwrap() {
            if let TraceEvent::Vima(v) = e {
                for a in v.src_addrs() {
                    if (layout::A..layout::B).contains(&a) {
                        *row_fetches.entry(a).or_insert(0u32) += 1;
                    }
                }
            }
        }
        // interior rows appear as up, center(x3: cur,cur,fma...), down
        let max = row_fetches.values().max().copied().unwrap_or(0);
        assert!(max >= 3, "rows must be referenced multiple times: {max}");
    }

    #[test]
    fn avx_emits_five_loads_per_chunk() {
        let p = TraceParams::new(KernelId::Stencil, Backend::Avx, 256 << 10);
        let evs: Vec<TraceEvent> = p.stream().unwrap().collect();
        let loads = evs
            .iter()
            .filter(|e| matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Load))
            .count();
        let stores = evs
            .iter()
            .filter(|e| matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Store))
            .count();
        assert_eq!(loads, stores * 5);
    }

    #[test]
    fn hive_reloads_every_row_three_times() {
        let p = TraceParams::new(KernelId::Stencil, Backend::Hive, 512 << 10);
        let mut loads = std::collections::HashMap::new();
        for e in p.stream().unwrap() {
            if let TraceEvent::Hive(HiveOp::LoadReg { addr, .. }) = e {
                *loads.entry(addr).or_insert(0u32) += 1;
            }
        }
        let interior_max = loads.values().max().copied().unwrap();
        assert_eq!(interior_max, 3, "no register reuse across HIVE transactions");
    }

    #[test]
    fn tiny_footprint_still_produces_rows() {
        let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 64 << 10);
        assert!(p.stream().unwrap().count() > 0);
    }
}
