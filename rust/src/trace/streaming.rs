//! Streaming kernels: MemSet, MemCopy, VecSum (Sec. IV-A).
//!
//! These have zero data reuse — the pure bandwidth workloads the paper's
//! intro motivates. Footprint convention (total bytes touched = `footprint`):
//! MemSet: one array; MemCopy: src+dst halves; VecSum: three equal arrays.

use super::{emit, layout, TraceChunker, TraceParams};
use crate::isa::{FuType, HiveOp, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};

// ---------------------------------------------------------------- MemSet ----

/// AVX-512 memset: 4x-unrolled 64 B stores from a pre-broadcast register.
pub struct MemSetAvx {
    pos: u64,
    end: u64,
}

impl MemSetAvx {
    pub fn new(p: &TraceParams) -> Self {
        let lines = p.footprint / emit::ZMM;
        let (lo, hi) = p.slice(lines);
        Self { pos: lo * emit::ZMM, end: hi * emit::ZMM }
    }
}

impl TraceChunker for MemSetAvx {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        // zmm0 holds the fill value (set once outside the loop; negligible).
        for u in 0..4 {
            if self.pos >= self.end {
                break;
            }
            buf.push(Uop::store(0x400 + u * 8, layout::A + self.pos, 64, [0, NO_REG, NO_REG]).into());
            self.pos += emit::ZMM;
        }
        emit::loop_ctl(buf, 0x440, 16, self.pos < self.end);
        true
    }
}

/// Intrinsics-VIMA memset: one broadcast instruction per vector.
pub struct MemSetVima {
    pos: u64,
    end: u64,
    vb: u64,
}

impl MemSetVima {
    pub fn new(p: &TraceParams) -> Self {
        let vecs = p.footprint / p.vector_bytes as u64;
        let (lo, hi) = p.slice(vecs);
        let vb = p.vector_bytes as u64;
        Self { pos: lo * vb, end: hi * vb, vb }
    }
}

impl TraceChunker for MemSetVima {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        buf.push(
            VimaInstr::new(VimaOp::Bcast, VDtype::I32, &[], Some(layout::A + self.pos), self.vb as u32)
                .into(),
        );
        self.pos += self.vb;
        emit::loop_ctl(buf, 0x480, 16, self.pos < self.end);
        true
    }
}

/// HIVE memset: transactions of 8 broadcast-computes + sequential write-back.
pub struct MemSetHive {
    pos: u64,
    end: u64,
    vb: u64,
    regs: u8,
}

impl MemSetHive {
    pub fn new(p: &TraceParams) -> Self {
        let vecs = p.footprint / p.vector_bytes as u64;
        let (lo, hi) = p.slice(vecs);
        let vb = p.vector_bytes as u64;
        Self { pos: lo * vb, end: hi * vb, vb, regs: 8 }
    }
}

impl TraceChunker for MemSetHive {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        buf.push(HiveOp::Lock.into());
        for r in 0..self.regs {
            if self.pos >= self.end {
                break;
            }
            // Broadcast the immediate into register r, then store it.
            buf.push(
                HiveOp::Compute { op: VimaOp::Bcast, dtype: VDtype::I32, r1: r, r2: r, rd: r }
                    .into(),
            );
            buf.push(HiveOp::StoreReg { reg: r, addr: layout::A + self.pos }.into());
            self.pos += self.vb;
            emit::loop_ctl(buf, 0x4C0, 16, self.pos < self.end);
        }
        buf.push(HiveOp::Unlock.into());
        true
    }
}

// --------------------------------------------------------------- MemCopy ----

/// AVX memcopy: 4x-unrolled load+store pairs.
pub struct MemCopyAvx {
    pos: u64,
    end: u64,
}

impl MemCopyAvx {
    pub fn new(p: &TraceParams) -> Self {
        let half = p.footprint / 2;
        let lines = half / emit::ZMM;
        let (lo, hi) = p.slice(lines);
        Self { pos: lo * emit::ZMM, end: hi * emit::ZMM }
    }
}

impl TraceChunker for MemCopyAvx {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        for u in 0..4u64 {
            if self.pos >= self.end {
                break;
            }
            let reg = (u % 4) as u8; // rotate zmm0-3 for ILP
            buf.push(Uop::load(0x500 + u * 16, layout::A + self.pos, 64, reg).into());
            buf.push(
                Uop::store(0x508 + u * 16, layout::B + self.pos, 64, [reg, NO_REG, NO_REG]).into(),
            );
            self.pos += emit::ZMM;
        }
        emit::loop_ctl(buf, 0x580, 16, self.pos < self.end);
        true
    }
}

/// Intrinsics-VIMA memcopy: one `_vim_mov` per vector.
pub struct MemCopyVima {
    pos: u64,
    end: u64,
    vb: u64,
}

impl MemCopyVima {
    pub fn new(p: &TraceParams) -> Self {
        let half = p.footprint / 2;
        let vecs = half / p.vector_bytes as u64;
        let (lo, hi) = p.slice(vecs);
        let vb = p.vector_bytes as u64;
        Self { pos: lo * vb, end: hi * vb, vb }
    }
}

impl TraceChunker for MemCopyVima {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        buf.push(
            VimaInstr::new(
                VimaOp::Mov,
                VDtype::I32,
                &[layout::A + self.pos],
                Some(layout::B + self.pos),
                self.vb as u32,
            )
            .into(),
        );
        self.pos += self.vb;
        emit::loop_ctl(buf, 0x5C0, 16, self.pos < self.end);
        true
    }
}

/// HIVE memcopy: per transaction, 4 loads then 4 (sequential) stores.
pub struct MemCopyHive {
    pos: u64,
    end: u64,
    vb: u64,
    /// (register, destination) of the transaction's staged stores, reused
    /// across refills so the chunk refill loop allocates nothing.
    staged: Vec<(u8, u64)>,
}

impl MemCopyHive {
    pub fn new(p: &TraceParams) -> Self {
        let half = p.footprint / 2;
        let vecs = half / p.vector_bytes as u64;
        let (lo, hi) = p.slice(vecs);
        let vb = p.vector_bytes as u64;
        Self { pos: lo * vb, end: hi * vb, vb, staged: Vec::with_capacity(4) }
    }
}

impl TraceChunker for MemCopyHive {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        buf.push(HiveOp::Lock.into());
        self.staged.clear();
        for r in 0..4u8 {
            if self.pos >= self.end {
                break;
            }
            buf.push(HiveOp::LoadReg { reg: r, addr: layout::A + self.pos }.into());
            self.staged.push((r, layout::B + self.pos));
            self.pos += self.vb;
            emit::loop_ctl(buf, 0x600, 16, self.pos < self.end);
        }
        for &(r, dst) in &self.staged {
            buf.push(HiveOp::StoreReg { reg: r, addr: dst }.into());
        }
        buf.push(HiveOp::Unlock.into());
        true
    }
}

// ---------------------------------------------------------------- VecSum ----

/// AVX vecsum: c[i] = a[i] + b[i], 2x-unrolled (2 loads + add + store).
pub struct VecSumAvx {
    pos: u64,
    end: u64,
}

impl VecSumAvx {
    pub fn new(p: &TraceParams) -> Self {
        let third = p.footprint / 3;
        let lines = third / emit::ZMM;
        let (lo, hi) = p.slice(lines);
        Self { pos: lo * emit::ZMM, end: hi * emit::ZMM }
    }
}

impl TraceChunker for VecSumAvx {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        for u in 0..2u64 {
            if self.pos >= self.end {
                break;
            }
            let (ra, rb, rc) = ((u * 3) as u8, (u * 3 + 1) as u8, (u * 3 + 2) as u8);
            buf.push(Uop::load(0x700 + u * 24, layout::A + self.pos, 64, ra).into());
            buf.push(Uop::load(0x708 + u * 24, layout::B + self.pos, 64, rb).into());
            buf.push(
                Uop::alu(0x710 + u * 24, FuType::FpAlu, [ra, rb, NO_REG], rc).into(),
            );
            buf.push(
                Uop::store(0x718 + u * 24, layout::C + self.pos, 64, [rc, NO_REG, NO_REG]).into(),
            );
            self.pos += emit::ZMM;
        }
        emit::loop_ctl(buf, 0x740, 16, self.pos < self.end);
        true
    }
}

/// Intrinsics-VIMA vecsum: one `_vim_add` per 8 KB triple.
pub struct VecSumVima {
    pos: u64,
    end: u64,
    vb: u64,
}

impl VecSumVima {
    pub fn new(p: &TraceParams) -> Self {
        let third = p.footprint / 3;
        let vecs = third / p.vector_bytes as u64;
        let (lo, hi) = p.slice(vecs);
        let vb = p.vector_bytes as u64;
        Self { pos: lo * vb, end: hi * vb, vb }
    }
}

impl TraceChunker for VecSumVima {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        buf.push(
            VimaInstr::new(
                VimaOp::Add,
                VDtype::F32,
                &[layout::A + self.pos, layout::B + self.pos],
                Some(layout::C + self.pos),
                self.vb as u32,
            )
            .into(),
        );
        self.pos += self.vb;
        emit::loop_ctl(buf, 0x780, 16, self.pos < self.end);
        true
    }
}

/// HIVE vecsum: per transaction 2x (load, load, add) then unlock write-back.
pub struct VecSumHive {
    pos: u64,
    end: u64,
    vb: u64,
}

impl VecSumHive {
    pub fn new(p: &TraceParams) -> Self {
        let third = p.footprint / 3;
        let vecs = third / p.vector_bytes as u64;
        let (lo, hi) = p.slice(vecs);
        let vb = p.vector_bytes as u64;
        Self { pos: lo * vb, end: hi * vb, vb }
    }
}

impl TraceChunker for VecSumHive {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        if self.pos >= self.end {
            return false;
        }
        buf.push(HiveOp::Lock.into());
        for u in 0..2u8 {
            if self.pos >= self.end {
                break;
            }
            let (ra, rb, rd) = (u * 2, u * 2 + 1, 4 + u);
            buf.push(HiveOp::LoadReg { reg: ra, addr: layout::A + self.pos }.into());
            buf.push(HiveOp::LoadReg { reg: rb, addr: layout::B + self.pos }.into());
            buf.push(
                HiveOp::Compute { op: VimaOp::Add, dtype: VDtype::F32, r1: ra, r2: rb, rd }.into(),
            );
            buf.push(HiveOp::StoreReg { reg: rd, addr: layout::C + self.pos }.into());
            self.pos += self.vb;
            emit::loop_ctl(buf, 0x7C0, 16, self.pos < self.end);
        }
        buf.push(HiveOp::Unlock.into());
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId};

    fn events(p: TraceParams) -> Vec<TraceEvent> {
        p.stream().unwrap().collect()
    }

    #[test]
    fn memset_avx_touches_whole_array_once() {
        let p = TraceParams::new(KernelId::MemSet, Backend::Avx, 64 << 10);
        let stores: Vec<u64> = events(p)
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Uop(u) if u.fu == FuType::Store => Some(u.addr),
                _ => None,
            })
            .collect();
        assert_eq!(stores.len(), 1024); // 64 KB / 64 B
        assert_eq!(stores[0], layout::A);
        assert_eq!(*stores.last().unwrap(), layout::A + (64 << 10) - 64);
    }

    #[test]
    fn memset_vima_one_bcast_per_vector() {
        let p = TraceParams::new(KernelId::MemSet, Backend::Vima, 64 << 10);
        let vimas: Vec<VimaInstr> = events(p)
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Vima(v) => Some(*v),
                _ => None,
            })
            .collect();
        assert_eq!(vimas.len(), 8); // 64 KB / 8 KB
        assert!(vimas.iter().all(|v| v.op == VimaOp::Bcast));
    }

    #[test]
    fn memcopy_avx_loads_match_stores() {
        let p = TraceParams::new(KernelId::MemCopy, Backend::Avx, 128 << 10);
        let evs = events(p);
        let loads = evs
            .iter()
            .filter(|e| matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Load))
            .count();
        let stores = evs
            .iter()
            .filter(|e| matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Store))
            .count();
        assert_eq!(loads, stores);
        assert_eq!(loads, 1024); // half the footprint
    }

    #[test]
    fn vecsum_vima_operands_line_up() {
        let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 48 << 10);
        for e in events(p) {
            if let TraceEvent::Vima(v) = e {
                let off = v.srcs[0] - layout::A;
                assert_eq!(v.srcs[1] - layout::B, off);
                assert_eq!(v.dst().unwrap() - layout::C, off);
            }
        }
    }

    #[test]
    fn vecsum_hive_transaction_structure() {
        let p = TraceParams::new(KernelId::VecSum, Backend::Hive, 48 << 10);
        let evs = events(p);
        let locks = evs.iter().filter(|e| matches!(e, TraceEvent::Hive(HiveOp::Lock))).count();
        let unlocks =
            evs.iter().filter(|e| matches!(e, TraceEvent::Hive(HiveOp::Unlock))).count();
        assert_eq!(locks, unlocks);
        assert!(locks >= 1);
    }

    #[test]
    fn last_branch_is_not_taken() {
        let p = TraceParams::new(KernelId::MemSet, Backend::Avx, 16 << 10);
        let branches: Vec<bool> = events(p)
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Uop(u) if u.fu == FuType::Branch => Some(u.taken),
                _ => None,
            })
            .collect();
        assert!(!branches.last().unwrap());
        assert!(branches[..branches.len() - 1].iter().all(|&t| t));
    }

    #[test]
    fn every_backend_ends_with_one_not_taken_branch() {
        // Branch accounting must agree across backends: every generator
        // models the same taken..taken,not-taken loop shape, ending on the
        // single loop-exit branch. The HIVE generators used to emit
        // taken=true forever, so their exit branch never existed.
        for kernel in [KernelId::MemSet, KernelId::MemCopy, KernelId::VecSum] {
            for backend in [Backend::Avx, Backend::Vima, Backend::Hive] {
                let branches: Vec<bool> = events(TraceParams::new(kernel, backend, 64 << 10))
                    .iter()
                    .filter_map(|e| match e {
                        TraceEvent::Uop(u) if u.fu == FuType::Branch => Some(u.taken),
                        _ => None,
                    })
                    .collect();
                assert!(!branches.is_empty(), "{kernel}/{backend}: no loop branches");
                assert_eq!(
                    branches.iter().filter(|&&t| !t).count(),
                    1,
                    "{kernel}/{backend}: expected exactly one loop-exit branch"
                );
                assert!(!branches.last().unwrap(), "{kernel}/{backend}: must end not-taken");
            }
        }
    }
}
