//! Network serving & scale-out (DESIGN.md §14).
//!
//! Promotes the [`SimService`](crate::service::SimService) from a
//! single stdin/stdout loop to real network serving and multi-process
//! sweeps, std-only (the default build stays dependency-free):
//!
//! * [`session`] — the one protocol implementation: newline-delimited
//!   JSONL framing, a bounded in-flight window for backpressure, typed
//!   inline errors, per-request timeouts, control ops, and graceful
//!   drain. `vima-sim serve`, every network connection, and every shard
//!   worker run this same core over different byte streams.
//! * [`server`] — the TCP / Unix-socket transport: one accept loop,
//!   one session thread per connection, and a shared drain switch
//!   (SIGINT or a client's `{"op": "shutdown"}`) that finishes and
//!   flushes all in-flight work before exit.
//! * [`coordinator`] — `vima-sim net coordinate`: shards a
//!   [`SweepPlan`](crate::sweep::SweepPlan) across spawned
//!   `vima-sim net worker` processes with fleet-wide exactly-once
//!   execution per [`CellKey`](crate::sweep::CellKey), bit-identical
//!   results, and re-queue recovery when a worker dies.
//! * [`wire`] — the bit-exact result codec (IEEE-754 bit patterns in
//!   hex) that makes "bit-identical across processes" literal.

pub mod coordinator;
pub mod server;
pub mod session;
pub mod wire;

pub use coordinator::{run_sharded, ShardOptions, ShardStats};
pub use server::{NetServer, NetSummary};
pub use session::{run_session, SessionCtl, SessionOptions, SessionSummary};
