//! The session core — one protocol implementation for every transport.
//!
//! A *session* pumps newline-delimited JSONL requests from any `BufRead`
//! against a [`SimService`] and writes one response line per request, in
//! request order, to any `Write`. `vima-sim serve` (stdin/stdout),
//! every `vima-sim net serve` connection (TCP or Unix socket), and
//! `vima-sim net worker` (a coordinator-driven child process) are all
//! this one function behind different byte streams.
//!
//! The mechanics:
//!
//! * **Reader/writer split.** The caller's thread parses and submits;
//!   a scoped responder thread waits on [`JobHandle`]s and writes
//!   answers. The two are joined by a bounded channel, so submission and
//!   response streaming overlap without reordering.
//! * **Backpressure.** The channel bound ([`SessionOptions::window`],
//!   default [`SERVE_WINDOW`](jsonl::SERVE_WINDOW)) caps how many
//!   requests may be in flight (submitted but unanswered): the reader
//!   blocks once the window fills, so a multi-million-line client keeps
//!   the session at O(window) memory, never O(total requests). Peak
//!   occupancy is `window + 2` — the queue, the item the responder is
//!   answering, and the item the reader is blocked on.
//! * **Typed errors inline.** A malformed line, unknown field, or
//!   invalid cell is answered with a `failed` line *in order* and the
//!   session keeps serving — a bad request must never take a connection
//!   down.
//! * **Timeouts.** A request's `timeout_ms` becomes an absolute deadline
//!   at submission; the responder waits with
//!   [`JobHandle::wait_timeout`] and answers a typed `timeout` line if
//!   the job has not settled. The job keeps running server-side and
//!   lands in the result cache.
//! * **Control ops.** `{"op": "ping"}` / `{"op": "stats"}` /
//!   `{"op": "shutdown"}` are answered through the same ordered channel.
//!   `shutdown` acks, stops reading, raises the shared [`SessionCtl`]
//!   drain flag (so a server stops accepting), finishes everything in
//!   flight, and flushes — the graceful-drain contract of DESIGN.md §14.
//!
//! Drain from *outside* (SIGINT, a peer's shutdown op) works the same
//! way: the transport unblocks the reader (EOF / socket read-shutdown),
//! the reader stops, and the responder settles the window before the
//! session returns its [`SessionSummary`].

use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::service::jsonl::{self, Op};
use crate::service::{JobHandle, SimService};
use crate::trace::TraceParams;
use crate::util::error::{Error, Result};

/// Tuning for one [`run_session`] call.
#[derive(Debug, Clone)]
pub struct SessionOptions {
    /// Backpressure bound: submitted-but-unanswered requests before the
    /// reader stops pulling lines. Clamped to at least 1.
    pub window: usize,
}

impl Default for SessionOptions {
    fn default() -> Self {
        Self { window: jsonl::SERVE_WINDOW }
    }
}

/// Shared drain switch. A server hands the same `SessionCtl` to every
/// connection; raising it (from a SIGINT handler's flag, or by any
/// session seeing `{"op": "shutdown"}`) tells the accept loop to stop
/// accepting and every session to stop reading at the next line
/// boundary. Already-submitted work still completes and flushes.
#[derive(Debug, Default)]
pub struct SessionCtl {
    drain: AtomicBool,
}

impl SessionCtl {
    pub fn new() -> Self {
        Self::default()
    }

    /// Raise the drain flag (idempotent).
    pub fn request_drain(&self) {
        self.drain.store(true, Ordering::SeqCst);
    }

    pub fn drain_requested(&self) -> bool {
        self.drain.load(Ordering::SeqCst)
    }
}

/// Totals of one session, returned when the request stream ends.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionSummary {
    /// Lines answered (jobs, ops, and malformed lines alike).
    pub requests: u64,
    /// `done` responses plus op acks.
    pub ok: u64,
    /// `failed` responses (parse errors, invalid cells, failed runs).
    pub failed: u64,
    /// Typed `timeout` responses.
    pub timeouts: u64,
    /// Peak submitted-but-unanswered requests; bounded by `window + 2`.
    pub max_in_flight: u64,
    /// The peer sent `{"op": "shutdown"}` on this session.
    pub shutdown_requested: bool,
}

enum Item {
    /// Answered without touching the scheduler: parse/shape errors and
    /// control-op acks, already rendered.
    Immediate { line: String, failed: bool },
    /// Submitted job: the responder blocks on its handle, in order.
    Pending {
        id: Option<String>,
        params: TraceParams,
        handle: JobHandle,
        /// Absolute deadline plus the request's `timeout_ms` (for the
        /// typed timeout line), when the request set one.
        deadline: Option<(Instant, u64)>,
        wire: bool,
    },
}

/// Serve one request stream to completion. See the module docs for the
/// contract; returns when `input` hits EOF, the peer requests shutdown,
/// or `ctl` is drained and the current line boundary is reached.
pub fn run_session<W: Write + Send>(
    service: &SimService,
    mut input: impl BufRead,
    output: W,
    opts: &SessionOptions,
    ctl: &SessionCtl,
) -> Result<SessionSummary> {
    let window = opts.window.max(1);
    let (tx, rx) = mpsc::sync_channel::<Item>(window);
    let in_flight = AtomicU64::new(0);
    let max_in_flight = AtomicU64::new(0);
    std::thread::scope(|scope| -> Result<SessionSummary> {
        let responder = scope.spawn(|| -> Result<SessionSummary> {
            let mut out = output;
            let mut summary = SessionSummary::default();
            for item in rx {
                summary.requests += 1;
                let line = match item {
                    Item::Immediate { line, failed } => {
                        if failed {
                            summary.failed += 1;
                        } else {
                            summary.ok += 1;
                        }
                        line
                    }
                    Item::Pending { id, params, handle, deadline, wire } => {
                        let outcome = match deadline {
                            None => handle.wait().map(Some),
                            Some((at, _)) => {
                                handle.wait_timeout(at.saturating_duration_since(Instant::now()))
                            }
                        };
                        match outcome {
                            Ok(Some(r)) => {
                                match jsonl::response_done(id.as_deref(), &params, &r, wire) {
                                    Ok(line) => {
                                        summary.ok += 1;
                                        line
                                    }
                                    Err(e) => {
                                        summary.failed += 1;
                                        jsonl::response_err(id.as_deref(), &e.to_string())
                                    }
                                }
                            }
                            Ok(None) => {
                                summary.timeouts += 1;
                                let ms = deadline.map(|(_, ms)| ms).unwrap_or(0);
                                jsonl::response_timeout(id.as_deref(), ms)
                            }
                            Err(e) => {
                                summary.failed += 1;
                                jsonl::response_err(id.as_deref(), &e.to_string())
                            }
                        }
                    }
                };
                in_flight.fetch_sub(1, Ordering::SeqCst);
                writeln!(out, "{line}")?;
                out.flush()?;
            }
            Ok(summary)
        });

        let mut shutdown_requested = false;
        let mut line = String::new();
        loop {
            if ctl.drain_requested() {
                break;
            }
            line.clear();
            if input.read_line(&mut line)? == 0 {
                break;
            }
            let text = line.trim();
            if text.is_empty() {
                continue;
            }
            let mut stop_after = false;
            let item = match jsonl::parse_flat_object(text) {
                Err(e) => Item::Immediate {
                    line: jsonl::response_err(None, &format!("bad request line: {e}")),
                    failed: true,
                },
                Ok(fields) => {
                    let id = jsonl::request_id(&fields);
                    match jsonl::request_op(&fields) {
                        Err(e) => Item::Immediate {
                            line: jsonl::response_err(id.as_deref(), &e.to_string()),
                            failed: true,
                        },
                        Ok(Some(op)) => {
                            if op == Op::Shutdown {
                                shutdown_requested = true;
                                stop_after = true;
                                ctl.request_drain();
                            }
                            Item::Immediate {
                                line: op_response(service, id.as_deref(), op),
                                failed: false,
                            }
                        }
                        Ok(None) => match jsonl::request_spec(&fields) {
                            Ok(spec) => {
                                let params = spec.job.params;
                                let handle = service.submit(spec.job);
                                let deadline = spec
                                    .timeout_ms
                                    .map(|ms| (Instant::now() + Duration::from_millis(ms), ms));
                                Item::Pending { id, params, handle, deadline, wire: spec.wire }
                            }
                            Err(e) => Item::Immediate {
                                line: jsonl::response_err(id.as_deref(), &e.to_string()),
                                failed: true,
                            },
                        },
                    }
                }
            };
            let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            max_in_flight.fetch_max(now, Ordering::SeqCst);
            if tx.send(item).is_err() {
                break; // responder died (output error); stop reading
            }
            if stop_after {
                break;
            }
        }
        drop(tx);
        let mut summary = responder
            .join()
            .unwrap_or_else(|_| Err(Error::msg("session responder panicked")))?;
        summary.max_in_flight = max_in_flight.load(Ordering::SeqCst);
        summary.shutdown_requested = shutdown_requested;
        Ok(summary)
    })
}

/// Render the ack line for a control op. The `stats` snapshot is taken
/// at read time, i.e. *after* every request earlier in the stream has
/// been submitted (submission accounting is synchronous) — this is what
/// lets a coordinator pin fleet-wide exactly-once execution by summing
/// worker `unique_runs` after all results are in.
fn op_response(service: &SimService, id: Option<&str>, op: Op) -> String {
    let mut s = String::from("{");
    if let Some(id) = id {
        s += &format!("\"id\": {id}, ");
    }
    match op {
        Op::Ping => s + "\"status\": \"ok\", \"op\": \"ping\"}",
        Op::Shutdown => s + "\"status\": \"ok\", \"op\": \"shutdown\", \"draining\": true}",
        Op::Stats => {
            let st = service.stats();
            s + &format!(
                "\"status\": \"ok\", \"op\": \"stats\", \"cells\": {}, \
                 \"unique_runs\": {}, \"cache_hits\": {}, \"cache_misses\": {}, \
                 \"evictions\": {}, \"cached_cells\": {}, \"jobs\": {}}}",
                st.cells,
                st.unique_runs,
                st.cache_hits,
                st.cache_misses,
                st.evictions,
                service.cached_cells(),
                service.jobs()
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::{ServiceConfig, SimService};

    fn small_service() -> SimService {
        SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() })
    }

    fn run(svc: &SimService, input: &str, window: usize) -> (String, SessionSummary) {
        let mut out = Vec::new();
        let summary = run_session(
            svc,
            input.as_bytes(),
            &mut out,
            &SessionOptions { window },
            &SessionCtl::new(),
        )
        .unwrap();
        (String::from_utf8(out).unwrap(), summary)
    }

    #[test]
    fn ops_are_answered_in_order() {
        let svc = small_service();
        let input = "{\"id\": 1, \"op\": \"ping\"}\n\
                     {\"id\": 2, \"workload\": \"vecsum\", \"backend\": \"vima\", \"mb\": 1}\n\
                     {\"id\": 3, \"op\": \"stats\"}\n";
        let (out, summary) = run(&svc, input, 8);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"id\": 1") && lines[0].contains("\"op\": \"ping\""));
        assert!(lines[1].contains("\"id\": 2") && lines[1].contains("\"status\": \"done\""));
        assert!(lines[2].contains("\"id\": 3") && lines[2].contains("\"unique_runs\": 1"));
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.ok, 3);
        assert!(!summary.shutdown_requested);
    }

    #[test]
    fn shutdown_acks_and_stops_reading() {
        let svc = small_service();
        let input = "{\"id\": 1, \"workload\": \"vecsum\", \"backend\": \"vima\", \"mb\": 1}\n\
                     {\"op\": \"shutdown\"}\n\
                     {\"id\": 99, \"workload\": \"vecsum\", \"backend\": \"avx\", \"mb\": 1}\n";
        let (out, summary) = run(&svc, input, 8);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2, "the line after shutdown must never be read:\n{out}");
        assert!(lines[0].contains("\"status\": \"done\""));
        assert!(lines[1].contains("\"draining\": true"));
        assert!(summary.shutdown_requested);
    }

    #[test]
    fn in_flight_stays_within_the_window() {
        let svc = small_service();
        let window = 4;
        let mut input = String::new();
        for i in 0..200 {
            // Distinct cells so every request is real scheduler work.
            input += &format!(
                "{{\"id\": {i}, \"workload\": \"memset\", \"backend\": \"avx\", \
                 \"footprint\": {}}}\n",
                (i + 1) * 4096
            );
        }
        let (out, summary) = run(&svc, &input, window);
        assert_eq!(out.lines().count(), 200);
        assert_eq!(summary.requests, 200);
        assert!(
            summary.max_in_flight <= window as u64 + 2,
            "max_in_flight {} exceeds window {} + 2",
            summary.max_in_flight,
            window
        );
    }

    #[test]
    fn timeouts_answer_typed_lines_without_wedging_the_session() {
        let svc = small_service();
        // timeout_ms: 1 on a real cell: either it finishes in time (done)
        // or we get the typed timeout line; both keep the session alive.
        let input = "{\"id\": 1, \"workload\": \"vecsum\", \"backend\": \"vima\", \"mb\": 4, \"timeout_ms\": 1}\n\
                     {\"id\": 2, \"op\": \"ping\"}\n";
        let (out, summary) = run(&svc, input, 8);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(
            lines[0].contains("\"status\": \"done\"") || lines[0].contains("\"status\": \"timeout\""),
            "{}",
            lines[0]
        );
        assert!(lines[1].contains("\"op\": \"ping\""));
        assert_eq!(summary.ok + summary.timeouts, 2);
    }

    #[test]
    fn wire_results_ride_the_done_line() {
        let svc = small_service();
        let input =
            "{\"id\": 1, \"workload\": \"vecsum\", \"backend\": \"vima\", \"mb\": 1, \"wire\": true}\n";
        let (out, _) = run(&svc, input, 8);
        let fields = jsonl::parse_flat_object(out.lines().next().unwrap()).unwrap();
        let encoded = fields
            .iter()
            .find(|(k, _)| k == "result")
            .map(|(_, v)| match v {
                jsonl::JsonValue::Str(s) => s.clone(),
                other => panic!("result must be a string, got {other:?}"),
            })
            .expect("done line carries a result field");
        let decoded = crate::net::wire::decode_result(&encoded).unwrap();
        let direct = crate::sim::simulate(
            &crate::config::SystemConfig::default(),
            TraceParams::new(
                crate::workload::resolve("vecsum").unwrap(),
                crate::trace::Backend::Vima,
                1 << 20,
            ),
        )
        .unwrap();
        assert_eq!(decoded.cycles, direct.cycles);
        assert_eq!(decoded.report, direct.report);
        assert_eq!(decoded.energy, direct.energy);
    }
}
