//! Shard coordinator: split a [`SweepPlan`] across worker *processes*.
//!
//! `vima-sim net coordinate` is the horizontal-scale counterpart of
//! [`SimService::run_plan`]: it spawns N `vima-sim net worker` children
//! (each a stdio [`run_session`](super::session::run_session) around its
//! own in-process service) and streams the plan's cells to them over the
//! JSONL protocol. The contract is the same as single-process plans —
//! results in plan order, **bit-identical**, with exactly-once execution
//! per [`CellKey`] fleet-wide — because the coordinator reuses the same
//! identity machinery end to end:
//!
//! * **Dedup before dispatch.** Cells are grouped by `cell.key(base)`
//!   (the full `TraceParams` + effective-config identity the service
//!   cache uses); each *unique* key is sent to exactly one worker, and
//!   duplicate cells in the plan are expanded from the merged results.
//!   Workers never see the same key twice, so the fleet executes each
//!   cell exactly once — pinned after the run by summing every worker's
//!   `unique_runs` stat.
//! * **Bit-exact transport.** Requests carry the *effective* config as
//!   TOML (`SystemConfig::to_toml` round-trips by value) and set
//!   `"wire": true`, so results come back through
//!   [`wire::decode_result`](super::wire::decode_result) with every
//!   `f64` bit intact.
//! * **Fault tolerance.** Each worker's stdout has a reader thread; a
//!   worker that dies (EOF, write error, kill -9) gets its unanswered
//!   cells re-queued to the survivors. Only if *every* worker is gone
//!   with cells unfinished does the sweep fail, with a typed error. A
//!   `failed` response (an invalid cell that slipped validation, or a
//!   simulator bug) fails fast with the cell's label, like `run_plan`.
//!
//! Dispatch is windowed per worker (a few cells outstanding each) so a
//! long plan load-balances by completion speed instead of by a static
//! partition — a worker stuck on a huge cell simply stops being fed.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc;

use crate::config::SystemConfig;
use crate::net::wire;
use crate::service::jsonl::{self, JsonValue};
use crate::sim::SimResult;
use crate::sweep::{CellKey, SweepPlan};
use crate::util::error::{Context, Error, Result};
use crate::workload;
use crate::{bail, ensure};

/// Tuning for one [`run_sharded`] call.
#[derive(Debug, Clone)]
pub struct ShardOptions {
    /// Worker processes to spawn (at least 1).
    pub workers: usize,
    /// Outstanding requests per worker. Small on purpose: the window
    /// exists for pipelining, while load balance comes from completion-
    /// driven dispatch.
    pub window: usize,
    /// `--jobs` handed to each worker (its in-process pool width);
    /// `0` = the worker's `available_parallelism()`.
    pub worker_jobs: usize,
    /// Worker binary; `None` = `std::env::current_exe()`.
    pub worker_cmd: Option<PathBuf>,
    /// Extra argv per worker index (fault injection in tests:
    /// `--exit-after N`). Workers beyond the vec get no extra args.
    pub worker_extra_args: Vec<Vec<String>>,
    /// Inherit worker stderr (per-worker logs); otherwise discarded.
    pub verbose: bool,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            window: 4,
            worker_jobs: 0,
            worker_cmd: None,
            worker_extra_args: Vec::new(),
            verbose: false,
        }
    }
}

/// Accounting for one sharded sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Plan cells (before dedup).
    pub cells: usize,
    /// Distinct [`CellKey`]s actually dispatched.
    pub unique_cells: usize,
    /// Job requests written to workers (`unique_cells` plus re-sends of
    /// requeued cells).
    pub requests_sent: u64,
    /// Cells re-queued because their worker died before answering.
    pub requeued: u64,
    /// Workers that died before the sweep completed.
    pub worker_deaths: u64,
    pub workers_spawned: usize,
    /// Sum of `unique_runs` over worker `stats` ops at completion, plus
    /// answered requests of workers that died (their stats are
    /// unreachable). With no deaths this equals `unique_cells` — the
    /// fleet-wide exactly-once pin.
    pub fleet_unique_runs: u64,
}

struct Worker {
    child: Child,
    /// `None` once the worker is dead (or its pipe failed).
    stdin: Option<ChildStdin>,
    alive: bool,
    /// Unique-cell indices awaiting this worker's answer.
    outstanding: Vec<usize>,
    /// Job responses received from this worker.
    answered: u64,
}

enum Event {
    Line(String),
    Gone,
}

/// Run `plan` across `opts.workers` child processes. Returns results in
/// plan order — bit-identical to [`SimService::run_plan`] on `base` —
/// plus the shard accounting.
///
/// [`SimService::run_plan`]: crate::service::SimService::run_plan
pub fn run_sharded(
    base: &SystemConfig,
    plan: &SweepPlan,
    opts: &ShardOptions,
) -> Result<(Vec<SimResult>, ShardStats)> {
    ensure!(opts.workers >= 1, "need at least one worker, got {}", opts.workers);
    let window = opts.window.max(1);
    let mut stats = ShardStats { cells: plan.cells().len(), ..ShardStats::default() };

    // Validate every cell up front — fail fast with the cell label,
    // before any process is spawned (run_plan's contract).
    for cell in plan.cells() {
        cell.params()
            .check()
            .map_err(|e| e.context(format!("sweep cell {}", cell.label())))?;
    }
    if plan.cells().is_empty() {
        return Ok((Vec::new(), stats));
    }

    // Dedup by full cell identity; duplicates expand from unique results.
    let mut key_to_unique: HashMap<CellKey, usize> = HashMap::new();
    let mut cell_to_unique: Vec<usize> = Vec::with_capacity(plan.cells().len());
    let mut requests: Vec<String> = Vec::new();
    let mut labels: Vec<String> = Vec::new();
    for cell in plan.cells() {
        let key = cell.key(base);
        let next = requests.len();
        let u = *key_to_unique.entry(key).or_insert(next);
        if u == requests.len() {
            requests.push(request_line(u, cell, base)?);
            labels.push(cell.label());
        }
        cell_to_unique.push(u);
    }
    stats.unique_cells = requests.len();

    // Spawn the fleet and one reader thread per worker stdout.
    stats.workers_spawned = opts.workers;
    let worker_cmd = match &opts.worker_cmd {
        Some(p) => p.clone(),
        None => std::env::current_exe().context("locate vima-sim binary for workers")?,
    };
    let (tx, rx) = mpsc::channel::<(usize, Event)>();
    let mut workers: Vec<Worker> = Vec::with_capacity(opts.workers);
    let mut readers = Vec::with_capacity(opts.workers);
    for w in 0..opts.workers {
        let mut cmd = Command::new(&worker_cmd);
        cmd.arg("net").arg("worker");
        cmd.arg("--jobs").arg(opts.worker_jobs.to_string());
        if let Some(extra) = opts.worker_extra_args.get(w) {
            cmd.args(extra);
        }
        cmd.stdin(Stdio::piped()).stdout(Stdio::piped());
        cmd.stderr(if opts.verbose { Stdio::inherit() } else { Stdio::null() });
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawn worker {w} ({})", worker_cmd.display()))?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        let tx = tx.clone();
        readers.push(std::thread::spawn(move || {
            for line in BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if tx.send((w, Event::Line(line))).is_err() {
                    break;
                }
            }
            let _ = tx.send((w, Event::Gone));
        }));
        workers.push(Worker {
            child,
            stdin: Some(stdin),
            alive: true,
            outstanding: Vec::new(),
            answered: 0,
        });
    }
    drop(tx);

    let run = drive(&mut workers, &rx, &requests, &labels, window, &mut stats);
    let fleet = match &run {
        Ok(_) => collect_fleet_stats(&mut workers, &rx, &mut stats),
        Err(_) => Ok(()),
    };
    // Wind the fleet down on every path: close pipes (EOF), reap, join.
    for worker in &mut workers {
        worker.stdin = None;
        if run.is_err() {
            let _ = worker.child.kill();
        }
        let _ = worker.child.wait();
    }
    drop(rx);
    for reader in readers {
        let _ = reader.join();
    }
    let unique_results = run?;
    fleet?;

    let results =
        cell_to_unique.iter().map(|&u| unique_results[u].clone()).collect::<Vec<_>>();
    Ok((results, stats))
}

/// The dispatch/receive loop: returns every unique result, or the first
/// hard failure.
fn drive(
    workers: &mut [Worker],
    rx: &mpsc::Receiver<(usize, Event)>,
    requests: &[String],
    labels: &[String],
    window: usize,
    stats: &mut ShardStats,
) -> Result<Vec<SimResult>> {
    let mut pending: VecDeque<usize> = (0..requests.len()).collect();
    let mut results: Vec<Option<SimResult>> = vec![None; requests.len()];
    let mut remaining = requests.len();

    for w in 0..workers.len() {
        dispatch(workers, w, &mut pending, requests, window, stats);
    }
    while remaining > 0 {
        ensure!(
            workers.iter().any(|w| w.alive),
            "all {} workers died with {} cells unfinished",
            workers.len(),
            remaining
        );
        let (w, event) = rx
            .recv()
            .map_err(|_| Error::msg("worker channel closed with cells unfinished"))?;
        match event {
            Event::Gone => {
                bury(workers, w, &mut pending, stats);
            }
            Event::Line(line) => {
                let fields = jsonl::parse_flat_object(&line)
                    .with_context(|| format!("worker {w} sent a malformed line: {line}"))?;
                let u = response_unique_index(&fields, requests.len(), &line)?;
                let status = find_str(&fields, "status").unwrap_or_default();
                match status {
                    "done" => {
                        let encoded = find_str(&fields, "result").with_context(|| {
                            format!("worker {w} sent a done line without a wire result: {line}")
                        })?;
                        let result = wire::decode_result(encoded)
                            .with_context(|| format!("sweep cell {}", labels[u]))?;
                        workers[w].outstanding.retain(|&o| o != u);
                        workers[w].answered += 1;
                        if results[u].replace(result).is_none() {
                            remaining -= 1;
                        }
                    }
                    other => {
                        let error = find_str(&fields, "error").unwrap_or("unknown error");
                        bail!("sweep cell {}: worker {w} answered {other}: {error}", labels[u]);
                    }
                }
                dispatch(workers, w, &mut pending, requests, window, stats);
            }
        }
        // A death may have re-queued cells while every survivor's window
        // was full of its own work; top everyone up.
        if !pending.is_empty() {
            for w in 0..workers.len() {
                dispatch(workers, w, &mut pending, requests, window, stats);
            }
        }
    }
    Ok(results.into_iter().map(|r| r.expect("remaining hit zero")).collect())
}

/// Feed worker `w` until its window is full (or it dies mid-write).
fn dispatch(
    workers: &mut [Worker],
    w: usize,
    pending: &mut VecDeque<usize>,
    requests: &[String],
    window: usize,
    stats: &mut ShardStats,
) {
    while workers[w].alive && workers[w].outstanding.len() < window {
        let Some(u) = pending.pop_front() else { return };
        let wrote = match workers[w].stdin.as_mut() {
            Some(stdin) => {
                writeln!(stdin, "{}", requests[u]).and_then(|_| stdin.flush()).is_ok()
            }
            None => false,
        };
        if wrote {
            workers[w].outstanding.push(u);
            stats.requests_sent += 1;
        } else {
            // Broken pipe: the worker is gone. Put the cell back and let
            // the survivors absorb its load.
            pending.push_front(u);
            bury(workers, w, pending, stats);
            return;
        }
    }
}

/// Mark worker `w` dead (idempotent) and re-queue its unanswered cells.
fn bury(
    workers: &mut [Worker],
    w: usize,
    pending: &mut VecDeque<usize>,
    stats: &mut ShardStats,
) {
    if !workers[w].alive {
        return;
    }
    workers[w].alive = false;
    workers[w].stdin = None;
    stats.worker_deaths += 1;
    let orphaned = std::mem::take(&mut workers[w].outstanding);
    stats.requeued += orphaned.len() as u64;
    // Answered work is banked; only the unanswered cells ran (at most
    // partially) for nothing.
    for u in orphaned {
        pending.push_front(u);
    }
    // The dead worker's unique_runs stat is unreachable; its answered
    // responses are the provable lower bound of what it ran.
    stats.fleet_unique_runs += workers[w].answered;
}

/// Completion phase: ask every survivor for its `stats`, sum
/// `unique_runs` into the fleet pin, then request graceful shutdown.
fn collect_fleet_stats(
    workers: &mut [Worker],
    rx: &mpsc::Receiver<(usize, Event)>,
    stats: &mut ShardStats,
) -> Result<()> {
    let mut awaiting = 0usize;
    for worker in workers.iter_mut().filter(|w| w.alive) {
        let ok = match worker.stdin.as_mut() {
            Some(stdin) => writeln!(stdin, "{}", r#"{"op": "stats"}"#)
                .and_then(|_| stdin.flush())
                .is_ok(),
            None => false,
        };
        if ok {
            awaiting += 1;
        } else {
            worker.alive = false;
            stats.worker_deaths += 1;
            stats.fleet_unique_runs += worker.answered;
        }
    }
    while awaiting > 0 {
        let Ok((w, event)) = rx.recv() else { break };
        match event {
            Event::Line(line) => {
                let fields = jsonl::parse_flat_object(&line)
                    .with_context(|| format!("worker {w} sent a malformed line: {line}"))?;
                if find_str(&fields, "op") == Some("stats") {
                    let runs = fields
                        .iter()
                        .find(|(k, _)| k == "unique_runs")
                        .and_then(|(_, v)| match v {
                            JsonValue::Num(n) => Some(*n as u64),
                            _ => None,
                        })
                        .with_context(|| format!("worker {w} stats without unique_runs: {line}"))?;
                    stats.fleet_unique_runs += runs;
                    awaiting -= 1;
                }
            }
            Event::Gone => {
                if workers[w].alive {
                    workers[w].alive = false;
                    stats.worker_deaths += 1;
                    stats.fleet_unique_runs += workers[w].answered;
                    awaiting -= 1;
                }
            }
        }
    }
    for worker in workers.iter_mut().filter(|w| w.alive) {
        if let Some(stdin) = worker.stdin.as_mut() {
            // Best-effort: closing stdin right after is the EOF fallback.
            let _ = writeln!(stdin, "{}", r#"{"op": "shutdown"}"#);
            let _ = stdin.flush();
        }
    }
    Ok(())
}

/// Render the job request for one unique cell. The request always ships
/// the cell's *effective* config as TOML (even when it equals the base)
/// so the worker's `CellKey` is the coordinator's, and always asks for
/// the wire-encoded result.
fn request_line(
    unique: usize,
    cell: &crate::sweep::RunCell,
    base: &SystemConfig,
) -> Result<String> {
    let params = cell.params();
    let cfg = cell.cfg_override.clone().unwrap_or_else(|| base.clone());
    Ok(format!(
        "{{\"id\": {unique}, \"workload\": \"{}\", \"backend\": \"{}\", \
         \"footprint\": {}, \"threads\": {}, \"vector_bytes\": {}, \
         \"wire\": true, \"cfg\": \"{}\"}}",
        jsonl::escape(&workload::name(params.workload)),
        params.backend,
        params.footprint,
        params.threads,
        params.vector_bytes,
        jsonl::escape(&cfg.to_toml())
    ))
}

fn find_str<'a>(fields: &'a [(String, JsonValue)], key: &str) -> Option<&'a str> {
    fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
        JsonValue::Str(s) => Some(s.as_str()),
        _ => None,
    })
}

/// Pull the echoed `id` back out of a response and bounds-check it
/// against the unique-cell table.
fn response_unique_index(
    fields: &[(String, JsonValue)],
    uniques: usize,
    line: &str,
) -> Result<usize> {
    let id = fields
        .iter()
        .find(|(k, _)| k == "id")
        .and_then(|(_, v)| match v {
            JsonValue::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as usize),
            _ => None,
        })
        .with_context(|| format!("worker response without a numeric id: {line}"))?;
    ensure!(id < uniques, "worker echoed an unknown request id {id}: {line}");
    Ok(id)
}
