//! TCP / Unix-socket transport: many concurrent [`run_session`]s behind
//! one accept loop.
//!
//! The server owns nothing protocol-shaped — each accepted connection is
//! handed verbatim to [`run_session`](super::session::run_session) on its
//! own scoped thread, with the per-connection backpressure window and a
//! [`SessionCtl`] **shared by every connection and the accept loop**.
//! That shared control is the whole drain story:
//!
//! 1. something raises the flag — a SIGINT handler's atomic (polled via
//!    [`NetServer::with_external_shutdown`]), any client's
//!    `{"op": "shutdown"}` line, or a test holding the
//!    [`ctl`](NetServer::ctl) handle;
//! 2. the accept loop (nonblocking + poll, so a signal can never leave it
//!    wedged inside `accept(2)` — Rust's std retries `EINTR`) stops
//!    accepting and half-closes the **read** side of every live
//!    connection, which unblocks each session's `read_line` with EOF;
//! 3. every session answers and flushes what was already in flight, the
//!    scoped threads join, and [`serve`](NetServer::serve) returns the
//!    merged [`NetSummary`].
//!
//! In-flight jobs are never abandoned and responses are never truncated
//! mid-line; clients see complete answers for everything they managed to
//! send.

use std::io::{BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::net::session::{run_session, SessionCtl, SessionOptions};
use crate::service::SimService;
use crate::util::error::{Context, Result};

/// How long the accept loop sleeps when no connection is pending. Drain
/// latency is bounded by this; it is far below human-perceptible.
const ACCEPT_POLL: Duration = Duration::from_millis(15);

/// Merged totals across every connection of one [`NetServer::serve`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetSummary {
    pub connections: u64,
    pub requests: u64,
    pub ok: u64,
    pub failed: u64,
    pub timeouts: u64,
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn try_clone(&self) -> Result<Conn> {
        Ok(match self {
            Conn::Tcp(s) => Conn::Tcp(s.try_clone().context("clone tcp stream")?),
            #[cfg(unix)]
            Conn::Unix(s) => Conn::Unix(s.try_clone().context("clone unix stream")?),
        })
    }

    /// Half-close the read side: the session's `read_line` sees EOF and
    /// winds down gracefully; pending responses still go out the write
    /// side.
    fn shutdown_read(&self) {
        match self {
            Conn::Tcp(s) => drop(s.shutdown(Shutdown::Read)),
            #[cfg(unix)]
            Conn::Unix(s) => drop(s.shutdown(Shutdown::Read)),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// A bound-but-not-yet-serving network server. Binding and serving are
/// split so a caller (tests, the saturation bench) can learn the
/// ephemeral port and keep a drain handle before the accept loop blocks.
pub struct NetServer {
    listener: Listener,
    ctl: Arc<SessionCtl>,
    window: usize,
    external_shutdown: Option<&'static AtomicBool>,
}

impl NetServer {
    /// Bind a TCP listener; `"127.0.0.1:0"` picks an ephemeral port
    /// (recover it with [`local_addr`](Self::local_addr)).
    pub fn bind_tcp(addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind tcp {addr:?}"))?;
        Ok(Self::over(Listener::Tcp(listener)))
    }

    /// Bind a Unix-domain socket; the path is unlinked when serving ends.
    #[cfg(unix)]
    pub fn bind_unix(path: &Path) -> Result<Self> {
        // A stale socket file from a crashed process would fail the bind.
        if path.exists() {
            std::fs::remove_file(path)
                .with_context(|| format!("remove stale socket {}", path.display()))?;
        }
        let listener = UnixListener::bind(path)
            .with_context(|| format!("bind unix socket {}", path.display()))?;
        Ok(Self::over(Listener::Unix(listener, path.to_path_buf())))
    }

    fn over(listener: Listener) -> Self {
        Self {
            listener,
            ctl: Arc::new(SessionCtl::new()),
            window: crate::service::jsonl::SERVE_WINDOW,
            external_shutdown: None,
        }
    }

    /// Per-connection backpressure window (default
    /// [`SERVE_WINDOW`](crate::service::jsonl::SERVE_WINDOW)).
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    /// Poll this flag in the accept loop and drain when it goes up — the
    /// bridge from a `signal(2)` handler (which may only touch a static
    /// atomic) to the graceful-drain path.
    pub fn with_external_shutdown(mut self, flag: &'static AtomicBool) -> Self {
        self.external_shutdown = Some(flag);
        self
    }

    /// The drain switch shared with every session.
    pub fn ctl(&self) -> Arc<SessionCtl> {
        Arc::clone(&self.ctl)
    }

    /// Where the server is listening: `host:port` for TCP, the socket
    /// path for Unix.
    pub fn local_addr(&self) -> String {
        match &self.listener {
            Listener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "<unknown>".to_string()),
            #[cfg(unix)]
            Listener::Unix(_, path) => path.display().to_string(),
        }
    }

    /// Accept and serve connections until drained. Blocks; returns the
    /// merged summary after every session thread has joined.
    pub fn serve(self, service: &SimService) -> Result<NetSummary> {
        match &self.listener {
            Listener::Tcp(l) => l.set_nonblocking(true).context("nonblocking tcp listener")?,
            #[cfg(unix)]
            Listener::Unix(l, _) => {
                l.set_nonblocking(true).context("nonblocking unix listener")?
            }
        }
        let summary = Mutex::new(NetSummary::default());
        let opts = SessionOptions { window: self.window };
        let result = std::thread::scope(|scope| -> Result<()> {
            // Read-shutdown handles for live connections, so drain can
            // unblock sessions stuck in read_line.
            let mut live: Vec<Conn> = Vec::new();
            loop {
                if self.external_shutdown.is_some_and(|f| f.load(Ordering::SeqCst)) {
                    self.ctl.request_drain();
                }
                if self.ctl.drain_requested() {
                    break;
                }
                let accepted = match &self.listener {
                    Listener::Tcp(l) => match l.accept() {
                        Ok((s, _)) => {
                            s.set_nonblocking(false).context("blocking tcp stream")?;
                            let _ = s.set_nodelay(true);
                            Some(Conn::Tcp(s))
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                        Err(e) => return Err(e).context("accept tcp connection"),
                    },
                    #[cfg(unix)]
                    Listener::Unix(l, _) => match l.accept() {
                        Ok((s, _)) => {
                            s.set_nonblocking(false).context("blocking unix stream")?;
                            Some(Conn::Unix(s))
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                        Err(e) => return Err(e).context("accept unix connection"),
                    },
                };
                let Some(conn) = accepted else {
                    std::thread::sleep(ACCEPT_POLL);
                    continue;
                };
                summary.lock().unwrap().connections += 1;
                let reader = conn.try_clone()?;
                let writer = conn.try_clone()?;
                live.push(conn);
                let (ctl, opts, summary) = (&self.ctl, &opts, &summary);
                scope.spawn(move || {
                    match run_session(service, BufReader::new(reader), writer, opts, ctl) {
                        Ok(s) => {
                            let mut total = summary.lock().unwrap();
                            total.requests += s.requests;
                            total.ok += s.ok;
                            total.failed += s.failed;
                            total.timeouts += s.timeouts;
                        }
                        // A peer that vanishes mid-write is its own
                        // problem; the server keeps serving others.
                        Err(e) => eprintln!("[vima-sim] net session error: {e}"),
                    }
                });
            }
            for conn in &live {
                conn.shutdown_read();
            }
            Ok(())
            // Scope exit joins every session thread: all in-flight work
            // answered and flushed before serve() returns.
        });
        #[cfg(unix)]
        if let Listener::Unix(_, path) = &self.listener {
            let _ = std::fs::remove_file(path);
        }
        result?;
        Ok(summary.into_inner().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::{ServiceConfig, SimService};
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn tcp_round_trip_and_ctl_drain() {
        let svc = SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() });
        let server = NetServer::bind_tcp("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let ctl = server.ctl();
        let summary = std::thread::scope(|scope| {
            let serving = scope.spawn(|| server.serve(&svc));

            let mut stream = TcpStream::connect(&addr).unwrap();
            writeln!(
                stream,
                "{{\"id\": 1, \"workload\": \"vecsum\", \"backend\": \"vima\", \"mb\": 1}}"
            )
            .unwrap();
            stream.flush().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("\"status\": \"done\""), "{line}");
            drop(reader);
            drop(stream);

            ctl.request_drain();
            serving.join().unwrap().unwrap()
        });
        assert_eq!(summary.connections, 1);
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.ok, 1);
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_round_trip() {
        let svc = SimService::new(ServiceConfig { jobs: 1, ..ServiceConfig::default() });
        let path = std::env::temp_dir().join(format!("vima-sim-test-{}.sock", std::process::id()));
        let server = NetServer::bind_unix(&path).unwrap();
        let ctl = server.ctl();
        let summary = std::thread::scope(|scope| {
            let serving = scope.spawn(|| server.serve(&svc));

            let mut stream = std::os::unix::net::UnixStream::connect(&path).unwrap();
            writeln!(stream, "{{\"op\": \"ping\"}}").unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("\"op\": \"ping\""), "{line}");
            drop(reader);
            drop(stream);

            ctl.request_drain();
            serving.join().unwrap().unwrap()
        });
        assert_eq!(summary.ok, 1);
        assert!(!path.exists(), "socket file must be unlinked after drain");
    }
}
