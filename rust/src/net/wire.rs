//! Bit-exact result transport — how a worker process ships a whole
//! [`SimResult`] back to a coordinator without losing a single bit.
//!
//! The acceptance bar for sharded sweeps is *bit identity* with
//! single-process [`run_plan`](crate::service::SimService::run_plan):
//! cycles, seconds, the full counter report, and every energy component
//! must survive the process boundary exactly. Decimal float printing
//! cannot guarantee that across the hand-rolled JSON layer, so the wire
//! format encodes every `f64` as its IEEE-754 bit pattern in hex and
//! packs the whole result into **one flat string field** (the JSONL
//! protocol is flat by design — no nesting):
//!
//! ```text
//! v1:<cycles hex>:<seconds bits hex>:<7 energy bits hex, comma-sep>:<report k=hex, comma-sep>
//! ```
//!
//! Counter keys are dotted identifiers (`l1d.hits`, `vima.busy_until.3`)
//! and never contain `:`, `,` or `=`, which the decoder enforces on the
//! encode side so a future exotic key fails loudly instead of producing
//! an ambiguous record.
//!
//! Configurations travel the other direction (coordinator → worker) as
//! TOML text in a request field: `SystemConfig::to_toml` round-trips
//! exactly (float fields emit with Rust's shortest-round-trip formatting
//! and hash/compare by bit pattern), so the worker reconstructs the
//! coordinator's *effective* config by value — `CellKey` identity is
//! preserved fleet-wide.

use crate::bail;
use crate::energy::EnergyBreakdown;
use crate::sim::SimResult;
use crate::stats::StatsReport;
use crate::util::error::{Context, Result};

/// Wire-format version tag; bump when the layout changes.
const VERSION: &str = "v1";

fn f64_hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn f64_from_hex(s: &str) -> Result<f64> {
    let bits = u64::from_str_radix(s, 16).with_context(|| format!("bad f64 bits {s:?}"))?;
    Ok(f64::from_bits(bits))
}

/// Encode a full [`SimResult`] as the flat `v1:...` wire string.
pub fn encode_result(r: &SimResult) -> Result<String> {
    let e = &r.energy;
    let energy = [
        e.core_j,
        e.cache_dynamic_j,
        e.cache_static_j,
        e.dram_dynamic_j,
        e.dram_static_j,
        e.vima_j,
        e.total_j,
    ]
    .map(f64_hex)
    .join(",");
    let mut report = String::new();
    for (k, v) in r.report.iter() {
        crate::ensure!(
            !k.is_empty() && k.bytes().all(|b| b != b':' && b != b',' && b != b'='),
            "counter key {k:?} is not wire-safe"
        );
        if !report.is_empty() {
            report.push(',');
        }
        report.push_str(k);
        report.push('=');
        report.push_str(&f64_hex(v));
    }
    Ok(format!("{VERSION}:{:x}:{}:{energy}:{report}", r.cycles, f64_hex(r.seconds)))
}

/// Decode the `v1:...` wire string back into a [`SimResult`] — the exact
/// bits [`encode_result`] was handed.
pub fn decode_result(s: &str) -> Result<SimResult> {
    let mut parts = s.splitn(5, ':');
    let version = parts.next().unwrap_or("");
    if version != VERSION {
        bail!("unsupported result wire version {version:?} (expected {VERSION})");
    }
    let cycles = parts.next().context("wire result: missing cycles")?;
    let cycles =
        u64::from_str_radix(cycles, 16).with_context(|| format!("bad cycles {cycles:?}"))?;
    let seconds = f64_from_hex(parts.next().context("wire result: missing seconds")?)?;
    let energy_field = parts.next().context("wire result: missing energy")?;
    let mut energy_bits = energy_field.split(',');
    let mut next_energy = || -> Result<f64> {
        f64_from_hex(energy_bits.next().context("wire result: truncated energy")?)
    };
    let energy = EnergyBreakdown {
        core_j: next_energy()?,
        cache_dynamic_j: next_energy()?,
        cache_static_j: next_energy()?,
        dram_dynamic_j: next_energy()?,
        dram_static_j: next_energy()?,
        vima_j: next_energy()?,
        total_j: next_energy()?,
    };
    let mut report = StatsReport::new();
    let report_field = parts.next().context("wire result: missing report")?;
    for entry in report_field.split(',').filter(|e| !e.is_empty()) {
        let (k, v) = entry
            .split_once('=')
            .with_context(|| format!("bad report entry {entry:?}"))?;
        report.set(k, f64_from_hex(v)?);
    }
    Ok(SimResult { cycles, seconds, energy, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::trace::{Backend, KernelId, TraceParams};

    #[test]
    fn round_trip_is_bit_exact() {
        let cfg = SystemConfig::default();
        let r = crate::sim::simulate(
            &cfg,
            TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20),
        )
        .unwrap();
        let back = decode_result(&encode_result(&r).unwrap()).unwrap();
        assert_eq!(back.cycles, r.cycles);
        assert_eq!(back.seconds.to_bits(), r.seconds.to_bits());
        assert_eq!(back.energy, r.energy);
        assert_eq!(back.report, r.report);
        assert_eq!(
            back.energy.total_j.to_bits(),
            r.energy.total_j.to_bits(),
            "energy must survive bit-for-bit"
        );
    }

    #[test]
    fn awkward_floats_survive() {
        let mut report = StatsReport::new();
        report.set("a.min_subnormal", f64::MIN_POSITIVE / 1e10);
        report.set("b.neg_zero", -0.0);
        report.set("c.huge", 1.23456789e300);
        let r = SimResult {
            cycles: u64::MAX,
            seconds: f64::MIN_POSITIVE,
            energy: EnergyBreakdown { total_j: 0.1 + 0.2, ..Default::default() },
            report,
        };
        let back = decode_result(&encode_result(&r).unwrap()).unwrap();
        assert_eq!(back.cycles, u64::MAX);
        assert_eq!(back.seconds.to_bits(), r.seconds.to_bits());
        assert_eq!(back.energy.total_j.to_bits(), r.energy.total_j.to_bits());
        assert_eq!(
            back.report.get("b.neg_zero").unwrap().to_bits(),
            (-0.0f64).to_bits()
        );
    }

    #[test]
    fn malformed_strings_are_typed_errors() {
        for bad in [
            "",
            "v0:1:3ff0000000000000::",
            "v1:xyz:3ff0000000000000::",
            "v1:1",
            "v1:1:3ff0000000000000:deadbeef:",
            "v1:1:3ff0000000000000:0,0,0,0,0,0,0:noequals",
        ] {
            assert!(decode_result(bad).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn config_toml_round_trips_by_value() {
        // The coordinator ships the *effective* config as TOML; identity
        // (Eq + Hash, hence CellKey) must survive the text round trip.
        let mut cfg = SystemConfig::default();
        cfg.vima.cache_bytes = 16 << 10;
        cfg.core.freq_ghz = 2.337;
        cfg.mem.core_to_bus_ratio = 1.0 / 3.0; // not representable in short decimal
        let back = SystemConfig::from_toml_str(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, back);
    }
}
