//! AVX -> VIMA trace transpiler — the paper's future-work item
//! ("Planning also a compiler pass for automatic conversion of AVX into
//! VIMA instructions, creating a transparent programming interface",
//! Sec. VI), realized at the trace level, where PRIMO-style NDP compilers
//! operate on the same information (memory streams + operation mix).
//!
//! The pass consumes an AVX µop stream in windows and recognizes
//! *streaming idioms*: groups of unit-stride memory streams (one per array)
//! plus the elementwise FP/int operation connecting them. Windows that
//! cover whole 8 KB spans of every stream are rewritten into VIMA
//! instructions; anything that does not match (irregular strides, reuse
//! patterns, partial vectors) passes through untouched, so transpilation is
//! always sound with respect to the memory traffic simulated.
//!
//! Recognized idioms (Sec. IV-A kernels that are pure streams):
//!
//! | loads | stores | FP ops       | rewrite            |
//! |-------|--------|--------------|--------------------|
//! | 0     | 1      | none         | `Bcast`  (MemSet)  |
//! | 1     | 1      | none         | `Mov`    (MemCopy) |
//! | 2     | 1      | add only     | `Add`    (VecSum)  |
//! | 2     | 1      | mul only     | `Mul`              |

use crate::isa::{FuType, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};
use crate::trace::{TraceChunker, TraceStream};

/// Bytes per emitted VIMA instruction.
const VECTOR_BYTES: u64 = 8192;
/// Hard cap on events buffered per transpilation window.
const WINDOW_EVENTS: usize = 65536;
/// Store lines per window (8 vectors' worth): windows end on a vector
/// boundary so a matching stream covers whole 8 KB spans.
const WINDOW_STORE_LINES: u64 = 1024;

/// Statistics of one transpilation run.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TranspileStats {
    pub windows: u64,
    pub windows_rewritten: u64,
    pub uops_consumed: u64,
    pub vima_emitted: u64,
    pub passthrough_events: u64,
}

/// One unit-stride memory stream found in a window.
#[derive(Debug)]
struct Stream {
    /// Array region (arrays live 4 GB apart in the trace layout).
    region: u64,
    base: u64,
    lines: u64,
}

/// Scan a window for per-region unit-stride streams.
///
/// Returns `(load_streams, store_streams, fp_adds, fp_muls, other_fp,
/// other_mem)` or `None` if any region's accesses are not one contiguous
/// 64 B-stride run.
fn analyze(window: &[TraceEvent]) -> Option<(Vec<Stream>, Vec<Stream>, u64, u64, u64)> {
    use std::collections::BTreeMap;
    let mut loads: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    let mut stores: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    let (mut adds, mut muls, mut other_fp) = (0u64, 0u64, 0u64);
    for ev in window {
        match ev {
            TraceEvent::Uop(u) => match u.fu {
                FuType::Load => loads.entry(u.addr >> 32).or_default().push(u.addr & !63),
                FuType::Store => stores.entry(u.addr >> 32).or_default().push(u.addr & !63),
                FuType::FpAlu => adds += 1,
                FuType::FpMul => muls += 1,
                FuType::FpDiv | FuType::IntMul | FuType::IntDiv => other_fp += 1,
                _ => {}
            },
            // already-VIMA or HIVE events: not an AVX window
            _ => return None,
        }
    }
    let to_streams = |m: BTreeMap<u64, Vec<u64>>| -> Option<Vec<Stream>> {
        let mut out = Vec::new();
        for (region, mut addrs) in m {
            addrs.dedup(); // unrolled bodies revisit the same line
            let base = *addrs.first()?;
            for (i, &a) in addrs.iter().enumerate() {
                if a != base + i as u64 * 64 {
                    return None; // not a unit-stride run
                }
            }
            out.push(Stream { region, base, lines: addrs.len() as u64 });
        }
        Some(out)
    };
    Some((to_streams(loads)?, to_streams(stores)?, adds, muls, other_fp))
}

/// Classify a window's streams into a VIMA opcode.
fn classify(loads: &[Stream], stores: &[Stream], adds: u64, muls: u64, other: u64) -> Option<VimaOp> {
    if stores.len() != 1 || other > 0 {
        return None;
    }
    match (loads.len(), adds > 0, muls > 0) {
        (0, false, false) => Some(VimaOp::Bcast),
        (1, false, false) => Some(VimaOp::Mov),
        (2, true, false) => Some(VimaOp::Add),
        (2, false, true) => Some(VimaOp::Mul),
        _ => None,
    }
}

/// The transpiling stream adaptor.
pub struct Transpiler {
    inner: TraceStream,
    out: Vec<TraceEvent>,
    pos: usize,
    window: Vec<TraceEvent>,
    window_store_lines: u64,
    exhausted: bool,
    pub stats: TranspileStats,
}

impl Transpiler {
    pub fn new(inner: TraceStream) -> Self {
        Self {
            inner,
            out: Vec::new(),
            pos: 0,
            window: Vec::with_capacity(4096),
            window_store_lines: 0,
            exhausted: false,
            stats: TranspileStats::default(),
        }
    }

    /// Transpile a full stream into an event vector (tests/inspection).
    pub fn run(inner: TraceStream) -> (Vec<TraceEvent>, TranspileStats) {
        let mut t = Self::new(inner);
        let mut v = Vec::new();
        for e in t.by_ref() {
            v.push(e);
        }
        (v, t.stats)
    }

    fn flush_window(&mut self) {
        self.window_store_lines = 0;
        self.stats.windows += 1;
        let rewritten = self.try_rewrite();
        if !rewritten {
            self.stats.passthrough_events += self.window.len() as u64;
            self.out.append(&mut self.window);
        }
        self.window.clear();
    }

    /// Attempt the idiom rewrite; on success fills `self.out` and returns true.
    fn try_rewrite(&mut self) -> bool {
        let Some((loads, stores, adds, muls, other)) = analyze(&self.window) else {
            return false;
        };
        let Some(op) = classify(&loads, &stores, adds, muls, other) else {
            return false;
        };
        let dst = &stores[0];
        // every stream must cover the same whole number of 8 KB vectors
        let vectors = dst.lines * 64 / VECTOR_BYTES;
        if vectors == 0 || dst.lines * 64 % VECTOR_BYTES != 0 || dst.base % VECTOR_BYTES != 0 {
            return false;
        }
        for l in &loads {
            if l.lines != dst.lines || l.base % VECTOR_BYTES != 0 || l.region == dst.region {
                return false;
            }
        }
        self.stats.windows_rewritten += 1;
        self.stats.uops_consumed += self.window.len() as u64;
        let dtype = if op == VimaOp::Mov || op == VimaOp::Bcast { VDtype::I32 } else { VDtype::F32 };
        for v in 0..vectors {
            let off = v * VECTOR_BYTES;
            let srcs: Vec<u64> = loads.iter().map(|l| l.base + off).collect();
            self.out.push(
                VimaInstr::new(op, dtype, &srcs, Some(dst.base + off), VECTOR_BYTES as u32).into(),
            );
            // keep the loop-control overhead the scalar core still executes
            self.out.push(Uop::alu(0xE00, FuType::IntAlu, [16, NO_REG, NO_REG], 16).into());
            self.out.push(Uop::branch(0xE04, true).into());
            self.stats.vima_emitted += 1;
        }
        true
    }
}

impl Iterator for Transpiler {
    type Item = TraceEvent;

    fn next(&mut self) -> Option<TraceEvent> {
        loop {
            if self.pos < self.out.len() {
                let e = self.out[self.pos];
                self.pos += 1;
                return Some(e);
            }
            self.out.clear();
            self.pos = 0;
            if self.exhausted {
                return None;
            }
            // Fill until the window covers a whole number of 8 KB vectors
            // of store traffic (or the stream/cap ends) so matching streams
            // align to vector boundaries.
            while self.window.len() < WINDOW_EVENTS {
                match self.inner.next() {
                    Some(e) => {
                        if let TraceEvent::Uop(u) = &e {
                            if u.fu == FuType::Store {
                                self.window_store_lines += 1;
                            }
                        }
                        self.window.push(e);
                        if self.window_store_lines >= WINDOW_STORE_LINES {
                            break;
                        }
                    }
                    None => {
                        self.exhausted = true;
                        break;
                    }
                }
            }
            if self.window.is_empty() {
                return None;
            }
            self.flush_window();
        }
    }
}

/// Transpile an AVX trace and wrap it back into a [`TraceStream`].
pub fn transpile(inner: TraceStream) -> TraceStream {
    struct C(Transpiler, bool);
    impl TraceChunker for C {
        fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
            if self.1 {
                return false;
            }
            buf.extend(self.0.by_ref().take(4096));
            if buf.is_empty() {
                self.1 = true;
                return false;
            }
            true
        }
    }
    TraceStream::new(Box::new(C(Transpiler::new(inner), false)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::Machine;
    use crate::trace::{Backend, KernelId, TraceParams};

    fn count_kinds(events: &[TraceEvent]) -> (u64, u64) {
        let mut uops = 0;
        let mut vima = 0;
        for e in events {
            match e {
                TraceEvent::Uop(_) => uops += 1,
                TraceEvent::Vima(_) => vima += 1,
                _ => {}
            }
        }
        (uops, vima)
    }

    #[test]
    fn vecsum_avx_transpiles_to_vima_adds() {
        let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 3 << 20);
        let (events, stats) = Transpiler::run(p.stream().unwrap());
        let (_, vima) = count_kinds(&events);
        assert!(vima > 0, "no VIMA instructions emitted");
        assert!(stats.windows_rewritten > 0);
        // 1 MB per array = 128 vectors
        assert_eq!(stats.vima_emitted, 128);
        for e in &events {
            if let TraceEvent::Vima(v) = e {
                assert_eq!(v.op, VimaOp::Add);
            }
        }
    }

    #[test]
    fn memset_avx_transpiles_to_bcast() {
        let p = TraceParams::new(KernelId::MemSet, Backend::Avx, 1 << 20);
        let (events, stats) = Transpiler::run(p.stream().unwrap());
        assert_eq!(stats.vima_emitted, 128);
        assert!(events.iter().any(|e| matches!(e, TraceEvent::Vima(v) if v.op == VimaOp::Bcast)));
    }

    #[test]
    fn memcopy_avx_transpiles_to_mov() {
        let p = TraceParams::new(KernelId::MemCopy, Backend::Avx, 2 << 20);
        let (_, stats) = Transpiler::run(p.stream().unwrap());
        assert_eq!(stats.vima_emitted, 128);
    }

    #[test]
    fn stencil_does_not_transpile() {
        // Overlapping row reuse is not a pure stream: the pass must leave
        // the trace byte-identical.
        let p = TraceParams::new(KernelId::Stencil, Backend::Avx, 1 << 20);
        let original: Vec<TraceEvent> = p.stream().unwrap().collect();
        let (events, stats) = Transpiler::run(p.stream().unwrap());
        assert_eq!(stats.vima_emitted, 0);
        assert_eq!(events.len(), original.len());
        assert_eq!(events, original);
    }

    #[test]
    fn matmul_does_not_transpile() {
        let p = TraceParams::new(KernelId::MatMul, Backend::Avx, 3 << 20);
        let (events, stats) = Transpiler::run(p.stream().unwrap());
        let _ = events;
        assert_eq!(stats.vima_emitted, 0, "strided column walks must pass through");
    }

    #[test]
    fn transpiled_vecsum_approaches_handwritten_vima() {
        let cfg = SystemConfig::default();
        let footprint = 6u64 << 20;
        let avx = TraceParams::new(KernelId::VecSum, Backend::Avx, footprint);
        let vima = TraceParams::new(KernelId::VecSum, Backend::Vima, footprint);

        let mut m = Machine::new(&cfg, 1).unwrap();
        let base = m.run(vec![avx.stream().unwrap()]).unwrap();
        let mut m = Machine::new(&cfg, 1).unwrap();
        let auto = m.run(vec![transpile(avx.stream().unwrap())]).unwrap();
        let mut m = Machine::new(&cfg, 1).unwrap();
        let hand = m.run(vec![vima.stream().unwrap()]).unwrap();

        let auto_speedup = base.cycles as f64 / auto.cycles as f64;
        let hand_speedup = base.cycles as f64 / hand.cycles as f64;
        assert!(auto_speedup > 0.7 * hand_speedup,
            "transpiled {auto_speedup:.2}x vs handwritten {hand_speedup:.2}x");
    }

    #[test]
    fn empty_stream_produces_nothing() {
        // Zero-footprint params are now a validation error, so build the
        // empty stream directly.
        struct Empty;
        impl TraceChunker for Empty {
            fn refill(&mut self, _buf: &mut Vec<TraceEvent>) -> bool {
                false
            }
        }
        let (events, stats) = Transpiler::run(TraceStream::new(Box::new(Empty)));
        assert!(events.is_empty());
        assert_eq!(stats.vima_emitted, 0);
    }

    #[test]
    fn vima_input_passes_through_untouched() {
        // Feeding an already-VIMA trace must be a no-op rewrite.
        let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20);
        let original: Vec<TraceEvent> = p.stream().unwrap().collect();
        let (events, stats) = Transpiler::run(p.stream().unwrap());
        assert_eq!(events, original);
        assert_eq!(stats.windows_rewritten, 0);
    }

    #[test]
    fn mixed_trace_transpiles_only_streaming_windows() {
        // VecSum (transpilable) followed by Stencil (not): the pass must
        // rewrite the first and keep the second.
        let vs = TraceParams::new(KernelId::VecSum, Backend::Avx, 3 << 20);
        let st = TraceParams::new(KernelId::Stencil, Backend::Avx, 1 << 20);
        let mixed: Vec<TraceEvent> = vs.stream().unwrap().chain(st.stream().unwrap()).collect();
        struct VecChunker(std::vec::IntoIter<TraceEvent>, bool);
        impl TraceChunker for VecChunker {
            fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
                if self.1 {
                    return false;
                }
                buf.extend(self.0.by_ref());
                self.1 = true;
                !buf.is_empty()
            }
        }
        let stream = TraceStream::new(Box::new(VecChunker(mixed.into_iter(), false)));
        let (events, stats) = Transpiler::run(stream);
        assert!(stats.vima_emitted > 0);
        assert!(stats.passthrough_events > 0);
        // stencil FpMul ops survive
        assert!(events
            .iter()
            .any(|e| matches!(e, TraceEvent::Uop(u) if u.fu == FuType::FpMul)));
    }
}
