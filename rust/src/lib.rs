//! # vima-sim — Vector-In-Memory Architecture reproduction
//!
//! A cycle-level simulator + PJRT functional runtime reproducing the paper
//! *"Vector In Memory Architecture for simple and high efficiency computing"*
//! (Alves et al., 2022).
//!
//! The stack has three layers (see `DESIGN.md`):
//!
//! * **Layer 3 (this crate)** — the Rust coordinator: a trace-driven,
//!   cycle-level timing model of the whole system of Table I (out-of-order
//!   core, three-level cache hierarchy, 3D-stacked memory with 32 vaults —
//!   shardable across `N` chained cubes via the [`fabric`] front door,
//!   one VIMA logic layer per cube — and the HIVE comparator), plus the
//!   experiment drivers that regenerate every figure of the paper through the
//!   [`sweep`] engine (a declarative, deduplicating, multi-threaded run
//!   grid — see EXPERIMENTS.md). The workload surface is *open*: the
//!   [`workload`] registry serves the paper's seven kernels and any
//!   user-registered workload — notably [`intrinsics::VimaProgram`]s, the
//!   streaming Intrinsics-VIMA DSL that lowers one program to both a VIMA
//!   stream and an honest AVX baseline — through the same
//!   `simulate`/sweep/CLI paths, with typed errors instead of panics on
//!   unsupported combinations. Every entry point funnels into the
//!   [`service`] layer: one long-lived [`service::SimService`] scheduler
//!   (worker pool, pooled machines, bounded result cache, exactly-once
//!   dedup) behind `simulate`, sweeps, figures, and the `vima-sim serve`
//!   JSONL mode — which the [`net`] layer promotes to real TCP/Unix-socket
//!   serving and multi-process sweep sharding (`vima-sim net`).
//! * **Layer 2 (python/compile/model.py)** — JAX workload graphs, AOT-lowered
//!   to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels modelling the
//!   256-lane VIMA vector units.
//!
//! The `runtime` module (behind the off-by-default `pjrt` feature — it
//! needs the `xla` crate, see `Cargo.toml`) loads the AOT artifacts through
//! the PJRT C API so simulations can be run *functionally* (real numerics)
//! as well as *temporally* (cycles/energy). Python is never on the run
//! path, and the default build has no dependencies at all.

#![forbid(unsafe_code)]

pub mod analyze;
pub mod bench;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod cpu;
pub mod energy;
pub mod fabric;
pub mod hive;
pub mod intrinsics;
pub mod isa;
pub mod mem3d;
pub mod net;
pub mod program;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod service;
pub mod sim;
pub mod stats;
pub mod sweep;
pub mod trace;
pub mod transpile;
pub mod util;
pub mod vima;
pub mod workload;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::analyze::{Diagnostic, Report, Severity};
    pub use crate::config::SystemConfig;
    pub use crate::fabric::{FabricPort, MemFabric, VimaDispatcher};
    pub use crate::coordinator::{
        workloads::{SizedWorkload, WorkloadSet},
        Experiment, FigTable, RunSpec,
    };
    pub use crate::intrinsics::{VecPtr, VimaProgram};
    pub use crate::net::{NetServer, NetSummary, ShardOptions, ShardStats};
    pub use crate::program::ParsedVpr;
    pub use crate::service::{Job, JobHandle, JobStatus, ServiceConfig, SimService};
    pub use crate::sim::{Machine, SimResult};
    pub use crate::sweep::{RunCell, SweepPlan, SweepRunner};
    pub use crate::trace::{Backend, KernelId, TraceParams};
    pub use crate::workload::{ProgramWorkload, Workload, WorkloadId, WorkloadKind};
}
