//! Instruction-set model: host micro-ops, VIMA vector instructions, and the
//! HIVE transaction ops, plus the trace-event container the simulator consumes.
//!
//! The simulator is trace-driven (the paper used Pin-generated traces; we
//! generate equivalent synthetic streams in [`crate::trace`]). A trace is a
//! sequence of [`TraceEvent`]s: ordinary x86-like micro-ops for the baseline
//! portions, [`VimaInstr`]s for code compiled against Intrinsics-VIMA, and
//! [`HiveOp`]s for the HIVE comparator.

/// Functional-unit classes of the out-of-order core (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FuType {
    IntAlu,
    IntMul,
    IntDiv,
    FpAlu,
    FpMul,
    FpDiv,
    Load,
    Store,
    Branch,
    /// Pipeline slot only (e.g. fences); no FU, 1-cycle.
    Nop,
}

/// Register id inside the synthetic trace; `NO_REG` means "unused slot".
pub type Reg = u8;
pub const NO_REG: Reg = u8::MAX;

/// One host micro-op as produced by the trace generators.
///
/// Kept small (fits in 32 bytes) — the simulator streams hundreds of millions
/// of these through the core model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uop {
    /// Static program counter (drives the branch predictor and BTB).
    pub pc: u64,
    pub fu: FuType,
    /// Source registers (`NO_REG` = unused).
    pub srcs: [Reg; 3],
    /// Destination register (`NO_REG` = none).
    pub dst: Reg,
    /// Memory address for loads/stores (ignored otherwise).
    pub addr: u64,
    /// Access size in bytes for loads/stores.
    pub size: u16,
    /// For branches: actually taken?
    pub taken: bool,
}

impl Uop {
    pub fn alu(pc: u64, fu: FuType, srcs: [Reg; 3], dst: Reg) -> Self {
        Self { pc, fu, srcs, dst, addr: 0, size: 0, taken: false }
    }

    pub fn load(pc: u64, addr: u64, size: u16, dst: Reg) -> Self {
        Self { pc, fu: FuType::Load, srcs: [NO_REG; 3], dst, addr, size, taken: false }
    }

    pub fn load_dep(pc: u64, addr: u64, size: u16, srcs: [Reg; 3], dst: Reg) -> Self {
        Self { pc, fu: FuType::Load, srcs, dst, addr, size, taken: false }
    }

    pub fn store(pc: u64, addr: u64, size: u16, srcs: [Reg; 3]) -> Self {
        Self { pc, fu: FuType::Store, srcs, dst: NO_REG, addr, size, taken: false }
    }

    pub fn branch(pc: u64, taken: bool) -> Self {
        Self { pc, fu: FuType::Branch, srcs: [NO_REG; 3], dst: NO_REG, addr: 0, size: 0, taken }
    }

    pub fn is_mem(&self) -> bool {
        matches!(self.fu, FuType::Load | FuType::Store)
    }
}

/// VIMA operand element types (Intrinsics-VIMA supports 32/64-bit int + fp).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VDtype {
    I32,
    I64,
    F32,
    F64,
}

impl VDtype {
    pub fn bytes(&self) -> usize {
        match self {
            VDtype::I32 | VDtype::F32 => 4,
            VDtype::I64 | VDtype::F64 => 8,
        }
    }

    pub fn is_float(&self) -> bool {
        matches!(self, VDtype::F32 | VDtype::F64)
    }
}

/// VIMA vector opcodes (NEON-flavoured, Sec. III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VimaOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
    And,
    Or,
    Xor,
    /// Fused multiply-add (3 sources).
    Fma,
    /// Copy src -> dst (MemCopy primitive).
    Mov,
    /// Broadcast an immediate into dst (MemSet primitive); no vector sources.
    Bcast,
    /// Dot-product reduction: consumes two vectors, produces a scalar.
    Dot,
    /// Horizontal sum, one vector -> scalar.
    RedSum,
}

impl VimaOp {
    /// Which VIMA FU pipeline executes this op (alu / mul / div).
    pub fn fu_kind(&self) -> VimaFuKind {
        match self {
            VimaOp::Mul | VimaOp::Dot | VimaOp::Fma => VimaFuKind::Mul,
            VimaOp::Div => VimaFuKind::Div,
            _ => VimaFuKind::Alu,
        }
    }

    pub fn num_srcs(&self) -> usize {
        match self {
            VimaOp::Bcast => 0,
            VimaOp::Mov | VimaOp::RedSum => 1,
            VimaOp::Fma => 3,
            _ => 2,
        }
    }

    /// Does this op write a full vector back to memory (vs a scalar)?
    pub fn writes_vector(&self) -> bool {
        !matches!(self, VimaOp::Dot | VimaOp::RedSum)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VimaFuKind {
    Alu,
    Mul,
    Div,
}

/// "No address" sentinel inside [`VimaInstr`] (kept compact: traces stream
/// hundreds of millions of events).
pub const NO_ADDR: u64 = u64::MAX;

/// One VIMA instruction: operates over `vector_bytes` starting at each
/// operand base address (operands are vector-aligned per Intrinsics-VIMA).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VimaInstr {
    pub op: VimaOp,
    pub dtype: VDtype,
    /// Source vector base addresses (`NO_ADDR` = unused/immediate slot).
    pub srcs: [u64; 3],
    /// Destination vector base address; `NO_ADDR` for reductions kept
    /// on-chip until the scalar result is signalled back.
    dst: u64,
    pub vector_bytes: u32,
}

impl VimaInstr {
    pub fn new(op: VimaOp, dtype: VDtype, srcs: &[u64], dst: Option<u64>, vector_bytes: u32) -> Self {
        assert!(srcs.len() <= 3, "VIMA instructions have at most 3 sources");
        assert_eq!(srcs.len(), op.num_srcs(), "{op:?} expects {} sources", op.num_srcs());
        let mut s = [NO_ADDR; 3];
        for (slot, &a) in s.iter_mut().zip(srcs) {
            *slot = a;
        }
        Self { op, dtype, srcs: s, dst: dst.unwrap_or(NO_ADDR), vector_bytes }
    }

    /// Destination base address, if this op writes one.
    pub fn dst(&self) -> Option<u64> {
        (self.dst != NO_ADDR).then_some(self.dst)
    }

    pub fn src_addrs(&self) -> impl Iterator<Item = u64> + '_ {
        self.srcs.iter().copied().filter(|&a| a != NO_ADDR)
    }

    /// Unique vector operands to fetch (sources sharing an address fetch once).
    pub fn unique_src_addrs(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.src_addrs().collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// HIVE ISA (Alves et al., DATE 2016): explicit register-bank management
/// wrapped in lock/unlock transactions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HiveOp {
    /// Acquire the register bank (whole-bank lock; blocks other threads).
    Lock,
    /// Release the bank; forces sequential write-back of all dirty registers.
    Unlock,
    /// Load one vector from memory into register `reg`.
    LoadReg { reg: u8, addr: u64 },
    /// Store register `reg` to memory (explicit, pre-unlock).
    StoreReg { reg: u8, addr: u64 },
    /// FU operation on registers: `rd = r1 op r2`.
    Compute { op: VimaOp, dtype: VDtype, r1: u8, r2: u8, rd: u8 },
}

/// One element of a simulation trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    Uop(Uop),
    Vima(VimaInstr),
    Hive(HiveOp),
}

impl From<Uop> for TraceEvent {
    fn from(u: Uop) -> Self {
        TraceEvent::Uop(u)
    }
}

impl From<VimaInstr> for TraceEvent {
    fn from(v: VimaInstr) -> Self {
        TraceEvent::Vima(v)
    }
}

impl From<HiveOp> for TraceEvent {
    fn from(h: HiveOp) -> Self {
        TraceEvent::Hive(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uop_is_small() {
        // The core model streams ~1e8 of these; keep them cache-friendly.
        assert!(std::mem::size_of::<Uop>() <= 32, "{}", std::mem::size_of::<Uop>());
        assert!(
            std::mem::size_of::<TraceEvent>() <= 56,
            "{}",
            std::mem::size_of::<TraceEvent>()
        );
    }

    #[test]
    fn vima_instr_construction() {
        let i = VimaInstr::new(VimaOp::Add, VDtype::F32, &[0x1000, 0x3000], Some(0x5000), 8192);
        assert_eq!(i.unique_src_addrs(), vec![0x1000, 0x3000]);
        assert_eq!(i.op.num_srcs(), 2);
        assert!(i.op.writes_vector());
    }

    #[test]
    fn vima_shared_operand_dedup() {
        let i = VimaInstr::new(VimaOp::Mul, VDtype::F32, &[0x1000, 0x1000], Some(0x5000), 8192);
        assert_eq!(i.unique_src_addrs(), vec![0x1000]);
    }

    #[test]
    #[should_panic(expected = "expects 2 sources")]
    fn vima_wrong_arity_panics() {
        VimaInstr::new(VimaOp::Add, VDtype::F32, &[0x1000], Some(0x5000), 8192);
    }

    #[test]
    fn fu_kind_mapping() {
        assert_eq!(VimaOp::Add.fu_kind(), VimaFuKind::Alu);
        assert_eq!(VimaOp::Dot.fu_kind(), VimaFuKind::Mul);
        assert_eq!(VimaOp::Div.fu_kind(), VimaFuKind::Div);
        assert_eq!(VimaOp::Bcast.num_srcs(), 0);
        assert!(!VimaOp::RedSum.writes_vector());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(VDtype::I32.bytes(), 4);
        assert_eq!(VDtype::F64.bytes(), 8);
        assert!(VDtype::F32.is_float());
        assert!(!VDtype::I64.is_float());
    }
}
