//! 3D-stacked memory model (Table I row 5).
//!
//! 32 vaults x 8 banks, 256 B row buffers, closed-row policy, DRAM @ 1666 MHz,
//! 4 serial links @ 8 GHz with 8 B bursts towards the host. All timestamps are
//! in **CPU cycles** (the host clock); DRAM/link cycles are converted through
//! the configured frequency ratios.
//!
//! The model is latency-forwarding rather than per-cycle: each request
//! reserves its resources (vault command slot, bank busy window, data bus,
//! link slots) by advancing per-resource `next_free` clocks, which yields the
//! same queueing behaviour as a cycle-stepped model for in-order resource
//! reservation at a fraction of the simulation cost.
//!
//! Two ports exist, matching the paper's two data paths:
//! * [`Mem3D::host_access`] — misses from the host LLC cross the serial
//!   links, touch one vault/bank, and return over the links.
//! * [`Mem3D::vima_access`] — VIMA sub-requests are issued *inside* the cube
//!   by the sequencer (Sec. III-D): no link crossing, full vault parallelism.

use crate::config::Mem3DConfig;
use crate::stats::StatsReport;
use crate::util::error::Result;

/// Per-request resource usage summary (returned for testing/inspection).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemCompletion {
    /// Cycle at which data is available at the requester.
    pub done: u64,
    pub vault: usize,
    pub bank: usize,
}

/// The memory-access port the logic-layer devices (VIMA, HIVE) drive.
///
/// A single [`Mem3D`] implements it directly (the classic one-cube system);
/// [`FabricPort`](crate::fabric::FabricPort) implements it by routing each
/// 64 B sub-request to the cube that owns its address and charging inter-cube
/// hops — so the devices are agnostic to whether they sit on one cube or on
/// a sharded multi-cube fabric.
pub trait MemPort {
    /// One 64 B sub-request issued from the logic layer (no host links).
    fn vima_access(&mut self, addr: u64, is_write: bool, now: u64) -> MemCompletion;
    /// Earliest cycle at which the backing memory is fully idle.
    fn drained_at(&self) -> u64;
}

impl MemPort for Mem3D {
    fn vima_access(&mut self, addr: u64, is_write: bool, now: u64) -> MemCompletion {
        Mem3D::vima_access(self, addr, is_write, now)
    }

    fn drained_at(&self) -> u64 {
        Mem3D::drained_at(self)
    }
}

#[derive(Debug, Default, Clone)]
pub struct MemStats {
    pub host_reads: u64,
    pub host_writes: u64,
    pub vima_reads: u64,
    pub vima_writes: u64,
    /// Bits moved on each path (drives the pJ/bit energy numbers).
    pub host_bits: u64,
    pub vima_bits: u64,
    /// Sum of queueing delays (cycles spent waiting for bank/vault/link).
    pub host_queue_cycles: u64,
    pub vima_queue_cycles: u64,
}

impl MemStats {
    /// Accumulate another stats block (per-cube totals in the fabric).
    pub fn accumulate(&mut self, other: &MemStats) {
        self.host_reads += other.host_reads;
        self.host_writes += other.host_writes;
        self.vima_reads += other.vima_reads;
        self.vima_writes += other.vima_writes;
        self.host_bits += other.host_bits;
        self.vima_bits += other.vima_bits;
        self.host_queue_cycles += other.host_queue_cycles;
        self.vima_queue_cycles += other.vima_queue_cycles;
    }

    /// Emit the standard `mem.*` counter keys.
    pub fn dump_into(&self, report: &mut StatsReport) {
        report.add("mem.host_reads", self.host_reads as f64);
        report.add("mem.host_writes", self.host_writes as f64);
        report.add("mem.vima_reads", self.vima_reads as f64);
        report.add("mem.vima_writes", self.vima_writes as f64);
        report.add("mem.host_bits", self.host_bits as f64);
        report.add("mem.vima_bits", self.vima_bits as f64);
        report.add("mem.host_queue_cycles", self.host_queue_cycles as f64);
        report.add("mem.vima_queue_cycles", self.vima_queue_cycles as f64);
    }
}

/// The stacked-memory cube.
#[derive(Debug)]
pub struct Mem3D {
    cfg: Mem3DConfig,
    /// `next_free` per bank (vault-major: `vault * banks_per_vault + bank`).
    bank_free: Vec<u64>,
    /// Open row per bank (open-row policy ablation; u64::MAX = closed).
    bank_open_row: Vec<u64>,
    /// Vault command-issue slot (one command per DRAM cycle).
    vault_cmd_free: Vec<u64>,
    /// Vault internal data bus (TSV column) occupancy.
    vault_data_free: Vec<u64>,
    /// Serial links, one aggregate channel per direction, in half-cycles
    /// (64 B occupies the aggregated links for 0.5 CPU cycles at Table I rates).
    link_to_mem_free_x2: u64,
    link_from_mem_free_x2: u64,
    /// Precomputed CPU-cycle latencies.
    lat_access: u64,
    lat_cas: u64,
    lat_cas_write: u64,
    lat_row_miss: u64,
    lat_row_miss_write: u64,
    lat_bank_busy: u64,
    lat_cmd: u64,
    lat_data_burst: u64,
    lat_write: u64,
    link_halfcycles_per_line: u64,
    /// Precomputed [`map`](Self::map) geometry — the mapping runs once per
    /// 64 B sub-request, the hottest DRAM-side path.
    vault_mask: usize,
    vault_shift: u32,
    bank_mask: usize,
    /// Row-index shift for [`map`](Self::map): line bits consumed by the
    /// vault index, the bank index, and the lines-per-row offset (derived
    /// from `row_buffer_bytes`, not hardcoded).
    row_shift: u32,
    pub stats: MemStats,
}

impl Mem3D {
    /// Build one cube, validating the address-geometry fields. The mask/
    /// shift mapping in [`map`](Self::map) silently corrupts vault/bank
    /// indices for non-power-of-two geometries, so those are typed errors
    /// (naming the bad field) rather than debug-only assertions.
    pub fn new(cfg: &Mem3DConfig, cpu_ghz: f64) -> Result<Self> {
        crate::ensure!(
            cfg.vaults >= 1 && cfg.vaults.is_power_of_two(),
            "mem3d.vaults ({}) must be a power of two (the vault index is mask/shift mapped)",
            cfg.vaults
        );
        crate::ensure!(
            cfg.banks_per_vault >= 1 && cfg.banks_per_vault.is_power_of_two(),
            "mem3d.banks_per_vault ({}) must be a power of two (the bank index is mask/shift mapped)",
            cfg.banks_per_vault
        );
        let lines_per_row = (cfg.row_buffer_bytes / 64).max(1);
        crate::ensure!(
            cfg.row_buffer_bytes % 64 == 0 && lines_per_row.is_power_of_two(),
            "mem3d.row_buffer_bytes ({}) must hold a power-of-two count of 64 B lines",
            cfg.row_buffer_bytes
        );
        let n_banks = cfg.vaults * cfg.banks_per_vault;
        // 64 B line over an 8 B-wide internal bank bus (one flit per DRAM cycle).
        let data_burst_dram = (64 / 8) as u64;
        let link_cyc = cfg.link_cycles_per_line(cpu_ghz);
        let row_shift = cfg.vaults.trailing_zeros()
            + cfg.banks_per_vault.trailing_zeros()
            + lines_per_row.trailing_zeros();
        Ok(Self {
            bank_free: vec![0; n_banks],
            bank_open_row: vec![u64::MAX; n_banks],
            vault_cmd_free: vec![0; cfg.vaults],
            vault_data_free: vec![0; cfg.vaults],
            link_to_mem_free_x2: 0,
            link_from_mem_free_x2: 0,
            lat_access: cfg.dram_to_cpu(cfg.access_dram_cycles(), cpu_ghz),
            lat_cas: cfg.dram_to_cpu(cfg.t_cas, cpu_ghz),
            lat_cas_write: cfg.dram_to_cpu(cfg.t_cwd, cpu_ghz),
            lat_row_miss: cfg.dram_to_cpu(cfg.t_rp + cfg.t_rcd + cfg.t_cas, cpu_ghz),
            lat_row_miss_write: cfg.dram_to_cpu(cfg.t_rp + cfg.t_rcd + cfg.t_cwd, cpu_ghz),
            lat_bank_busy: cfg.dram_to_cpu(cfg.bank_busy_dram_cycles(), cpu_ghz),
            lat_cmd: cfg.dram_to_cpu(1, cpu_ghz).max(1),
            lat_data_burst: cfg.dram_to_cpu(data_burst_dram, cpu_ghz),
            lat_write: cfg.dram_to_cpu(cfg.t_cwd + cfg.t_rcd, cpu_ghz),
            link_halfcycles_per_line: (link_cyc * 2.0).ceil() as u64,
            vault_mask: cfg.vaults - 1,
            vault_shift: cfg.vaults.trailing_zeros(),
            bank_mask: cfg.banks_per_vault - 1,
            row_shift,
            cfg: cfg.clone(),
            stats: MemStats::default(),
        })
    }

    pub fn config(&self) -> &Mem3DConfig {
        &self.cfg
    }

    /// Latency of one uncontended host read (activate + column + burst +
    /// link), used e.g. as the prefetch fill-time estimate.
    pub fn uncontended_read_latency(&self) -> u64 {
        self.lat_cmd + self.lat_access + self.lat_data_burst + self.link_halfcycles_per_line
    }

    /// Line-interleaved address mapping with XOR-folded bank/vault hashing:
    /// consecutive 64 B lines hit consecutive vaults (full stream
    /// parallelism, Sec. III-D: sub-requests "are issued to different vaults
    /// and banks"), while higher address bits are folded in so that distinct
    /// arrays and thread slices land on decorrelated vault/bank phases —
    /// the standard channel-hash memory controllers use to avoid pathological
    /// multi-stream bank conflicts.
    pub fn map(&self, addr: u64) -> (usize, usize, u64) {
        let line = addr >> 6;
        let mix = line ^ (line >> 5) ^ (line >> 10) ^ (line >> 15) ^ (line >> 20) ^ (line >> 25);
        let vault = (mix as usize) & self.vault_mask;
        let bank = ((mix >> self.vault_shift) as usize) & self.bank_mask;
        let row = line >> self.row_shift;
        (vault, bank, row)
    }

    /// Schedule the DRAM-side portion (vault command + bank + data bus).
    /// Returns (data_ready_at_vault, queue_delay).
    fn dram_access(&mut self, addr: u64, is_write: bool, at: u64) -> (u64, u64, usize, usize) {
        let (vault, bank, row) = self.map(addr);
        let bank_idx = vault * self.cfg.banks_per_vault + bank;

        // Vault controller issues one command per DRAM cycle.
        let cmd_start = at.max(self.vault_cmd_free[vault]);
        self.vault_cmd_free[vault] = cmd_start + self.lat_cmd;

        let bank_start = cmd_start.max(self.bank_free[bank_idx]);
        let (busy, access) = if self.cfg.open_row {
            // Open-row ablation: a row-buffer hit pays the column latency
            // only; a miss pays precharge + activate + column and keeps the
            // row open. Writes use the write column delay (CWD), not CAS.
            let (hit, miss) = if is_write {
                (self.lat_cas_write, self.lat_row_miss_write)
            } else {
                (self.lat_cas, self.lat_row_miss)
            };
            if self.bank_open_row[bank_idx] == row {
                (hit, hit)
            } else {
                self.bank_open_row[bank_idx] = row;
                (miss, miss)
            }
        } else {
            // Table I: closed-row policy — every access activates; the bank
            // is busy for RAS + RP.
            (self.lat_bank_busy, if is_write { self.lat_write } else { self.lat_access })
        };
        self.bank_free[bank_idx] = bank_start + busy;
        let array_done = bank_start + access;

        // Data crosses the vault's internal bus (shared by its 8 banks).
        let bus_start = array_done.max(self.vault_data_free[vault]);
        self.vault_data_free[vault] = bus_start + self.lat_data_burst;
        let done = bus_start + self.lat_data_burst;

        let queue = (bank_start - at) + (bus_start - array_done);
        (done, queue, vault, bank)
    }

    /// Reserve one 64 B slot on a link direction; returns transfer-done time.
    fn link_transfer(free_x2: &mut u64, at: u64, occupancy_x2: u64) -> u64 {
        let start_x2 = (at * 2).max(*free_x2);
        *free_x2 = start_x2 + occupancy_x2;
        (start_x2 + occupancy_x2).div_ceil(2)
    }

    /// Host-side access for one 64 B line (issued on an LLC miss/writeback).
    ///
    /// Reads: command crosses the links, DRAM access, data returns over the
    /// links. Writes: data crosses the links and is posted; completion is the
    /// DRAM accept time.
    pub fn host_access(&mut self, addr: u64, is_write: bool, now: u64) -> MemCompletion {
        let occ = self.link_halfcycles_per_line;
        let at_mem = if is_write {
            // command + 64 B payload to the cube
            Self::link_transfer(&mut self.link_to_mem_free_x2, now, occ)
        } else {
            // command packet: negligible payload, 1 half-cycle slot
            Self::link_transfer(&mut self.link_to_mem_free_x2, now, 1)
        };
        let (dram_done, queue, vault, bank) = self.dram_access(addr, is_write, at_mem);
        let done = if is_write {
            dram_done
        } else {
            Self::link_transfer(&mut self.link_from_mem_free_x2, dram_done, occ)
        };
        if is_write {
            self.stats.host_writes += 1;
        } else {
            self.stats.host_reads += 1;
        }
        self.stats.host_bits += 64 * 8;
        self.stats.host_queue_cycles += queue;
        MemCompletion { done, vault, bank }
    }

    /// VIMA-side access for one 64 B sub-request: no link crossing, the
    /// requester sits on the logic layer under the vaults.
    pub fn vima_access(&mut self, addr: u64, is_write: bool, now: u64) -> MemCompletion {
        let (done, queue, vault, bank) = self.dram_access(addr, is_write, now);
        if is_write {
            self.stats.vima_writes += 1;
        } else {
            self.stats.vima_reads += 1;
        }
        self.stats.vima_bits += 64 * 8;
        self.stats.vima_queue_cycles += queue;
        MemCompletion { done, vault, bank }
    }

    /// Functional (state-update-only) host access: count the traffic and
    /// the bits moved, touch **no** resource clock. Used by the sampled
    /// engine's fast-forward phases (DESIGN.md §11): traffic counters stay
    /// exact while `bank_free`/`vault_*`/link clocks — which would fake
    /// resource saturation into the next detailed window if advanced at a
    /// frozen timestamp — are left untouched. Queue-delay cycles are a
    /// timing quantity and accrue only in detailed windows.
    #[inline]
    pub fn host_access_functional(&mut self, _addr: u64, is_write: bool) {
        if is_write {
            self.stats.host_writes += 1;
        } else {
            self.stats.host_reads += 1;
        }
        self.stats.host_bits += 64 * 8;
    }

    /// Functional VIMA-side access; see
    /// [`host_access_functional`](Self::host_access_functional).
    #[inline]
    pub fn vima_access_functional(&mut self, _addr: u64, is_write: bool) {
        if is_write {
            self.stats.vima_writes += 1;
        } else {
            self.stats.vima_reads += 1;
        }
        self.stats.vima_bits += 64 * 8;
    }

    /// Earliest cycle at which every resource is idle (drain point):
    /// banks, vault data buses, **vault command slots**, and both link
    /// directions. The command slots used to be omitted, so the drain point
    /// could land before the last vault command retired whenever a timing
    /// configuration makes `lat_cmd` exceed the post-command bank/bus
    /// occupancy.
    pub fn drained_at(&self) -> u64 {
        let b = self.bank_free.iter().copied().max().unwrap_or(0);
        let v = self.vault_data_free.iter().copied().max().unwrap_or(0);
        let c = self.vault_cmd_free.iter().copied().max().unwrap_or(0);
        b.max(v)
            .max(c)
            .max(self.link_from_mem_free_x2.div_ceil(2))
            .max(self.link_to_mem_free_x2.div_ceil(2))
    }

    pub fn dump_stats(&self, report: &mut StatsReport) {
        self.stats.dump_into(report);
    }

    /// Reset all resource clocks and stats (reuse across runs).
    pub fn reset(&mut self) {
        self.bank_free.fill(0);
        self.bank_open_row.fill(u64::MAX);
        self.vault_cmd_free.fill(0);
        self.vault_data_free.fill(0);
        self.link_to_mem_free_x2 = 0;
        self.link_from_mem_free_x2 = 0;
        self.stats = MemStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> Mem3D {
        Mem3D::new(&Mem3DConfig::default(), 2.0).unwrap()
    }

    #[test]
    fn map_interleaves_lines_across_vaults() {
        let m = mem();
        // 32 consecutive lines must cover all 32 vaults.
        let mut seen = std::collections::HashSet::new();
        for i in 0..32u64 {
            seen.insert(m.map(i * 64).0);
        }
        assert_eq!(seen.len(), 32);
    }

    #[test]
    fn map_decorrelates_array_bases() {
        // The trace layout puts arrays 1 GB apart; equal offsets into
        // different arrays must not collide on the same (vault, bank).
        let m = mem();
        let a = m.map(0x1_0000_0000);
        let b = m.map(0x2_0000_0000);
        let c = m.map(0x3_0000_0000);
        assert!(a != b || b != c, "array streams alias: {a:?} {b:?} {c:?}");
    }

    #[test]
    fn single_read_latency() {
        let mut m = mem();
        let c = m.vima_access(0, false, 0);
        // RCD+CAS = 18 DRAM cycles ~ 22 CPU cycles + burst ~ 10 + cmd slot
        assert!(c.done >= 22 && c.done <= 45, "latency {}", c.done);
        assert_eq!(m.stats.vima_reads, 1);
    }

    #[test]
    fn same_bank_serializes_different_banks_overlap() {
        let mut m = mem();
        // Two accesses to the same line -> same bank: second waits.
        let a = m.vima_access(0, false, 0);
        let b = m.vima_access(0, false, 0);
        assert!(b.done > a.done);

        let mut m2 = mem();
        // Different vaults: near-perfect overlap.
        let a2 = m2.vima_access(0, false, 0);
        let b2 = m2.vima_access(64, false, 0);
        assert!(b2.done <= a2.done + m2.lat_cmd, "{} vs {}", b2.done, a2.done);
    }

    #[test]
    fn host_read_pays_link_crossing() {
        let mut host = mem();
        let mut vima = mem();
        let h = host.host_access(0, false, 0);
        let v = vima.vima_access(0, false, 0);
        assert!(h.done > v.done, "host {} vs vima {}", h.done, v.done);
    }

    #[test]
    fn link_contention_throttles_host_streams() {
        let mut m = mem();
        // Saturate: 1000 reads to distinct vaults/banks at cycle 0.
        let mut last = 0;
        for i in 0..1000u64 {
            last = m.host_access(i * 64, false, 0).done;
        }
        // Aggregate link BW = 128 B/cycle => 1000 lines need >= 500 cycles.
        assert!(last >= 500, "links not throttling: {last}");
    }

    #[test]
    fn vima_parallel_vector_fetch_is_fast() {
        let mut m = mem();
        // One 8 KB vector = 128 sub-requests, line-interleaved.
        let mut done = 0;
        for i in 0..128u64 {
            done = done.max(m.vima_access(i * 64, false, 0).done);
        }
        // 128 lines over 32 vaults = 4 per vault: burst-pipelined, far faster
        // than 128 serial accesses (~128*30 cycles).
        assert!(done < 150, "vector fetch too slow: {done}");
        assert_eq!(m.stats.vima_reads, 128);
    }

    #[test]
    fn writes_post_faster_than_reads_return() {
        let mut m = mem();
        let w = m.host_access(0, true, 0);
        let mut m2 = mem();
        let r = m2.host_access(0, false, 0);
        assert!(w.done <= r.done);
    }

    #[test]
    fn reset_clears_state() {
        let mut m = mem();
        m.host_access(0, false, 0);
        m.reset();
        assert_eq!(m.stats.host_reads, 0);
        assert_eq!(m.drained_at(), 0);
    }

    #[test]
    fn open_row_policy_rewards_locality() {
        let mut cfg = Mem3DConfig::default();
        cfg.open_row = true;
        let mut open = Mem3D::new(&cfg, 2.0).unwrap();
        let mut closed = mem();
        // 4 consecutive lines share a 256 B row: sequential same-row hits.
        let mut t_open = 0;
        let mut t_closed = 0;
        for rep in 0..64u64 {
            let addr = (rep / 4) * 32 * 64 * 8 + (rep % 4) * 64; // same vault/bank row walk
            let _ = addr;
        }
        // simpler: hammer one bank with the same row
        for _ in 0..32 {
            t_open = open.vima_access(0, false, t_open).done;
            t_closed = closed.vima_access(0, false, t_closed).done;
        }
        assert!(t_open < t_closed, "open-row must win on locality: {t_open} vs {t_closed}");
    }

    #[test]
    fn open_row_write_uses_write_timing() {
        let mut cfg = Mem3DConfig::default();
        cfg.open_row = true;
        let mut mw = Mem3D::new(&cfg, 2.0).unwrap();
        let mut mr = Mem3D::new(&cfg, 2.0).unwrap();
        // Open the row, then time a row-hit write vs a row-hit read on
        // identical devices: CWD (7 DRAM cycles) < CAS (9), so the write
        // must complete strictly earlier. The old code charged CAS to both.
        mw.vima_access(0, false, 0);
        mr.vima_access(0, false, 0);
        let w = mw.vima_access(0, true, 1000).done;
        let r = mr.vima_access(0, false, 1000).done;
        assert!(w < r, "row-hit write (t_cwd) must beat row-hit read (t_cas): {w} vs {r}");
    }

    #[test]
    fn row_shift_derives_from_row_buffer_size() {
        // Default 256 B rows = 4 lines/row: row bits start after
        // 6 (line) + 5 (vault) + 3 (bank) + 2 (lines-per-row) address bits.
        let m = mem();
        assert_eq!(m.map(1 << (6 + 5 + 3 + 2)).2, 1);
        assert_eq!(m.map((1 << (6 + 5 + 3 + 2)) - 64).2, 0);
        // 512 B rows = 8 lines/row: one more line bit before the row bits
        // (the old code hardcoded the 256 B case for every configuration).
        let mut cfg = Mem3DConfig::default();
        cfg.row_buffer_bytes = 512;
        let m = Mem3D::new(&cfg, 2.0).unwrap();
        assert_eq!(m.row_shift, 5 + 3 + 3);
        assert_eq!(m.map(1 << (6 + 5 + 3 + 3)).2, 1);
        assert_eq!(m.map((1 << (6 + 5 + 3 + 3)) - 64).2, 0);
    }

    #[test]
    fn drained_at_includes_vault_command_slots() {
        // A command-slot-bound state: the last vault command retires after
        // every bank/bus/link is idle. `drained_at` used to ignore the
        // command clocks entirely and report the earlier (wrong) point.
        let mut m = mem();
        m.vima_access(0, false, 0);
        let settled = m.drained_at();
        m.vault_cmd_free[7] = settled + 500;
        assert_eq!(m.drained_at(), settled + 500, "drain point must cover vault cmd slots");

        // Behavioral: after any traffic burst, no per-vault command clock
        // may sit past the reported drain point.
        let mut m = mem();
        for i in 0..256u64 {
            m.host_access(i * 64, i % 3 == 0, i);
        }
        let drained = m.drained_at();
        let last_cmd = m.vault_cmd_free.iter().copied().max().unwrap();
        assert!(drained >= last_cmd, "drain {drained} before last cmd slot {last_cmd}");
    }

    #[test]
    fn new_rejects_non_power_of_two_geometry() {
        // Non-power-of-two vault/bank counts silently corrupt the mask/
        // shift address mapping; they must be typed errors naming the field.
        let mut cfg = Mem3DConfig::default();
        cfg.vaults = 24;
        let e = Mem3D::new(&cfg, 2.0).unwrap_err().to_string();
        assert!(e.contains("mem3d.vaults") && e.contains("24"), "{e}");

        let mut cfg = Mem3DConfig::default();
        cfg.banks_per_vault = 6;
        let e = Mem3D::new(&cfg, 2.0).unwrap_err().to_string();
        assert!(e.contains("mem3d.banks_per_vault") && e.contains("6"), "{e}");

        let mut cfg = Mem3DConfig::default();
        cfg.row_buffer_bytes = 192;
        let e = Mem3D::new(&cfg, 2.0).unwrap_err().to_string();
        assert!(e.contains("mem3d.row_buffer_bytes") && e.contains("192"), "{e}");
    }

    #[test]
    fn functional_accesses_count_traffic_without_advancing_clocks() {
        let mut m = mem();
        for i in 0..100u64 {
            m.host_access_functional(i * 64, i % 2 == 0);
            m.vima_access_functional(i * 64, i % 3 == 0);
        }
        assert_eq!(m.stats.host_reads + m.stats.host_writes, 100);
        assert_eq!(m.stats.vima_reads + m.stats.vima_writes, 100);
        assert_eq!(m.stats.host_bits, 100 * 64 * 8);
        assert_eq!(m.stats.vima_bits, 100 * 64 * 8);
        assert_eq!(m.stats.host_queue_cycles, 0, "no timing in functional mode");
        assert_eq!(m.drained_at(), 0, "functional traffic must not advance resource clocks");
    }

    #[test]
    fn queueing_stats_accumulate() {
        let mut m = mem();
        for _ in 0..10 {
            m.vima_access(0, false, 0); // same bank, forced queueing
        }
        assert!(m.stats.vima_queue_cycles > 0);
    }
}
