//! vima-sim CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands regenerate each of the paper's figures/tables, run the whole
//! suite as one deduplicated parallel sweep, run single workloads, dump the
//! Table-I configuration, and run the functional (PJRT-backed) smoke check.
//!
//! ```text
//! vima-sim sweep [--jobs N] [--figs fig2,custom|all] [--csv DIR] [--quick]
//! vima-sim fig2|fig3|fig4|fig5|ablation|headline|custom|all [--quick]
//! vima-sim run <workload|file.vpr> <backend> [--mb N] [--threads N] [--sampled] [--stats]
//! vima-sim check <file.vpr|workload> ... [--predict] [--json [FILE]]
//! vima-sim serve [--jobs N] [--cache N] [--load PATH]  (JSONL: stdin -> stdout)
//! vima-sim net serve [--tcp ADDR|--unix PATH] [--jobs N] [--window N]
//! vima-sim net worker [--jobs N]              (stdio protocol; spawned by coordinate)
//! vima-sim net coordinate [--workers N] [--figs fig2|all] [--quick] [--check]
//! vima-sim bench [--quick] [--iters N] [--sampled] [--net] [--json FILE]
//! vima-sim workloads          (list the registry: kernels + programs)
//! vima-sim config [--config FILE]
//! vima-sim selftest           (requires a build with --features pjrt)
//! ```
//!
//! `--load PATH` (any command) registers a `.vpr` program file — or every
//! `.vpr` in a directory — before dispatch, so loaded programs are
//! first-class workloads for `run`, `serve`, `sweep --figs custom`, and
//! `workloads` alike. See DESIGN.md §12 for the format.

use std::io::Write;

use vima_sim::bail;
use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::{SizeScale, WorkloadSet};
use vima_sim::coordinator::{Experiment, FigTable};
use vima_sim::net::{self, NetServer, ShardOptions};
#[cfg(feature = "pjrt")]
use vima_sim::runtime::{default_artifacts_dir, Engine};
use vima_sim::service::{self, ServiceConfig, SimService};
use vima_sim::sim::simulate_threads;
use vima_sim::sweep::{RunCell, SweepPlan};
use vima_sim::trace::{Backend, TraceParams};
use vima_sim::util::cli::Args;
use vima_sim::util::error::Result;
use vima_sim::workload;

/// Every figure name `sweep --figs` / `figure_tables` accepts.
const FIG_NAMES: [&str; 8] =
    ["fig2", "fig3", "fig4", "fig5", "ablation", "headline", "custom", "scaling"];

/// The default `sweep` set (everything except the custom-program figure,
/// which `--figs custom` / `--figs all` opts into).
const DEFAULT_FIGS: [&str; 6] = ["fig2", "fig3", "fig4", "fig5", "ablation", "headline"];

const USAGE: &str = "\
vima-sim — VIMA (Vector-In-Memory Architecture) paper-reproduction simulator

USAGE:
  vima-sim <COMMAND> [OPTIONS]

COMMANDS:
  sweep       Reproduce the whole suite (fig2-fig5 + ablations + headline)
              as one deduplicated, multi-threaded run grid — shared AVX
              baselines simulate once; restrict with --figs
  fig2        Reproduce Fig. 2 (HIVE vs VIMA vs AVX, MemSet/VecSum/Stencil)
  fig3        Reproduce Fig. 3 (single-thread speedup, 7 kernels x 3 sizes)
  fig4        Reproduce Fig. 4 (multithreaded AVX vs VIMA, speedup + energy)
  fig5        Reproduce Fig. 5 (VIMA cache-size sweep)
  ablation    Sec. III-C ablations (vector size, stop-and-go)
  headline    Max speedup / energy saving (paper: 26x, 93%)
  all         Everything above in sequence (one shared result cache)
  run         Run one workload: vima-sim run <workload> <backend> [--mb N]
              workload: any registered name (see `vima-sim workloads`) —
              the 7 paper kernels plus Intrinsics-VIMA programs like
              saxpy / softmax — or a path to a `.vpr` program file
              (e.g. vima-sim run examples/programs/saxpy.vpr vima);
              backends: avx vima hive
  check       Static analysis (DESIGN.md §13, §15): run the vima-check
              dataflow analyzer + lint pass and the vima-verify symbolic
              cross-backend equivalence prover over `.vpr` files and/or
              registered program workloads against the session machine
              configuration (same machine flags as run: --cubes,
              --threads, --config); diagnostics are
              `file:line:col: severity[lint-id]: message` lines sorted by
              (file, line, col, lint-id) across all targets, --json emits
              the machine-readable report in the same order, --predict
              adds the static cost model's per-file traffic and cycle
              predictions (DESIGN.md §15), and the exit status is nonzero
              exactly when any error-severity lint fires (warnings alone
              exit 0)
  serve       Long-running service mode: read JSONL job requests from
              stdin, write JSONL results to stdout (one line each, in
              request order; the in-flight window simulates in parallel
              with dedup). Request:
                {"id": 1, "workload": "vecsum", "backend": "vima",
                 "mb": 4, "threads": 2}
              with --load DIR, clients can submit loaded .vpr programs
              by name; see EXPERIMENTS.md §Serving for the full protocol
  net         Network serving & scale-out (DESIGN.md §14):
                net serve [--tcp ADDR|--unix PATH]
                  serve the same JSONL protocol over a socket to many
                  concurrent clients; Ctrl-C (or a client's
                  {\"op\": \"shutdown\"}) drains gracefully — stops
                  accepting, finishes in-flight work, flushes, exits
                net worker
                  one stdio protocol worker (what `coordinate` spawns)
                net coordinate [--workers N] [--figs fig2|all] [--check]
                  shard a sweep plan across N worker processes with
                  exactly-once execution per cell fleet-wide; results
                  are bit-identical to the single-process sweep
                  (--check verifies that against an in-process run)
  custom      Custom-workload figure: each registered Intrinsics-VIMA
              program, VIMA vs the AVX lowering of the same program
  scaling     Cube-scaling figure: streaming kernels on 1/2/4/8-cube
              sharded memory fabrics (8 threads, speedup vs 1 cube)
  bench       Simulator throughput benchmark: chunked execution engine vs
              the event-at-a-time reference path, in simulated events/sec;
              --json FILE writes the BENCH_*.json perf-trajectory record
              (e.g. BENCH_PR3.json); --sampled adds the sampled-execution
              accuracy/speed frontier (full vs sampled wall time + error);
              --net adds the serving saturation section: jobs/sec vs
              concurrent connections (loopback TCP) and sharded-sweep
              cells/sec vs worker-process count; --predict adds the
              static-cost-model cross-check: predicted vs simulated
              cycles per registered program, with relative error
              (DESIGN.md §15)
  workloads   List every workload in the registry (name, backends, size)
  transpile   Future-work demo: auto-convert an AVX trace to VIMA
              (vima-sim transpile <workload> [--mb N])
  config      Print the effective configuration (Table I + overrides)
  selftest    Execute every f32 PJRT artifact once (needs `make artifacts`
              and a binary built with `--features pjrt`)

OPTIONS:
  --jobs N         sweep/serve worker threads (default: all cores; 1 = serial);
                   (net coordinate) per-worker-process pool width
  --cache N        (serve, net serve) result-cache bound in cells (default 1024)
  --tcp ADDR       (net serve) listen address, e.g. 127.0.0.1:7117; port 0
                   picks an ephemeral port (printed on stderr)
  --unix PATH      (net serve) listen on a Unix-domain socket instead
  --window N       (net serve/worker) per-connection in-flight window
                   (backpressure bound, default 256);
                   (net coordinate) outstanding cells per worker (default 4)
  --workers N      (net coordinate) worker processes to spawn (default 2)
  --check          (net coordinate) also run the plan in-process and verify
                   the sharded results are bit-identical
  --net            (bench) measure the serving saturation section
  --exit-after N   (net worker) fault injection for tests: crash the worker
                   process after answering N responses
  --iters N        (bench) timed iterations per cell, median reported (3)
  --predict        (check) append the static cost model's prediction per
                   file: instruction/event counts, vcache hits/misses,
                   DRAM traffic, and predicted cycles for the VIMA
                   lowering (text and --json);
                   (bench) add the predicted-vs-simulated cross-check
                   section: relative cycle error per golden program
  --json FILE      (bench) write the JSON record to FILE;
                   (check) write the JSON report to FILE, or to stdout
                   when the flag is bare
  --quick          1/16 dataset sizes (smoke runs)
  --config FILE    TOML overrides for Table I
  --load PATH      register a .vpr program file (or every .vpr in a
                   directory) before running the command (DESIGN.md §12)
  --cubes N        memory cubes in the sharded fabric (default 1; power of
                   two; equivalent to [mem] num_cubes in --config)
  --out DIR        also write each table as CSV into DIR
  --csv DIR        (sweep) same as --out
  --figs LIST      (sweep) comma-separated subset, e.g. fig2,fig5,custom;
                   'all' = every figure including custom
  --threads N      (run) data-parallel cores; (check) accepted for flag
                   parity with run — the analyzer is keyed on the machine
                   config (--cubes/--config), not the core count
  --mb N           (run) footprint in MiB
  --sampled        (run) sampled execution: functional fast-forward between
                   detailed windows, extrapolated result (DESIGN.md §11);
                   (bench) measure the accuracy/speed frontier
  --stats          (run) dump the full counter report
  --verbose        progress lines on stderr
";

fn emit(table: &FigTable, out: Option<&str>) -> Result<()> {
    println!("{}", table.to_markdown());
    if let Some(dir) = out {
        std::fs::create_dir_all(dir)?;
        let slug: String = table
            .title
            .chars()
            .take_while(|c| *c != ':')
            .filter(|c| c.is_alphanumeric())
            .collect::<String>()
            .to_lowercase();
        let path = format!("{dir}/{slug}.csv");
        std::fs::write(&path, table.to_csv())?;
        eprintln!("[vima-sim] wrote {path}");
    }
    Ok(())
}

/// Produce the named figure's tables through the shared-cache experiment.
fn figure_tables(exp: &Experiment, name: &str) -> Result<Vec<FigTable>> {
    Ok(match name {
        "fig2" => vec![exp.fig2()?],
        "fig3" => vec![exp.fig3()?],
        "fig4" => vec![exp.fig4()?],
        "fig5" => vec![exp.fig5()?],
        "ablation" => vec![
            exp.ablation_vector_size()?,
            exp.ablation_stop_and_go()?,
            exp.ablation_prefetcher()?,
        ],
        "headline" => vec![exp.headline()?],
        "custom" => vec![exp.custom_programs()?],
        "scaling" => vec![exp.scaling_cubes()?],
        other => {
            bail!(
                "unknown figure {other:?}; valid figures: {} (or 'all' for every one)",
                FIG_NAMES.join(", ")
            )
        }
    })
}

fn main() -> Result<()> {
    let args = Args::parse();
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };

    let mut cfg = match args.get("config") {
        Some(path) => SystemConfig::from_toml_file(path)?,
        None => SystemConfig::default(),
    };
    // `--cubes N`: size the sharded memory fabric (DESIGN.md §10) without
    // a config file; 1 (the default) is the paper's single-cube system.
    if let Some(cubes) = args.get("cubes") {
        cfg.mem.num_cubes = cubes.parse::<usize>()?;
    }
    cfg.validate()?;
    // `--load PATH`: register `.vpr` programs before dispatch so every
    // command (run, serve, sweep --figs custom, workloads) sees them.
    if let Some(path) = args.get("load") {
        let ids = vima_sim::program::load_path(path)?;
        let names: Vec<String> = ids.iter().map(|&id| workload::name(id)).collect();
        eprintln!("[vima-sim] loaded {} program(s) from {path}: {}", ids.len(), names.join(", "));
    }
    let scale = if args.flag("quick") { SizeScale::Quick } else { SizeScale::Paper };
    let jobs = args.get_usize("jobs", 0);
    // Built only by the figure-running commands: constructing an
    // Experiment spawns its service worker pool, which `run`, `serve`,
    // `bench`, etc. never use.
    let make_exp = || {
        let mut exp = Experiment::with_jobs(cfg.clone(), scale, jobs);
        exp.verbose = args.flag("verbose");
        exp
    };
    let out = args.get("out");

    match cmd {
        "sweep" => {
            let exp = make_exp();
            let figs = args
                .get_list("figs")
                .unwrap_or_else(|| DEFAULT_FIGS.map(String::from).to_vec());
            // `--figs all`: the whole suite, custom figure included.
            let figs: Vec<String> = if figs.iter().any(|f| f == "all") {
                FIG_NAMES.map(String::from).to_vec()
            } else {
                figs
            };
            let out = args.get("csv").or(out);
            let before = vima_sim::sim::run_invocations();
            for fig in &figs {
                for table in figure_tables(&exp, fig)? {
                    emit(&table, out)?;
                }
            }
            let stats = exp.sweep_stats();
            eprintln!(
                "[vima-sim] sweep: {} cells -> {} unique simulations \
                 ({} machine runs), {} cache hits, {} worker(s)",
                stats.cells,
                stats.unique_runs,
                vima_sim::sim::run_invocations() - before,
                stats.cache_hits,
                exp.jobs(),
            );
        }
        "fig2" | "fig3" | "fig4" | "fig5" | "headline" | "ablation" | "custom" | "scaling" => {
            let exp = make_exp();
            for table in figure_tables(&exp, cmd)? {
                emit(&table, out)?;
            }
        }
        "all" => {
            let exp = make_exp();
            for fig in DEFAULT_FIGS {
                for table in figure_tables(&exp, fig)? {
                    emit(&table, out)?;
                }
            }
        }
        "config" => print!("{}", cfg.to_toml()),
        "transpile" => {
            let name = args.positional.get(1).map(String::as_str).unwrap_or("vecsum");
            let id = workload::resolve(name)?;
            // Programs carry their own (non-MiB-aligned) footprint; --mb
            // overrides where the workload allows it.
            let footprint = match args.get("mb") {
                Some(mb) => mb.parse::<u64>()? << 20,
                None => workload::get(id)?.default_footprint(),
            };
            let p = TraceParams::new(id, Backend::Avx, footprint);
            let mut m = vima_sim::sim::Machine::new(&cfg, 1)?;
            let native = m.run(vec![p.stream()?])?;
            let mut m = vima_sim::sim::Machine::new(&cfg, 1)?;
            let auto = m.run(vec![vima_sim::transpile::transpile(p.stream()?)])?;
            let hand = simulate_threads(
                &cfg,
                TraceParams::new(id, Backend::Vima, footprint),
                1,
            )?;
            println!("{} {:.1} MiB:", workload::name(id), footprint as f64 / (1 << 20) as f64);
            println!("  native AVX trace      : {:>12} cycles", native.cycles);
            println!(
                "  auto-transpiled VIMA  : {:>12} cycles ({:.2}x)",
                auto.cycles,
                native.cycles as f64 / auto.cycles as f64
            );
            println!(
                "  hand-written VIMA     : {:>12} cycles ({:.2}x)",
                hand.cycles,
                native.cycles as f64 / hand.cycles as f64
            );
            println!(
                "  VIMA instrs emitted by the pass: {}",
                auto.report.get("vima.instructions").unwrap_or(0.0)
            );
        }
        "run" => {
            let target = args.positional.get(1).map(String::as_str).unwrap_or_default();
            // A `.vpr` path runs directly: load (register) then resolve.
            let id = if target.ends_with(".vpr") {
                vima_sim::program::load_file(target)?
            } else {
                match workload::resolve(target) {
                    Ok(id) => id,
                    Err(e) => bail!(
                        "{e} (a .vpr program file also runs directly: \
                         vima-sim run examples/programs/saxpy.vpr vima)"
                    ),
                }
            };
            let backend: Backend =
                args.positional.get(2).map(String::as_str).unwrap_or_default().parse()?;
            // Programs carry their own footprint; --mb overrides where the
            // workload allows it.
            let footprint = match args.get("mb") {
                Some(mb) => mb.parse::<u64>()? << 20,
                None => workload::get(id)?.default_footprint(),
            };
            let threads = args.get_usize("threads", 1);
            let p = TraceParams::new(id, backend, footprint);
            let mut cfg = cfg.clone();
            // `--sampled`: route through the sampled engine at the
            // workload's default window/period ([sample] in --config
            // overrides them).
            cfg.sample.enabled |= args.flag("sampled");
            let r = simulate_threads(&cfg, p, threads)?;
            println!(
                "cycles={} seconds={:.6} energy_j={:.6}",
                r.cycles, r.seconds, r.energy.total_j
            );
            if args.flag("stats") {
                print!("{}", r.report);
            }
        }
        "check" => {
            let mut targets: Vec<String> = args.positional[1..].to_vec();
            // A bare `--json` before a target swallows the target as its
            // value (the parser can't tell); hand a `.vpr` value back.
            let mut json_file: Option<&str> = None;
            if let Some(v) = args.get("json") {
                if v.ends_with(".vpr") {
                    targets.push(v.to_string());
                } else {
                    json_file = Some(v);
                }
            }
            if targets.is_empty() {
                bail!(
                    "usage: vima-sim check <file.vpr|workload> ... [--predict] \
                     [--json [FILE]]; targets are .vpr paths or registered \
                     program workloads (see `vima-sim workloads`)"
                );
            }
            let predict = args.flag("predict");
            // `check` shares `run`'s machine flags: --cubes and --config
            // already shaped `cfg` above; --threads is accepted so
            // scripted run/check pairs can pass one flag set (the
            // analyzer and cost model are keyed on the machine config,
            // not the host core count).
            let threads = args.get_usize("threads", 1);
            let _ = threads;
            // (label, lint report, cost prediction) per analyzable target.
            type Checked =
                (String, vima_sim::analyze::Report, Option<vima_sim::analyze::cost::CostReport>);
            let mut reports: Vec<Checked> = Vec::new();
            let mut skipped: Vec<&str> = Vec::new();
            for target in &targets {
                if target.ends_with(".vpr") {
                    let src = match std::fs::read_to_string(target) {
                        Ok(s) => s,
                        Err(e) => bail!("{target}: {e}"),
                    };
                    let parsed = match vima_sim::program::parse(&src) {
                        Ok(p) => p,
                        Err(e) => bail!("{target}: {e}"),
                    };
                    let cost = predict
                        .then(|| vima_sim::analyze::cost::predict(&parsed.program, &cfg));
                    reports.push((
                        target.clone(),
                        vima_sim::analyze::analyze_parsed(&parsed, &cfg),
                        cost,
                    ));
                } else {
                    let id = workload::resolve(target)?;
                    let w = workload::get(id)?;
                    match w.analyze(&cfg) {
                        Some(report) => {
                            let cost = if predict { w.predict(&cfg) } else { None };
                            reports.push((target.clone(), report, cost));
                        }
                        None => skipped.push(target),
                    }
                }
            }
            // Deterministic multi-file output: targets sort by label, and
            // each report's diagnostics are already (line, col, lint-id)
            // sorted, so the stream is globally ordered by
            // (file, span, lint id) no matter the argument order.
            reports.sort_by(|a, b| a.0.cmp(&b.0));
            skipped.sort_unstable();
            let errors: usize = reports.iter().map(|(_, r, _)| r.error_count()).sum();
            let warnings: usize = reports.iter().map(|(_, r, _)| r.warning_count()).sum();
            let infos: usize = reports.iter().map(|(_, r, _)| r.info_count()).sum();
            if args.flag("json") {
                let files: Vec<String> = reports
                    .iter()
                    .map(|(f, r, cost)| {
                        let mut obj = r.to_json(f);
                        if let Some(c) = cost {
                            // Splice the prediction into the per-file
                            // object (house-style hand-rolled JSON).
                            obj.truncate(obj.len() - 1);
                            obj.push_str(&format!(", \"predict\": {}}}", c.to_json()));
                        }
                        obj
                    })
                    .collect();
                let doc = format!(
                    "{{\"files\": [{}], \"errors\": {errors}, \
                     \"warnings\": {warnings}, \"infos\": {infos}}}\n",
                    files.join(", ")
                );
                match json_file {
                    Some(path) => {
                        std::fs::write(path, &doc)?;
                        eprintln!("[vima-sim] wrote {path}");
                    }
                    None => print!("{doc}"),
                }
            } else {
                for (file, report, cost) in &reports {
                    if report.is_clean() {
                        println!("{file}: clean");
                    } else {
                        print!("{}", report.render(file));
                    }
                    if let Some(c) = cost {
                        print!("{}", c.render(file));
                    }
                }
            }
            for name in &skipped {
                eprintln!("[vima-sim] {name}: not analyzable (paper kernel)");
            }
            eprintln!(
                "[vima-sim] check: {} file(s) checked: {errors} error(s), \
                 {warnings} warning(s), {infos} info(s)",
                reports.len(),
            );
            if errors > 0 {
                bail!("check failed: {errors} error(s)");
            }
        }
        "serve" => {
            let cache = args.get_usize("cache", service::DEFAULT_CACHE_CAPACITY);
            let svc = SimService::new(ServiceConfig {
                base: cfg.clone(),
                jobs,
                cache_capacity: cache,
                ..ServiceConfig::default()
            });
            eprintln!(
                "[vima-sim] serve: reading JSONL jobs from stdin ({} worker(s), \
                 cache {} cells); EOF ends the session",
                svc.jobs(),
                cache,
            );
            let stdin = std::io::stdin();
            let summary = service::jsonl::serve(&svc, stdin.lock(), std::io::stdout())?;
            let stats = svc.stats();
            eprintln!(
                "[vima-sim] serve: {} request(s) -> {} ok, {} failed; \
                 {} unique simulation(s), {} cache hit(s), {} eviction(s)",
                summary.requests,
                summary.ok,
                summary.failed,
                stats.unique_runs,
                stats.cache_hits,
                stats.evictions,
            );
        }
        "net" => {
            let sub = args.positional.get(1).map(String::as_str).unwrap_or_default();
            let cache = args.get_usize("cache", service::DEFAULT_CACHE_CAPACITY);
            let make_svc = || {
                SimService::new(ServiceConfig {
                    base: cfg.clone(),
                    jobs,
                    cache_capacity: cache,
                    ..ServiceConfig::default()
                })
            };
            match sub {
                "serve" => {
                    let window = args.get_usize("window", service::jsonl::SERVE_WINDOW);
                    let svc = make_svc();
                    let server = match args.get("unix") {
                        Some(path) => bind_unix_server(path)?,
                        None => NetServer::bind_tcp(args.get("tcp").unwrap_or("127.0.0.1:7117"))?,
                    };
                    let server = server.with_window(window);
                    #[cfg(unix)]
                    let server = {
                        sigint::install();
                        server.with_external_shutdown(&sigint::FLAG)
                    };
                    eprintln!(
                        "[vima-sim] net serve: listening on {} ({} worker(s), cache {} \
                         cells, window {}); Ctrl-C or {{\"op\": \"shutdown\"}} drains",
                        server.local_addr(),
                        svc.jobs(),
                        cache,
                        window,
                    );
                    let summary = server.serve(&svc)?;
                    let stats = svc.stats();
                    eprintln!(
                        "[vima-sim] net serve: {} connection(s), {} request(s) -> {} ok, \
                         {} failed, {} timeout(s); {} unique simulation(s), {} cache hit(s)",
                        summary.connections,
                        summary.requests,
                        summary.ok,
                        summary.failed,
                        summary.timeouts,
                        stats.unique_runs,
                        stats.cache_hits,
                    );
                }
                "worker" => {
                    let window = args.get_usize("window", service::jsonl::SERVE_WINDOW);
                    let svc = make_svc();
                    let opts = net::SessionOptions { window };
                    let ctl = net::SessionCtl::new();
                    let stdin = std::io::stdin();
                    let summary = match args.get("exit-after") {
                        Some(n) => {
                            let out =
                                ExitAfter { inner: std::io::stdout(), remaining: n.parse()? };
                            net::run_session(&svc, stdin.lock(), out, &opts, &ctl)?
                        }
                        None => {
                            net::run_session(&svc, stdin.lock(), std::io::stdout(), &opts, &ctl)?
                        }
                    };
                    let stats = svc.stats();
                    eprintln!(
                        "[vima-sim] net worker: {} request(s) -> {} ok, {} failed, \
                         {} timeout(s); {} unique simulation(s)",
                        summary.requests,
                        summary.ok,
                        summary.failed,
                        summary.timeouts,
                        stats.unique_runs,
                    );
                }
                "coordinate" => {
                    let figs = args.get("figs").unwrap_or("fig2");
                    let sized = match figs {
                        "fig2" => WorkloadSet::fig2(scale),
                        "all" => WorkloadSet::all(scale),
                        other => bail!(
                            "unknown --figs {other:?} for net coordinate; valid: fig2, all"
                        ),
                    };
                    let backends: &[Backend] = if figs == "fig2" {
                        &[Backend::Avx, Backend::Hive, Backend::Vima]
                    } else {
                        &[Backend::Avx, Backend::Vima]
                    };
                    let mut plan = SweepPlan::new();
                    for &w in &sized {
                        for &b in backends {
                            plan.push(RunCell::new(w, b));
                        }
                    }
                    let opts = ShardOptions {
                        workers: args.get_usize("workers", 2),
                        window: args.get_usize("window", 4),
                        worker_jobs: jobs,
                        verbose: args.flag("verbose"),
                        ..ShardOptions::default()
                    };
                    let t0 = std::time::Instant::now();
                    let (results, stats) = net::run_sharded(&cfg, &plan, &opts)?;
                    let wall = t0.elapsed().as_secs_f64();
                    println!(
                        "{:<16} {:>7} {:>14} {:>12} {:>12}",
                        "cell", "backend", "cycles", "seconds", "energy_j"
                    );
                    for (cell, r) in plan.cells().iter().zip(&results) {
                        println!(
                            "{:<16} {:>7} {:>14} {:>12.6} {:>12.6}",
                            cell.label(),
                            cell.params().backend.to_string(),
                            r.cycles,
                            r.seconds,
                            r.energy.total_j,
                        );
                    }
                    if args.flag("check") {
                        let svc = make_svc();
                        let local = svc.run_plan(&cfg, &plan, args.flag("verbose"))?;
                        for ((cell, sharded), serial) in
                            plan.cells().iter().zip(&results).zip(&local)
                        {
                            if sharded.cycles != serial.cycles
                                || sharded.seconds.to_bits() != serial.seconds.to_bits()
                                || sharded.energy != serial.energy
                                || sharded.report != serial.report
                            {
                                bail!(
                                    "sharded result for cell {} differs from the \
                                     single-process sweep",
                                    cell.label()
                                );
                            }
                        }
                        eprintln!(
                            "[vima-sim] net coordinate: --check passed: {} cell(s) \
                             bit-identical to the single-process sweep",
                            results.len(),
                        );
                    }
                    eprintln!(
                        "[vima-sim] net coordinate: {} cells -> {} unique across {} \
                         worker(s) in {wall:.2}s ({:.1} cells/s); {} request(s) sent, \
                         {} requeued, {} worker death(s), fleet unique_runs {}",
                        stats.cells,
                        stats.unique_cells,
                        stats.workers_spawned,
                        stats.cells as f64 / wall.max(1e-9),
                        stats.requests_sent,
                        stats.requeued,
                        stats.worker_deaths,
                        stats.fleet_unique_runs,
                    );
                }
                other => bail!(
                    "unknown net subcommand {other:?}; valid: serve, worker, coordinate"
                ),
            }
        }
        "bench" => {
            let iters = args.get_usize("iters", 3) as u32;
            let mut report =
                vima_sim::bench::throughput(&cfg, args.flag("quick"), iters, true)?;
            println!(
                "{:<10} {:>6} {:>12} {:>16} {:>16} {:>9}",
                "workload", "backend", "events", "reference ev/s", "chunked ev/s", "speedup"
            );
            for r in &report.rows {
                println!(
                    "{:<10} {:>6} {:>12} {:>16.0} {:>16.0} {:>8.2}x",
                    r.workload, r.backend, r.events, r.reference_eps, r.chunked_eps, r.speedup
                );
            }
            println!(
                "geomean speedup {:.2}x, min {:.2}x, peak {:.2}M ev/s",
                report.geomean_speedup(),
                report.min_speedup(),
                report.peak_chunked_eps() / 1e6
            );
            if args.flag("sampled") {
                report.sampled =
                    vima_sim::bench::sampled_frontier(&cfg, args.flag("quick"), iters, true)?;
                println!(
                    "\n{:<10} {:>6} {:>12} {:>12} {:>9} {:>10} {:>11}",
                    "workload",
                    "backend",
                    "events",
                    "detailed",
                    "speedup",
                    "cyc err %",
                    "energy err %"
                );
                for r in &report.sampled {
                    println!(
                        "{:<10} {:>6} {:>12} {:>12} {:>8.2}x {:>10.3} {:>11.3}",
                        r.workload,
                        r.backend,
                        r.events,
                        r.detailed_events,
                        r.speedup,
                        r.cycle_error_pct,
                        r.energy_error_pct
                    );
                }
                println!(
                    "sampled geomean {:.2}x, max cycle err {:.3}%, max energy err {:.3}%",
                    report.geomean_sampled_speedup(),
                    report.max_cycle_error_pct(),
                    report.max_energy_error_pct()
                );
            }
            if args.flag("net") {
                let netr = vima_sim::bench::net_saturation(&cfg, args.flag("quick"), true)?;
                println!(
                    "\n{:<12} {:>10} {:>9} {:>12}",
                    "connections", "requests", "wall_s", "jobs/sec"
                );
                for r in &netr.conn_rows {
                    println!(
                        "{:<12} {:>10} {:>9.3} {:>12.0}",
                        r.connections, r.requests, r.wall_s, r.jobs_per_sec
                    );
                }
                println!(
                    "\n{:<8} {:>7} {:>8} {:>9} {:>12}",
                    "workers", "cells", "unique", "wall_s", "cells/sec"
                );
                for r in &netr.worker_rows {
                    println!(
                        "{:<8} {:>7} {:>8} {:>9.3} {:>12.2}",
                        r.workers, r.cells, r.unique, r.wall_s, r.cells_per_sec
                    );
                }
                println!(
                    "net peak {:.0} jobs/sec at {} connection(s)",
                    netr.peak_jobs_per_sec(),
                    netr.peak_connections()
                );
                report.net = Some(netr);
            }
            if args.flag("predict") {
                report.predict = vima_sim::bench::predict_frontier(&cfg, true)?;
                println!(
                    "\n{:<12} {:>7} {:>14} {:>14} {:>8}",
                    "workload", "backend", "predicted", "simulated", "err %"
                );
                for r in &report.predict {
                    println!(
                        "{:<12} {:>7} {:>14} {:>14} {:>7.2}%",
                        r.workload, "vima", r.predicted_cycles, r.simulated_cycles, r.error_pct
                    );
                }
                println!(
                    "predict max |err| {:.2}% over {} program(s)",
                    report.max_predict_error_pct(),
                    report.predict.len()
                );
            }
            if let Some(path) = args.get("json") {
                std::fs::write(path, report.to_json())?;
                eprintln!("[vima-sim] wrote {path}");
            }
        }
        "workloads" => {
            println!(
                "{:<16} {:<12} {:>15} {:>10} {:>8}  {}",
                "name", "kind", "backends", "default", "lint", "description"
            );
            for id in workload::all_ids() {
                let w = workload::get(id)?;
                let backends: Vec<String> =
                    w.backends().iter().map(|b| b.to_string()).collect();
                // `-` = not analyzable (paper kernels have no statement
                // tree); programs get their vima-check summary.
                let lint = match w.analyze(&cfg) {
                    Some(report) => report.counts_label(),
                    None => "-".to_string(),
                };
                println!(
                    "{:<16} {:<12} {:>15} {:>8.1}MB {:>8}  {}",
                    w.name(),
                    w.kind(),
                    backends.join(","),
                    w.default_footprint() as f64 / (1 << 20) as f64,
                    lint,
                    w.description(),
                );
            }
        }
        #[cfg(feature = "pjrt")]
        "selftest" => {
            let mut engine = Engine::new(default_artifacts_dir())?;
            let mut names: Vec<String> = engine.names().map(String::from).collect();
            names.sort();
            let mut ran = 0;
            for name in &names {
                let meta = engine.meta(name).unwrap().clone();
                let all_f32 =
                    meta.inputs.iter().chain(meta.outputs.iter()).all(|s| s.dtype == "float32");
                if !all_f32 {
                    continue; // f32 smoke only; int paths covered by pytest
                }
                let inputs: Vec<Vec<f32>> =
                    meta.inputs.iter().map(|s| vec![1.0f32; s.elements()]).collect();
                let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
                let output = engine.execute_f32(name, &refs)?;
                vima_sim::ensure!(
                    !meta.outputs.is_empty() && output.len() == meta.outputs[0].elements(),
                    "{name}: wrong output size"
                );
                ran += 1;
                println!("ok {name} ({} inputs -> {} elems)", refs.len(), output.len());
            }
            println!("selftest: {ran}/{} f32 artifacts executed", names.len());
        }
        #[cfg(not(feature = "pjrt"))]
        "selftest" => {
            bail!("this binary was built without the `pjrt` feature; rebuild with \
                   `cargo build --features pjrt` (requires the xla crate)")
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => bail!(
            "unknown command {other:?}; valid commands: sweep, fig2, fig3, fig4, fig5, \
             ablation, headline, custom, scaling, all, run, check, serve, net, bench, \
             workloads, transpile, config, selftest, help"
        ),
    }
    Ok(())
}

/// Bind the `net serve --unix PATH` listener where the platform has
/// Unix-domain sockets, and fail with a typed error where it does not.
#[cfg(unix)]
fn bind_unix_server(path: &str) -> Result<NetServer> {
    NetServer::bind_unix(std::path::Path::new(path))
}

#[cfg(not(unix))]
fn bind_unix_server(_path: &str) -> Result<NetServer> {
    bail!("--unix sockets are unavailable on this platform; use --tcp ADDR")
}

/// `net worker --exit-after N` fault injection: a stdout wrapper that
/// kills the whole process right after the N-th response line reaches the
/// pipe — an abrupt worker death (no drain, no flush of later answers)
/// for the coordinator's re-queue path and its tests.
struct ExitAfter<W: Write> {
    inner: W,
    remaining: u64,
}

impl<W: Write> Write for ExitAfter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        for &b in &buf[..n] {
            if b == b'\n' {
                self.remaining = self.remaining.saturating_sub(1);
                if self.remaining == 0 {
                    let _ = self.inner.flush();
                    std::process::exit(86);
                }
            }
        }
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// SIGINT-to-drain bridge for `net serve`. A `signal(2)` handler may only
/// do async-signal-safe work, so the handler body is a single atomic
/// store; the accept loop polls [`FLAG`](sigint::FLAG) (it never blocks in
/// `accept(2)` — Rust's std retries `EINTR`) and runs the graceful drain.
/// Lives in the binary crate because the library forbids `unsafe`.
#[cfg(unix)]
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static FLAG: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigint(_signum: i32) {
        FLAG.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        // SAFETY: registering an async-signal-safe handler (one relaxed-
        // enough atomic store, no allocation, no locks) for SIGINT (2).
        unsafe { signal(2, on_sigint) };
    }
}
