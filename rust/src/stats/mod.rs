//! Simulation statistics: typed counters on the hot path, a generic table
//! for reporting.
//!
//! Components own plain-`u64` counter structs (no hashing while simulating);
//! [`StatsReport`] collects everything at the end of a run for printing and
//! for the energy model.

use std::collections::BTreeMap;
use std::fmt;

/// A named bag of counters/gauges collected from all components after a run.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct StatsReport {
    entries: BTreeMap<String, f64>,
}

impl StatsReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: impl Into<String>, value: f64) {
        self.entries.insert(key.into(), value);
    }

    pub fn add(&mut self, key: impl Into<String>, value: f64) {
        *self.entries.entry(key.into()).or_insert(0.0) += value;
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.entries.get(key).copied()
    }

    /// All keys with a given prefix (e.g. `"l1d."`).
    pub fn with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = (&'a str, f64)> {
        self.entries
            .iter()
            .filter(move |(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), *v))
    }

    /// Scale every entry in place by `f` — the §Sampling extrapolation
    /// contract: event counters of a uniformly sub-sampled run extrapolate
    /// linearly, without rebuilding the report.
    pub fn scale_all(&mut self, f: f64) {
        for v in self.entries.values_mut() {
            *v *= f;
        }
    }

    /// Scale only duration-like entries (stall/queue cycle sums and busy
    /// timestamps) by `f` — the sampled-execution extrapolation (DESIGN.md
    /// §11). During functional fast-forward every *event* is counted but
    /// time stands still, so durations accrue only inside the detailed
    /// windows and must extrapolate by the sample factor, while the event
    /// counters are already whole-run exact.
    pub fn scale_durations(&mut self, f: f64) {
        for (k, v) in self.entries.iter_mut() {
            if Self::is_duration(k) {
                *v *= f;
            }
        }
    }

    /// Duration-like keys: cycle sums (`*_cycles`, `*_cycles_sum`) and the
    /// device busy timestamps. Event counters (hits, misses, traffic) and
    /// hardware-count gauges are *not* durations.
    fn is_duration(key: &str) -> bool {
        key.ends_with("_cycles") || key.ends_with("_cycles_sum") || key.ends_with(".busy_until")
    }

    /// Non-summable gauges: timestamps ("when did this component go
    /// idle") and fixed hardware counts. Unlike event counters they must
    /// combine by `max`: summing two reports' `sim.cycles` or
    /// `vima.busy_until` produces a point in time that never existed, and
    /// summing two reports' `fabric.cubes` / `vima.devices` invents
    /// hardware. `sim.scale` is a per-run factor, also not summable.
    fn is_timestamp_gauge(key: &str) -> bool {
        key == "sim.cycles"
            || key == "sim.scale"
            || key == "fabric.cubes"
            || key == "vima.devices"
            || key.ends_with(".busy_until")
            // Sampled-run summary statistics (window means, CI widths,
            // extrapolation factor) are per-run descriptors, not summable
            // event counts.
            || key.starts_with("sample.")
    }

    /// Merge another report into this one: event counters sum, timestamp
    /// gauges (`is_timestamp_gauge`) take the max.
    pub fn merge(&mut self, other: &StatsReport) {
        for (k, v) in &other.entries {
            if Self::is_timestamp_gauge(k) {
                let e = self.entries.entry(k.clone()).or_insert(*v);
                *e = e.max(*v);
            } else {
                self.add(k.clone(), *v);
            }
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl fmt::Display for StatsReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (k, v) in &self.entries {
            if v.fract() == 0.0 && v.abs() < 1e15 {
                writeln!(f, "{k:<48} {:>16}", *v as i64)?;
            } else {
                writeln!(f, "{k:<48} {v:>16.4}")?;
            }
        }
        Ok(())
    }
}

/// Streaming mean/variance accumulator (Welford) over the per-window cycle
/// costs of a sampled run (DESIGN.md §11). Drives the confidence interval
/// the engine reports next to every extrapolated result: with `k` detailed
/// windows of measured cost `x_i`, the run-total estimate is
/// `mean(x) * k * factor` and its 95% CI half-width follows from the
/// sample standard deviation, `1.96 * s / sqrt(k)` per window.
#[derive(Debug, Default, Clone)]
pub struct WindowStats {
    count: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl WindowStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        if self.count == 1 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Sample variance (n-1 denominator); 0 with fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / (self.count - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// 95% CI half-width of the per-window mean: `1.96 * s / sqrt(k)`.
    pub fn ci95_half(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            1.96 * self.stddev() / (self.count as f64).sqrt()
        }
    }

    /// CI half-width relative to the mean (0 when the mean is 0).
    pub fn rel_ci95(&self) -> f64 {
        ratio(self.ci95_half(), self.mean.abs())
    }
}

/// Ratio helper that tolerates zero denominators.
pub fn ratio(num: f64, den: f64) -> f64 {
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Simple fixed-bucket histogram for latency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    max: u64,
}

impl Histogram {
    /// Power-of-two buckets up to `max_exp` (e.g. 16 -> buckets 1,2,4..65536,+inf).
    pub fn pow2(max_exp: u32) -> Self {
        let bounds: Vec<u64> = (0..=max_exp).map(|e| 1u64 << e).collect();
        let n = bounds.len() + 1;
        Self { bounds, counts: vec![0; n], total: 0, sum: 0, max: 0 }
    }

    pub fn record(&mut self, value: u64) {
        let idx = self.bounds.partition_point(|&b| b < value);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += value as u128;
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        ratio(self.sum as f64, self.total as f64)
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate percentile from bucket upper bounds.
    ///
    /// Two edge cases are pinned by regression tests: `p = 0.0` must land on
    /// the first **non-empty** bucket (the old `target = 0` matched the
    /// first bucket even when it held nothing), and no percentile may exceed
    /// the recorded max (an all-one-bucket histogram used to report the
    /// bucket's upper bound, disagreeing with [`max`](Self::max)).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p / 100.0 * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= target {
                return self.bounds.get(i).copied().unwrap_or(self.max).min(self.max);
            }
        }
        self.max
    }

    pub fn dump_into(&self, report: &mut StatsReport, prefix: &str) {
        report.set(format!("{prefix}.count"), self.total as f64);
        report.set(format!("{prefix}.mean"), self.mean());
        report.set(format!("{prefix}.max"), self.max as f64);
        report.set(format!("{prefix}.p50"), self.percentile(50.0) as f64);
        report.set(format!("{prefix}.p99"), self.percentile(99.0) as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_merge_and_prefix() {
        let mut a = StatsReport::new();
        a.set("l1d.hits", 10.0);
        a.set("l1d.misses", 2.0);
        a.set("l2.hits", 1.0);
        let mut b = StatsReport::new();
        b.set("l1d.hits", 5.0);
        a.merge(&b);
        assert_eq!(a.get("l1d.hits"), Some(15.0));
        assert_eq!(a.with_prefix("l1d.").count(), 2);
    }

    #[test]
    fn merge_takes_max_of_timestamp_gauges() {
        let mut a = StatsReport::new();
        a.set("sim.cycles", 100.0);
        a.set("vima.busy_until", 90.0);
        a.set("core.uops", 10.0);
        a.set("fabric.cubes", 4.0);
        let mut b = StatsReport::new();
        b.set("sim.cycles", 80.0);
        b.set("vima.busy_until", 95.0);
        b.set("hive.busy_until", 40.0);
        b.set("core.uops", 5.0);
        b.set("fabric.cubes", 4.0);
        a.merge(&b);
        assert_eq!(a.get("sim.cycles"), Some(100.0), "gauges combine by max");
        assert_eq!(a.get("vima.busy_until"), Some(95.0));
        assert_eq!(a.get("hive.busy_until"), Some(40.0), "missing keys adopt the other side");
        assert_eq!(a.get("core.uops"), Some(15.0), "counters still sum");
        assert_eq!(a.get("fabric.cubes"), Some(4.0), "hardware counts don't sum");
    }

    #[test]
    fn scale_all_in_place() {
        let mut r = StatsReport::new();
        r.set("core.uops", 100.0);
        r.set("mem.reads", 8.0);
        r.scale_all(2.5);
        assert_eq!(r.get("core.uops"), Some(250.0));
        assert_eq!(r.get("mem.reads"), Some(20.0));
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::pow2(10);
        for v in [1, 2, 3, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 221.2).abs() < 1e-9);
        assert!(h.percentile(50.0) <= 4);
        assert!(h.percentile(99.0) >= 512);
    }

    #[test]
    fn percentile_zero_skips_empty_buckets() {
        // Values land only in high buckets; p0 must not report the (empty)
        // first bucket's bound of 1.
        let mut h = Histogram::pow2(10);
        for v in [600, 700, 900] {
            h.record(v);
        }
        // All three live in the (512, 1024] bucket, clamped to the max.
        assert_eq!(h.percentile(0.0), 900);
        assert!(h.percentile(0.0) >= 512, "p0 fell into an empty bucket");
    }

    #[test]
    fn percentile_never_exceeds_recorded_max() {
        // All samples share one bucket (513..=1024): every percentile —
        // including p100 — must agree with the recorded max, not the
        // bucket's upper bound of 1024.
        let mut h = Histogram::pow2(10);
        for _ in 0..5 {
            h.record(1000);
        }
        assert_eq!(h.percentile(0.0), 1000);
        assert_eq!(h.percentile(50.0), 1000);
        assert_eq!(h.percentile(100.0), 1000);
    }

    #[test]
    fn percentile_100_is_max_with_overflow_bucket() {
        let mut h = Histogram::pow2(4); // bounds 1..16, +inf
        h.record(3);
        h.record(1_000_000);
        assert_eq!(h.percentile(100.0), 1_000_000);
        assert_eq!(h.percentile(0.0), 4); // 3 lands in the (2,4] bucket
        // Empty histogram stays 0 for any p.
        let e = Histogram::pow2(4);
        assert_eq!(e.percentile(0.0), 0);
        assert_eq!(e.percentile(100.0), 0);
    }

    #[test]
    fn ratio_zero_denominator() {
        assert_eq!(ratio(5.0, 0.0), 0.0);
        assert_eq!(ratio(6.0, 3.0), 2.0);
    }

    #[test]
    fn window_stats_welford_matches_direct_formulas() {
        let xs = [10.0, 12.0, 11.0, 13.0, 9.0];
        let mut w = WindowStats::new();
        for x in xs {
            w.record(x);
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
        assert_eq!(w.count(), 5);
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 9.0);
        assert_eq!(w.max(), 13.0);
        let ci = 1.96 * var.sqrt() / n.sqrt();
        assert!((w.ci95_half() - ci).abs() < 1e-12);
        assert!((w.rel_ci95() - ci / mean).abs() < 1e-12);
    }

    #[test]
    fn window_stats_degenerate_cases() {
        let w = WindowStats::new();
        assert_eq!((w.count(), w.mean(), w.variance(), w.ci95_half()), (0, 0.0, 0.0, 0.0));
        let mut one = WindowStats::new();
        one.record(42.0);
        assert_eq!(one.mean(), 42.0);
        assert_eq!(one.variance(), 0.0, "a single window has no spread estimate");
        assert_eq!(one.ci95_half(), 0.0);
        // Identical windows (perfectly regular streaming kernel): zero CI.
        let mut flat = WindowStats::new();
        for _ in 0..10 {
            flat.record(7.0);
        }
        assert_eq!(flat.stddev(), 0.0);
        assert_eq!(flat.rel_ci95(), 0.0);
    }

    #[test]
    fn scale_durations_touches_only_time_keys() {
        let mut r = StatsReport::new();
        r.set("core.fu_stall_cycles", 10.0);
        r.set("vima.fetch_cycles_sum", 4.0);
        r.set("vima.busy_until", 100.0);
        r.set("core.uops", 50.0);
        r.set("mem.host_reads", 7.0);
        r.scale_durations(3.0);
        assert_eq!(r.get("core.fu_stall_cycles"), Some(30.0));
        assert_eq!(r.get("vima.fetch_cycles_sum"), Some(12.0));
        assert_eq!(r.get("vima.busy_until"), Some(300.0));
        assert_eq!(r.get("core.uops"), Some(50.0), "event counters must not scale");
        assert_eq!(r.get("mem.host_reads"), Some(7.0));
    }

    #[test]
    fn sample_keys_merge_as_gauges() {
        let mut a = StatsReport::new();
        a.set("sample.factor", 32.0);
        let mut b = StatsReport::new();
        b.set("sample.factor", 30.0);
        a.merge(&b);
        assert_eq!(a.get("sample.factor"), Some(32.0), "sample.* must not sum on merge");
    }
}
