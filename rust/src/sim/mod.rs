//! Full-machine simulation: cores + cache hierarchy + 3D memory + the VIMA
//! and HIVE logic layers, driven by per-thread trace streams.
//!
//! The simulator is deterministic and single-threaded (like SiNUCA): cores
//! are interleaved in bounded time windows so shared resources (LLC, DRAM
//! banks, links, the VIMA FUs) observe requests in approximately global time
//! order.

use std::collections::hash_map::DefaultHasher;
use std::hash::Hasher;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::cache::MemorySystem;
use crate::config::SystemConfig;
use crate::cpu::Core;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::fabric::{FabricPort, VimaDispatcher};
use crate::hive::HiveDevice;
use crate::isa::TraceEvent;
use crate::stats::{StatsReport, WindowStats};
use crate::trace::{TraceParams, TraceStream};
use crate::util::error::Result;

/// Process-wide count of [`Machine::run`] invocations. The sweep engine's
/// result cache exists to minimize this number; the `sweep` CLI summary and
/// the dedup tests read it.
static RUN_INVOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Total `Machine::run` calls since process start (all threads).
pub fn run_invocations() -> u64 {
    RUN_INVOCATIONS.load(Ordering::Relaxed)
}

/// Outcome of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end execution time in CPU cycles (all cores + devices drained).
    pub cycles: u64,
    /// Wall-clock seconds at the configured core frequency.
    pub seconds: f64,
    /// Total dynamic+static energy, joules.
    pub energy: EnergyBreakdown,
    /// Raw counters from every component.
    pub report: StatsReport,
}

impl SimResult {
    /// Speedup of `self` relative to a baseline run.
    ///
    /// Degenerate inputs are guarded instead of leaking `inf`/`NaN` into
    /// figure tables and geomeans: two zero-cycle runs compare as 1.0
    /// (equal), and a zero-cycle `self` against a real baseline saturates
    /// to `f64::MAX`.
    pub fn speedup_vs(&self, baseline: &SimResult) -> f64 {
        guarded_ratio(baseline.cycles as f64, self.cycles as f64)
    }

    /// Energy of `self` relative to a baseline run (1.0 = same). Zero-joule
    /// baselines are guarded like [`speedup_vs`](Self::speedup_vs): 0/0 is
    /// 1.0, and a real numerator over a zero baseline saturates to
    /// `f64::MAX` instead of returning `inf`.
    pub fn energy_ratio_vs(&self, baseline: &SimResult) -> f64 {
        guarded_ratio(self.energy.total_j, baseline.energy.total_j)
    }
}

/// `num / den` with zero-denominator guards: finite for all finite inputs
/// (0/0 → 1.0, x/0 → `f64::MAX`), untouched whenever `den > 0`.
fn guarded_ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else if num == 0.0 {
        1.0
    } else {
        f64::MAX
    }
}

/// The simulated machine.
pub struct Machine {
    pub cfg: SystemConfig,
    cores: Vec<Core>,
    pub mem: MemorySystem,
    /// One VIMA logic layer per memory cube, with home-cube routing
    /// ([`VimaDispatcher`]); a single-cube fabric behaves exactly like the
    /// old lone `VimaDevice`.
    pub vima: VimaDispatcher,
    pub hive: HiveDevice,
    /// Optional multiplier applied to the final cycle count (trace sampling
    /// extrapolation; see DESIGN.md §Sampling). Stats scale linearly too.
    scale: f64,
    /// Bookkeeping of the last [`run_sampled`](Self::run_sampled) run:
    /// per-window cycle costs and the detailed/fast-forwarded event split
    /// that [`finish`](Self::finish) extrapolates from. `None` for plain
    /// detailed runs.
    sample: Option<SampleMeasure>,
}

/// Measurements accumulated by one sampled run (DESIGN.md §11).
struct SampleMeasure {
    /// Events executed in detail per sample period (per core).
    window_events: u64,
    /// Total events per sample period (per core); `period - window` are
    /// fast-forwarded functionally.
    period_events: u64,
    /// Cycle cost of each *complete* detailed window (partial trailing
    /// windows contribute to the clock but not to the spread estimate).
    windows: WindowStats,
    /// Events executed with full timing, across all cores.
    detailed_events: u64,
    /// Events fast-forwarded functionally, across all cores.
    ff_events: u64,
}

/// Interleaving window: a core may run at most this far (in cycles) past the
/// slowest core before yielding. The shared-resource model reserves
/// bandwidth with monotonic `next_free` clocks (no backfill), so cross-core
/// request disorder must stay small or later-processed cores queue behind
/// earlier-processed ones' whole timelines; 4 cycles keeps the skew small
/// relative to a DRAM round-trip (~70 cycles).
const WINDOW: u64 = 4;

impl Machine {
    /// Build a machine for `threads` cores. Invalid thread counts and
    /// invalid memory geometry (non-power-of-two vaults/banks/cubes, bad
    /// row buffers) are typed errors, not panics or silent corruption.
    pub fn new(cfg: &SystemConfig, threads: usize) -> Result<Self> {
        crate::ensure!(
            threads >= 1 && threads <= cfg.core.num_cores,
            "thread count {threads} out of range (config has {} cores)",
            cfg.core.num_cores
        );
        Ok(Self {
            cores: (0..threads).map(|i| Core::new(i, &cfg.core)).collect(),
            mem: MemorySystem::new(cfg, threads)?,
            vima: VimaDispatcher::new(
                &cfg.vima,
                cfg.mem.inst_lat_cycles,
                cfg.core.freq_ghz,
                cfg.mem.num_cubes,
            ),
            hive: HiveDevice::new(&cfg.hive, cfg.core.freq_ghz),
            scale: 1.0,
            sample: None,
            cfg: cfg.clone(),
        })
    }

    /// Set the sampling extrapolation factor (cycles & energy multiply).
    pub fn set_scale(&mut self, scale: f64) {
        assert!(scale >= 1.0);
        self.scale = scale;
    }

    /// Number of simulated cores this machine was built for.
    pub fn threads(&self) -> usize {
        self.cores.len()
    }

    /// Process one trace event on core `c`. Returns the core-local time.
    fn step(&mut self, c: usize, ev: &TraceEvent) -> Result<u64> {
        Ok(match ev {
            TraceEvent::Uop(u) => self.cores[c].run_uop(u, &mut self.mem),
            TraceEvent::Vima(v) => {
                // Stop-and-go dispatch (Sec. III-C): the VIMA instruction
                // leaves only after everything before it has committed.
                let t = self.cores[c].drain();
                // VIMA-aware coherence: write back + invalidate host-cached
                // lines of every operand range before execution.
                let mut settle = t;
                for a in v.src_addrs() {
                    let (s, _) = self.mem.flush_range(a, v.vector_bytes as usize, t);
                    settle = settle.max(s);
                }
                if let Some(d) = v.dst() {
                    let (s, _) = self.mem.flush_range(d, v.vector_bytes as usize, t);
                    settle = settle.max(s);
                }
                let done = self.vima.execute(v, settle, &mut self.mem.mem)?;
                if self.cfg.vima.stop_and_go {
                    // Wait for the completion signal + dispatch gap.
                    self.cores[c].serialize_until(done + self.cfg.vima.dispatch_gap_cycles);
                    self.cores[c].drain()
                } else {
                    // Ablation: fire-and-forget (non-precise exceptions).
                    t
                }
            }
            TraceEvent::Hive(h) => {
                // HIVE ops are posted (non-precise): the host continues.
                // The HIVE register bank sits on the host-attached cube 0;
                // remote vectors stream through the fabric as hops.
                let t = self.cores[c].now();
                self.hive.execute(h, t, &mut FabricPort::new(&mut self.mem.mem, 0))?;
                t
            }
        })
    }

    /// Run one trace stream per thread to completion on the chunked
    /// execution path: each core consumes its stream's refill buffer in
    /// place through [`run_chunk_until`](Self::run_chunk_until) — no
    /// per-event `Iterator::next` round trip. Event-for-event it performs
    /// exactly the state transitions of
    /// [`run_reference`](Self::run_reference); cycle counts are
    /// bit-identical (see `tests/chunked_equivalence.rs` and DESIGN.md
    /// §Chunked execution).
    pub fn run(&mut self, traces: Vec<TraceStream>) -> Result<SimResult> {
        RUN_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
        assert_eq!(traces.len(), self.cores.len(), "one trace per core");
        self.sample = None;
        let mut streams = traces;

        if streams.len() == 1 {
            // Single-core fast path: no windowing/watermark bookkeeping —
            // whole chunks execute back to back.
            let stream = &mut streams[0];
            while stream.fill() {
                let n = self.run_chunk_until(0, stream.chunk(), u64::MAX)?;
                stream.consume(n);
            }
        } else {
            self.run_interleaved(&mut streams)?;
        }
        self.finish()
    }

    /// Multi-core chunked path: interleave cores in bounded windows of
    /// simulated time. The start position rotates every round: whoever
    /// issues first in a window gets the shared resources first, and a
    /// fixed order would systematically starve the last core.
    fn run_interleaved(&mut self, streams: &mut [TraceStream]) -> Result<()> {
        let n = streams.len();
        let mut done = vec![false; n];
        let mut round = 0usize;
        while !done.iter().all(|&d| d) {
            let watermark = self
                .cores
                .iter()
                .zip(&done)
                .filter(|(_, &d)| !d)
                .map(|(c, _)| c.now())
                .min();
            let Some(watermark) = watermark else { break };
            let limit = watermark + WINDOW;
            round += 1;
            for i in 0..n {
                let c = (i + round) % n;
                if done[c] {
                    continue;
                }
                while self.cores[c].now() <= limit {
                    if !streams[c].fill() {
                        done[c] = true;
                        break;
                    }
                    let consumed = self.run_chunk_until(c, streams[c].chunk(), limit)?;
                    streams[c].consume(consumed);
                }
            }
        }
        Ok(())
    }

    /// Execute the leading events of `events` on core `c`, stopping before
    /// the first event once the core-local clock passes `limit`. Returns
    /// how many events were consumed.
    ///
    /// This is the chunked hot loop: runs of host µops dispatch through a
    /// tight per-kind inner loop with the core/memory borrows (and the
    /// enum match) hoisted out of the per-µop path; VIMA/HIVE events fall
    /// back to the general per-event `step`. The limit check happens
    /// before every event, exactly like the reference interleaver.
    pub fn run_chunk_until(
        &mut self,
        c: usize,
        events: &[TraceEvent],
        limit: u64,
    ) -> Result<usize> {
        let mut i = 0;
        while i < events.len() && self.cores[c].now() <= limit {
            if let TraceEvent::Uop(_) = events[i] {
                let core = &mut self.cores[c];
                let mem = &mut self.mem;
                while i < events.len() && core.now() <= limit {
                    let TraceEvent::Uop(u) = &events[i] else { break };
                    core.run_uop(u, mem);
                    i += 1;
                }
            } else {
                self.step(c, &events[i])?;
                i += 1;
            }
        }
        Ok(i)
    }

    /// Execute one whole chunk of events on core `c` (no time bound) —
    /// the single-core fast path, exposed for external chunk drivers.
    pub fn run_chunk(&mut self, c: usize, events: &[TraceEvent]) -> Result<()> {
        self.run_chunk_until(c, events, u64::MAX).map(|_| ())
    }

    /// Functional twin of [`step`](Self::step): the event's *state*
    /// transitions (cache tags, TLB, branch predictor, vector caches,
    /// event counters, DRAM traffic) happen in the exact order of detailed
    /// execution, but no resource clock advances and no completion time is
    /// computed. `now` is the frozen fast-forward clock, used only to
    /// stamp in-flight prefetch entries.
    fn step_functional(&mut self, c: usize, ev: &TraceEvent, now: u64) -> Result<()> {
        match ev {
            TraceEvent::Uop(u) => self.cores[c].run_uop_functional(u, &mut self.mem, now),
            TraceEvent::Vima(v) => {
                // Same coherence walk as the detailed path: write back +
                // invalidate host-cached operand lines before execution.
                for a in v.src_addrs() {
                    self.mem.flush_range_functional(a, v.vector_bytes as usize);
                }
                if let Some(d) = v.dst() {
                    self.mem.flush_range_functional(d, v.vector_bytes as usize);
                }
                self.vima.execute_functional(v, &mut self.mem.mem)?;
            }
            TraceEvent::Hive(h) => {
                // HIVE register traffic streams through cube 0 like the
                // detailed FabricPort, minus hop/lock timing.
                let fabric = &mut self.mem.mem;
                self.hive.execute_functional(h, |a, w| {
                    fabric.vima_access_functional_from(0, a, w)
                })?;
            }
        }
        Ok(())
    }

    /// Execute a whole chunk functionally on core `c` (fast-forward hot
    /// loop; µop runs dispatch with the borrows hoisted like
    /// [`run_chunk_until`](Self::run_chunk_until)). Consumes every event.
    pub fn run_chunk_functional(&mut self, c: usize, events: &[TraceEvent]) -> Result<()> {
        let now = self.cores[c].now();
        let mut i = 0;
        while i < events.len() {
            if let TraceEvent::Uop(_) = events[i] {
                let core = &mut self.cores[c];
                let mem = &mut self.mem;
                while let Some(TraceEvent::Uop(u)) = events.get(i) {
                    core.run_uop_functional(u, mem, now);
                    i += 1;
                }
            } else {
                self.step_functional(c, &events[i], now)?;
                i += 1;
            }
        }
        Ok(())
    }

    /// Sampled execution (DESIGN.md §11): alternate *detailed* windows of
    /// `window_events` events per core — full timing, exactly the
    /// [`run`](Self::run) machinery — with functional fast-forward over the
    /// remaining `period_events - window_events` events, where every event
    /// still updates microarchitectural state (caches, TLBs, branch
    /// predictors, vector caches) and traffic counters but time stands
    /// still. [`finish`](Self::finish) extrapolates the measured cycles by
    /// `total_events / detailed_events` and reports per-window spread under
    /// `sample.*` keys.
    ///
    /// `window_events >= period_events` degenerates to a plain detailed
    /// run, bit-identical to [`run`](Self::run) /
    /// [`run_reference`](Self::run_reference) (pinned by
    /// `tests/sampled_equivalence.rs`).
    pub fn run_sampled(
        &mut self,
        traces: Vec<TraceStream>,
        window_events: u64,
        period_events: u64,
    ) -> Result<SimResult> {
        if window_events >= period_events {
            return self.run(traces);
        }
        assert!(window_events >= 1, "sample window must cover at least one event");
        RUN_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
        assert_eq!(traces.len(), self.cores.len(), "one trace per core");
        let mut streams = traces;
        let mut m = SampleMeasure {
            window_events,
            period_events,
            windows: WindowStats::new(),
            detailed_events: 0,
            ff_events: 0,
        };
        if streams.len() == 1 {
            self.run_sampled_single(&mut streams[0], &mut m)?;
        } else {
            self.run_sampled_interleaved(&mut streams, &mut m)?;
        }
        self.sample = Some(m);
        self.finish()
    }

    /// Single-core sampled driver: back-to-back chunks, no windowing
    /// bookkeeping (mirrors the [`run`](Self::run) fast path).
    fn run_sampled_single(
        &mut self,
        stream: &mut TraceStream,
        m: &mut SampleMeasure,
    ) -> Result<()> {
        let ff_budget = m.period_events - m.window_events;
        loop {
            // --- detailed window ---
            let start = self.cores[0].now();
            let mut left = m.window_events;
            while left > 0 {
                if !stream.fill() {
                    // Partial trailing window: its cycles are on the clock
                    // but its spread is unrepresentative — don't record it.
                    m.detailed_events += m.window_events - left;
                    return Ok(());
                }
                let chunk = stream.chunk();
                let take = (left as usize).min(chunk.len());
                let n = self.run_chunk_until(0, &chunk[..take], u64::MAX)?;
                stream.consume(n);
                left -= n as u64;
            }
            m.detailed_events += m.window_events;
            m.windows.record((self.cores[0].now() - start) as f64);

            // --- functional fast-forward ---
            self.mem.begin_functional();
            let mut left = ff_budget;
            while left > 0 {
                if !stream.fill() {
                    break;
                }
                let chunk = stream.chunk();
                let take = (left as usize).min(chunk.len());
                self.run_chunk_functional(0, &chunk[..take])?;
                stream.consume(take);
                left -= take as u64;
            }
            m.ff_events += ff_budget - left;
            self.mem.end_functional();
            if left > 0 {
                return Ok(()); // stream ran dry mid-fast-forward
            }
        }
    }

    /// Multi-core sampled driver: detailed windows run through the same
    /// bounded-skew watermark/rotation interleaver as
    /// [`run_interleaved`](Self::run_interleaved) with a per-core event
    /// budget; fast-forward phases visit cores sequentially (no timing, so
    /// interleaving order is irrelevant).
    fn run_sampled_interleaved(
        &mut self,
        streams: &mut [TraceStream],
        m: &mut SampleMeasure,
    ) -> Result<()> {
        let n = streams.len();
        let ff_budget = m.period_events - m.window_events;
        let mut done = vec![false; n];
        let mut round = 0usize;
        while !done.iter().all(|&d| d) {
            // --- detailed window ---
            let start = self.cores.iter().map(|c| c.now()).max().unwrap_or(0);
            let live_at_start = done.clone();
            let mut budget = vec![m.window_events; n];
            loop {
                let watermark = (0..n)
                    .filter(|&c| !done[c] && budget[c] > 0)
                    .map(|c| self.cores[c].now())
                    .min();
                let Some(watermark) = watermark else { break };
                let limit = watermark + WINDOW;
                round += 1;
                for i in 0..n {
                    let c = (i + round) % n;
                    if done[c] || budget[c] == 0 {
                        continue;
                    }
                    while self.cores[c].now() <= limit && budget[c] > 0 {
                        if !streams[c].fill() {
                            done[c] = true;
                            break;
                        }
                        let chunk = streams[c].chunk();
                        let take = (budget[c] as usize).min(chunk.len());
                        let consumed = self.run_chunk_until(c, &chunk[..take], limit)?;
                        streams[c].consume(consumed);
                        budget[c] -= consumed as u64;
                        m.detailed_events += consumed as u64;
                    }
                }
            }
            let end = self.cores.iter().map(|c| c.now()).max().unwrap_or(start);
            // Record only clean windows: if a stream ran dry mid-window the
            // measured cost is unrepresentative of a full one.
            if done == live_at_start {
                m.windows.record((end - start) as f64);
            }
            if done.iter().all(|&d| d) {
                break;
            }

            // --- functional fast-forward ---
            self.mem.begin_functional();
            for c in 0..n {
                if done[c] {
                    continue;
                }
                let mut left = ff_budget;
                while left > 0 {
                    if !streams[c].fill() {
                        done[c] = true;
                        break;
                    }
                    let chunk = streams[c].chunk();
                    let take = (left as usize).min(chunk.len());
                    self.run_chunk_functional(c, &chunk[..take])?;
                    streams[c].consume(take);
                    left -= take as u64;
                }
                m.ff_events += ff_budget - left;
            }
            self.mem.end_functional();
        }
        Ok(())
    }

    /// Digest of every *order-driven* microarchitectural structure the
    /// functional fast-forward path promises to keep bit-identical to
    /// detailed execution: cache tag/LRU/dirty arrays at every level, the
    /// region occupancy filter, each core's DTLB and branch predictor, and
    /// each VIMA device's vector cache. Timing state (resource clocks,
    /// MSHR windows, pipeline rings, in-flight prefetch ready times) is
    /// excluded by design. Pinned by `tests/sampled_equivalence.rs`.
    pub fn state_digest(&self) -> u64 {
        let mut h = DefaultHasher::new();
        for c in &self.cores {
            c.dtlb.digest_into(&mut h);
            c.bpred.digest_into(&mut h);
        }
        self.mem.digest_into(&mut h);
        self.vima.digest_into(&mut h);
        h.finish()
    }

    /// Event-at-a-time reference implementation of [`run`] — the
    /// pre-chunking execution path, kept as the determinism oracle (the
    /// chunked engine must reproduce its cycle counts bit for bit) and as
    /// the baseline the `simcore` throughput benchmark reports against.
    pub fn run_reference(&mut self, traces: Vec<TraceStream>) -> Result<SimResult> {
        RUN_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
        assert_eq!(traces.len(), self.cores.len(), "one trace per core");
        self.sample = None;
        let mut streams: Vec<_> = traces.into_iter().map(Some).collect();
        let mut done = vec![false; streams.len()];

        // Single-core fast path: no windowing/watermark bookkeeping needed.
        if streams.len() == 1 {
            let stream = streams[0].as_mut().expect("stream");
            let mut buf = Vec::new();
            while {
                buf.clear();
                buf.extend(stream.by_ref().take(4096));
                !buf.is_empty()
            } {
                for ev in &buf {
                    self.step(0, ev)?;
                }
            }
            done[0] = true;
        }

        // Interleave cores in bounded windows of simulated time (see
        // `run_interleaved` for the rotation rationale).
        let mut round = 0usize;
        while !done.iter().all(|&d| d) {
            let watermark = self
                .cores
                .iter()
                .zip(&done)
                .filter(|(_, &d)| !d)
                .map(|(c, _)| c.now())
                .min();
            let Some(watermark) = watermark else { break };
            let limit = watermark + WINDOW;
            round += 1;
            for i in 0..self.cores.len() {
                let c = (i + round) % self.cores.len();
                if done[c] {
                    continue;
                }
                let stream = streams[c].as_mut().expect("stream");
                while self.cores[c].now() <= limit {
                    match stream.next() {
                        Some(ev) => {
                            self.step(c, &ev)?;
                        }
                        None => {
                            done[c] = true;
                            break;
                        }
                    }
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }
        self.finish()
    }

    /// Shared run epilogue: drain devices (dirty VIMA cache lines, HIVE
    /// write-backs, posted stores, DRAM) and assemble the result.
    fn finish(&mut self) -> Result<SimResult> {
        self.mem.drain_pending();
        let core_end = self.cores.iter().map(|c| c.now()).max().unwrap_or(0);
        let vima_end = self.vima.drain(core_end, &mut self.mem.mem);
        let hive_end = self.hive.drained_at();
        if std::env::var_os("VIMA_DEBUG_SIM").is_some() {
            let ends: Vec<u64> = self.cores.iter().map(|c| c.now()).collect();
            eprintln!(
                "core_ends={ends:?} vima_end={vima_end} hive_end={hive_end} mem_drained={}",
                self.mem.mem.drained_at()
            );
        }
        let cycles_raw = core_end.max(vima_end).max(hive_end).max(self.mem.mem.drained_at());
        // Sampled-run extrapolation (DESIGN.md §11): the clock advanced
        // only during detailed windows, so measured cycles blow up by the
        // fraction of events they covered. Composes with the trace-level
        // sampling `scale` — the two sub-sample along independent axes.
        let factor = match &self.sample {
            Some(m) if m.detailed_events > 0 => {
                (m.detailed_events + m.ff_events) as f64 / m.detailed_events as f64
            }
            _ => 1.0,
        };
        // Extrapolate through f64 only when a factor is set, and round
        // instead of truncating: `as u64` floors, which past 2^53 (or
        // with any fractional scale) biases every scaled run downward.
        let eff = self.scale * factor;
        let cycles =
            if eff == 1.0 { cycles_raw } else { (cycles_raw as f64 * eff).round() as u64 };

        let mut report = StatsReport::new();
        for core in &self.cores {
            core.dump_stats(&mut report);
        }
        self.mem.dump_stats(&mut report);
        self.vima.dump_stats(&mut report);
        self.hive.dump_stats(&mut report);
        if self.scale != 1.0 {
            // Linear extrapolation of event counters (uniform sampled work),
            // in place — no clone/rebuild of the whole report.
            report.scale_all(self.scale);
            // Hardware-count gauges don't extrapolate; restore them after
            // the blanket scaling (like the sim.* gauges set below).
            if self.cfg.mem.num_cubes > 1 {
                report.set("fabric.cubes", self.cfg.mem.num_cubes as f64);
                report.set("vima.devices", self.vima.num_devices() as f64);
            }
        }
        if factor != 1.0 {
            // Durations (stall/queue cycle sums, busy timestamps) accrued
            // only inside detailed windows; event counters are whole-run
            // exact. Extrapolate just the former.
            report.scale_durations(factor);
        }
        report.set("sim.cycles", cycles as f64);
        report.set("sim.threads", self.cores.len() as f64);
        report.set("sim.scale", self.scale);
        if let Some(m) = &self.sample {
            let k = m.windows.count().max(1) as f64;
            report.set("sample.windows", m.windows.count() as f64);
            report.set("sample.window_events", m.window_events as f64);
            report.set("sample.period_events", m.period_events as f64);
            report.set("sample.detailed_events", m.detailed_events as f64);
            report.set("sample.total_events", (m.detailed_events + m.ff_events) as f64);
            report.set("sample.factor", factor);
            report.set("sample.cycles_mean", m.windows.mean());
            report.set("sample.cycles_stddev", m.windows.stddev());
            // Error bound on the extrapolated cycle count: the window
            // mean's 95% CI plus a 1/k boundary term (cold-start and
            // partial-window bias shrink as more windows are measured).
            report.set("sample.cycles_ci95", cycles as f64 * (m.windows.rel_ci95() + 1.0 / k));
        }

        let energy = EnergyModel::new(&self.cfg).compute(&report, cycles, self.cores.len());
        let seconds = cycles as f64 / (self.cfg.core.freq_ghz * 1e9);
        Ok(SimResult { cycles, seconds, energy, report })
    }

    /// Reset every component for a fresh run with the same configuration.
    pub fn reset(&mut self) {
        for c in &mut self.cores {
            c.reset();
        }
        self.mem.reset();
        self.vima.reset();
        self.hive.reset();
        self.scale = 1.0;
        self.sample = None;
    }
}

/// Convenience: simulate one workload end to end, honoring the thread
/// count already carried in `params` (1 for freshly built params) — so a
/// multi-threaded `RunCell::params()` simulated directly agrees with the
/// sweep result for the same cache key.
///
/// This is now a thin wrapper over the process-default
/// [`SimService`](crate::service::SimService): the job runs on its
/// long-lived worker pool, machines are pooled and reset instead of
/// rebuilt, and a repeated call is a result-cache hit. Results are
/// bit-identical to a fresh `Machine::new` + [`run_on`] (the simulator is
/// deterministic and reset ≡ fresh; see `machine_reuse_matches_fresh_runs`).
pub fn simulate(cfg: &SystemConfig, params: crate::trace::TraceParams) -> Result<SimResult> {
    simulate_threads(cfg, params, params.threads)
}

/// Simulate a data-parallel workload over an explicit `threads` override
/// (replaces whatever thread count `params` carries). Like [`simulate`],
/// a wrapper over the process-default service — invalid thread counts are
/// typed errors now, not `Machine::new` panics.
pub fn simulate_threads(
    cfg: &SystemConfig,
    params: TraceParams,
    threads: usize,
) -> Result<SimResult> {
    let mut p = params;
    p.thread = 0;
    p.threads = threads;
    crate::service::default_service()
        .submit(crate::service::Job::new(p).with_cfg(cfg.clone()))
        .wait()
}

/// Run one data-parallel workload (`params.threads` cores) on an existing
/// (fresh or just-reset) machine. This is the execution primitive the
/// [`service`](crate::service) workers call: they pool machines per
/// `(config, threads)` shape and call [`Machine::reset`] between runs
/// instead of reallocating the whole hierarchy. Callers who own a machine
/// (benchmarks, the transpile demo) use it directly.
///
/// The workload comes from the registry: its sampling-extrapolation factor
/// (DESIGN.md §Sampling) is applied, and unknown workloads / unsupported
/// backends / invalid parameters are typed errors, never panics.
pub fn run_on(machine: &mut Machine, params: TraceParams) -> Result<SimResult> {
    crate::ensure!(
        machine.threads() == params.threads,
        "machine was built for {} threads, params want {}",
        machine.threads(),
        params.threads
    );
    let workload = crate::workload::get(params.workload)?;
    // The extrapolation factor is computed from the cell's own parameters
    // (historically it was evaluated on a `with_threads(0, 1)` view). The
    // per-thread generators divide their sampling caps by the thread count
    // (see matmul::sampling_for), so every single-thread cell and fig4's
    // t<=8 cells are bit-unchanged — pinned by
    // `sampling_scale_matches_single_thread_view` in
    // tests/sampled_equivalence.rs. At 16/32 threads MatMul's per-thread
    // cap floors at 6 rows and the factor now matches the rows each thread
    // actually emits; the old view overestimated cycles there (intentional
    // fix, documented in DESIGN.md §11).
    machine.set_scale(workload.sampling_scale(&params).max(1.0));
    let traces = (0..params.threads)
        .map(|t| params.with_threads(t, params.threads).stream())
        .collect::<Result<Vec<_>>>()?;
    if machine.cfg.sample.enabled {
        // Zero window/period defer to the workload's own defaults.
        let (dw, dp) = workload.sample_defaults(&params);
        let w = machine.cfg.sample.window_events;
        let p = machine.cfg.sample.period_events;
        let window = if w > 0 { w } else { dw };
        let period = if p > 0 { p } else { dp };
        machine.run_sampled(traces, window, period)
    } else {
        machine.run(traces)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId, TraceParams};

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn vecsum_vima_beats_avx() {
        let c = cfg();
        let avx = simulate(&c, TraceParams::new(KernelId::VecSum, Backend::Avx, 3 << 20)).unwrap();
        let vima =
            simulate(&c, TraceParams::new(KernelId::VecSum, Backend::Vima, 3 << 20)).unwrap();
        let speedup = vima.speedup_vs(&avx);
        assert!(speedup > 1.5, "VecSum VIMA speedup {speedup}");
        assert!(vima.energy_ratio_vs(&avx) < 0.7, "VIMA must save energy");
    }

    #[test]
    fn memset_vima_large_speedup() {
        let c = cfg();
        let avx = simulate(&c, TraceParams::new(KernelId::MemSet, Backend::Avx, 4 << 20)).unwrap();
        let vima =
            simulate(&c, TraceParams::new(KernelId::MemSet, Backend::Vima, 4 << 20)).unwrap();
        let speedup = vima.speedup_vs(&avx);
        assert!(speedup > 4.0, "MemSet VIMA speedup {speedup}");
    }

    #[test]
    fn multithreading_speeds_up_avx() {
        let c = cfg();
        let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 3 << 20);
        let t1 = simulate_threads(&c, p, 1).unwrap();
        let t4 = simulate_threads(&c, p, 4).unwrap();
        let speedup = t1.cycles as f64 / t4.cycles as f64;
        assert!(speedup > 1.5, "4-thread speedup {speedup}");
        assert!(speedup <= 4.5);
    }

    #[test]
    fn stop_and_go_ablation_changes_time() {
        let mut c = cfg();
        let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20);
        let with = simulate(&c, p).unwrap();
        c.vima.stop_and_go = false;
        let without = simulate(&c, p).unwrap();
        assert!(
            without.cycles < with.cycles,
            "removing stop-and-go must help: {} vs {}",
            without.cycles,
            with.cycles
        );
    }

    #[test]
    fn machine_reuse_matches_fresh_runs() {
        // Reset-and-reuse (the sweep engine's fast path) must be
        // indistinguishable from a freshly allocated machine.
        let c = cfg();
        let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 1 << 20);
        let q = TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20);
        let mut m = Machine::new(&c, 1).unwrap();
        let first = run_on(&mut m, p).unwrap();
        m.reset();
        let second = run_on(&mut m, q).unwrap();
        assert_eq!(second.cycles, simulate(&c, q).unwrap().cycles);
        m.reset();
        let again = run_on(&mut m, p).unwrap();
        assert_eq!(first.cycles, again.cycles);
        assert_eq!(first.report, again.report);
    }

    #[test]
    fn deterministic_runs() {
        let c = cfg();
        let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 1 << 20);
        let a = simulate(&c, p).unwrap();
        let b = simulate(&c, p).unwrap();
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn loop_branches_predict_well_on_every_backend() {
        // Every backend's generator emits the same taken..taken,not-taken
        // loop shape, so the two-level predictor must be near perfect on
        // all of them — the HIVE streams used to skip the loop-exit branch
        // entirely, silently flattering their front-end accounting.
        let c = cfg();
        for backend in [Backend::Avx, Backend::Vima, Backend::Hive] {
            let r = simulate(&c, TraceParams::new(KernelId::MemSet, backend, 4 << 20)).unwrap();
            let branches = r.report.get("core.branches").unwrap();
            let mis = r.report.get("core.mispredicts").unwrap();
            assert!(branches > 0.0, "{backend}: no branches simulated");
            assert!(mis * 20.0 < branches, "{backend}: {mis}/{branches} mispredicts");
        }
    }

    #[test]
    fn hive_runs_and_drains() {
        let c = cfg();
        let r = simulate(&c, TraceParams::new(KernelId::VecSum, Backend::Hive, 1 << 20)).unwrap();
        assert!(r.cycles > 0);
        assert!(r.report.get("hive.transactions").unwrap() > 0.0);
    }

    #[test]
    fn ratio_guards_zero_baselines() {
        let zero = SimResult {
            cycles: 0,
            seconds: 0.0,
            energy: crate::energy::EnergyBreakdown::default(),
            report: StatsReport::new(),
        };
        let mut real = zero.clone();
        real.cycles = 1000;
        real.energy.total_j = 0.5;

        // 0/0 pins to 1.0 (equal), never NaN.
        assert_eq!(zero.speedup_vs(&zero), 1.0);
        assert_eq!(zero.energy_ratio_vs(&zero), 1.0);
        // A zero denominator saturates finite instead of returning inf.
        assert_eq!(zero.speedup_vs(&real), f64::MAX);
        assert_eq!(real.energy_ratio_vs(&zero), f64::MAX);
        // Zero numerators over real denominators are plain zero...
        assert_eq!(real.speedup_vs(&zero), 0.0);
        assert_eq!(zero.energy_ratio_vs(&real), 0.0);
        // ...and everything stays finite (geomean/max reductions survive).
        for v in [
            zero.speedup_vs(&real),
            real.speedup_vs(&zero),
            zero.energy_ratio_vs(&real),
            real.energy_ratio_vs(&zero),
        ] {
            assert!(v.is_finite(), "{v}");
        }
        // Real runs are untouched by the guard.
        let mut twice = real.clone();
        twice.cycles = 2000;
        twice.energy.total_j = 1.0;
        assert_eq!(real.speedup_vs(&twice), 2.0);
        assert_eq!(real.energy_ratio_vs(&twice), 0.5);
    }

    #[test]
    fn sampled_run_reports_sample_keys_and_tracks_full_run() {
        let c = cfg();
        let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20);
        let full = simulate(&c, p).unwrap();
        let mut m = Machine::new(&c, 1).unwrap();
        let sampled = m.run_sampled(vec![p.stream().unwrap()], 2048, 32768).unwrap();
        let r = &sampled.report;
        assert!(r.get("sample.windows").unwrap() >= 1.0);
        assert!(r.get("sample.factor").unwrap() > 1.0);
        assert_eq!(
            r.get("sample.total_events").unwrap(),
            full.report.get("core.uops").unwrap(),
            "every event must be executed (functionally or in detail)"
        );
        let err = (sampled.cycles as f64 - full.cycles as f64).abs() / full.cycles as f64;
        assert!(err < 0.10, "extrapolated cycles off by {:.1}%", err * 100.0);
        // Detailed events are a strict subset: the run must be cheaper in
        // simulated timing work (factor > 1 implies skipped timing).
        assert!(
            r.get("sample.detailed_events").unwrap() < r.get("sample.total_events").unwrap()
        );
    }

    #[test]
    fn sampled_degenerate_window_equals_plain_run() {
        let c = cfg();
        let p = TraceParams::new(KernelId::MemCopy, Backend::Avx, 1 << 20);
        let full = simulate(&c, p).unwrap();
        let mut m = Machine::new(&c, 1).unwrap();
        let degen = m.run_sampled(vec![p.stream().unwrap()], 4096, 4096).unwrap();
        assert_eq!(degen.cycles, full.cycles);
        assert_eq!(degen.report, full.report);
        assert!(degen.report.get("sample.windows").is_none(), "no sample keys on delegation");
    }

    #[test]
    fn report_contains_core_and_memory_keys() {
        let c = cfg();
        let r = simulate(&c, TraceParams::new(KernelId::MemCopy, Backend::Avx, 1 << 20)).unwrap();
        for key in ["core.uops", "l1d.accesses", "llc.accesses", "mem.host_reads", "sim.cycles"] {
            assert!(r.report.get(key).is_some(), "missing {key}");
        }
    }
}
