//! Full-machine simulation: cores + cache hierarchy + 3D memory + the VIMA
//! and HIVE logic layers, driven by per-thread trace streams.
//!
//! The simulator is deterministic and single-threaded (like SiNUCA): cores
//! are interleaved in bounded time windows so shared resources (LLC, DRAM
//! banks, links, the VIMA FUs) observe requests in approximately global time
//! order.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::cache::MemorySystem;
use crate::config::SystemConfig;
use crate::cpu::Core;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::fabric::{FabricPort, VimaDispatcher};
use crate::hive::HiveDevice;
use crate::isa::TraceEvent;
use crate::stats::StatsReport;
use crate::trace::{TraceParams, TraceStream};
use crate::util::error::Result;

/// Process-wide count of [`Machine::run`] invocations. The sweep engine's
/// result cache exists to minimize this number; the `sweep` CLI summary and
/// the dedup tests read it.
static RUN_INVOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Total `Machine::run` calls since process start (all threads).
pub fn run_invocations() -> u64 {
    RUN_INVOCATIONS.load(Ordering::Relaxed)
}

/// Outcome of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end execution time in CPU cycles (all cores + devices drained).
    pub cycles: u64,
    /// Wall-clock seconds at the configured core frequency.
    pub seconds: f64,
    /// Total dynamic+static energy, joules.
    pub energy: EnergyBreakdown,
    /// Raw counters from every component.
    pub report: StatsReport,
}

impl SimResult {
    /// Speedup of `self` relative to a baseline run.
    ///
    /// Degenerate inputs are guarded instead of leaking `inf`/`NaN` into
    /// figure tables and geomeans: two zero-cycle runs compare as 1.0
    /// (equal), and a zero-cycle `self` against a real baseline saturates
    /// to `f64::MAX`.
    pub fn speedup_vs(&self, baseline: &SimResult) -> f64 {
        guarded_ratio(baseline.cycles as f64, self.cycles as f64)
    }

    /// Energy of `self` relative to a baseline run (1.0 = same). Zero-joule
    /// baselines are guarded like [`speedup_vs`](Self::speedup_vs): 0/0 is
    /// 1.0, and a real numerator over a zero baseline saturates to
    /// `f64::MAX` instead of returning `inf`.
    pub fn energy_ratio_vs(&self, baseline: &SimResult) -> f64 {
        guarded_ratio(self.energy.total_j, baseline.energy.total_j)
    }
}

/// `num / den` with zero-denominator guards: finite for all finite inputs
/// (0/0 → 1.0, x/0 → `f64::MAX`), untouched whenever `den > 0`.
fn guarded_ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else if num == 0.0 {
        1.0
    } else {
        f64::MAX
    }
}

/// The simulated machine.
pub struct Machine {
    pub cfg: SystemConfig,
    cores: Vec<Core>,
    pub mem: MemorySystem,
    /// One VIMA logic layer per memory cube, with home-cube routing
    /// ([`VimaDispatcher`]); a single-cube fabric behaves exactly like the
    /// old lone `VimaDevice`.
    pub vima: VimaDispatcher,
    pub hive: HiveDevice,
    /// Optional multiplier applied to the final cycle count (trace sampling
    /// extrapolation; see DESIGN.md §Sampling). Stats scale linearly too.
    scale: f64,
}

/// Interleaving window: a core may run at most this far (in cycles) past the
/// slowest core before yielding. The shared-resource model reserves
/// bandwidth with monotonic `next_free` clocks (no backfill), so cross-core
/// request disorder must stay small or later-processed cores queue behind
/// earlier-processed ones' whole timelines; 4 cycles keeps the skew small
/// relative to a DRAM round-trip (~70 cycles).
const WINDOW: u64 = 4;

impl Machine {
    /// Build a machine for `threads` cores. Invalid thread counts and
    /// invalid memory geometry (non-power-of-two vaults/banks/cubes, bad
    /// row buffers) are typed errors, not panics or silent corruption.
    pub fn new(cfg: &SystemConfig, threads: usize) -> Result<Self> {
        crate::ensure!(
            threads >= 1 && threads <= cfg.core.num_cores,
            "thread count {threads} out of range (config has {} cores)",
            cfg.core.num_cores
        );
        Ok(Self {
            cores: (0..threads).map(|i| Core::new(i, &cfg.core)).collect(),
            mem: MemorySystem::new(cfg, threads)?,
            vima: VimaDispatcher::new(
                &cfg.vima,
                cfg.mem.inst_lat_cycles,
                cfg.core.freq_ghz,
                cfg.mem.num_cubes,
            ),
            hive: HiveDevice::new(&cfg.hive, cfg.core.freq_ghz),
            scale: 1.0,
            cfg: cfg.clone(),
        })
    }

    /// Set the sampling extrapolation factor (cycles & energy multiply).
    pub fn set_scale(&mut self, scale: f64) {
        assert!(scale >= 1.0);
        self.scale = scale;
    }

    /// Number of simulated cores this machine was built for.
    pub fn threads(&self) -> usize {
        self.cores.len()
    }

    /// Process one trace event on core `c`. Returns the core-local time.
    fn step(&mut self, c: usize, ev: &TraceEvent) -> Result<u64> {
        Ok(match ev {
            TraceEvent::Uop(u) => self.cores[c].run_uop(u, &mut self.mem),
            TraceEvent::Vima(v) => {
                // Stop-and-go dispatch (Sec. III-C): the VIMA instruction
                // leaves only after everything before it has committed.
                let t = self.cores[c].drain();
                // VIMA-aware coherence: write back + invalidate host-cached
                // lines of every operand range before execution.
                let mut settle = t;
                for a in v.src_addrs() {
                    let (s, _) = self.mem.flush_range(a, v.vector_bytes as usize, t);
                    settle = settle.max(s);
                }
                if let Some(d) = v.dst() {
                    let (s, _) = self.mem.flush_range(d, v.vector_bytes as usize, t);
                    settle = settle.max(s);
                }
                let done = self.vima.execute(v, settle, &mut self.mem.mem)?;
                if self.cfg.vima.stop_and_go {
                    // Wait for the completion signal + dispatch gap.
                    self.cores[c].serialize_until(done + self.cfg.vima.dispatch_gap_cycles);
                    self.cores[c].drain()
                } else {
                    // Ablation: fire-and-forget (non-precise exceptions).
                    t
                }
            }
            TraceEvent::Hive(h) => {
                // HIVE ops are posted (non-precise): the host continues.
                // The HIVE register bank sits on the host-attached cube 0;
                // remote vectors stream through the fabric as hops.
                let t = self.cores[c].now();
                self.hive.execute(h, t, &mut FabricPort::new(&mut self.mem.mem, 0));
                t
            }
        })
    }

    /// Run one trace stream per thread to completion on the chunked
    /// execution path: each core consumes its stream's refill buffer in
    /// place through [`run_chunk_until`](Self::run_chunk_until) — no
    /// per-event `Iterator::next` round trip. Event-for-event it performs
    /// exactly the state transitions of
    /// [`run_reference`](Self::run_reference); cycle counts are
    /// bit-identical (see `tests/chunked_equivalence.rs` and DESIGN.md
    /// §Chunked execution).
    pub fn run(&mut self, traces: Vec<TraceStream>) -> Result<SimResult> {
        RUN_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
        assert_eq!(traces.len(), self.cores.len(), "one trace per core");
        let mut streams = traces;

        if streams.len() == 1 {
            // Single-core fast path: no windowing/watermark bookkeeping —
            // whole chunks execute back to back.
            let stream = &mut streams[0];
            while stream.fill() {
                let n = self.run_chunk_until(0, stream.chunk(), u64::MAX)?;
                stream.consume(n);
            }
        } else {
            self.run_interleaved(&mut streams)?;
        }
        self.finish()
    }

    /// Multi-core chunked path: interleave cores in bounded windows of
    /// simulated time. The start position rotates every round: whoever
    /// issues first in a window gets the shared resources first, and a
    /// fixed order would systematically starve the last core.
    fn run_interleaved(&mut self, streams: &mut [TraceStream]) -> Result<()> {
        let n = streams.len();
        let mut done = vec![false; n];
        let mut round = 0usize;
        while !done.iter().all(|&d| d) {
            let watermark = self
                .cores
                .iter()
                .zip(&done)
                .filter(|(_, &d)| !d)
                .map(|(c, _)| c.now())
                .min();
            let Some(watermark) = watermark else { break };
            let limit = watermark + WINDOW;
            round += 1;
            for i in 0..n {
                let c = (i + round) % n;
                if done[c] {
                    continue;
                }
                while self.cores[c].now() <= limit {
                    if !streams[c].fill() {
                        done[c] = true;
                        break;
                    }
                    let consumed = self.run_chunk_until(c, streams[c].chunk(), limit)?;
                    streams[c].consume(consumed);
                }
            }
        }
        Ok(())
    }

    /// Execute the leading events of `events` on core `c`, stopping before
    /// the first event once the core-local clock passes `limit`. Returns
    /// how many events were consumed.
    ///
    /// This is the chunked hot loop: runs of host µops dispatch through a
    /// tight per-kind inner loop with the core/memory borrows (and the
    /// enum match) hoisted out of the per-µop path; VIMA/HIVE events fall
    /// back to the general per-event `step`. The limit check happens
    /// before every event, exactly like the reference interleaver.
    pub fn run_chunk_until(
        &mut self,
        c: usize,
        events: &[TraceEvent],
        limit: u64,
    ) -> Result<usize> {
        let mut i = 0;
        while i < events.len() && self.cores[c].now() <= limit {
            if let TraceEvent::Uop(_) = events[i] {
                let core = &mut self.cores[c];
                let mem = &mut self.mem;
                while i < events.len() && core.now() <= limit {
                    let TraceEvent::Uop(u) = &events[i] else { break };
                    core.run_uop(u, mem);
                    i += 1;
                }
            } else {
                self.step(c, &events[i])?;
                i += 1;
            }
        }
        Ok(i)
    }

    /// Execute one whole chunk of events on core `c` (no time bound) —
    /// the single-core fast path, exposed for external chunk drivers.
    pub fn run_chunk(&mut self, c: usize, events: &[TraceEvent]) -> Result<()> {
        self.run_chunk_until(c, events, u64::MAX).map(|_| ())
    }

    /// Event-at-a-time reference implementation of [`run`] — the
    /// pre-chunking execution path, kept as the determinism oracle (the
    /// chunked engine must reproduce its cycle counts bit for bit) and as
    /// the baseline the `simcore` throughput benchmark reports against.
    pub fn run_reference(&mut self, traces: Vec<TraceStream>) -> Result<SimResult> {
        RUN_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
        assert_eq!(traces.len(), self.cores.len(), "one trace per core");
        let mut streams: Vec<_> = traces.into_iter().map(Some).collect();
        let mut done = vec![false; streams.len()];

        // Single-core fast path: no windowing/watermark bookkeeping needed.
        if streams.len() == 1 {
            let stream = streams[0].as_mut().expect("stream");
            let mut buf = Vec::new();
            while {
                buf.clear();
                buf.extend(stream.by_ref().take(4096));
                !buf.is_empty()
            } {
                for ev in &buf {
                    self.step(0, ev)?;
                }
            }
            done[0] = true;
        }

        // Interleave cores in bounded windows of simulated time (see
        // `run_interleaved` for the rotation rationale).
        let mut round = 0usize;
        while !done.iter().all(|&d| d) {
            let watermark = self
                .cores
                .iter()
                .zip(&done)
                .filter(|(_, &d)| !d)
                .map(|(c, _)| c.now())
                .min();
            let Some(watermark) = watermark else { break };
            let limit = watermark + WINDOW;
            round += 1;
            for i in 0..self.cores.len() {
                let c = (i + round) % self.cores.len();
                if done[c] {
                    continue;
                }
                let stream = streams[c].as_mut().expect("stream");
                while self.cores[c].now() <= limit {
                    match stream.next() {
                        Some(ev) => {
                            self.step(c, &ev)?;
                        }
                        None => {
                            done[c] = true;
                            break;
                        }
                    }
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }
        self.finish()
    }

    /// Shared run epilogue: drain devices (dirty VIMA cache lines, HIVE
    /// write-backs, posted stores, DRAM) and assemble the result.
    fn finish(&mut self) -> Result<SimResult> {
        self.mem.drain_pending();
        let core_end = self.cores.iter().map(|c| c.now()).max().unwrap_or(0);
        let vima_end = self.vima.drain(core_end, &mut self.mem.mem);
        let hive_end = self.hive.drained_at();
        if std::env::var_os("VIMA_DEBUG_SIM").is_some() {
            let ends: Vec<u64> = self.cores.iter().map(|c| c.now()).collect();
            eprintln!(
                "core_ends={ends:?} vima_end={vima_end} hive_end={hive_end} mem_drained={}",
                self.mem.mem.drained_at()
            );
        }
        let cycles_raw = core_end.max(vima_end).max(hive_end).max(self.mem.mem.drained_at());
        // Extrapolate through f64 only when a sampling scale is set, and
        // round instead of truncating: `as u64` floors, which past 2^53 (or
        // with any fractional scale) biases every scaled run downward.
        let cycles = if self.scale == 1.0 {
            cycles_raw
        } else {
            (cycles_raw as f64 * self.scale).round() as u64
        };

        let mut report = StatsReport::new();
        for core in &self.cores {
            core.dump_stats(&mut report);
        }
        self.mem.dump_stats(&mut report);
        self.vima.dump_stats(&mut report);
        self.hive.dump_stats(&mut report);
        if self.scale != 1.0 {
            // Linear extrapolation of event counters (uniform sampled work),
            // in place — no clone/rebuild of the whole report.
            report.scale_all(self.scale);
            // Hardware-count gauges don't extrapolate; restore them after
            // the blanket scaling (like the sim.* gauges set below).
            if self.cfg.mem.num_cubes > 1 {
                report.set("fabric.cubes", self.cfg.mem.num_cubes as f64);
                report.set("vima.devices", self.vima.num_devices() as f64);
            }
        }
        report.set("sim.cycles", cycles as f64);
        report.set("sim.threads", self.cores.len() as f64);
        report.set("sim.scale", self.scale);

        let energy = EnergyModel::new(&self.cfg).compute(&report, cycles, self.cores.len());
        let seconds = cycles as f64 / (self.cfg.core.freq_ghz * 1e9);
        Ok(SimResult { cycles, seconds, energy, report })
    }

    /// Reset every component for a fresh run with the same configuration.
    pub fn reset(&mut self) {
        for c in &mut self.cores {
            c.reset();
        }
        self.mem.reset();
        self.vima.reset();
        self.hive.reset();
        self.scale = 1.0;
    }
}

/// Convenience: simulate one workload end to end, honoring the thread
/// count already carried in `params` (1 for freshly built params) — so a
/// multi-threaded `RunCell::params()` simulated directly agrees with the
/// sweep result for the same cache key.
///
/// This is now a thin wrapper over the process-default
/// [`SimService`](crate::service::SimService): the job runs on its
/// long-lived worker pool, machines are pooled and reset instead of
/// rebuilt, and a repeated call is a result-cache hit. Results are
/// bit-identical to a fresh `Machine::new` + [`run_on`] (the simulator is
/// deterministic and reset ≡ fresh; see `machine_reuse_matches_fresh_runs`).
pub fn simulate(cfg: &SystemConfig, params: crate::trace::TraceParams) -> Result<SimResult> {
    simulate_threads(cfg, params, params.threads)
}

/// Simulate a data-parallel workload over an explicit `threads` override
/// (replaces whatever thread count `params` carries). Like [`simulate`],
/// a wrapper over the process-default service — invalid thread counts are
/// typed errors now, not `Machine::new` panics.
pub fn simulate_threads(
    cfg: &SystemConfig,
    params: TraceParams,
    threads: usize,
) -> Result<SimResult> {
    let mut p = params;
    p.thread = 0;
    p.threads = threads;
    crate::service::default_service()
        .submit(crate::service::Job::new(p).with_cfg(cfg.clone()))
        .wait()
}

/// Run one data-parallel workload (`params.threads` cores) on an existing
/// (fresh or just-reset) machine. This is the execution primitive the
/// [`service`](crate::service) workers call: they pool machines per
/// `(config, threads)` shape and call [`Machine::reset`] between runs
/// instead of reallocating the whole hierarchy. Callers who own a machine
/// (benchmarks, the transpile demo) use it directly.
///
/// The workload comes from the registry: its sampling-extrapolation factor
/// (DESIGN.md §Sampling) is applied, and unknown workloads / unsupported
/// backends / invalid parameters are typed errors, never panics.
pub fn run_on(machine: &mut Machine, params: TraceParams) -> Result<SimResult> {
    crate::ensure!(
        machine.threads() == params.threads,
        "machine was built for {} threads, params want {}",
        machine.threads(),
        params.threads
    );
    let workload = crate::workload::get(params.workload)?;
    // The extrapolation factor is a property of the *cell*, computed from
    // the single-thread view of the parameters (the per-thread generators
    // divide their sampling caps by the thread count themselves; see
    // matmul::sampling_for) — this keeps sweep output identical whether a
    // cell was declared threaded or not.
    machine.set_scale(workload.sampling_scale(&params.with_threads(0, 1)).max(1.0));
    let traces = (0..params.threads)
        .map(|t| params.with_threads(t, params.threads).stream())
        .collect::<Result<Vec<_>>>()?;
    machine.run(traces)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId, TraceParams};

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn vecsum_vima_beats_avx() {
        let c = cfg();
        let avx = simulate(&c, TraceParams::new(KernelId::VecSum, Backend::Avx, 3 << 20)).unwrap();
        let vima =
            simulate(&c, TraceParams::new(KernelId::VecSum, Backend::Vima, 3 << 20)).unwrap();
        let speedup = vima.speedup_vs(&avx);
        assert!(speedup > 1.5, "VecSum VIMA speedup {speedup}");
        assert!(vima.energy_ratio_vs(&avx) < 0.7, "VIMA must save energy");
    }

    #[test]
    fn memset_vima_large_speedup() {
        let c = cfg();
        let avx = simulate(&c, TraceParams::new(KernelId::MemSet, Backend::Avx, 4 << 20)).unwrap();
        let vima =
            simulate(&c, TraceParams::new(KernelId::MemSet, Backend::Vima, 4 << 20)).unwrap();
        let speedup = vima.speedup_vs(&avx);
        assert!(speedup > 4.0, "MemSet VIMA speedup {speedup}");
    }

    #[test]
    fn multithreading_speeds_up_avx() {
        let c = cfg();
        let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 3 << 20);
        let t1 = simulate_threads(&c, p, 1).unwrap();
        let t4 = simulate_threads(&c, p, 4).unwrap();
        let speedup = t1.cycles as f64 / t4.cycles as f64;
        assert!(speedup > 1.5, "4-thread speedup {speedup}");
        assert!(speedup <= 4.5);
    }

    #[test]
    fn stop_and_go_ablation_changes_time() {
        let mut c = cfg();
        let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20);
        let with = simulate(&c, p).unwrap();
        c.vima.stop_and_go = false;
        let without = simulate(&c, p).unwrap();
        assert!(
            without.cycles < with.cycles,
            "removing stop-and-go must help: {} vs {}",
            without.cycles,
            with.cycles
        );
    }

    #[test]
    fn machine_reuse_matches_fresh_runs() {
        // Reset-and-reuse (the sweep engine's fast path) must be
        // indistinguishable from a freshly allocated machine.
        let c = cfg();
        let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 1 << 20);
        let q = TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20);
        let mut m = Machine::new(&c, 1).unwrap();
        let first = run_on(&mut m, p).unwrap();
        m.reset();
        let second = run_on(&mut m, q).unwrap();
        assert_eq!(second.cycles, simulate(&c, q).unwrap().cycles);
        m.reset();
        let again = run_on(&mut m, p).unwrap();
        assert_eq!(first.cycles, again.cycles);
        assert_eq!(first.report, again.report);
    }

    #[test]
    fn deterministic_runs() {
        let c = cfg();
        let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 1 << 20);
        let a = simulate(&c, p).unwrap();
        let b = simulate(&c, p).unwrap();
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn loop_branches_predict_well_on_every_backend() {
        // Every backend's generator emits the same taken..taken,not-taken
        // loop shape, so the two-level predictor must be near perfect on
        // all of them — the HIVE streams used to skip the loop-exit branch
        // entirely, silently flattering their front-end accounting.
        let c = cfg();
        for backend in [Backend::Avx, Backend::Vima, Backend::Hive] {
            let r = simulate(&c, TraceParams::new(KernelId::MemSet, backend, 4 << 20)).unwrap();
            let branches = r.report.get("core.branches").unwrap();
            let mis = r.report.get("core.mispredicts").unwrap();
            assert!(branches > 0.0, "{backend}: no branches simulated");
            assert!(mis * 20.0 < branches, "{backend}: {mis}/{branches} mispredicts");
        }
    }

    #[test]
    fn hive_runs_and_drains() {
        let c = cfg();
        let r = simulate(&c, TraceParams::new(KernelId::VecSum, Backend::Hive, 1 << 20)).unwrap();
        assert!(r.cycles > 0);
        assert!(r.report.get("hive.transactions").unwrap() > 0.0);
    }

    #[test]
    fn ratio_guards_zero_baselines() {
        let zero = SimResult {
            cycles: 0,
            seconds: 0.0,
            energy: crate::energy::EnergyBreakdown::default(),
            report: StatsReport::new(),
        };
        let mut real = zero.clone();
        real.cycles = 1000;
        real.energy.total_j = 0.5;

        // 0/0 pins to 1.0 (equal), never NaN.
        assert_eq!(zero.speedup_vs(&zero), 1.0);
        assert_eq!(zero.energy_ratio_vs(&zero), 1.0);
        // A zero denominator saturates finite instead of returning inf.
        assert_eq!(zero.speedup_vs(&real), f64::MAX);
        assert_eq!(real.energy_ratio_vs(&zero), f64::MAX);
        // Zero numerators over real denominators are plain zero...
        assert_eq!(real.speedup_vs(&zero), 0.0);
        assert_eq!(zero.energy_ratio_vs(&real), 0.0);
        // ...and everything stays finite (geomean/max reductions survive).
        for v in [
            zero.speedup_vs(&real),
            real.speedup_vs(&zero),
            zero.energy_ratio_vs(&real),
            real.energy_ratio_vs(&zero),
        ] {
            assert!(v.is_finite(), "{v}");
        }
        // Real runs are untouched by the guard.
        let mut twice = real.clone();
        twice.cycles = 2000;
        twice.energy.total_j = 1.0;
        assert_eq!(real.speedup_vs(&twice), 2.0);
        assert_eq!(real.energy_ratio_vs(&twice), 0.5);
    }

    #[test]
    fn report_contains_core_and_memory_keys() {
        let c = cfg();
        let r = simulate(&c, TraceParams::new(KernelId::MemCopy, Backend::Avx, 1 << 20)).unwrap();
        for key in ["core.uops", "l1d.accesses", "llc.accesses", "mem.host_reads", "sim.cycles"] {
            assert!(r.report.get(key).is_some(), "missing {key}");
        }
    }
}
