//! HIVE comparator (Alves et al., *"Large vector extensions inside the HMC"*,
//! DATE 2016) — the state-of-the-art the paper compares against in Fig. 2.
//!
//! HIVE exposes an 8-entry register bank of 8 KB vectors on the logic layer.
//! Code runs as *transactions*: the register bank is locked, vectors are
//! loaded into registers, FU ops execute register-to-register, and the unlock
//! forces a **sequential** write-back of every dirty register (Sec. III-E).
//!
//! Two behavioural differences vs VIMA matter for Fig. 2's shape:
//!
//! * no stop-and-go: HIVE ops are posted, so loads for the next vectors
//!   overlap FU work (HIVE wins on pure streaming like VecSum) — at the cost
//!   of non-precise exceptions;
//! * the lock + sequential unlock write-back serializes every 8 vectors
//!   (HIVE loses on MemSet and on reuse-heavy Stencil).

use crate::config::HiveConfig;
use crate::isa::{HiveOp, VDtype, VimaFuKind};
use crate::mem3d::MemPort;
use crate::stats::StatsReport;
use crate::util::error::Result;

#[derive(Debug, Default, Clone)]
pub struct HiveStats {
    pub transactions: u64,
    pub loads: u64,
    pub stores: u64,
    pub computes: u64,
    pub lock_wait_cycles: u64,
    pub writeback_cycles: u64,
    pub busy_until: u64,
}

#[derive(Debug, Clone, Copy, Default)]
struct HiveReg {
    ready: u64,
    dirty: bool,
    addr: u64,
}

/// The HIVE device on the logic layer.
pub struct HiveDevice {
    pub cfg: HiveConfig,
    cpu_ghz: f64,
    regs: Vec<HiveReg>,
    /// Transaction state: when the current lock was released last.
    lock_free_at: u64,
    /// Outstanding lock acquisitions (multiple host threads may have
    /// transactions in flight in processing order; the bank serializes
    /// them through `lock_free_at`).
    lock_depth: u64,
    lock_acquired_at: u64,
    /// FU pipelines as in VIMA: [int_alu, int_mul, int_div, fp_alu, fp_mul, fp_div].
    fu_free: [u64; 6],
    /// Sequential write-back chain tail.
    wb_tail: u64,
    pub stats: HiveStats,
}

impl HiveDevice {
    pub fn new(cfg: &HiveConfig, cpu_ghz: f64) -> Self {
        Self {
            regs: vec![HiveReg::default(); cfg.registers],
            lock_free_at: 0,
            lock_depth: 0,
            lock_acquired_at: 0,
            fu_free: [0; 6],
            wb_tail: 0,
            cpu_ghz,
            stats: HiveStats::default(),
            cfg: cfg.clone(),
        }
    }

    fn subreqs(&self) -> u64 {
        (self.cfg.vector_bytes / 64) as u64
    }

    fn fu_index(dtype: VDtype, kind: VimaFuKind) -> usize {
        let base = if dtype.is_float() { 3 } else { 0 };
        base + match kind {
            VimaFuKind::Alu => 0,
            VimaFuKind::Mul => 1,
            VimaFuKind::Div => 2,
        }
    }

    /// HIVE uses the same FU latencies class as VIMA's array (the designs
    /// share the 256-lane datapath; HIVE just lacks the cache).
    fn fu_latency(&self, dtype: VDtype, kind: VimaFuKind) -> u64 {
        let vima_cycles = match (dtype.is_float(), kind) {
            (false, VimaFuKind::Alu) => 8,
            (false, VimaFuKind::Mul) => 12,
            (false, VimaFuKind::Div) => 28,
            (true, VimaFuKind::Alu) => 13,
            (true, VimaFuKind::Mul) => 13,
            (true, VimaFuKind::Div) => 28,
        };
        (vima_cycles as f64 * self.cpu_ghz / self.cfg.freq_ghz).ceil() as u64
    }

    /// Fetch one vector into register `r` (parallel sub-requests).
    fn load_reg(&mut self, r: usize, addr: u64, at: u64, mem: &mut impl MemPort) -> u64 {
        self.stats.loads += 1;
        let mut ready = at;
        for i in 0..self.subreqs() {
            ready = ready.max(mem.vima_access(addr + i * 64, false, at).done);
        }
        self.regs[r] = HiveReg { ready, dirty: false, addr };
        ready
    }

    /// Sequentially write register `r` back (one vector fully, then next).
    fn store_reg(&mut self, r: usize, addr: u64, at: u64, mem: &mut impl MemPort) -> u64 {
        self.stats.stores += 1;
        let start = if self.cfg.sequential_writeback {
            at.max(self.wb_tail).max(self.regs[r].ready)
        } else {
            at.max(self.regs[r].ready)
        };
        let mut done = start;
        for i in 0..self.subreqs() {
            done = done.max(mem.vima_access(addr + i * 64, true, start).done);
        }
        self.wb_tail = done;
        self.regs[r].dirty = false;
        self.stats.writeback_cycles += done - at;
        done
    }

    /// Process one HIVE op arriving at CPU-cycle `at` (posted: the host does
    /// not wait). Returns the op's internal completion time. An `Unlock`
    /// with no open lock is a typed error (a malformed trace stream), never
    /// a silently-simulated state.
    pub fn execute(&mut self, op: &HiveOp, at: u64, mem: &mut impl MemPort) -> Result<u64> {
        Ok(match *op {
            HiveOp::Lock => {
                self.stats.transactions += 1;
                let start = at.max(self.lock_free_at);
                self.stats.lock_wait_cycles += start - at;
                self.lock_acquired_at = start + self.cfg.lock_cycles;
                self.lock_depth += 1;
                self.lock_acquired_at
            }
            HiveOp::Unlock => {
                crate::ensure!(self.lock_depth > 0, "HIVE unlock without a matching lock");
                // Sequential write-back of every dirty register.
                let mut t = at.max(self.lock_acquired_at);
                for r in 0..self.regs.len() {
                    if self.regs[r].dirty {
                        let addr = self.regs[r].addr;
                        t = self.store_reg(r, addr, t, mem);
                    }
                }
                let done = t + self.cfg.unlock_cycles;
                self.lock_free_at = done;
                self.lock_depth -= 1;
                self.stats.busy_until = self.stats.busy_until.max(done);
                done
            }
            HiveOp::LoadReg { reg, addr } => {
                let start = at.max(self.lock_acquired_at);
                let done = self.load_reg(reg as usize, addr, start, mem);
                self.stats.busy_until = self.stats.busy_until.max(done);
                done
            }
            HiveOp::StoreReg { reg, addr } => {
                let start = at.max(self.lock_acquired_at);
                let done = self.store_reg(reg as usize, addr, start, mem);
                self.stats.busy_until = self.stats.busy_until.max(done);
                done
            }
            HiveOp::Compute { op, dtype, r1, r2, rd } => {
                self.stats.computes += 1;
                let deps = self.regs[r1 as usize]
                    .ready
                    .max(self.regs[r2 as usize].ready)
                    .max(self.lock_acquired_at)
                    .max(at);
                let fu = Self::fu_index(dtype, op.fu_kind());
                let start = deps.max(self.fu_free[fu]);
                let done = start + self.fu_latency(dtype, op.fu_kind());
                self.fu_free[fu] = done;
                let dst = &mut self.regs[rd as usize];
                dst.ready = done;
                dst.dirty = true;
                // dst address is bound at StoreReg/unlock time by the trace;
                // keep the last known target if any.
                self.stats.busy_until = self.stats.busy_until.max(done);
                done
            }
        })
    }

    /// Functional-phase twin of [`execute`](Self::execute): tracks the
    /// register bank's order state (dirty bits, bound addresses) and
    /// counts every 64 B DRAM sub-request through `mem`, but advances no
    /// lock/FU/write-back clock — `lock_wait_cycles`,
    /// `writeback_cycles` and `busy_until` are durations and accrue only
    /// inside detailed sample windows (DESIGN.md §11). Register `ready`
    /// times are dropped to zero (HIVE is timing-entangled, so it is
    /// excluded from the warm-up state-identity guarantee; its event
    /// counters and traffic stay exact).
    pub fn execute_functional(
        &mut self,
        op: &HiveOp,
        mut mem: impl FnMut(u64, bool),
    ) -> Result<()> {
        match *op {
            HiveOp::Lock => {
                self.stats.transactions += 1;
                self.lock_depth += 1;
            }
            HiveOp::Unlock => {
                crate::ensure!(self.lock_depth > 0, "HIVE unlock without a matching lock");
                let subs = (self.cfg.vector_bytes / 64) as u64;
                for reg in &mut self.regs {
                    if reg.dirty {
                        self.stats.stores += 1;
                        for i in 0..subs {
                            mem(reg.addr + i * 64, true);
                        }
                        reg.dirty = false;
                    }
                }
                self.lock_depth -= 1;
            }
            HiveOp::LoadReg { reg, addr } => {
                self.stats.loads += 1;
                for i in 0..self.subreqs() {
                    mem(addr + i * 64, false);
                }
                self.regs[reg as usize] = HiveReg { ready: 0, dirty: false, addr };
            }
            HiveOp::StoreReg { reg, addr } => {
                self.stats.stores += 1;
                for i in 0..self.subreqs() {
                    mem(addr + i * 64, true);
                }
                self.regs[reg as usize].dirty = false;
            }
            HiveOp::Compute { rd, .. } => {
                self.stats.computes += 1;
                self.regs[rd as usize].dirty = true;
            }
        }
        Ok(())
    }

    /// Bind the memory address a register will write back to (set by the
    /// trace generator when a compute result has a known destination).
    pub fn bind_reg_addr(&mut self, reg: u8, addr: u64) {
        self.regs[reg as usize].addr = addr;
    }

    /// All in-flight work completed.
    pub fn drained_at(&self) -> u64 {
        self.stats.busy_until.max(self.wb_tail)
    }

    pub fn dump_stats(&self, report: &mut StatsReport) {
        let s = &self.stats;
        report.add("hive.transactions", s.transactions as f64);
        report.add("hive.loads", s.loads as f64);
        report.add("hive.stores", s.stores as f64);
        report.add("hive.computes", s.computes as f64);
        report.add("hive.lock_wait_cycles", s.lock_wait_cycles as f64);
        report.add("hive.writeback_cycles", s.writeback_cycles as f64);
    }

    pub fn reset(&mut self) {
        for r in &mut self.regs {
            *r = HiveReg::default();
        }
        self.lock_free_at = 0;
        self.lock_depth = 0;
        self.lock_acquired_at = 0;
        self.fu_free = [0; 6];
        self.wb_tail = 0;
        self.stats = HiveStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mem3DConfig;
    use crate::isa::VimaOp;
    use crate::mem3d::Mem3D;

    fn setup() -> (HiveDevice, Mem3D) {
        (
            HiveDevice::new(&HiveConfig::default(), 2.0),
            Mem3D::new(&Mem3DConfig::default(), 2.0).unwrap(),
        )
    }

    #[test]
    fn lock_costs_cycles() {
        let (mut h, mut mem) = setup();
        let t = h.execute(&HiveOp::Lock, 100, &mut mem).unwrap();
        assert_eq!(t, 100 + h.cfg.lock_cycles);
    }

    #[test]
    fn loads_within_transaction_overlap() {
        let (mut h, mut mem) = setup();
        let t0 = h.execute(&HiveOp::Lock, 0, &mut mem).unwrap();
        let a = h.execute(&HiveOp::LoadReg { reg: 0, addr: 0x0000 }, t0, &mut mem).unwrap();
        let b = h.execute(&HiveOp::LoadReg { reg: 1, addr: 0x2000 }, t0, &mut mem).unwrap();
        // Issued at the same time, different vaults: near-full overlap.
        assert!(b < a + 100, "loads should overlap: {a} vs {b}");
    }

    #[test]
    fn compute_waits_for_registers() {
        let (mut h, mut mem) = setup();
        let t0 = h.execute(&HiveOp::Lock, 0, &mut mem).unwrap();
        let la = h.execute(&HiveOp::LoadReg { reg: 0, addr: 0x0000 }, t0, &mut mem).unwrap();
        let lb = h.execute(&HiveOp::LoadReg { reg: 1, addr: 0x2000 }, t0, &mut mem).unwrap();
        let c = h
            .execute(
                &HiveOp::Compute { op: VimaOp::Add, dtype: VDtype::F32, r1: 0, r2: 1, rd: 2 },
                t0,
                &mut mem,
            )
            .unwrap();
        assert!(c > la.max(lb), "compute must wait for both loads");
    }

    #[test]
    fn unlock_serializes_dirty_writebacks() {
        let (mut h, mut mem) = setup();
        let t0 = h.execute(&HiveOp::Lock, 0, &mut mem).unwrap();
        // Two dirty result registers.
        for (rd, dst) in [(2u8, 0x8000u64), (3, 0xA000)] {
            h.execute(&HiveOp::LoadReg { reg: 0, addr: 0x0000 }, t0, &mut mem).unwrap();
            h.execute(&HiveOp::LoadReg { reg: 1, addr: 0x2000 }, t0, &mut mem).unwrap();
            h.execute(
                &HiveOp::Compute { op: VimaOp::Add, dtype: VDtype::F32, r1: 0, r2: 1, rd },
                t0,
                &mut mem,
            )
            .unwrap();
            h.bind_reg_addr(rd, dst);
        }
        let writes_before = mem.stats.vima_writes;
        let t1 = h.execute(&HiveOp::Unlock, t0 + 1000, &mut mem).unwrap();
        assert_eq!(mem.stats.vima_writes - writes_before, 256);
        // Sequential: strictly more than one parallel vector writeback.
        let (h2, mut mem2) = setup();
        let mut one = 0;
        for i in 0..128u64 {
            one = one.max(mem2.vima_access(0x8000 + i * 64, true, 0).done);
        }
        let _ = h2;
        assert!(t1 - (t0 + 1000) > one, "writeback must serialize");
    }

    #[test]
    fn second_lock_waits_for_unlock() {
        let (mut h, mut mem) = setup();
        let t0 = h.execute(&HiveOp::Lock, 0, &mut mem).unwrap();
        let t1 = h.execute(&HiveOp::Unlock, t0 + 10, &mut mem).unwrap();
        let t2 = h.execute(&HiveOp::Lock, 5, &mut mem).unwrap(); // arrives "early"
        assert!(t2 >= t1, "lock must wait for previous unlock");
        assert!(h.stats.lock_wait_cycles > 0);
    }

    #[test]
    fn explicit_store_reg_writes_memory() {
        let (mut h, mut mem) = setup();
        let t0 = h.execute(&HiveOp::Lock, 0, &mut mem).unwrap();
        h.execute(&HiveOp::LoadReg { reg: 0, addr: 0x0000 }, t0, &mut mem).unwrap();
        let w = mem.stats.vima_writes;
        h.execute(&HiveOp::StoreReg { reg: 0, addr: 0x4000 }, t0, &mut mem).unwrap();
        assert_eq!(mem.stats.vima_writes - w, 128);
    }

    #[test]
    fn unlock_without_lock_is_a_typed_error() {
        let (mut h, mut mem) = setup();
        let err = h.execute(&HiveOp::Unlock, 0, &mut mem).unwrap_err();
        assert!(err.to_string().contains("unlock"), "{err}");
        // A proper lock/unlock pair still works afterwards.
        let t0 = h.execute(&HiveOp::Lock, 0, &mut mem).unwrap();
        assert!(h.execute(&HiveOp::Unlock, t0, &mut mem).is_ok());
    }

    #[test]
    fn functional_unlock_without_lock_is_a_typed_error() {
        let (mut h, _mem) = setup();
        let err = h.execute_functional(&HiveOp::Unlock, |_, _| {}).unwrap_err();
        assert!(err.to_string().contains("unlock"), "{err}");
        h.execute_functional(&HiveOp::Lock, |_, _| {}).unwrap();
        h.execute_functional(&HiveOp::Unlock, |_, _| {}).unwrap();
    }
}
