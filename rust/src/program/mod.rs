//! The `.vpr` serialized program format — closing the compiler loop.
//!
//! The paper's pitch (Sec. III/VI) is an *easy programming interface*:
//! ordinary code emits VIMA instructions through an intrinsics library.
//! This module gives that interface a wire format, so programs can reach
//! the simulator without a Rust toolchain in the loop:
//!
//! * **emit** — [`VimaProgram::to_vpr`] serializes any Intrinsics-VIMA
//!   program (allocations, nested `vloop`s, strided operands, host loads)
//!   to a line-oriented text file;
//! * **parse** — [`parse`] reads it back into a [`VimaProgram`] that lowers
//!   to event streams *bit-identical* to the original DSL construction, on
//!   both the VIMA and honest-AVX backends (pinned by
//!   `tests/program_format.rs`). Every malformed input is a typed
//!   [`util::error`](crate::util::error) result carrying line/column
//!   context, never a panic;
//! * **load** — [`load_file`]/[`load_dir`] register parsed programs in the
//!   [`workload`] registry, after which they are first-class workloads:
//!   runnable (`vima-sim run prog.vpr`), servable by name over JSONL
//!   (`vima-sim serve --load DIR`), sweepable with result-cache dedup, and
//!   listed by `vima-sim workloads` as kind "loaded .vpr".
//!
//! `python/compile/vpr.py` is the other end of the bridge: it lowers the
//! `python/compile/kernels/` entry points straight to this format (the
//! committed goldens live in `examples/programs/`), so a kernel authored
//! against the Pallas model runs in the simulator with no JAX/XLA at
//! runtime. Grammar reference: DESIGN.md §12.
//!
//! # Format sketch
//!
//! ```text
//! # comments run to end of line; blank lines are ignored
//! vpr 1                      # magic + version, first significant line
//! name saxpy-vpr             # optional registry name
//! desc y = a*x + y           # optional one-line description
//! vector_bytes 8192          # power of two >= 64 (default 8192)
//! footprint 4202496          # optional cross-check vs the allocs
//! loop_overhead on           # on (default) | off
//! alloc alpha 8192           # name + bytes (vector-aligned up)
//! alloc x 2097152
//! alloc y 2097152
//! vim2k_sets -> alpha        # broadcast: no sources
//! vloop 256                  # 256 iterations; loops nest
//!   vim2k_fmadds alpha x:8192 y:8192 -> y:8192
//! end
//! ```
//!
//! Operands are `NAME[+OFFSET][:STRIDE]` (bytes, decimal or `0x...` hex):
//! the offset addresses into the named allocation, the stride is the
//! per-iteration advance of the innermost enclosing `vloop` — exactly
//! [`VecPtr::walk`](crate::intrinsics::VecPtr::walk). Mnemonics outside
//! the Intrinsics-VIMA surface use the generic form
//! `vop <op> <dtype> srcs... [-> dst]` (e.g. `vop max f32 a b -> c`).

use std::path::Path;
use std::sync::{Arc, OnceLock};

use crate::intrinsics::{Alloc, Operand, Stmt, VimaProgram, HEAP_BASE};
use crate::isa::{VDtype, VimaOp};
use crate::util::error::{Context as _, Error, Result};
use crate::workload::{self, ProgramWorkload, WorkloadId, WorkloadKind};

/// Bidirectional mnemonic table: the Intrinsics-VIMA surface of
/// [`VimaProgram`] <-> `.vpr` statement keywords. Combinations outside
/// this table round-trip through the generic `vop <op> <dtype>` form.
const MNEMONICS: [(&str, VimaOp, VDtype); 11] = [
    ("vim2k_adds", VimaOp::Add, VDtype::F32),
    ("vim2k_subs", VimaOp::Sub, VDtype::F32),
    ("vim2k_muls", VimaOp::Mul, VDtype::F32),
    ("vim2k_divs", VimaOp::Div, VDtype::F32),
    ("vim2k_fmadds", VimaOp::Fma, VDtype::F32),
    ("vim2k_movs", VimaOp::Mov, VDtype::I32),
    ("vim2k_sets", VimaOp::Bcast, VDtype::F32),
    ("vim2k_dots", VimaOp::Dot, VDtype::F32),
    ("vim2k_addu", VimaOp::Add, VDtype::I32),
    ("vim2k_andu", VimaOp::And, VDtype::I32),
    ("vim1k_addd", VimaOp::Add, VDtype::F64),
];

/// `vop` opcode spellings, one per [`VimaOp`] variant.
const OP_NAMES: [(&str, VimaOp); 14] = [
    ("add", VimaOp::Add),
    ("sub", VimaOp::Sub),
    ("mul", VimaOp::Mul),
    ("div", VimaOp::Div),
    ("min", VimaOp::Min),
    ("max", VimaOp::Max),
    ("and", VimaOp::And),
    ("or", VimaOp::Or),
    ("xor", VimaOp::Xor),
    ("fma", VimaOp::Fma),
    ("mov", VimaOp::Mov),
    ("bcast", VimaOp::Bcast),
    ("dot", VimaOp::Dot),
    ("redsum", VimaOp::RedSum),
];

const DTYPE_NAMES: [(&str, VDtype); 4] = [
    ("i32", VDtype::I32),
    ("i64", VDtype::I64),
    ("f32", VDtype::F32),
    ("f64", VDtype::F64),
];

fn op_name(op: VimaOp) -> &'static str {
    OP_NAMES.iter().find(|(_, o)| *o == op).map(|(n, _)| *n).expect("every VimaOp is named")
}

fn dtype_name(d: VDtype) -> &'static str {
    DTYPE_NAMES.iter().find(|(_, t)| *t == d).map(|(n, _)| *n).expect("every VDtype is named")
}

// ---------------------------------------------------------------- emitter

impl VimaProgram {
    /// Serialize this program to `.vpr` text under `name` (becomes the
    /// file's `name` directive; pass `""` to omit it). Errors if an
    /// operand points outside every allocation, or if a loop carries a
    /// nonzero start iteration (i.e. the program is a per-thread slice —
    /// serialize the original, not a slice).
    pub fn to_vpr(&self, name: &str) -> Result<String> {
        let mut out = String::new();
        out.push_str("vpr 1\n");
        if !name.is_empty() {
            out.push_str(&format!("name {name}\n"));
        }
        out.push_str(&format!("vector_bytes {}\n", self.vector_bytes));
        out.push_str(&format!("footprint {}\n", self.footprint()));
        out.push_str(&format!(
            "loop_overhead {}\n",
            if self.loop_overhead { "on" } else { "off" }
        ));
        for (i, a) in self.allocs.iter().enumerate() {
            out.push_str(&format!("alloc v{i} {}\n", a.size));
        }
        emit_stmts(&mut out, &self.stmts, &self.allocs, 0)?;
        Ok(out)
    }
}

fn emit_stmts(out: &mut String, stmts: &[Stmt], allocs: &[Alloc], depth: usize) -> Result<()> {
    let pad = "  ".repeat(depth);
    for s in stmts {
        match s {
            Stmt::Instr { op, dtype, srcs, dst } => {
                let mut line =
                    match MNEMONICS.iter().find(|(_, o, d)| o == op && d == dtype) {
                        Some((m, _, _)) => (*m).to_string(),
                        None => format!("vop {} {}", op_name(*op), dtype_name(*dtype)),
                    };
                for src in srcs {
                    line.push(' ');
                    line.push_str(&operand_text(src, allocs)?);
                }
                if let Some(d) = dst {
                    line.push_str(" -> ");
                    line.push_str(&operand_text(d, allocs)?);
                }
                out.push_str(&format!("{pad}{line}\n"));
            }
            Stmt::HostLoad { addr, bytes } => {
                out.push_str(&format!(
                    "{pad}host_load {} {bytes}\n",
                    operand_text(addr, allocs)?
                ));
            }
            Stmt::Loop { start, end, body } => {
                crate::ensure!(
                    *start == 0,
                    "cannot serialize a thread-sliced loop (iterations {start}..{end}); \
                     emit .vpr from the original program, not a per-thread slice"
                );
                out.push_str(&format!("{pad}vloop {end}\n"));
                emit_stmts(out, body, allocs, depth + 1)?;
                out.push_str(&format!("{pad}end\n"));
            }
        }
    }
    Ok(())
}

/// Render an operand as `vN[+off][:stride]` by locating the allocation
/// containing its base address.
fn operand_text(o: &Operand, allocs: &[Alloc]) -> Result<String> {
    let (idx, a) = allocs
        .iter()
        .enumerate()
        .find(|(_, a)| o.base >= a.base && o.base < a.base + a.size)
        .with_context(|| {
            format!("operand address {:#x} is not inside any allocation", o.base)
        })?;
    let mut s = format!("v{idx}");
    let off = o.base - a.base;
    if off > 0 {
        s.push_str(&format!("+{off}"));
    }
    if o.stride > 0 {
        s.push_str(&format!(":{}", o.stride));
    }
    Ok(s)
}

// ----------------------------------------------------------------- parser

/// A parsed `.vpr` file: the optional header identity plus the program.
#[derive(Debug, Clone)]
pub struct ParsedVpr {
    /// `name` header directive (the registration name), if present.
    pub name: Option<String>,
    /// `desc` header directive, if present.
    pub description: Option<String>,
    /// The reconstructed program; lowers bit-identically to the DSL
    /// construction it was emitted from.
    pub program: VimaProgram,
    /// Statement spans and allocation names for the static analyzer
    /// ([`crate::analyze`]), so diagnostics point at real lines/columns.
    pub source: crate::analyze::SourceInfo,
}

/// Typed parse error with line/column context.
fn perr<T>(line: usize, col: usize, msg: impl std::fmt::Display) -> Result<T> {
    Err(Error::msg(format!("line {line}, col {col}: {msg}")))
}

/// Split a line into (1-based column, token) pairs.
fn tokenize(line: &str) -> Vec<(usize, &str)> {
    let mut toks = Vec::new();
    let mut start: Option<usize> = None;
    for (i, c) in line.char_indices() {
        if c.is_whitespace() {
            if let Some(s) = start.take() {
                toks.push((s + 1, &line[s..i]));
            }
        } else if start.is_none() {
            start = Some(i);
        }
    }
    if let Some(s) = start {
        toks.push((s + 1, &line[s..]));
    }
    toks
}

/// Unsigned byte/count literal: decimal or `0x` hex, `_` separators ok.
fn parse_num(s: &str) -> Option<u64> {
    let digits = s.replace('_', "");
    match digits.strip_prefix("0x").or_else(|| digits.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => digits.parse().ok(),
    }
}

/// One open parse frame: the innermost `vloop` being filled (`iters`, the
/// line that opened it, and its statements so far). Frame 0 is the top
/// level; its `iters`/line are unused. `spans` mirrors `stmts` one-to-one
/// for the analyzer.
struct Frame {
    iters: u64,
    opened_at: usize,
    opened_span: crate::analyze::Span,
    stmts: Vec<Stmt>,
    spans: Vec<crate::analyze::SpanNode>,
}

/// Parse `.vpr` text into a [`ParsedVpr`]. Every failure is a typed error
/// naming the offending line (and column where it helps); the reconstructed
/// program's event streams are bit-identical to the DSL construction the
/// text was emitted from.
pub fn parse(src: &str) -> Result<ParsedVpr> {
    let mut name: Option<String> = None;
    let mut description: Option<String> = None;
    let mut vector_bytes: u32 = 8192;
    let mut vb_seen = false;
    let mut footprint_decl: Option<u64> = None;
    let mut loop_overhead = true;
    let mut allocs: Vec<(String, Alloc)> = Vec::new();
    let mut heap = HEAP_BASE;
    let mut saw_magic = false;
    let mut body_started = false;
    let mut vb_span = crate::analyze::Span::UNKNOWN;
    let mut stack = vec![Frame {
        iters: 0,
        opened_at: 0,
        opened_span: crate::analyze::Span::UNKNOWN,
        stmts: Vec::new(),
        spans: Vec::new(),
    }];

    for (idx, raw) in src.lines().enumerate() {
        let lno = idx + 1;
        let line = raw.split('#').next().unwrap_or("");
        let toks = tokenize(line);
        let Some(&(col0, kw)) = toks.first() else { continue };
        if !saw_magic {
            if kw != "vpr" {
                return perr(
                    lno,
                    col0,
                    "expected the `vpr 1` magic header on the first significant line",
                );
            }
            let Some(&(_, ver)) = toks.get(1) else {
                return perr(lno, col0, "expected a version after `vpr`");
            };
            if ver != "1" {
                return perr(
                    lno,
                    toks[1].0,
                    format!("unsupported .vpr version `{ver}` (this build reads version 1)"),
                );
            }
            saw_magic = true;
            continue;
        }
        let in_header = !body_started && allocs.is_empty();
        match kw {
            "name" | "desc" | "vector_bytes" | "footprint" | "loop_overhead"
                if !in_header =>
            {
                return perr(
                    lno,
                    col0,
                    format!("`{kw}` must appear in the header, before any alloc or statement"),
                );
            }
            "name" => {
                if toks.len() != 2 {
                    return perr(lno, col0, "`name` takes exactly one value");
                }
                if name.is_some() {
                    return perr(lno, col0, "duplicate `name` directive");
                }
                name = Some(toks[1].1.to_string());
            }
            "desc" => {
                if toks.len() < 2 {
                    return perr(lno, col0, "`desc` needs a description text");
                }
                let text: Vec<&str> = toks[1..].iter().map(|&(_, t)| t).collect();
                description = Some(text.join(" "));
            }
            "vector_bytes" => {
                if vb_seen {
                    return perr(lno, col0, "duplicate `vector_bytes` directive");
                }
                let Some(v) = toks.get(1).and_then(|&(_, t)| parse_num(t)) else {
                    return perr(lno, col0, "`vector_bytes` needs a byte count");
                };
                if v < 64 || !v.is_power_of_two() || v > u64::from(u32::MAX) {
                    return perr(
                        lno,
                        toks[1].0,
                        format!("vector_bytes must be a power of two >= 64 (got {v})"),
                    );
                }
                vector_bytes = v as u32;
                vb_seen = true;
                vb_span = crate::analyze::Span::new(lno as u32, col0 as u32);
            }
            "footprint" => {
                let Some(v) = toks.get(1).and_then(|&(_, t)| parse_num(t)) else {
                    return perr(lno, col0, "`footprint` needs a byte count");
                };
                footprint_decl = Some(v);
            }
            "loop_overhead" => {
                loop_overhead = match toks.get(1).map(|&(_, t)| t) {
                    Some("on") => true,
                    Some("off") => false,
                    _ => return perr(lno, col0, "`loop_overhead` must be `on` or `off`"),
                };
            }
            "alloc" => {
                if stack.len() > 1 {
                    return perr(lno, col0, "alloc is not allowed inside a vloop");
                }
                if body_started {
                    return perr(lno, col0, "alloc must precede all statements");
                }
                if toks.len() != 3 {
                    return perr(lno, col0, "alloc takes a name and a byte count");
                }
                let (ncol, aname) = toks[1];
                let mut chars = aname.chars();
                let head_ok =
                    chars.next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_');
                let rest_ok =
                    chars.all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '.' | '-'));
                if !head_ok || !rest_ok {
                    return perr(
                        lno,
                        ncol,
                        format!(
                            "bad allocation name `{aname}` (letters, digits, `_`, `.`, `-`; \
                             must start with a letter or `_`)"
                        ),
                    );
                }
                if allocs.iter().any(|(n, _)| n == aname) {
                    return perr(lno, ncol, format!("duplicate allocation name `{aname}`"));
                }
                let Some(bytes) = parse_num(toks[2].1) else {
                    return perr(lno, toks[2].0, "alloc needs a byte count");
                };
                let vb = u64::from(vector_bytes);
                let aligned = bytes
                    .div_ceil(vb)
                    .checked_mul(vb)
                    .and_then(|sz| heap.checked_add(sz).map(|_| sz));
                let Some(size) = aligned else {
                    return perr(
                        lno,
                        toks[2].0,
                        "allocation overflows the simulated address space",
                    );
                };
                allocs.push((aname.to_string(), Alloc { base: heap, size }));
                heap += size;
            }
            "vloop" => {
                body_started = true;
                let Some(iters) = toks.get(1).and_then(|&(_, t)| parse_num(t)) else {
                    return perr(lno, col0, "vloop needs an iteration count");
                };
                stack.push(Frame {
                    iters,
                    opened_at: lno,
                    opened_span: crate::analyze::Span::new(lno as u32, col0 as u32),
                    stmts: Vec::new(),
                    spans: Vec::new(),
                });
            }
            "end" => {
                if stack.len() == 1 {
                    return perr(lno, col0, "`end` with no open vloop");
                }
                let frame = stack.pop().expect("stack holds at least the open frame");
                let top = stack.last_mut().expect("top-level frame is never popped");
                top.stmts.push(Stmt::Loop {
                    start: 0,
                    end: frame.iters,
                    body: frame.stmts,
                });
                top.spans.push(crate::analyze::SpanNode::Loop(frame.opened_span, frame.spans));
            }
            _ => {
                body_started = true;
                let inner_iters = (stack.len() > 1)
                    .then(|| stack.last().expect("non-empty stack").iters);
                let stmt =
                    parse_stmt(&toks, lno, &allocs, heap, vector_bytes, inner_iters)?;
                let top = stack.last_mut().expect("non-empty stack");
                top.stmts.push(stmt);
                top.spans.push(crate::analyze::SpanNode::Leaf(crate::analyze::Span::new(
                    lno as u32, col0 as u32,
                )));
            }
        }
    }

    if stack.len() > 1 {
        let opened = stack.last().expect("open frame").opened_at;
        return Err(Error::msg(format!(
            "line {opened}: this vloop is never closed (missing `end` before end of file)"
        )));
    }
    crate::ensure!(saw_magic, "empty .vpr input: expected the `vpr 1` magic header");
    let top = stack.pop().expect("top-level frame");
    let (stmts, spans) = (top.stmts, top.spans);
    crate::ensure!(!stmts.is_empty(), "program has no statements");
    let footprint = heap - HEAP_BASE;
    if let Some(decl) = footprint_decl {
        crate::ensure!(
            decl == footprint,
            "header declares footprint {decl} but the allocations total {footprint} bytes"
        );
    }
    let program = VimaProgram {
        stmts,
        allocs: allocs.iter().map(|(_, a)| *a).collect(),
        heap,
        vector_bytes,
        loop_overhead,
    };
    let source = crate::analyze::SourceInfo {
        spans,
        alloc_names: allocs.iter().map(|(n, _)| n.clone()).collect(),
        vb_span,
    };
    Ok(ParsedVpr { name, description, program, source })
}

/// Parse one statement line (an intrinsic mnemonic, `vop`, or `host_load`).
fn parse_stmt(
    toks: &[(usize, &str)],
    lno: usize,
    allocs: &[(String, Alloc)],
    heap: u64,
    vector_bytes: u32,
    inner_iters: Option<u64>,
) -> Result<Stmt> {
    let (col0, kw) = toks[0];
    if kw == "host_load" {
        if toks.len() != 3 {
            return perr(lno, col0, "host_load takes an operand and a byte count");
        }
        let bytes = match parse_num(toks[2].1) {
            Some(b) if (1..=u64::from(u16::MAX)).contains(&b) => b,
            _ => return perr(lno, toks[2].0, "host_load byte count must be 1..=65535"),
        };
        let addr = parse_operand(toks[1], lno, allocs, heap, bytes, inner_iters)?;
        return Ok(Stmt::HostLoad { addr, bytes: bytes as u16 });
    }
    let (op, dtype, operand_start) = if kw == "vop" {
        if toks.len() < 3 {
            return perr(lno, col0, "vop takes `<op> <dtype>` then operands");
        }
        let Some(&(_, op)) = OP_NAMES.iter().find(|(n, _)| *n == toks[1].1) else {
            let valid: Vec<&str> = OP_NAMES.iter().map(|&(n, _)| n).collect();
            return perr(
                lno,
                toks[1].0,
                format!("unknown vector op `{}` (valid: {})", toks[1].1, valid.join(", ")),
            );
        };
        let Some(&(_, dtype)) = DTYPE_NAMES.iter().find(|(n, _)| *n == toks[2].1) else {
            return perr(
                lno,
                toks[2].0,
                format!("unknown dtype `{}` (valid: i32, i64, f32, f64)", toks[2].1),
            );
        };
        (op, dtype, 3)
    } else if let Some(&(_, op, dtype)) = MNEMONICS.iter().find(|(m, _, _)| *m == kw) {
        (op, dtype, 1)
    } else {
        return perr(
            lno,
            col0,
            format!(
                "unknown statement `{kw}` (expected an intrinsic like vim2k_adds, or \
                 vop / host_load / vloop / end / alloc)"
            ),
        );
    };
    let rest = &toks[operand_start..];
    let (src_toks, dst_tok) = match rest.iter().position(|&(_, t)| t == "->") {
        Some(i) => {
            if rest.len() != i + 2 {
                let col = rest.get(i + 2).map_or(rest[i].0, |&(c, _)| c);
                return perr(lno, col, "expected exactly one destination operand after `->`");
            }
            (&rest[..i], Some(rest[i + 1]))
        }
        None => (rest, None),
    };
    if src_toks.len() != op.num_srcs() {
        return perr(
            lno,
            col0,
            format!("`{kw}` expects {} source operand(s), got {}", op.num_srcs(), src_toks.len()),
        );
    }
    if op.writes_vector() && dst_tok.is_none() {
        return perr(lno, col0, format!("`{kw}` requires a destination (`-> dst`)"));
    }
    if let (false, Some((dcol, _))) = (op.writes_vector(), dst_tok) {
        return perr(
            lno,
            dcol,
            format!("`{kw}` reduces to a scalar and takes no `-> dst`"),
        );
    }
    let vb = u64::from(vector_bytes);
    let srcs = src_toks
        .iter()
        .map(|&t| parse_operand(t, lno, allocs, heap, vb, inner_iters))
        .collect::<Result<Vec<_>>>()?;
    let dst = dst_tok
        .map(|t| parse_operand(t, lno, allocs, heap, vb, inner_iters))
        .transpose()?;
    Ok(Stmt::Instr { op, dtype, srcs, dst })
}

/// Parse `NAME[+OFFSET][:STRIDE]` and bounds-check it: the base must lie
/// inside the named allocation, and the farthest byte the operand touches
/// across the innermost loop (`base + (iters-1)*stride + extent`) must stay
/// inside the program footprint.
fn parse_operand(
    (col, tok): (usize, &str),
    lno: usize,
    allocs: &[(String, Alloc)],
    heap: u64,
    extent: u64,
    inner_iters: Option<u64>,
) -> Result<Operand> {
    let (head, stride) = match tok.split_once(':') {
        Some((h, s)) => match parse_num(s) {
            Some(n) => (h, n),
            None => return perr(lno, col, format!("bad stride in operand `{tok}`")),
        },
        None => (tok, 0),
    };
    let (base_name, off) = match head.split_once('+') {
        Some((n, o)) => match parse_num(o) {
            Some(v) => (n, v),
            None => return perr(lno, col, format!("bad offset in operand `{tok}`")),
        },
        None => (head, 0),
    };
    let Some((_, a)) = allocs.iter().find(|(n, _)| n == base_name) else {
        return perr(lno, col, format!("unknown allocation `{base_name}` in operand `{tok}`"));
    };
    if off >= a.size {
        return perr(
            lno,
            col,
            format!("offset {off} is outside allocation `{base_name}` ({} bytes)", a.size),
        );
    }
    let base = a.base + off;
    let span = match inner_iters {
        Some(n) if stride > 0 => n.saturating_sub(1),
        _ => 0,
    };
    let reach = span
        .checked_mul(stride)
        .and_then(|x| x.checked_add(base))
        .and_then(|x| x.checked_add(extent));
    match reach {
        Some(r) if r <= heap => Ok(Operand { base, stride }),
        Some(r) => perr(
            lno,
            col,
            format!(
                "out-of-footprint operand `{tok}`: reaches {} bytes past the end of the \
                 program's allocations",
                r - heap
            ),
        ),
        None => perr(lno, col, format!("out-of-footprint operand `{tok}`: address overflow")),
    }
}

// ----------------------------------------------------------------- loader

/// Parse `src` and register the program as a loaded-`.vpr` workload. The
/// registered name is the file's `name` directive when present, else
/// `fallback_name`. Re-registering a taken name is a clean "already
/// registered" error from the registry, never a panic.
///
/// The static analyzer ([`crate::analyze`]) gates registration: a program
/// with error-severity diagnostics is rejected here, before it can reach a
/// simulator — the load-time half of the precise-exception story. The gate
/// analyzes against a default machine widened to the program's own vector
/// size, so only machine-independent defects (uninitialized reads, partial
/// overlaps) reject; machine-fit lints belong to `vima-sim check`, which
/// uses the session's real configuration. Warnings and infos stay attached
/// to the registered workload via [`Workload::analyze`].
///
/// [`Workload::analyze`]: crate::workload::Workload::analyze
pub fn load_str(src: &str, fallback_name: &str) -> Result<WorkloadId> {
    let parsed = parse(src)?;
    let mut cfg = crate::config::SystemConfig::default();
    cfg.vima.vector_bytes = cfg.vima.vector_bytes.max(parsed.program.vector_bytes() as usize);
    let report = crate::analyze::analyze_parsed(&parsed, &cfg);
    if let Some(err) = report.first_error() {
        let name = parsed.name.as_deref().unwrap_or(fallback_name);
        return Err(Error::msg(format!("program rejected by check: {}", err.render(name))));
    }
    let name = parsed.name.unwrap_or_else(|| fallback_name.to_string());
    crate::ensure!(!name.is_empty(), "program has no `name` directive and no fallback name");
    let desc = parsed.description.unwrap_or_else(|| "loaded .vpr program".to_string());
    workload::register(Arc::new(
        ProgramWorkload::new(name, parsed.program)
            .with_description(desc)
            .with_kind(WorkloadKind::LoadedVpr)
            .with_source_info(parsed.source),
    ))
}

/// Load and register one `.vpr` file; the registered name defaults to the
/// file stem when the file has no `name` directive.
pub fn load_file(path: impl AsRef<Path>) -> Result<WorkloadId> {
    let path = path.as_ref();
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let stem =
        path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    load_str(&src, &stem).with_context(|| path.display().to_string())
}

/// Load every `.vpr` file in `dir` (sorted by path, so registration order
/// is deterministic). Errors if the directory holds none.
pub fn load_dir(dir: impl AsRef<Path>) -> Result<Vec<WorkloadId>> {
    let dir = dir.as_ref();
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "vpr"))
        .collect();
    paths.sort();
    crate::ensure!(!paths.is_empty(), "no .vpr files in {}", dir.display());
    paths.iter().map(load_file).collect()
}

/// Load a single `.vpr` file or every `.vpr` in a directory — the CLI
/// `--load PATH` flag.
pub fn load_path(path: impl AsRef<Path>) -> Result<Vec<WorkloadId>> {
    let path = path.as_ref();
    if path.is_dir() {
        load_dir(path)
    } else {
        Ok(vec![load_file(path)?])
    }
}

/// The bench-matrix program cell: `saxpy` round-tripped through the text
/// format (emit -> parse -> register), so `vima-sim bench` tracks the
/// parse-then-`ProgramChunker` path's throughput alongside the native
/// generators. Registered once per process as `saxpy-vpr-bench`.
pub fn bench_workload() -> Result<WorkloadId> {
    static ID: OnceLock<Result<WorkloadId, String>> = OnceLock::new();
    ID.get_or_init(|| {
        let build = || -> Result<WorkloadId> {
            let text = crate::workload::programs::saxpy(1024).to_vpr("saxpy-vpr-bench")?;
            load_str(&text, "saxpy-vpr-bench")
        };
        build().map_err(|e| e.to_string())
    })
    .clone()
    .map_err(Error::msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Backend;
    use crate::workload::programs::{saxpy, softmax};

    #[test]
    fn tokenizer_reports_columns() {
        let toks = tokenize("  vloop 16 ");
        assert_eq!(toks, vec![(3, "vloop"), (9, "16")]);
        assert!(tokenize("").is_empty());
    }

    #[test]
    fn numbers_parse_decimal_hex_and_separators() {
        assert_eq!(parse_num("8192"), Some(8192));
        assert_eq!(parse_num("0x2000"), Some(8192));
        assert_eq!(parse_num("8_192"), Some(8192));
        assert_eq!(parse_num("nope"), None);
    }

    #[test]
    fn every_mnemonic_round_trips() {
        for (m, op, dtype) in MNEMONICS {
            let mut p = VimaProgram::new();
            let a = p.alloc(8192);
            let b = p.alloc(8192);
            let c = p.alloc(8192);
            match op.num_srcs() {
                0 => p.vim2k_sets(c),
                1 => p.vim2k_movs(a, c),
                3 => p.vim2k_fmadds(a, b, c, c),
                _ if op.writes_vector() => {
                    // Reuse the statement shape through the parser's own
                    // generic path below; here push via the text form.
                    let text = format!(
                        "vpr 1\nvector_bytes 8192\nalloc a 8192\nalloc b 8192\n\
                         alloc c 8192\n{m} a b -> c\n"
                    );
                    let rt = parse(&text).unwrap();
                    assert_eq!(rt.program.to_vpr("").unwrap().matches(m).count(), 1);
                    continue;
                }
                _ => p.vim2k_dots(a, b),
            }
            let text = p.to_vpr("t").unwrap();
            let rt = parse(&text).unwrap();
            assert_eq!(
                rt.program.build_for(Backend::Vima).unwrap(),
                p.build_for(Backend::Vima).unwrap(),
                "{m}: round-trip must be bit-identical ({op:?} {dtype:?})"
            );
        }
    }

    #[test]
    fn builtin_programs_round_trip_bit_identically() {
        for (p, name) in [(saxpy(64), "s1"), (softmax(32), "s2")] {
            let text = p.to_vpr(name).unwrap();
            let rt = parse(&text).unwrap();
            assert_eq!(rt.name.as_deref(), Some(name));
            for backend in [Backend::Vima, Backend::Avx] {
                assert_eq!(
                    rt.program.build_for(backend).unwrap(),
                    p.build_for(backend).unwrap(),
                    "{name}/{backend}"
                );
            }
        }
    }

    #[test]
    fn generic_vop_form_round_trips() {
        let text = "vpr 1\nvector_bytes 8192\nalloc a 8192\nalloc z 8192\n\
                    vop max f32 a z -> a\nvop redsum f32 a\n";
        let rt = parse(text).unwrap();
        let emitted = rt.program.to_vpr("").unwrap();
        assert!(emitted.contains("vop max f32"), "{emitted}");
        assert!(emitted.contains("vop redsum f32"), "{emitted}");
        let rt2 = parse(&emitted).unwrap();
        assert_eq!(
            rt2.program.build_for(Backend::Vima).unwrap(),
            rt.program.build_for(Backend::Vima).unwrap()
        );
    }

    #[test]
    fn parse_errors_name_the_line() {
        let unclosed = "vpr 1\nalloc a 8192\nvloop 4\nvim2k_movs a -> a\n";
        let e = parse(unclosed).unwrap_err().to_string();
        assert!(e.contains("line 3"), "{e}");
        let stray = "vpr 1\nalloc a 8192\nvim2k_movs a -> a\nend\n";
        let e = parse(stray).unwrap_err().to_string();
        assert!(e.contains("line 4") && e.contains("no open vloop"), "{e}");
        let oob = "vpr 1\nalloc a 8192\nvloop 4\nvim2k_movs a:8192 -> a\nend\n";
        let e = parse(oob).unwrap_err().to_string();
        assert!(e.contains("line 4") && e.contains("out-of-footprint"), "{e}");
    }

    #[test]
    fn loader_registers_and_rejects_duplicates() {
        let text = saxpy(4).to_vpr("ut-vpr-loaded").unwrap();
        let id = load_str(&text, "unused-fallback").unwrap();
        assert_eq!(workload::name(id), "ut-vpr-loaded");
        assert_eq!(workload::get(id).unwrap().kind(), WorkloadKind::LoadedVpr);
        let e = load_str(&text, "unused-fallback").unwrap_err().to_string();
        assert!(e.contains("already registered"), "{e}");
    }

    #[test]
    fn bench_workload_is_idempotent() {
        let a = bench_workload().unwrap();
        let b = bench_workload().unwrap();
        assert_eq!(a, b);
        assert_eq!(workload::name(a), "saxpy-vpr-bench");
    }
}
