//! Intrinsics-VIMA programs as first-class workloads.
//!
//! [`ProgramWorkload`] adapts a [`VimaProgram`] (the streaming DSL) to the
//! [`Workload`] trait: the program lowers to VIMA *and* to an honest AVX
//! baseline, slices its top-level loops across data-parallel threads, and
//! carries a fixed footprint (its allocations) as its cache identity.
//!
//! Two example programs ship registered — proof that the registry opens
//! workloads beyond the paper's seven without touching the simulator:
//!
//! * **saxpy** — `y = a*x + y`, the classic streaming kernel: one fused
//!   multiply-add per vector, with the broadcast `a` vector staying
//!   resident in the VIMA cache.
//! * **softmax** — a reduction-heavy normalization shaped like a softmax
//!   denominator pass: per row, a dot-product reduction, a host read of the
//!   scalar result, a broadcast, and an elementwise divide. Exercises the
//!   stop-and-go dispatch + host synchronization path the streaming kernels
//!   never hit.

use std::sync::Arc;

use super::{common_validate, Workload, WorkloadKind};
use crate::ensure;
use crate::intrinsics::VimaProgram;
use crate::trace::{Backend, TraceChunker, TraceParams};
use crate::util::error::Result;

/// A registered Intrinsics-VIMA program.
pub struct ProgramWorkload {
    name: String,
    description: String,
    kind: WorkloadKind,
    program: VimaProgram,
    source: crate::analyze::SourceInfo,
}

impl ProgramWorkload {
    pub fn new(name: impl Into<String>, program: VimaProgram) -> Self {
        Self {
            name: name.into(),
            description: String::new(),
            kind: WorkloadKind::Program,
            program,
            source: crate::analyze::SourceInfo::default(),
        }
    }

    pub fn with_description(mut self, d: impl Into<String>) -> Self {
        self.description = d.into();
        self
    }

    /// Tag the provenance (the `.vpr` loader marks its registrations
    /// [`WorkloadKind::LoadedVpr`]).
    pub fn with_kind(mut self, kind: WorkloadKind) -> Self {
        self.kind = kind;
        self
    }

    /// Attach `.vpr` source spans and allocation names so analyzer
    /// diagnostics name real lines and allocations.
    pub fn with_source_info(mut self, source: crate::analyze::SourceInfo) -> Self {
        self.source = source;
        self
    }
}

impl Workload for ProgramWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn backends(&self) -> &[Backend] {
        &[Backend::Avx, Backend::Vima]
    }

    fn description(&self) -> &str {
        &self.description
    }

    fn kind(&self) -> WorkloadKind {
        self.kind
    }

    fn default_footprint(&self) -> u64 {
        self.program.footprint()
    }

    fn validate(&self, p: &TraceParams) -> Result<()> {
        common_validate(p)?;
        ensure!(
            p.vector_bytes == self.program.vector_bytes(),
            "program `{}` was built for {} B vectors, not {} B",
            self.name,
            self.program.vector_bytes(),
            p.vector_bytes
        );
        ensure!(
            p.footprint == self.program.footprint(),
            "program `{}` has a fixed {} B footprint (got {} B); its structure, \
             not the footprint knob, defines its size",
            self.name,
            self.program.footprint(),
            p.footprint
        );
        Ok(())
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        self.program.chunker(p.backend, p.thread, p.threads)
    }

    fn analyze(&self, cfg: &crate::config::SystemConfig) -> Option<crate::analyze::Report> {
        Some(crate::analyze::analyze(&self.program, &self.source, cfg))
    }

    fn verify(&self) -> Option<crate::analyze::VerifyReport> {
        Some(crate::analyze::verify::verify(&self.program, &self.source))
    }

    fn predict(
        &self,
        cfg: &crate::config::SystemConfig,
    ) -> Option<crate::analyze::cost::CostReport> {
        Some(crate::analyze::cost::predict(&self.program, cfg))
    }
}

/// SAXPY over `vectors` vectors: `y = a*x + y` with a resident broadcast
/// multiplier.
pub fn saxpy(vectors: u64) -> VimaProgram {
    let mut p = VimaProgram::new();
    let vb = p.vector_bytes() as u64;
    let alpha = p.alloc(vb);
    let x = p.alloc(vectors * vb);
    let y = p.alloc(vectors * vb);
    p.vim2k_sets(alpha);
    p.vloop(vectors, |l| l.vim2k_fmadds(alpha, x.walk(vb), y.walk(vb), y.walk(vb)));
    p
}

/// Softmax-shaped row normalization over `rows` vectors: per row a
/// dot-product reduction feeds a host-read scalar, which is broadcast and
/// divided back through the row. (The exponential is folded into the
/// synthetic trace — timing-wise the kernel is reduction + host sync +
/// broadcast + divide, which is what distinguishes it from the streaming
/// kernels.)
pub fn softmax(rows: u64) -> VimaProgram {
    let mut p = VimaProgram::new();
    let vb = p.vector_bytes() as u64;
    let input = p.alloc(rows * vb);
    let denom = p.alloc(vb);
    let out = p.alloc(rows * vb);
    p.vloop(rows, |l| {
        l.vim2k_dots(input.walk(vb), input.walk(vb)); // row reduction -> status
        l.host_load(denom, 8); // host reads the scalar result
        l.vim2k_sets(denom); // broadcast the normalizer
        l.vim2k_divs(input.walk(vb), denom, out.walk(vb));
    });
    p
}

pub(super) fn builtins() -> Vec<Arc<dyn Workload>> {
    vec![
        Arc::new(
            ProgramWorkload::new("saxpy", saxpy(256))
                .with_description("y = a*x + y Intrinsics-VIMA program (streaming FMA)"),
        ),
        Arc::new(
            ProgramWorkload::new("softmax", softmax(256)).with_description(
                "softmax-shaped row normalization (reduction + host sync per row)",
            ),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saxpy_counts() {
        let p = saxpy(64);
        assert_eq!(p.instructions(), 1 + 64); // set + one fma per vector
        assert_eq!(p.footprint(), (2 * 64 + 1) * 8192);
    }

    #[test]
    fn softmax_is_reduction_heavy() {
        let p = softmax(32);
        assert_eq!(p.instructions(), 32 * 3); // dot + set + div per row
        assert_eq!(p.events(), 32 * (3 * 3 + 1)); // + loop ctl + host load
    }

    #[test]
    fn program_workload_validates_identity() {
        let w = ProgramWorkload::new("t-val", saxpy(8));
        let good = TraceParams::new(
            crate::workload::resolve("saxpy").unwrap(),
            Backend::Vima,
            w.default_footprint(),
        );
        assert!(w.validate(&good).is_ok());
        let mut wrong = good;
        wrong.footprint = 1 << 20;
        assert!(w.validate(&wrong).is_err());
        let mut vb = good;
        vb.vector_bytes = 256;
        assert!(w.validate(&vb).is_err());
    }
}
