//! The paper's seven kernels (Sec. IV-A) as [`Workload`] registrations.
//!
//! Each kernel is one small struct whose [`Workload::chunker`] dispatches
//! over its *own* supported backends to the existing trace generators in
//! [`crate::trace`] — the old crate-wide `match (KernelId, Backend)` (which
//! panicked on the HIVE gaps for MatMul/kNN/MLP) no longer exists; an
//! unsupported backend is a typed error raised before any trace is built.

use std::sync::Arc;

use super::{Workload, WorkloadKind};
use crate::trace::{knn, matmul, mlp, stencil, streaming, Backend, TraceChunker, TraceParams};
use crate::util::error::Result;

const ALL_BACKENDS: [Backend; 3] = [Backend::Avx, Backend::Vima, Backend::Hive];
const NO_HIVE: [Backend; 2] = [Backend::Avx, Backend::Vima];

pub(super) fn all() -> Vec<Arc<dyn Workload>> {
    vec![
        Arc::new(MemSet),
        Arc::new(MemCopy),
        Arc::new(VecSum),
        Arc::new(Stencil),
        Arc::new(MatMul),
        Arc::new(Knn),
        Arc::new(Mlp),
    ]
}

pub struct MemSet;

impl Workload for MemSet {
    fn name(&self) -> &str {
        "MemSet"
    }

    fn backends(&self) -> &[Backend] {
        &ALL_BACKENDS
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::PaperKernel
    }

    fn description(&self) -> &str {
        "fill one array (pure store bandwidth)"
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        Ok(match p.backend {
            Backend::Avx => Box::new(streaming::MemSetAvx::new(p)),
            Backend::Vima => Box::new(streaming::MemSetVima::new(p)),
            Backend::Hive => Box::new(streaming::MemSetHive::new(p)),
        })
    }
}

pub struct MemCopy;

impl Workload for MemCopy {
    fn name(&self) -> &str {
        "MemCopy"
    }

    fn backends(&self) -> &[Backend] {
        &ALL_BACKENDS
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::PaperKernel
    }

    fn description(&self) -> &str {
        "copy src array to dst array (load+store bandwidth)"
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        Ok(match p.backend {
            Backend::Avx => Box::new(streaming::MemCopyAvx::new(p)),
            Backend::Vima => Box::new(streaming::MemCopyVima::new(p)),
            Backend::Hive => Box::new(streaming::MemCopyHive::new(p)),
        })
    }
}

pub struct VecSum;

impl Workload for VecSum {
    fn name(&self) -> &str {
        "VecSum"
    }

    fn backends(&self) -> &[Backend] {
        &ALL_BACKENDS
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::PaperKernel
    }

    fn description(&self) -> &str {
        "c = a + b elementwise (streaming compute)"
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        Ok(match p.backend {
            Backend::Avx => Box::new(streaming::VecSumAvx::new(p)),
            Backend::Vima => Box::new(streaming::VecSumVima::new(p)),
            Backend::Hive => Box::new(streaming::VecSumHive::new(p)),
        })
    }
}

pub struct Stencil;

impl Workload for Stencil {
    fn name(&self) -> &str {
        "Stencil"
    }

    fn backends(&self) -> &[Backend] {
        &ALL_BACKENDS
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::PaperKernel
    }

    fn description(&self) -> &str {
        "5-point convolution with row reuse"
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        Ok(match p.backend {
            Backend::Avx => Box::new(stencil::StencilAvx::new(p)),
            Backend::Vima => Box::new(stencil::StencilVima::new(p)),
            Backend::Hive => Box::new(stencil::StencilHive::new(p)),
        })
    }
}

pub struct MatMul;

impl Workload for MatMul {
    fn name(&self) -> &str {
        "MatMul"
    }

    fn backends(&self) -> &[Backend] {
        &NO_HIVE
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::PaperKernel
    }

    fn description(&self) -> &str {
        "C = A x B, naive loop nest (data-reuse showcase)"
    }

    fn default_footprint(&self) -> u64 {
        6 << 20
    }

    fn sampling_scale(&self, p: &TraceParams) -> f64 {
        let s = matmul::sampling_for(p);
        s.rows_total as f64 / s.rows_simulated as f64
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        Ok(match p.backend {
            Backend::Avx => Box::new(matmul::MatMulAvx::new(p)),
            Backend::Vima => Box::new(matmul::MatMulVima::new(p)),
            Backend::Hive => crate::bail!("MatMul has no HIVE trace generator"),
        })
    }
}

pub struct Knn;

impl Workload for Knn {
    fn name(&self) -> &str {
        "kNN"
    }

    fn backends(&self) -> &[Backend] {
        &NO_HIVE
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::PaperKernel
    }

    fn description(&self) -> &str {
        "k-nearest-neighbours distance sweep"
    }

    fn sampling_scale(&self, _p: &TraceParams) -> f64 {
        knn::scale_factor()
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        Ok(match p.backend {
            Backend::Avx => Box::new(knn::KnnAvx::new(p)),
            Backend::Vima => Box::new(knn::KnnVima::new(p)),
            Backend::Hive => crate::bail!("kNN has no HIVE trace generator"),
        })
    }
}

pub struct Mlp;

impl Workload for Mlp {
    fn name(&self) -> &str {
        "MLP"
    }

    fn backends(&self) -> &[Backend] {
        &NO_HIVE
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::PaperKernel
    }

    fn description(&self) -> &str {
        "multi-layer perceptron inference"
    }

    fn sampling_scale(&self, _p: &TraceParams) -> f64 {
        mlp::scale_factor()
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        Ok(match p.backend {
            Backend::Avx => Box::new(mlp::MlpAvx::new(p)),
            Backend::Vima => Box::new(mlp::MlpVima::new(p)),
            Backend::Hive => crate::bail!("MLP has no HIVE trace generator"),
        })
    }
}
