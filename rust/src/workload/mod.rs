//! Open workload API: the [`Workload`] trait and the process-wide registry.
//!
//! The paper's headline is not just the 26x speedup but the *programming
//! interface* (Sec. III-B, Intrinsics-VIMA): new workloads should be data,
//! not enum arms. This module makes the workload surface open:
//!
//! * [`Workload`] — what a workload *is*: a name, the set of backends it can
//!   lower to, parameter validation, an optional sampling-extrapolation
//!   factor, and a per-backend [`TraceChunker`] factory.
//! * the **registry** — a process-wide name -> workload table. The paper's
//!   seven kernels ([`paper`]) and two Intrinsics-VIMA example programs
//!   ([`programs`]) are pre-registered; user code adds its own with
//!   [`register`] (or [`VimaProgram::register`]) and the new workload is
//!   immediately runnable everywhere a built-in is: `simulate`/`run_on`,
//!   [`SweepPlan`]/[`RunCell`] (with result-cache dedup — workload identity
//!   is part of [`TraceParams`], which is `Eq + Hash`), and the
//!   `vima-sim run`/`sweep` CLI.
//! * [`WorkloadId`] — a small copyable identity. For the built-in kernels it
//!   coincides with [`KernelId`] (`WorkloadId::from(KernelId::MemSet)` etc.),
//!   so existing call sites keep working unchanged.
//!
//! Dispatch that used to be a 20-arm `match (KernelId, Backend)` (and a
//! panic on the gaps) is now `registry lookup -> backend check -> chunker`,
//! with every failure a typed [`util::error`](crate::util::error) result.
//!
//! [`VimaProgram::register`]: crate::intrinsics::VimaProgram::register
//! [`SweepPlan`]: crate::sweep::SweepPlan
//! [`RunCell`]: crate::sweep::RunCell
//! [`TraceParams`]: crate::trace::TraceParams

pub mod paper;
pub mod programs;

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, OnceLock, RwLock};

use crate::ensure;
use crate::trace::{Backend, KernelId, TraceChunker, TraceParams};
use crate::util::error::Result;

pub use programs::ProgramWorkload;

/// Where a workload came from — surfaced by `vima-sim workloads` so loaded
/// programs are discoverable next to the built-ins, and used by the custom
/// figure to enumerate every program-shaped workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// One of the paper's seven kernels (hand-written trace generators).
    PaperKernel,
    /// An Intrinsics-VIMA program registered from Rust code.
    Program,
    /// A program loaded from a `.vpr` file at runtime (see
    /// [`crate::program`]).
    LoadedVpr,
}

impl fmt::Display for WorkloadKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `pad` (not `write_str`) so callers' width specs apply.
        f.pad(match self {
            WorkloadKind::PaperKernel => "paper kernel",
            WorkloadKind::Program => "program",
            WorkloadKind::LoadedVpr => "loaded .vpr",
        })
    }
}

/// An open workload: anything that can lower itself to a per-backend trace
/// stream. Implementations are registered once ([`register`]) and addressed
/// by [`WorkloadId`] afterwards.
pub trait Workload: Send + Sync {
    /// Unique display name (registry keys are case-insensitive).
    fn name(&self) -> &str;

    /// Backends this workload can lower to. Requesting any other backend is
    /// a typed error from [`TraceParams::stream`], never a panic.
    fn backends(&self) -> &[Backend];

    /// One-line description for `vima-sim workloads`.
    fn description(&self) -> &str {
        ""
    }

    /// Provenance of this workload (paper kernel / program / loaded
    /// `.vpr`). Programs are the open-registry default; the paper kernels
    /// override.
    fn kind(&self) -> WorkloadKind {
        WorkloadKind::Program
    }

    /// Validate parameters before any trace is generated. The default
    /// checks the invariants every generator assumes; overrides should call
    /// [`common_validate`] first and then add their own constraints.
    fn validate(&self, p: &TraceParams) -> Result<()> {
        common_validate(p)
    }

    /// Sampling extrapolation factor (cycles and counters scale linearly;
    /// see DESIGN.md §Sampling). 1.0 = the whole workload is simulated.
    fn sampling_scale(&self, p: &TraceParams) -> f64 {
        let _ = p;
        1.0
    }

    /// Footprint used when the caller does not specify one (CLI `run`
    /// without `--mb`, the custom sweep figure).
    fn default_footprint(&self) -> u64 {
        4 << 20
    }

    /// Default `(window_events, period_events)` for sampled execution
    /// (DESIGN.md §11), used when `[sample]` is enabled with zero
    /// window/period. The heuristic estimates the per-thread event count
    /// from the footprint — ~6 events per 64 B line on the scalar backend,
    /// ~6 per vector on VIMA/HIVE — and slices it into ~16 periods with a
    /// 1/64 detailed fraction. The window floor keeps each measured window
    /// long enough to amortize its boundary transient (pipeline/MSHR
    /// refill after a fast-forward phase); the period floor makes short
    /// runs degenerate toward full-detail execution rather than a single
    /// unrepresentative window.
    fn sample_defaults(&self, p: &TraceParams) -> (u64, u64) {
        let per_unit = match p.backend {
            Backend::Avx => p.footprint.div_ceil(64),
            _ => p.footprint.div_ceil(p.vector_bytes),
        };
        let est = (per_unit * 6 / p.threads.max(1) as u64).max(1);
        let period = (est / 16).max(2048);
        let window = (period / 64).max(1024);
        (window, period)
    }

    /// Build the trace producer for `p` (`p.backend` is guaranteed to be in
    /// [`backends`](Self::backends) and `p` to have passed
    /// [`validate`](Self::validate)).
    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>>;

    /// Run the static analyzer ([`crate::analyze`]) over this workload's
    /// program against `cfg`, if it has one. `None` means "not analyzable"
    /// (the paper kernels are synthetic trace generators with no statement
    /// tree); program-backed workloads return a [`Report`](crate::analyze::Report).
    fn analyze(&self, cfg: &crate::config::SystemConfig) -> Option<crate::analyze::Report> {
        let _ = cfg;
        None
    }

    /// Prove this workload's VIMA and AVX lowerings dataflow-equivalent
    /// ([`crate::analyze::verify`]), if it has a statement tree. `None`
    /// means "not verifiable" (paper kernels have no program to compare);
    /// program-backed workloads return the full [`VerifyReport`] with the
    /// per-backend symbolic summaries.
    ///
    /// [`VerifyReport`]: crate::analyze::VerifyReport
    fn verify(&self) -> Option<crate::analyze::VerifyReport> {
        None
    }

    /// Predict this workload's cost on `cfg` with the static cost model
    /// ([`crate::analyze::cost`]), if it has a statement tree.
    fn predict(
        &self,
        cfg: &crate::config::SystemConfig,
    ) -> Option<crate::analyze::cost::CostReport> {
        let _ = cfg;
        None
    }
}

/// Parameter invariants shared by every trace generator.
pub fn common_validate(p: &TraceParams) -> Result<()> {
    ensure!(p.footprint > 0, "footprint must be non-zero");
    ensure!(
        p.vector_bytes >= 64 && p.vector_bytes.is_power_of_two(),
        "vector_bytes must be a power of two >= 64 (got {})",
        p.vector_bytes
    );
    ensure!(
        p.threads >= 1 && p.thread < p.threads,
        "thread {} out of range for {} threads",
        p.thread,
        p.threads
    );
    Ok(())
}

/// Registry identity of a workload — a small, copyable, hashable handle.
/// Stable for the whole process; the built-in kernels occupy the indices of
/// [`KernelId`] so the conversion is free.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkloadId(u32);

impl WorkloadId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl From<KernelId> for WorkloadId {
    fn from(k: KernelId) -> Self {
        // The registry constructor registers the paper kernels first, in
        // `KernelId` declaration order (asserted by `builtin_ids_line_up`).
        WorkloadId(k as u32)
    }
}

struct Registry {
    entries: Vec<Arc<dyn Workload>>,
    by_name: HashMap<String, WorkloadId>,
}

impl Registry {
    fn with_builtins() -> Self {
        let mut r = Registry { entries: Vec::new(), by_name: HashMap::new() };
        for w in paper::all() {
            r.insert(w).expect("built-in kernel registration cannot collide");
        }
        for w in programs::builtins() {
            r.insert(w).expect("built-in program registration cannot collide");
        }
        r
    }

    fn insert(&mut self, w: Arc<dyn Workload>) -> Result<WorkloadId> {
        let key = w.name().to_ascii_lowercase();
        ensure!(!key.is_empty(), "workload name must be non-empty");
        ensure!(
            !self.by_name.contains_key(&key),
            "workload `{}` is already registered",
            w.name()
        );
        let id = WorkloadId(self.entries.len() as u32);
        self.by_name.insert(key, id);
        self.entries.push(w);
        Ok(id)
    }
}

fn global() -> &'static RwLock<Registry> {
    static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| RwLock::new(Registry::with_builtins()))
}

/// Register a workload; its name becomes addressable from every layer
/// (params, sweeps, CLI). Errors if the (case-insensitive) name is taken.
pub fn register(w: Arc<dyn Workload>) -> Result<WorkloadId> {
    global().write().unwrap().insert(w)
}

/// Look a workload up by (case-insensitive) name.
pub fn resolve(name: &str) -> Result<WorkloadId> {
    let r = global().read().unwrap();
    match r.by_name.get(&name.to_ascii_lowercase()) {
        Some(&id) => Ok(id),
        None => {
            let mut names: Vec<String> =
                r.entries.iter().map(|w| w.name().to_string()).collect();
            names.sort_unstable();
            crate::bail!("unknown workload {name:?}; registered: {}", names.join(", "))
        }
    }
}

/// Fetch a registered workload by id.
pub fn get(id: WorkloadId) -> Result<Arc<dyn Workload>> {
    let r = global().read().unwrap();
    match r.entries.get(id.index()) {
        Some(w) => Ok(Arc::clone(w)),
        None => crate::bail!("workload id #{} is not registered", id.0),
    }
}

/// Display name for an id (`"workload#N"` if the id is unknown — labels
/// must never fail).
pub fn name(id: WorkloadId) -> String {
    get(id).map(|w| w.name().to_string()).unwrap_or_else(|_| format!("workload#{}", id.0))
}

/// All registered workload ids, in registration order.
pub fn all_ids() -> Vec<WorkloadId> {
    let r = global().read().unwrap();
    (0..r.entries.len() as u32).map(WorkloadId).collect()
}

/// Ids of every registered *program* workload (built-in or loaded `.vpr` —
/// anything that is not a paper kernel) that lowers to both AVX and VIMA:
/// the custom-figure set, in registration order.
pub fn program_ids() -> Vec<WorkloadId> {
    let r = global().read().unwrap();
    (0..r.entries.len() as u32)
        .map(WorkloadId)
        .filter(|id| {
            let w = &r.entries[id.index()];
            w.kind() != WorkloadKind::PaperKernel
                && w.backends().contains(&Backend::Avx)
                && w.backends().contains(&Backend::Vima)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_ids_line_up() {
        for k in [
            KernelId::MemSet,
            KernelId::MemCopy,
            KernelId::VecSum,
            KernelId::Stencil,
            KernelId::MatMul,
            KernelId::Knn,
            KernelId::Mlp,
        ] {
            let id = WorkloadId::from(k);
            let w = get(id).unwrap();
            assert_eq!(w.name(), k.to_string(), "registry order must match KernelId");
            assert_eq!(resolve(w.name()).unwrap(), id);
        }
    }

    #[test]
    fn resolution_is_case_insensitive() {
        assert_eq!(resolve("memset").unwrap(), WorkloadId::from(KernelId::MemSet));
        assert_eq!(resolve("MEMSET").unwrap(), WorkloadId::from(KernelId::MemSet));
        assert_eq!(resolve("kNN").unwrap(), WorkloadId::from(KernelId::Knn));
    }

    #[test]
    fn unknown_name_lists_registered() {
        let e = resolve("no-such-kernel").unwrap_err().to_string();
        assert!(e.contains("no-such-kernel"), "{e}");
        assert!(e.contains("MemSet"), "error must list registered workloads: {e}");
        assert!(e.contains("saxpy"), "error must list registered programs: {e}");
    }

    #[test]
    fn builtin_programs_are_registered() {
        for name in ["saxpy", "softmax"] {
            let id = resolve(name).unwrap();
            let w = get(id).unwrap();
            assert!(w.backends().contains(&Backend::Vima));
            assert!(w.backends().contains(&Backend::Avx));
            assert!(w.default_footprint() > 0);
        }
    }

    #[test]
    fn kinds_distinguish_kernels_from_programs() {
        let memset = get(WorkloadId::from(KernelId::MemSet)).unwrap();
        assert_eq!(memset.kind(), WorkloadKind::PaperKernel);
        let saxpy = get(resolve("saxpy").unwrap()).unwrap();
        assert_eq!(saxpy.kind(), WorkloadKind::Program);
        let programs = program_ids();
        assert!(programs.contains(&resolve("saxpy").unwrap()));
        assert!(programs.contains(&resolve("softmax").unwrap()));
        assert!(!programs.contains(&WorkloadId::from(KernelId::MemSet)));
    }

    #[test]
    fn common_validate_rejects_bad_params() {
        let good = TraceParams::new(KernelId::MemSet, Backend::Avx, 1 << 20);
        assert!(common_validate(&good).is_ok());
        let mut zero = good;
        zero.footprint = 0;
        assert!(common_validate(&zero).is_err());
        let mut odd = good;
        odd.vector_bytes = 100;
        assert!(common_validate(&odd).is_err());
    }
}
