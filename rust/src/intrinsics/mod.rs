//! Intrinsics-VIMA (Sec. III-B) as a Rust program-builder DSL.
//!
//! The paper ships a C/C++ intrinsics library (`_vim2K_adds`,
//! `_vim1K_fmadd`, ...) so programmers can emit VIMA instructions from
//! ordinary code. This module is the same interface for this repository's
//! users — and since the open-workload redesign it is a *streaming program
//! DSL*, not an eager event buffer:
//!
//! * programs are a statement tree ([`vloop`](VimaProgram::vloop) vector
//!   loops over [`Operand`]s that stride through allocations), lowered
//!   lazily through a [`TraceChunker`] — a million-iteration loop costs a
//!   few statements of memory, never a materialized trace;
//! * one program lowers to **multiple backends**: the VIMA stream *and* an
//!   honest AVX baseline (each vector instruction becomes the 64 B
//!   load/compute/store loop a `-O3` AVX-512 build would run), so custom
//!   workloads get real speedup numbers, not self-comparisons;
//! * [`VimaProgram::register`] turns a program into a first-class
//!   [`Workload`](crate::workload::Workload): runnable via
//!   `simulate`/`run_on`, deduped in sweep plans, addressable from the
//!   `vima-sim run`/`sweep` CLI by name.
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the xla_extension rpath
//! use vima_sim::intrinsics::VimaProgram;
//! let mut p = VimaProgram::new();
//! let vb = 8192;
//! let a = p.alloc(16 * vb);
//! let b = p.alloc(16 * vb);
//! let c = p.alloc(16 * vb);
//! p.vloop(16, |l| {
//!     l.vim2k_adds(a.walk(vb), b.walk(vb), c.walk(vb)); // c = a + b per vector
//! });
//! assert_eq!(p.instructions(), 16); // VIMA instructions, loops expanded
//! assert_eq!(p.events(), 48);       // + loop-control µops
//! let id = p.register("my-vecsum").unwrap();
//! # let _ = id;
//! ```

use crate::isa::{FuType, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};
use crate::trace::{emit, Backend, TraceChunker, TraceStream};
use crate::util::error::Result;
use crate::workload::WorkloadId;

/// Base of the simulated heap [`VimaProgram::alloc`] carves from.
pub(crate) const HEAP_BASE: u64 = 0x5_0000_0000;

/// Handle to a vector-aligned allocation in the simulated address space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VecPtr(pub u64);

impl VecPtr {
    /// Strided operand: inside a [`VimaProgram::vloop`] body the effective
    /// address advances by `stride_bytes` per iteration (use the vector size
    /// to walk an array one vector at a time). Outside a loop the stride is
    /// inert.
    pub fn walk(self, stride_bytes: u64) -> Operand {
        Operand { base: self.0, stride: stride_bytes }
    }
}

/// An instruction operand: a base address plus a per-iteration stride
/// (resolved against the innermost enclosing loop's induction variable).
/// A bare [`VecPtr`] converts to a stride-0 operand, so scalars/broadcast
/// vectors stay pinned while `walk`ed operands stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Operand {
    pub(crate) base: u64,
    pub(crate) stride: u64,
}

impl Operand {
    /// Resolved address at loop iteration `iter` (also used by the
    /// symbolic evaluator in [`crate::analyze`]).
    pub(crate) fn at(self, iter: u64) -> u64 {
        self.base + iter * self.stride
    }
}

impl From<VecPtr> for Operand {
    fn from(p: VecPtr) -> Self {
        Operand { base: p.0, stride: 0 }
    }
}

/// One program statement. Loops carry an iteration *range* so the chunker
/// can slice them across data-parallel threads without rewriting bodies.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Stmt {
    Instr { op: VimaOp, dtype: VDtype, srcs: Vec<Operand>, dst: Option<Operand> },
    HostLoad { addr: Operand, bytes: u16 },
    Loop { start: u64, end: u64, body: Vec<Stmt> },
}

/// One [`VimaProgram::alloc`] record: base address and vector-aligned size.
/// Kept so the `.vpr` emitter (`VimaProgram::to_vpr`, see `crate::program`)
/// can name the allocations and resolve operand addresses back to symbolic
/// offsets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Alloc {
    pub(crate) base: u64,
    pub(crate) size: u64,
}

/// Builder for VIMA programs (the Intrinsics-VIMA surface). Cloneable so a
/// registered workload can hand out fresh trace streams forever.
#[derive(Debug, Clone)]
pub struct VimaProgram {
    pub(crate) stmts: Vec<Stmt>,
    pub(crate) allocs: Vec<Alloc>,
    pub(crate) heap: u64,
    pub(crate) vector_bytes: u32,
    /// Emit host-side loop-control µops after each instruction (mirrors the
    /// compiled intrinsics call overhead). On by default.
    pub loop_overhead: bool,
}

impl Default for VimaProgram {
    fn default() -> Self {
        Self::new()
    }
}

impl VimaProgram {
    pub fn new() -> Self {
        Self {
            stmts: Vec::new(),
            allocs: Vec::new(),
            heap: HEAP_BASE,
            vector_bytes: 8192,
            loop_overhead: true,
        }
    }

    /// Use a non-default vector size (design-space exploration).
    pub fn with_vector_bytes(mut self, vb: u32) -> Self {
        self.vector_bytes = vb;
        self
    }

    /// Vector size this program was built for.
    pub fn vector_bytes(&self) -> u32 {
        self.vector_bytes
    }

    /// Total bytes allocated so far (the workload's data footprint).
    pub fn footprint(&self) -> u64 {
        self.heap - HEAP_BASE
    }

    /// Allocate `bytes` of vector-aligned simulated memory.
    pub fn alloc(&mut self, bytes: u64) -> VecPtr {
        let aligned = bytes.div_ceil(self.vector_bytes as u64) * self.vector_bytes as u64;
        let p = VecPtr(self.heap);
        self.allocs.push(Alloc { base: self.heap, size: aligned });
        self.heap += aligned;
        p
    }

    /// Vector loop: run `body` `iters` times. Operands built with
    /// [`VecPtr::walk`] advance by their stride each iteration; plain
    /// [`VecPtr`] operands stay fixed. Loops nest (strides bind to the
    /// innermost enclosing loop), and the trace is generated lazily — the
    /// loop is never unrolled in memory.
    ///
    /// The closure receives the same builder (allocations made inside the
    /// body persist), and builder-level settings such as
    /// [`loop_overhead`](Self::loop_overhead) carry through — the flag is a
    /// whole-program property, so flipping it inside a body affects the
    /// entire lowering, not just that loop.
    pub fn vloop(&mut self, iters: u64, f: impl FnOnce(&mut VimaProgram)) {
        let mut body = VimaProgram {
            stmts: Vec::new(),
            allocs: Vec::new(),
            heap: self.heap,
            vector_bytes: self.vector_bytes,
            loop_overhead: self.loop_overhead,
        };
        f(&mut body);
        self.heap = body.heap;
        self.loop_overhead = body.loop_overhead;
        self.allocs.extend(body.allocs);
        self.stmts.push(Stmt::Loop { start: 0, end: iters, body: body.stmts });
    }

    fn push_instr(&mut self, op: VimaOp, dtype: VDtype, srcs: &[Operand], dst: Option<Operand>) {
        self.stmts.push(Stmt::Instr { op, dtype, srcs: srcs.to_vec(), dst });
    }

    // --- the Intrinsics-VIMA operation set (Sec. III-B naming) -----------

    /// `_vim2K_adds`: c = a + b (f32).
    pub fn vim2k_adds(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
    ) {
        self.push_instr(VimaOp::Add, VDtype::F32, &[a.into(), b.into()], Some(c.into()));
    }

    /// `_vim2K_subs`: c = a - b (f32).
    pub fn vim2k_subs(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
    ) {
        self.push_instr(VimaOp::Sub, VDtype::F32, &[a.into(), b.into()], Some(c.into()));
    }

    /// `_vim2K_muls`: c = a * b (f32).
    pub fn vim2k_muls(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
    ) {
        self.push_instr(VimaOp::Mul, VDtype::F32, &[a.into(), b.into()], Some(c.into()));
    }

    /// `_vim2K_divs`: c = a / b (f32).
    pub fn vim2k_divs(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
    ) {
        self.push_instr(VimaOp::Div, VDtype::F32, &[a.into(), b.into()], Some(c.into()));
    }

    /// `_vim2K_fmadds`: d = a * b + c (f32).
    pub fn vim2k_fmadds(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
        d: impl Into<Operand>,
    ) {
        self.push_instr(
            VimaOp::Fma,
            VDtype::F32,
            &[a.into(), b.into(), c.into()],
            Some(d.into()),
        );
    }

    /// `_vim2K_movs`: copy a -> c.
    pub fn vim2k_movs(&mut self, a: impl Into<Operand>, c: impl Into<Operand>) {
        self.push_instr(VimaOp::Mov, VDtype::I32, &[a.into()], Some(c.into()));
    }

    /// `_vim2K_sets` (broadcast): c[:] = immediate. (Earlier revisions
    /// mislabelled this `_vim2K_mods`; the paper's intrinsic for filling a
    /// vector with a scalar is the set/broadcast form modelled here.)
    pub fn vim2k_sets(&mut self, c: impl Into<Operand>) {
        self.push_instr(VimaOp::Bcast, VDtype::F32, &[], Some(c.into()));
    }

    /// `_vim2K_idots`: dot-product reduction of a . b (scalar result
    /// returned via the status signal).
    pub fn vim2k_dots(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) {
        self.push_instr(VimaOp::Dot, VDtype::F32, &[a.into(), b.into()], None);
    }

    /// Integer variants (`_vim2K_addu` etc.).
    pub fn vim2k_addu(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
    ) {
        self.push_instr(VimaOp::Add, VDtype::I32, &[a.into(), b.into()], Some(c.into()));
    }

    pub fn vim2k_andu(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
    ) {
        self.push_instr(VimaOp::And, VDtype::I32, &[a.into(), b.into()], Some(c.into()));
    }

    /// 64-bit element variants (`_vim1K_*`, 1024 elements per 8 KB vector).
    pub fn vim1k_addd(
        &mut self,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
        c: impl Into<Operand>,
    ) {
        self.push_instr(VimaOp::Add, VDtype::F64, &[a.into(), b.into()], Some(c.into()));
    }

    /// Host-side scalar work between VIMA calls (e.g. reading a reduction).
    pub fn host_load(&mut self, addr: impl Into<Operand>, bytes: u16) {
        self.stmts.push(Stmt::HostLoad { addr: addr.into(), bytes });
    }

    /// Number of vector *instructions* this program emits (loops expanded).
    /// Loop-control µops and host loads are not instructions — count those
    /// via [`events`](Self::events).
    pub fn instructions(&self) -> u64 {
        fn walk(stmts: &[Stmt]) -> u64 {
            stmts
                .iter()
                .map(|s| match s {
                    Stmt::Instr { .. } => 1,
                    Stmt::HostLoad { .. } => 0,
                    Stmt::Loop { start, end, body } => {
                        end.saturating_sub(*start) * walk(body)
                    }
                })
                .sum()
        }
        walk(&self.stmts)
    }

    /// Total trace events of the VIMA lowering (instructions **plus**
    /// loop-control µops and host loads) — the stream length a
    /// [`Machine`](crate::sim::Machine) will consume.
    pub fn events(&self) -> u64 {
        let per_instr = if self.loop_overhead { 3 } else { 1 };
        fn walk(stmts: &[Stmt], per_instr: u64) -> u64 {
            stmts
                .iter()
                .map(|s| match s {
                    Stmt::Instr { .. } => per_instr,
                    Stmt::HostLoad { .. } => 1,
                    Stmt::Loop { start, end, body } => {
                        end.saturating_sub(*start) * walk(body, per_instr)
                    }
                })
                .sum()
        }
        walk(&self.stmts, per_instr)
    }

    pub fn is_empty(&self) -> bool {
        self.stmts.is_empty()
    }

    /// Register this program as a named [`Workload`](crate::workload::Workload):
    /// afterwards it runs everywhere the paper kernels do (simulate, sweep
    /// plans with cache dedup, the CLI).
    pub fn register(self, name: impl Into<String>) -> Result<WorkloadId> {
        crate::workload::register(std::sync::Arc::new(
            crate::workload::ProgramWorkload::new(name, self),
        ))
    }

    /// Lazy trace producer for one backend and one data-parallel slice.
    /// Top-level loops are sliced across `threads`; straight-line setup
    /// statements run on thread 0 only.
    pub fn chunker(
        &self,
        backend: Backend,
        thread: usize,
        threads: usize,
    ) -> Result<Box<dyn TraceChunker>> {
        crate::ensure!(
            matches!(backend, Backend::Avx | Backend::Vima),
            "VimaProgram has no {backend} lowering (supported: AVX, VIMA)"
        );
        crate::ensure!(threads >= 1 && thread < threads, "thread {thread}/{threads} out of range");
        let stmts = if threads == 1 {
            self.stmts.clone()
        } else {
            self.stmts
                .iter()
                .filter_map(|s| match s {
                    Stmt::Loop { start, end, body } => {
                        let n = end.saturating_sub(*start);
                        let per = n.div_ceil(threads as u64);
                        let lo = start + (thread as u64 * per).min(n);
                        let hi = (lo + per).min(*end);
                        Some(Stmt::Loop { start: lo, end: hi, body: body.clone() })
                    }
                    other => (thread == 0).then(|| other.clone()),
                })
                .collect()
        };
        Ok(Box::new(ProgramChunker {
            stmts,
            backend,
            vector_bytes: self.vector_bytes,
            loop_overhead: self.loop_overhead,
            stack: vec![Frame { loop_idx: usize::MAX, next: 0, iter: 0, end: 1 }],
        }))
    }

    /// Lazy stream for any supported backend.
    pub fn stream_for(&self, backend: Backend) -> Result<TraceStream> {
        Ok(TraceStream::new(self.chunker(backend, 0, 1)?))
    }

    /// Finish: a simulator-ready VIMA stream (lazy; loops never unroll in
    /// memory).
    pub fn into_stream(self) -> TraceStream {
        self.stream_for(Backend::Vima).expect("VIMA lowering is always available")
    }

    /// Finish: the fully expanded VIMA event list (e.g. for
    /// `runtime::functional::FunctionalVima` replay — `pjrt` feature).
    /// Prefer [`into_stream`](Self::into_stream) for simulation — `build`
    /// materializes every loop iteration.
    pub fn build(self) -> Vec<TraceEvent> {
        self.stream_for(Backend::Vima).expect("VIMA lowering is always available").collect()
    }

    /// Fully expanded event list for any supported backend.
    pub fn build_for(&self, backend: Backend) -> Result<Vec<TraceEvent>> {
        Ok(self.stream_for(backend)?.collect())
    }
}

/// One level of the lazy statement-tree walk: a body being executed.
#[derive(Debug, Clone, Copy)]
struct Frame {
    /// Index of the `Stmt::Loop` in the *parent* body (unused for the root).
    loop_idx: usize,
    /// Next statement index within this body.
    next: usize,
    /// Current iteration (loops carry global iteration numbers so strided
    /// operands resolve identically under thread slicing).
    iter: u64,
    /// One past the last iteration.
    end: u64,
}

/// Streaming lowering of a [`VimaProgram`]: one leaf statement instance per
/// refill, so even unbounded loops use O(program text) memory.
struct ProgramChunker {
    stmts: Vec<Stmt>,
    backend: Backend,
    vector_bytes: u32,
    loop_overhead: bool,
    stack: Vec<Frame>,
}

fn body_of<'a>(stmts: &'a [Stmt], stack: &[Frame], depth: usize) -> &'a [Stmt] {
    let mut body = stmts;
    for f in &stack[1..=depth] {
        match &body[f.loop_idx] {
            Stmt::Loop { body: b, .. } => body = b,
            _ => unreachable!("frame loop_idx must point at a loop"),
        }
    }
    body
}

impl TraceChunker for ProgramChunker {
    fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
        // Fill the chunk buffer to about this many events per refill
        // (matches TraceStream's buffer sizing).
        const TARGET: usize = 4096;
        let start_len = buf.len();
        while buf.len() - start_len < TARGET && !self.stack.is_empty() {
            let depth = self.stack.len() - 1;
            let f = self.stack[depth];
            let body_len = body_of(&self.stmts, &self.stack, depth).len();
            if f.next >= body_len {
                if f.iter + 1 < f.end {
                    self.stack[depth].iter += 1;
                    self.stack[depth].next = 0;
                } else if depth == 0 {
                    self.stack.pop(); // program exhausted
                } else {
                    self.stack.pop();
                    let d = self.stack.len() - 1;
                    self.stack[d].next += 1;
                }
                continue;
            }
            // Emitting borrows `self` only immutably, so the leaf is lowered
            // in place (no per-iteration statement clone); the stack is
            // mutated strictly after the borrow ends.
            let descend = {
                let body = body_of(&self.stmts, &self.stack, depth);
                match &body[f.next] {
                    Stmt::Loop { start, end, body } => {
                        Some((*start, *end, body.is_empty()))
                    }
                    leaf => {
                        self.emit(leaf, f.iter, buf);
                        None
                    }
                }
            };
            match descend {
                Some((start, end, empty)) => {
                    if start >= end || empty {
                        self.stack[depth].next += 1;
                    } else {
                        self.stack.push(Frame { loop_idx: f.next, next: 0, iter: start, end });
                    }
                }
                None => self.stack[depth].next += 1,
            }
        }
        buf.len() > start_len
    }
}

impl ProgramChunker {
    fn emit(&self, stmt: &Stmt, iter: u64, buf: &mut Vec<TraceEvent>) {
        match stmt {
            Stmt::Instr { op, dtype, srcs, dst } => {
                // Resolve operands into a fixed buffer (VIMA instructions
                // carry at most 3 sources) — the chunk refill loop must not
                // allocate per leaf statement.
                let mut sbuf = [0u64; 3];
                let n = srcs.len().min(3);
                for (slot, o) in sbuf.iter_mut().zip(srcs.iter()) {
                    *slot = o.at(iter);
                }
                let srcs = &sbuf[..n];
                let dst = dst.map(|o| o.at(iter));
                match self.backend {
                    Backend::Vima => {
                        buf.push(
                            VimaInstr::new(*op, *dtype, srcs, dst, self.vector_bytes).into(),
                        );
                        if self.loop_overhead {
                            buf.push(
                                Uop::alu(0xF00, FuType::IntAlu, [16, NO_REG, NO_REG], 16).into(),
                            );
                            buf.push(Uop::branch(0xF04, true).into());
                        }
                    }
                    Backend::Avx => self.emit_avx(*op, *dtype, srcs, dst, buf),
                    Backend::Hive => unreachable!("rejected at chunker construction"),
                }
            }
            Stmt::HostLoad { addr, bytes } => {
                buf.push(Uop::load(0xF10, addr.at(iter), *bytes, 1).into());
            }
            Stmt::Loop { .. } => unreachable!("loops are walked, not emitted"),
        }
    }

    /// Honest AVX-512 baseline for one vector instruction: the 64 B
    /// load/compute/store loop a `-O3` compiled scalar source would run.
    fn emit_avx(
        &self,
        op: VimaOp,
        dtype: VDtype,
        srcs: &[u64],
        dst: Option<u64>,
        buf: &mut Vec<TraceEvent>,
    ) {
        let chunks = (self.vector_bytes as u64 / emit::ZMM).max(1);
        let fu = avx_fu(op, dtype);
        for c in 0..chunks {
            let off = c * emit::ZMM;
            let mut in_regs = [NO_REG; 3];
            for (k, &s) in srcs.iter().enumerate().take(3) {
                buf.push(Uop::load(0xF20 + k as u64 * 8, s + off, 64, k as u8).into());
                in_regs[k] = k as u8;
            }
            let out_reg = if matches!(op, VimaOp::Mov | VimaOp::Bcast) {
                // Pure data movement: no compute µop; stores re-use the
                // loaded register (or the pre-broadcast zmm0 for Bcast).
                if srcs.is_empty() {
                    0
                } else {
                    in_regs[0]
                }
            } else {
                buf.push(Uop::alu(0xF40, fu, in_regs, 4).into());
                4
            };
            if let Some(d) = dst {
                buf.push(Uop::store(0xF48, d + off, 64, [out_reg, NO_REG, NO_REG]).into());
            }
            emit::loop_ctl(buf, 0xF50, 16, c + 1 < chunks);
        }
    }
}

fn avx_fu(op: VimaOp, dtype: VDtype) -> FuType {
    let fp = matches!(dtype, VDtype::F32 | VDtype::F64);
    match op {
        VimaOp::Mul | VimaOp::Fma | VimaOp::Dot => {
            if fp {
                FuType::FpMul
            } else {
                FuType::IntMul
            }
        }
        VimaOp::Div => {
            if fp {
                FuType::FpDiv
            } else {
                FuType::IntDiv
            }
        }
        _ => {
            if fp {
                FuType::FpAlu
            } else {
                FuType::IntAlu
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::Machine;

    #[test]
    fn builder_emits_instructions_and_overhead() {
        let mut p = VimaProgram::new();
        let (a, b, c) = (p.alloc(8192), p.alloc(8192), p.alloc(8192));
        p.vim2k_adds(a, b, c);
        assert_eq!(p.instructions(), 1);
        assert_eq!(p.events(), 3); // instr + 2 loop-control µops
        let ev = p.build();
        assert_eq!(ev.len(), 3);
        assert!(matches!(ev[0], TraceEvent::Vima(v) if v.op == VimaOp::Add));
    }

    #[test]
    fn alloc_is_vector_aligned_and_disjoint() {
        let mut p = VimaProgram::new();
        let a = p.alloc(100); // rounds to 8192
        let b = p.alloc(8192);
        assert_eq!(a.0 % 8192, 0);
        assert_eq!(b.0 - a.0, 8192);
        assert_eq!(p.footprint(), 2 * 8192);
    }

    #[test]
    fn vloop_streams_lazily_and_matches_manual_unroll() {
        let vb = 8192u64;
        let mut looped = VimaProgram::new();
        let a = looped.alloc(8 * vb);
        let b = looped.alloc(8 * vb);
        let c = looped.alloc(8 * vb);
        looped.vloop(8, |l| l.vim2k_adds(a.walk(vb), b.walk(vb), c.walk(vb)));

        let mut unrolled = VimaProgram::new();
        let (ua, ub, uc) = (unrolled.alloc(8 * vb), unrolled.alloc(8 * vb), unrolled.alloc(8 * vb));
        for i in 0..8 {
            unrolled.vim2k_adds(
                VecPtr(ua.0 + i * vb),
                VecPtr(ub.0 + i * vb),
                VecPtr(uc.0 + i * vb),
            );
        }

        assert_eq!(looped.instructions(), unrolled.instructions());
        let lv: Vec<TraceEvent> = looped.stream_for(Backend::Vima).unwrap().collect();
        let uv: Vec<TraceEvent> = unrolled.build();
        assert_eq!(lv, uv, "streamed loop must equal the eager unroll");
    }

    #[test]
    fn nested_loops_bind_strides_to_innermost() {
        let vb = 8192u64;
        let mut p = VimaProgram::new();
        let a = p.alloc(4 * vb);
        let c = p.alloc(4 * vb);
        p.vloop(2, |outer| {
            outer.vloop(4, |inner| inner.vim2k_movs(a.walk(vb), c.walk(vb)));
        });
        let instrs: Vec<VimaInstr> = p
            .stream_for(Backend::Vima)
            .unwrap()
            .filter_map(|e| match e {
                TraceEvent::Vima(v) => Some(v),
                _ => None,
            })
            .collect();
        assert_eq!(instrs.len(), 8);
        // Both outer iterations sweep the same 4 inner addresses.
        assert_eq!(instrs[0].srcs[0], instrs[4].srcs[0]);
        assert_eq!(instrs[3].srcs[0], a.0 + 3 * vb);
    }

    #[test]
    fn avx_lowering_is_an_honest_baseline() {
        let vb = 8192u64;
        let mut p = VimaProgram::new();
        let a = p.alloc(4 * vb);
        let b = p.alloc(4 * vb);
        let c = p.alloc(4 * vb);
        p.vloop(4, |l| l.vim2k_adds(a.walk(vb), b.walk(vb), c.walk(vb)));

        let avx: Vec<TraceEvent> = p.build_for(Backend::Avx).unwrap();
        let loads = avx
            .iter()
            .filter(|e| matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Load))
            .count();
        let stores = avx
            .iter()
            .filter(|e| matches!(e, TraceEvent::Uop(u) if u.fu == FuType::Store))
            .count();
        // 4 vectors x 128 chunks: 2 loads + 1 store each, no VIMA instrs.
        assert_eq!(loads, 4 * 128 * 2);
        assert_eq!(stores, 4 * 128);
        assert!(avx.iter().all(|e| !matches!(e, TraceEvent::Vima(_))));
        // Same data moved with far fewer VIMA events.
        assert!(avx.len() as u64 > 50 * p.instructions());
    }

    #[test]
    fn hive_lowering_is_a_typed_error() {
        let p = VimaProgram::new();
        let e = p.stream_for(Backend::Hive).unwrap_err().to_string();
        assert!(e.contains("HIVE"), "{e}");
    }

    #[test]
    fn thread_slicing_partitions_top_level_loops() {
        let vb = 8192u64;
        let mut p = VimaProgram::new();
        let alpha = p.alloc(vb);
        let x = p.alloc(10 * vb);
        let y = p.alloc(10 * vb);
        p.vim2k_sets(alpha);
        p.vloop(10, |l| l.vim2k_fmadds(alpha, x.walk(vb), y.walk(vb), y.walk(vb)));

        let whole: Vec<TraceEvent> = p.build_for(Backend::Vima).unwrap();
        let mut sliced = Vec::new();
        for t in 0..3 {
            let mut s = TraceStream::new(p.chunker(Backend::Vima, t, 3).unwrap());
            sliced.extend(s.by_ref());
        }
        // Setup (thread 0 only) + a partition of the loop: same multiset of
        // VIMA instructions, same total event count.
        assert_eq!(sliced.len(), whole.len());
        let addrs = |evs: &[TraceEvent]| {
            let mut v: Vec<u64> = evs
                .iter()
                .filter_map(|e| match e {
                    TraceEvent::Vima(i) => Some(i.srcs[1]),
                    _ => None,
                })
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(addrs(&sliced), addrs(&whole));
    }

    #[test]
    fn program_simulates_end_to_end() {
        let mut p = VimaProgram::new();
        let bufs: Vec<_> = (0..4).map(|_| p.alloc(8192)).collect();
        p.vim2k_sets(bufs[0]);
        p.vim2k_sets(bufs[1]);
        p.vim2k_adds(bufs[0], bufs[1], bufs[2]);
        p.vim2k_fmadds(bufs[0], bufs[1], bufs[2], bufs[3]);
        p.vim2k_dots(bufs[2], bufs[3]);
        let mut m = Machine::new(&SystemConfig::default(), 1).unwrap();
        let r = m.run(vec![p.into_stream()]).unwrap();
        assert!(r.cycles > 0);
        assert_eq!(r.report.get("vima.instructions"), Some(5.0));
    }

    #[test]
    fn saxpy_via_intrinsics_reuses_cache() {
        // y = a*x + y over 16 vectors: the broadcast vector stays resident.
        let vb = 8192u64;
        let mut p = VimaProgram::new();
        let alpha = p.alloc(vb);
        let x = p.alloc(16 * vb);
        let y = p.alloc(16 * vb);
        p.vim2k_sets(alpha);
        p.vloop(16, |l| l.vim2k_fmadds(alpha, x.walk(vb), y.walk(vb), y.walk(vb)));
        let mut m = Machine::new(&SystemConfig::default(), 1).unwrap();
        let r = m.run(vec![p.into_stream()]).unwrap();
        let hits = r.report.get("vima.vcache_hits").unwrap();
        assert!(hits >= 16.0, "alpha must hit the VIMA cache: {hits}");
    }

    #[test]
    fn smaller_vectors_supported() {
        let mut p = VimaProgram::new().with_vector_bytes(256);
        let a = p.alloc(256);
        let b = p.alloc(256);
        let c = p.alloc(256);
        p.vim2k_adds(a, b, c);
        let ev = p.build();
        assert!(matches!(ev[0], TraceEvent::Vima(v) if v.vector_bytes == 256));
    }
}
