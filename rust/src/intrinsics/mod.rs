//! Intrinsics-VIMA (Sec. III-B) as a Rust trace-builder API.
//!
//! The paper ships a C/C++ intrinsics library (`_vim2K_adds`,
//! `_vim1K_fmadd`, ...) so programmers can emit VIMA instructions from
//! ordinary code. This module is the same interface for this repository's
//! users: a [`VimaProgram`] builder that produces a simulator-ready
//! [`TraceStream`] *and* (through [`crate::runtime::functional`]) a
//! functionally executable instruction list — custom workloads beyond the
//! paper's seven kernels in a few lines:
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the xla_extension rpath
//! use vima_sim::intrinsics::VimaProgram;
//! let mut p = VimaProgram::new();
//! let a = p.alloc(8192);
//! let b = p.alloc(8192);
//! let c = p.alloc(8192);
//! p.vim2k_adds(a, b, c);          // c = a + b over one 8 KB vector
//! let events = p.build();
//! assert_eq!(events.len(), 3);    // instruction + loop-control µops
//! ```

use crate::isa::{FuType, TraceEvent, Uop, VDtype, VimaInstr, VimaOp, NO_REG};
use crate::trace::{TraceChunker, TraceStream};

/// Handle to a vector-aligned allocation in the simulated address space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VecPtr(pub u64);

/// Builder for VIMA instruction sequences (the Intrinsics-VIMA surface).
#[derive(Default)]
pub struct VimaProgram {
    events: Vec<TraceEvent>,
    heap: u64,
    vector_bytes: u32,
    /// Emit host-side loop-control µops between instructions (mirrors the
    /// compiled intrinsics call overhead). On by default.
    pub loop_overhead: bool,
}

impl VimaProgram {
    pub fn new() -> Self {
        Self { events: Vec::new(), heap: 0x5_0000_0000, vector_bytes: 8192, loop_overhead: true }
    }

    /// Use a non-default vector size (design-space exploration).
    pub fn with_vector_bytes(mut self, vb: u32) -> Self {
        self.vector_bytes = vb;
        self
    }

    /// Allocate `bytes` of vector-aligned simulated memory.
    pub fn alloc(&mut self, bytes: u64) -> VecPtr {
        let aligned = bytes.div_ceil(self.vector_bytes as u64) * self.vector_bytes as u64;
        let p = VecPtr(self.heap);
        self.heap += aligned;
        p
    }

    fn push_instr(&mut self, op: VimaOp, dtype: VDtype, srcs: &[u64], dst: Option<u64>) {
        self.events.push(VimaInstr::new(op, dtype, srcs, dst, self.vector_bytes).into());
        if self.loop_overhead {
            self.events.push(Uop::alu(0xF00, FuType::IntAlu, [16, NO_REG, NO_REG], 16).into());
            self.events.push(Uop::branch(0xF04, true).into());
        }
    }

    // --- the Intrinsics-VIMA operation set (Sec. III-B naming) -----------

    /// `_vim2K_adds`: c = a + b (f32).
    pub fn vim2k_adds(&mut self, a: VecPtr, b: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::Add, VDtype::F32, &[a.0, b.0], Some(c.0));
    }

    /// `_vim2K_subs`: c = a - b (f32).
    pub fn vim2k_subs(&mut self, a: VecPtr, b: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::Sub, VDtype::F32, &[a.0, b.0], Some(c.0));
    }

    /// `_vim2K_muls`: c = a * b (f32).
    pub fn vim2k_muls(&mut self, a: VecPtr, b: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::Mul, VDtype::F32, &[a.0, b.0], Some(c.0));
    }

    /// `_vim2K_divs`: c = a / b (f32).
    pub fn vim2k_divs(&mut self, a: VecPtr, b: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::Div, VDtype::F32, &[a.0, b.0], Some(c.0));
    }

    /// `_vim2K_fmadds`: d = a * b + c (f32).
    pub fn vim2k_fmadds(&mut self, a: VecPtr, b: VecPtr, c: VecPtr, d: VecPtr) {
        self.push_instr(VimaOp::Fma, VDtype::F32, &[a.0, b.0, c.0], Some(d.0));
    }

    /// `_vim2K_movs`: copy a -> c.
    pub fn vim2k_movs(&mut self, a: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::Mov, VDtype::I32, &[a.0], Some(c.0));
    }

    /// `_vim2K_mods` (broadcast/set): c[:] = immediate.
    pub fn vim2k_sets(&mut self, c: VecPtr) {
        self.push_instr(VimaOp::Bcast, VDtype::F32, &[], Some(c.0));
    }

    /// `_vim2K_idots`: dot-product reduction of a . b (scalar result
    /// returned via the status signal).
    pub fn vim2k_dots(&mut self, a: VecPtr, b: VecPtr) {
        self.push_instr(VimaOp::Dot, VDtype::F32, &[a.0, b.0], None);
    }

    /// Integer variants (`_vim2K_addu` etc.).
    pub fn vim2k_addu(&mut self, a: VecPtr, b: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::Add, VDtype::I32, &[a.0, b.0], Some(c.0));
    }

    pub fn vim2k_andu(&mut self, a: VecPtr, b: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::And, VDtype::I32, &[a.0, b.0], Some(c.0));
    }

    /// 64-bit element variants (`_vim1K_*`, 1024 elements per 8 KB vector).
    pub fn vim1k_addd(&mut self, a: VecPtr, b: VecPtr, c: VecPtr) {
        self.push_instr(VimaOp::Add, VDtype::F64, &[a.0, b.0], Some(c.0));
    }

    /// Host-side scalar work between VIMA calls (e.g. reading a reduction).
    pub fn host_load(&mut self, addr: VecPtr, bytes: u16) {
        self.events.push(Uop::load(0xF10, addr.0, bytes, 1).into());
    }

    /// Number of instructions queued so far.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Finish: the raw event list (e.g. for [`FunctionalVima`] replay).
    ///
    /// [`FunctionalVima`]: crate::runtime::functional::FunctionalVima
    pub fn build(self) -> Vec<TraceEvent> {
        self.events
    }

    /// Finish: a simulator-ready stream.
    pub fn into_stream(self) -> TraceStream {
        struct VecChunker(std::vec::IntoIter<TraceEvent>, bool);
        impl TraceChunker for VecChunker {
            fn refill(&mut self, buf: &mut Vec<TraceEvent>) -> bool {
                if self.1 {
                    return false;
                }
                buf.extend(self.0.by_ref());
                self.1 = true;
                !buf.is_empty()
            }
        }
        TraceStream::new(Box::new(VecChunker(self.events.into_iter(), false)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::Machine;

    #[test]
    fn builder_emits_instructions_and_overhead() {
        let mut p = VimaProgram::new();
        let (a, b, c) = (p.alloc(8192), p.alloc(8192), p.alloc(8192));
        p.vim2k_adds(a, b, c);
        let ev = p.build();
        assert_eq!(ev.len(), 3); // instr + 2 loop-control µops
        assert!(matches!(ev[0], TraceEvent::Vima(v) if v.op == VimaOp::Add));
    }

    #[test]
    fn alloc_is_vector_aligned_and_disjoint() {
        let mut p = VimaProgram::new();
        let a = p.alloc(100); // rounds to 8192
        let b = p.alloc(8192);
        assert_eq!(a.0 % 8192, 0);
        assert_eq!(b.0 - a.0, 8192);
    }

    #[test]
    fn program_simulates_end_to_end() {
        let mut p = VimaProgram::new();
        let bufs: Vec<_> = (0..4).map(|_| p.alloc(8192)).collect();
        p.vim2k_sets(bufs[0]);
        p.vim2k_sets(bufs[1]);
        p.vim2k_adds(bufs[0], bufs[1], bufs[2]);
        p.vim2k_fmadds(bufs[0], bufs[1], bufs[2], bufs[3]);
        p.vim2k_dots(bufs[2], bufs[3]);
        let mut m = Machine::new(&SystemConfig::default(), 1);
        let r = m.run(vec![p.into_stream()]);
        assert!(r.cycles > 0);
        assert_eq!(r.report.get("vima.instructions"), Some(5.0));
    }

    #[test]
    fn saxpy_via_intrinsics_reuses_cache() {
        // y = a*x + y over 16 vectors: the broadcast vector stays resident.
        let mut p = VimaProgram::new();
        let alpha = p.alloc(8192);
        p.vim2k_sets(alpha);
        for _ in 0..16 {
            let x = p.alloc(8192);
            let y = p.alloc(8192);
            p.vim2k_fmadds(alpha, x, y, y);
        }
        let mut m = Machine::new(&SystemConfig::default(), 1);
        let r = m.run(vec![p.into_stream()]);
        let hits = r.report.get("vima.vcache_hits").unwrap();
        assert!(hits >= 16.0, "alpha must hit the VIMA cache: {hits}");
    }

    #[test]
    fn smaller_vectors_supported() {
        let mut p = VimaProgram::new().with_vector_bytes(256);
        let a = p.alloc(256);
        let b = p.alloc(256);
        let c = p.alloc(256);
        p.vim2k_adds(a, b, c);
        let ev = p.build();
        assert!(matches!(ev[0], TraceEvent::Vima(v) if v.vector_bytes == 256));
    }
}
