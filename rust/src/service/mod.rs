//! Long-lived simulation service — the one front door for running
//! simulations.
//!
//! Every entry point the crate used to expose separately (`sim::simulate`,
//! `sim::simulate_threads`, [`SweepRunner`](crate::sweep::SweepRunner)
//! plans, the `Experiment` figure drivers, the `vima-sim serve` JSONL mode)
//! now funnels into a [`SimService`]: construct it once and submit [`Job`]s
//! individually ([`submit`](SimService::submit)), in batches
//! ([`submit_batch`](SimService::submit_batch)), or as whole
//! [`SweepPlan`]s ([`submit_plan`](SimService::submit_plan) /
//! [`run_plan`](SimService::run_plan)). Each submission returns a ticketed
//! [`JobHandle`] with a typed [`JobStatus`]
//! (`Queued`/`Running`/`Done`/`Failed`) and a blocking
//! [`wait`](JobHandle::wait) for the [`SimResult`].
//!
//! The scheduler owns the three concerns the old entry points each solved
//! partially:
//!
//! * **worker pool** — `jobs` long-lived threads (default
//!   `available_parallelism()`) pull leader jobs from a shared FIFO deque;
//!   workers outlive any single plan, so repeated submissions pay no
//!   cold-start cost;
//! * **machine pooling** — each worker keeps a [`MachinePool`] of up to a
//!   few [`Machine`]s keyed by `(config, threads)` and calls
//!   [`Machine::reset`] on reuse instead of reallocating the cache
//!   hierarchy (reset-and-reuse is bit-identical to a fresh machine; see
//!   `sim::tests::machine_reuse_matches_fresh_runs`);
//! * **result cache + dedup** — results are cached under the cell's full
//!   identity ([`CellKey`]: `TraceParams` + effective `SystemConfig`),
//!   exactly as the sweep engine always keyed them, so equal keys never
//!   simulate twice. A submission whose key is already **in flight** joins
//!   the running leader instead of spawning a duplicate run — concurrent
//!   submitters observe exactly-once execution per key. The cache is
//!   **bounded**: a configurable capacity with LRU-ish eviction
//!   (least-recently-touched entry evicted on overflow), with hit/miss/
//!   evict accounting surfaced through [`SweepStats`].
//!
//! Determinism: the simulator is single-threaded and deterministic per
//! cell, machine reuse is bit-identical to fresh machines, and the cache
//! key is the cell's complete identity — so scheduling order, worker
//! count, batching, and cache hits can never change a result. Sweep
//! output through the service is bit-identical to the pre-service engine.
//!
//! A panicking simulation (a bug, not a typed error) is caught per job:
//! the worker discards the possibly-inconsistent pooled machine, marks the
//! job `Failed`, and keeps serving.

pub mod jsonl;

use std::collections::{HashMap, VecDeque};
use std::io::IsTerminal;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::config::SystemConfig;
use crate::sim::{run_on, Machine, SimResult};
use crate::sweep::{CellKey, RunCell, SweepPlan, SweepStats};
use crate::trace::TraceParams;
use crate::util::error::{Error, Result};
use crate::workload;

/// Default bound on the service result cache, in cached `SimResult`s. The
/// full paper suite is 111 cells (61 unique), so the default never evicts
/// mid-suite; long-lived `serve` processes can lower it with `--cache`.
pub const DEFAULT_CACHE_CAPACITY: usize = 1024;

/// Default per-worker [`MachinePool`] capacity. Figure sweeps cycle
/// through a handful of config shapes (base, cache-size points, ablation
/// overrides); a few pooled machines catch most reuse without hoarding
/// memory.
pub const DEFAULT_MACHINE_POOL: usize = 4;

/// Construction parameters for a [`SimService`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Config a [`Job`] runs on when it carries no override.
    pub base: SystemConfig,
    /// Worker threads; `0` means `available_parallelism()`.
    pub jobs: usize,
    /// Result-cache bound (entries); clamped to at least 1.
    pub cache_capacity: usize,
    /// Per-worker machine-pool bound (machines); clamped to at least 1.
    pub machine_pool: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            base: SystemConfig::default(),
            jobs: 0,
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            machine_pool: DEFAULT_MACHINE_POOL,
        }
    }
}

/// One unit of work: any registered workload x backend x footprint x
/// threads, with an optional full-config override (`None` = the service's
/// base config). The cell-identity fields live in [`TraceParams`].
#[derive(Debug, Clone)]
pub struct Job {
    pub params: TraceParams,
    /// Full-config override; `None` runs on the service's base config.
    pub cfg: Option<SystemConfig>,
    /// Log one `[vima-sim] run <label>` line on stderr when this job
    /// actually simulates (cache hits and joins stay silent).
    pub verbose: bool,
    /// Progress-label override (plan submissions pass the cell's own
    /// label); derived from `params` when `None`.
    pub label: Option<String>,
}

impl Job {
    pub fn new(params: TraceParams) -> Self {
        Self { params, cfg: None, verbose: false, label: None }
    }

    pub fn with_cfg(mut self, cfg: SystemConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    pub fn with_verbose(mut self, verbose: bool) -> Self {
        self.verbose = verbose;
        self
    }
}

impl From<RunCell> for Job {
    fn from(cell: RunCell) -> Self {
        let params = cell.params();
        let label = Some(cell.label());
        Self { params, cfg: cell.cfg_override, verbose: false, label }
    }
}

/// Lifecycle of a submitted [`Job`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobStatus {
    /// Accepted; waiting for a worker (or for the in-flight leader run it
    /// joined).
    Queued,
    /// A worker is simulating this job's cell right now.
    Running,
    /// Finished; [`JobHandle::wait`] returns the result immediately.
    Done,
    /// Rejected at submission (validation) or failed during simulation;
    /// [`JobHandle::wait`] returns the error.
    Failed,
}

/// Ticket for a submitted job. Dropping the handle abandons the job (the
/// service forgets its bookkeeping once the run finishes); results stay
/// available in the result cache either way.
pub struct JobHandle {
    id: u64,
    shared: Arc<Shared>,
}

impl JobHandle {
    /// Service-local ticket number (submission order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Current typed status (non-blocking).
    pub fn status(&self) -> JobStatus {
        let st = self.shared.state.lock().unwrap();
        st.table.get(&self.id).map(|e| e.status).unwrap_or(JobStatus::Failed)
    }

    /// Block until the job completes; returns its result (or the typed
    /// error that failed it). Idempotent: waiting again returns the same
    /// outcome.
    pub fn wait(&self) -> Result<SimResult> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            match Self::settled_outcome(&st, self.id) {
                Some(Ok(r)) => return Ok((*r).clone()),
                Some(Err(msg)) => return Err(Error::msg(msg)),
                None => st = self.shared.done_cv.wait(st).unwrap(),
            }
        }
    }

    /// Block for at most `timeout`; `Ok(None)` means the job is still
    /// queued/running when the deadline passes (the simulation itself
    /// keeps going — a later [`wait`](Self::wait) still returns it). This
    /// is what keeps a network session from hanging forever on a wedged
    /// job: the serving layer maps a request's `timeout_ms` onto it and
    /// answers with a typed `timeout` line instead of blocking the
    /// connection (DESIGN.md §14).
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> Result<Option<SimResult>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            match Self::settled_outcome(&st, self.id) {
                Some(Ok(r)) => return Ok(Some((*r).clone())),
                Some(Err(msg)) => return Err(Error::msg(msg)),
                None => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return Ok(None);
                    }
                    st = self.shared.done_cv.wait_timeout(st, deadline - now).unwrap().0;
                }
            }
        }
    }

    /// The job's outcome if it has settled (`Done`/`Failed`), else `None`.
    fn settled_outcome(st: &State, id: u64) -> Option<Result<Arc<SimResult>, String>> {
        let entry = st.table.get(&id).expect("job entry lives while handle does");
        match entry.status {
            JobStatus::Done | JobStatus::Failed => {
                Some(entry.outcome.clone().expect("completed job has outcome"))
            }
            JobStatus::Queued | JobStatus::Running => None,
        }
    }
}

impl Drop for JobHandle {
    fn drop(&mut self) {
        let Ok(mut st) = self.shared.state.lock() else { return };
        let completed = st
            .table
            .get(&self.id)
            .map(|e| matches!(e.status, JobStatus::Done | JobStatus::Failed))
            .unwrap_or(true);
        if completed {
            st.table.remove(&self.id);
        } else if let Some(e) = st.table.get_mut(&self.id) {
            // Still queued/running: the worker drops the entry on
            // completion instead of storing an outcome nobody will read.
            e.abandoned = true;
        }
    }
}

/// Per-job bookkeeping while a handle (or the scheduler) needs it.
struct JobEntry {
    params: TraceParams,
    /// Effective (already base-resolved) configuration.
    cfg: SystemConfig,
    label: String,
    verbose: bool,
    status: JobStatus,
    /// Set exactly once, at completion. `Err` carries the flattened
    /// message (the in-tree [`Error`] is not `Clone`).
    outcome: Option<Result<Arc<SimResult>, String>>,
    /// Handle dropped before completion: drop the entry at completion.
    abandoned: bool,
}

/// Bounded result cache: `CellKey -> SimResult`, least-recently-touched
/// entry evicted when the capacity overflows ("LRU-ish": a full scan
/// picks the victim — capacities are small and eviction is rare).
struct ResultCache {
    capacity: usize,
    tick: u64,
    map: HashMap<CellKey, (Arc<SimResult>, u64)>,
}

impl ResultCache {
    fn new(capacity: usize) -> Self {
        Self { capacity: capacity.max(1), tick: 0, map: HashMap::new() }
    }

    fn get(&mut self, key: &CellKey) -> Option<Arc<SimResult>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|slot| {
            slot.1 = tick;
            Arc::clone(&slot.0)
        })
    }

    /// Insert and evict down to capacity; returns how many entries were
    /// evicted.
    fn insert(&mut self, key: CellKey, result: Arc<SimResult>) -> u64 {
        self.tick += 1;
        self.map.insert(key, (result, self.tick));
        let mut evicted = 0;
        while self.map.len() > self.capacity {
            let victim = self.map.iter().min_by_key(|(_, slot)| slot.1).map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            self.map.remove(&k);
            evicted += 1;
        }
        evicted
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Per-worker machine reuse, pooled by `(config, threads)` shape: a cell
/// whose shape matches a pooled machine re-runs on it after
/// [`Machine::reset`] (bit-identical to a fresh machine) instead of
/// reallocating the whole cache hierarchy. The least-recently-used
/// machine is dropped when the pool overflows.
pub struct MachinePool {
    slots: Vec<PoolSlot>,
    capacity: usize,
    tick: u64,
    /// Machines constructed (pool misses).
    pub builds: u64,
    /// Cells served by resetting a pooled machine.
    pub reuses: u64,
}

struct PoolSlot {
    threads: usize,
    last_use: u64,
    machine: Machine,
}

impl Default for MachinePool {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_MACHINE_POOL)
    }
}

impl MachinePool {
    pub fn with_capacity(capacity: usize) -> Self {
        Self { slots: Vec::new(), capacity: capacity.max(1), tick: 0, builds: 0, reuses: 0 }
    }

    /// Machines currently pooled.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Fetch a reset machine for this shape, building (and evicting) if no
    /// pooled machine matches. Invalid configurations surface as the
    /// typed construction error instead of a worker panic.
    pub fn get(&mut self, cfg: &SystemConfig, threads: usize) -> Result<&mut Machine> {
        self.tick += 1;
        let tick = self.tick;
        let found = self
            .slots
            .iter()
            .position(|s| s.threads == threads && s.machine.cfg == *cfg);
        if let Some(i) = found {
            self.reuses += 1;
            self.slots[i].last_use = tick;
            self.slots[i].machine.reset();
            return Ok(&mut self.slots[i].machine);
        }
        let machine = Machine::new(cfg, threads)?;
        self.builds += 1;
        if self.slots.len() >= self.capacity {
            let oldest = self
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.last_use)
                .map(|(i, _)| i);
            if let Some(i) = oldest {
                self.slots.swap_remove(i);
            }
        }
        self.slots.push(PoolSlot { threads, last_use: tick, machine });
        let slot = self.slots.last_mut().expect("just pushed");
        Ok(&mut slot.machine)
    }

    /// Drop the pooled machine for this shape (used after a panic, when
    /// the machine's state can no longer be trusted).
    pub fn discard(&mut self, cfg: &SystemConfig, threads: usize) {
        self.slots.retain(|s| !(s.threads == threads && s.machine.cfg == *cfg));
    }
}

struct State {
    /// Leader job ids awaiting a worker, FIFO.
    queue: VecDeque<u64>,
    /// Every live job (handle not yet dropped, or not yet completed).
    table: HashMap<u64, JobEntry>,
    /// Key -> leader job id, for submissions to join while a cell is
    /// queued or running.
    leaders: HashMap<CellKey, u64>,
    /// Leader job id -> jobs that joined its run.
    followers: HashMap<u64, Vec<u64>>,
    cache: ResultCache,
    stats: SweepStats,
    next_id: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers sleep here while the queue is empty.
    work_cv: Condvar,
    /// Handles sleep here while their job is queued/running.
    done_cv: Condvar,
}

/// The service: a worker pool + bounded result cache behind a submission
/// queue. See the module docs for the scheduling contract.
pub struct SimService {
    shared: Arc<Shared>,
    base: SystemConfig,
    jobs: usize,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl SimService {
    pub fn new(cfg: ServiceConfig) -> Self {
        let jobs = resolve_jobs(cfg.jobs);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                table: HashMap::new(),
                leaders: HashMap::new(),
                followers: HashMap::new(),
                cache: ResultCache::new(cfg.cache_capacity),
                stats: SweepStats::default(),
                next_id: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (0..jobs)
            .map(|i| {
                let sh = Arc::clone(&shared);
                let pool = cfg.machine_pool;
                std::thread::Builder::new()
                    .name(format!("vima-sim-worker-{i}"))
                    .spawn(move || worker_loop(sh, pool))
                    .expect("spawn service worker")
            })
            .collect();
        Self { shared, base: cfg.base, jobs, workers }
    }

    /// Service over a base config with default pool/cache sizing.
    pub fn with_base(base: SystemConfig) -> Self {
        Self::new(ServiceConfig { base, ..ServiceConfig::default() })
    }

    /// Worker-pool width.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// The config jobs without an override run on.
    pub fn base(&self) -> &SystemConfig {
        &self.base
    }

    /// Scheduler accounting across everything ever submitted.
    pub fn stats(&self) -> SweepStats {
        self.shared.state.lock().unwrap().stats
    }

    /// Number of distinct cells currently cached.
    pub fn cached_cells(&self) -> usize {
        self.shared.state.lock().unwrap().cache.len()
    }

    /// Submit one job. Never blocks on simulation: invalid jobs come back
    /// already `Failed`, cached cells already `Done`, and everything else
    /// is `Queued` (either as a leader or joined to an in-flight run).
    pub fn submit(&self, job: Job) -> JobHandle {
        let mut st = self.shared.state.lock().unwrap();
        let id = self.submit_locked(&mut st, job);
        drop(st);
        JobHandle { id, shared: Arc::clone(&self.shared) }
    }

    /// Submit a batch atomically: no worker can complete (and no other
    /// submitter can interleave) between the first and last job, so
    /// intra-batch duplicates deterministically join their leader.
    pub fn submit_batch(&self, jobs: Vec<Job>) -> Vec<JobHandle> {
        let mut st = self.shared.state.lock().unwrap();
        let ids: Vec<u64> = jobs.into_iter().map(|j| self.submit_locked(&mut st, j)).collect();
        drop(st);
        ids.into_iter().map(|id| JobHandle { id, shared: Arc::clone(&self.shared) }).collect()
    }

    /// Submit every cell of a plan (against the service base config) as
    /// one batch; handles come back in plan order.
    pub fn submit_plan(&self, plan: &SweepPlan) -> Vec<JobHandle> {
        self.submit_batch(plan.cells().iter().cloned().map(Job::from).collect())
    }

    /// Blocking plan execution — the sweep engine's contract: pre-validate
    /// every cell (fail fast with the cell label, before any simulation),
    /// submit the batch, and collect results in plan order. `base`
    /// overrides the service base for cells without their own override.
    pub fn run_plan(
        &self,
        base: &SystemConfig,
        plan: &SweepPlan,
        verbose: bool,
    ) -> Result<Vec<SimResult>> {
        for cell in plan.cells() {
            cell.params()
                .check()
                .map_err(|e| e.context(format!("sweep cell {}", cell.label())))?;
        }
        let jobs: Vec<Job> = plan
            .cells()
            .iter()
            .map(|cell| Job {
                params: cell.params(),
                cfg: Some(cell.cfg_override.clone().unwrap_or_else(|| base.clone())),
                verbose,
                label: Some(cell.label()),
            })
            .collect();
        let handles = self.submit_batch(jobs);
        // Progress heartbeat for long sweeps: completed/total + ETA on
        // stderr every ~10% of the plan. On for interactive terminals and
        // under --verbose; off when stderr is piped (CSV/script capture).
        let total = handles.len();
        let progress = verbose || std::io::stderr().is_terminal();
        let every = (total / 10).max(1);
        let t0 = std::time::Instant::now();
        let mut out = Vec::with_capacity(handles.len());
        for (i, (handle, cell)) in handles.iter().zip(plan.cells()).enumerate() {
            out.push(
                handle
                    .wait()
                    .map_err(|e| e.context(format!("sweep cell {}", cell.label())))?,
            );
            let done = i + 1;
            if progress && done % every == 0 && done < total {
                let elapsed = t0.elapsed().as_secs_f64();
                let eta = elapsed / done as f64 * (total - done) as f64;
                eprintln!(
                    "[vima-sim] sweep progress: {done}/{total} cells, \
                     elapsed {elapsed:.1}s, eta {eta:.1}s"
                );
            }
        }
        Ok(out)
    }

    /// Core submission, under the state lock. Returns the job id.
    fn submit_locked(&self, st: &mut State, job: Job) -> u64 {
        let id = st.next_id;
        st.next_id += 1;
        st.stats.cells += 1;

        let cfg = job.cfg.clone().unwrap_or_else(|| self.base.clone());
        let overridden = job.cfg.as_ref().is_some_and(|c| *c != self.base);
        let mut entry = JobEntry {
            params: job.params,
            cfg,
            label: String::new(),
            verbose: job.verbose,
            status: JobStatus::Queued,
            outcome: None,
            abandoned: false,
        };

        // Validate before normalizing: `with_threads` asserts on zero.
        let checked = validate_job(&entry.params, &entry.cfg);
        if let Err(e) = checked {
            entry.status = JobStatus::Failed;
            entry.outcome = Some(Err(e.to_string()));
            st.table.insert(id, entry);
            return id;
        }
        // Normalize to the cell-level (thread 0) view so a job built from
        // a per-thread `TraceParams` shares the cell's cache identity.
        entry.params = entry.params.with_threads(0, entry.params.threads);
        entry.label =
            job.label.unwrap_or_else(|| job_label(&entry.params, overridden));

        let key = CellKey::new(entry.params, entry.cfg.clone());
        if let Some(result) = st.cache.get(&key) {
            st.stats.cache_hits += 1;
            entry.status = JobStatus::Done;
            entry.outcome = Some(Ok(result));
            st.table.insert(id, entry);
            return id;
        }
        if let Some(&leader) = st.leaders.get(&key) {
            // Join the in-flight run: exactly-once execution per key.
            st.stats.cache_hits += 1;
            entry.status = st.table.get(&leader).map(|e| e.status).unwrap_or(JobStatus::Queued);
            st.followers.entry(leader).or_default().push(id);
            st.table.insert(id, entry);
            return id;
        }
        st.stats.unique_runs += 1;
        st.stats.cache_misses += 1;
        st.leaders.insert(key, id);
        st.queue.push_back(id);
        st.table.insert(id, entry);
        self.shared.work_cv.notify_one();
        id
    }
}

impl Drop for SimService {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            // Fail whatever never reached a worker so waiters can't hang;
            // in-flight leaders complete normally before workers exit.
            while let Some(id) = st.queue.pop_front() {
                let mut ids = vec![id];
                ids.extend(st.followers.remove(&id).unwrap_or_default());
                for jid in ids {
                    if let Some(e) = st.table.get_mut(&jid) {
                        e.status = JobStatus::Failed;
                        e.outcome =
                            Some(Err("service shut down before the job ran".to_string()));
                    }
                }
            }
        }
        self.shared.work_cv.notify_all();
        self.shared.done_cv.notify_all();
        for handle in std::mem::take(&mut self.workers) {
            let _ = handle.join();
        }
    }
}

/// Submission-time validation: everything that would otherwise panic in a
/// worker (`Machine::new` thread bounds) or fail later anyway
/// (`TraceParams::check`).
fn validate_job(params: &TraceParams, cfg: &SystemConfig) -> Result<()> {
    crate::ensure!(params.threads >= 1, "job needs at least one thread");
    crate::ensure!(
        params.threads <= cfg.core.num_cores,
        "job wants {} threads but the config has {} cores",
        params.threads,
        cfg.core.num_cores
    );
    // Invalid memory geometry (vault/bank/cube counts...) fails here with
    // the config's typed error instead of inside a worker.
    cfg.validate()?;
    params.check()
}

/// Progress label (mirrors `RunCell::label`, which the sweep engine
/// printed before the service existed).
fn job_label(params: &TraceParams, overridden: bool) -> String {
    let mut s = format!(
        "{}/{} {:.1}MB x{}",
        workload::name(params.workload),
        params.backend,
        params.footprint as f64 / (1 << 20) as f64,
        params.threads
    );
    if params.vector_bytes != 8192 {
        s += &format!(" vb={}", params.vector_bytes);
    }
    if overridden {
        s += " [cfg]";
    }
    s
}

/// `jobs = 0` means `available_parallelism()`.
pub(crate) fn resolve_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        jobs
    }
}

/// Worker body: claim a leader, simulate it on a pooled machine, publish
/// the outcome to the leader and everyone who joined it.
fn worker_loop(shared: Arc<Shared>, pool_capacity: usize) {
    let mut pool = MachinePool::with_capacity(pool_capacity);
    loop {
        let (id, params, cfg, label, verbose) = {
            let mut st = shared.state.lock().unwrap();
            let id = loop {
                if let Some(id) = st.queue.pop_front() {
                    break id;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            };
            let follower_ids = st.followers.get(&id).cloned().unwrap_or_default();
            for jid in std::iter::once(id).chain(follower_ids) {
                if let Some(e) = st.table.get_mut(&jid) {
                    e.status = JobStatus::Running;
                }
            }
            let e = st.table.get(&id).expect("leader entry");
            (id, e.params, e.cfg.clone(), e.label.clone(), e.verbose)
        };

        if verbose {
            eprintln!("[vima-sim] run {label}");
        }
        let outcome = match catch_unwind(AssertUnwindSafe(|| {
            run_on(pool.get(&cfg, params.threads)?, params)
        })) {
            Ok(Ok(result)) => Ok(Arc::new(result)),
            Ok(Err(e)) => Err(e.to_string()),
            Err(panic) => {
                // The machine may be mid-run: never reuse it.
                pool.discard(&cfg, params.threads);
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".to_string());
                Err(format!("simulation panicked: {msg}"))
            }
        };

        let mut st = shared.state.lock().unwrap();
        let key = CellKey::new(params, cfg);
        if let Ok(result) = &outcome {
            let evicted = st.cache.insert(key.clone(), Arc::clone(result));
            st.stats.evictions += evicted;
        }
        st.leaders.remove(&key);
        let mut ids = vec![id];
        ids.extend(st.followers.remove(&id).unwrap_or_default());
        for jid in ids {
            let abandoned = st.table.get(&jid).map(|e| e.abandoned).unwrap_or(true);
            if abandoned {
                st.table.remove(&jid);
                continue;
            }
            let e = st.table.get_mut(&jid).expect("checked above");
            e.status = if outcome.is_ok() { JobStatus::Done } else { JobStatus::Failed };
            e.outcome = Some(outcome.clone());
        }
        drop(st);
        shared.done_cv.notify_all();
    }
}

/// The process-default service behind `sim::simulate` /
/// `sim::simulate_threads`: default config base, `available_parallelism()`
/// workers, default cache bound. Built lazily on first use and never torn
/// down (idle workers just sleep on the queue).
pub fn default_service() -> &'static SimService {
    static DEFAULT: OnceLock<SimService> = OnceLock::new();
    DEFAULT.get_or_init(|| SimService::new(ServiceConfig::default()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Backend, KernelId};

    fn params(kernel: KernelId, backend: Backend, mb: u64) -> TraceParams {
        TraceParams::new(kernel, backend, mb << 20)
    }

    fn small_service(jobs: usize) -> SimService {
        SimService::new(ServiceConfig { jobs, ..ServiceConfig::default() })
    }

    #[test]
    fn submit_and_wait_round_trip() {
        let svc = small_service(2);
        let h = svc.submit(Job::new(params(KernelId::MemSet, Backend::Avx, 1)));
        let r = h.wait().unwrap();
        assert!(r.cycles > 0);
        assert_eq!(h.status(), JobStatus::Done);
    }

    #[test]
    fn duplicate_submissions_share_one_run() {
        let svc = small_service(2);
        let job = Job::new(params(KernelId::MemSet, Backend::Vima, 1));
        let handles = svc.submit_batch(vec![job.clone(), job.clone(), job]);
        let results: Vec<_> = handles.iter().map(|h| h.wait().unwrap()).collect();
        assert_eq!(results[0].cycles, results[1].cycles);
        assert_eq!(results[0].cycles, results[2].cycles);
        let stats = svc.stats();
        assert_eq!(stats.cells, 3);
        assert_eq!(stats.unique_runs, 1);
        assert_eq!(stats.cache_hits, 2);
        assert_eq!(stats.cache_misses, 1);
    }

    #[test]
    fn invalid_job_fails_fast_with_typed_error() {
        let svc = small_service(1);
        // MLP has no HIVE lowering.
        let h = svc.submit(Job::new(params(KernelId::Mlp, Backend::Hive, 4)));
        assert_eq!(h.status(), JobStatus::Failed);
        let e = h.wait().unwrap_err().to_string();
        assert!(e.contains("HIVE"), "{e}");

        // Thread counts beyond the config are a typed error, not a panic.
        let mut p = params(KernelId::MemSet, Backend::Avx, 1);
        p.threads = 10_000;
        let e = svc.submit(Job::new(p)).wait().unwrap_err().to_string();
        assert!(e.contains("threads"), "{e}");
    }

    #[test]
    fn cache_eviction_is_bounded_and_counted() {
        let svc = SimService::new(ServiceConfig {
            jobs: 1,
            cache_capacity: 2,
            ..ServiceConfig::default()
        });
        for mb in [1u64, 2, 3] {
            svc.submit(Job::new(params(KernelId::MemSet, Backend::Avx, mb))).wait().unwrap();
        }
        assert_eq!(svc.cached_cells(), 2);
        let stats = svc.stats();
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.unique_runs, 3);
        // The evicted (least-recently-touched) cell re-simulates...
        svc.submit(Job::new(params(KernelId::MemSet, Backend::Avx, 1))).wait().unwrap();
        assert_eq!(svc.stats().unique_runs, 4);
        // ...while a resident cell is a pure hit.
        svc.submit(Job::new(params(KernelId::MemSet, Backend::Avx, 3))).wait().unwrap();
        assert_eq!(svc.stats().unique_runs, 4);
    }

    #[test]
    fn machine_pool_reuses_and_evicts() {
        let cfg = SystemConfig::default();
        let mut pool = MachinePool::with_capacity(2);
        pool.get(&cfg, 1).unwrap();
        pool.get(&cfg, 1).unwrap();
        assert_eq!((pool.builds, pool.reuses), (1, 1));
        pool.get(&cfg, 2).unwrap();
        assert_eq!(pool.len(), 2);
        pool.get(&cfg, 4).unwrap(); // overflows: evicts the LRU (threads=1) machine
        assert_eq!(pool.len(), 2);
        pool.get(&cfg, 1).unwrap(); // rebuild after eviction
        assert_eq!((pool.builds, pool.reuses), (4, 1));
    }

    #[test]
    fn results_match_the_plain_entry_points() {
        let svc = small_service(2);
        let p = params(KernelId::VecSum, Backend::Vima, 1);
        let via_service = svc.submit(Job::new(p)).wait().unwrap();
        let direct = crate::sim::simulate(&SystemConfig::default(), p).unwrap();
        assert_eq!(via_service.cycles, direct.cycles);
        assert_eq!(via_service.report, direct.report);
    }
}
