//! The JSONL request/response protocol — vima-sim's one wire vocabulary.
//!
//! One request per line, one response per line. This module owns the
//! *grammar* (hand-rolled flat JSON — the offline build is
//! dependency-free): parsing request lines into [`Job`]s and emitting
//! response lines. The *session* machinery that pumps a request stream
//! against a [`SimService`] (bounded in-flight window, ordered
//! responses, timeouts, control ops, graceful drain) lives in
//! [`net::session`](crate::net::session); `vima-sim serve` (stdin/stdout)
//! and `vima-sim net serve` (TCP/Unix socket) are two transports over
//! that single implementation.
//!
//! ```text
//! {"id": 1, "workload": "vecsum", "backend": "vima", "mb": 4, "threads": 2}
//! ```
//!
//! Fields: `workload` (registry name, required), `backend`
//! (`avx`/`vima`/`hive`, required), one of `mb` (MiB) or `footprint`
//! (bytes) — default is the workload's own footprint — plus optional
//! `threads` (default 1), `vector_bytes` (default 8192), and `id`, an
//! arbitrary scalar echoed verbatim in the response. Network sessions
//! (DESIGN.md §14) add three optional fields: `timeout_ms` (answer with a
//! typed `timeout` line if the job has not settled in time), `cfg` (a
//! full `SystemConfig` as TOML text, the coordinator→worker transport of
//! the effective config), and `wire` (`true` asks for the bit-exact
//! [`wire`](crate::net::wire)-encoded result in the response). A line
//! whose only meaningful field is `op` is a **control request**
//! (`ping`/`stats`/`shutdown`), handled by the session layer.
//!
//! Responses (same order as the requests; the service still simulates the
//! whole in-flight window in parallel and dedups identical cells):
//!
//! ```text
//! {"id": 1, "status": "done", "workload": "VecSum", "backend": "VIMA", "threads": 2, "cycles": 123456, "seconds": 0.000041, "energy_j": 0.000972}
//! {"id": 2, "status": "failed", "error": "unknown backend \"neon\"; valid backends: avx, vima, hive"}
//! {"id": 3, "status": "timeout", "error": "job exceeded timeout_ms 50"}
//! ```
//!
//! A malformed line is answered with a `failed` response and the stream
//! keeps serving — a bad request must never take the service down.

use std::io::{BufRead, Write};

use crate::bail;
use crate::config::SystemConfig;
use crate::service::{Job, SimService};
use crate::trace::{Backend, TraceParams};
use crate::util::error::{Context, Error, Result};
use crate::workload;

/// A scalar JSON value (the protocol is flat by design).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Null,
}

impl JsonValue {
    /// Re-serialize the value as a JSON token (used to echo `id`).
    fn to_json(&self) -> String {
        match self {
            JsonValue::Str(s) => format!("\"{}\"", escape(s)),
            JsonValue::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            JsonValue::Bool(b) => b.to_string(),
            JsonValue::Null => "null".to_string(),
        }
    }
}

/// Escape a string for embedding in a JSON document.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse one flat JSON object (`{"k": scalar, ...}`) into key/value pairs
/// in document order. Nested objects/arrays are a typed error.
pub fn parse_flat_object(line: &str) -> Result<Vec<(String, JsonValue)>> {
    let mut p = Parser { s: line.as_bytes(), i: 0 };
    p.ws();
    p.eat(b'{')?;
    let mut fields = Vec::new();
    p.ws();
    if p.peek() == Some(b'}') {
        p.i += 1;
    } else {
        loop {
            p.ws();
            let key = p.string()?;
            p.eat(b':')?;
            let value = p.value()?;
            fields.push((key, value));
            p.ws();
            match p.next_byte()? {
                b',' => continue,
                b'}' => break,
                other => bail!("expected ',' or '}}' after a field, got {:?}", other as char),
            }
        }
    }
    p.ws();
    if p.i != p.s.len() {
        bail!("trailing bytes after the JSON object");
    }
    Ok(fields)
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn next_byte(&mut self) -> Result<u8> {
        let b = self.peek().context("unexpected end of request line")?;
        self.i += 1;
        Ok(b)
    }

    fn eat(&mut self, want: u8) -> Result<()> {
        self.ws();
        match self.peek() {
            Some(b) if b == want => {
                self.i += 1;
                Ok(())
            }
            Some(b) => bail!("expected {:?}, got {:?}", want as char, b as char),
            None => bail!("expected {:?}, got end of line", want as char),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out: Vec<u8> = Vec::new();
        loop {
            match self.next_byte()? {
                b'"' => break,
                b'\\' => {
                    let esc = self.next_byte()?;
                    match esc {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.next_byte()?;
                                let d = (h as char)
                                    .to_digit(16)
                                    .with_context(|| format!("bad \\u hex digit {:?}", h as char))?;
                                code = code * 16 + d;
                            }
                            let c = char::from_u32(code)
                                .context("surrogate \\u escapes are not supported")?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                        }
                        other => bail!("unsupported escape \\{}", other as char),
                    }
                }
                b => out.push(b),
            }
        }
        String::from_utf8(out).map_err(|_| Error::msg("request string is not valid UTF-8"))
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.ws();
        match self.peek() {
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') if self.s[self.i..].starts_with(b"true") => {
                self.i += 4;
                Ok(JsonValue::Bool(true))
            }
            Some(b'f') if self.s[self.i..].starts_with(b"false") => {
                self.i += 5;
                Ok(JsonValue::Bool(false))
            }
            Some(b'n') if self.s[self.i..].starts_with(b"null") => {
                self.i += 4;
                Ok(JsonValue::Null)
            }
            Some(b'{') | Some(b'[') => {
                bail!("nested objects/arrays are not part of the flat JSONL protocol")
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let start = self.i;
                while self
                    .peek()
                    .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
                {
                    self.i += 1;
                }
                let text = std::str::from_utf8(&self.s[start..self.i]).unwrap_or("");
                let n: f64 = text
                    .parse()
                    .with_context(|| format!("bad number {text:?}"))?;
                if !n.is_finite() {
                    // `1e999` parses to inf; echoing it back (e.g. as an
                    // `id`) would emit a line no JSON parser accepts.
                    bail!("number out of range: {text}");
                }
                Ok(JsonValue::Num(n))
            }
            Some(c) => bail!("unexpected value starting with {:?}", c as char),
            None => bail!("missing value"),
        }
    }
}

/// The request's `id` token, re-serialized for echoing (if present).
pub fn request_id(fields: &[(String, JsonValue)]) -> Option<String> {
    fields.iter().find(|(k, _)| k == "id").map(|(_, v)| v.to_json())
}

fn field_str<'a>(v: &'a JsonValue, key: &str) -> Result<&'a str> {
    match v {
        JsonValue::Str(s) => Ok(s),
        other => bail!("field {key:?} must be a string, got {}", other.to_json()),
    }
}

fn field_num(v: &JsonValue, key: &str) -> Result<f64> {
    match v {
        JsonValue::Num(n) => Ok(*n),
        other => bail!("field {key:?} must be a number, got {}", other.to_json()),
    }
}

fn field_count(v: &JsonValue, key: &str) -> Result<u64> {
    let n = field_num(v, key)?;
    if n < 1.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
        bail!("field {key:?} must be a positive integer, got {n}");
    }
    Ok(n as u64)
}

fn field_bool(v: &JsonValue, key: &str) -> Result<bool> {
    match v {
        JsonValue::Bool(b) => Ok(*b),
        other => bail!("field {key:?} must be a boolean, got {}", other.to_json()),
    }
}

/// A session control request: a line whose `op` field names an action
/// instead of a simulation. Answered in request order like any job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Liveness probe; acked immediately.
    Ping,
    /// Scheduler accounting snapshot (cells, unique runs, cache traffic).
    Stats,
    /// Graceful drain: ack this line, answer everything already in
    /// flight, flush, then end the session.
    Shutdown,
}

/// Detect a control request. `Ok(None)` means the line is a job request.
pub fn request_op(fields: &[(String, JsonValue)]) -> Result<Option<Op>> {
    let Some((_, v)) = fields.iter().find(|(k, _)| k == "op") else {
        return Ok(None);
    };
    let op = match field_str(v, "op")? {
        "ping" => Op::Ping,
        "stats" => Op::Stats,
        "shutdown" => Op::Shutdown,
        other => bail!("unknown op {other:?}; expected ping, stats, shutdown"),
    };
    for (key, _) in fields {
        if key != "op" && key != "id" {
            bail!("op request carries unexpected field {key:?} (only \"id\" may accompany \"op\")");
        }
    }
    Ok(Some(op))
}

/// A fully parsed job request: the [`Job`] plus session-level options.
#[derive(Debug)]
pub struct RequestSpec {
    pub job: Job,
    /// Answer with a typed `timeout` line if the job has not settled
    /// within this many milliseconds of submission.
    pub timeout_ms: Option<u64>,
    /// Attach the bit-exact [`wire`](crate::net::wire)-encoded result to
    /// the `done` line (coordinator→worker traffic sets this).
    pub wire: bool,
}

/// Turn a parsed request into a [`RequestSpec`] (the service validates
/// the cell itself at submission; this resolves names and shapes the
/// parameters).
pub fn request_spec(fields: &[(String, JsonValue)]) -> Result<RequestSpec> {
    let mut workload_name: Option<&str> = None;
    let mut backend: Option<&str> = None;
    let mut mb: Option<f64> = None;
    let mut footprint: Option<u64> = None;
    let mut threads: u64 = 1;
    let mut vector_bytes: Option<u64> = None;
    let mut cfg: Option<SystemConfig> = None;
    let mut timeout_ms: Option<u64> = None;
    let mut wire = false;
    for (key, value) in fields {
        match key.as_str() {
            "id" => {}
            "workload" => workload_name = Some(field_str(value, key)?),
            "backend" => backend = Some(field_str(value, key)?),
            "mb" => mb = Some(field_num(value, key)?),
            "footprint" => footprint = Some(field_count(value, key)?),
            "threads" => threads = field_count(value, key)?,
            "vector_bytes" => vector_bytes = Some(field_count(value, key)?),
            "cfg" => {
                let toml = field_str(value, key)?;
                cfg = Some(
                    SystemConfig::from_toml_str(toml)
                        .map_err(|e| e.context("field \"cfg\" is not a valid config TOML"))?,
                );
            }
            "timeout_ms" => timeout_ms = Some(field_count(value, key)?),
            "wire" => wire = field_bool(value, key)?,
            "op" => bail!("\"op\" cannot be combined with job fields"),
            other => bail!(
                "unknown request field {other:?}; expected id, workload, backend, \
                 mb, footprint, threads, vector_bytes, cfg, timeout_ms, wire, op"
            ),
        }
    }
    let workload_name = workload_name.context("request is missing \"workload\"")?;
    let id = workload::resolve(workload_name)?;
    let backend: Backend = backend.context("request is missing \"backend\"")?.parse()?;
    let footprint = match (footprint, mb) {
        (Some(bytes), _) => bytes,
        (None, Some(mb)) => {
            if !mb.is_finite() || mb <= 0.0 {
                bail!("field \"mb\" must be a positive number, got {mb}");
            }
            (mb * (1u64 << 20) as f64) as u64
        }
        (None, None) => workload::get(id)?.default_footprint(),
    };
    let mut params = TraceParams::new(id, backend, footprint);
    if let Some(vb) = vector_bytes {
        if vb > u32::MAX as u64 {
            bail!("field \"vector_bytes\" is too large: {vb}");
        }
        params = params.with_vector_bytes(vb as u32);
    }
    params.threads = threads as usize;
    let mut job = Job::new(params);
    job.cfg = cfg;
    Ok(RequestSpec { job, timeout_ms, wire })
}

/// Turn a parsed request into a bare [`Job`] (compatibility surface over
/// [`request_spec`]).
pub fn request_job(fields: &[(String, JsonValue)]) -> Result<Job> {
    request_spec(fields).map(|spec| spec.job)
}

/// Success response line.
pub fn response_ok(id: Option<&str>, params: &TraceParams, r: &crate::sim::SimResult) -> String {
    let mut s = String::from("{");
    if let Some(id) = id {
        s += &format!("\"id\": {id}, ");
    }
    s += &format!(
        "\"status\": \"done\", \"workload\": \"{}\", \"backend\": \"{}\", \
         \"threads\": {}, \"cycles\": {}, \"seconds\": {:.9}, \"energy_j\": {:.9}}}",
        escape(&workload::name(params.workload)),
        params.backend,
        params.threads,
        r.cycles,
        r.seconds,
        r.energy.total_j
    );
    s
}

/// Success response line for the session layer: [`response_ok`] plus,
/// when the request set `"wire": true`, the bit-exact encoded result.
/// With `wire = false` the line is byte-identical to [`response_ok`].
pub fn response_done(
    id: Option<&str>,
    params: &TraceParams,
    r: &crate::sim::SimResult,
    wire: bool,
) -> Result<String> {
    let mut s = response_ok(id, params, r);
    if wire {
        let encoded = crate::net::wire::encode_result(r)?;
        s.pop(); // the closing '}'
        s += &format!(", \"result\": \"{}\"}}", escape(&encoded));
    }
    Ok(s)
}

/// Failure response line.
pub fn response_err(id: Option<&str>, error: &str) -> String {
    let mut s = String::from("{");
    if let Some(id) = id {
        s += &format!("\"id\": {id}, ");
    }
    s + &format!("\"status\": \"failed\", \"error\": \"{}\"}}", escape(error))
}

/// Typed timeout response line. The job itself keeps running server-side
/// (and lands in the result cache); only this request's answer gave up
/// waiting.
pub fn response_timeout(id: Option<&str>, timeout_ms: u64) -> String {
    let mut s = String::from("{");
    if let Some(id) = id {
        s += &format!("\"id\": {id}, ");
    }
    s + &format!("\"status\": \"timeout\", \"error\": \"job exceeded timeout_ms {timeout_ms}\"}}")
}

/// Totals of one [`serve`] session.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeSummary {
    pub requests: u64,
    pub ok: u64,
    pub failed: u64,
}

/// Backpressure bound: how many requests may be in flight (submitted but
/// not yet answered) before the reader stops pulling from the transport.
/// Keeps a multi-million-line input from materializing its whole job
/// table in memory — peak usage is O(window), not O(total requests) —
/// while still giving the scheduler a deep parallel window.
pub const SERVE_WINDOW: usize = 256;

/// Serve JSONL requests from `input` until EOF, writing one response line
/// per request to `output` **in request order**. This is the stdin/stdout
/// transport over [`net::session::run_session`](crate::net::session::run_session)
/// — the exact machinery behind every `vima-sim net serve` connection —
/// with the default [`SERVE_WINDOW`] backpressure bound. Reading and
/// responding are decoupled, so a harness may stream requests and read
/// responses concurrently without deadlocking, and every job in the
/// in-flight window runs through the service's parallel scheduler.
pub fn serve<W: Write + Send>(
    service: &SimService,
    input: impl BufRead,
    output: W,
) -> Result<ServeSummary> {
    let opts = crate::net::session::SessionOptions::default();
    let ctl = crate::net::session::SessionCtl::new();
    let s = crate::net::session::run_session(service, input, output, &opts, &ctl)?;
    Ok(ServeSummary {
        requests: s.requests,
        ok: s.ok,
        failed: s.failed + s.timeouts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_objects() {
        let f = parse_flat_object(
            r#"{"id": 7, "workload": "vecsum", "quick": true, "note": "a\"b", "x": null}"#,
        )
        .unwrap();
        assert_eq!(f[0], ("id".to_string(), JsonValue::Num(7.0)));
        assert_eq!(f[1], ("workload".to_string(), JsonValue::Str("vecsum".into())));
        assert_eq!(f[2], ("quick".to_string(), JsonValue::Bool(true)));
        assert_eq!(f[3], ("note".to_string(), JsonValue::Str("a\"b".into())));
        assert_eq!(f[4], ("x".to_string(), JsonValue::Null));
        assert!(parse_flat_object("{}").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in [
            "not json",
            "{\"a\": }",
            "{\"a\": 1",
            "{\"a\": {\"nested\": 1}}",
            "{\"a\": [1]}",
            "{\"a\": 1} trailing",
            "{\"a\": 1e999}", // overflows f64: would echo as invalid JSON

        ] {
            assert!(parse_flat_object(bad).is_err(), "{bad} must fail");
        }
    }

    #[test]
    fn unicode_escapes_decode() {
        let f = parse_flat_object(r#"{"s": "café\n"}"#).unwrap();
        assert_eq!(f[0].1, JsonValue::Str("café\n".into()));
    }

    #[test]
    fn request_to_job_defaults_and_overrides() {
        let fields =
            parse_flat_object(r#"{"workload": "vecsum", "backend": "vima", "mb": 2, "threads": 2}"#)
                .unwrap();
        let job = request_job(&fields).unwrap();
        assert_eq!(job.params.footprint, 2 << 20);
        assert_eq!(job.params.threads, 2);
        assert_eq!(job.params.vector_bytes, 8192);

        // Missing required fields and unknown names are typed errors.
        let missing = parse_flat_object(r#"{"backend": "vima"}"#).unwrap();
        assert!(request_job(&missing).unwrap_err().to_string().contains("workload"));
        let unknown =
            parse_flat_object(r#"{"workload": "vecsum", "backend": "neon"}"#).unwrap();
        let e = request_job(&unknown).unwrap_err().to_string();
        assert!(e.contains("valid backends"), "{e}");
    }

    #[test]
    fn id_tokens_echo_verbatim() {
        let f = parse_flat_object(r#"{"id": "a-1", "workload": "x"}"#).unwrap();
        assert_eq!(request_id(&f).as_deref(), Some("\"a-1\""));
        let f = parse_flat_object(r#"{"id": 42}"#).unwrap();
        assert_eq!(request_id(&f).as_deref(), Some("42"));
        assert_eq!(request_id(&[]), None);
    }

    #[test]
    fn response_lines_are_flat_json() {
        let err = response_err(Some("7"), "boom \"quoted\"");
        assert_eq!(err, r#"{"id": 7, "status": "failed", "error": "boom \"quoted\""}"#);
        assert!(parse_flat_object(&err).is_ok(), "{err}");
        let t = response_timeout(Some("3"), 50);
        assert_eq!(t, r#"{"id": 3, "status": "timeout", "error": "job exceeded timeout_ms 50"}"#);
        assert!(parse_flat_object(&t).is_ok(), "{t}");
    }

    #[test]
    fn session_fields_parse() {
        let cfg = SystemConfig::default();
        let line = format!(
            r#"{{"workload": "vecsum", "backend": "vima", "timeout_ms": 250, "wire": true, "cfg": "{}"}}"#,
            escape(&cfg.to_toml())
        );
        let spec = request_spec(&parse_flat_object(&line).unwrap()).unwrap();
        assert_eq!(spec.timeout_ms, Some(250));
        assert!(spec.wire);
        assert_eq!(spec.job.cfg.as_ref(), Some(&cfg));

        // A bad cfg payload is a typed error naming the field.
        let bad = parse_flat_object(r#"{"workload": "x", "backend": "vima", "cfg": "!!"}"#).unwrap();
        let e = request_spec(&bad).unwrap_err().to_string();
        assert!(e.contains("cfg"), "{e}");
    }

    #[test]
    fn ops_parse_and_reject_mixed_lines() {
        let f = parse_flat_object(r#"{"id": 1, "op": "ping"}"#).unwrap();
        assert_eq!(request_op(&f).unwrap(), Some(Op::Ping));
        let f = parse_flat_object(r#"{"op": "shutdown"}"#).unwrap();
        assert_eq!(request_op(&f).unwrap(), Some(Op::Shutdown));
        let f = parse_flat_object(r#"{"workload": "vecsum"}"#).unwrap();
        assert_eq!(request_op(&f).unwrap(), None);
        let f = parse_flat_object(r#"{"op": "reboot"}"#).unwrap();
        assert!(request_op(&f).is_err());
        let f = parse_flat_object(r#"{"op": "ping", "workload": "vecsum"}"#).unwrap();
        assert!(request_op(&f).is_err());
    }
}
