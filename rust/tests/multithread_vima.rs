//! Multi-threaded NDP tests: the paper's Sec. III-E claim that VIMA's
//! lock-free design "enable[s] a multi-threaded environment by not locking
//! any structure", vs HIVE whose whole-bank lock serializes threads.

use vima_sim::config::SystemConfig;
use vima_sim::sim::{simulate_threads, Machine};
use vima_sim::trace::{Backend, KernelId, TraceParams};

#[test]
fn vima_multithread_fills_stop_and_go_gaps() {
    // Sec. III-E: VIMA "enable[s] a multi-threaded environment by not
    // locking any structure". Two threads' stop-and-go round trips overlap
    // on the shared device for a streaming kernel (no cache contention).
    let cfg = SystemConfig::default();
    let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 24 << 20);
    let t1 = simulate_threads(&cfg, p, 1).unwrap();
    let t2 = simulate_threads(&cfg, p, 2).unwrap();
    assert!(
        t2.cycles < t1.cycles,
        "2-thread VIMA must overlap dispatch gaps: {} vs {}",
        t2.cycles,
        t1.cycles
    );
}

#[test]
fn vima_multithread_reuse_kernels_may_thrash_but_never_deadlock() {
    // With reuse-heavy kernels, two threads can exceed the 8-line VIMA
    // cache (more threads is not always faster — a real design property);
    // the run must still complete, deterministically, without locking.
    let cfg = SystemConfig::default();
    let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 8 << 20);
    let t4a = simulate_threads(&cfg, p, 4).unwrap();
    let t4b = simulate_threads(&cfg, p, 4).unwrap();
    assert_eq!(t4a.cycles, t4b.cycles);
    assert!(t4a.cycles > 0);
    // a 4x larger cache restores the reuse for 4 threads
    let mut big = cfg.clone();
    big.vima.cache_bytes = 256 << 10;
    let t4_big = simulate_threads(&big, p, 4).unwrap();
    assert!(t4_big.cycles <= t4a.cycles);
}

#[test]
fn hive_lock_serializes_threads() {
    // HIVE's register bank is locked per transaction (Sec. III-E): adding
    // threads cannot scale the way VIMA does, because every transaction
    // waits for the bank.
    let cfg = SystemConfig::default();
    let p = TraceParams::new(KernelId::VecSum, Backend::Hive, 12 << 20);
    let t1 = simulate_threads(&cfg, p, 1).unwrap();
    let t4 = simulate_threads(&cfg, p, 4).unwrap();
    let hive_scaling = t1.cycles as f64 / t4.cycles as f64;
    // The lock holds the bank for the whole load/compute/writeback span;
    // scaling must be well below ideal.
    assert!(
        hive_scaling < 2.0,
        "HIVE should serialize on the bank lock: {hive_scaling:.2}x at 4 threads"
    );
    let lock_wait = t4.report.get("hive.lock_wait_cycles").unwrap_or(0.0);
    assert!(lock_wait > 0.0, "threads must contend on the lock");
}

#[test]
fn vima_multithread_shares_the_vcache_coherently() {
    // Two threads running stencil on disjoint halves still share the VIMA
    // cache; the run must stay deterministic and account every fetch.
    let cfg = SystemConfig::default();
    let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 8 << 20);
    let a = simulate_threads(&cfg, p, 2).unwrap();
    let b = simulate_threads(&cfg, p, 2).unwrap();
    assert_eq!(a.cycles, b.cycles, "multithreaded VIMA must stay deterministic");
    let hits = a.report.get("vima.vcache_hits").unwrap();
    let misses = a.report.get("vima.vcache_misses").unwrap();
    let fetches = a.report.get("vima.vector_fetches").unwrap();
    assert_eq!(hits + misses, fetches);
}

#[test]
fn intrinsics_programs_run_per_thread() {
    // Two hand-built Intrinsics-VIMA programs on two cores.
    use vima_sim::intrinsics::VimaProgram;
    let cfg = SystemConfig::default();
    let mut machine = Machine::new(&cfg, 2).unwrap();
    let mut progs = Vec::new();
    for t in 0..2u64 {
        let mut p = VimaProgram::new();
        // separate heaps per thread
        for _ in 0..t {
            p.alloc(1 << 20);
        }
        let a = p.alloc(8192);
        let b = p.alloc(8192);
        let c = p.alloc(8192);
        p.vim2k_sets(a);
        p.vim2k_sets(b);
        for _ in 0..8 {
            p.vim2k_adds(a, b, c);
        }
        progs.push(p.into_stream());
    }
    let r = machine.run(progs).unwrap();
    assert_eq!(r.report.get("vima.instructions"), Some(2.0 * (2.0 + 8.0)));
}
