//! Property-based tests on simulator invariants (in-tree `util::proptest`
//! driver — the offline build has no proptest crate, the methodology is the
//! same: randomized cases with reproducible seeds).

use std::collections::VecDeque;

use vima_sim::config::SystemConfig;
use vima_sim::sim::{simulate, simulate_threads};
use vima_sim::trace::{Backend, KernelId, TraceParams};
use vima_sim::util::{proptest, Rng};
use vima_sim::vima::VCache;

const KERNELS: [KernelId; 7] = [
    KernelId::MemSet,
    KernelId::MemCopy,
    KernelId::VecSum,
    KernelId::Stencil,
    KernelId::MatMul,
    KernelId::Knn,
    KernelId::Mlp,
];

fn random_params(rng: &mut Rng) -> TraceParams {
    let kernel = *rng.pick(&KERNELS);
    let backend = if rng.bool() { Backend::Avx } else { Backend::Vima };
    let footprint = (1 << 20) << rng.below(3); // 1..4 MB
    TraceParams::new(kernel, backend, footprint)
}

#[test]
fn simulation_is_deterministic() {
    proptest(8, |rng| {
        let p = random_params(rng);
        let cfg = SystemConfig::default();
        let a = simulate(&cfg, p).unwrap();
        let b = simulate(&cfg, p).unwrap();
        assert_eq!(a.cycles, b.cycles, "{p:?}");
        assert_eq!(a.report, b.report, "{p:?}");
    });
}

#[test]
fn cycles_and_energy_are_positive_and_consistent() {
    proptest(10, |rng| {
        let p = random_params(rng);
        let r = simulate(&SystemConfig::default(), p).unwrap();
        assert!(r.cycles > 0, "{p:?}");
        assert!(r.energy.total_j > 0.0, "{p:?}");
        let sum = r.energy.core_j
            + r.energy.cache_dynamic_j
            + r.energy.cache_static_j
            + r.energy.dram_dynamic_j
            + r.energy.dram_static_j
            + r.energy.vima_j;
        assert!((r.energy.total_j - sum).abs() < 1e-9, "{p:?}");
    });
}

#[test]
fn cache_counters_are_coherent() {
    proptest(10, |rng| {
        let p = random_params(rng);
        let r = simulate(&SystemConfig::default(), p).unwrap();
        let g = |k: &str| r.report.get(k).unwrap_or(0.0);
        // hits + misses == accesses at every level
        for lvl in ["l1d", "l2", "llc"] {
            let acc = g(&format!("{lvl}.accesses"));
            let h = g(&format!("{lvl}.hits"));
            let m = g(&format!("{lvl}.misses"));
            assert!((h + m - acc).abs() < 0.5, "{p:?}: {lvl} {h}+{m} != {acc}");
        }
        // loads on an AVX run reach the hierarchy
        if p.backend == Backend::Avx {
            assert!(g("l1d.accesses") >= g("core.loads"), "{p:?}");
        }
    });
}

#[test]
fn thread_slicing_conserves_memory_traffic() {
    proptest(6, |rng| {
        let kernel = *rng.pick(&[KernelId::MemCopy, KernelId::VecSum, KernelId::Stencil]);
        let p = TraceParams::new(kernel, Backend::Avx, 4 << 20);
        let cfg = SystemConfig::default();
        let one = simulate(&cfg, p).unwrap();
        let threads = 1 + rng.below(7) as usize;
        let many = simulate_threads(&cfg, p, threads).unwrap();
        let (a, b) = (
            one.report.get("l1d.misses").unwrap_or(0.0),
            many.report.get("l1d.misses").unwrap_or(0.0),
        );
        // Cold misses are identical work regardless of the thread split
        // (within a few % of boundary effects).
        assert!((a - b).abs() / a.max(1.0) < 0.1, "{kernel}: {a} vs {b} ({threads} thr)");
    });
}

#[test]
fn more_threads_never_substantially_hurt() {
    proptest(4, |rng| {
        let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 4 << 20);
        let cfg = SystemConfig::default();
        let t1 = simulate_threads(&cfg, p, 1).unwrap();
        let tn = simulate_threads(&cfg, p, 2 + rng.below(14) as usize).unwrap();
        assert!(tn.cycles <= t1.cycles + t1.cycles / 10);
    });
}

/// Reference model for the VIMA cache: LRU over full vectors, via VecDeque.
struct RefVCache {
    lines: VecDeque<(u64, bool)>, // front = MRU
    capacity: usize,
}

impl RefVCache {
    fn lookup(&mut self, tag: u64) -> bool {
        if let Some(pos) = self.lines.iter().position(|&(t, _)| t == tag) {
            let e = self.lines.remove(pos).unwrap();
            self.lines.push_front(e);
            true
        } else {
            false
        }
    }

    fn insert(&mut self, tag: u64, dirty: bool) -> Option<u64> {
        if let Some(pos) = self.lines.iter().position(|&(t, _)| t == tag) {
            let mut e = self.lines.remove(pos).unwrap();
            e.1 |= dirty;
            self.lines.push_front(e);
            return None;
        }
        let evicted = if self.lines.len() == self.capacity {
            self.lines.pop_back().filter(|&(_, d)| d).map(|(t, _)| t)
        } else {
            None
        };
        self.lines.push_front((tag, dirty));
        evicted
    }
}

#[test]
fn vcache_matches_reference_lru_model() {
    proptest(25, |rng| {
        let lines = 1 + rng.below(8) as usize;
        let vb = 8192u64;
        let mut dut = VCache::new(lines, vb as usize);
        let mut reference = RefVCache { lines: VecDeque::new(), capacity: lines };
        for _ in 0..300 {
            let tag = rng.below(12) * vb;
            if rng.bool() {
                assert_eq!(dut.lookup(tag), reference.lookup(tag), "lookup({tag:#x})");
            } else {
                let dirty = rng.bool();
                let got = dut.insert(tag, dirty).map(|(a, _)| a);
                let want = reference.insert(tag, dirty);
                assert_eq!(got, want, "insert({tag:#x}, {dirty})");
            }
        }
    });
}

#[test]
fn config_toml_roundtrip_under_random_mutation() {
    proptest(20, |rng| {
        let mut cfg = SystemConfig::default();
        cfg.vima.cache_bytes = (1usize << rng.range(13, 19)) * 8; // 64K..4M
        cfg.vima.vector_bytes = 1 << rng.range(8, 14);
        cfg.llc.mshrs = rng.range(1, 300) as usize;
        cfg.core.rob_entries = rng.range(16, 512) as usize;
        cfg.vima.stop_and_go = rng.bool();
        let text = cfg.to_toml();
        let back = SystemConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg, back);
    });
}

#[test]
fn sampling_extrapolation_scales_cycles() {
    // MatMul sampled rows scale: doubling footprint must not *reduce*
    // extrapolated cycles on either backend.
    let cfg = SystemConfig::default();
    for backend in [Backend::Avx, Backend::Vima] {
        let small = simulate(&cfg, TraceParams::new(KernelId::MatMul, backend, 3 << 20)).unwrap();
        let big = simulate(&cfg, TraceParams::new(KernelId::MatMul, backend, 6 << 20)).unwrap();
        assert!(big.cycles > small.cycles, "{backend}: {} !> {}", big.cycles, small.cycles);
    }
}
