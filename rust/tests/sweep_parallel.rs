//! Integration tests of the sweep engine: parallel execution must be
//! bit-identical to serial execution (the simulator is deterministic per
//! cell; only scheduling changes), and the result cache must dedup the
//! baseline cells the figures share (EXPERIMENTS.md §Dedup).

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::{SizeScale, WorkloadSet};
use vima_sim::coordinator::Experiment;
use vima_sim::sim::SimResult;
use vima_sim::sweep::{RunCell, SweepPlan, SweepRunner};
use vima_sim::trace::{Backend, TraceStream};

/// Compile-time proof that trace streams (and results) can cross into the
/// worker pool.
#[test]
fn trace_streams_are_send() {
    fn assert_send<T: Send>() {}
    assert_send::<TraceStream>();
    assert_send::<SimResult>();
}

#[test]
fn parallel_and_serial_runs_are_bit_identical() {
    let cfg = SystemConfig::default();
    let mut plan = SweepPlan::new();
    // Reduced grid: first four fig2 workloads on all three backends.
    for w in WorkloadSet::fig2(SizeScale::Quick).into_iter().take(4) {
        for b in [Backend::Avx, Backend::Hive, Backend::Vima] {
            plan.push(RunCell::new(w, b));
        }
    }
    let serial = SweepRunner::new(1).run(&cfg, &plan).unwrap();
    let parallel = SweepRunner::new(8).run(&cfg, &plan).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for ((a, b), cell) in serial.iter().zip(&parallel).zip(plan.cells()) {
        assert_eq!(a.cycles, b.cycles, "{}", cell.label());
        assert_eq!(a.report, b.report, "{}", cell.label());
        assert_eq!(a.energy.total_j.to_bits(), b.energy.total_j.to_bits(), "{}", cell.label());
    }
}

#[test]
fn figure_tables_identical_serial_vs_parallel() {
    let a = Experiment::with_jobs(SystemConfig::default(), SizeScale::Quick, 1).fig2().unwrap();
    let b = Experiment::with_jobs(SystemConfig::default(), SizeScale::Quick, 4).fig2().unwrap();
    assert_eq!(a.columns, b.columns);
    assert_eq!(a.rows, b.rows);
}

/// The acceptance criterion of ISSUE 1: a full figure-suite run performs
/// strictly fewer simulations than the seed's per-figure serial loops,
/// because shared cells (AVX baselines, default-config VIMA runs) hit the
/// result cache.
#[test]
fn full_suite_dedup_accounting() {
    let exp = Experiment::with_jobs(SystemConfig::default(), SizeScale::Quick, 0);
    exp.fig2().unwrap();
    exp.fig3().unwrap();
    let after_fig3 = exp.sweep_stats();
    exp.fig4().unwrap();
    let after_fig4 = exp.sweep_stats();
    exp.fig5().unwrap();
    let stats = exp.sweep_stats();

    // The seed's loops simulated every cell: 27 (fig2) + 42 (fig3) +
    // 24 (fig4) + 18 (fig5).
    assert_eq!(stats.cells, 111);
    assert!(
        stats.unique_runs < stats.cells,
        "dedup must shrink the grid: {} of {}",
        stats.unique_runs,
        stats.cells
    );
    assert_eq!(stats.cache_hits, stats.cells - stats.unique_runs);

    // fig4 declares 24 cells; its AVX-1T column is the baseline cell, its
    // baselines/VIMA runs are fig3 cells, so only the 2..32-thread AVX runs
    // (5 x 3 workloads) are new.
    assert_eq!(after_fig4.cells - after_fig3.cells, 24);
    assert_eq!(after_fig4.unique_runs - after_fig3.unique_runs, 15);

    // fig5 declares 18 cells; baselines are cached and the 64 KB point is
    // the Table-I default VIMA config, so 4 sizes x 3 workloads are new.
    assert_eq!(stats.cells - after_fig4.cells, 18);
    assert_eq!(stats.unique_runs - after_fig4.unique_runs, 12);

    // Quick-scale footprints clamp to >= 1 MB, which collapses the two
    // smallest sizes of every kernel; with cross-figure sharing on top the
    // whole 111-cell suite needs exactly 61 simulations.
    assert_eq!(stats.unique_runs, 61);

    // A repeated figure is fully served from the cache.
    exp.fig3().unwrap();
    assert_eq!(exp.sweep_stats().unique_runs, stats.unique_runs);
}
