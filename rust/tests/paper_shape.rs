//! Integration tests asserting the paper's qualitative result *shapes*
//! (who wins, crossovers, rough factors) at reduced-but-meaningful sizes.
//! These are the acceptance criteria of DESIGN.md §5.

use vima_sim::config::SystemConfig;
use vima_sim::sim::{simulate, simulate_threads};
use vima_sim::trace::{Backend, KernelId, TraceParams};

fn cfg() -> SystemConfig {
    SystemConfig::default()
}

fn speedup(kernel: KernelId, bytes: u64) -> f64 {
    let avx = simulate(&cfg(), TraceParams::new(kernel, Backend::Avx, bytes)).unwrap();
    let vima = simulate(&cfg(), TraceParams::new(kernel, Backend::Vima, bytes)).unwrap();
    vima.speedup_vs(&avx)
}

#[test]
fn streaming_kernels_show_large_vima_speedup() {
    // Fig. 3: streaming kernels gain integer factors.
    assert!(speedup(KernelId::MemSet, 8 << 20) > 3.0);
    assert!(speedup(KernelId::MemCopy, 8 << 20) > 3.0);
    assert!(speedup(KernelId::VecSum, 12 << 20) > 4.0);
}

#[test]
fn stencil_benefits_from_vector_reuse() {
    let avx =
        simulate(&cfg(), TraceParams::new(KernelId::Stencil, Backend::Avx, 16 << 20)).unwrap();
    let vima =
        simulate(&cfg(), TraceParams::new(KernelId::Stencil, Backend::Vima, 16 << 20)).unwrap();
    assert!(vima.speedup_vs(&avx) > 1.3, "stencil speedup {}", vima.speedup_vs(&avx));
    // The VIMA cache must be doing real work: rows are reused.
    let hits = vima.report.get("vima.vcache_hits").unwrap();
    let misses = vima.report.get("vima.vcache_misses").unwrap();
    assert!(hits > misses, "expected reuse: {hits} hits vs {misses} misses");
}

#[test]
fn knn_crossover_with_llc_capacity() {
    // Fig. 3 discussion: no/low speedup while the training set fits the LLC,
    // large speedup once it exceeds it (64 MB > 16 MB LLC).
    let small = speedup(KernelId::Knn, 4 << 20);
    let large = speedup(KernelId::Knn, 64 << 20);
    assert!(
        large > small * 1.5,
        "expected LLC crossover: 4MB -> {small:.2}x, 64MB -> {large:.2}x"
    );
}

#[test]
fn mlp_crossover_with_llc_capacity() {
    let small = speedup(KernelId::Mlp, 4 << 20);
    let large = speedup(KernelId::Mlp, 64 << 20);
    assert!(
        large > small,
        "expected LLC crossover: 4MB -> {small:.2}x, 64MB -> {large:.2}x"
    );
}

#[test]
fn matmul_vima_wins_with_same_algorithm() {
    // Sec. IV-B1: same straightforward algorithm on both systems.
    let s = speedup(KernelId::MatMul, 6 << 20);
    assert!(s > 3.0, "MatMul speedup {s}");
}

#[test]
fn avx_multithread_catches_vima_on_vecsum() {
    // Fig. 4: AVX needs on the order of 16 cores to reach VIMA on VecSum.
    let c = cfg();
    let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 24 << 20);
    let base = simulate(&c, p).unwrap();
    let vima = simulate(&c, TraceParams::new(KernelId::VecSum, Backend::Vima, 24 << 20)).unwrap();
    let avx2 = simulate_threads(&c, p, 2).unwrap();
    let avx16 = simulate_threads(&c, p, 16).unwrap();
    let vima_speedup = vima.speedup_vs(&base);
    assert!(
        avx2.speedup_vs(&base) < vima_speedup,
        "2 AVX cores must not reach VIMA"
    );
    assert!(
        avx16.speedup_vs(&base) > 0.4 * vima_speedup,
        "16 AVX cores should approach VIMA: {:.2}x vs {:.2}x",
        avx16.speedup_vs(&base),
        vima_speedup
    );
}

#[test]
fn avx_multithread_scaling_is_monotone() {
    let c = cfg();
    let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 12 << 20);
    let mut prev = u64::MAX;
    for th in [1, 2, 4, 8] {
        let r = simulate_threads(&c, p, th).unwrap();
        assert!(r.cycles <= prev, "{th} threads slower than {}", prev);
        prev = r.cycles;
    }
}

#[test]
fn vima_saves_energy() {
    // Headline: up to 93% energy saving; any streaming kernel must save >50%.
    let c = cfg();
    for kernel in [KernelId::VecSum, KernelId::MemCopy] {
        let avx = simulate(&c, TraceParams::new(kernel, Backend::Avx, 8 << 20)).unwrap();
        let vima = simulate(&c, TraceParams::new(kernel, Backend::Vima, 8 << 20)).unwrap();
        let ratio = vima.energy_ratio_vs(&avx);
        assert!(ratio < 0.5, "{kernel}: energy ratio {ratio}");
    }
}

#[test]
fn vima_dram_energy_per_bit_is_lower() {
    let c = cfg();
    let avx = simulate(&c, TraceParams::new(KernelId::MemCopy, Backend::Avx, 4 << 20)).unwrap();
    let vima = simulate(&c, TraceParams::new(KernelId::MemCopy, Backend::Vima, 4 << 20)).unwrap();
    // Both move the same payload, but VIMA pays 4.8 pJ/bit vs 10.8.
    let avx_bits = avx.report.get("mem.host_bits").unwrap();
    let vima_bits = vima.report.get("mem.vima_bits").unwrap();
    assert!(vima_bits > 0.0 && avx_bits > 0.0);
    assert!(vima.energy.dram_dynamic_j < avx.energy.dram_dynamic_j);
}

#[test]
fn vector_size_ablation_matches_sec3c() {
    // Sec. III-C: 256 B vectors perform much worse than 8 KB (paper: ~74%).
    let mut small_cfg = cfg();
    small_cfg.vima.vector_bytes = 256;
    let small = simulate(
        &small_cfg,
        TraceParams::new(KernelId::VecSum, Backend::Vima, 6 << 20).with_vector_bytes(256),
    )
    .unwrap();
    let big = simulate(&cfg(), TraceParams::new(KernelId::VecSum, Backend::Vima, 6 << 20)).unwrap();
    let penalty = small.cycles as f64 / big.cycles as f64;
    assert!(penalty > 1.5, "256 B vectors must underperform: {penalty:.2}x slower");
}

#[test]
fn stop_and_go_overhead_is_small_but_real() {
    // Sec. III-C: the dispatch bubble costs a few percent.
    let with =
        simulate(&cfg(), TraceParams::new(KernelId::VecSum, Backend::Vima, 6 << 20)).unwrap();
    let mut nc = cfg();
    nc.vima.stop_and_go = false;
    nc.vima.dispatch_gap_cycles = 0;
    let without =
        simulate(&nc, TraceParams::new(KernelId::VecSum, Backend::Vima, 6 << 20)).unwrap();
    let overhead = with.cycles as f64 / without.cycles as f64 - 1.0;
    assert!(overhead >= 0.0, "negative overhead {overhead}");
    assert!(overhead < 2.0, "stop-and-go should not dominate: {overhead}");
}

#[test]
fn hive_beats_baseline_but_not_vima_on_reuse() {
    // Fig. 2: HIVE > AVX on streaming; VIMA > HIVE on Stencil (reuse).
    let c = cfg();
    let bytes = 8 << 20;
    let avx = simulate(&c, TraceParams::new(KernelId::Stencil, Backend::Avx, bytes)).unwrap();
    let hive = simulate(&c, TraceParams::new(KernelId::Stencil, Backend::Hive, bytes)).unwrap();
    let vima = simulate(&c, TraceParams::new(KernelId::Stencil, Backend::Vima, bytes)).unwrap();
    assert!(hive.cycles < avx.cycles, "HIVE must beat the baseline");
    assert!(vima.cycles < hive.cycles, "VIMA must beat HIVE on stencil reuse");
}

#[test]
fn bigger_vima_cache_never_hurts_stencil() {
    let base = cfg();
    let mut prev = u64::MAX;
    for kb in [16usize, 64, 256] {
        let mut c = base.clone();
        c.vima.cache_bytes = kb << 10;
        let r = simulate(&c, TraceParams::new(KernelId::Stencil, Backend::Vima, 8 << 20)).unwrap();
        assert!(
            r.cycles <= prev.saturating_add(prev / 50),
            "{kb}KB hurt: {} vs {prev}",
            r.cycles
        );
        prev = r.cycles;
    }
}
