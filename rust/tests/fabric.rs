//! Sharded multi-cube fabric acceptance tests (DESIGN.md §10).
//!
//! The contract has three legs:
//! * `num_cubes = 1` is **bit-identical** to the classic single-`Mem3D`
//!   system — the fabric's routing parameters (hop latency, shard size)
//!   must be unobservable with one cube, across every paper kernel and
//!   backend, and single-cube reports must carry no `fabric.*` keys;
//! * multi-cube runs are **deterministic**, including under the parallel
//!   sweep engine (`--jobs N` can never change a result);
//! * the cube-scaling figure shows streaming-kernel throughput
//!   **improving** with cube count, with cross-cube gathers honestly
//!   accounted.

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizeScale;
use vima_sim::coordinator::Experiment;
use vima_sim::sim::{simulate, simulate_threads};
use vima_sim::sweep::{RunCell, SweepPlan, SweepRunner};
use vima_sim::trace::{Backend, KernelId, TraceParams};

const KERNELS: [KernelId; 7] = [
    KernelId::MemSet,
    KernelId::MemCopy,
    KernelId::VecSum,
    KernelId::Stencil,
    KernelId::MatMul,
    KernelId::Knn,
    KernelId::Mlp,
];

fn with_cubes(n: usize) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.mem.num_cubes = n;
    cfg
}

#[test]
fn single_cube_is_blind_to_fabric_parameters() {
    // With one cube every routing decision lands on cube 0 at zero hop
    // cost, so wild hop-latency / shard-size settings must be completely
    // unobservable: bit-identical cycles and reports for every paper
    // kernel on every backend it supports. This pins "num_cubes = 1 ≡ the
    // pre-fabric single-Mem3D simulator" without keeping the old code.
    let base = SystemConfig::default();
    let mut weird = SystemConfig::default();
    weird.mem.cube_hop_cycles = 9_999;
    weird.mem.cube_shard_bytes = 64 << 10;
    weird.validate().unwrap();
    for kernel in KERNELS {
        for backend in [Backend::Avx, Backend::Vima, Backend::Hive] {
            let p = TraceParams::new(kernel, backend, 2 << 20);
            if p.check().is_err() {
                continue; // e.g. MatMul/kNN/MLP have no HIVE generator
            }
            let a = simulate(&base, p).unwrap();
            let b = simulate(&weird, p).unwrap();
            assert_eq!(a.cycles, b.cycles, "{kernel}/{backend}: cycles saw fabric params");
            assert_eq!(a.report, b.report, "{kernel}/{backend}: report saw fabric params");
            assert_eq!(
                a.energy.total_j, b.energy.total_j,
                "{kernel}/{backend}: energy saw fabric params"
            );
        }
    }
}

#[test]
fn single_cube_reports_have_no_fabric_keys() {
    let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 2 << 20);
    let r = simulate(&SystemConfig::default(), p).unwrap();
    assert_eq!(r.report.with_prefix("fabric.").count(), 0, "1-cube runs must not grow keys");
    assert!(r.report.get("vima.busy_cycles_sum").is_none());
    assert!(r.report.get("vima.devices").is_none());
}

#[test]
fn multi_cube_runs_are_deterministic() {
    let cfg = with_cubes(4);
    for backend in [Backend::Avx, Backend::Vima, Backend::Hive] {
        let p = TraceParams::new(KernelId::VecSum, backend, 2 << 20);
        let a = simulate_threads(&cfg, p, 2).unwrap();
        let b = simulate_threads(&cfg, p, 2).unwrap();
        assert_eq!(a.cycles, b.cycles, "{backend}: nondeterministic cycles");
        assert_eq!(a.report, b.report, "{backend}: nondeterministic report");
    }
}

#[test]
fn multi_cube_accounts_cross_cube_traffic() {
    let cfg = with_cubes(4);
    let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 4 << 20);
    let r = simulate(&cfg, p).unwrap();
    assert_eq!(r.report.get("fabric.cubes"), Some(4.0));
    assert!(
        r.report.get("fabric.cross_cube_lines").unwrap_or(0.0) > 0.0,
        "streaming operands must gather across cubes"
    );
    assert!(r.report.get("fabric.hop_cycles").unwrap_or(0.0) > 0.0);
    // The per-device VIMA counters still balance after aggregation.
    let hits = r.report.get("vima.vcache_hits").unwrap();
    let misses = r.report.get("vima.vcache_misses").unwrap();
    let fetches = r.report.get("vima.vector_fetches").unwrap();
    assert_eq!(hits + misses, fetches);
    // Multi-cube runs expose the device count and summed busy time.
    assert_eq!(r.report.get("vima.devices"), Some(4.0));
    assert!(r.report.get("vima.busy_cycles_sum").unwrap() > 0.0);
}

#[test]
fn multi_cube_host_backend_still_serves_all_traffic() {
    // AVX (host-only) path through a 4-cube fabric: every LLC miss routes
    // to some cube, totals conserved, chained cubes actually used.
    let one = simulate(&with_cubes(1), TraceParams::new(KernelId::VecSum, Backend::Avx, 2 << 20))
        .unwrap();
    let four = simulate(&with_cubes(4), TraceParams::new(KernelId::VecSum, Backend::Avx, 2 << 20))
        .unwrap();
    assert_eq!(
        one.report.get("mem.host_reads"),
        four.report.get("mem.host_reads"),
        "sharding must not change how many lines DRAM serves"
    );
    assert!(four.report.get("fabric.chained_host_lines").unwrap() > 0.0);
}

#[test]
fn multi_cube_fabric_scales_threaded_streaming() {
    // The scaling claim at test size: 8 threads hammering one cube
    // serialize on a single VIMA device and one cube's vaults; 4 cubes
    // give ~4x the device and DRAM parallelism, far outweighing the hop
    // cost of cross-cube gathers.
    let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 8 << 20);
    let one = simulate_threads(&with_cubes(1), p, 8).unwrap();
    let four = simulate_threads(&with_cubes(4), p, 8).unwrap();
    assert!(
        four.cycles < one.cycles,
        "4-cube fabric must beat 1 cube on threaded streaming: {} vs {}",
        four.cycles,
        one.cycles
    );

    let p = TraceParams::new(KernelId::MemSet, Backend::Vima, 8 << 20);
    let one = simulate_threads(&with_cubes(1), p, 8).unwrap();
    let four = simulate_threads(&with_cubes(4), p, 8).unwrap();
    assert!(
        four.cycles < one.cycles,
        "4-cube fabric must beat 1 cube on MemSet: {} vs {}",
        four.cycles,
        one.cycles
    );
}

#[test]
fn scaling_figure_shows_throughput_improving() {
    let e = Experiment::with_jobs(SystemConfig::default(), SizeScale::Quick, 2);
    let t = e.scaling_cubes().unwrap();
    assert_eq!(t.rows.len(), 3, "MemSet / MemCopy / VecSum");
    assert_eq!(t.columns, vec!["1cube", "2cube", "4cube", "8cube"]);
    for (label, vals) in &t.rows {
        assert!((vals[0] - 1.0).abs() < 1e-12, "{label}: 1-cube point must normalize to 1.0");
        let best = vals.iter().copied().fold(0.0f64, f64::max);
        assert!(best > 1.2, "{label}: no cube count improved throughput: {vals:?}");
    }
}

#[test]
fn parallel_sweep_of_multi_cube_cells_is_bit_identical() {
    // `sweep --jobs N` determinism extends to fabric configs: the same
    // multi-cube plan through 1 worker and 4 workers must agree bit for
    // bit on every cell.
    let base = SystemConfig::default();
    let mut plan = SweepPlan::new();
    for kernel in [KernelId::MemSet, KernelId::VecSum, KernelId::Stencil] {
        for cubes in [2usize, 4] {
            let w = vima_sim::coordinator::workloads::SizedWorkload {
                workload: kernel.into(),
                footprint: 2 << 20,
                size_label: "2MB",
            };
            plan.push(
                RunCell::new(w, Backend::Vima).with_cfg(with_cubes(cubes)).with_threads(4),
            );
        }
    }
    let serial = SweepRunner::new(1).run(&base, &plan).unwrap();
    let parallel = SweepRunner::new(4).run(&base, &plan).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(s.cycles, p.cycles, "cell {i}: cycles diverged across --jobs");
        assert_eq!(s.report, p.report, "cell {i}: report diverged across --jobs");
    }
}

#[test]
fn hardware_gauges_survive_sampling_extrapolation() {
    // MatMul extrapolates from sampled rows (sim.scale > 1): event
    // counters scale linearly, but the hardware-count gauges must come
    // through unscaled — 4 cubes, not 4 x scale.
    let p = TraceParams::new(KernelId::MatMul, Backend::Vima, 6 << 20);
    let r = simulate(&with_cubes(4), p).unwrap();
    assert!(
        r.report.get("sim.scale").unwrap() > 1.0,
        "test needs a sampled run to be meaningful"
    );
    assert_eq!(r.report.get("fabric.cubes"), Some(4.0));
    assert_eq!(r.report.get("vima.devices"), Some(4.0));
}

#[test]
fn bad_cube_config_is_a_typed_error_everywhere() {
    // Through the service front door (simulate), not just MemFabric::new.
    let mut cfg = SystemConfig::default();
    cfg.mem.num_cubes = 3;
    let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20);
    let e = simulate(&cfg, p).unwrap_err().to_string();
    assert!(e.contains("num_cubes") && e.contains('3'), "{e}");
}
