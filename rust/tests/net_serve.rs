//! Integration tests of the network serving layer (DESIGN.md §14): the
//! TCP transport must answer concurrent clients with results bit-identical
//! to serial `simulate`, the shard coordinator must execute each unique
//! cell exactly once fleet-wide and recover from worker death, and a
//! client-requested shutdown must drain gracefully — every in-flight
//! request answered and flushed before the server exits.
//!
//! The coordinator tests spawn the real `vima-sim` binary as worker
//! processes (`CARGO_BIN_EXE_vima-sim`), so they cover the `net worker`
//! CLI path end to end, including `--exit-after` fault injection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizedWorkload;
use vima_sim::net::{run_sharded, wire, NetServer, ShardOptions};
use vima_sim::service::{jsonl, ServiceConfig, SimService};
use vima_sim::sim::simulate;
use vima_sim::sweep::{RunCell, SweepPlan};
use vima_sim::trace::{Backend, KernelId, TraceParams};

fn worker_binary() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_vima-sim"))
}

fn sized(kernel: KernelId, mb: u64) -> SizedWorkload {
    SizedWorkload { workload: kernel.into(), footprint: mb << 20, size_label: "test" }
}

/// A small plan with real variety: three kernels, two backends, an exact
/// duplicate cell (dedup must collapse it), and a config-override cell
/// (the full-config identity must survive the process boundary).
fn test_plan(base: &SystemConfig) -> SweepPlan {
    let mut cfg2 = base.clone();
    cfg2.mem.num_cubes = 2;
    let mut plan = SweepPlan::new();
    plan.push(RunCell::new(sized(KernelId::VecSum, 1), Backend::Avx));
    plan.push(RunCell::new(sized(KernelId::VecSum, 1), Backend::Vima));
    plan.push(RunCell::new(sized(KernelId::MemSet, 1), Backend::Avx));
    plan.push(RunCell::new(sized(KernelId::MemSet, 1), Backend::Avx)); // duplicate
    plan.push(RunCell::new(sized(KernelId::Stencil, 1), Backend::Vima));
    plan.push(RunCell::new(sized(KernelId::VecSum, 2), Backend::Vima));
    plan.push(RunCell::new(sized(KernelId::VecSum, 1), Backend::Vima).with_cfg(cfg2));
    plan
}

fn find_str<'a>(fields: &'a [(String, jsonl::JsonValue)], key: &str) -> Option<&'a str> {
    fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
        jsonl::JsonValue::Str(s) => Some(s.as_str()),
        _ => None,
    })
}

/// The tentpole acceptance check, client side: several concurrent TCP
/// clients stream wire-encoded requests and every decoded result is
/// bit-identical to a serial `simulate` of the same cell.
#[test]
fn tcp_multi_client_matches_serial_simulate() {
    let cfg = SystemConfig::default();
    let svc = SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() });
    let server = NetServer::bind_tcp("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let ctl = server.ctl();

    let kernels = [KernelId::VecSum, KernelId::MemSet, KernelId::MemCopy];
    std::thread::scope(|scope| {
        let serving = scope.spawn(|| server.serve(&svc));
        let clients: Vec<_> = kernels
            .iter()
            .map(|&kernel| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut stream = TcpStream::connect(&addr).unwrap();
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    // Two backends per client, pipelined before reading.
                    for (i, backend) in ["avx", "vima"].iter().enumerate() {
                        writeln!(
                            stream,
                            "{{\"id\": {i}, \"workload\": \"{kernel}\", \
                             \"backend\": \"{backend}\", \"mb\": 1, \"wire\": true}}",
                        )
                        .unwrap();
                    }
                    stream.flush().unwrap();
                    let mut line = String::new();
                    for (i, backend) in [Backend::Avx, Backend::Vima].iter().enumerate() {
                        line.clear();
                        assert!(reader.read_line(&mut line).unwrap() > 0, "server hung up");
                        let fields = jsonl::parse_flat_object(&line).unwrap();
                        assert_eq!(
                            find_str(&fields, "status"),
                            Some("done"),
                            "client {kernel}: {line}"
                        );
                        let decoded =
                            wire::decode_result(find_str(&fields, "result").unwrap()).unwrap();
                        let direct = simulate(
                            &SystemConfig::default(),
                            TraceParams::new(kernel, *backend, 1 << 20),
                        )
                        .unwrap();
                        assert_eq!(decoded.cycles, direct.cycles, "{kernel}/{backend} id {i}");
                        assert_eq!(decoded.seconds.to_bits(), direct.seconds.to_bits());
                        assert_eq!(decoded.energy, direct.energy);
                        assert_eq!(decoded.report, direct.report);
                    }
                })
            })
            .collect();
        for client in clients {
            client.join().unwrap();
        }
        ctl.request_drain();
        let summary = serving.join().unwrap().unwrap();
        assert_eq!(summary.connections, 3);
        assert_eq!(summary.requests, 6);
        assert_eq!(summary.ok, 6);
        assert_eq!(summary.failed, 0);
    });
}

/// The tentpole acceptance check, coordinator side: a sharded sweep across
/// two worker processes returns results in plan order, bit-identical to
/// `SimService::run_plan`, with each unique `CellKey` executed exactly
/// once fleet-wide.
#[test]
fn sharded_sweep_is_bit_identical_and_exactly_once() {
    let cfg = SystemConfig::default();
    let plan = test_plan(&cfg);
    let opts = ShardOptions {
        workers: 2,
        worker_jobs: 1,
        worker_cmd: Some(worker_binary()),
        ..ShardOptions::default()
    };
    let (sharded, stats) = run_sharded(&cfg, &plan, &opts).unwrap();

    let svc = SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() });
    let serial = svc.run_plan(&cfg, &plan, false).unwrap();
    assert_eq!(sharded.len(), serial.len());
    for ((cell, a), b) in plan.cells().iter().zip(&sharded).zip(&serial) {
        assert_eq!(a.cycles, b.cycles, "cell {}", cell.label());
        assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "cell {}", cell.label());
        assert_eq!(a.energy, b.energy, "cell {}", cell.label());
        assert_eq!(a.report, b.report, "cell {}", cell.label());
    }

    assert_eq!(stats.cells, 7);
    assert_eq!(stats.unique_cells, 6, "the duplicate cell must dedup before dispatch");
    assert_eq!(
        stats.requests_sent, 6,
        "exactly one request per unique cell when no worker dies"
    );
    assert_eq!(stats.worker_deaths, 0);
    assert_eq!(stats.requeued, 0);
    assert_eq!(stats.workers_spawned, 2);
    assert_eq!(
        stats.fleet_unique_runs, 6,
        "fleet-wide exactly-once: summed worker unique_runs must equal unique cells"
    );
}

/// Fault tolerance: worker 0 crashes after answering one response
/// (`--exit-after 1`); its unanswered cells are re-queued to the survivor
/// and the merged results are still bit-identical to the in-process plan.
#[test]
fn worker_death_requeues_and_results_stay_identical() {
    let cfg = SystemConfig::default();
    let plan = test_plan(&cfg);
    let opts = ShardOptions {
        workers: 2,
        worker_jobs: 1,
        worker_cmd: Some(worker_binary()),
        worker_extra_args: vec![vec!["--exit-after".into(), "1".into()]],
        ..ShardOptions::default()
    };
    let (sharded, stats) = run_sharded(&cfg, &plan, &opts).unwrap();

    let svc = SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() });
    let serial = svc.run_plan(&cfg, &plan, false).unwrap();
    for ((cell, a), b) in plan.cells().iter().zip(&sharded).zip(&serial) {
        assert_eq!(a.cycles, b.cycles, "cell {}", cell.label());
        assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "cell {}", cell.label());
        assert_eq!(a.energy, b.energy, "cell {}", cell.label());
        assert_eq!(a.report, b.report, "cell {}", cell.label());
    }

    assert!(stats.worker_deaths >= 1, "the --exit-after worker must count as dead");
    assert!(stats.requeued >= 1, "its unanswered cells must be re-queued");
    assert!(
        stats.requests_sent > stats.unique_cells as u64,
        "re-queued cells are re-sent, so requests exceed unique cells"
    );
    assert_eq!(
        stats.fleet_unique_runs, stats.unique_cells as u64,
        "every unique cell is answered exactly once even across a death"
    );
}

/// Graceful drain: a client that pipelines jobs and then requests shutdown
/// still receives every response — in order, shutdown ack last — before
/// the server exits.
#[test]
fn shutdown_drains_in_flight_work() {
    let svc = SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() });
    let server = NetServer::bind_tcp("127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let summary = std::thread::scope(|scope| {
        let serving = scope.spawn(|| server.serve(&svc));
        let mut stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for i in 0..5 {
            // Distinct footprints: real scheduler work in flight when the
            // shutdown line lands.
            writeln!(
                stream,
                "{{\"id\": {i}, \"workload\": \"memset\", \"backend\": \"avx\", \
                 \"footprint\": {}}}",
                (i + 1) * 65536
            )
            .unwrap();
        }
        writeln!(stream, "{{\"id\": 99, \"op\": \"shutdown\"}}").unwrap();
        stream.flush().unwrap();
        let mut lines = Vec::new();
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            lines.push(line.trim().to_string());
            line.clear();
        }
        assert_eq!(lines.len(), 6, "all in-flight jobs + the ack must flush:\n{lines:?}");
        for (i, l) in lines[..5].iter().enumerate() {
            assert!(l.contains(&format!("\"id\": {i}")), "{l}");
            assert!(l.contains("\"status\": \"done\""), "{l}");
        }
        assert!(lines[5].contains("\"draining\": true"), "{}", lines[5]);
        // The shutdown op drains the whole server, not just this session.
        serving.join().unwrap().unwrap()
    });
    assert_eq!(summary.requests, 6);
    assert_eq!(summary.ok, 6);
    assert_eq!(summary.failed, 0);
}

/// Per-request timeouts answer a typed line over the wire and never wedge
/// the connection: the follow-up ping is still served.
#[test]
fn timeout_is_typed_and_session_survives() {
    let svc = SimService::new(ServiceConfig { jobs: 1, ..ServiceConfig::default() });
    let server = NetServer::bind_tcp("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let ctl = server.ctl();

    std::thread::scope(|scope| {
        let serving = scope.spawn(|| server.serve(&svc));
        let mut stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        writeln!(
            stream,
            "{{\"id\": 1, \"workload\": \"stencil\", \"backend\": \"vima\", \"mb\": 4, \
             \"timeout_ms\": 1}}"
        )
        .unwrap();
        writeln!(stream, "{{\"id\": 2, \"op\": \"ping\"}}").unwrap();
        stream.flush().unwrap();
        let mut first = String::new();
        reader.read_line(&mut first).unwrap();
        // Either the cell beat the deadline (done) or the typed timeout
        // line came back; both must carry the request id.
        assert!(first.contains("\"id\": 1"), "{first}");
        assert!(
            first.contains("\"status\": \"done\"") || first.contains("\"status\": \"timeout\""),
            "{first}"
        );
        if first.contains("\"status\": \"timeout\"") {
            assert!(first.contains("timeout_ms"), "typed timeout must name the budget: {first}");
        }
        let mut second = String::new();
        reader.read_line(&mut second).unwrap();
        assert!(second.contains("\"op\": \"ping\""), "{second}");
        drop(reader);
        drop(stream);
        ctl.request_drain();
        serving.join().unwrap().unwrap();
    });
}
