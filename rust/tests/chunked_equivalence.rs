//! Chunked-execution oracle: `Machine::run` (the chunked hot path) must
//! reproduce `Machine::run_reference` (the event-at-a-time pull path)
//! **bit for bit** — same cycle count, same value for every counter in the
//! report. Determinism is the regression oracle for the whole PR-3
//! throughput work; CI runs this file in release mode as the
//! serial ≡ parallel ≡ chunked smoke (parallel ≡ serial lives in
//! `sweep_parallel.rs`).

use vima_sim::config::SystemConfig;
use vima_sim::sim::Machine;
use vima_sim::trace::{Backend, KernelId, TraceParams, TraceStream};
use vima_sim::util::error::Result;

/// One representative cell per figure family:
/// fig2 (HIVE comparator), fig3 (single-thread VIMA + reuse-heavy kernel),
/// fig4 (multithreaded AVX), fig5-ish config sensitivity via MatMul's
/// partial vectors, and the Sec. III-C vector-size ablation shape.
fn cells() -> Vec<(TraceParams, usize)> {
    vec![
        (TraceParams::new(KernelId::VecSum, Backend::Hive, 1 << 20), 1),
        (TraceParams::new(KernelId::Stencil, Backend::Vima, 1 << 20), 1),
        (TraceParams::new(KernelId::MatMul, Backend::Vima, 256 << 10), 1),
        (TraceParams::new(KernelId::MemCopy, Backend::Avx, 1 << 20), 1),
        (TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20), 4),
        (TraceParams::new(KernelId::MemSet, Backend::Vima, 1 << 20).with_vector_bytes(256), 1),
    ]
}

fn streams(p: TraceParams, threads: usize) -> Result<Vec<TraceStream>> {
    (0..threads).map(|t| p.with_threads(t, threads).stream()).collect()
}

#[test]
fn chunked_matches_reference_bit_for_bit() {
    let cfg = SystemConfig::default();
    for (p, threads) in cells() {
        let mut m = Machine::new(&cfg, threads).unwrap();
        let chunked = m.run(streams(p, threads).unwrap()).unwrap();
        let mut m = Machine::new(&cfg, threads).unwrap();
        let reference = m.run_reference(streams(p, threads).unwrap()).unwrap();
        assert_eq!(chunked.cycles, reference.cycles, "cycles diverged for {p:?} x{threads}");
        assert_eq!(chunked.report, reference.report, "report diverged for {p:?} x{threads}");
    }
}

#[test]
fn chunked_reset_reuse_matches_reference() {
    // The sweep engine reuses machines across cells via reset(); the
    // chunked path must stay equivalent under reuse too.
    let cfg = SystemConfig::default();
    let p = TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20);
    let q = TraceParams::new(KernelId::MemCopy, Backend::Avx, 1 << 20);
    let mut m = Machine::new(&cfg, 1).unwrap();
    m.run(streams(p, 1).unwrap()).unwrap();
    m.reset();
    let chunked = m.run(streams(q, 1).unwrap()).unwrap();
    let mut m = Machine::new(&cfg, 1).unwrap();
    let reference = m.run_reference(streams(q, 1).unwrap()).unwrap();
    assert_eq!(chunked.cycles, reference.cycles);
    assert_eq!(chunked.report, reference.report);
}

#[test]
fn run_chunk_until_respects_the_window_limit() {
    // Driving a chunk with a finite limit must stop before the first event
    // that would start past it, exactly like the reference interleaver.
    let cfg = SystemConfig::default();
    let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 256 << 10);
    let mut s = p.stream().unwrap();
    assert!(s.fill());
    let mut m = Machine::new(&cfg, 1).unwrap();
    let consumed = m.run_chunk_until(0, s.chunk(), 50).unwrap();
    assert!(consumed > 0, "at least one event runs inside the window");
    assert!(consumed < s.chunk().len(), "a 50-cycle window cannot drain a whole chunk");
}
