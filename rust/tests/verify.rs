//! Integration tests of vima-verify (ISSUE 10): analyzer + verifier
//! verdicts are invariant under the `.vpr` emit -> parse round trip for
//! every committed program, every golden and registered program proves
//! cross-backend dataflow-equivalent, the `check` CLI is deterministic
//! across argument order and distinguishes warnings-only (exit 0) from
//! errors (nonzero), and the static cost model's cycle predictions track
//! the detailed simulator within the DESIGN.md §15 bound on the
//! streaming kernels.

use std::path::{Path, PathBuf};
use std::process::Command;

use vima_sim::analyze::{analyze_parsed, lint, verify, Report};
use vima_sim::bench::predict_frontier;
use vima_sim::config::SystemConfig;
use vima_sim::program::{self, parse};
use vima_sim::workload::{self, programs};

fn programs_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/programs"))
}

fn bad_dir() -> PathBuf {
    programs_dir().join("bad")
}

fn vpr_paths(dir: &Path) -> Vec<PathBuf> {
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "vpr"))
        .collect();
    paths.sort();
    paths
}

/// Same per-fixture machine config as `tests/analyze.rs`.
fn fixture_cfg(fname: &str) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    if fname == "cube-ping-pong.vpr" {
        cfg.mem.num_cubes = 4;
    }
    cfg.validate().unwrap();
    cfg
}

/// Sorted multiset of lint IDs — the round-trip invariant. Spans and
/// operand names may shift across emit/parse (the emitter regenerates
/// lines and allocation names); the verdicts must not.
fn lint_ids(r: &Report) -> Vec<&'static str> {
    let mut ids: Vec<&'static str> = r.diags.iter().map(|d| d.id).collect();
    ids.sort_unstable();
    ids
}

/// Property: for every committed `.vpr` — the 8 goldens *and* the bad
/// fixtures — the analyzer's lint-ID multiset and the verifier's
/// equivalence verdict survive a `to_vpr` -> `parse` round trip.
#[test]
fn verdicts_survive_vpr_round_trip() {
    let mut paths = vpr_paths(&programs_dir());
    paths.extend(vpr_paths(&bad_dir()));
    assert!(paths.len() >= 22, "expected goldens + fixtures, found {}", paths.len());
    for path in paths {
        let fname = path.file_name().unwrap().to_string_lossy().into_owned();
        let cfg = fixture_cfg(&fname);
        let src = std::fs::read_to_string(&path).unwrap();
        let first = parse(&src).unwrap_or_else(|e| panic!("{fname}: {e}"));
        let emitted = first.program.to_vpr("rt").unwrap_or_else(|e| panic!("{fname}: {e}"));
        let second = parse(&emitted).unwrap_or_else(|e| panic!("{fname} re-parse: {e}"));

        let r1 = analyze_parsed(&first, &cfg);
        let r2 = analyze_parsed(&second, &cfg);
        assert_eq!(
            lint_ids(&r1),
            lint_ids(&r2),
            "{fname}: lint verdicts must survive the emit/parse round trip"
        );

        let v1 = verify::verify(&first.program, &first.source);
        let v2 = verify::verify(&second.program, &second.source);
        assert_eq!(v1.equivalent(), v2.equivalent(), "{fname}: equivalence verdict flipped");
        assert_eq!(
            v1.statements_checked(),
            v2.statements_checked(),
            "{fname}: statement count drifted"
        );
    }
}

/// The registered DSL programs obey the same round-trip invariant, and
/// their verdicts match what the `Workload` hooks report. Sizes match
/// the builtins (256) so config-keyed lints see the same working set.
#[test]
fn registered_programs_round_trip_and_match_workload_hooks() {
    let cfg = SystemConfig::default();
    for (p, name) in [(programs::saxpy(256), "saxpy"), (programs::softmax(256), "softmax")] {
        let src = vima_sim::analyze::SourceInfo::default();
        let direct = vima_sim::analyze::analyze(&p, &src, &cfg);
        let rt = parse(&p.to_vpr(name).unwrap()).unwrap();
        let round = analyze_parsed(&rt, &cfg);
        assert_eq!(lint_ids(&direct), lint_ids(&round), "{name}");

        let w = workload::get(workload::resolve(name).unwrap()).unwrap();
        let hook = w.analyze(&cfg).expect("programs are analyzable");
        assert_eq!(lint_ids(&direct), lint_ids(&hook), "{name}: hook disagrees");
    }
}

/// Acceptance: every committed golden and every registered program
/// workload proves cross-backend dataflow-equivalent. The float
/// reduction kernels may carry `reduction-order-sensitive` warnings
/// (rounding drift, not a dataflow divergence) but never an error.
#[test]
fn goldens_and_registered_programs_are_divergence_clean() {
    for path in vpr_paths(&programs_dir()) {
        let label = path.file_name().unwrap().to_string_lossy().into_owned();
        let src = std::fs::read_to_string(&path).unwrap();
        let parsed = parse(&src).unwrap_or_else(|e| panic!("{label}: {e}"));
        let v = verify::verify(&parsed.program, &parsed.source);
        assert!(
            v.equivalent(),
            "{label}: lowerings must be dataflow-equivalent: {:?}",
            v.diags
        );
        assert!(v.statements_checked() > 0, "{label}: nothing was compared");
        assert!(
            v.diags.iter().all(|d| d.id == lint::REDUCTION_ORDER_SENSITIVE),
            "{label}: unexpected divergence diagnostics: {:?}",
            v.diags
        );
    }
    for id in workload::all_ids() {
        let w = workload::get(id).unwrap();
        if let Some(v) = w.verify() {
            assert!(v.equivalent(), "{}: {:?}", w.name(), v.diags);
        }
    }
}

fn check_cmd(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_vima-sim"))
        .arg("check")
        .args(args)
        .output()
        .expect("spawn vima-sim check")
}

/// Exit-code contract: warnings-only analysis succeeds (exit 0), any
/// error-severity diagnostic fails the command (nonzero).
#[test]
fn check_exit_code_distinguishes_warnings_from_errors() {
    let warn = bad_dir().join("reduction-order-sensitive.vpr");
    let out = check_cmd(&[warn.to_str().unwrap()]);
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(out.status.success(), "warnings-only must exit 0: {stdout}");
    assert!(stdout.contains("warning[reduction-order-sensitive]"), "{stdout}");

    let err = bad_dir().join("backend-divergence.vpr");
    let out = check_cmd(&[err.to_str().unwrap()]);
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(!out.status.success(), "errors must exit nonzero: {stdout}");
    assert!(stdout.contains("error[backend-divergence]"), "{stdout}");
}

/// Multi-file `check` output is deterministic: both argument orders give
/// byte-identical stdout (text and `--json` alike), globally sorted by
/// (file, span, lint ID).
#[test]
fn check_output_is_deterministic_across_argument_order() {
    let a = bad_dir().join("backend-divergence.vpr");
    let b = bad_dir().join("reduction-order-sensitive.vpr");
    let (a, b) = (a.to_str().unwrap(), b.to_str().unwrap());

    for json in [false, true] {
        let mut fwd: Vec<&str> = vec![a, b];
        let mut rev: Vec<&str> = vec![b, a];
        if json {
            fwd.push("--json");
            rev.push("--json");
        }
        let out1 = check_cmd(&fwd);
        let out2 = check_cmd(&rev);
        assert_eq!(out1.status.code(), out2.status.code());
        assert!(!out1.stdout.is_empty());
        assert_eq!(
            out1.stdout, out2.stdout,
            "check output must not depend on argument order (json={json})"
        );
        let text = String::from_utf8(out1.stdout).unwrap();
        let first = text.find(a).expect("first file appears");
        let second = text.find(b).expect("second file appears");
        assert!(first < second, "files must report in sorted order:\n{text}");
    }
}

/// Acceptance: `--predict` cycle predictions track the detailed simulator
/// within the DESIGN.md §15 bound (|error| <= 75%) on the streaming
/// kernels. The reuse-heavy kernels (matmul-block above all) may exceed
/// the bound but must still be measured and reported.
#[test]
fn predictions_track_the_simulator_on_streaming_kernels() {
    let cfg = SystemConfig::default();
    program::load_dir(programs_dir()).unwrap();
    let rows = predict_frontier(&cfg, false).unwrap();
    assert!(rows.len() >= 10, "builtins + goldens expected, got {}", rows.len());
    let names: Vec<&str> = rows.iter().map(|r| r.workload.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort_unstable();
    assert_eq!(names, sorted, "rows must be name-sorted for stable reports");
    for r in &rows {
        assert!(r.predicted_cycles > 0, "{}: zero prediction", r.workload);
        assert!(r.simulated_cycles > 0, "{}: zero simulation", r.workload);
        assert!(r.error_pct.is_finite(), "{}: non-finite error", r.workload);
    }
    // matmul-block is the documented outlier: reported, never gated.
    assert!(names.contains(&"matmul-block"), "{names:?}");
    for streaming in ["saxpy", "saxpy-vpr", "vecadd-vpr"] {
        let row = rows
            .iter()
            .find(|r| r.workload == streaming)
            .unwrap_or_else(|| panic!("{streaming} missing from {names:?}"));
        assert!(
            row.error_pct.abs() <= 75.0,
            "{streaming}: predicted {} vs simulated {} cycles ({:+.2}%) exceeds \
             the documented streaming-kernel bound",
            row.predicted_cycles,
            row.simulated_cycles,
            row.error_pct
        );
    }
}
