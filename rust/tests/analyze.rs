//! Integration tests of the vima-check static analyzer (ISSUE 8): every
//! bad fixture in `examples/programs/bad/` reproduces its pinned
//! diagnostics byte-for-byte (line/column included), the committed goldens
//! stay error-clean, the loaders reject error-bearing programs in both the
//! `run` and `serve --load` choke points, and registered program workloads
//! expose their reports through `Workload::analyze`.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use vima_sim::analyze::{analyze_parsed, lint};
use vima_sim::config::SystemConfig;
use vima_sim::program::{self, parse};
use vima_sim::workload;

fn programs_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/programs"))
}

fn bad_dir() -> PathBuf {
    programs_dir().join("bad")
}

fn vpr_paths(dir: &Path) -> Vec<PathBuf> {
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "vpr"))
        .collect();
    paths.sort();
    paths
}

/// The machine configuration each fixture is pinned against. All but one
/// use the Table-I default; the cube-ping-pong fixture needs a multi-cube
/// fabric to have cube links to ping-pong across.
fn fixture_cfg(fname: &str) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    if fname == "cube-ping-pong.vpr" {
        cfg.mem.num_cubes = 4;
    }
    cfg.validate().unwrap();
    cfg
}

/// Every bad fixture reproduces its committed `.expect` diagnostics
/// byte-for-byte, and the corpus jointly exercises every lint ID the
/// analyzer can emit.
#[test]
fn bad_fixtures_reproduce_pinned_diagnostics() {
    let paths = vpr_paths(&bad_dir());
    assert!(paths.len() >= 14, "expected one fixture per lint, found {}", paths.len());
    let mut seen: BTreeSet<&'static str> = BTreeSet::new();
    for path in paths {
        let fname = path.file_name().unwrap().to_string_lossy().into_owned();
        let expect = std::fs::read_to_string(path.with_extension("expect"))
            .unwrap_or_else(|e| panic!("{fname}: missing .expect file: {e}"));
        let src = std::fs::read_to_string(&path).unwrap();
        let parsed = parse(&src).unwrap_or_else(|e| panic!("{fname}: {e}"));
        let report = analyze_parsed(&parsed, &fixture_cfg(&fname));
        assert!(!report.is_clean(), "{fname}: fixture must produce diagnostics");
        assert_eq!(
            report.render(&fname),
            expect,
            "{fname}: diagnostics must match the pinned .expect byte-for-byte"
        );
        for d in &report.diags {
            seen.insert(d.id);
        }
    }
    for id in lint::ALL {
        assert!(seen.contains(id), "no fixture exercises lint `{id}`");
    }
}

/// Property: every committed golden is error-clean under the default
/// configuration — `vima-sim check examples/programs/*.vpr` must pass.
#[test]
fn committed_goldens_are_error_clean() {
    let cfg = SystemConfig::default();
    let paths = vpr_paths(&programs_dir());
    assert!(paths.len() >= 8, "expected the 8 committed goldens, found {}", paths.len());
    for path in paths {
        let label = path.file_name().unwrap().to_string_lossy().into_owned();
        let src = std::fs::read_to_string(&path).unwrap();
        let parsed = parse(&src).unwrap_or_else(|e| panic!("{label}: {e}"));
        let report = analyze_parsed(&parsed, &cfg);
        assert_eq!(
            report.error_count(),
            0,
            "{label} must be error-clean:\n{}",
            report.render(&label)
        );
    }
}

/// The matmul golden carries a real (informational) hazard: its
/// accumulator tiles are loop-carried, so the outer loop is not safe to
/// slice across threads. The analyzer must surface it without erroring.
#[test]
fn matmul_reports_the_thread_slicing_hazard() {
    let src = std::fs::read_to_string(programs_dir().join("matmul.vpr")).unwrap();
    let report = analyze_parsed(&parse(&src).unwrap(), &SystemConfig::default());
    assert_eq!(report.error_count(), 0);
    assert!(
        report.diags.iter().any(|d| d.id == lint::LOOP_CARRIED_ALIAS),
        "matmul's carried accumulator must be reported:\n{}",
        report.render("matmul.vpr")
    );
}

/// Error-bearing programs are rejected at load time — in `load_file` (the
/// `vima-sim run prog.vpr` path) and `load_path` (the `--load` path used
/// by `serve`) alike — with the same stable lint ID in the message.
#[test]
fn loaders_reject_error_programs_in_both_choke_points() {
    let path = bad_dir().join("uninit-read.vpr");
    let e = program::load_file(&path).unwrap_err().to_string();
    assert!(e.contains("rejected by check"), "{e}");
    assert!(e.contains("uninit-read"), "{e}");
    let e = program::load_path(&path).unwrap_err().to_string();
    assert!(e.contains("uninit-read"), "{e}");
}

/// Registered Intrinsics-VIMA programs expose reports through
/// `Workload::analyze`; paper kernels (no statement tree) return None.
#[test]
fn workload_analyze_hook_distinguishes_programs_from_kernels() {
    let cfg = SystemConfig::default();
    let saxpy = workload::get(workload::resolve("saxpy").unwrap()).unwrap();
    let report = saxpy.analyze(&cfg).expect("programs are analyzable");
    assert_eq!(report.error_count(), 0, "{}", report.render("saxpy"));
    let vecsum = workload::get(workload::resolve("vecsum").unwrap()).unwrap();
    assert!(vecsum.analyze(&cfg).is_none(), "paper kernels are not analyzable");
}
