//! Integration tests of the service API (the one front door for running
//! simulations): concurrent batched submission through `SimService` must
//! match serial `simulate` results bit for bit, overlapping submissions
//! must observe exactly-once execution per cell identity, job handles
//! must report typed statuses, and the `serve` JSONL protocol must
//! round-trip requests in order.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::{SizeScale, WorkloadSet};
use vima_sim::service::{jsonl, Job, JobStatus, ServiceConfig, SimService};
use vima_sim::sim::{simulate, SimResult};
use vima_sim::sweep::{RunCell, SweepPlan, SweepRunner};
use vima_sim::trace::{Backend, KernelId, TraceChunker, TraceParams};
use vima_sim::util::error::Result;
use vima_sim::workload::{self, Workload, WorkloadId};

/// The acceptance check: a batch submitted concurrently from many threads
/// returns, for every job, exactly the result a serial `simulate` call
/// produces — cycles, full counter report, and energy bits.
#[test]
fn concurrent_batched_submission_matches_serial_simulate() {
    let cfg = SystemConfig::default();
    let svc = SimService::with_base(cfg.clone());
    let mut jobs = Vec::new();
    for kernel in [KernelId::MemSet, KernelId::VecSum] {
        for backend in [Backend::Avx, Backend::Vima] {
            jobs.push(Job::new(TraceParams::new(kernel, backend, 1 << 20)));
        }
    }

    let batches: Vec<Vec<SimResult>> = std::thread::scope(|s| {
        let threads: Vec<_> = (0..4)
            .map(|_| {
                s.spawn(|| {
                    svc.submit_batch(jobs.clone())
                        .iter()
                        .map(|h| h.wait().unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        threads.into_iter().map(|t| t.join().unwrap()).collect()
    });

    for batch in &batches {
        assert_eq!(batch.len(), jobs.len());
        for (job, result) in jobs.iter().zip(batch) {
            let direct = simulate(&cfg, job.params).unwrap();
            assert_eq!(result.cycles, direct.cycles);
            assert_eq!(result.report, direct.report);
            assert_eq!(
                result.energy.total_j.to_bits(),
                direct.energy.total_j.to_bits(),
                "energy must be bit-identical"
            );
        }
    }
}

/// Instrumented workload: counts trace-generator builds (one per run per
/// thread), delegating the actual stream to MemSet's generators.
struct Counting {
    runs: Arc<AtomicU64>,
}

const COUNTING_BACKENDS: [Backend; 2] = [Backend::Avx, Backend::Vima];

impl Workload for Counting {
    fn name(&self) -> &str {
        "svc-counting"
    }

    fn backends(&self) -> &[Backend] {
        &COUNTING_BACKENDS
    }

    fn chunker(&self, p: &TraceParams) -> Result<Box<dyn TraceChunker>> {
        self.runs.fetch_add(1, Ordering::SeqCst);
        workload::get(WorkloadId::from(KernelId::MemSet))?.chunker(p)
    }
}

/// Many threads submitting overlapping jobs observe exactly-once
/// execution per cell identity: the trace generator builds exactly once
/// per distinct cell, no matter how many submitters race.
#[test]
fn overlapping_submissions_execute_exactly_once_per_key() {
    let runs = Arc::new(AtomicU64::new(0));
    let id = workload::register(Arc::new(Counting { runs: Arc::clone(&runs) })).unwrap();
    let svc = SimService::new(ServiceConfig { jobs: 4, ..ServiceConfig::default() });
    let cells: Vec<TraceParams> =
        (1u64..=3).map(|mb| TraceParams::new(id, Backend::Avx, mb << 20)).collect();

    let results: Vec<Vec<SimResult>> = std::thread::scope(|s| {
        let threads: Vec<_> = (0..8)
            .map(|_| {
                s.spawn(|| {
                    cells
                        .iter()
                        .map(|p| svc.submit(Job::new(*p)).wait().unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        threads.into_iter().map(|t| t.join().unwrap()).collect()
    });

    // One generator build per distinct cell — never one per submission.
    assert_eq!(runs.load(Ordering::SeqCst), cells.len() as u64);
    let stats = svc.stats();
    assert_eq!(stats.cells, 24);
    assert_eq!(stats.unique_runs, 3);
    assert_eq!(stats.cache_hits, 21);

    // Every submitter saw identical (deterministic) results.
    for batch in &results[1..] {
        for (a, b) in results[0].iter().zip(batch) {
            assert_eq!(a.cycles, b.cycles);
            assert_eq!(a.report, b.report);
        }
    }
}

#[test]
fn handle_statuses_track_the_job_lifecycle() {
    let svc = SimService::new(ServiceConfig { jobs: 1, ..ServiceConfig::default() });

    // Invalid jobs are Failed at submission, with the typed error on wait.
    let bad = svc.submit(Job::new(TraceParams::new(KernelId::Mlp, Backend::Hive, 4 << 20)));
    assert_eq!(bad.status(), JobStatus::Failed);
    let e = bad.wait().unwrap_err().to_string();
    assert!(e.contains("HIVE"), "{e}");

    // Valid jobs move through live states and settle on Done.
    let good = svc.submit(Job::new(TraceParams::new(KernelId::MemSet, Backend::Avx, 1 << 20)));
    assert!(matches!(
        good.status(),
        JobStatus::Queued | JobStatus::Running | JobStatus::Done
    ));
    good.wait().unwrap();
    assert_eq!(good.status(), JobStatus::Done);

    // A duplicate of a cached cell is already Done when submitted.
    let dup = svc.submit(Job::new(TraceParams::new(KernelId::MemSet, Backend::Avx, 1 << 20)));
    assert_eq!(dup.status(), JobStatus::Done);
    dup.wait().unwrap();
    assert_eq!(svc.stats().unique_runs, 1);
}

/// The sweep path and direct service plan submission are the same
/// scheduler: identical plans produce bit-identical results either way.
#[test]
fn plan_submission_matches_sweep_runner() {
    let cfg = SystemConfig::default();
    let mut plan = SweepPlan::new();
    for w in WorkloadSet::fig2(SizeScale::Quick).into_iter().take(2) {
        for b in [Backend::Avx, Backend::Vima] {
            plan.push(RunCell::new(w, b));
        }
    }
    let svc = SimService::with_base(cfg.clone());
    let via_service = svc.run_plan(&cfg, &plan, false).unwrap();
    let runner = SweepRunner::new(2);
    let via_runner = runner.run(&cfg, &plan).unwrap();
    assert_eq!(via_service.len(), via_runner.len());
    for ((a, b), cell) in via_service.iter().zip(&via_runner).zip(plan.cells()) {
        assert_eq!(a.cycles, b.cycles, "{}", cell.label());
        assert_eq!(a.report, b.report, "{}", cell.label());
    }

    // submit_plan hands back one handle per cell, in plan order.
    let handles = svc.submit_plan(&plan);
    assert_eq!(handles.len(), plan.len());
    for (h, r) in handles.iter().zip(&via_service) {
        assert_eq!(h.wait().unwrap().cycles, r.cycles);
    }
}

/// JSONL serve round trip: responses come back one per request, in
/// request order, well-formed, with errors answered inline instead of
/// killing the session.
#[test]
fn serve_jsonl_round_trips_in_order() {
    let cfg = SystemConfig::default();
    let svc = SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() });
    let input = concat!(
        "{\"id\": 1, \"workload\": \"vecsum\", \"backend\": \"vima\", \"mb\": 1}\n",
        "\n", // blank lines are skipped, not answered
        "{\"id\": \"j2\", \"workload\": \"memset\", \"backend\": \"avx\", \"mb\": 1, \"threads\": 2}\n",
        "{\"id\": 3, \"workload\": \"vecsum\", \"backend\": \"neon\"}\n",
        "this is not json\n",
    );
    let mut out: Vec<u8> = Vec::new();
    let summary = jsonl::serve(&svc, input.as_bytes(), &mut out).unwrap();
    assert_eq!((summary.requests, summary.ok, summary.failed), (4, 2, 2));

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4, "{text}");

    // Every response is itself parseable flat JSON.
    for line in &lines {
        jsonl::parse_flat_object(line).unwrap_or_else(|e| panic!("{line}: {e}"));
    }

    // In request order, ids echoed verbatim.
    assert!(lines[0].starts_with("{\"id\": 1, \"status\": \"done\""), "{}", lines[0]);
    assert!(lines[1].starts_with("{\"id\": \"j2\", \"status\": \"done\""), "{}", lines[1]);
    assert!(lines[2].starts_with("{\"id\": 3, \"status\": \"failed\""), "{}", lines[2]);
    assert!(lines[2].contains("valid backends"), "{}", lines[2]);
    assert!(lines[3].contains("\"status\": \"failed\""), "{}", lines[3]);
    assert!(lines[3].contains("bad request line"), "{}", lines[3]);

    // The served result is the simulator's result, not an approximation.
    let direct =
        simulate(&cfg, TraceParams::new(KernelId::VecSum, Backend::Vima, 1 << 20)).unwrap();
    assert!(
        lines[0].contains(&format!("\"cycles\": {}", direct.cycles)),
        "{} vs cycles {}",
        lines[0],
        direct.cycles
    );
}
