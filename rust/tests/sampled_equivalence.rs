//! Sampled-execution oracle (DESIGN.md §11): the functional fast-forward
//! engine must preserve microarchitectural state bit-for-bit, the
//! degenerate `window == period` configuration must reproduce
//! `Machine::run_reference` exactly, the reported error bars must bracket
//! the full-run result on the paper kernels, and sampled jobs must never
//! collide with full-detail jobs in the service result cache.

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::{SizeScale, WorkloadSet};
use vima_sim::service::{Job, ServiceConfig, SimService};
use vima_sim::sim::Machine;
use vima_sim::sweep::RunCell;
use vima_sim::trace::{Backend, KernelId, TraceParams, TraceStream};
use vima_sim::util::error::Result;
use vima_sim::workload;

/// Single-core cells covering every event kind: µop-dense AVX streams,
/// VIMA dispatch + coherence walks (including partial vectors), and HIVE
/// register transactions.
fn cells() -> Vec<TraceParams> {
    vec![
        TraceParams::new(KernelId::VecSum, Backend::Avx, 2 << 20),
        TraceParams::new(KernelId::MemCopy, Backend::Avx, 1 << 20),
        TraceParams::new(KernelId::Stencil, Backend::Vima, 1 << 20),
        TraceParams::new(KernelId::MatMul, Backend::Vima, 256 << 10),
        TraceParams::new(KernelId::MemSet, Backend::Vima, 1 << 20).with_vector_bytes(256),
        TraceParams::new(KernelId::VecSum, Backend::Hive, 1 << 20),
    ]
}

fn streams(p: TraceParams, threads: usize) -> Result<Vec<TraceStream>> {
    (0..threads).map(|t| p.with_threads(t, threads).stream()).collect()
}

/// (a) `window == period` leaves no fast-forward budget: `run_sampled`
/// degenerates to a plain detailed run, bit-identical to the
/// event-at-a-time reference oracle — cycles and every counter — and
/// reports no `sample.*` keys.
#[test]
fn window_equals_period_matches_reference_bit_for_bit() {
    let cfg = SystemConfig::default();
    let mut shapes: Vec<(TraceParams, usize)> = cells().into_iter().map(|p| (p, 1)).collect();
    shapes.push((TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20), 4));
    for (p, threads) in shapes {
        let mut m = Machine::new(&cfg, threads).unwrap();
        let sampled = m.run_sampled(streams(p, threads).unwrap(), 4096, 4096).unwrap();
        let mut m = Machine::new(&cfg, threads).unwrap();
        let reference = m.run_reference(streams(p, threads).unwrap()).unwrap();
        assert_eq!(sampled.cycles, reference.cycles, "cycles diverged for {p:?} x{threads}");
        assert_eq!(sampled.report, reference.report, "report diverged for {p:?} x{threads}");
        assert!(
            sampled.report.get("sample.windows").is_none(),
            "degenerate sampled run must not report sample.* keys for {p:?}"
        );
    }
}

/// (b) After a sampled run the order-driven microarchitectural state —
/// cache tag/LRU/dirty arrays, region filter, DTLB, branch predictor,
/// VIMA vector caches — is bit-identical to a full detailed run of the
/// same trace: fast-forward replays the exact state transitions of
/// detailed execution, only without timing. (Single-core cells: with
/// several cores the fast-forward phases visit cores sequentially, which
/// legitimately reorders accesses to shared structures.)
#[test]
fn fast_forward_preserves_microarchitectural_state() {
    let cfg = SystemConfig::default();
    for p in cells() {
        let mut detailed = Machine::new(&cfg, 1).unwrap();
        detailed.run(streams(p, 1).unwrap()).unwrap();
        let mut sampled = Machine::new(&cfg, 1).unwrap();
        let r = sampled.run_sampled(streams(p, 1).unwrap(), 512, 8192).unwrap();
        assert!(
            r.report.get("sample.windows").unwrap_or(0.0) >= 1.0,
            "cell must actually sample: {p:?}"
        );
        assert_eq!(
            detailed.state_digest(),
            sampled.state_digest(),
            "microarchitectural state diverged for {p:?}"
        );
    }
}

/// (c) On all seven paper kernels at quick scale, the sampled cycle count
/// must land within its own reported 95% error bar of the full-run truth.
#[test]
fn error_bars_bracket_full_run_on_paper_kernels() {
    let cfg = SystemConfig::default();
    let kernels = [
        KernelId::MemSet,
        KernelId::MemCopy,
        KernelId::VecSum,
        KernelId::Stencil,
        KernelId::MatMul,
        KernelId::Knn,
        KernelId::Mlp,
    ];
    for kernel in kernels {
        let w = WorkloadSet::sizes(kernel, SizeScale::Quick)[0];
        let p = RunCell::new(w, Backend::Avx).params();
        // ~16 periods over the real event count, 1/16 detailed fraction:
        // windows long enough that the boundary transient is amortized and
        // few enough that the ci95's 1/k term covers what remains.
        let events = p.stream().unwrap().count() as u64;
        let period = (events / 16).max(2048);
        let window = (period / 16).max(256);
        let mut m = Machine::new(&cfg, 1).unwrap();
        let full = m.run(streams(p, 1).unwrap()).unwrap();
        let mut m = Machine::new(&cfg, 1).unwrap();
        let sampled = m.run_sampled(streams(p, 1).unwrap(), window, period).unwrap();
        let err = (sampled.cycles as f64 - full.cycles as f64).abs();
        match sampled.report.get("sample.cycles_ci95") {
            Some(ci95) => {
                assert!(
                    err <= ci95,
                    "{kernel:?}: |{} - {}| = {err} exceeds ci95 {ci95:.0}",
                    sampled.cycles,
                    full.cycles
                );
            }
            // Degenerate defaults (short trace): the run was full-detail
            // and must agree exactly.
            None => assert_eq!(sampled.cycles, full.cycles, "{kernel:?}"),
        }
    }
}

/// (d) A sampled job and a full-detail job for the same cell have
/// different `CellKey`s (`SampleConfig` is part of the config identity):
/// they never share a cached result, while resubmissions of each still
/// hit their own entry.
#[test]
fn sampled_and_full_jobs_never_collide_in_the_service_cache() {
    let svc = SimService::new(ServiceConfig { jobs: 1, ..ServiceConfig::default() });
    let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 1 << 20);
    let mut sampled_cfg = SystemConfig::default();
    sampled_cfg.sample.enabled = true;

    let full = svc.submit(Job::new(p)).wait().unwrap();
    let sampled = svc.submit(Job::new(p).with_cfg(sampled_cfg.clone())).wait().unwrap();
    assert!(full.report.get("sample.windows").is_none());
    assert!(
        sampled.report.get("sample.windows").unwrap_or(0.0) >= 1.0,
        "sampled job must run the sampled engine"
    );
    let stats = svc.stats();
    assert_eq!(stats.unique_runs, 2, "sampled and full cells must simulate separately");
    assert_eq!(stats.cache_hits, 0);

    // Resubmitting each flavor is a pure hit on its own cell.
    let full2 = svc.submit(Job::new(p)).wait().unwrap();
    let sampled2 = svc.submit(Job::new(p).with_cfg(sampled_cfg)).wait().unwrap();
    assert_eq!(full2.cycles, full.cycles);
    assert_eq!(sampled2.cycles, sampled.cycles);
    let stats = svc.stats();
    assert_eq!(stats.unique_runs, 2);
    assert_eq!(stats.cache_hits, 2);
}

/// Satellite regression pin: `run_on` now evaluates the trace-level
/// sampling factor on the cell's own parameters instead of a hardcoded
/// `with_threads(0, 1)` view. Every single-thread cell (all of fig2, fig3
/// and fig5) and fig4's 1/2/4/8-thread cells are bit-unchanged; at 16/32
/// threads MatMul's per-thread row cap floors at 6, so the factor now
/// matches the rows each thread actually emits — the historical view
/// overestimated extrapolated cycles there (intentional fix, documented
/// in DESIGN.md §11).
#[test]
fn sampling_scale_matches_single_thread_view() {
    // Figs 2/3/5 grids: single-thread cells across the whole matrix.
    for w in WorkloadSet::all(SizeScale::Paper) {
        for backend in [Backend::Avx, Backend::Vima] {
            let p = RunCell::new(w, backend).params();
            let wl = workload::get(p.workload).unwrap();
            assert_eq!(
                wl.sampling_scale(&p),
                wl.sampling_scale(&p.with_threads(0, 1)),
                "single-thread cell changed: {} {backend:?}",
                wl.name()
            );
        }
    }
    // Fig 4 grid: multithreaded AVX on the largest Stencil/VecSum/MatMul.
    for w in WorkloadSet::multithread(SizeScale::Paper) {
        let wl = {
            let p = RunCell::new(w, Backend::Avx).params();
            workload::get(p.workload).unwrap()
        };
        for threads in [1usize, 2, 4, 8] {
            let p = RunCell::new(w, Backend::Avx).with_threads(threads).params();
            assert_eq!(
                wl.sampling_scale(&p),
                wl.sampling_scale(&p.with_threads(0, 1)),
                "fig4 cell changed: {} x{threads}",
                wl.name()
            );
        }
        for threads in [16usize, 32] {
            let p = RunCell::new(w, Backend::Avx).with_threads(threads).params();
            let actual = wl.sampling_scale(&p);
            let single = wl.sampling_scale(&p.with_threads(0, 1));
            assert!(
                actual <= single,
                "deep-thread factor must not exceed the historical view: \
                 {} x{threads} ({actual} vs {single})",
                wl.name()
            );
        }
    }
}
