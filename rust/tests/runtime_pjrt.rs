//! Integration tests of the PJRT functional runtime against the AOT
//! artifacts. Requires `make artifacts`; each test skips (with a notice)
//! when the artifacts directory is missing so `cargo test` works before the
//! Python toolchain has run.

use vima_sim::isa::{TraceEvent, VDtype, VimaInstr, VimaOp};
use vima_sim::runtime::functional::FunctionalVima;
use vima_sim::runtime::{default_artifacts_dir, literal_f32, Engine};
use vima_sim::trace::{layout, Backend, KernelId, TraceParams};
use vima_sim::util::Rng;

fn engine() -> Option<Engine> {
    match Engine::new(default_artifacts_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping PJRT test: {err}");
            None
        }
    }
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32(-10.0, 10.0)).collect()
}

#[test]
fn vadd_matches_rust() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(1);
    let a = randv(&mut rng, 2048);
    let b = randv(&mut rng, 2048);
    let out = e.execute_f32("vadd_f32", &[&a, &b]).unwrap();
    for i in 0..2048 {
        assert!((out[i] - (a[i] + b[i])).abs() < 1e-5, "elem {i}");
    }
}

#[test]
fn vfma_and_vdot_match_rust() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(2);
    let a = randv(&mut rng, 2048);
    let b = randv(&mut rng, 2048);
    let c = randv(&mut rng, 2048);
    let fma = e.execute_f32("vfma_f32", &[&a, &b, &c]).unwrap();
    for i in 0..2048 {
        assert!((fma[i] - (a[i] * b[i] + c[i])).abs() < 1e-3, "fma elem {i}");
    }
    let dot = e.execute_f32("vdot_f32", &[&a, &b]).unwrap();
    let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
    assert!((dot[0] - want).abs() / want.abs().max(1.0) < 1e-3, "{} vs {want}", dot[0]);
}

#[test]
fn vecsum_workload_artifact_matches() {
    let Some(mut e) = engine() else { return };
    let mut rng = Rng::new(3);
    let n = 16 * 2048;
    let a = randv(&mut rng, n);
    let b = randv(&mut rng, n);
    let out = e.execute_f32("vecsum_f32", &[&a, &b]).unwrap();
    for i in (0..n).step_by(97) {
        assert!((out[i] - (a[i] + b[i])).abs() < 1e-5);
    }
}

#[test]
fn stencil2d_artifact_matches_reference() {
    let Some(mut e) = engine() else { return };
    let (h, w) = (64usize, 2048usize);
    let mut rng = Rng::new(4);
    let x = randv(&mut rng, h * w);
    let out = e.execute_f32("stencil2d_f32", &[&x]).unwrap();
    // 5-point stencil oracle with zero boundary, cc=0.5 cn=0.125
    let get = |r: i64, c: i64| -> f32 {
        if r < 0 || c < 0 || r >= h as i64 || c >= w as i64 {
            0.0
        } else {
            x[r as usize * w + c as usize]
        }
    };
    for (r, c) in [(0i64, 0i64), (1, 1), (31, 1000), (63, 2047), (17, 512)] {
        let want = 0.5 * get(r, c)
            + 0.125 * (get(r - 1, c) + get(r + 1, c) + get(r, c - 1) + get(r, c + 1));
        let got = out[r as usize * w + c as usize];
        assert!((got - want).abs() < 1e-4, "({r},{c}): {got} vs {want}");
    }
}

#[test]
fn matmul_artifact_matches_reference() {
    let Some(mut e) = engine() else { return };
    let n = 256usize;
    let mut rng = Rng::new(5);
    let a = randv(&mut rng, n * n);
    let b = randv(&mut rng, n * n);
    let out = e.execute_f32("matmul_f32", &[&a, &b]).unwrap();
    // spot-check a handful of entries
    for &(i, j) in &[(0usize, 0usize), (1, 2), (100, 200), (255, 255)] {
        let want: f32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
        let got = out[i * n + j];
        assert!((got - want).abs() / want.abs().max(1.0) < 1e-3, "({i},{j}): {got} vs {want}");
    }
}

#[test]
fn knn_dist_artifact_matches_reference() {
    let Some(mut e) = engine() else { return };
    let (r, f) = (256usize, 512usize);
    let mut rng = Rng::new(6);
    let test = randv(&mut rng, f);
    let train = randv(&mut rng, r * f);
    let out = e.execute_f32("knn_dist_f32", &[&test, &train]).unwrap();
    for &row in &[0usize, 17, 128, 255] {
        let want: f32 =
            (0..f).map(|c| (train[row * f + c] - test[c]).powi(2)).sum();
        assert!((out[row] - want).abs() / want.max(1.0) < 1e-3, "row {row}");
    }
}

#[test]
fn functional_vima_replays_stencil_trace() {
    // Execute the *actual VIMA instruction stream* of the Stencil trace
    // through PJRT and compare to a direct Rust stencil.
    let Some(e) = engine() else { return };
    let mut fx = FunctionalVima::new(e);
    let w = 2048usize;
    let rows = 6u64; // interior rows 1..5 in a (footprint/2/8K)-row matrix
    let mut rng = Rng::new(7);
    let matrix: Vec<Vec<f32>> = (0..rows).map(|_| randv(&mut rng, w)).collect();
    for (r, row) in matrix.iter().enumerate() {
        fx.write_vector(layout::A + r as u64 * 8192, row.clone());
    }
    // The coefficient broadcast carries no immediate in the trace; the
    // generator uses cn = 0.125 semantically.
    fx.bcast_value = 0.125;

    let p = TraceParams::new(KernelId::Stencil, Backend::Vima, 2 * rows * 8192);
    for ev in p.stream().unwrap() {
        if let TraceEvent::Vima(instr) = ev {
            fx.execute(&instr).unwrap();
        }
    }
    // Trace semantics: out = fma(cur, coeff, cn*(up+down+cur+cur))
    // (left/right alias the aligned center vector; see trace/stencil.rs).
    for r in 1..(rows as usize - 1) {
        let out = fx.read_vector(layout::B + r as u64 * 8192).expect("row result");
        for i in (0..w).step_by(191) {
            let t3 = matrix[r - 1][i] + matrix[r + 1][i] + 2.0 * matrix[r][i];
            let want = matrix[r][i] * 0.125 + t3 * 0.125;
            assert!((out[i] - want).abs() < 1e-3, "row {r} elem {i}: {} vs {want}", out[i]);
        }
    }
    assert!(fx.executed > 0);
}

#[test]
fn bcast_uses_driver_value() {
    let Some(e) = engine() else { return };
    let mut fx = FunctionalVima::new(e);
    fx.bcast_value = 42.5;
    let i = VimaInstr::new(VimaOp::Bcast, VDtype::F32, &[], Some(0x8000), 8192);
    fx.execute(&i).unwrap();
    let v = fx.read_vector(0x8000).unwrap();
    assert_eq!(v.len(), 2048);
    assert!(v.iter().all(|&x| x == 42.5));
}

#[test]
fn engine_rejects_bad_inputs() {
    let Some(mut e) = engine() else { return };
    assert!(e.execute_f32("no_such_artifact", &[]).is_err());
    let short = vec![1.0f32; 3];
    assert!(e.execute_f32("vadd_f32", &[&short, &short]).is_err());
    // wrong arity through the literal API
    let lit = literal_f32(&vec![0.0; 2048], &[2048]).unwrap();
    assert!(e.execute("vadd_f32", &[lit]).is_err());
}

#[test]
fn engine_caches_compiled_executables() {
    let Some(mut e) = engine() else { return };
    let a = vec![1.0f32; 2048];
    assert_eq!(e.compiled_count(), 0);
    e.execute_f32("vadd_f32", &[&a, &a]).unwrap();
    assert_eq!(e.compiled_count(), 1);
    e.execute_f32("vadd_f32", &[&a, &a]).unwrap();
    assert_eq!(e.compiled_count(), 1);
}
